"""Paper Fig. 6.1(a): pivot-search time vs iteration index j, plus the
seed-vs-fused/chunked hot-path comparison.

The paper's claim: with the Eq. (6.3) running-sum update, the pivot search
is O(2MN) per iteration, INDEPENDENT of j.  We measure T_j^pivot/N for a
range of N and check flatness across j.

The hot-path rows time the production shape (N=4096, M=16384, f32) through
two drivers:

  fig6.1a_hotpath_seed   — the seed per-step driver (one jitted step plus
                           ``float(errs[k-1])``/``float(rnorms[k-1])``
                           host syncs per basis vector, single stream),
  fig6.1a_hotpath_fused  — the chunked device-resident driver: C iterations
                           per jitted ``lax.while_loop``, hot primitives
                           routed through ``repro.core.backend``, snapshot
                           columns sharded over every available device
                           (``benchmarks/run.py`` forces one host device
                           per core — XLA does not thread the GEMV sweep).

Per-iteration cost is measured by differencing two driver runs (K2 - K1
iterations), which cancels init/compile/fixed overheads exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, steady_min as _steady_min, time_fn
from repro.core.greedy import greedy_init, _jitted_step


def run(csv: bool = True):
    # Hot-path comparison first: it is the acceptance-tracked row and wants
    # the process in its quietest state (no leftover benchmark arrays).
    hotpath = run_hotpath(csv=csv)
    M = 2000
    results = []
    for N in (256, 1024, 4096):
        rng = np.random.default_rng(0)
        S = jnp.asarray(rng.standard_normal((N, M)), jnp.float32)
        state = greedy_init(S, 64)
        times = {}
        for j in range(48):
            t = time_fn(lambda: _jitted_step(S, state), warmup=1, iters=3)
            if j in (4, 16, 32, 44):
                times[j] = t
            state = _jitted_step(S, state)
        scaled = {j: t / N * 1e9 for j, t in times.items()}
        flatness = max(scaled.values()) / max(min(scaled.values()), 1e-12)
        results.append((N, scaled, flatness))
        if csv:
            emit(
                f"fig6.1a_pivot_N{N}",
                np.mean(list(times.values())) * 1e6,
                f"T_j/N[ns]@j4/16/32/44="
                + "/".join(f"{scaled[j]:.2f}" for j in (4, 16, 32, 44))
                + f";flatness={flatness:.2f}",
            )
    results.append(hotpath)
    return results


def run_hotpath(csv: bool = True, N: int = 4096, M: int = 16384,
                chunk: int = 8, max_k: int = 64):
    """Seed per-step driver vs chunked/fused hot loop at the production
    shape, for the GW production dtype (complex64 — the paper's Sec. 6.1.4
    workload) and real float32.

    Measures the steady-state per-iteration cost of each hot-loop form by
    repeated application from a fixed state (the Eq.-6.3 cost is
    j-independent — that is Fig. 6.1a's point, asserted by the flatness
    rows — so iterating from k=0 is representative):

      seed    one jitted seed-implementation step (``backend="xla_ref"``:
              complex GEMV and all) + the seed driver's per-iteration host
              work (``int(k)``, ``float(errs)``, ``float(rnorms)`` syncs),
      chunked ``chunk`` iterations inside one jitted while_loop + the
              chunk-boundary host work (two scalar syncs), single device,
              plane-split complex sweeps (the `xla` backend),
      fused   the same chunk through the column-sharded distributed driver
              over all available devices (the production hot path),
      blocked the panel-blocked chunk (``repro.core.block_greedy``): p
              pivots per Eq.-(6.3) sweep, ONE (p,N)x(N,M) panel GEMM per
              block — the BLAS-3 path that lifts the f32 sweep off the
              DRAM roof (time reported PER BASIS for comparability).
    """
    out = {}
    for dtype, suffix, primary in ((jnp.complex64, "", True),
                                   (jnp.float32, "_f32", False)):
        out[str(jnp.dtype(dtype))] = _hotpath_one_dtype(
            csv=csv, N=N, M=M, chunk=chunk, max_k=max_k, dtype=dtype,
            suffix=suffix, primary=primary,
        )
    return out


def _hotpath_one_dtype(csv, N, M, chunk, max_k, dtype, suffix, primary):
    from repro.core.greedy import _greedy_chunk  # module top imports the rest

    rng = np.random.default_rng(0)
    cplx = jnp.issubdtype(dtype, jnp.complexfloating)
    S = rng.standard_normal((N, M))
    if cplx:
        S = S + 1j * rng.standard_normal((N, M))
    S = jnp.asarray(S, dtype)
    rdt = jnp.float32
    state0 = greedy_init(S, max_k)
    jax.block_until_ready(state0)

    # Seed-faithful baseline: the reference ops the seed shipped (complex
    # GEMV included) at the seed driver's per-iteration host-sync cadence.
    def seed_iter():
        st = _jitted_step(S, state0, backend="xla_ref")
        k = int(st.k)
        _ = float(st.errs[k - 1])
        _ = float(st.rnorms[k - 1])
        return st

    # complex-GEMV steps are ~40x slower; fewer repeats keep CI time sane
    t_seed = _steady_min(seed_iter, 1, repeats=(6 if cplx else 4 * chunk),
                         warmup=2)

    # stop thresholds that never fire (pure hot-loop measurement)
    consts = (jnp.asarray(0.0, rdt), jnp.asarray(1e6, rdt),
              jnp.asarray(1e12, rdt), jnp.asarray(100.0, rdt))

    def chunk_iter():
        st, n_done, stop = _greedy_chunk(S, state0, *consts, chunk=chunk,
                                         check_refresh=False)
        _ = int(n_done), int(stop)
        return st

    t_chunk1 = _steady_min(chunk_iter, chunk, repeats=(6 if cplx else 12))

    # Panel-blocked chunk: BLOCK_CHUNK blocks x BLOCK_P bases per
    # application; per-basis time is what competes with the rows above.
    from repro.core.block_greedy import _block_chunk

    BLOCK_P, BLOCK_CHUNK = 8, 2

    def blocked_iter():
        st, n_done, stop = _block_chunk(
            S, state0, *consts, chunk=BLOCK_CHUNK, p=BLOCK_P,
            backend="xla", check_refresh=False,
        )
        _ = int(n_done), int(stop)
        return st

    t_blocked = _steady_min(blocked_iter, BLOCK_P * BLOCK_CHUNK,
                            repeats=(6 if cplx else 12))
    piv_blocked = int(blocked_iter().pivots[0])

    n_dev = len(jax.devices())
    if n_dev > 1 and M % n_dev == 0:
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.core.distributed import (
            dist_greedy_init, make_dist_greedy_chunk,
        )

        mesh = Mesh(np.asarray(jax.devices()), ("cols",))
        S_sh = jax.device_put(S, NamedSharding(mesh, P(None, ("cols",))))
        dstate0 = dist_greedy_init(S_sh, max_k, mesh)
        jax.block_until_ready(dstate0)
        dchunk = make_dist_greedy_chunk(mesh, chunk, check_refresh=False,
                                        donate=False)

        def fused_iter():
            st, n_done, stop = dchunk(S_sh, dstate0, *consts)
            _ = int(n_done), int(stop)
            return st

        t_fused = _steady_min(fused_iter, chunk, repeats=(6 if cplx else 12))
        piv_fused = int(fused_iter().pivots[0])
        fused_label = f"chunked+sharded(P={n_dev},C={chunk})"
    else:
        t_fused = t_chunk1
        piv_fused = int(chunk_iter().pivots[0])
        fused_label = f"chunked(P=1,C={chunk})"

    speedup = t_seed / max(t_fused, 1e-12)
    # both forms must select the same first pivot from the same state
    pivots_equal = bool(piv_fused == int(seed_iter().pivots[0]))
    dt_name = str(jnp.dtype(dtype))
    if csv:
        emit(f"fig6.1a_hotpath_seed_N{N}_M{M}{suffix}", t_seed * 1e6,
             f"dtype={dt_name};seed per-step driver (ref ops + err/rnorm "
             f"sync per basis)")
        emit(f"fig6.1a_hotpath_fused_N{N}_M{M}{suffix}", t_fused * 1e6,
             f"dtype={dt_name};{fused_label};"
             f"speedup_vs_seed={speedup:.2f}x;pivots_equal={pivots_equal}")
        emit(f"fig6.1a_hotpath_chunked1dev_N{N}_M{M}{suffix}",
             t_chunk1 * 1e6,
             f"dtype={dt_name};chunked(P=1,C={chunk});"
             f"speedup_vs_seed={t_seed / max(t_chunk1, 1e-12):.2f}x")
        emit(f"fig6.1a_hotpath_blocked_N{N}_M{M}{suffix}",
             t_blocked * 1e6,
             f"dtype={dt_name};blocked(p={BLOCK_P},C={BLOCK_CHUNK});"
             f"us_per_basis;one S read per {BLOCK_P} bases;"
             f"speedup_vs_seed={t_seed / max(t_blocked, 1e-12):.2f}x;"
             f"first_pivot_equal={piv_blocked == int(seed_iter().pivots[0])}")
    return {
        "t_seed_us": t_seed * 1e6,
        "t_fused_us": t_fused * 1e6,
        "t_chunked_1dev_us": t_chunk1 * 1e6,
        "t_blocked_us": t_blocked * 1e6,
        "speedup": speedup,
        "speedup_blocked": t_seed / max(t_blocked, 1e-12),
        "pivots_equal": pivots_equal,
    }


if __name__ == "__main__":
    run()
