"""Paper Fig. 6.1(a): pivot-search time vs iteration index j.

The paper's claim: with the Eq. (6.3) running-sum update, the pivot search
is O(2MN) per iteration, INDEPENDENT of j.  We measure T_j^pivot/N for a
range of N and check flatness across j.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.greedy import greedy_init, _jitted_step


def run(csv: bool = True):
    M = 2000
    results = []
    for N in (256, 1024, 4096):
        rng = np.random.default_rng(0)
        S = jnp.asarray(rng.standard_normal((N, M)), jnp.float32)
        state = greedy_init(S, 64)
        times = {}
        for j in range(48):
            t = time_fn(lambda: _jitted_step(S, state), warmup=1, iters=3)
            if j in (4, 16, 32, 44):
                times[j] = t
            state = _jitted_step(S, state)
        scaled = {j: t / N * 1e9 for j, t in times.items()}
        flatness = max(scaled.values()) / max(min(scaled.values()), 1e-12)
        results.append((N, scaled, flatness))
        if csv:
            emit(
                f"fig6.1a_pivot_N{N}",
                np.mean(list(times.values())) * 1e6,
                f"T_j/N[ns]@j4/16/32/44="
                + "/".join(f"{scaled[j]:.2f}" for j in (4, 16, 32, 44))
                + f";flatness={flatness:.2f}",
            )
    return results


if __name__ == "__main__":
    run()
