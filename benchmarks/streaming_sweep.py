"""Out-of-core streaming greedy smoke benchmark.

Builds a reduced basis from a MEMMAPPED complex64 snapshot matrix whose
column count M is >= 8x the resident tile width — the paper's "matrix too
large to load into memory" scenario at smoke scale — and compares against
the in-memory chunked driver on the same matrix.  Emits BENCH-style rows
(see benchmarks/common.emit); run standalone to write
``BENCH_streaming.json`` for the CI artifact.

Peak device allocation of the streamed build is O(N * (max_k + 2*tile_m)):
basis Q plus the current and prefetched tiles (the `device_bytes_bound`
annotation), independent of M.  Shape overrides: REPRO_STREAM_N /
REPRO_STREAM_M / REPRO_STREAM_TILE; REPRO_STREAM_REPEATS for best-of-N;
REPRO_STREAM_BLOCK_P for the blocked-stream row's panel width.
"""

from __future__ import annotations

import math
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

N = int(os.environ.get("REPRO_STREAM_N", 512))
M = int(os.environ.get("REPRO_STREAM_M", 8192))
TILE_M = int(os.environ.get("REPRO_STREAM_TILE", M // 8))
TAU = 1e-6
MAX_K = 48
REPEATS = int(os.environ.get("REPRO_STREAM_REPEATS", 3))
BLOCK_P = int(os.environ.get("REPRO_STREAM_BLOCK_P", 4))


def _smooth_complex_matrix(n: int, m: int) -> np.ndarray:
    """Vectorized smooth family (fast-decaying n-width), complex64."""
    x = np.linspace(0.0, 1.0, n)[:, None]
    nu = np.linspace(0.5, 2.0, m)[None, :]
    S = np.sin(2 * np.pi * nu * x) * np.exp(-nu * x) * np.exp(1j * nu * x)
    return S.astype(np.complex64)


def run(csv: bool = False) -> None:
    from repro.api import ReductionSpec, build_basis
    from repro.data import MemmapProvider, write_snapshot_npy

    del csv
    S_host = _smooth_complex_matrix(N, M)
    itemsize = S_host.dtype.itemsize

    with tempfile.TemporaryDirectory() as td:
        path = write_snapshot_npy(os.path.join(td, "S.npy"), S_host)
        del S_host  # from here on the matrix lives only on disk
        prov = MemmapProvider(path)
        spec_stream = ReductionSpec(source=prov, strategy="streamed",
                                    tau=TAU, max_k=MAX_K, tile_m=TILE_M,
                                    keep_R=False)

        # warm once (jit compilation excluded from the tracked rows), then
        # best-of-N: single-shot wall clock on the shared CI box swings
        # ~±40%, best-of-N steady state is the stable method (see
        # benchmarks/pivot_timing)
        build_basis(spec_stream)
        t_stream = math.inf
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            stream = build_basis(spec_stream)
            t_stream = min(t_stream, time.perf_counter() - t0)

        # Blocked stream: each transferred tile serves BLOCK_P bases (the
        # stream is transfer-bound, so this attacks the overhead head-on)
        spec_blocked = ReductionSpec(source=prov, strategy="streamed",
                                     tau=TAU, max_k=MAX_K, tile_m=TILE_M,
                                     block_p=BLOCK_P, keep_R=False)
        build_basis(spec_blocked)
        t_blocked = math.inf
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            blocked = build_basis(spec_blocked)
            t_blocked = min(t_blocked, time.perf_counter() - t0)

        S_dev = jnp.asarray(np.load(path))
        spec_res = ReductionSpec(source=S_dev, strategy="greedy", tau=TAU,
                                 max_k=MAX_K)
        res = build_basis(spec_res)
        jax.block_until_ready(res.Q)
        t_resident = math.inf
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            res = build_basis(spec_res)
            jax.block_until_ready(res.Q)
            t_resident = min(t_resident, time.perf_counter() - t0)

    k = res.k
    n_tiles = -(-M // TILE_M)
    match = (stream.k == k and
             np.array_equal(stream.pivots, np.asarray(res.pivots)))
    # current tile + prefetched next tile are both device-resident
    device_bytes_bound = N * (MAX_K + 2 * TILE_M + 2) * itemsize
    ratio = t_stream / max(t_resident, 1e-9)
    emit(
        "stream_build_c64_memmap", t_stream * 1e6,
        derived=(f"N={N},M={M},tile_m={TILE_M},tiles={n_tiles},"
                 f"M_over_tile={M // TILE_M},k={stream.k},"
                 f"device_bytes_bound={device_bytes_bound},"
                 f"pivots_match_resident={match},"
                 f"overhead_vs_resident={ratio:.2f}x (next-tile prefetch "
                 f"overlaps host<->device copies with the sweep)"),
    )
    emit("stream_resident_baseline_c64", t_resident * 1e6,
         derived=f"k={k} (device-resident build_basis strategy='greedy', "
                 f"warm)")
    # Blocked-stream row: amortizes host->device tile traffic by BLOCK_P.
    # Pivot staleness means extra bases vs the stepwise stream, so the
    # check is approximation quality: the blocked basis must reach the
    # error the resident baseline actually achieved (this c64 shape floors
    # above the nominal tau at the f32-precision rank guard, for EVERY
    # driver — only the achieved error is comparable).
    from repro.core.errors import proj_error_max

    res_err = float(proj_error_max(S_dev, res.Q))
    blocked_err = float(proj_error_max(S_dev, blocked.Q))
    quality_ok = blocked_err <= max(TAU, 2.0 * res_err)
    ratio_blocked = t_blocked / max(t_resident, 1e-9)
    emit(
        f"stream_build_c64_memmap_blocked_p{BLOCK_P}", t_blocked * 1e6,
        derived=(f"N={N},M={M},tile_m={TILE_M},block_p={BLOCK_P},"
                 f"k={blocked.k},proj_err={blocked_err:.2e} (resident "
                 f"{res_err:.2e}),overhead_vs_resident="
                 f"{ratio_blocked:.2f}x (one tile transfer per {BLOCK_P} "
                 f"bases; stepwise stream above is {ratio:.2f}x)"),
    )
    if not match:
        raise RuntimeError(
            "streamed pivots diverged from the resident driver — parity "
            "violation, see tests/test_streaming.py"
        )
    if not quality_ok:
        raise RuntimeError(
            f"blocked streamed build quality regressed: proj_err "
            f"{blocked_err:.3e} vs resident {res_err:.3e} — see "
            f"tests/test_streaming.py blocked-mode suite"
        )


def main() -> None:
    from benchmarks.common import write_bench_json

    print("name,us_per_call,derived")
    run(csv=True)
    out = os.environ.get("REPRO_STREAM_BENCH_JSON", "BENCH_streaming.json")
    n_rows = write_bench_json(out)
    print(f"# wrote {n_rows} rows to {out}")


if __name__ == "__main__":
    main()
