"""Out-of-core streaming greedy smoke benchmark.

Builds a reduced basis from a MEMMAPPED complex64 snapshot matrix whose
column count M is >= 8x the resident tile width — the paper's "matrix too
large to load into memory" scenario at smoke scale — and compares against
the in-memory chunked driver on the same matrix.  Emits BENCH-style rows
(see benchmarks/common.emit); run standalone to write
``BENCH_streaming.json`` for the CI artifact.

Peak device allocation of the streamed build is O(N * (max_k + tile_m)):
basis Q plus one tile (the `device_bytes_bound` annotation), independent
of M.  Shape overrides: REPRO_STREAM_N / REPRO_STREAM_M / REPRO_STREAM_TILE.
"""

from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

N = int(os.environ.get("REPRO_STREAM_N", 512))
M = int(os.environ.get("REPRO_STREAM_M", 8192))
TILE_M = int(os.environ.get("REPRO_STREAM_TILE", M // 8))
TAU = 1e-6
MAX_K = 48


def _smooth_complex_matrix(n: int, m: int) -> np.ndarray:
    """Vectorized smooth family (fast-decaying n-width), complex64."""
    x = np.linspace(0.0, 1.0, n)[:, None]
    nu = np.linspace(0.5, 2.0, m)[None, :]
    S = np.sin(2 * np.pi * nu * x) * np.exp(-nu * x) * np.exp(1j * nu * x)
    return S.astype(np.complex64)


def run(csv: bool = False) -> None:
    from repro.core import rb_greedy, rb_greedy_streamed
    from repro.data import MemmapProvider, write_snapshot_npy

    del csv
    S_host = _smooth_complex_matrix(N, M)
    itemsize = S_host.dtype.itemsize

    with tempfile.TemporaryDirectory() as td:
        path = write_snapshot_npy(os.path.join(td, "S.npy"), S_host)
        del S_host  # from here on the matrix lives only on disk
        prov = MemmapProvider(path)

        # warm both paths once (jit compilation excluded from the tracked
        # rows; wall-clock trend tracking needs compile noise out)
        rb_greedy_streamed(prov, tau=TAU, max_k=MAX_K, tile_m=TILE_M,
                           keep_R=False)
        t0 = time.perf_counter()
        stream = rb_greedy_streamed(prov, tau=TAU, max_k=MAX_K,
                                    tile_m=TILE_M, keep_R=False)
        t_stream = time.perf_counter() - t0

        S_dev = jnp.asarray(np.load(path))
        res = rb_greedy(S_dev, tau=TAU, max_k=MAX_K)
        jax.block_until_ready(res.Q)
        t0 = time.perf_counter()
        res = rb_greedy(S_dev, tau=TAU, max_k=MAX_K)
        jax.block_until_ready(res.Q)
        t_resident = time.perf_counter() - t0

    k = int(res.k)
    match = (stream.k == k and
             np.array_equal(stream.pivots[:k], np.asarray(res.pivots[:k])))
    device_bytes_bound = N * (MAX_K + TILE_M + 2) * itemsize
    ratio = t_stream / max(t_resident, 1e-9)
    emit(
        "stream_build_c64_memmap", t_stream * 1e6,
        derived=(f"N={N},M={M},tile_m={TILE_M},tiles={stream.n_tiles},"
                 f"M_over_tile={M // TILE_M},k={stream.k},"
                 f"device_bytes_bound={device_bytes_bound},"
                 f"pivots_match_resident={match},"
                 f"overhead_vs_resident={ratio:.2f}x (host<->device tile "
                 f"copies dominate on CPU at smoke shape)"),
    )
    emit("stream_resident_baseline_c64", t_resident * 1e6,
         derived=f"k={k} (fully device-resident rb_greedy, warm)")
    if not match:
        raise RuntimeError(
            "streamed pivots diverged from the resident driver — parity "
            "violation, see tests/test_streaming.py"
        )


def main() -> None:
    from benchmarks.common import write_bench_json

    print("name,us_per_call,derived")
    run(csv=True)
    out = os.environ.get("REPRO_STREAM_BENCH_JSON", "BENCH_streaming.json")
    n_rows = write_bench_json(out)
    print(f"# wrote {n_rows} rows to {out}")


if __name__ == "__main__":
    main()
