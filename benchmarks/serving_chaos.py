"""ROQ serving chaos harness: graceful degradation under injected faults.

Where ``serving_load.py`` measures the engine at its best, this harness
measures it at its worst — five scenarios, each an injected failure mode
with a hard invariant gate (the run FAILS if the engine hangs a future,
serves a wrong bit, or degrades silently):

  serving_chaos_overload       — offered load far past capacity (slow
                                 batches via REPRO_FAULT_SERVE_SLOW_BATCH,
                                 tight queue, mixed deadlines): every
                                 submit resolves exactly one way (bitwise
                                 result / QueueFullError / ShedError /
                                 TimeoutError), counters sum to the
                                 offered load, degraded mode engages.
  serving_chaos_worker_kill    — REPRO_FAULT_SERVE_KILL_WORKER mid-
                                 traffic: the dying batch fails with
                                 EngineUnhealthyError (never hangs),
                                 supervision restarts the worker, and the
                                 row records time-to-recovery.
  serving_chaos_breaker        — one basis made unloadable
                                 (REPRO_FAULT_SERVE_RAISE_AT_LOAD): its
                                 breaker opens after the threshold and
                                 fast-fails, the healthy basis keeps
                                 serving bitwise, and once the fault
                                 clears a half-open probe closes the
                                 breaker.
  serving_chaos_hot_reload     — ``refresh()`` swaps a rebuilt artifact
                                 mid-traffic: generation bumps, ZERO
                                 in-flight failures, every response
                                 bitwise vs the generation it was served
                                 under.
  serving_chaos_corrupt_reload — the reload candidate is corrupt
                                 (REPRO_FAULT_SERVE_CORRUPT_RELOAD):
                                 refresh rejects it, the live basis keeps
                                 serving untouched.

Run standalone to MERGE rows into ``BENCH_serving.json`` (env override
``REPRO_SERVING_BENCH_JSON``); a full per-scenario metrics snapshot goes
to ``REPRO_SERVING_SNAPSHOT_JSON`` (default
``serving_chaos_metrics.json``, a CI artifact — not committed).
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

from benchmarks.common import emit

N = int(os.environ.get("REPRO_CHAOS_N", 512))
M = int(os.environ.get("REPRO_CHAOS_M", 128))
MAX_K = int(os.environ.get("REPRO_CHAOS_MAX_K", 8))
OFFERED = int(os.environ.get("REPRO_CHAOS_OFFERED", 400))
SLOW_MS = float(os.environ.get("REPRO_CHAOS_SLOW_MS", 3.0))

WAIT_S = 30.0
_SNAPSHOTS: dict[str, dict] = {}


def _gate(ok: bool, msg: str) -> None:
    if not ok:
        raise RuntimeError(f"chaos invariant violated: {msg}")


def _smooth(n, m, dtype, phase=0.0):
    x = np.linspace(0.0, 1.0, n)[:, None]
    nu = np.linspace(0.5, 2.0, m)[None, :]
    S = np.sin(2 * np.pi * nu * x + phase) * np.exp(-nu * x)
    if np.issubdtype(dtype, np.complexfloating):
        S = S * np.exp(1j * nu * x)
    return S.astype(dtype)


def _build(root: str, name: str, phase=0.0, dtype=np.float32) -> str:
    from repro.api import build_basis

    basis = build_basis(source=_smooth(N, M, dtype, phase=phase),
                        strategy="greedy", tau=1e-12, max_k=MAX_K)
    d = os.path.join(root, name)
    basis.save(d)
    return d


def _reqs(basis, n, seed=0):
    rng = np.random.default_rng(seed)
    dtype = np.asarray(basis.Q).dtype
    f = rng.standard_normal((basis.k, n))
    if np.issubdtype(dtype, np.complexfloating):
        f = f + 1j * rng.standard_normal((basis.k, n))
    return f.astype(dtype)


def _wait_until(cond, timeout=WAIT_S, step=0.002):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if cond():
            return True
        time.sleep(step)
    return False


class _env:
    """Scoped env injection: faults never leak into the next scenario."""

    def __init__(self, **kv):
        self.kv = kv

    def __enter__(self):
        self.old = {k: os.environ.get(k) for k in self.kv}
        for k, v in self.kv.items():
            os.environ[k] = str(v)

    def __exit__(self, *exc):
        for k, v in self.old.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


# ------------------------------------------------------------- scenarios ----

def scenario_overload(dirs) -> None:
    from repro.serving import (
        QueueFullError, ROQEngine, ShedError, direct_interpolate)

    with _env(REPRO_FAULT_SERVE_SLOW_BATCH=SLOW_MS):
        eng = ROQEngine({"a": dirs["a"]}, max_batch=4, max_wait_ms=1.0,
                        queue_depth=16, degrade_queue_frac=0.5)
        basis, eim = eng.router.get("a")
        pool = _reqs(basis, 64, seed=1)
        rng = np.random.default_rng(2)
        shed = queue_full = 0
        accepted = []
        t0 = time.perf_counter()
        for i in range(OFFERED):
            if i % 8 == 0:
                time.sleep(0.004)   # offered ~2x capacity, not infinity:
                # the worker gets cycles, the EWMA warms, and the
                # backlog-based paths (shed, degraded mode) can engage
                # instead of queue-full absorbing everything
            col = i % pool.shape[1]
            timeout = None if rng.random() < 0.5 else \
                float(rng.choice([0.002, 0.05, 10.0]))
            try:
                fut = eng.submit("a", pool[:, col], timeout_s=timeout)
            except ShedError:
                shed += 1
            except QueueFullError:
                queue_full += 1
            else:
                accepted.append((fut, col))
        eng.close(drain=True)
        wall = time.perf_counter() - t0

    served = timed_out = mismatches = 0
    for fut, col in accepted:
        err = fut.exception(timeout=WAIT_S)
        if err is None:
            served += 1
            if not np.array_equal(fut.result(),
                                  direct_interpolate(eim, pool[:, col])):
                mismatches += 1
        elif isinstance(err, TimeoutError):
            timed_out += 1
        else:
            _gate(False, f"unexpected overload resolution: {err!r}")

    c = eng.stats()["counters"]
    _SNAPSHOTS["overload"] = eng.stats()
    _gate(served + timed_out + shed + queue_full == OFFERED,
          "overload submits did not all resolve exactly once")
    _gate(mismatches == 0, f"{mismatches} wrong-bit responses under load")
    _gate(c["submitted"] == c["completed"] + c["timeouts"] + c["errors"],
          "metrics counters do not sum to accepted load")
    _gate(shed + queue_full > 0, "no explicit rejections under 25x load")
    emit("serving_chaos_overload", wall / OFFERED * 1e6,
         derived=(f"offered={OFFERED},served={served},shed={shed},"
                  f"queue_full={queue_full},timeouts={timed_out},"
                  f"mismatches=0,degraded_entered="
                  f"{c['degraded_entered']},resolved=100%"))


def scenario_worker_kill(dirs) -> None:
    from repro.serving import (
        EngineUnhealthyError, RestartPolicy, ROQEngine, direct_interpolate)

    with _env(REPRO_FAULT_SERVE_KILL_WORKER=5):
        eng = ROQEngine({"a": dirs["a"]}, max_batch=2, max_wait_ms=0.5,
                        restart=RestartPolicy(backoff_base_s=0.01))
        basis, eim = eng.router.get("a")
        pool = _reqs(basis, 32, seed=3)
        futs = []
        died_at = recovered_at = None
        for i in range(40):
            fut = None
            try:
                fut = eng.submit("a", pool[:, i % 32])
            except EngineUnhealthyError:
                died_at = died_at or time.perf_counter()
            if fut is not None:
                futs.append((fut, i % 32))
            if not eng.healthy():
                died_at = died_at or time.perf_counter()
            elif died_at is not None and recovered_at is None:
                recovered_at = time.perf_counter()
            time.sleep(0.002)
        _gate(_wait_until(eng.healthy), "worker never restarted")
        if recovered_at is None:
            recovered_at = time.perf_counter()
        # post-recovery request must serve bitwise
        f = pool[:, 0]
        out = eng.submit("a", f).result(timeout=WAIT_S)
        _gate(np.array_equal(out, direct_interpolate(eim, f)),
              "post-recovery response is not bitwise")
        eng.close(drain=True)

    failed = served = 0
    for fut, col in futs:
        err = fut.exception(timeout=WAIT_S)
        if err is None:
            served += 1
            _gate(np.array_equal(
                fut.result(), direct_interpolate(eim, pool[:, col])),
                "wrong-bit response around a worker death")
        else:
            _gate(isinstance(err, EngineUnhealthyError),
                  f"stranded/unexpected future after kill: {err!r}")
            failed += 1
    c = eng.stats()["counters"]
    _SNAPSHOTS["worker_kill"] = eng.stats()
    _gate(c["worker_deaths"] == 1 and c["worker_restarts"] == 1,
          f"expected 1 death + 1 restart, got {c['worker_deaths']}/"
          f"{c['worker_restarts']}")
    recovery_ms = ((recovered_at - died_at) * 1e3
                   if died_at is not None else 0.0)
    emit("serving_chaos_worker_kill", recovery_ms * 1e3,
         derived=(f"killed_batch=5,inflight_failed={failed},"
                  f"served={served},recovery_ms={recovery_ms:.1f},"
                  f"restarts={c['worker_restarts']},"
                  f"post_recovery_bitwise=ok"))


def scenario_breaker(dirs) -> None:
    from repro.serving import CircuitOpenError, ROQEngine, direct_interpolate

    eng = ROQEngine({"good": dirs["a"], "bad": dirs["b"]}, max_batch=4,
                    max_wait_ms=0.5, breaker_threshold=3,
                    breaker_cooldown_s=0.2)
    basis, eim = eng.router.get("good")
    pool = _reqs(basis, 16, seed=4)
    bad_shape = np.zeros(1, dtype=np.float32)  # shape checked at flush

    with _env(REPRO_FAULT_SERVE_RAISE_AT_LOAD="bad"):
        # drive consecutive failed batches into the unloadable basis
        # (each submit waits its future, so each is its own batch)
        load_failures = 0
        for _ in range(3):
            fut = eng.submit("bad", bad_shape)
            err = fut.exception(timeout=WAIT_S)
            _gate(isinstance(err, IOError), f"expected load fault: {err!r}")
            load_failures += 1
        _gate(eng.breakers.state("bad") == "open",
              "breaker did not open after threshold consecutive failures")
        fastfail_t0 = time.perf_counter()
        rejected = 0
        try:
            eng.submit("bad", bad_shape)
        except CircuitOpenError:
            rejected += 1
        fastfail_us = (time.perf_counter() - fastfail_t0) * 1e6
        _gate(rejected == 1, "open breaker did not fast-fail")
        # the healthy basis is untouched by its neighbor's storm
        f = pool[:, 0]
        out = eng.submit("good", f).result(timeout=WAIT_S)
        _gate(np.array_equal(out, direct_interpolate(eim, f)),
              "healthy basis disturbed by a neighboring breaker storm")

    time.sleep(0.25)   # cooldown; fault env cleared -> probe can load
    fut = eng.submit("bad", _reqs_for(eng, "bad"))
    _gate(fut.exception(timeout=WAIT_S) is None,
          "half-open probe failed after the fault cleared")
    _gate(eng.breakers.state("bad") == "closed",
          "served probe did not close the breaker")
    eng.close(drain=True)
    c = eng.stats()["counters"]
    _SNAPSHOTS["breaker"] = eng.stats()
    _gate(c["breaker_opened"] >= 1 and c["breaker_half_open"] >= 1
          and c["breaker_closed"] >= 1, "breaker transition counters off")
    emit("serving_chaos_breaker", fastfail_us,
         derived=(f"load_failures={load_failures},opened="
                  f"{c['breaker_opened']},rejected={c['breaker_rejected']},"
                  f"half_open={c['breaker_half_open']},closed="
                  f"{c['breaker_closed']},good_basis_bitwise=ok"))


def _reqs_for(eng, bid):
    basis, _ = eng.router.get(bid)
    return _reqs(basis, 1, seed=9)[:, 0]


def scenario_hot_reload(dirs) -> None:
    from repro.api import build_basis
    from repro.serving import ROQEngine, direct_interpolate

    d = dirs["hot"]
    eng = ROQEngine({"hot": d}, max_batch=4, max_wait_ms=0.5)
    basis1, eim1 = eng.router.get("hot")
    pool = _reqs(basis1, 32, seed=6)
    # rebuild from a shifted source: same k (fixed max_k, tiny tau), new B
    b2 = build_basis(source=_smooth(N, M, np.float32, phase=0.4),
                     strategy="greedy", tau=1e-12, max_k=MAX_K)
    _gate(b2.k == basis1.k, "rebuild changed k; scenario needs same shape")
    futs = []
    for i in range(30):
        futs.append((eng.submit("hot", pool[:, i % 32]), i % 32))
        if i == 10:
            b2.save(d)   # new artifact step lands on disk...
            t0 = time.perf_counter()
            gen = eng.refresh("hot")   # ...and swaps in mid-traffic
            refresh_us = (time.perf_counter() - t0) * 1e6
            _gate(gen == 1, f"expected generation 1, got {gen}")
        time.sleep(0.001)
    eng.close(drain=True)
    _, eim2 = eng.router.get("hot")

    failures = old_gen = new_gen = 0
    for fut, col in futs:
        err = fut.exception(timeout=WAIT_S)
        if err is not None:
            failures += 1
            continue
        out = fut.result()
        if np.array_equal(out, direct_interpolate(eim1, pool[:, col])):
            old_gen += 1
        elif np.array_equal(out, direct_interpolate(eim2, pool[:, col])):
            new_gen += 1
        else:
            _gate(False, "response matches NEITHER generation bitwise")
    _SNAPSHOTS["hot_reload"] = eng.stats()
    c = eng.stats()["counters"]
    _gate(failures == 0, f"{failures} in-flight requests failed across "
          f"a refresh (must be zero)")
    _gate(old_gen > 0 and new_gen > 0,
          "traffic did not straddle the generation swap")
    _gate(c["reloads"] == 1, "reload not counted")
    emit("serving_chaos_hot_reload", refresh_us,
         derived=(f"generation=1,old_gen_responses={old_gen},"
                  f"new_gen_responses={new_gen},inflight_failures=0,"
                  f"mismatches=0"))


def scenario_corrupt_reload(dirs) -> None:
    from repro.serving import ROQEngine, direct_interpolate

    eng = ROQEngine({"a": dirs["a"]}, max_batch=4, max_wait_ms=0.5)
    basis, eim = eng.router.get("a")
    pool = _reqs(basis, 8, seed=7)
    with _env(REPRO_FAULT_SERVE_CORRUPT_RELOAD=1):
        t0 = time.perf_counter()
        rejected = False
        try:
            eng.refresh("a")
        except IOError:
            rejected = True
        reject_us = (time.perf_counter() - t0) * 1e6
    _gate(rejected, "corrupt reload candidate was accepted")
    served = 0
    for i in range(8):   # live basis keeps serving, untouched
        out = eng.submit("a", pool[:, i]).result(timeout=WAIT_S)
        _gate(np.array_equal(out, direct_interpolate(eim, pool[:, i])),
              "live basis disturbed by a rejected reload")
        served += 1
    eng.close(drain=True)
    c = eng.stats()["counters"]
    _SNAPSHOTS["corrupt_reload"] = eng.stats()
    _gate(c["reload_failures"] == 1 and c["reloads"] == 0,
          "corrupt-reload counters off")
    _gate(eng.stats()["router"]["generations"] == {},
          "generation bumped despite a rejected candidate")
    emit("serving_chaos_corrupt_reload", reject_us,
         derived=(f"reload_failures=1,reloads=0,served_after={served},"
                  f"live_basis_bitwise=ok"))


def run(csv: bool = False) -> None:
    del csv
    import tempfile

    for k in ("REPRO_FAULT_ONCE", "REPRO_FAULT_SERVE_KILL_WORKER",
              "REPRO_FAULT_SERVE_SLOW_BATCH",
              "REPRO_FAULT_SERVE_RAISE_AT_LOAD",
              "REPRO_FAULT_SERVE_CORRUPT_RELOAD"):
        os.environ.pop(k, None)
    with tempfile.TemporaryDirectory() as td:
        dirs = {"a": _build(td, "a"), "b": _build(td, "b", phase=0.2),
                "hot": _build(td, "hot")}
        scenario_overload(dirs)
        scenario_worker_kill(dirs)
        scenario_breaker(dirs)
        scenario_hot_reload(dirs)
        scenario_corrupt_reload(dirs)


def main() -> None:
    from benchmarks.common import write_bench_json

    print("name,us_per_call,derived")
    run(csv=True)
    out = os.environ.get("REPRO_SERVING_BENCH_JSON", "BENCH_serving.json")
    n_rows = write_bench_json(out, merge=True)
    print(f"# merged {n_rows} chaos rows into {out}")
    snap_path = os.environ.get("REPRO_SERVING_SNAPSHOT_JSON",
                               "serving_chaos_metrics.json")
    with open(snap_path, "w") as f:
        json.dump(_SNAPSHOTS, f, indent=1, sort_keys=True, default=str)
    print(f"# wrote per-scenario metrics snapshots to {snap_path}")


if __name__ == "__main__":
    main()
