"""Shared benchmark utilities: timing, CSV emission, JSON registry."""

from __future__ import annotations

import time

import jax
import numpy as np

# Every emit() is recorded here; benchmarks/run.py dumps the registry to
# BENCH_greedy.json so the perf trajectory is machine-readable across PRs.
_RECORDS: list[dict] = []


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (s) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def steady_min(fn, per: int, repeats: int = 12, warmup: int = 3) -> float:
    """Best-of-``repeats`` steady-state seconds per iteration.

    ``fn`` performs ``per`` hot-loop iterations and must block on its
    outputs; it is timed CONSECUTIVELY (hot thread pools, warm allocator —
    what a production driver loop experiences) and the minimum rejects
    load spikes / unlucky thread placement on a shared CI box.  Single-shot
    wall clock swings ~±40% on the 2-core box; this is the stable method
    every committed hot-path BENCH row uses.  (Canonical implementation:
    :func:`repro.timing.steady_min` — shared with the serving launcher.)
    """
    from repro.timing import steady_min as _impl

    return _impl(fn, per=per, repeats=repeats, warmup=warmup)


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.3f},{derived}")
    _RECORDS.append(
        {"name": name, "us_per_call": float(us_per_call), "derived": derived}
    )


def records() -> list[dict]:
    """All rows emitted so far (in emission order)."""
    return list(_RECORDS)


def write_bench_json(path: str, merge: bool = False) -> int:
    """Dump the registry as {name: us_per_call, _derived: {...}} JSON —
    the machine-readable perf-trajectory format tracked across PRs.
    Returns the number of rows written.

    ``merge=True`` folds this run's rows into an existing file instead of
    replacing it — how multiple harnesses (e.g. serving_load + the chaos
    harness) share one BENCH_serving.json without clobbering each other's
    rows.  Same-named rows are overwritten by the newer run.
    """
    import json
    import os

    rows = records()
    payload, derived = {}, {}
    if merge and os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
        derived = payload.pop("_derived", {})
    payload.update({r["name"]: r["us_per_call"] for r in rows})
    derived.update({r["name"]: r["derived"] for r in rows if r["derived"]})
    payload["_derived"] = derived
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    return len(rows)
