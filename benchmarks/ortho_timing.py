"""Paper Fig. 6.1(b): orthogonalization time vs iteration index j, plus the
seed-vs-chunked IMGS hot-path comparison.

IMGS cost is O(nu_j * j * N): linear growth with the basis size j.  We
measure T_j^IMGS/N and fit the slope.

The hot-path rows compare, at N=4096:

  fig6.1b_hotpath_seed   — one jitted :func:`imgs_orthogonalize` dispatch
                           per basis vector (the seed driver's cadence),
  fig6.1b_hotpath_fused  — the same orthogonalizations executed
                           device-resident inside one jitted ``lax.scan``
                           chunk (the chunked driver's cadence), amortizing
                           dispatch + host sync over the chunk.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.greedy import imgs_orthogonalize


def run(csv: bool = True):
    hotpath = run_hotpath(csv=csv)
    results = []
    for N in (1024, 4096):
        rng = np.random.default_rng(0)
        js, ts = [], []
        fn = jax.jit(lambda v, Q: imgs_orthogonalize(v, Q)[0])
        for j in (8, 16, 32, 64, 128):
            Q, _ = np.linalg.qr(rng.standard_normal((N, j)))
            v = jnp.asarray(rng.standard_normal(N), jnp.float32)
            Qj = jnp.asarray(Q, jnp.float32)
            t = time_fn(fn, v, Qj, warmup=2, iters=5)
            js.append(j)
            ts.append(t)
        slope = np.polyfit(js, ts, 1)[0]
        r = np.corrcoef(js, ts)[0, 1]
        results.append((N, js, ts, slope, r))
        if csv:
            emit(
                f"fig6.1b_imgs_N{N}",
                np.mean(ts) * 1e6,
                f"linear_fit_slope={slope*1e6:.3f}us/basis;corr={r:.4f}",
            )
    results.append(hotpath)
    return results


def run_hotpath(csv: bool = True, N: int = 4096, j: int = 64,
                chunk: int = 16, repeats: int = 9):
    """Per-call vs chunk-amortized IMGS at the production row count, for
    the GW production dtype (complex64) and real float32.

    seed:  one jitted :func:`imgs_orthogonalize` dispatch per basis vector
           with the seed implementation (``backend="xla_ref"``: complex
           matvecs and all).
    fused: the same orthogonalizations device-resident inside one jitted
           ``lax.scan`` chunk through the ``xla`` backend (plane-split
           complex), amortizing dispatch + host sync over the chunk.

    Each candidate is timed best-of-``repeats`` in its own steady state
    (see benchmarks.pivot_timing._steady_min for the rationale).
    """
    out = {}
    for dtype, suffix in ((jnp.complex64, ""), (jnp.float32, "_f32")):
        out[str(jnp.dtype(dtype))] = _hotpath_one_dtype(
            csv, N, j, chunk, repeats, dtype, suffix
        )
    return out


def _hotpath_one_dtype(csv, N, j, chunk, repeats, dtype, suffix):
    from benchmarks.pivot_timing import _steady_min

    rng = np.random.default_rng(0)
    cplx = jnp.issubdtype(dtype, jnp.complexfloating)
    A = rng.standard_normal((N, j))
    v = rng.standard_normal((chunk, N))
    if cplx:
        A = A + 1j * rng.standard_normal((N, j))
        v = v + 1j * rng.standard_normal((chunk, N))
    Qj = jnp.asarray(np.linalg.qr(A)[0], dtype)
    V = jnp.asarray(v, dtype)

    # seed cadence: one dispatch + sync per orthogonalization, seed ops
    fn = jax.jit(
        lambda v, Q: imgs_orthogonalize(v, Q, backend="xla_ref")[0]
    )

    def percall():
        out = [fn(V[i], Qj) for i in range(chunk)]
        jax.block_until_ready(out)

    # chunked cadence: the same passes device-resident inside one jit
    @jax.jit
    def scanned(V, Q):
        def body(_, v):
            q, _, _, _ = imgs_orthogonalize(v, Q)
            return 0, q
        _, qs = jax.lax.scan(body, 0, V)
        return qs

    def chunked():
        jax.block_until_ready(scanned(V, Qj))

    t_seed = _steady_min(percall, chunk, repeats=repeats, warmup=2)
    t_fused = _steady_min(chunked, chunk, repeats=repeats, warmup=2)

    speedup = t_seed / max(t_fused, 1e-12)
    dt_name = str(jnp.dtype(dtype))
    if csv:
        emit(f"fig6.1b_hotpath_seed_N{N}_j{j}{suffix}", t_seed * 1e6,
             f"dtype={dt_name};per-call jitted IMGS (seed ops + cadence)")
        emit(f"fig6.1b_hotpath_fused_N{N}_j{j}{suffix}", t_fused * 1e6,
             f"dtype={dt_name};device-resident scan chunk C={chunk};"
             f"speedup_vs_seed={speedup:.2f}x")
    return {"t_seed_us": t_seed * 1e6, "t_fused_us": t_fused * 1e6,
            "speedup": speedup}


if __name__ == "__main__":
    run()
