"""Paper Fig. 6.1(b): orthogonalization time vs iteration index j.

IMGS cost is O(nu_j * j * N): linear growth with the basis size j.  We
measure T_j^IMGS/N and fit the slope.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.greedy import imgs_orthogonalize


def run(csv: bool = True):
    results = []
    for N in (1024, 4096):
        rng = np.random.default_rng(0)
        js, ts = [], []
        fn = jax.jit(lambda v, Q: imgs_orthogonalize(v, Q)[0])
        for j in (8, 16, 32, 64, 128):
            Q, _ = np.linalg.qr(rng.standard_normal((N, j)))
            v = jnp.asarray(rng.standard_normal(N), jnp.float32)
            Qj = jnp.asarray(Q, jnp.float32)
            t = time_fn(fn, v, Qj, warmup=2, iters=5)
            js.append(j)
            ts.append(t)
        slope = np.polyfit(js, ts, 1)[0]
        r = np.corrcoef(js, ts)[0, 1]
        results.append((N, js, ts, slope, r))
        if csv:
            emit(
                f"fig6.1b_imgs_N{N}",
                np.mean(ts) * 1e6,
                f"linear_fit_slope={slope*1e6:.3f}us/basis;corr={r:.4f}",
            )
    return results


if __name__ == "__main__":
    run()
