"""Paper Fig. 6.1(b): orthogonalization time vs iteration index j, plus the
seed-vs-chunked IMGS hot-path comparison and the panel-ortho rows.

IMGS cost is O(nu_j * j * N): linear growth with the basis size j.  We
measure T_j^IMGS/N and fit the slope.

All rows time with ``benchmarks.common.steady_min`` (best-of-N from a
steady state — single-shot wall clock swings ±40% on the shared box; the
pre-PR-5 fig6.1b_imgs rows were single-shot medians and meaningless at
that noise level).

The hot-path rows compare, at N=4096:

  fig6.1b_hotpath_seed   — one jitted :func:`imgs_orthogonalize` dispatch
                           per basis vector (the seed driver's cadence),
  fig6.1b_hotpath_fused  — the same orthogonalizations executed
                           device-resident inside one jitted ``lax.scan``
                           chunk (the chunked driver's cadence), amortizing
                           dispatch + host sync over the chunk.

The panel-ortho rows time the blocked drivers' per-block orthogonalization
(N=4096, k=64 resident bases, p=8 candidates — the production blocked
shape) through the two `_ortho_block` paths:

  fig6.1b_panelortho_seq    — p sequential :func:`imgs_orthogonalize`
                              calls with fixed-slot writes (the pre-PR-5
                              blocked path: p separate k*N GEMV chains),
  fig6.1b_panelortho_panel  — the fused BLAS-3 panel path
                              (:func:`repro.core.greedy.
                              panel_imgs_orthogonalize`: iterated
                              (k,N)x(N,p) panel projection + within-panel
                              sweep + BCGS2 re-ortho cycle).

Both are timed PER BASIS (total block time / p) in f32 and c64 (the GW
production dtype; plane-split GEMMs under the xla backend).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, steady_min
from repro.core.block_greedy import _ortho_block
from repro.core.greedy import imgs_orthogonalize


def run(csv: bool = True):
    hotpath = run_hotpath(csv=csv)
    panel = run_panel(csv=csv)
    results = []
    for N in (1024, 4096):
        rng = np.random.default_rng(0)
        js, ts = [], []
        fn = jax.jit(lambda v, Q: imgs_orthogonalize(v, Q)[0])
        for j in (8, 16, 32, 64, 128):
            Q, _ = np.linalg.qr(rng.standard_normal((N, j)))
            v = jnp.asarray(rng.standard_normal(N), jnp.float32)
            Qj = jnp.asarray(Q, jnp.float32)
            t = steady_min(
                lambda: jax.block_until_ready(fn(v, Qj)),
                per=1, repeats=7, warmup=2,
            )
            js.append(j)
            ts.append(t)
        slope = np.polyfit(js, ts, 1)[0]
        r = np.corrcoef(js, ts)[0, 1]
        results.append((N, js, ts, slope, r))
        if csv:
            emit(
                f"fig6.1b_imgs_N{N}",
                np.mean(ts) * 1e6,
                f"linear_fit_slope={slope*1e6:.3f}us/basis;corr={r:.4f}",
            )
    results.append(hotpath)
    results.append(panel)
    return results


def run_hotpath(csv: bool = True, N: int = 4096, j: int = 64,
                chunk: int = 16, repeats: int = 9):
    """Per-call vs chunk-amortized IMGS at the production row count, for
    the GW production dtype (complex64) and real float32.

    seed:  one jitted :func:`imgs_orthogonalize` dispatch per basis vector
           with the seed implementation (``backend="xla_ref"``: complex
           matvecs and all).
    fused: the same orthogonalizations device-resident inside one jitted
           ``lax.scan`` chunk through the ``xla`` backend (plane-split
           complex), amortizing dispatch + host sync over the chunk.

    Each candidate is timed best-of-``repeats`` in its own steady state
    (``benchmarks.common.steady_min``).
    """
    out = {}
    for dtype, suffix in ((jnp.complex64, ""), (jnp.float32, "_f32")):
        out[str(jnp.dtype(dtype))] = _hotpath_one_dtype(
            csv, N, j, chunk, repeats, dtype, suffix
        )
    return out


def _hotpath_one_dtype(csv, N, j, chunk, repeats, dtype, suffix):
    rng = np.random.default_rng(0)
    cplx = jnp.issubdtype(dtype, jnp.complexfloating)
    A = rng.standard_normal((N, j))
    v = rng.standard_normal((chunk, N))
    if cplx:
        A = A + 1j * rng.standard_normal((N, j))
        v = v + 1j * rng.standard_normal((chunk, N))
    Qj = jnp.asarray(np.linalg.qr(A)[0], dtype)
    V = jnp.asarray(v, dtype)

    # seed cadence: one dispatch + sync per orthogonalization, seed ops
    fn = jax.jit(
        lambda v, Q: imgs_orthogonalize(v, Q, backend="xla_ref")[0]
    )

    def percall():
        out = [fn(V[i], Qj) for i in range(chunk)]
        jax.block_until_ready(out)

    # chunked cadence: the same passes device-resident inside one jit
    @jax.jit
    def scanned(V, Q):
        def body(_, v):
            q, _, _, _ = imgs_orthogonalize(v, Q)
            return 0, q
        _, qs = jax.lax.scan(body, 0, V)
        return qs

    def chunked():
        jax.block_until_ready(scanned(V, Qj))

    t_seed = steady_min(percall, chunk, repeats=repeats, warmup=2)
    t_fused = steady_min(chunked, chunk, repeats=repeats, warmup=2)

    speedup = t_seed / max(t_fused, 1e-12)
    dt_name = str(jnp.dtype(dtype))
    if csv:
        emit(f"fig6.1b_hotpath_seed_N{N}_j{j}{suffix}", t_seed * 1e6,
             f"dtype={dt_name};per-call jitted IMGS (seed ops + cadence)")
        emit(f"fig6.1b_hotpath_fused_N{N}_j{j}{suffix}", t_fused * 1e6,
             f"dtype={dt_name};device-resident scan chunk C={chunk};"
             f"speedup_vs_seed={speedup:.2f}x")
    return {"t_seed_us": t_seed * 1e6, "t_fused_us": t_fused * 1e6,
            "speedup": speedup}


def run_panel(csv: bool = True, N: int = 4096, k: int = 64, p: int = 8,
              repeats: int = 9):
    """Blocked-ortho comparison: p sequential project_pass chains vs the
    fused BLAS-3 panel, per basis, through the actual driver helper
    (:func:`repro.core.block_greedy._ortho_block`), f32 and c64."""
    out = {}
    for dtype, suffix in ((jnp.complex64, ""), (jnp.float32, "_f32")):
        out[str(jnp.dtype(dtype))] = _panel_one_dtype(
            csv, N, k, p, repeats, dtype, suffix
        )
    return out


def _panel_one_dtype(csv, N, k, p, repeats, dtype, suffix):
    rng = np.random.default_rng(0)
    cplx = jnp.issubdtype(dtype, jnp.complexfloating)
    A = rng.standard_normal((N, k))
    V = rng.standard_normal((N, p))
    if cplx:
        A = A + 1j * rng.standard_normal((N, k))
        V = V + 1j * rng.standard_normal((N, p))
    Qk = np.linalg.qr(A)[0]
    # the driver's slot layout: k resident bases + p free slots
    Qbuf = np.zeros((N, k + p), np.dtype(dtype))
    Qbuf[:, :k] = Qk
    Qbuf = jnp.asarray(Qbuf)
    S = jnp.asarray(V.astype(dtype))   # the p candidate columns
    idx = jnp.arange(p, dtype=jnp.int32)
    eps = float(jnp.finfo(jnp.zeros((), dtype).real.dtype).eps)
    scale = float(np.max(np.linalg.norm(V, axis=0)))

    @functools.partial(jax.jit, static_argnames=("panel",))
    def block_ortho(S_, Q_, panel: bool):
        Qout, Qnew, oks, _, _ = _ortho_block(
            S_, Q_, idx, jnp.asarray(k, jnp.int32), p, 2.0, 3, eps,
            scale, None, panel,
        )
        return Qout, Qnew, oks

    def timed(panel):
        return steady_min(
            lambda: jax.block_until_ready(block_ortho(S, Qbuf,
                                                      panel=panel)),
            per=p, repeats=repeats, warmup=2,
        )

    t_seq = timed(False)
    t_panel = timed(True)
    speedup = t_seq / max(t_panel, 1e-12)
    dt_name = str(jnp.dtype(dtype))
    if csv:
        emit(f"fig6.1b_panelortho_seq_N{N}_k{k}_p{p}{suffix}",
             t_seq * 1e6,
             f"dtype={dt_name};p sequential project_pass chains, per "
             f"basis")
        emit(f"fig6.1b_panelortho_panel_N{N}_k{k}_p{p}{suffix}",
             t_panel * 1e6,
             f"dtype={dt_name};fused BLAS-3 panel IMGS, per basis;"
             f"speedup_vs_seq={speedup:.2f}x")
    return {"t_seq_us": t_seq * 1e6, "t_panel_us": t_panel * 1e6,
            "speedup": speedup}


if __name__ == "__main__":
    run()
