"""Fusion evidence for the greedy_update hot loop (DESIGN.md §2).

Compares HBM bytes (HLO cost analysis) and CPU wall-time of:
  (a) fused one-pass update (c, acc, argmax in one sweep over S) — what the
      Pallas kernel guarantees on TPU and XLA fuses here,
  (b) an explicitly two-pass version (matvec pass; then norms+argmax pass
      with S re-read via a second matvec-sized traversal).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn


def _fused(q, S, acc, norms):
    c = q.conj() @ S
    acc2 = acc + jnp.abs(c) ** 2
    res = norms - acc2
    return c, acc2, jnp.argmax(res)


def _two_pass(q, S, acc, norms):
    c = q.conj() @ S
    # second pass re-derives the residuals from S (what a non-fused
    # implementation without Eq.-6.3 bookkeeping pays every iteration)
    col_sq = jnp.sum(jnp.abs(S) ** 2, axis=0)
    res = col_sq - (norms - (norms - acc)) - jnp.abs(c) ** 2
    return c, acc + jnp.abs(c) ** 2, jnp.argmax(res)


def _bytes_of(fn, *args):
    ca = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return float(ca.get("bytes accessed", 0))


def run(csv: bool = True):
    rng = np.random.default_rng(0)
    N, M = 2000, 8000
    S = jnp.asarray(rng.standard_normal((N, M)), jnp.float32)
    q = jnp.asarray(rng.standard_normal(N), jnp.float32)
    q = q / jnp.linalg.norm(q)
    acc = jnp.zeros((M,), jnp.float32)
    norms = jnp.sum(jnp.abs(S) ** 2, axis=0)

    b_fused = _bytes_of(_fused, q, S, acc, norms)
    b_two = _bytes_of(_two_pass, q, S, acc, norms)
    t_fused = time_fn(jax.jit(_fused), q, S, acc, norms)
    t_two = time_fn(jax.jit(_two_pass), q, S, acc, norms)
    if csv:
        emit(
            "perf_greedy_fusion",
            t_fused * 1e6,
            f"bytes_fused={b_fused:.3e};bytes_2pass={b_two:.3e};"
            f"byte_ratio={b_two/b_fused:.2f};"
            f"t_fused={t_fused*1e3:.2f}ms;t_2pass={t_two*1e3:.2f}ms",
        )
    return b_fused, b_two, t_fused, t_two


if __name__ == "__main__":
    run()
