"""Paper Remark 5.4 / Sec 6.1.2: FLOP-count model validation.

Our implementation (Eq. 6.3 bookkeeping) should cost
  O(2MNk + 1/2 nu N k(k+1))      (paper Sec. 6.1.2)
to find k bases.  We count actual HLO FLOPs of one jitted greedy step at
several basis sizes and fit against the model's per-iteration derivative
  d/dk = 2MN + nu N k.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.greedy import greedy_init, greedy_step


def _step_flops(N, M, k):
    """HLO FLOPs of one greedy step with k bases already present."""
    S = jax.ShapeDtypeStruct((N, M), jnp.float32)
    state = jax.eval_shape(
        lambda: greedy_init(jnp.zeros((N, M), jnp.float32), 64)
    )
    state = state._replace(k=jax.ShapeDtypeStruct((), jnp.int32))
    compiled = (
        jax.jit(lambda s, st: greedy_step(s, st))
        .lower(S, state)
        .compile()
    )
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return float(ca.get("flops", 0))


def run(csv: bool = True):
    N, M = 1000, 2000
    f = _step_flops(N, M, 0)
    # model per-iteration: pivot search 2MN + R-row 2MN... our step does
    # c = q^H S (2MN), residual update (3M), IMGS vs zero-padded max_k basis
    # (2 * 2*N*max_k per pass).  With max_k=64 static padding:
    model = 2 * M * N + 2 * 2 * 2 * N * 64 + 5 * M + 4 * N
    ratio = f / model
    if csv:
        emit(
            "rem5.4_flops_per_iter",
            0.0,
            f"hlo_flops={f:.3e};model={model:.3e};ratio={ratio:.3f}",
        )
    assert 0.3 < ratio < 3.0, "FLOP model badly off"
    return f, model, ratio


if __name__ == "__main__":
    run()
