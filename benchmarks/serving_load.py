"""ROQ serving load harness: the online stage under sustained traffic.

Three scenarios over tiny-but-real basis artifacts (f32 built by greedy,
c64 by the randomized sketch — the per-parameter-region mix the router
exists for):

  serving_oneshot_b{B}    — the pre-engine one-shot path: every
                            invocation rebuilds ``jax.jit(lambda fn:
                            ei.B @ fn)`` and recompiles before evaluating
                            one B-wide batch (exactly what the old
                            ``launch/serve.py --basis`` did per call).
  serving_engine_burst_b{B} — the persistent warm-cache engine serving
                            the same total requests at max_batch=B,
                            open-loop burst submission.  The derived
                            field records the req/s speedup over the
                            one-shot row (gated >= REPRO_SERVING_MIN_SPEEDUP,
                            default 5).
  serving_paced / latency — open-loop arrivals (seeded exponential
                            inter-arrival gaps, mixed ragged sizes,
                            BOTH bases round-robin) at a rate well under
                            burst capacity; per-request latency rolls up
                            into serving_latency_p{50,95,99}_us rows via
                            repro.timing.percentiles.

Every engine response in the paced scenario is checked BIT-IDENTICAL to
:func:`repro.serving.direct_interpolate` of the same request — routed
multi-basis traffic must cost nothing in exactness.

Run standalone to write ``BENCH_serving.json`` (env override
``REPRO_SERVING_BENCH_JSON``); shape/scale knobs: REPRO_SERVE_N,
REPRO_SERVE_BATCH, REPRO_SERVE_REQUESTS, REPRO_SERVE_RATE_RPS.
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

from benchmarks.common import emit

N = int(os.environ.get("REPRO_SERVE_N", 1024))
M = int(os.environ.get("REPRO_SERVE_M", 256))
MAX_K = int(os.environ.get("REPRO_SERVE_MAX_K", 16))
BATCH = int(os.environ.get("REPRO_SERVE_BATCH", 32))
REQUESTS = int(os.environ.get("REPRO_SERVE_REQUESTS", 4096))
# arrival rate for the paced scenario, in ARRIVALS per second (each
# arrival submits 1-4 requests, so offered load ~2.5x this).  Default
# sits well under the measured multi-basis burst capacity so the latency
# rows describe an uncongested service, not a saturated queue.
RATE_RPS = float(os.environ.get("REPRO_SERVE_RATE_RPS", 600.0))
MIN_SPEEDUP = float(os.environ.get("REPRO_SERVING_MIN_SPEEDUP", 5.0))
ONESHOT_ROUNDS = int(os.environ.get("REPRO_SERVE_ONESHOT_ROUNDS", 8))


def _smooth(n, m, dtype):
    x = np.linspace(0.0, 1.0, n)[:, None]
    nu = np.linspace(0.5, 2.0, m)[None, :]
    S = np.sin(2 * np.pi * nu * x) * np.exp(-nu * x)
    if np.issubdtype(dtype, np.complexfloating):
        S = S * np.exp(1j * nu * x)
    return S.astype(dtype)


def _build_bases(root: str) -> dict:
    from repro.api import build_basis

    dirs = {}
    f32 = build_basis(source=_smooth(N, M, np.float32), strategy="greedy",
                      tau=1e-6, max_k=MAX_K)
    c64 = build_basis(source=_smooth(3 * N // 4, M, np.complex64),
                      strategy="randomized", tau=1e-6, max_k=MAX_K)
    for bid, basis in (("f32_greedy", f32), ("c64_rand", c64)):
        d = os.path.join(root, bid)
        basis.save(d)
        dirs[bid] = d
        print(f"# built {bid}: k={basis.k} N={basis.N} "
              f"dtype={np.asarray(basis.Q).dtype}")
    return dirs


def _request_pool(basis, eim, pool: int, seed: int):
    rng = np.random.default_rng(seed)
    dtype = np.asarray(basis.Q).dtype
    coeff = rng.standard_normal((basis.k, pool))
    if np.issubdtype(dtype, np.complexfloating):
        coeff = coeff + 1j * rng.standard_normal((basis.k, pool))
    full = np.asarray(basis.Q) @ coeff.astype(dtype)
    return np.ascontiguousarray(full[np.asarray(eim.nodes), :])


def _oneshot_reqps(basis, eim, at_nodes):
    """The old serve path, per invocation: fresh jit(lambda) -> compile
    -> one batched evaluation.  Best-of-rounds (req/s, seconds) — every
    round pays the rebuild+recompile; that IS the path being measured."""
    import jax
    import jax.numpy as jnp

    batch = at_nodes.shape[1]
    fn_dev = jnp.asarray(at_nodes)
    best = float("inf")
    for _ in range(ONESHOT_ROUNDS):
        t0 = time.perf_counter()
        interp = jax.jit(lambda fn: eim.B @ fn)  # a FRESH jit every round
        jax.block_until_ready(interp(fn_dev))
        best = min(best, time.perf_counter() - t0)
    return batch / best, best


def _engine_burst_reqps(dirs, bid, at_nodes, repeats: int = 3):
    """Warm engine, same total request count, open-loop burst."""
    from repro.serving import ROQEngine

    pool = at_nodes.shape[1]
    best_wall, served = float("inf"), 0
    for _ in range(repeats):
        eng = ROQEngine({bid: dirs[bid]}, max_batch=BATCH,
                        max_wait_ms=2.0, queue_depth=2 * REQUESTS)
        eng.warm(bid)
        t0 = time.perf_counter()
        futs = [eng.submit(bid, at_nodes[:, i % pool])
                for i in range(REQUESTS)]
        eng.close(drain=True)
        wall = time.perf_counter() - t0
        for f in futs:
            f.result()
        served = len(futs)
        best_wall = min(best_wall, wall)
    return served / best_wall, best_wall


def _paced_multibasis(dirs):
    """Open-loop arrivals over BOTH bases, mixed ragged sizes; returns
    (stats snapshot, req/s, mismatches)."""
    from repro.serving import ROQEngine, direct_interpolate

    eng = ROQEngine(dirs, max_batch=BATCH, max_wait_ms=2.0,
                    queue_depth=2 * REQUESTS)
    ids = sorted(dirs)
    pools, eims = {}, {}
    for bid in ids:
        basis, eim = eng.router.get(bid)
        pools[bid] = _request_pool(basis, eim, pool=4 * BATCH, seed=17)
        eims[bid] = eim
        eng.warm(bid)

    rng = np.random.default_rng(5)
    n = min(REQUESTS, int(RATE_RPS * 2.0))  # ~2s of paced traffic max
    gaps = rng.exponential(1.0 / RATE_RPS, size=n)
    t0 = time.perf_counter()
    deadline = t0
    futs = []
    for i in range(n):
        deadline += gaps[i]
        lag = deadline - time.perf_counter()
        if lag > 0:
            time.sleep(lag)
        # mixed sizes: burst 1..4 requests per arrival, mixed bases
        bid = ids[i % len(ids)]
        pool = pools[bid]
        for j in range(int(rng.integers(1, 5))):
            col = int(rng.integers(pool.shape[1]))
            futs.append((bid, col, eng.submit(bid, pool[:, col])))
    eng.close(drain=True)
    wall = time.perf_counter() - t0
    mismatches = sum(
        not np.array_equal(fut.result(),
                           direct_interpolate(eims[bid], pools[bid][:, col]))
        for bid, col, fut in futs)
    return eng.stats(), len(futs) / wall, mismatches


def run(csv: bool = False) -> None:
    del csv
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        dirs = _build_bases(td)

        from repro.api import ReducedBasis

        basis = ReducedBasis.load(dirs["f32_greedy"])
        eim = basis.eim()
        at_nodes = _request_pool(basis, eim, pool=BATCH, seed=3)

        oneshot_rps, oneshot_t = _oneshot_reqps(basis, eim, at_nodes)
        emit(f"serving_oneshot_b{BATCH}", oneshot_t * 1e6,
             derived=(f"N={basis.N},k={basis.k},batch={BATCH},"
                      f"reqps={oneshot_rps:.0f} (jit rebuilt+recompiled "
                      f"per invocation — the pre-engine path)"))

        engine_rps, engine_wall = _engine_burst_reqps(dirs, "f32_greedy",
                                                      at_nodes)
        speedup = engine_rps / oneshot_rps
        emit(f"serving_engine_burst_b{BATCH}",
             engine_wall / REQUESTS * 1e6,
             derived=(f"requests={REQUESTS},max_batch={BATCH},"
                      f"reqps={engine_rps:.0f},speedup_vs_oneshot="
                      f"{speedup:.1f}x (warm interpolant cache, "
                      f"open-loop burst)"))

        stats, paced_rps, mismatches = _paced_multibasis(dirs)
        lat = stats["latency_ms"]
        for q in ("p50", "p95", "p99"):
            emit(f"serving_latency_{q}_us", lat[q] * 1e3,
                 derived=(f"open-loop rate={RATE_RPS:.0f}/s over "
                          f"{stats['router']['registered']} bases "
                          f"(mixed f32/c64, ragged 1-4 per arrival), "
                          f"n={lat['n']}"))
        emit("serving_multibasis_paced", 1e6 / max(paced_rps, 1e-9),
             derived=(f"reqps={paced_rps:.0f},batches="
                      f"{stats['counters']['batches']},occupancy="
                      f"{stats['batch_occupancy_mean']:.2f},cache_hit_rate="
                      f"{stats['cache_hit_rate']:.2f},bitwise_mismatches="
                      f"{mismatches}"))

        if mismatches:
            raise RuntimeError(
                f"{mismatches} routed responses differ from direct "
                f"per-basis evaluation — the bitwise serving contract is "
                f"broken (see tests/test_serving.py)")
        if speedup < MIN_SPEEDUP:
            raise RuntimeError(
                f"warm-cache engine speedup {speedup:.1f}x < "
                f"{MIN_SPEEDUP:.0f}x over the one-shot path at batch "
                f"{BATCH} — serving perf regressed "
                f"(REPRO_SERVING_MIN_SPEEDUP overrides)")


def main() -> None:
    from benchmarks.common import write_bench_json

    print("name,us_per_call,derived")
    run(csv=True)
    out = os.environ.get("REPRO_SERVING_BENCH_JSON", "BENCH_serving.json")
    n_rows = write_bench_json(out)
    print(f"# wrote {n_rows} rows to {out}")


if __name__ == "__main__":
    main()
