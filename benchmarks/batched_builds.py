"""Batched many-basis greedy vs B sequential builds (the PR-9 headline).

A tau sweep is the canonical shared-S batched workload: B basis states
sweep ONE resident snapshot matrix.  The fused lockstep driver stacks
all lanes' query planes into two real GEMMs per sweep, so each plane of
S is read from DRAM once for all B lanes — B sequential ``rb_greedy``
runs read it B times, through XLA's single-threaded CPU GEMV.  Rows:

  batched_vs_sequential_fused_b8   one fused pass, B=8 taus (logspace
                                   3.2e-2..6.3e-3 of the family scale),
                                   shared S (N=4096 x M=16384
                                   complex64); derived carries
                                   speedup=<x> vs the sequential row,
                                   pivot_prefix_equal=<bool> (per-lane
                                   pivot sequences vs the scalar driver
                                   over the common accepted prefix) and
                                   rank_max_delta=<n> — GEMM float
                                   summation differs from the GEMV's, so
                                   a lane whose error grazes its tau can
                                   in principle accept one vector
                                   more/less than the scalar build (the
                                   blocked-driver contract); at this
                                   configuration parity is exact
                                   (delta 0 => pivot-for-pivot)
  batched_vs_sequential_seq_x8     the 8 sequential scalar builds
  batched_vs_sequential_stacked    stacked layout (B=4 distinct smaller
                                   matrices); derived carries
                                   bitwise_equal=<bool> — Q/R/pivots/errs
                                   per lane vs the scalar driver

The acceptance gate (ci.yml bench-smoke) asserts the fused row exists
with speedup >= 3 and the stacked row with bitwise_equal=True.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, steady_min

_N, _M, _B = 4096, 16384, 8
_MAX_K = 32
# All taus sit ABOVE the refresh trigger sqrt(safety*eps)*scale
# (~3.5e-3 of the family scale at f32 / safety=100): neither side pays
# exact-residual refreshes, so the row isolates the sweep itself.
_TAU_FRACS = tuple(float(t) for t in np.logspace(-1.5, -2.2, _B))
_SN, _SM, _SB = 512, 2048, 4


def _smooth_c64(n: int, m: int, seed: int = 0) -> np.ndarray:
    """Fast-decaying-n-width complex family (oscillatory x damped).

    Per-column amplitude/phase jitter keeps residual maxima separated
    by far more than GEMM-vs-GEMV f32 drift, so the fused sweep's
    argmax pivots are comparable to the scalar driver's."""
    rng = np.random.default_rng(seed)
    x = np.linspace(0.0, 1.0, n, dtype=np.float64)[:, None]
    nu = np.sort(rng.uniform(0.5, 4.0, size=m))[None, :]
    amp = rng.uniform(0.5, 1.5, size=m)[None, :]
    ph = np.exp(2j * np.pi * rng.uniform(0.0, 1.0, size=m))[None, :]
    S = amp * ph * np.exp(2j * np.pi * nu * x) * np.exp(-nu * x)
    return S.astype(np.complex64)


def _prefix_parity(res, refs):
    """(all pivot prefixes equal, max |k_fused - k_seq|) across lanes."""
    ok, delta = True, 0
    for b, ref in enumerate(refs):
        k = min(int(res.k[b]), int(ref.k))
        ok &= bool(np.array_equal(np.asarray(res.lane(b).pivots[:k]),
                                  np.asarray(ref.pivots[:k])))
        delta = max(delta, abs(int(res.k[b]) - int(ref.k)))
    return ok, delta


def _lanes_bitwise(res, refs) -> bool:
    for b, ref in enumerate(refs):
        lane = res.lane(b)
        if int(lane.k) != int(ref.k):
            return False
        for field in ("Q", "R", "pivots", "errs"):
            if not np.array_equal(np.asarray(getattr(lane, field)),
                                  np.asarray(getattr(ref, field))):
                return False
    return True


def run(csv: bool = True):
    import jax

    from repro.core.batch_greedy import batch_rb_greedy
    from repro.core.greedy import rb_greedy

    results = []

    # ---- shared-S tau sweep at the production shape --------------------
    Sh = _smooth_c64(_N, _M)
    err0 = float(np.sqrt(np.max(np.sum(np.abs(Sh) ** 2, axis=0))))
    taus = [err0 * f for f in _TAU_FRACS]
    S = jax.device_put(Sh)
    jax.block_until_ready(S)
    del Sh

    def fused():
        return batch_rb_greedy(S, taus, max_k=_MAX_K, backend="xla")

    def sequential():
        return [rb_greedy(S, tau, max_k=_MAX_K, backend="xla")
                for tau in taus]

    refs = sequential()                      # warm + parity reference
    res = fused()
    prefix_ok, rank_delta = _prefix_parity(res, refs)

    t_fused = steady_min(fused, per=1, repeats=2, warmup=1)
    t_seq = steady_min(sequential, per=1, repeats=2, warmup=1)
    speedup = t_seq / t_fused
    ks = ",".join(str(int(k)) for k in res.k)
    results.append(("fused_b8", t_fused, speedup, prefix_ok))
    if csv:
        emit("batched_vs_sequential_fused_b8", t_fused * 1e6,
             f"speedup={speedup:.2f};B={_B};N={_N};M={_M};dtype=c64;"
             f"k={ks};pivot_prefix_equal={prefix_ok};"
             f"rank_max_delta={rank_delta}")
        emit("batched_vs_sequential_seq_x8", t_seq * 1e6,
             f"B={_B};N={_N};M={_M};dtype=c64;per_basis_us="
             f"{t_seq * 1e6 / _B:.1f}")
    del S, res, refs

    # ---- stacked layout: distinct matrices, bitwise contract -----------
    stack = jax.device_put(np.stack(
        [_smooth_c64(_SN, _SM, seed=7 + b) for b in range(_SB)]))
    jax.block_until_ready(stack)
    tau = 1e-2

    def fused_stacked():
        return batch_rb_greedy(stack, tau, max_k=24, batch=_SB,
                               backend="xla")

    srefs = [rb_greedy(stack[b], tau, max_k=24, backend="xla")
             for b in range(_SB)]
    bitwise = _lanes_bitwise(fused_stacked(), srefs)
    t_stacked = steady_min(fused_stacked, per=1, repeats=2, warmup=1)
    results.append(("stacked", t_stacked, None, bitwise))
    if csv:
        emit("batched_vs_sequential_stacked", t_stacked * 1e6,
             f"B={_SB};N={_SN};M={_SM};dtype=c64;"
             f"bitwise_equal={bitwise}")
    return results


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(csv=True)
