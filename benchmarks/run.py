"""Benchmark harness: one entry per paper table/figure + assignment tables.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit) and
persists every row to ``BENCH_greedy.json`` (name -> us_per_call, plus the
derived annotations under ``_derived``) so the perf trajectory is tracked
machine-readably across PRs.

  fig6.1a  — pivot-search time vs iteration (constant in j) + the seed
             per-step driver vs the fused/chunked device-resident hot path
  fig6.1b  — IMGS orthogonalization time vs iteration (linear in j) + the
             per-call vs chunk-amortized comparison
  fig6.2   — strong-scaling efficiency (compiled per-device costs + Eq 6.6)
  fig6.4   — weak scaling incl. the Blue Waters flagship dry-run cells
  rem5.4   — FLOP-count model validation
  perf_*   — greedy_update fusion evidence
  roofline — the full arch x shape x mesh baseline table (from artifacts)
  sketch_vs_greedy — randomized one-pass range-finder vs streamed greedy
             pass-count / wall-time at a fixed rank target
  batched_vs_sequential — B=8 lockstep fused tau-sweep vs 8 sequential
             scalar builds (+ the stacked-layout bitwise-parity row)

The chunked hot-path row shards snapshot columns over one host device per
core (XLA's CPU GEMV is single-threaded; the column-sharded sweep is how
the production driver uses the machine), so the device count is forced
BEFORE jax initializes.
"""

from __future__ import annotations

import os
import sys
import traceback

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={os.cpu_count() or 1}"
    ).strip()

BENCH_JSON = os.environ.get("REPRO_BENCH_JSON", "BENCH_greedy.json")


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (
        batched_builds,
        common,
        flops_model,
        kernel_fusion,
        ortho_timing,
        pivot_timing,
        roofline_table,
        sketch_vs_greedy,
        strong_scaling,
        weak_scaling,
    )

    # (benchmarks/streaming_sweep.py is its own CI step writing
    # BENCH_streaming.json — not in this loop, so the smoke runs once)
    ok = True
    for mod in (pivot_timing, ortho_timing, flops_model, kernel_fusion,
                strong_scaling, weak_scaling, roofline_table,
                sketch_vs_greedy, batched_builds):
        try:
            mod.run(csv=True)
        except Exception as e:  # keep the harness going; report at the end
            ok = False
            print(f"{mod.__name__},0.0,ERROR:{type(e).__name__}:{e}",
                  file=sys.stdout)
            traceback.print_exc(file=sys.stderr)

    n_rows = common.write_bench_json(BENCH_JSON)
    print(f"# wrote {n_rows} rows to {BENCH_JSON}", file=sys.stderr)

    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
