"""Benchmark harness: one entry per paper table/figure + assignment tables.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).

  fig6.1a  — pivot-search time vs iteration (constant in j)
  fig6.1b  — IMGS orthogonalization time vs iteration (linear in j)
  fig6.2   — strong-scaling efficiency (compiled per-device costs + Eq 6.6)
  fig6.4   — weak scaling incl. the Blue Waters flagship dry-run cells
  rem5.4   — FLOP-count model validation
  perf_*   — greedy_update fusion evidence
  roofline — the full arch x shape x mesh baseline table (from artifacts)
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (
        flops_model,
        kernel_fusion,
        ortho_timing,
        pivot_timing,
        roofline_table,
        strong_scaling,
        weak_scaling,
    )

    ok = True
    for mod in (pivot_timing, ortho_timing, flops_model, kernel_fusion,
                strong_scaling, weak_scaling, roofline_table):
        try:
            mod.run(csv=True)
        except Exception as e:  # keep the harness going; report at the end
            ok = False
            print(f"{mod.__name__},0.0,ERROR:{type(e).__name__}:{e}",
                  file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
