"""Paper Fig. 6.2 / 6.3(a): strong-scaling efficiency of the pivot search.

This container has ONE physical core, so multi-device wall-clock is
meaningless; scaling is derived the same way the roofline is: per-device
compiled cost at P in {1, 2, 4, 8} host devices (subprocess with forced
device count) + the paper's Amdahl model Eq. (6.6):

    E ~ 1 - nu*k*(P-1) / (2M)        [master-orthogonalization serial term]

Our SPMD design replicates orthogonalization (no master), so the measured
per-device byte/FLOP share should scale ~1/P with only the collective
overhead added — we report both the paper's model and the compiled-cost
scaling.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

from benchmarks.common import emit

_SCRIPT = r"""
import jax, json
import jax.numpy as jnp
import numpy as np
from repro.compat import make_auto_mesh
from repro.core.distributed import dist_greedy_init, make_dist_greedy_step
from jax.sharding import NamedSharding, PartitionSpec as P

P_dev = len(jax.devices())
N, M = 1000, 240 * P_dev * 0 + 2048  # fixed M (strong scaling)
mesh = make_auto_mesh((P_dev,), ("cols",))
S = jax.ShapeDtypeStruct((N, M), jnp.complex64,
                         sharding=NamedSharding(mesh, P(None, ("cols",))))
st = jax.eval_shape(lambda: dist_greedy_init(
    jnp.zeros((N, M), jnp.complex64), 32, mesh))
from repro.core.distributed import state_shardings
sh = state_shardings(mesh)
st = jax.tree.map(lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                    sharding=h), st, sh)
step = make_dist_greedy_step(mesh)
compiled = step.lower(S, st).compile()
ca = compiled.cost_analysis()
if isinstance(ca, list):
    ca = ca[0]
from repro.launch.roofline import collective_bytes
coll = collective_bytes(compiled.as_text())["total"]
print("RESULT " + json.dumps({
    "P": P_dev, "flops": float(ca.get("flops", 0)),
    "bytes": float(ca.get("bytes accessed", 0)), "coll": float(coll)}))
"""


def run(csv: bool = True):
    results = []
    for P in (1, 2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={P}"
        env["JAX_PLATFORMS"] = "cpu"
        env.setdefault("PYTHONPATH", "src")
        p = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                           capture_output=True, text=True, timeout=600)
        if p.returncode != 0:
            raise RuntimeError(p.stderr[-2000:])
        line = [l for l in p.stdout.splitlines()
                if l.startswith("RESULT ")][-1]
        results.append(json.loads(line[len("RESULT "):]))

    base = results[0]
    rows = []
    for r in results:
        P = r["P"]
        # per-device share of the dominant (memory) term vs perfect 1/P
        eff_bytes = base["bytes"] / (P * r["bytes"])
        # paper's Eq. 6.6 with nu=2, k=32, M=2048
        eff_model = 1 - 2 * 32 * (P - 1) / (2 * 2048)
        rows.append((P, eff_bytes, eff_model, r["coll"]))
        if csv:
            emit(
                f"fig6.2_strong_P{P}",
                0.0,
                f"eff_compiled_bytes={eff_bytes:.3f};"
                f"eff_eq6.6={eff_model:.3f};coll_bytes={r['coll']:.2e}",
            )
    return rows


if __name__ == "__main__":
    run()
