"""Assignment §Roofline: per-(arch x shape x mesh) roofline terms.

Reads the dry-run JSON artifacts and prints the full baseline table as CSV
(one row per cell): three terms in seconds, dominant bottleneck,
MODEL_FLOPS / HLO_FLOPs usefulness ratio, bytes/device.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def run(csv: bool = True, art_dir: str = "artifacts/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        rec = json.load(open(path))
        tag = os.path.basename(path)[:-5]
        if "skipped" in rec:
            if csv:
                emit(f"roofline_{tag}", 0.0, f"SKIP:{rec['skipped'][:40]}")
            continue
        if "error" in rec:
            if csv:
                emit(f"roofline_{tag}", 0.0, f"ERROR:{rec['error'][:60]}")
            continue
        if "roofline" in rec and "roofline" in rec.get("roofline", {}):
            ro = rec["roofline"]["roofline"]
            useful = rec["roofline"]["useful_flop_ratio"]
            mem = rec.get("full", {}).get("memory", {})
            args_gb = mem.get("argument_size_in_bytes", 0) / 1e9
            if csv:
                emit(
                    f"roofline_{tag}",
                    ro["bound_s"] * 1e6,
                    f"compute={ro['compute_s']:.3e};"
                    f"memory={ro['memory_s']:.3e};"
                    f"collective={ro['collective_s']:.3e};"
                    f"dominant={ro['dominant']};useful={useful:.3f};"
                    f"args_gb_per_dev={args_gb:.2f}",
                )
            rows.append((tag, ro, useful))
        elif "roofline" in rec:  # gw flagship artifact layout
            ro = rec["roofline"]
            if csv:
                emit(
                    f"roofline_{tag}",
                    ro["bound_s"] * 1e6,
                    f"compute={ro['compute_s']:.3e};"
                    f"memory={ro['memory_s']:.3e};"
                    f"collective={ro['collective_s']:.3e};"
                    f"dominant={ro['dominant']};"
                    f"useful={rec.get('useful_flop_ratio', 0):.3f}",
                )
            rows.append((tag, ro, rec.get("useful_flop_ratio")))
        elif "full" in rec and csv:
            c = rec["full"]["raw_cost"]
            emit(
                f"dryrun_{tag}",
                0.0,
                f"compiled_ok=1;flops_raw={c['flops']:.3e};"
                f"coll_raw={c['collective_bytes']:.3e}",
            )
    return rows


if __name__ == "__main__":
    run()
