"""Sketch-vs-greedy: pass count and wall time to a fixed rank target.

The randomized range-finder's pitch is pass complexity: greedy streams S
once per accepted basis vector (once per ``block_p`` when blocked), the
sketch streams it ``1 + 2*power`` times TOTAL.  This sweep builds the
same rank-``max_k`` basis over one memmapped snapshot family through

  sketch_vs_greedy_rand_pw0    randomized, power=0   (1 pass)
  sketch_vs_greedy_rand_pw1    randomized, power=1   (3 passes)
  sketch_vs_greedy_stream_bp1  streamed greedy       (~max_k passes)
  sketch_vs_greedy_stream_bp8  streamed greedy, block_p=8 (~max_k/8)

and emits per-build wall time with the pass count in the derived column
— the measured form of the ``"auto"`` cutover rule (sketch wins when
greedy's pass count exceeds ~2x the sketch's).
"""

from __future__ import annotations

import os
import tempfile

import numpy as np

from benchmarks.common import emit, steady_min
from repro.core.randomized import rb_randomized_streamed
from repro.core.streaming import rb_greedy_streamed
from repro.data.providers import MemmapProvider, write_snapshot_npy

_N, _M = 1024, 4096
_MAX_K = 32
_TILE_M = 512


def _snapshots(path: str) -> MemmapProvider:
    # smooth parameterized family (fast-decaying n-width) at a size whose
    # streamed build is dominated by the per-pass sweep, not init
    x = np.linspace(0.0, 1.0, _N, dtype=np.float64)[:, None]
    nu = np.linspace(0.5, 4.0, _M, dtype=np.float64)[None, :]
    S = (np.sin(2 * np.pi * nu * x) * np.exp(-nu * x)).astype(np.float32)
    return MemmapProvider(write_snapshot_npy(path, S))


def run(csv: bool = True):
    results = []
    with tempfile.TemporaryDirectory() as d:
        prov = _snapshots(os.path.join(d, "S.npy"))

        def build_rand(power):
            return lambda: rb_randomized_streamed(
                prov, tau=None, max_k=_MAX_K, power=power, tile_m=_TILE_M)

        def build_greedy(block_p):
            return lambda: rb_greedy_streamed(
                prov, tau=0.0, max_k=_MAX_K, block_p=block_p,
                tile_m=_TILE_M, keep_R=False)

        n_tiles = -(-_M // _TILE_M)
        cases = [
            ("rand_pw0", build_rand(0), 1),
            ("rand_pw1", build_rand(1), 3),
            ("stream_bp1", build_greedy(1), _MAX_K),
            ("stream_bp8", build_greedy(8), -(-_MAX_K // 8)),
        ]
        for name, fn, passes in cases:
            t = steady_min(fn, per=1, repeats=3, warmup=1)
            results.append((name, t, passes))
            if csv:
                emit(
                    f"sketch_vs_greedy_{name}",
                    t * 1e6,
                    f"passes={passes};k={_MAX_K};N={_N};M={_M};"
                    f"tiles={n_tiles}",
                )
    return results


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(csv=True)
