"""Paper Fig. 6.3(b) / 6.4: weak scaling — M grows with the device count.

greedycpp's headline: N=10,000, M = 100 * cores, up to 32,768 cores with a
~flat time per basis.  Weak scaling holds when the per-device compiled cost
is constant as (P, M) scale together and the collective term grows at most
logarithmically.  We verify per-device costs at P in {1,2,4,8} (subprocess,
forced host devices) and report the flagship 256/512-device dry-run numbers
from artifacts/dryrun (the Blue Waters-shape cell).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

_SCRIPT = r"""
import jax, json
import jax.numpy as jnp
from repro.compat import make_auto_mesh
from repro.core.distributed import dist_greedy_init, make_dist_greedy_step, state_shardings
from jax.sharding import NamedSharding, PartitionSpec as P

P_dev = len(jax.devices())
N, M = 1000, 512 * P_dev   # M grows with P (weak scaling)
mesh = make_auto_mesh((P_dev,), ("cols",))
S = jax.ShapeDtypeStruct((N, M), jnp.complex64,
                         sharding=NamedSharding(mesh, P(None, ("cols",))))
st = jax.eval_shape(lambda: dist_greedy_init(
    jnp.zeros((N, M), jnp.complex64), 32, mesh))
sh = state_shardings(mesh)
st = jax.tree.map(lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                    sharding=h), st, sh)
compiled = make_dist_greedy_step(mesh).lower(S, st).compile()
ca = compiled.cost_analysis()
if isinstance(ca, list):
    ca = ca[0]
from repro.launch.roofline import collective_bytes
coll = collective_bytes(compiled.as_text())["total"]
print("RESULT " + json.dumps({
    "P": P_dev, "M": M, "flops": float(ca.get("flops", 0)),
    "bytes": float(ca.get("bytes accessed", 0)), "coll": float(coll)}))
"""


def run(csv: bool = True):
    results = []
    for P in (1, 2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={P}"
        env["JAX_PLATFORMS"] = "cpu"
        env.setdefault("PYTHONPATH", "src")
        p = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                           capture_output=True, text=True, timeout=600)
        if p.returncode != 0:
            raise RuntimeError(p.stderr[-2000:])
        line = [l for l in p.stdout.splitlines()
                if l.startswith("RESULT ")][-1]
        results.append(json.loads(line[len("RESULT "):]))

    base = results[0]
    for r in results:
        eff = base["bytes"] / r["bytes"]  # perfect weak scaling -> 1.0
        if csv:
            emit(
                f"fig6.4_weak_P{r['P']}_M{r['M']}",
                0.0,
                f"per_device_bytes={r['bytes']:.3e};eff={eff:.3f};"
                f"coll={r['coll']:.2e}",
            )

    # flagship cells from the dry-run artifacts (256 / 512 devices)
    for mesh in ("single", "multi"):
        path = f"artifacts/dryrun/gw_greedy__{mesh}.json"
        if os.path.exists(path):
            rec = json.load(open(path))
            c = rec["per_device_cost"]
            if csv:
                emit(
                    f"fig6.4_weak_flagship_{mesh}_P{rec['devices']}",
                    rec["roofline"]["bound_s"] * 1e6,
                    f"bytes={c['bytes']:.3e};coll={c['collective_bytes']:.2e};"
                    f"dominant={rec['roofline']['dominant']};"
                    f"bound_s_per_iter={rec['roofline']['bound_s']:.2e}",
                )
    return results


if __name__ == "__main__":
    run()
