"""Pipeline parallelism over the pod axis (GPipe-style fill–drain).

The multi-pod mesh adds a "pod" axis; inter-pod ICI/DCN links are the
slowest in the hierarchy, so the natural large-scale layout is pipeline
stages across pods (layer ranges per pod) with microbatches streaming
through — DP×TP inside each pod stays exactly as in the single-pod design.

Implementation: ``shard_map`` manual over ("pod",) with stage-stacked
parameters (leading dim = n_stages sharded over "pod"); activations step
stage-to-stage with ``lax.ppermute`` inside a scan over
``n_micro + n_stages - 1`` ticks (fill–drain schedule; bubble fraction
(n_stages-1)/(n_micro+n_stages-1)).  The backward pass differentiates
through the ppermute scan (its transpose is the reverse permute), giving
GPipe-correct gradients without hand-written send/recv.

This module is intentionally self-contained (dense decoder family) — it is
the PP *feature* demonstration lowered in the dry-run; fusing it with the
full trainer is configuration plumbing, not new machinery.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from jax.experimental.shard_map import shard_map

from repro.models import transformer as tfm
from repro.models.layers import rms_norm


def stage_params_shape(cfg, n_stages: int):
    """Abstract stage-stacked block params: (n_stages, L/n_stages, ...)."""
    assert cfg.n_layers % n_stages == 0
    per = cfg.n_layers // n_stages

    def stack(leaf):
        return jax.ShapeDtypeStruct(
            (n_stages, per) + leaf.shape[1:], leaf.dtype
        )

    blocks = jax.eval_shape(
        lambda k: tfm._stack_init(
            lambda kk: tfm.init_decoder_block(kk, cfg), k, cfg.n_layers
        ),
        jax.random.key(0),
    )
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((n_stages, cfg.n_layers // n_stages)
                                       + l.shape[1:], l.dtype),
        blocks,
    )


def make_pipeline_forward(cfg, mesh: Mesh, n_micro: int):
    """Jittable pipelined forward + mean CE loss over microbatches.

    Args (abstract shapes):
      embed:   (V, d) replicated over pod (used by stage 0 / last stage)
      blocks:  stage-stacked block params, leading dim sharded over "pod"
      norm_w, lm_head: final norm + head (last stage)
      tokens, labels: (n_micro, B_micro, S) batch, replicated over pod
    """
    n_stages = mesh.shape["pod"]
    per = cfg.n_layers // n_stages

    def stage_apply(stage_blocks, x):
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1])[None], x.shape[:2]
        )

        def body(xx, bp):
            return tfm.decoder_block(bp, xx, cfg, positions), None

        x, _ = jax.lax.scan(body, x, stage_blocks)
        return x

    def local_fn(embed, blocks, norm_w, lm_head, tokens, labels):
        # blocks arrive as (1, per, ...) — this pod's stage
        stage_blocks = jax.tree.map(lambda b: b[0], blocks)
        stage_id = jax.lax.axis_index("pod")
        n_ticks = n_micro + n_stages - 1
        B, S = tokens.shape[1], tokens.shape[2]
        d = cfg.d_model

        def tick(carry, t):
            loss_sum, buf = carry
            # stage 0 ingests microbatch t (if in range)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            x_in = embed[tokens[mb_idx]]
            x = jnp.where(stage_id == 0, x_in, buf)
            y = stage_apply(stage_blocks, x.astype(x_in.dtype))
            # last stage computes the loss for the microbatch that entered
            # the pipe at tick t - (n_stages - 1)
            done_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            logits = (
                rms_norm(y, norm_w, cfg.norm_eps) @ lm_head
            ).astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, labels[done_idx][..., None], axis=-1
            )[..., 0]
            mb_loss = jnp.mean(logz - gold)
            active = (t >= n_stages - 1) & (stage_id == n_stages - 1)
            loss_sum = loss_sum + jnp.where(active, mb_loss, 0.0)
            # shift activations one stage forward
            perm = [(i, i + 1) for i in range(n_stages - 1)]
            buf_next = jax.lax.ppermute(y, "pod", perm)
            return (loss_sum, buf_next), None

        buf0 = jnp.zeros((B, S, d), embed.dtype)
        (loss_sum, _), _ = jax.lax.scan(
            tick, (jnp.zeros((), jnp.float32), buf0),
            jnp.arange(n_ticks),
        )
        # broadcast the last stage's mean loss to every pod
        loss = jax.lax.psum(loss_sum, "pod") / n_micro
        return loss[None]

    pod_axis = ("pod",)
    in_specs = (
        P(*([None] * 2)),                     # embed replicated over pod
        jax.tree.map(lambda _: P("pod"), stage_params_shape(
            cfg, n_stages)),                  # stage dim over pod
        P(None),
        P(None, None),
        P(*([None] * 3)),                     # tokens (n_micro, B, S)
        P(*([None] * 3)),
    )
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=in_specs, out_specs=P("pod"),
        check_rep=False,
    )

    def loss_fn(embed, blocks, norm_w, lm_head, tokens, labels):
        # fn returns the (identical, psum'd) loss once per pod: average
        out = fn(embed, blocks, norm_w, lm_head, tokens, labels)
        return jnp.sum(out) / n_stages

    return jax.jit(loss_fn), stage_params_shape(cfg, n_stages)
