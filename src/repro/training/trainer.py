"""Training loop core: microbatched, donated, compression-aware train_step.

``make_train_step`` builds a jitted step:

  - gradient accumulation over ``n_microbatches`` via lax.scan (keeps the
    live activation set to one microbatch — the knob that fits
    global_batch=256 x 4k-seq cells in HBM);
  - optional error-feedback top-k gradient compression before the (implicit)
    DP all-reduce;
  - AdamW with warmup-cosine schedule and global-norm clipping;
  - buffer donation on (params, opt, data) for in-place updates.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import api
from repro.optim import (
    AdamWState,
    adamw_init,
    adamw_update,
    ef_state_init,
    ef_topk_compress,
    warmup_cosine,
)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    ef: Any          # error-feedback accumulators (None if disabled)
    step: jax.Array


def train_state_init(cfg, key, compression: bool = False) -> TrainState:
    params = api.init_params(cfg, key)
    return TrainState(
        params=params,
        opt=adamw_init(params),
        ef=ef_state_init(params) if compression else None,
        step=jnp.zeros((), jnp.int32),
    )


def _split_microbatches(batch: dict, n: int) -> dict:
    def resh(x):
        B = x.shape[0]
        return x.reshape(n, B // n, *x.shape[1:])

    return {k: resh(v) for k, v in batch.items()}


def make_train_step(
    cfg,
    n_microbatches: int = 1,
    base_lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10000,
    weight_decay: float = 0.01,
    clip_norm: float = 1.0,
    compression_ratio: Optional[float] = None,
    donate: bool = True,
):
    """Returns jitted ``step(state, batch) -> (state, metrics)``."""

    def loss_of(params, mb):
        return api.loss_fn(cfg, params, mb)

    def train_step(state: TrainState, batch: dict):
        if n_microbatches > 1:
            mbs = _split_microbatches(batch, n_microbatches)

            def acc_body(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_of)(state.params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                )
                return (gsum, lsum + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (gsum, lsum), _ = jax.lax.scan(acc_body, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / n_microbatches, gsum)
            loss = lsum / n_microbatches
        else:
            loss, grads = jax.value_and_grad(loss_of)(state.params, batch)

        ef = state.ef
        if compression_ratio is not None and ef is not None:
            grads, ef = ef_topk_compress(grads, ef, compression_ratio)

        lr = warmup_cosine(state.step, base_lr, warmup, total_steps)
        params, opt = adamw_update(
            grads, state.opt, state.params, lr,
            weight_decay=weight_decay, clip_norm=clip_norm,
        )
        new_state = TrainState(params=params, opt=opt, ef=ef,
                               step=state.step + 1)
        metrics = {"loss": loss, "lr": lr,
                   "grad_norm": _tree_norm(grads)}
        return new_state, metrics

    donate_argnums = (0,) if donate else ()
    return jax.jit(train_step, donate_argnums=donate_argnums)


def _tree_norm(tree):
    sq = jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree
    )
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))
