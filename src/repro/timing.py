"""Steady-state timing: the one wall-clock method every reported number
uses.

Single-shot wall clock swings ~±40% on a shared 2-core box — PRs 3–4
purged it from the committed benchmarks in favor of this method, and the
serving launcher and roofline calibration report with it too.  The rule:
warm up, then time CONSECUTIVE repeats (hot thread pools, warm allocator
— what a production driver loop experiences) and take the MINIMUM, which
rejects load spikes and unlucky thread placement.

``benchmarks/common.steady_min`` delegates here (the benchmarks package
is repo tooling, not importable from the installed ``repro`` package, so
the canonical implementation lives on the package side).
"""

from __future__ import annotations

import time


def steady_min(fn, per: int = 1, repeats: int = 12, warmup: int = 3) -> float:
    """Best-of-``repeats`` steady-state seconds per iteration.

    ``fn`` performs ``per`` hot-loop iterations and must block on its
    outputs (``jax.block_until_ready``) before returning.
    """
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best / per
