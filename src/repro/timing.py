"""Steady-state timing: the one wall-clock method every reported number
uses.

Single-shot wall clock swings ~±40% on a shared 2-core box — PRs 3–4
purged it from the committed benchmarks in favor of this method, and the
serving launcher and roofline calibration report with it too.  The rule:
warm up, then time CONSECUTIVE repeats (hot thread pools, warm allocator
— what a production driver loop experiences) and take the MINIMUM, which
rejects load spikes and unlucky thread placement.

``benchmarks/common.steady_min`` delegates here (the benchmarks package
is repo tooling, not importable from the installed ``repro`` package, so
the canonical implementation lives on the package side).
"""

from __future__ import annotations

import time


def steady_min(fn, per: int = 1, repeats: int = 12, warmup: int = 3) -> float:
    """Best-of-``repeats`` steady-state seconds per iteration.

    ``fn`` performs ``per`` hot-loop iterations and must block on its
    outputs (``jax.block_until_ready``) before returning.
    """
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best / per


def percentiles(samples, qs=(50.0, 95.0, 99.0)) -> dict:
    """Percentiles of ``samples`` by sorted linear interpolation.

    The one quantile method every latency report uses (serving metrics
    snapshots and the load harness both call this instead of hand-rolling
    index math).  ``qs`` are percent ranks in [0, 100]; returns
    ``{q: value}`` with the values linearly interpolated between order
    statistics (numpy's default "linear" method), so ``percentiles(s,
    (0, 50, 100))`` gives min / median / max exactly.

    Raises ``ValueError`` on an empty sample set or an out-of-range q —
    an empty latency window is a caller-level condition (report "no
    samples", don't fabricate a 0.0 percentile).
    """
    xs = sorted(float(x) for x in samples)
    if not xs:
        raise ValueError("percentiles() of empty sample set")
    out = {}
    n = len(xs)
    for q in qs:
        fq = float(q)
        if not 0.0 <= fq <= 100.0:
            raise ValueError(f"percentile rank {q!r} outside [0, 100]")
        pos = (fq / 100.0) * (n - 1)
        lo = int(pos)
        hi = min(lo + 1, n - 1)
        frac = pos - lo
        out[q] = xs[lo] + (xs[hi] - xs[lo]) * frac
    return out
