"""Public jit'd wrapper for the classical-GS panel projection pass.

Complex panels are handled via the real embedding  z = x + iy  ↦  [x; y],
A ↦ [[Ar, -Ai], [Ai, Ar]]  (a ring isomorphism, exactly as in
:mod:`repro.kernels.imgs_project.ops`), under which ``C = Q^H V`` and
``V' = V - Q C`` become the real kernel applied to the embedded operands:
``embed(Q)^T stack(V) = stack(Q^H V)``.  This keeps one kernel for both
dtypes; the production TPU path for the GW (complex) case feeds the planes
directly.  For c64/f32 the kernel accumulates in f32 (TPU MXU native); use
the ref path when f64-level precision is required on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.imgs_panel import kernel as _k
from repro.kernels.common import (
    LANES,
    default_interpret,
    validate_tiles,
)
from repro.kernels.common import pad_to as _pad_to
from repro.kernels.common import round_up as _round_up

_SUBLANES = 8  # f32 sublane count: the panel's row-padding quantum


def imgs_panel(
    V: jax.Array,
    Q: jax.Array,
    nt: int = 1024,
    kt: int = 512,
    interpret: bool | None = None,
):
    """One classical-GS panel pass: returns (V - Q Q^H V, Q^H V).

    Args:
      V: (N, p) candidate panel (zero columns are exact no-ops).
      Q: (N, K) basis (zero columns are no-ops).
      nt, kt: VMEM tile sizes (rows of Q, columns of Q).
      interpret: force Pallas interpret mode; default: interpret unless the
        backend is TPU.

    Matches :func:`repro.kernels.imgs_panel.ref.imgs_panel_ref`.
    """
    if interpret is None:
        interpret = default_interpret()
    validate_tiles("imgs_panel", nt=nt, kt=kt)

    N, K = Q.shape
    p = V.shape[1]
    if jnp.iscomplexobj(Q):
        plane = jnp.float32 if Q.dtype == jnp.complex64 else jnp.float64
        Ve = jnp.concatenate(
            [V.real.astype(plane), V.imag.astype(plane)], axis=0
        )  # (2N, p) stacked planes
        Qr = Q.real.astype(plane)
        Qi = Q.imag.astype(plane)
        Qe = jnp.block([[Qr, -Qi], [Qi, Qr]])  # (2N, 2K) real embedding
        Ve_out, Ce = imgs_panel(Ve, Qe, nt=nt, kt=kt, interpret=interpret)
        V_out = (Ve_out[:N] + 1j * Ve_out[N:]).astype(Q.dtype)
        C = (Ce[:K] + 1j * Ce[K:]).astype(Q.dtype)
        return V_out, C

    pp = _round_up(max(p, 1), _SUBLANES)
    nt = min(nt, _round_up(N, LANES))
    kt = min(kt, _round_up(K, LANES))
    Np, Kp = _round_up(N, nt), _round_up(K, kt)
    vt = _pad_to(_pad_to(V.T.astype(Q.dtype), pp, 0), Np, 1)
    Qp = _pad_to(_pad_to(Q, Np, 0), Kp, 1)
    vt_out, ct = _k.imgs_panel_real(vt, Qp, nt, kt, interpret)
    return vt_out[:p, :N].T, ct[:p, :K].T
