"""Pure-jnp oracle for one classical-GS PANEL projection pass."""

from __future__ import annotations

import jax


def imgs_panel_ref(V: jax.Array, Q: jax.Array):
    """One classical-GS pass on a whole candidate panel.

    The BLAS-3 form of :func:`repro.kernels.imgs_project.ref.imgs_project_ref`
    applied to p candidates at once: ``C = Q^H V``; ``V' = V - Q C`` — one
    read of Q per panel instead of per candidate (the panel factorization
    idea of the blocked-QR literature the paper cites: Quintana-Orti's
    BLAS-3 QR, Demmel et al. CA-RRQR).

    Args:
      V: (N, p) candidate panel (zero columns are no-ops).
      Q: (N, K) basis (zero columns are no-ops).

    Returns (V', C) with C: (K, p).
    """
    C = Q.conj().T @ V
    return V - Q @ C, C
