"""Pallas TPU kernel for one classical-GS PANEL projection pass (BLAS-3).

The blocked drivers' remaining serial residue after the PR-4 panel sweep is
orthogonalization: p sequential :mod:`repro.kernels.imgs_project` calls per
block, each a pair of k-by-N GEMVs plus a host-visible while_loop — the
per-basis bound the paper's Sec. 4 predicts for iterated MGS.  This kernel
is the panel factorization fix (cf. Quintana-Orti's BLAS-3 QR and Demmel et
al.'s CA-RRQR, both cited in PAPERS.md): project the WHOLE (N, p) candidate
panel against Q in one pass,

  proj:   C = Q^H V        (K, p)  — one read of Q per panel,
  update: V' = V - Q C     (N, p)  — rank-K panel update,

so k*p*N GEMM work replaces p separate k*N GEMV chains.  Two pallas_calls
(the reduction C needs all rows of Q before the update can start — a true
dependency), mirroring :mod:`repro.kernels.imgs_project.kernel` with the
candidate panel V^T (p, N) in place of the single row vector:

  proj:   grid (K/kt, N/nt), accumulate  c_tile += vt_blk @ Q_blk  in VMEM.
  update: grid (N/nt, K/kt), accumulate  p_tile += c_blk @ Q_blk^T; then
          v' = v - p at the last k-block.

Tiles default to (nt, kt) = (1024, 512): Q blocks are 2 MB f32 in VMEM; the
panel adds p rows per tile (p is padded to a sublane multiple by ops.py;
padded rows are zero and project to zero).  Complex inputs use the real
embedding in ops.py (see there), so the kernel itself is real-only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _proj_kernel(vt_ref, q_ref, c_ref, c_scr):
    n_i = pl.program_id(1)
    n_blocks = pl.num_programs(1)

    @pl.when(n_i == 0)
    def _():
        c_scr[...] = jnp.zeros_like(c_scr)

    # (p, nt) @ (nt, kt) -> (p, kt): the panel's C^T tile
    c_scr[...] += jnp.dot(
        vt_ref[...], q_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(n_i == n_blocks - 1)
    def _():
        c_ref[...] = c_scr[...].astype(c_ref.dtype)


def _update_kernel(vt_ref, q_ref, c_ref, out_ref, p_scr):
    k_i = pl.program_id(1)
    k_blocks = pl.num_programs(1)

    @pl.when(k_i == 0)
    def _():
        p_scr[...] = jnp.zeros_like(p_scr)

    # (p, kt) @ (kt, nt) -> (p, nt): the rank-K panel update tile
    p_scr[...] += jnp.dot(
        c_ref[...], q_ref[...].T, preferred_element_type=jnp.float32
    )

    @pl.when(k_i == k_blocks - 1)
    def _():
        out_ref[...] = vt_ref[...] - p_scr[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("nt", "kt", "interpret"))
def imgs_panel_real(vt, Q, nt: int = 1024, kt: int = 512,
                    interpret: bool = True):
    """One panel GS pass on padded real inputs: returns (vt', ct).

    vt: (p, N) = V^T; Q: (N, K); p % 8 == 0, N % nt == 0, K % kt == 0.
    ct is C^T with shape (p, K).
    """
    p, _ = vt.shape
    N, K = Q.shape
    ct = pl.pallas_call(
        _proj_kernel,
        grid=(K // kt, N // nt),
        in_specs=[
            pl.BlockSpec((p, nt), lambda k, n: (0, n)),
            pl.BlockSpec((nt, kt), lambda k, n: (n, k)),
        ],
        out_specs=pl.BlockSpec((p, kt), lambda k, n: (0, k)),
        out_shape=jax.ShapeDtypeStruct((p, K), Q.dtype),
        scratch_shapes=[pltpu.VMEM((p, kt), jnp.float32)],
        interpret=interpret,
    )(vt, Q)

    vt_out = pl.pallas_call(
        _update_kernel,
        grid=(N // nt, K // kt),
        in_specs=[
            pl.BlockSpec((p, nt), lambda n, k: (0, n)),
            pl.BlockSpec((nt, kt), lambda n, k: (n, k)),
            pl.BlockSpec((p, kt), lambda n, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((p, nt), lambda n, k: (0, n)),
        out_shape=jax.ShapeDtypeStruct((p, N), vt.dtype),
        scratch_shapes=[pltpu.VMEM((p, nt), jnp.float32)],
        interpret=interpret,
    )(vt, Q, ct)
    return vt_out, ct
