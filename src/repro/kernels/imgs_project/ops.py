"""Public jit'd wrapper for the iterated-GS projection pass.

Complex vectors are handled via the real embedding  z = x + iy  ↦  [x; y],
A ↦ [[Ar, -Ai], [Ai, Ar]]  (a ring isomorphism), under which
``c = Q^H v`` and ``v' = v - Q c`` become exactly the real kernel applied to
the embedded operands:  embed(Q)^T embed(v) = embed(Q^H v).  This keeps one
kernel for both dtypes; the production TPU path for the GW (complex) case
feeds the planes directly.  For c64/f32 the kernel accumulates in f32 (TPU
MXU native); use the ref path when f64-level precision is required on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.imgs_project import kernel as _k
from repro.kernels.common import (
    LANES,
    default_interpret,
    validate_tiles,
)
from repro.kernels.common import pad_to as _pad_to
from repro.kernels.common import round_up as _round_up


def imgs_project(
    v: jax.Array,
    Q: jax.Array,
    nt: int = 1024,
    kt: int = 512,
    interpret: bool | None = None,
):
    """One classical-GS pass: returns (v - Q Q^H v, Q^H v).

    Matches :func:`repro.kernels.imgs_project.ref.imgs_project_ref`.
    """
    if interpret is None:
        interpret = default_interpret()
    validate_tiles("imgs_project", nt=nt, kt=kt)

    N, K = Q.shape
    if jnp.iscomplexobj(Q):
        plane = jnp.float32 if Q.dtype == jnp.complex64 else jnp.float64
        ve = jnp.concatenate(
            [v.real.astype(plane), v.imag.astype(plane)]
        )
        Qr = Q.real.astype(plane)
        Qi = Q.imag.astype(plane)
        Qe = jnp.block([[Qr, -Qi], [Qi, Qr]])
        ve_out, ce = imgs_project(ve, Qe, nt=nt, kt=kt, interpret=interpret)
        v_out = (ve_out[:N] + 1j * ve_out[N:]).astype(Q.dtype)
        c = (ce[:K] + 1j * ce[K:]).astype(Q.dtype)
        return v_out, c

    nt = min(nt, _round_up(N, LANES))
    kt = min(kt, _round_up(K, LANES))
    Np, Kp = _round_up(N, nt), _round_up(K, kt)
    vp = _pad_to(v[None, :].astype(Q.dtype), Np, 1)
    Qp = _pad_to(_pad_to(Q, Np, 0), Kp, 1)
    v_out, c = _k.imgs_project_real(vp, Qp, nt, kt, interpret)
    return v_out[0, :N], c[0, :K]
