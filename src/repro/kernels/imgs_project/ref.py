"""Pure-jnp oracle for one iterated-Gram-Schmidt projection pass."""

from __future__ import annotations

import jax


def imgs_project_ref(v: jax.Array, Q: jax.Array):
    """One classical-GS pass: c = Q^H v; v' = v - Q c.

    Args:
      v: (N,) vector to orthogonalize.
      Q: (N, K) basis (zero columns are no-ops).

    Returns (v', c) with c: (K,).
    """
    c = Q.conj().T @ v
    return v - Q @ c, c
