"""Pallas TPU kernel for one iterated-Gram-Schmidt projection pass.

The orthogonalization step (paper Fig. 6.1b) is the second hot-spot:
``c = Q^H v`` followed by the rank-k update ``v' = v - Q c``.  The paper
notes (§6.1.5) that its sequential-MGS formulation precludes BLAS-2; we use
the classical iterated form exactly so that both halves are matvecs that map
onto the MXU (the fix the paper itself suggests via Hoffmann's "CMGSI").

Two pallas_calls (the reduction c needs all rows of Q before the update can
start — a true dependency):

  proj:   grid (K/kt, N/nt), accumulate  c_tile += v_blk @ Q_blk  in VMEM.
  update: grid (N/nt, K/kt), accumulate  p_tile += c_blk @ Q_blk^T; then
          v' = v - p at the last k-block.

Tiles default to (nt, kt) = (1024, 512): Q blocks are 2 MB f32 in VMEM.
Complex inputs use split re/im planes (see greedy_update.kernel for the
rationale).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _proj_kernel(v_ref, q_ref, c_ref, c_scr):
    n_i = pl.program_id(1)
    n_blocks = pl.num_programs(1)

    @pl.when(n_i == 0)
    def _():
        c_scr[...] = jnp.zeros_like(c_scr)

    c_scr[...] += jnp.dot(
        v_ref[...], q_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(n_i == n_blocks - 1)
    def _():
        c_ref[...] = c_scr[...].astype(c_ref.dtype)


def _update_kernel(v_ref, q_ref, c_ref, out_ref, p_scr):
    k_i = pl.program_id(1)
    k_blocks = pl.num_programs(1)

    @pl.when(k_i == 0)
    def _():
        p_scr[...] = jnp.zeros_like(p_scr)

    # (1, kt) @ (kt, nt) -> (1, nt)
    p_scr[...] += jnp.dot(
        c_ref[...], q_ref[...].T, preferred_element_type=jnp.float32
    )

    @pl.when(k_i == k_blocks - 1)
    def _():
        out_ref[...] = v_ref[...] - p_scr[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("nt", "kt", "interpret"))
def imgs_project_real(v, Q, nt: int = 1024, kt: int = 512,
                      interpret: bool = True):
    """One GS pass on padded real inputs: returns (v', c).

    v: (1, N); Q: (N, K); N % nt == 0, K % kt == 0.
    """
    N, K = Q.shape
    c = pl.pallas_call(
        _proj_kernel,
        grid=(K // kt, N // nt),
        in_specs=[
            pl.BlockSpec((1, nt), lambda k, n: (0, n)),
            pl.BlockSpec((nt, kt), lambda k, n: (n, k)),
        ],
        out_specs=pl.BlockSpec((1, kt), lambda k, n: (0, k)),
        out_shape=jax.ShapeDtypeStruct((1, K), Q.dtype),
        scratch_shapes=[pltpu.VMEM((1, kt), jnp.float32)],
        interpret=interpret,
    )(v, Q)

    v_out = pl.pallas_call(
        _update_kernel,
        grid=(N // nt, K // kt),
        in_specs=[
            pl.BlockSpec((1, nt), lambda n, k: (0, n)),
            pl.BlockSpec((nt, kt), lambda n, k: (n, k)),
            pl.BlockSpec((1, kt), lambda n, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((1, nt), lambda n, k: (0, n)),
        out_shape=jax.ShapeDtypeStruct((1, N), v.dtype),
        scratch_shapes=[pltpu.VMEM((1, nt), jnp.float32)],
        interpret=interpret,
    )(v, Q, c)
    return v_out, c
