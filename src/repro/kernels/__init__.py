"""Pallas TPU kernels for the framework's compute hot-spots.

Three kernels, each a package with ``kernel.py`` (pl.pallas_call + BlockSpec
VMEM tiling), ``ops.py`` (jit'd public wrapper with padding/dtype handling
and CPU interpret fallback), and ``ref.py`` (pure-jnp oracle used by the
allclose tests):

- ``greedy_update``   — the paper's pivot-search hot loop (Fig. 6.1a):
                        fused c = q^H S, acc += |c|^2, residual, block argmax.
- ``imgs_project``    — one iterated-GS pass: c = Q^H v, v' = v - Q c.
- ``flash_attention`` — causal/sliding-window GQA attention (online softmax)
                        for the LM architecture stack.
"""

from repro.kernels.greedy_update.ops import greedy_update
from repro.kernels.imgs_project.ops import imgs_project
from repro.kernels.flash_attention.ops import flash_attention

__all__ = ["greedy_update", "imgs_project", "flash_attention"]
