"""Pallas TPU kernel for the greedy pivot-search update (paper Fig. 6.1a).

The paper's hot loop is the per-iteration O(2MN) sweep: project every local
column onto the newly revealed basis vector (``c = q^H S``), update the
accumulated residual sums (Eq. 6.3) and find the local pivot (argmax).  The
serial code vectorizes this with AVX2; on TPU we fuse all three steps into
one Pallas kernel so the shard of S is read from HBM exactly once:

  unfused: read S (matvec) -> write c -> read c + acc (norm update + argmax)
  fused:   read S once; c, acc and per-block max/argmax produced in VMEM.

The sweep is memory-bound (arithmetic intensity ~2 FLOP per 4 bytes for f32,
~8 FLOP per 16 bytes for c64), so minimizing HBM traffic is the entire game
— the fusion is worth ~1.5x on the roofline (S is by far the dominant
stream; see the ``perf_greedy_fusion`` row in BENCH_greedy.json).

Complex snapshots (the GW production case) are handled as split re/im planes
(TPU MXUs are real): ``c = q^H S`` becomes four real matvecs evaluated in the
same pass.

Tiling: S is blocked (Nt x Mt) in VMEM with the column dimension M as the
outer (parallel) grid axis and the row dimension N as the inner (reduction)
axis, accumulating partial dot products into a VMEM scratch of width Mt.
Default (Nt, Mt) = (512, 1024): f32 planes use 2 * 2 MB VMEM for S-blocks
(re+im), well inside the ~16 MB v5e VMEM budget, and Mt = 1024 = 8 * 128
lanes keeps the MXU/VPU fully shaped.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_LARGE = -1e30


def _kernel_real(q_ref, s_ref, acc_ref, norms_ref,
                 c_ref, acc_out_ref, bmax_ref, bidx_ref, c_scr):
    m_i = pl.program_id(0)
    n_i = pl.program_id(1)
    n_blocks = pl.num_programs(1)

    @pl.when(n_i == 0)
    def _():
        c_scr[...] = jnp.zeros_like(c_scr)

    c_scr[...] += jnp.dot(
        q_ref[...], s_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(n_i == n_blocks - 1)
    def _():
        c = c_scr[...]
        c_ref[...] = c.astype(c_ref.dtype)
        acc = acc_ref[...] + c * c
        acc_out_ref[...] = acc
        res = norms_ref[...] - acc
        mt = res.shape[1]
        bmax_ref[0, 0] = jnp.max(res)
        local = jnp.argmax(res[0]).astype(jnp.int32)
        bidx_ref[0, 0] = local + m_i * mt


def _kernel_complex(qr_ref, qi_ref, sr_ref, si_ref, acc_ref, norms_ref,
                    cr_ref, ci_ref, acc_out_ref, bmax_ref, bidx_ref,
                    cr_scr, ci_scr):
    m_i = pl.program_id(0)
    n_i = pl.program_id(1)
    n_blocks = pl.num_programs(1)

    @pl.when(n_i == 0)
    def _():
        cr_scr[...] = jnp.zeros_like(cr_scr)
        ci_scr[...] = jnp.zeros_like(ci_scr)

    qr = qr_ref[...]
    qi = qi_ref[...]
    sr = sr_ref[...]
    si = si_ref[...]
    # c = q^H S = (qr - i qi)^T (sr + i si)
    cr_scr[...] += jnp.dot(qr, sr, preferred_element_type=jnp.float32)
    cr_scr[...] += jnp.dot(qi, si, preferred_element_type=jnp.float32)
    ci_scr[...] += jnp.dot(qr, si, preferred_element_type=jnp.float32)
    ci_scr[...] -= jnp.dot(qi, sr, preferred_element_type=jnp.float32)

    @pl.when(n_i == n_blocks - 1)
    def _():
        cr = cr_scr[...]
        ci = ci_scr[...]
        cr_ref[...] = cr.astype(cr_ref.dtype)
        ci_ref[...] = ci.astype(ci_ref.dtype)
        acc = acc_ref[...] + cr * cr + ci * ci
        acc_out_ref[...] = acc
        res = norms_ref[...] - acc
        mt = res.shape[1]
        bmax_ref[0, 0] = jnp.max(res)
        local = jnp.argmax(res[0]).astype(jnp.int32)
        bidx_ref[0, 0] = local + m_i * mt


@functools.partial(
    jax.jit, static_argnames=("nt", "mt", "interpret")
)
def greedy_update_real(q, S, acc, norms_sq, nt: int = 512, mt: int = 1024,
                       interpret: bool = True):
    """Real-dtype fused update on padded inputs (see ops.py for padding).

    q: (1, N) f32; S: (N, M) f32; acc, norms_sq: (1, M) f32.
    N % nt == 0 and M % mt == 0 must hold.
    """
    N, M = S.shape
    grid = (M // mt, N // nt)
    c, acc_out, bmax, bidx = pl.pallas_call(
        _kernel_real,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, nt), lambda m, n: (0, n)),
            pl.BlockSpec((nt, mt), lambda m, n: (n, m)),
            pl.BlockSpec((1, mt), lambda m, n: (0, m)),
            pl.BlockSpec((1, mt), lambda m, n: (0, m)),
        ],
        out_specs=[
            pl.BlockSpec((1, mt), lambda m, n: (0, m)),
            pl.BlockSpec((1, mt), lambda m, n: (0, m)),
            pl.BlockSpec((1, 1), lambda m, n: (0, m)),
            pl.BlockSpec((1, 1), lambda m, n: (0, m)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, M), S.dtype),
            jax.ShapeDtypeStruct((1, M), jnp.float32),
            jax.ShapeDtypeStruct((1, M // mt), jnp.float32),
            jax.ShapeDtypeStruct((1, M // mt), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, mt), jnp.float32)],
        interpret=interpret,
    )(q, S, acc, norms_sq)
    return c, acc_out, bmax, bidx


@functools.partial(
    jax.jit, static_argnames=("nt", "mt", "interpret")
)
def greedy_update_complex(qr, qi, Sr, Si, acc, norms_sq,
                          nt: int = 512, mt: int = 1024,
                          interpret: bool = True):
    """Complex fused update on split re/im planes (padded; see ops.py)."""
    N, M = Sr.shape
    grid = (M // mt, N // nt)
    cr, ci, acc_out, bmax, bidx = pl.pallas_call(
        _kernel_complex,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, nt), lambda m, n: (0, n)),
            pl.BlockSpec((1, nt), lambda m, n: (0, n)),
            pl.BlockSpec((nt, mt), lambda m, n: (n, m)),
            pl.BlockSpec((nt, mt), lambda m, n: (n, m)),
            pl.BlockSpec((1, mt), lambda m, n: (0, m)),
            pl.BlockSpec((1, mt), lambda m, n: (0, m)),
        ],
        out_specs=[
            pl.BlockSpec((1, mt), lambda m, n: (0, m)),
            pl.BlockSpec((1, mt), lambda m, n: (0, m)),
            pl.BlockSpec((1, mt), lambda m, n: (0, m)),
            pl.BlockSpec((1, 1), lambda m, n: (0, m)),
            pl.BlockSpec((1, 1), lambda m, n: (0, m)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, M), Sr.dtype),
            jax.ShapeDtypeStruct((1, M), Sr.dtype),
            jax.ShapeDtypeStruct((1, M), jnp.float32),
            jax.ShapeDtypeStruct((1, M // mt), jnp.float32),
            jax.ShapeDtypeStruct((1, M // mt), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, mt), jnp.float32),
            pltpu.VMEM((1, mt), jnp.float32),
        ],
        interpret=interpret,
    )(qr, qi, Sr, Si, acc, norms_sq)
    return cr, ci, acc_out, bmax, bidx
