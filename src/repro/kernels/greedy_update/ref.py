"""Pure-jnp oracle for the fused pivot-search update (paper Eq. 6.3)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy_update_ref(q: jax.Array, S: jax.Array, acc: jax.Array,
                      norms_sq: jax.Array):
    """Reference semantics of one pivot-search update.

    Args:
      q:        (N,) current basis vector (real or complex).
      S:        (N, M) local snapshot shard.
      acc:      (M,) accumulated sum_j |c_j|^2 (real).
      norms_sq: (M,) reference norms (real).

    Returns:
      c:        (M,) = q^H S (dtype of S).
      acc_out:  (M,) = acc + |c|^2.
      max_res:  ()  max_i (norms_sq - acc_out)_i.
      argmax:   ()  int32 argmax_i of the residual.
    """
    c = q.conj() @ S
    acc_out = acc + jnp.abs(c) ** 2
    res = norms_sq - acc_out
    return c, acc_out, jnp.max(res), jnp.argmax(res).astype(jnp.int32)
