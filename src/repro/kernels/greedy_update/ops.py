"""Public jit'd wrapper for the fused greedy pivot-search update.

Handles dtype dispatch (real vs complex planes), tile padding, and CPU
interpret fallback.  The padded columns get ``norms_sq = -1e30`` so they can
never win the argmax; padded rows are zeros so they are no-ops in the dot
products.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.greedy_update import kernel as _k
from repro.kernels.common import (  # noqa: F401  (re-exported)
    LANES,
    default_interpret,
    validate_tiles,
)
from repro.kernels.common import pad_to as _pad_to
from repro.kernels.common import round_up as _round_up


def greedy_update(
    q: jax.Array,
    S: jax.Array,
    acc: jax.Array,
    norms_sq: jax.Array,
    nt: int = 512,
    mt: int = 1024,
    interpret: bool | None = None,
):
    """Fused pivot-search update: c = q^H S, acc += |c|^2, residual argmax.

    Args:
      q:        (N,) basis vector (f32/f64/c64/c128).
      S:        (N, M) snapshot shard.
      acc:      (M,) accumulated |c|^2 (real).
      norms_sq: (M,) reference norms (real).
      nt, mt:   VMEM tile sizes (rows, cols).
      interpret: force Pallas interpret mode; default: interpret unless the
        backend is TPU.

    Returns (c, acc_out, max_res, argmax) matching
    :func:`repro.kernels.greedy_update.ref.greedy_update_ref`.
    """
    if interpret is None:
        interpret = default_interpret()
    validate_tiles("greedy_update", nt=nt, mt=mt)

    N, M = S.shape
    nt = min(nt, _round_up(N, LANES))
    mt = min(mt, _round_up(M, LANES))
    Np, Mp = _round_up(N, nt), _round_up(M, mt)

    acc_p = _pad_to(acc[None, :].astype(jnp.float32), Mp, 1)
    norms_p = _pad_to(
        norms_sq[None, :].astype(jnp.float32), Mp, 1, value=_k.NEG_LARGE
    )

    if jnp.iscomplexobj(S):
        plane = jnp.float32 if S.dtype == jnp.complex64 else jnp.float64
        qr = _pad_to(q.real[None, :].astype(plane), Np, 1)
        qi = _pad_to(q.imag[None, :].astype(plane), Np, 1)
        Sr = _pad_to(_pad_to(S.real.astype(plane), Np, 0), Mp, 1)
        Si = _pad_to(_pad_to(S.imag.astype(plane), Np, 0), Mp, 1)
        cr, ci, acc_out, bmax, bidx = _k.greedy_update_complex(
            qr, qi, Sr, Si, acc_p, norms_p, nt=nt, mt=mt, interpret=interpret
        )
        c = (cr[0, :M] + 1j * ci[0, :M]).astype(S.dtype)
    else:
        qp = _pad_to(q[None, :].astype(S.dtype), Np, 1)
        Sp = _pad_to(_pad_to(S, Np, 0), Mp, 1)
        c, acc_out, bmax, bidx = _k.greedy_update_real(
            qp, Sp, acc_p, norms_p, nt=nt, mt=mt, interpret=interpret
        )
        c = c[0, :M]

    # Final reduction over the per-block maxima (tiny: M/mt entries).
    blk = jnp.argmax(bmax[0])
    max_res = bmax[0, blk]
    argmax = bidx[0, blk]
    acc_out = acc_out[0, :M].astype(acc.dtype)
    return c, acc_out, max_res.astype(norms_sq.dtype), argmax
