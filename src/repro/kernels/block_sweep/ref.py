"""Pure-jnp oracle for the fused blocked Eq.-(6.3) panel sweep."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def block_sweep_ref(Qnew: jax.Array, S: jax.Array, acc: jax.Array):
    """Reference semantics of one blocked pivot-sweep update.

    The blocked form of the paper's Eq. (6.3) bookkeeping: after a block of
    p new basis vectors is revealed, every column's accumulated sum gains
    the squared projections onto ALL p of them in one pass over S.

    Args:
      Qnew: (N, p) the block's new basis vectors (rejected in-block
            candidates are zero columns — exact no-ops here).
      S:    (N, M) local snapshot shard.
      acc:  (M,) accumulated sum_j |c_j|^2 (real).

    Returns:
      C:       (p, M) = Qnew^H S (dtype of S) — the block's rows of R.
      acc_out: (M,) = acc + sum_i |C[i]|^2.
    """
    C = Qnew.conj().T @ S
    acc_out = acc + jnp.sum(jnp.abs(C) ** 2, axis=0).astype(acc.dtype)
    return C, acc_out
