"""Public jit'd wrapper for the fused blocked Eq.-(6.3) panel sweep.

Handles dtype dispatch (real vs complex planes), panel/tile padding, and
CPU interpret fallback.  The panel row count p is padded to a sublane
multiple with zero rows (no-ops in the GEMMs and in the acc column sums);
padded snapshot rows/columns are zero too, so C and acc are exact on the
un-padded region.

Precision note: the kernel accumulates C and acc in f32 (TPU MXU native),
so f64/c128 inputs are reduced at f32 accuracy on this path — for builds
whose tau sits below ~1e-7 use the ``xla``/``xla_ref`` backends, which
keep full working precision (same caveat as
:mod:`repro.kernels.imgs_project` / :mod:`repro.kernels.imgs_panel`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.block_sweep import kernel as _k
from repro.kernels.common import (
    LANES,
    default_interpret,
    validate_tiles,
)
from repro.kernels.common import pad_to as _pad_to
from repro.kernels.common import round_up as _round_up

_SUBLANES = 8  # f32 sublane count: the panel's row-padding quantum


def block_sweep(
    Qnew: jax.Array,
    S: jax.Array,
    acc: jax.Array,
    nt: int = 512,
    mt: int = 1024,
    interpret: bool | None = None,
):
    """Fused blocked sweep: C = Qnew^H S, acc += sum_i |C_i|^2.

    Args:
      Qnew: (N, p) block of new basis vectors (f32/f64/c64/c128); rejected
        in-block candidates are zero columns (exact no-ops).
      S:    (N, M) snapshot shard.
      acc:  (M,) accumulated |c|^2 (real).
      nt, mt: VMEM tile sizes (rows, cols).
      interpret: force Pallas interpret mode; default: interpret unless the
        backend is TPU.

    Returns (C, acc_out) matching
    :func:`repro.kernels.block_sweep.ref.block_sweep_ref`.
    """
    if interpret is None:
        interpret = default_interpret()
    validate_tiles("block_sweep", nt=nt, mt=mt)

    N, M = S.shape
    p = Qnew.shape[1]
    pp = _round_up(max(p, 1), _SUBLANES)
    nt = min(nt, _round_up(N, LANES))
    mt = min(mt, _round_up(M, LANES))
    Np, Mp = _round_up(N, nt), _round_up(M, mt)

    acc_p = _pad_to(acc[None, :].astype(jnp.float32), Mp, 1)

    if jnp.iscomplexobj(S):
        plane = jnp.float32 if S.dtype == jnp.complex64 else jnp.float64
        qhr = _pad_to(_pad_to(Qnew.real.T.astype(plane), pp, 0), Np, 1)
        qhi = _pad_to(_pad_to(Qnew.imag.T.astype(plane), pp, 0), Np, 1)
        Sr = _pad_to(_pad_to(S.real.astype(plane), Np, 0), Mp, 1)
        Si = _pad_to(_pad_to(S.imag.astype(plane), Np, 0), Mp, 1)
        cr, ci, acc_out = _k.block_sweep_complex(
            qhr, qhi, Sr, Si, acc_p, nt=nt, mt=mt, interpret=interpret
        )
        C = (cr[:p, :M] + 1j * ci[:p, :M]).astype(S.dtype)
    else:
        qh = _pad_to(_pad_to(Qnew.T.astype(S.dtype), pp, 0), Np, 1)
        Sp = _pad_to(_pad_to(S, Np, 0), Mp, 1)
        c, acc_out = _k.block_sweep_real(
            qh, Sp, acc_p, nt=nt, mt=mt, interpret=interpret
        )
        C = c[:p, :M]

    return C, acc_out[0, :M].astype(acc.dtype)


def batched_block_sweep(
    Qnew: jax.Array,
    S: jax.Array,
    acc: jax.Array,
    nt: int = 512,
    mt: int = 1024,
    interpret: bool | None = None,
):
    """B-lane blocked sweep: per lane ``C_b = Qnew_b^H S_b``,
    ``acc_b += sum_i |C_b,i|^2``.

    Args:
      Qnew: (B, N, p) one panel of new basis vectors per lane.
      S:    (B, N, M) stacked per-lane snapshots, or (N, M) shared.
      acc:  (B, M) per-lane accumulated sums (real).

    Returns ``(C, acc_out)`` with shapes ((B, p, M), (B, M)).

    Shared layout: the B panels stack along the panel axis into ONE
    (N, B*p) kernel call — a single fused HBM pass over S serves every
    lane (the batched amortization the lockstep driver exists for).  The
    kernel's fused per-column sum spans ALL B*p rows, so per-lane acc is
    recomputed from the returned C (each lane only sums its own p rows);
    the kernel is fed a zero acc and its cross-lane sum is discarded.

    Stacked layout: per-lane fused kernel calls (each lane reads its own
    S_b exactly once — there is no cross-lane traffic to amortize).
    """
    B, N, p = Qnew.shape
    if S.ndim == 2:
        panel = jnp.swapaxes(Qnew, 1, 2).reshape(B * p, N).T  # (N, B*p)
        C_flat, _ = block_sweep(
            panel, S, jnp.zeros_like(acc[0]), nt=nt, mt=mt,
            interpret=interpret,
        )
        C = C_flat.reshape(B, p, -1)
        acc_out = acc + jnp.sum(jnp.abs(C) ** 2, axis=1).astype(acc.dtype)
        return C, acc_out
    outs = [block_sweep(Qnew[b], S[b], acc[b], nt=nt, mt=mt,
                        interpret=interpret) for b in range(B)]
    return (jnp.stack([o[0] for o in outs]),
            jnp.stack([o[1] for o in outs]))
