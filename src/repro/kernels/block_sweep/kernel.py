"""Pallas TPU kernel for the blocked Eq.-(6.3) panel sweep (BLAS-3 greedy).

The stepwise greedy hot loop reads the whole snapshot shard S once per basis
vector at ~1 FLOP/byte — a pure DRAM-roof workload (see BENCH_greedy.json's
f32 hot-path rows).  Block pivoting (classical blocked column-pivoted QR:
[35] Quintana-Orti, [18] Demmel et al. CA-RRQR) selects p pivots per sweep,
so ONE read of S serves p bases.  This kernel is the fused device form of
that sweep:

  unfused: read S (panel GEMM) -> write C -> read C + acc (norm update)
  fused:   read S once; C and acc produced from VMEM in the same pass.

Layout mirrors :mod:`repro.kernels.greedy_update.kernel`: S is blocked
(Nt x Mt) with columns M as the outer (parallel) grid axis and rows N as
the inner (reduction) axis; the panel lives as its conjugate transpose
Qh = Qnew^H (p x N, real planes) so each grid step is one MXU
(p, Nt) x (Nt, Mt) GEMM accumulated into a (p, Mt) VMEM scratch.  The row
count p is padded to a sublane multiple by ops.py; padded rows are zero, so
their C rows are zero and contribute nothing to acc.

Complex snapshots (the GW production case) run as split re/im planes
(TPU MXUs are real): C = Qnew^H S becomes four real GEMMs in the same pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel_real(qh_ref, s_ref, acc_ref, c_ref, acc_out_ref, c_scr):
    n_i = pl.program_id(1)
    n_blocks = pl.num_programs(1)

    @pl.when(n_i == 0)
    def _():
        c_scr[...] = jnp.zeros_like(c_scr)

    c_scr[...] += jnp.dot(
        qh_ref[...], s_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(n_i == n_blocks - 1)
    def _():
        c = c_scr[...]
        c_ref[...] = c.astype(c_ref.dtype)
        acc_out_ref[...] = acc_ref[...] + jnp.sum(c * c, axis=0,
                                                  keepdims=True)


def _kernel_complex(qhr_ref, qhi_ref, sr_ref, si_ref, acc_ref,
                    cr_ref, ci_ref, acc_out_ref, cr_scr, ci_scr):
    n_i = pl.program_id(1)
    n_blocks = pl.num_programs(1)

    @pl.when(n_i == 0)
    def _():
        cr_scr[...] = jnp.zeros_like(cr_scr)
        ci_scr[...] = jnp.zeros_like(ci_scr)

    qhr = qhr_ref[...]
    qhi = qhi_ref[...]
    sr = sr_ref[...]
    si = si_ref[...]
    # C = Qnew^H S = (Qr - i Qi)^T (Sr + i Si); qh* hold Q*^T
    cr_scr[...] += jnp.dot(qhr, sr, preferred_element_type=jnp.float32)
    cr_scr[...] += jnp.dot(qhi, si, preferred_element_type=jnp.float32)
    ci_scr[...] += jnp.dot(qhr, si, preferred_element_type=jnp.float32)
    ci_scr[...] -= jnp.dot(qhi, sr, preferred_element_type=jnp.float32)

    @pl.when(n_i == n_blocks - 1)
    def _():
        cr = cr_scr[...]
        ci = ci_scr[...]
        cr_ref[...] = cr.astype(cr_ref.dtype)
        ci_ref[...] = ci.astype(ci_ref.dtype)
        acc_out_ref[...] = acc_ref[...] + jnp.sum(cr * cr + ci * ci,
                                                  axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("nt", "mt", "interpret"))
def block_sweep_real(qh, S, acc, nt: int = 512, mt: int = 1024,
                     interpret: bool = True):
    """Real-dtype fused panel sweep on padded inputs (see ops.py).

    qh: (p, N) = Qnew^T; S: (N, M); acc: (1, M) f32.
    p % 8 == 0, N % nt == 0 and M % mt == 0 must hold.
    """
    p, _ = qh.shape
    N, M = S.shape
    grid = (M // mt, N // nt)
    c, acc_out = pl.pallas_call(
        _kernel_real,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, nt), lambda m, n: (0, n)),
            pl.BlockSpec((nt, mt), lambda m, n: (n, m)),
            pl.BlockSpec((1, mt), lambda m, n: (0, m)),
        ],
        out_specs=[
            pl.BlockSpec((p, mt), lambda m, n: (0, m)),
            pl.BlockSpec((1, mt), lambda m, n: (0, m)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, M), S.dtype),
            jax.ShapeDtypeStruct((1, M), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, mt), jnp.float32)],
        interpret=interpret,
    )(qh, S, acc)
    return c, acc_out


@functools.partial(jax.jit, static_argnames=("nt", "mt", "interpret"))
def block_sweep_complex(qhr, qhi, Sr, Si, acc, nt: int = 512,
                        mt: int = 1024, interpret: bool = True):
    """Complex fused panel sweep on split re/im planes (padded; see ops.py)."""
    p, _ = qhr.shape
    N, M = Sr.shape
    grid = (M // mt, N // nt)
    cr, ci, acc_out = pl.pallas_call(
        _kernel_complex,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, nt), lambda m, n: (0, n)),
            pl.BlockSpec((p, nt), lambda m, n: (0, n)),
            pl.BlockSpec((nt, mt), lambda m, n: (n, m)),
            pl.BlockSpec((nt, mt), lambda m, n: (n, m)),
            pl.BlockSpec((1, mt), lambda m, n: (0, m)),
        ],
        out_specs=[
            pl.BlockSpec((p, mt), lambda m, n: (0, m)),
            pl.BlockSpec((p, mt), lambda m, n: (0, m)),
            pl.BlockSpec((1, mt), lambda m, n: (0, m)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, M), Sr.dtype),
            jax.ShapeDtypeStruct((p, M), Sr.dtype),
            jax.ShapeDtypeStruct((1, M), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((p, mt), jnp.float32),
            pltpu.VMEM((p, mt), jnp.float32),
        ],
        interpret=interpret,
    )(qhr, qhi, Sr, Si, acc)
    return cr, ci, acc_out
