"""Pallas TPU flash attention (causal / sliding-window, GQA).

Online-softmax attention with the canonical running (m, l, o) state held in
VMEM scratch.  Used by the LM architecture stack for train/prefill paths;
the sliding-window variant serves Mixtral's SWA and RecurrentGemma's local
attention.  TPU adaptation notes:

- grid (B, Hq, Sq/Bq, Skv/Bk); the kv axis is the innermost (sequential on
  TPU) so the scratch accumulators carry across kv steps of one q block.
- GQA is handled in the BlockSpec index maps (kv head = q head // group) —
  no repeated K/V materialization in HBM.
- Causal + window skipping is done with pl.when guards per block; the
  diagonal blocks apply an iota mask.  MXU matmuls are (Bq, D) x (D, Bk)
  and (Bq, Bk) x (Bk, D) with f32 accumulation.
- Default tiles Bq = Bk = 128 keep (q, k, v, o, p) blocks ≈ 0.5 MB VMEM at
  D = 128 in bf16 — far under budget, leaving headroom for double-buffered
  pipelining by the Mosaic compiler.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
               *, causal: bool, window: int | None, sm_scale: float,
               block_q: int, block_k: int, seq_off: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(ki == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Absolute token positions of this block pair (seq_off aligns shorter
    # query windows to the end of the kv sequence, e.g. decode).
    q_lo = qi * block_q + seq_off
    k_lo = ki * block_k

    # Block-level skip tests (static per (qi, ki) pair at trace time only if
    # grid indices were static; they are dynamic, so use pl.when).
    relevant = jnp.asarray(True)
    if causal:
        relevant &= k_lo <= q_lo + block_q - 1
    if window is not None:
        relevant &= k_lo + block_k - 1 > q_lo - window

    @pl.when(relevant)
    def _():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale
        k = k_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)

        rows = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        vv = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, vv, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_cur

    @pl.when(ki == n_kv - 1)
    def _():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "sm_scale", "block_q", "block_k", "interpret"
    ),
)
def flash_attention_kernel(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Padded-shape flash attention.  Sq % block_q == Skv % block_k == 0.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D).  Causal alignment matches
    :func:`repro.kernels.flash_attention.ref.attention_ref` (query block
    aligned to the end of the kv sequence).
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    seq_off = Skv - Sq

    grid = (B, Hq, Sq // block_q, Skv // block_k)
    kernel = functools.partial(
        _fa_kernel,
        causal=causal,
        window=window,
        sm_scale=sm_scale,
        block_q=block_q,
        block_k=block_k,
        seq_off=seq_off,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_k, D), lambda b, h, qi, ki: (b, h // g, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, D), lambda b, h, qi, ki: (b, h // g, ki, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
