"""Pure-jnp oracle for causal/sliding-window GQA attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
) -> jax.Array:
    """Reference attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0 (GQA).
    causal masks j > i (aligned at the sequence end: query i attends to
    keys j <= i + (Skv - Sq)); window additionally masks j < i+off - window + 1.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)

    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) * sm_scale

    i = jnp.arange(Sq)[:, None] + (Skv - Sq)
    j = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= j <= i
    if window is not None:
        mask &= j > i - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
