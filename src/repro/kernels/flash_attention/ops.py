"""Public wrapper: padding, backend dispatch, CPU fallback.

On TPU this calls the Pallas kernel; elsewhere (or under ``force_ref``) it
uses the memory-bounded pure-JAX online-softmax fallback from
``repro.models.attention`` semantics via the ref oracle.  The wrapper pads
sequence lengths to tile multiples with fully-masked key padding.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as _k
from repro.kernels.flash_attention.ref import attention_ref


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
    use_kernel: bool | None = None,
) -> jax.Array:
    """Flash attention with GQA + causal/sliding-window masking.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D).  Returns (B, Hq, Sq, D).
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
        # In tests the kernel runs with interpret=True explicitly.
    if not use_kernel:
        return attention_ref(q, k, v, causal=causal, window=window,
                             sm_scale=sm_scale)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    B, Hq, Sq, D = q.shape
    Skv = k.shape[2]
    bq = min(block_q, _round_up(Sq, 128))
    bk = min(block_k, _round_up(Skv, 128))
    Sqp, Skvp = _round_up(Sq, bq), _round_up(Skv, bk)

    # Pad keys at the FRONT so causal end-alignment is preserved, queries at
    # the front likewise; padded key rows are masked by causality relative
    # to padded query rows... simpler and robust: pad at the end and mask by
    # clamping — padded queries produce garbage rows that we slice off, and
    # padded keys are masked via an additional window/causal-safe key count.
    if Sqp != Sq or Skvp != Skv:
        qp = jnp.pad(q, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, Skvp - Skv), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, Skvp - Skv), (0, 0)))
        if not causal or (Skvp - Sqp) != (Skv - Sq):
            # Padded keys are hidden only when causal end-alignment is
            # preserved (equal padding on both axes); otherwise fall back
            # to the ref path for ragged shapes.
            return attention_ref(q, k, v, causal=causal, window=window,
                                 sm_scale=sm_scale)
        out = _k.flash_attention_kernel(
            qp, kp, vp, causal=causal, window=window, sm_scale=sm_scale,
            block_q=bq, block_k=bk, interpret=interpret,
        )
        return out[:, :, :Sq]
    return _k.flash_attention_kernel(
        q, k, v, causal=causal, window=window, sm_scale=sm_scale,
        block_q=bq, block_k=bk, interpret=interpret,
    )
