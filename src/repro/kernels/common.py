"""Helpers shared by the Pallas kernel wrappers (ops.py modules).

Single home for tile/padding/backend-detection logic so a change to
padding semantics or lane constraints applies to every kernel at once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

LANES = 128


@functools.lru_cache(maxsize=None)
def default_interpret() -> bool:
    """Interpret-mode default, resolved ONCE per process (not per trace).

    ``jax.default_backend()`` initializes backends and walks the device
    list; calling it inside every trace of a jitted hot loop is wasted work
    and can deadlock under some plugin backends.  The platform cannot change
    after JAX is initialized, so a process-wide cache is exact.
    """
    return jax.default_backend() != "tpu"


def validate_tiles(name: str, **tiles: int) -> None:
    """Reject tile sizes the TPU lanes cannot shape, with a clear error."""
    for tile_name, tile in tiles.items():
        if tile <= 0 or tile % LANES != 0:
            raise ValueError(
                f"{name}: tile {tile_name}={tile} must be a positive "
                f"multiple of {LANES} (TPU lane count); got a remainder of "
                f"{tile % LANES if tile > 0 else tile}"
            )


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pad_to(x, size, axis, value=0.0):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)
