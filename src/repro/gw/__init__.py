"""Gravitational-wave snapshot substrate.

The paper fills its snapshot matrix by calls to the IMRPhenomPv2 waveform
model from LALSuite (Sec. 6.1.1).  LALSuite is C code with external data;
here the same role is played by a closed-form, frequency-domain post-
Newtonian inspiral model (TaylorF2, 3.5PN phasing) implemented in pure JAX —
the standard model family of the GW ROQ literature (e.g. Canizares et al.,
PRL 114, 071104, which the paper cites as its application).  The snapshot
generator contract is identical: ``nu -> M(x; nu)`` producing one complex
column per parameter value, no file I/O.
"""

from repro.gw.waveform import taylorf2, taylorf2_batch
from repro.gw.grids import chirp_grid, mass_grid, frequency_grid
from repro.gw.snapshots import build_snapshot_matrix

__all__ = [
    "taylorf2",
    "taylorf2_batch",
    "chirp_grid",
    "mass_grid",
    "frequency_grid",
    "build_snapshot_matrix",
]
