"""Snapshot-matrix construction (the greedycpp model interface).

greedycpp's strategy (Sec. 6.1.1): "The parameter values that define S are
distributed among the different MPI processes, and each process is
responsible for forming a 'slice' of S over a subset of columns."  The JAX
analogue: parameters are sharded on the column mesh axis and each device
vmaps the model over its local parameter slice — no host round-trip, no file
I/O.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.gw.waveform import taylorf2


def build_snapshot_matrix(
    f: np.ndarray,
    m1s: np.ndarray,
    m2s: np.ndarray,
    dtype=jnp.complex64,
    sharding: jax.sharding.NamedSharding | None = None,
    chunk: int = 4096,
) -> jax.Array:
    """Build S (N, M) column-chunked; optionally placed with ``sharding``.

    ``sharding`` should shard the column (second) axis; each chunk is
    generated jit-compiled and placed directly, so the full matrix never
    exists unsharded (the paper's "may be too large to load into memory"
    setting).
    """
    f = jnp.asarray(f)
    gen = jax.jit(jax.vmap(lambda a, b: taylorf2(f, a, b, dtype=dtype)))
    M = len(m1s)
    outs = []
    # generate on host CPU (jit's backend= kwarg is deprecated; the
    # default_device context is the supported spelling), then place
    with jax.default_device(jax.local_devices(backend="cpu")[0]):
        for lo in range(0, M, chunk):
            hi = min(lo + chunk, M)
            block = gen(jnp.asarray(m1s[lo:hi]), jnp.asarray(m2s[lo:hi])).T
            outs.append(block)
        S = jnp.concatenate(outs, axis=1)
    if sharding is not None:
        S = jax.device_put(S, sharding)
    return S
