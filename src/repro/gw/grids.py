"""Parameter and frequency grids for snapshot generation."""

from __future__ import annotations

import numpy as np


def frequency_grid(f_min: float = 20.0, f_max: float = 512.0, n: int = 2000):
    """Uniform frequency grid in Hz (the rows / independent variable x)."""
    return np.linspace(f_min, f_max, n)


def mass_grid(
    m_min: float = 5.0, m_max: float = 30.0, n_per_dim: int = 40,
):
    """Uniform 2-D (m1, m2) grid with m1 >= m2 (dedup by symmetry)."""
    m = np.linspace(m_min, m_max, n_per_dim)
    m1, m2 = np.meshgrid(m, m, indexing="ij")
    keep = m1 >= m2
    return m1[keep].ravel(), m2[keep].ravel()


def chirp_grid(
    mc_min: float = 5.0, mc_max: float = 15.0,
    eta_min: float = 0.1, eta_max: float = 0.25,
    n_mc: int = 60, n_eta: int = 20,
):
    """Grid in (chirp mass, symmetric mass ratio), mapped to (m1, m2)."""
    mc, eta = np.meshgrid(
        np.linspace(mc_min, mc_max, n_mc),
        np.linspace(eta_min, eta_max, n_eta),
        indexing="ij",
    )
    mc = mc.ravel()
    eta = np.minimum(eta.ravel(), 0.25 - 1e-9)
    M = mc / eta**0.6
    disc = np.sqrt(np.maximum(1.0 - 4.0 * eta, 0.0))
    m1 = 0.5 * M * (1.0 + disc)
    m2 = 0.5 * M * (1.0 - disc)
    return m1, m2


def random_mass_samples(n: int, m_min=5.0, m_max=30.0, seed: int = 0):
    """Random (m1 >= m2) samples — used for out-of-sample validation."""
    rng = np.random.default_rng(seed)
    a = rng.uniform(m_min, m_max, size=n)
    b = rng.uniform(m_min, m_max, size=n)
    return np.maximum(a, b), np.minimum(a, b)
