"""Frequency-domain inspiral waveform (TaylorF2, 3.5PN phasing) in JAX.

h(f; m1, m2) = A(f) exp(i Psi(f)),  A ~ Mc^(5/6) f^(-7/6),
with the stationary-phase-approximation phasing

  Psi(f) = 2 pi f t_c - phi_c - pi/4 + 3/(128 eta v^5) * sum_k alpha_k v^k,
  v = (pi M f)^(1/3)   (geometric units, G = c = 1).

The snapshots vary smoothly with (m1, m2), so the Kolmogorov n-width of the
waveform family decays exponentially — exactly the regime the paper's
greedy/QR reduction targets (Sec. 1: "for smooth models the n-width (and
thus the greedy error) is expected to decay exponentially fast").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Solar mass in seconds (G Msun / c^3) — geometric units conversion.
MSUN_S = 4.925491025543576e-06
EULER_GAMMA = 0.5772156649015329


def _pn_phasing(v: jax.Array, eta: jax.Array) -> jax.Array:
    """3.5PN TaylorF2 phasing series sum_k alpha_k(eta) v^k (k = 0..7)."""
    v2 = v * v
    v3 = v2 * v
    v4 = v2 * v2
    v5 = v4 * v
    v6 = v3 * v3
    v7 = v6 * v
    logv = jnp.log(v)

    a0 = 1.0
    a2 = 3715.0 / 756.0 + 55.0 * eta / 9.0
    a3 = -16.0 * jnp.pi
    a4 = 15293365.0 / 508032.0 + 27145.0 * eta / 504.0 + 3085.0 * eta**2 / 72.0
    a5 = jnp.pi * (38645.0 / 756.0 - 65.0 * eta / 9.0) * (1.0 + 3.0 * logv)
    a6 = (
        11583231236531.0 / 4694215680.0
        - 6848.0 * EULER_GAMMA / 21.0
        - 640.0 * jnp.pi**2 / 3.0
        + (-15737765635.0 / 3048192.0 + 2255.0 * jnp.pi**2 / 12.0) * eta
        + 76055.0 * eta**2 / 1728.0
        - 127825.0 * eta**3 / 1296.0
        - 6848.0 / 63.0 * jnp.log(64.0 * v6)
    )
    a7 = jnp.pi * (
        77096675.0 / 254016.0
        + 378515.0 * eta / 1512.0
        - 74045.0 * eta**2 / 756.0
    )
    return a0 + a2 * v2 + a3 * v3 + a4 * v4 + a5 * v5 + a6 * v6 + a7 * v7


def taylorf2(
    f: jax.Array,
    m1: jax.Array,
    m2: jax.Array,
    normalize: bool = True,
    dtype=jnp.complex64,
) -> jax.Array:
    """One waveform column h(f) for component masses (m1, m2) in Msun.

    Frequencies ``f`` in Hz.  Returns a complex (len(f),) vector; with
    ``normalize=True`` the column has unit l2 norm (the ROQ convention).
    """
    M = (m1 + m2) * MSUN_S
    eta = (m1 * m2) / (m1 + m2) ** 2
    v = (jnp.pi * M * f) ** (1.0 / 3.0)
    v5 = v**5

    psi = (
        -jnp.pi / 4.0
        + 3.0 / (128.0 * eta * v5) * _pn_phasing(v, eta)
    )
    amp = f ** (-7.0 / 6.0)
    h = (amp * jnp.exp(1j * psi)).astype(dtype)
    if normalize:
        h = h / jnp.linalg.norm(h).astype(dtype)
    return h


def taylorf2_batch(
    f: jax.Array, m1s: jax.Array, m2s: jax.Array, normalize: bool = True,
    dtype=jnp.complex64,
) -> jax.Array:
    """Snapshot matrix S (N=len(f), M=len(m1s)): one column per parameter."""
    cols = jax.vmap(
        lambda a, b: taylorf2(f, a, b, normalize=normalize, dtype=dtype)
    )(m1s, m2s)
    return cols.T  # (N, M)
