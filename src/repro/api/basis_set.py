"""`ReducedBasisSet`: one artifact holding B per-basis children.

The batched strategy builds B bases in one lockstep pass
(:mod:`repro.core.batch_greedy`) — per parameter region, per frequency
band (:func:`repro.data.bands.band_split`), or per tau in a sweep.  They
ship as ONE artifact directory::

    <dir>/basis_0/ ... basis_<B-1>/   one ReducedBasis artifact each
    <dir>/set.json                    the set manifest (commit marker)

Each child is a complete, independently loadable
:class:`~repro.api.artifact.ReducedBasis` (same step/manifest/CRC layout,
same ``eim()`` / ``roq_weights()``), so the serving
:class:`~repro.serving.router.BasisRouter` can register the child
directories directly — :meth:`ReducedBasisSet.register` does exactly
that.  ``set.json`` is written atomically AFTER every child, so a reader
that finds it is guaranteed B intact children (the same
commit-marker-last discipline as the artifact steps themselves).
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Iterator, Optional

from repro.api.artifact import ReducedBasis

SET_VERSION = 1

_SET_MANIFEST = "set.json"


def _child_name(i: int) -> str:
    return f"basis_{i}"


@dataclasses.dataclass(frozen=True)
class ReducedBasisSet:
    """B reduced bases built (and shipped) together.

    Attributes:
      children: one :class:`~repro.api.artifact.ReducedBasis` per lane,
        in build order (band order for banded workloads, source order for
        stacked/list workloads, tau order for shared-S sweeps).
      provenance: the batched build's provenance dict (shared across
        children; each child additionally carries its own copy with its
        lane index / stop code under ``"lane"``).
    """

    children: tuple
    provenance: Optional[dict] = None

    def __post_init__(self):
        object.__setattr__(self, "children", tuple(self.children))
        if not self.children:
            raise ValueError("ReducedBasisSet needs at least one basis")

    @property
    def batch(self) -> int:
        return len(self.children)

    def __len__(self) -> int:
        return len(self.children)

    def __getitem__(self, i: int) -> ReducedBasis:
        return self.children[i]

    def __iter__(self) -> Iterator[ReducedBasis]:
        return iter(self.children)

    # ------------------------------------------------------- persistence --

    def save(self, directory: str) -> str:
        """Persist every child under ``directory`` plus the set manifest.

        Children save first (each is its own atomic artifact step), the
        manifest last via write-to-temp + rename — the commit marker.  A
        crash mid-save leaves child directories but no ``set.json``, so
        :meth:`load` never observes a partial set; re-running the save
        completes it (child saves append fresh steps, never corrupt).
        """
        os.makedirs(directory, exist_ok=True)
        for i, child in enumerate(self.children):
            child.save(os.path.join(directory, _child_name(i)))
        manifest = {
            "set_version": SET_VERSION,
            "batch": self.batch,
            "children": [_child_name(i) for i in range(self.batch)],
            "provenance": self.provenance,
        }
        final = os.path.join(directory, _SET_MANIFEST)
        tmp = final + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)
        return directory

    @classmethod
    def load(cls, directory: str) -> "ReducedBasisSet":
        """Load a set saved by :meth:`save` (children bit-identical).

        Requires the ``set.json`` commit marker; each child loads through
        :meth:`ReducedBasis.load` (newest intact step, CRC-checked) and
        keeps its backing ``directory`` so the router can re-load it
        lazily after eviction.
        """
        path = os.path.join(directory, _SET_MANIFEST)
        try:
            with open(path) as f:
                manifest = json.load(f)
        except FileNotFoundError:
            raise FileNotFoundError(
                f"no basis-set manifest at {path} (incomplete save, or "
                f"not a ReducedBasisSet directory)")
        if manifest.get("set_version") != SET_VERSION:
            raise IOError(
                f"unsupported set_version {manifest.get('set_version')!r} "
                f"in {path}")
        children = tuple(
            ReducedBasis.load(os.path.join(directory, name))
            for name in manifest["children"])
        return cls(children=children, provenance=manifest.get("provenance"))

    # ------------------------------------------------------ serving handoff --

    def register(self, router, prefix: str = "basis",
                 names=None) -> list:
        """Register every child with a serving router; returns the ids.

        ``names`` overrides the default ``"{prefix}_{i}"`` ids (must have
        one entry per child).  Children backed by a directory (i.e. the
        set was saved or loaded) register by directory — evictable under
        the router's device-memory budget; unsaved in-memory children are
        pinned, exactly the :meth:`repro.serving.router.BasisRouter.
        register` contract.
        """
        if names is None:
            names = [f"{prefix}_{i}" for i in range(self.batch)]
        if len(names) != self.batch:
            raise ValueError(
                f"{len(names)} names for {self.batch} children")
        for name, child in zip(names, self.children):
            router.register(name, child)
        return list(names)
