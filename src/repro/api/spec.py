"""`ReductionSpec`: one declarative description of a basis build.

The paper presents POD (Algorithm 1), pivoted MGS (Algorithm 2) and
RB-greedy (Algorithm 3) as *interchangeable* reducers with the same error
estimate (Prop. 5.3 / Thm. 5.1), and its software section sells a single
workflow: build a basis from snapshots, then reuse it.  A
:class:`ReductionSpec` captures everything that workflow needs — what the
snapshots are, which reducer to run, to what tolerance, and how to execute
it — so :func:`repro.api.build_basis` is the only call site a consumer
ever touches.

The spec is a frozen dataclass: reuse one across builds with
``dataclasses.replace(spec, tau=...)`` (or pass overrides straight to
``build_basis(spec, tau=...)``).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Optional

# Reduction strategies build_basis dispatches on.  "auto" resolves to
# "batched" (a many-basis workload: spec.batch set, or a stacked/list
# source), "distributed" (a mesh was given), "greedy" / "block_greedy"
# (the problem fits the device memory budget; blocked when the Eq.-(6.3)
# sweep is DRAM-roof-bound), "streamed" (it does not fit; blocked under
# the same roofline test), or "randomized" (a max_k is given and the
# roofline model predicts the greedy pass count costs more than twice
# the sketch's 1 + 2*sketch_power passes) — see repro.api.build.
STRATEGIES = (
    "pod", "mgs", "greedy", "block_greedy", "streamed", "distributed",
    "randomized", "sketch+greedy", "batched", "auto",
)


@dataclasses.dataclass(frozen=True)
class ReductionSpec:
    """Everything :func:`repro.api.build_basis` needs to build a basis.

    Attributes:
      source: the snapshot matrix — anything
        :func:`repro.data.providers.as_provider` accepts: a resident
        (jax/numpy) array, a path to a ``.npy`` file (memory-mapped), or a
        :class:`~repro.data.providers.SnapshotProvider` (e.g. a
        :class:`~repro.data.providers.WaveformProvider` generating GW
        snapshot tiles on the fly; see :meth:`waveform`).
      strategy: one of ``STRATEGIES``.  ``"auto"`` picks from the problem
        shape and the device-memory budget and logs its choice.
      tau: greedy/POD stopping tolerance (the paper's ``tau``).
      max_k: basis-size cap (default ``min(N, M)``).

    Execution options (each consumed only by the strategies it applies to):
      backend: hot-loop primitive backend (``repro.core.backend``):
        ``"auto" | "xla" | "pallas" | "xla_ref"`` or None (env/default).
      chunk: greedy iterations per device-resident chunk
        (``greedy`` / ``distributed``).
      tile_m: streamed tile width in columns (``streamed``).
      mesh: a ``jax.sharding.Mesh`` — required by ``distributed``, and
        flips ``"auto"`` to it.
      block_p: pivots per sweep, flowing to every blocked execution path
        (``block_greedy``; ``streamed`` and ``distributed`` run blocked
        when > 1).  ``1`` = stepwise (exact paper semantics); > 1 amortizes
        each read/transfer of S over block_p bases at the cost of pivot
        staleness (a few extra bases on fast-decaying families).
        ``"auto"`` may raise it on roof-bound shapes (logged).
      panel_ortho: orthogonalize each block of pivots through the BLAS-3
        panel path (:func:`repro.core.greedy.panel_imgs_orthogonalize`:
        one iterated (k, N) x (N, p) panel projection + within-panel
        sweep) instead of p sequential GEMV chains.  Consulted by every
        blocked execution path at ``block_p > 1``; both settings span the
        same space (float summation order differs).
      adaptive_block: treat ``block_p`` as a CEILING and let the resident
        blocked driver retune the live panel width between chunks from
        the in-block rank guard's rejection rate (the stale-pivot
        signal): halve on a >25%-rejected chunk, double back on a clean
        one.  The width trajectory lands in the artifact provenance
        (``p_trajectory``).  Consumed by ``block_greedy`` only.
      kappa, max_passes: Hoffmann iterated-GS controls (greedy family).
      refresh, refresh_safety: Eq.-(6.3) exact-refresh policy
        (greedy family; ``"never"`` is the paper-faithful mode).
      keep_R: accumulate the (k, M) R factor (``streamed``; the one result
        piece that scales with M).
      workdir: directory owning the build's full lifecycle (any greedy
        strategy).  Mid-build checkpoints go to ``<workdir>/build/`` and
        on completion the finished basis is finalized atomically into
        ``<workdir>`` itself (a ``final``-tagged artifact step) and the
        build scratch is removed — a crash at ANY point (including
        mid-finalize) plus a relaunch with ``resume=True`` lands on the
        identical artifact, and :meth:`repro.api.ReducedBasis.load` never
        observes a partial one.  Mutually exclusive with
        ``checkpoint_dir`` (which is the raw driver-level knob).
      checkpoint_dir / checkpoint_every_tiles / resume: mid-build
        checkpointing (greedy strategies; ``checkpoint_every_tiles`` is
        ``streamed``-only).  ``resume`` also governs :attr:`workdir`
        (resume the build, or return the finished artifact if one is
        already finalized there).
      callback: per-progress callback, forwarded verbatim to the driver
        (chunk-cadence for ``greedy``/``distributed``, per-basis dict for
        ``streamed``).
      memory_budget_bytes: device-memory budget ``"auto"`` decides
        against (default: detected device memory, overridable with the
        ``REPRO_DEVICE_MEM_BUDGET`` env var).
      bandwidth_gbps, peak_gflops, cache_bytes: the DRAM-roofline machine
        model ``"auto"`` uses to detect roof-bound Eq.-(6.3) sweeps (and
        pick a blocked strategy).  ``None`` falls back to the
        ``REPRO_DRAM_BW_GBPS`` / ``REPRO_PEAK_GFLOPS`` /
        ``REPRO_LLC_BYTES`` env vars, then (for bandwidth/FLOPs) to a
        one-time ~100 ms on-device measurement
        (:mod:`repro.api.roofline`; ``REPRO_ROOFLINE_MEASURE=0`` opts
        out), then to conservative per-platform defaults (see
        :func:`repro.api.build.machine_roofline`).
      sketch_p, sketch_power, sketch_seed, sketch_kind: randomized
        range-finder knobs (``randomized`` / ``sketch+greedy``):
        oversampling columns beyond ``max_k`` (the bound's p),
        subspace-iteration rounds (2 extra passes over S each),
        the test-matrix seed, and its distribution (``"gaussian"`` or
        ``"rademacher"``) — blocks are derived per tile from
        ``fold_in(PRNGKey(sketch_seed), tile_index)``, so builds are
        bit-reproducible and resumable.
      batch: lane count B for the many-basis lockstep build
        (``"batched"``; setting it also flips ``"auto"`` to it).  For a
        stacked workload — a (B, N, M) array, a list of per-lane sources,
        or a :class:`~repro.data.bands.BandSplit` — B is implied and
        ``batch`` may stay None (it is validated when given); a shared
        2-D source REQUIRES it (or a length-B ``tau`` sequence), because
        B is the number of independent basis states sweeping the one
        matrix.  ``tau`` may be a length-B sequence for per-lane
        tolerances.  The build returns a
        :class:`~repro.api.basis_set.ReducedBasisSet` of B children —
        every other strategy returns a single
        :class:`~repro.api.ReducedBasis`.
    """

    source: Any = None
    strategy: str = "auto"
    tau: Any = 1e-6
    max_k: Optional[int] = None
    backend: Optional[str] = None
    chunk: int = 16
    tile_m: int = 8192
    mesh: Any = None
    block_p: int = 1
    panel_ortho: bool = True
    adaptive_block: bool = False
    kappa: float = 2.0
    max_passes: int = 3
    refresh: str = "auto"
    refresh_safety: float = 100.0
    keep_R: bool = True
    workdir: Optional[str] = None
    checkpoint_dir: Optional[str] = None
    checkpoint_every_tiles: int = 0
    resume: bool = False
    callback: Optional[Callable] = None
    memory_budget_bytes: Optional[int] = None
    bandwidth_gbps: Optional[float] = None
    peak_gflops: Optional[float] = None
    cache_bytes: Optional[int] = None
    sketch_p: int = 10
    sketch_power: int = 0
    sketch_seed: int = 0
    sketch_kind: str = "gaussian"
    batch: Optional[int] = None

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(
                f"unknown strategy {self.strategy!r}; valid: {STRATEGIES}"
            )
        if self.source is None:
            raise ValueError("ReductionSpec requires a source")
        if self.workdir is not None and self.checkpoint_dir is not None:
            raise ValueError(
                "workdir and checkpoint_dir are mutually exclusive: "
                "workdir manages its own build/ checkpoint directory"
            )
        if self.batch is not None:
            if self.batch < 1:
                raise ValueError(f"batch must be >= 1, got {self.batch}")
            if self.strategy not in ("batched", "auto"):
                raise ValueError(
                    f"batch= only applies to the batched strategy "
                    f"(got strategy={self.strategy!r})")
        if self.strategy == "batched" and self.checkpoint_dir is not None:
            raise ValueError(
                "the batched strategy does not support checkpoint_dir; "
                "use workdir= (the finished set finalizes atomically)")

    @classmethod
    def waveform(cls, f, m1s, m2s, dtype=None, normalize: bool = True,
                 **kwargs) -> "ReductionSpec":
        """Spec over a GW waveform grid: columns generated on the fly.

        Wraps ``(f, m1s, m2s)`` in a
        :class:`~repro.data.providers.WaveformProvider` — the snapshot
        matrix is never materialized, so this pairs naturally with
        ``strategy="streamed"`` (or ``"auto"``, which will pick it when
        the grid exceeds the memory budget).
        """
        import jax.numpy as jnp

        from repro.data.providers import WaveformProvider

        prov = WaveformProvider(
            f, m1s, m2s,
            dtype=jnp.complex64 if dtype is None else dtype,
            normalize=normalize,
        )
        return cls(source=prov, **kwargs)

    def describe(self) -> dict:
        """JSON-serializable provenance view of this spec (source/mesh/
        callback summarized, not embedded)."""
        # shallow per-field dict (dataclasses.asdict deep-copies, which
        # chokes on device arrays / mesh Device objects)
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self)}
        src = self.source
        shape = getattr(src, "shape", None)
        d["source"] = {
            "kind": type(src).__name__,
            "shape": list(shape) if shape is not None else None,
            "dtype": str(getattr(src, "dtype", None)),
            **({"path": os.fspath(src)}
               if isinstance(src, (str, os.PathLike)) else {}),
        }
        d["mesh"] = (
            None if self.mesh is None
            else {"axis_names": list(self.mesh.axis_names),
                  "shape": [int(s) for s in self.mesh.devices.shape]}
        )
        d["callback"] = None if self.callback is None else "<callback>"
        return d
