"""`ReducedBasis`: the one result artifact of every reduction strategy.

Wraps the trimmed basis Q (plus R / pivots / errs where the strategy
produces them) together with build provenance, and carries the paper's
downstream workflow as methods: projection / reconstruction / per-column
errors (Sec. 4), empirical-interpolation nodes and ROQ weights (the GW
application, Sec. 6.2), and durable ``save``/``load`` built on
:mod:`repro.checkpoint.io` (atomic step directory, CRC-verified leaves).
"""

from __future__ import annotations

import dataclasses
import functools
import json
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

_ARTIFACT_VERSION = 1
# The EIM leaves ride in the same artifact step behind their own version
# gate (additive: version-1 readers ignore unknown leaves, and loading an
# older artifact without them just recomputes on first eim() call).
_EIM_VERSION = 1


@dataclasses.dataclass(frozen=True)
class ReducedBasis:
    """A built reduced basis plus provenance.

    Attributes:
      Q:      (N, k) orthonormal basis, trimmed to the accepted rank
              (legacy drivers zero-pad to max_k; the artifact never does).
      pivots: (k,) int32 selected snapshot columns.  Empty for ``pod``
              (SVD has no pivots).
      errs:   (k,) per-basis greedy errors (error *before* adding basis j;
              Cor. 5.6) — for ``pod`` the singular values sigma_1..sigma_k,
              for ``mgs`` the pivoted diagonal R(j,j) (equal quantities by
              Cor. 5.6 / Prop. 5.3).
      k:      accepted rank (== Q.shape[1]).
      R:      (k, M) triangular rows ``R[j] = q_j^H S`` in ORIGINAL column
              order, or None (pod; streamed with ``keep_R=False``).
      provenance: how the basis was built — strategy, backend, dtype,
              snapshot shape, wall time, and the originating spec
              (:meth:`repro.api.spec.ReductionSpec.describe`).
    """

    Q: jax.Array
    pivots: np.ndarray
    errs: np.ndarray
    k: int
    R: Optional[np.ndarray] = None
    provenance: dict = dataclasses.field(default_factory=dict)

    # ---------------------------------------------------------- reuse ----
    @property
    def N(self) -> int:
        return int(self.Q.shape[0])

    def project(self, f: jax.Array) -> jax.Array:
        """Basis coefficients ``c = Q^H f`` for a vector or (N, m) batch."""
        return self.Q.conj().T @ jnp.asarray(f)

    def reconstruct(self, f: jax.Array) -> jax.Array:
        """Orthogonal projection ``Q Q^H f`` onto the reduced subspace."""
        return self.Q @ self.project(f)

    def per_column_errors(self, S) -> jax.Array:
        """``|s_i - Q Q^H s_i|_2`` per column of S (Thm 4.3)."""
        from repro.core.errors import per_column_errors
        from repro.data.providers import materialize_source

        return per_column_errors(materialize_source(S), self.Q)

    @functools.cached_property
    def _eim(self):
        from repro.core.eim import eim_nodes

        return eim_nodes(self.Q)

    def eim(self):
        """EIM/DEIM node selection for this basis (cached EIMResult)."""
        return self._eim

    def roq_weights(self, data: jax.Array, quad_w: jax.Array) -> jax.Array:
        """Reduced-order quadrature weights for ``<data, .>`` at the EIM
        nodes (the paper's GW likelihood application)."""
        from repro.core.eim import roq_weights

        return roq_weights(jnp.asarray(data), jnp.asarray(quad_w),
                           self._eim.B)

    # ------------------------------------------------------ persistence ----
    def save(self, directory: str) -> str:
        """Persist to ``directory`` (atomic; one step dir under it).

        Arrays round-trip bit-identically (``.npy`` leaves, CRC-checked by
        the manifest); provenance rides along as a JSON leaf.  Each save
        writes a NEW step directory numbered past any existing steps
        (:meth:`load` reads the newest), so saving into a reused directory
        never shadows the fresh artifact behind stale higher-numbered
        steps.  The step's manifest carries a ``final`` commit marker: it
        only exists once the atomic rename lands, so a crash mid-save
        leaves nothing :meth:`load` would ever observe.  Returns the
        written step directory.

        The EIM node set and interpolant matrix are persisted alongside Q
        (``eim_nodes`` / ``eim_B`` leaves, gated by ``eim_version``):
        serving startup then skips the O(N·k²) EIM build entirely —
        :meth:`load` pre-seeds the :meth:`eim` cache from the leaves.
        Loading an older artifact without them (or with a future
        ``eim_version``) falls back to recomputing on first use.
        """
        from repro.checkpoint.io import latest_step, save_checkpoint

        ei = self.eim()  # cached; computed here at most once per basis
        tree = {
            "artifact_version": np.asarray(_ARTIFACT_VERSION, np.int64),
            "Q": np.asarray(jax.device_get(self.Q)),
            "pivots": np.asarray(self.pivots),
            "errs": np.asarray(self.errs),
            "k": np.asarray(self.k, np.int64),
            "eim_version": np.asarray(_EIM_VERSION, np.int64),
            "eim_nodes": np.asarray(jax.device_get(ei.nodes)),
            "eim_B": np.asarray(jax.device_get(ei.B)),
            "provenance_json": np.asarray(
                json.dumps(self.provenance, default=str)
            ),
        }
        if self.R is not None:
            tree["R"] = np.asarray(self.R)
        last = latest_step(directory)
        out = save_checkpoint(tree, directory,
                              0 if last is None else last + 1,
                              meta={"final": True})
        object.__setattr__(self, "_directory", directory)
        return out

    @property
    def directory(self) -> Optional[str]:
        """Where this basis was last saved/loaded from (None if neither)."""
        return getattr(self, "_directory", None)

    @classmethod
    def load(cls, directory: str) -> "ReducedBasis":
        """Load a basis saved by :meth:`save` (bit-identical arrays).

        Scans step directories newest-first and returns the newest INTACT
        artifact step: corrupt steps (CRC mismatch, truncated manifest)
        and non-artifact steps (e.g. a raw driver checkpoint written into
        the same directory by mistake) are skipped with the next-newest
        tried, so one damaged save never strands the artifact.
        """
        from repro.checkpoint.io import list_steps, load_checkpoint_raw

        steps = list_steps(directory)
        if not steps:
            raise FileNotFoundError(f"no artifact steps in {directory}")
        errors = []
        for s in reversed(steps):
            try:
                tree = load_checkpoint_raw(directory, s)
                if "artifact_version" not in tree:
                    raise KeyError(
                        f"step {s} has no artifact_version leaf "
                        f"(not a ReducedBasis artifact)")
                break
            except (IOError, KeyError) as e:
                errors.append(str(e))
        else:
            raise IOError(
                f"no intact ReducedBasis artifact in {directory}; tried "
                f"steps {list(reversed(steps))}: " + "; ".join(errors))
        version = int(tree["artifact_version"])
        if version != _ARTIFACT_VERSION:
            raise ValueError(
                f"ReducedBasis artifact version {version} != supported "
                f"{_ARTIFACT_VERSION}"
            )
        basis = cls(
            Q=jnp.asarray(tree["Q"]),
            pivots=tree["pivots"],
            errs=tree["errs"],
            k=int(tree["k"]),
            R=tree.get("R"),
            provenance=json.loads(str(tree["provenance_json"])),
        )
        object.__setattr__(basis, "_directory", directory)
        if ("eim_nodes" in tree and "eim_B" in tree
                and int(tree.get("eim_version", -1)) == _EIM_VERSION):
            from repro.core.eim import EIMResult

            # pre-seed the eim() cache so serving startup skips the
            # O(N·k²) node selection (cached_property stores here)
            object.__setattr__(basis, "_eim", EIMResult(
                nodes=jnp.asarray(tree["eim_nodes"]),
                B=jnp.asarray(tree["eim_B"]),
            ))
        return basis

    # ------------------------------------------------------- enrichment ----
    def enrich(self, source, tau: Optional[float] = None,
               max_k: Optional[int] = None, tile_m: int = 8192,
               save: bool = True, **stream_kwargs) -> "ReducedBasis":
        """Extend this basis with new snapshots; returns the grown basis.

        Streams the columns of ``source`` (anything
        :func:`repro.data.providers.as_provider` accepts) through the
        greedy driver warm-started from this basis's Q: existing bases are
        kept verbatim (bit-identical leading columns), and new bases are
        appended only where ``source`` has residual above ``tau``
        (default: the original build's tau, else 1e-6).  Pivot indices
        ``< self.k`` refer to the ORIGINAL build's source; new pivots
        index ``source``.

        When this basis is directory-backed (:attr:`directory` set by
        :meth:`save`/:meth:`load`) and ``save=True``, the enriched basis
        is saved there as a NEW artifact step — the old artifact remains
        on disk one step back, and the save is atomic like any other.
        """
        from repro.core.greedy import STOP_NAMES
        from repro.core.streaming import rb_greedy_streamed

        if tau is None:
            tau = float(self.provenance.get("tau", 1e-6))
        warm = {
            "Q": self.Q,
            "pivots": np.asarray(self.pivots),
            "errs": np.asarray(self.errs),
        }
        res = rb_greedy_streamed(
            source, tau=tau, max_k=max_k, tile_m=tile_m,
            warm_start=warm, **stream_kwargs,
        )
        k = int(res.k)
        provenance = {
            **self.provenance,
            "enriched_from_k": int(self.k),
            "enrich_tau": tau,
            "stop": STOP_NAMES.get(int(res.stop), str(int(res.stop))),
        }
        basis = ReducedBasis(
            Q=res.Q[:, :k],
            pivots=np.asarray(res.pivots[:k]),
            errs=np.asarray(res.errs[:k]),
            k=k,
            R=None if res.R is None else np.asarray(res.R[:k]),
            provenance=provenance,
        )
        directory = self.directory
        if save and directory is not None:
            basis.save(directory)
        return basis

    def __repr__(self) -> str:  # compact, log-friendly
        p = self.provenance
        return (
            f"ReducedBasis(k={self.k}, N={self.N}, "
            f"dtype={self.Q.dtype}, strategy={p.get('strategy')!r}, "
            f"backend={p.get('backend')!r})"
        )
