"""One-time on-device roofline measurement for the ``"auto"`` strategy.

The PR-4 DRAM-roofline model classified the Eq.-(6.3) pivot sweep with
per-platform DEFAULT bandwidth/FLOP roofs — fine for the ratio test on
typical hardware, but the block/stepwise cutover really wants the numbers
of THIS box.  :func:`measured_roofline` spends ~100 ms once per process to
get them:

  bandwidth   one fused f32 Eq.-(6.3) sweep over a snapshot matrix sized
              well past any last-level cache (one read of S per call), so
              ``bytes / seconds`` is the streaming DRAM rate the real
              sweep will see — the same access pattern, not a synthetic
              triad,
  peak FLOPs  one square f32 GEMM (the compute the blocked panel path is
              made of), ``2 n^3 / seconds``.

Both are timed best-of-N from a steady state (mirroring
``benchmarks/common.steady_min``: consecutive repeats, minimum taken —
single-shot wall clock swings ±40% on shared boxes) and cached for the
process lifetime.

Knob precedence stays exactly as documented on
:func:`repro.api.build.machine_roofline`: an explicit spec field or
``REPRO_DRAM_BW_GBPS`` / ``REPRO_PEAK_GFLOPS`` env var always wins;
measurement only fills knobs nobody pinned.  ``REPRO_ROOFLINE_MEASURE=0``
opts out entirely (falling back to the per-platform defaults) — CI's test
matrix sets it to keep auto-strategy decisions deterministic on noisy
runners.  The measured numbers are logged once on logger ``repro.api``.
"""

from __future__ import annotations

import functools
import logging
import os
import time

import jax
import jax.numpy as jnp

logger = logging.getLogger("repro.api")

_ENV_MEASURE = "REPRO_ROOFLINE_MEASURE"

# Sweep operand sized to defeat any plausible LLC (256 MB f32) while
# keeping the whole calibration ~100 ms at laptop-class bandwidth; the
# GEMM is large enough to reach steady MXU/FMA throughput but small next
# to the sweep.
_SWEEP_SHAPE = (2048, 16384)     # 128 MB f32 + re-read per call
_GEMM_N = 512                    # 2 * 512^3 = 268 MFLOP per call
_REPEATS = 5
_WARMUP = 2


def roofline_measurement_enabled() -> bool:
    """Whether ``"auto"`` may spend ~100 ms measuring the machine roofs.

    ``REPRO_ROOFLINE_MEASURE=0`` (or empty/false-y) disables; default on.
    """
    raw = os.environ.get(_ENV_MEASURE, "1").strip().lower()
    return raw not in ("0", "false", "no", "off", "")


def _steady_min(fn, repeats: int = _REPEATS, warmup: int = _WARMUP) -> float:
    """Best-of-``repeats`` seconds per call, timed consecutively from a
    steady state (the committed-bench method; see
    ``benchmarks/common.steady_min`` — not importable from the installed
    package, so the ~5-line method is restated here)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


@functools.lru_cache(maxsize=None)
def _measure_roofline_once() -> tuple[float, float]:
    """The raw calibration.  RAISES on failure — ``lru_cache`` does not
    memoize exceptions, so a failed attempt is retried on the next call
    while a successful measurement is cached for the process lifetime."""
    from repro.core.backend import pivot_update

    N, M = _SWEEP_SHAPE
    key = jax.random.PRNGKey(0)
    S = jax.random.normal(key, (N, M), jnp.float32)
    q = jax.random.normal(key, (N,), jnp.float32)
    q = q / jnp.linalg.norm(q)
    norms = jnp.sum(S * S, axis=0)
    acc = jnp.zeros((M,), jnp.float32)
    # operands are ARGUMENTS, not closure captures: a captured S is an
    # XLA constant and the whole sweep constant-folds at compile time
    # (timing a no-op at "1 TB/s")
    sweep_fn = jax.jit(
        lambda q_, S_, a_, n_: pivot_update(q_, S_, a_, n_,
                                            backend=None)
    )
    t_sweep = _steady_min(lambda: sweep_fn(q, S, acc, norms))
    # one read of S dominates the sweep's traffic (q, acc, norms are
    # O(N + M) next to N*M)
    bw_gbps = (N * M * 4) / t_sweep / 1e9

    A = jax.random.normal(key, (_GEMM_N, _GEMM_N), jnp.float32)
    B = jax.random.normal(key, (_GEMM_N, _GEMM_N), jnp.float32)
    gemm_fn = jax.jit(lambda a, b: a @ b)
    t_gemm = _steady_min(lambda: gemm_fn(A, B))
    gflops = (2.0 * _GEMM_N ** 3) / t_gemm / 1e9

    logger.info(
        "measured roofline: %.1f GB/s DRAM, %.1f GFLOP/s peak "
        "(one-time ~100 ms calibration; REPRO_ROOFLINE_MEASURE=0 or "
        "REPRO_DRAM_BW_GBPS/REPRO_PEAK_GFLOPS override to skip)",
        bw_gbps, gflops,
    )
    return (float(bw_gbps), float(gflops))


def measured_roofline() -> tuple[float, float]:
    """Measure (DRAM bandwidth GB/s, peak GFLOP/s) on the default device.

    A successful measurement is cached per process (the platform cannot
    change after JAX initializes).  Call
    :func:`roofline_measurement_enabled` first — this function always
    measures.  On failure (e.g. a backend without timers) it returns the
    ``(0.0, 0.0)`` sentinel; callers must treat non-positive values as
    "not measured".  Failures are NOT cached: one transient calibration
    hiccup must not disable measured roofs for the process lifetime, so
    the next call simply retries.
    """
    try:
        return _measure_roofline_once()
    except Exception as e:  # never let calibration break a build
        logger.warning("roofline measurement failed (%s); falling back to "
                       "platform defaults", e)
        return (0.0, 0.0)


# The process-lifetime cache is an observable behavior (tests and callers
# reset it between scenarios); expose the underlying cache controls on
# the public wrapper.
measured_roofline.cache_clear = _measure_roofline_once.cache_clear
measured_roofline.cache_info = _measure_roofline_once.cache_info


# ------------------------------------------------- LLC self-calibration ----
# The third roofline knob.  _sweep_roofline's "sweep_bytes > cache" test
# decides whether Eq.-(6.3) traffic actually hits DRAM; until now the
# cache size came only from a per-platform default or REPRO_LLC_BYTES.
# The working-set sweep below finds it empirically: stream working sets
# of doubling size and locate the bandwidth cliff where they stop
# fitting in the last-level cache.

_CACHE_SIZES_MB = (1, 2, 4, 8, 16, 32, 64, 128)
# constant traffic per timed call (repeats scale inversely with size) so
# small working sets aren't drowned by dispatch overhead
_CACHE_TRAFFIC_MB = 64
# a real LLC->DRAM transition drops streaming rate well over 1.5x; less
# contrast than this is noise (e.g. a DRAM-bandwidth-bound accelerator
# where the sweep cannot see the cache at all)
_CACHE_MIN_CONTRAST = 1.5


def _timed_stream_rate(n: int, reps: int) -> float:
    """Effective streaming GB/s over an ``n``-float working set.

    Each of the ``reps`` chained self-dots re-reads the operand (the
    carry feeds back into the next dot's input, so XLA can neither hoist
    the loop-invariant dot nor fold the chain), giving ``reps * 2 * 4n``
    bytes of traffic per call with one launch.
    """
    x = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)

    def chain(x_):
        def body(_, carry):
            # carry is O(1e-38)-scaled so x + carry keeps x's magnitude
            return jnp.vdot(x_ + carry, x_) * jnp.float32(1e-38)
        return jax.lax.fori_loop(0, reps, body, jnp.float32(0.0))

    fn = jax.jit(chain)
    t = _steady_min(lambda: fn(x), repeats=3, warmup=1)
    return (reps * 2.0 * n * 4) / t / 1e9


@functools.lru_cache(maxsize=None)
def _measure_cache_once() -> int:
    """The raw LLC sweep.  Returns the ``0`` sentinel when no cliff is
    visible — that is a STABLE property of the box (e.g. a compute-bound
    timer that cannot resolve the cache), so unlike a transient
    calibration exception it IS cached for the process lifetime; real
    exceptions propagate uncached and retry on the next call."""
    rates = []
    for mb in _CACHE_SIZES_MB:
        n = mb * (1 << 20) // 4
        reps = max(1, _CACHE_TRAFFIC_MB // mb)
        rates.append(_timed_stream_rate(n, reps))
    # DRAM floor from the largest working sets; cache ceiling from the
    # fastest point.  No real contrast -> the machine (or this timer)
    # cannot resolve the cache; the caller falls back to defaults.
    dram = min(rates[-2:])
    peak = max(rates)
    if not (dram > 0 and peak / dram >= _CACHE_MIN_CONTRAST):
        logger.info(
            "no LLC bandwidth cliff visible (peak %.1f vs DRAM %.1f GB/s "
            "over %s MB working sets); using platform default cache size",
            peak, dram, list(_CACHE_SIZES_MB))
        return 0
    # the cache edge: last size still streaming above the geometric
    # mean of the cache-resident and DRAM rates
    threshold = (peak * dram) ** 0.5
    cache_mb = max(mb for mb, r in zip(_CACHE_SIZES_MB, rates)
                   if r >= threshold)
    logger.info(
        "measured LLC ~%d MB (stream rates %s GB/s over %s MB working "
        "sets; REPRO_LLC_BYTES overrides)",
        cache_mb, [f"{r:.0f}" for r in rates], list(_CACHE_SIZES_MB),
    )
    return cache_mb * (1 << 20)


def measured_cache_bytes() -> int:
    """Measure the last-level-cache size by working-set sweep.

    Returns the bytes of the largest working set that still streams at
    cache-resident rate, or ``0`` when no cache cliff is detectable
    (callers must treat non-positive as "not measured" and fall back).
    Both outcomes are cached per process — an invisible cliff is a
    property of the box, not a transient — while genuine measurement
    exceptions retry on the next call.  Respect
    :func:`roofline_measurement_enabled` before calling — this function
    always measures (a few seconds on first call).
    """
    try:
        return _measure_cache_once()
    except Exception as e:  # never let calibration break a build
        logger.warning("LLC measurement failed (%s); falling back to "
                       "platform default cache size", e)
        return 0


measured_cache_bytes.cache_clear = _measure_cache_once.cache_clear
measured_cache_bytes.cache_info = _measure_cache_once.cache_info
