"""One front door for model reduction.

The paper's Prop. 5.3 / Thm. 5.1 make POD, pivoted MGS and RB-greedy
interchangeable reducers with the same error estimate; this package makes
them interchangeable in code::

    from repro.api import build_basis

    basis = build_basis(source=S, tau=1e-6)        # strategy="auto"
    basis.eim()                                    # EIM nodes + interpolant
    basis.save("artifacts/basis")                  # durable artifact

- :class:`ReductionSpec`   — declarative build description (source,
  strategy, tolerance, execution options).
- :func:`build_basis`      — spec (or kwargs) in, :class:`ReducedBasis`
  out; ``strategy="auto"`` picks resident / streamed / distributed from
  the problem shape and device-memory budget.
- :class:`ReducedBasis`    — the one result artifact: trimmed Q / R /
  pivots / errs + provenance, with ``project`` / ``reconstruct`` /
  ``per_column_errors`` / ``eim`` / ``roq_weights`` and
  ``save``/``load``.
- :func:`build_basis_set` / :class:`ReducedBasisSet` — the many-basis
  door: B lockstep greedy builds in one fused pass
  (``strategy="batched"``: banded, stacked, list, or shared tau-sweep
  workloads), shipped as one artifact of B loadable children.

The legacy drivers in :mod:`repro.core` remain the strategy engines (and
keep working), but new code should come through this door — it is the
seam future strategies (e.g. randomized sketching) plug into without
another bespoke entry point.
"""

from repro.api.artifact import ReducedBasis
from repro.api.basis_set import ReducedBasisSet
from repro.api.build import build_basis, build_basis_set, device_memory_budget
from repro.api.spec import STRATEGIES, ReductionSpec

__all__ = [
    "ReductionSpec",
    "ReducedBasis",
    "ReducedBasisSet",
    "build_basis",
    "build_basis_set",
    "device_memory_budget",
    "STRATEGIES",
]
