"""`build_basis`: the one front door to every reduction strategy.

Dispatches a :class:`~repro.api.spec.ReductionSpec` to the matching driver
in :mod:`repro.core` and wraps the result as a
:class:`~repro.api.artifact.ReducedBasis`.  Strategy ``"auto"`` picks the
driver from the problem shape and a device-memory budget:

  mesh given                         -> "distributed"
  N*M (+ greedy state) fits budget   -> "greedy"   (resident chunked)
  otherwise                          -> "streamed" (tile-streamed)

and logs the choice (logger ``repro.api``).  Every strategy goes through
the same drivers the legacy entry points use, so results are bit-for-bit
identical to calling those drivers directly (asserted in
``tests/test_api.py``).
"""

from __future__ import annotations

import dataclasses
import logging
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.artifact import ReducedBasis
from repro.api.spec import STRATEGIES, ReductionSpec

logger = logging.getLogger("repro.api")

_ENV_BUDGET = "REPRO_DEVICE_MEM_BUDGET"
_FALLBACK_BUDGET = 4 << 30  # 4 GiB when nothing else is detectable


def device_memory_budget() -> int:
    """Device-memory budget (bytes) the ``"auto"`` strategy plans against.

    Precedence: ``REPRO_DEVICE_MEM_BUDGET`` env var > the default device's
    reported memory (``memory_stats()["bytes_limit"]``, TPU/GPU) > half of
    host MemAvailable (CPU devices share host RAM) > 4 GiB.
    """
    env = os.environ.get(_ENV_BUDGET)
    if env:
        return int(float(env))
    try:
        stats = jax.devices()[0].memory_stats()
        if stats and stats.get("bytes_limit"):
            return int(stats["bytes_limit"])
    except Exception:  # memory_stats unimplemented on some backends
        pass
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemAvailable:"):
                    return int(line.split()[1]) * 1024 // 2
    except OSError:
        pass
    return _FALLBACK_BUDGET


def _resident_bytes(shape, dtype, max_k: Optional[int]) -> int:
    """Device footprint of a resident greedy build: S + Q + R (+ M-vectors)."""
    N, M = shape
    mk = min(N, M) if max_k is None else min(max_k, N, M)
    itemsize = jnp.dtype(dtype).itemsize
    return itemsize * (N * M + mk * (N + M)) + 4 * M * itemsize


def _auto_strategy(spec: ReductionSpec, shape, dtype) -> str:
    if spec.mesh is not None:
        choice, why = "distributed", "a mesh was passed"
    else:
        need = _resident_bytes(shape, dtype, spec.max_k)
        budget = (spec.memory_budget_bytes
                  if spec.memory_budget_bytes is not None
                  else device_memory_budget())
        if need <= budget:
            choice = "greedy"
            why = (f"resident footprint ~{need / 1e6:.0f} MB fits the "
                   f"device budget ~{budget / 1e6:.0f} MB")
        else:
            choice = "streamed"
            why = (f"resident footprint ~{need / 1e6:.0f} MB exceeds the "
                   f"device budget ~{budget / 1e6:.0f} MB")
    logger.info(
        "auto strategy -> %r for shape %s %s (%s)",
        choice, tuple(shape), jnp.dtype(dtype).name, why,
    )
    return choice


# ------------------------------------------------------- strategy bodies ----
# Each returns (Q, pivots, errs, R, k) TRIMMED to the accepted rank, with
# values bit-identical to the corresponding legacy driver's (sliced) output.


def _trim_greedy(res):
    k = int(res.k)
    return (res.Q[:, :k], np.asarray(res.pivots[:k]),
            np.asarray(res.errs[:k]),
            None if res.R is None else np.asarray(res.R[:k]), k)


def _build_greedy(spec, S):
    from repro.core.greedy import rb_greedy

    return _trim_greedy(rb_greedy(
        S, tau=spec.tau, max_k=spec.max_k, kappa=spec.kappa,
        max_passes=spec.max_passes, callback=spec.callback,
        refresh=spec.refresh, refresh_safety=spec.refresh_safety,
        chunk=spec.chunk, backend=spec.backend,
    ))


def _build_block_greedy(spec, S):
    from repro.core.block_greedy import _rb_greedy_block_impl

    return _trim_greedy(_rb_greedy_block_impl(
        S, tau=spec.tau, p=spec.block_p, max_k=spec.max_k,
        kappa=spec.kappa, max_passes=spec.max_passes, refresh=spec.refresh,
        refresh_safety=spec.refresh_safety, backend=spec.backend,
    ))


def _build_distributed(spec, S):
    from repro.core.distributed import distributed_greedy

    if spec.mesh is None:
        raise ValueError('strategy "distributed" requires spec.mesh')
    N, M = S.shape
    max_k = min(N, M) if spec.max_k is None else spec.max_k
    return _trim_greedy(distributed_greedy(
        S, tau=spec.tau, max_k=max_k, mesh=spec.mesh,
        callback=spec.callback, refresh=spec.refresh,
        refresh_safety=spec.refresh_safety, kappa=spec.kappa,
        max_passes=spec.max_passes, chunk=spec.chunk, backend=spec.backend,
    ))


def _build_streamed(spec, _S_unused=None):
    from repro.core.streaming import rb_greedy_streamed

    res = rb_greedy_streamed(
        spec.source, tau=spec.tau, max_k=spec.max_k, tile_m=spec.tile_m,
        kappa=spec.kappa, max_passes=spec.max_passes, refresh=spec.refresh,
        refresh_safety=spec.refresh_safety, backend=spec.backend,
        keep_R=spec.keep_R, checkpoint_dir=spec.checkpoint_dir,
        checkpoint_every_tiles=spec.checkpoint_every_tiles,
        resume=spec.resume, callback=spec.callback,
    )
    k = int(res.k)
    return (res.Q[:, :k], np.asarray(res.pivots[:k]),
            np.asarray(res.errs[:k]),
            None if res.R is None else np.asarray(res.R[:k]), k)


def _build_mgs(spec, S):
    from repro.core.mgs import _mgs_pivoted_qr_impl

    res = _mgs_pivoted_qr_impl(S, tau=spec.tau, max_k=spec.max_k)
    return (res.Q, np.asarray(res.pivots), np.asarray(res.r_diag),
            np.asarray(res.R), int(res.k))


def _build_pod(spec, S):
    from repro.core.pod import pod

    res = pod(S, tau=spec.tau)
    k = int(res.k)
    if spec.max_k is not None:
        k = min(k, spec.max_k)
    return (res.basis[:, :k], np.zeros((0,), np.int32),
            np.asarray(res.sigmas[:k]), None, k)


_BUILDERS = {
    "greedy": _build_greedy,
    "block_greedy": _build_block_greedy,
    "distributed": _build_distributed,
    "streamed": _build_streamed,
    "mgs": _build_mgs,
    "pod": _build_pod,
}


def build_basis(spec: ReductionSpec | None = None,
                **kwargs) -> ReducedBasis:
    """Build a reduced basis: the front door to every strategy.

    Call with a :class:`ReductionSpec`, keyword arguments, or both (the
    keywords override spec fields)::

        basis = build_basis(source=S, tau=1e-6)              # auto strategy
        basis = build_basis(ReductionSpec(source=S, strategy="pod"))
        basis = build_basis(spec, tau=1e-8)                  # override

    Returns a :class:`ReducedBasis` whose arrays are bit-identical to the
    corresponding legacy driver's output, trimmed to the accepted rank,
    with build provenance attached.
    """
    if spec is None:
        spec = ReductionSpec(**kwargs)
    elif kwargs:
        spec = dataclasses.replace(spec, **kwargs)
    if not isinstance(spec, ReductionSpec):
        raise TypeError(
            f"build_basis takes a ReductionSpec (or keyword args), got "
            f"{type(spec).__name__}"
        )

    from repro.core.backend import resolve_backend
    from repro.data.providers import as_provider, materialize_source

    strategy = spec.strategy
    if strategy == "streamed":
        shape, dtype = (p := as_provider(spec.source)).shape, p.dtype
        S = None
    else:
        # Every resident strategy accepts anything as_provider accepts
        # (small sources are materialized); "auto" decides BEFORE
        # materializing so an out-of-core source never lands on device.
        if strategy == "auto":
            prov = as_provider(spec.source)
            shape, dtype = prov.shape, prov.dtype
            strategy = _auto_strategy(spec, shape, dtype)
        if strategy == "streamed":
            S = None
        else:
            S = materialize_source(spec.source)
            shape, dtype = S.shape, S.dtype

    build = _BUILDERS[strategy]
    t0 = time.perf_counter()
    Q, pivots, errs, R, k = build(spec, S)
    jax.block_until_ready(Q)
    wall = time.perf_counter() - t0

    provenance = {
        "strategy": strategy,
        "requested_strategy": spec.strategy,
        "backend": (None if strategy in ("pod", "mgs")
                    else resolve_backend(spec.backend)),
        "dtype": jnp.dtype(dtype).name,
        "shape": [int(shape[0]), int(shape[1])],
        "tau": spec.tau,
        "max_k": spec.max_k,
        "wall_time_s": wall,
        "spec": spec.describe(),
        "repro_version": _repro_version(),
    }
    return ReducedBasis(Q=Q, pivots=pivots, errs=errs, k=k, R=R,
                        provenance=provenance)


def _repro_version() -> str:
    import repro

    return getattr(repro, "__version__", "unknown")
