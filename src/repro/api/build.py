"""`build_basis`: the one front door to every reduction strategy.

Dispatches a :class:`~repro.api.spec.ReductionSpec` to the matching driver
in :mod:`repro.core` and wraps the result as a
:class:`~repro.api.artifact.ReducedBasis`.  Strategy ``"auto"`` picks the
driver from the problem shape, a device-memory budget and a DRAM-roofline
machine model:

  mesh given                         -> "distributed"
  roof-bound, max_k set, greedy pass
    count > 2x the sketch's          -> "randomized" (one-pass range-finder)
  fits budget, sweep roof-bound      -> "block_greedy" (BLAS-3 panel sweep)
  fits budget otherwise              -> "greedy"   (resident chunked)
  too big, sweep roof-bound          -> "streamed" + block_p (blocked)
  too big otherwise                  -> "streamed" (tile-streamed)

"Roof-bound" means the Eq.-(6.3) pivot sweep's arithmetic intensity sits
below the machine balance (peak FLOP/s over DRAM bandwidth) AND one sweep
over S exceeds the last-level cache — i.e. every basis vector pays a full
DRAM read of S, which block pivoting amortizes by block_p.  The model's
knobs come from the spec (``bandwidth_gbps`` / ``peak_gflops`` /
``cache_bytes``), the ``REPRO_DRAM_BW_GBPS`` / ``REPRO_PEAK_GFLOPS`` /
``REPRO_LLC_BYTES`` env vars, or per-platform defaults, in that order.

The choice (and the roofline numbers behind it) is logged on logger
``repro.api``.  Every strategy goes through the same drivers the legacy
entry points use, so results are bit-for-bit identical to calling those
drivers directly (asserted in ``tests/test_api.py``).
"""

from __future__ import annotations

import dataclasses
import logging
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.artifact import ReducedBasis
from repro.api.spec import STRATEGIES, ReductionSpec

logger = logging.getLogger("repro.api")

_ENV_BUDGET = "REPRO_DEVICE_MEM_BUDGET"
_FALLBACK_BUDGET = 4 << 30  # 4 GiB when nothing else is detectable


def device_memory_budget() -> int:
    """Device-memory budget (bytes) the ``"auto"`` strategy plans against.

    Precedence: ``REPRO_DEVICE_MEM_BUDGET`` env var > the default device's
    reported memory (``memory_stats()["bytes_limit"]``, TPU/GPU) > half of
    host MemAvailable (CPU devices share host RAM) > 4 GiB.
    """
    env = os.environ.get(_ENV_BUDGET)
    if env:
        return int(float(env))
    try:
        stats = jax.devices()[0].memory_stats()
        if stats and stats.get("bytes_limit"):
            return int(stats["bytes_limit"])
    except Exception:  # memory_stats unimplemented on some backends
        pass
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemAvailable:"):
                    return int(line.split()[1]) * 1024 // 2
    except OSError:
        pass
    return _FALLBACK_BUDGET


def _resident_bytes(shape, dtype, max_k: Optional[int]) -> int:
    """Device footprint of a resident greedy build: S + Q + R (+ M-vectors)."""
    N, M = shape
    mk = min(N, M) if max_k is None else min(max_k, N, M)
    itemsize = jnp.dtype(dtype).itemsize
    return itemsize * (N * M + mk * (N + M)) + 4 * M * itemsize


# --------------------------------------------------- DRAM roofline model ----

_ENV_BW = "REPRO_DRAM_BW_GBPS"
_ENV_FLOPS = "REPRO_PEAK_GFLOPS"
_ENV_CACHE = "REPRO_LLC_BYTES"

# Conservative per-platform roofs for when nothing is measured/configured:
# (DRAM bandwidth GB/s, peak GFLOP/s, last-level cache bytes).  The point
# is the RATIO (machine balance) and the cache cutoff, not precision —
# override with the spec fields or REPRO_* env vars for a measured box.
_PLATFORM_ROOFS = {
    "cpu": (25.0, 80.0, 64 << 20),
    "gpu": (900.0, 30_000.0, 64 << 20),
    "tpu": (800.0, 100_000.0, 128 << 20),
}

# Panel width "auto" applies when it decides blocking pays and the spec
# left block_p at the stepwise default: one S read per 8 bases cuts the
# dominant DRAM term ~8x while the staleness cost stays a few extra bases
# on fast-decaying families (tests/test_block_greedy.py).
_AUTO_BLOCK_P = 8


def machine_roofline(spec: Optional[ReductionSpec] = None):
    """(bandwidth GB/s, peak GFLOP/s, cache bytes) the ``"auto"`` roofline
    model plans against.  Precedence per knob: spec field >
    ``REPRO_DRAM_BW_GBPS`` / ``REPRO_PEAK_GFLOPS`` / ``REPRO_LLC_BYTES``
    env var > one-time on-device measurement
    (:func:`repro.api.roofline.measured_roofline` for bandwidth/FLOPs,
    :func:`repro.api.roofline.measured_cache_bytes` for the LLC
    working-set sweep; all skipped under ``REPRO_ROOFLINE_MEASURE=0``) >
    per-platform default."""
    from repro.api.roofline import (
        measured_cache_bytes,
        measured_roofline,
        roofline_measurement_enabled,
    )

    defaults = _PLATFORM_ROOFS.get(jax.default_backend(),
                                   _PLATFORM_ROOFS["cpu"])

    def pinned(field, env):
        if field is not None:
            return float(field)
        raw = os.environ.get(env)
        return float(raw) if raw else None

    bw = pinned(getattr(spec, "bandwidth_gbps", None), _ENV_BW)
    gf = pinned(getattr(spec, "peak_gflops", None), _ENV_FLOPS)
    if (bw is None or gf is None) and roofline_measurement_enabled():
        # only knobs nobody pinned are filled from the measurement (a
        # failed calibration reports 0.0 and falls through to defaults)
        m_bw, m_gf = measured_roofline()
        if bw is None and m_bw > 0:
            bw = m_bw
        if gf is None and m_gf > 0:
            gf = m_gf

    cache_field = getattr(spec, "cache_bytes", None)
    if cache_field is not None:
        cache = int(cache_field)
    else:
        raw = os.environ.get(_ENV_CACHE)
        if raw:
            cache = int(float(raw))
        else:
            cache = defaults[2]
            if roofline_measurement_enabled():
                m_cache = measured_cache_bytes()
                if m_cache > 0:
                    cache = m_cache

    return (
        defaults[0] if bw is None else bw,
        defaults[1] if gf is None else gf,
        cache,
    )


def _sweep_roofline(shape, dtype, spec: Optional[ReductionSpec] = None):
    """Classify the Eq.-(6.3) pivot sweep for this problem.

    Returns ``(roof_bound, why)``: one sweep reads S once (``N*M*itemsize``
    bytes) for 2 real FLOPs per element (8 for complex, on the plane-split
    path).  The sweep is DRAM-roof-bound when that intensity sits below the
    machine balance AND the sweep exceeds the last-level cache — exactly
    the regime where block pivoting (one read per block_p bases) is the
    lever.
    """
    bw, gflops, cache = machine_roofline(spec)
    N, M = shape
    dt = jnp.dtype(dtype)
    sweep_bytes = N * M * dt.itemsize
    flops = (8 if jnp.issubdtype(dt, jnp.complexfloating) else 2) * N * M
    intensity = flops / sweep_bytes
    balance = gflops / bw
    roof_bound = intensity < balance and sweep_bytes > cache
    why = (f"sweep ~{sweep_bytes / 1e6:.0f} MB at {intensity:.2f} FLOP/B "
           f"vs balance {balance:.2f} FLOP/B, cache ~{cache / 1e6:.0f} MB"
           f" -> {'roof-bound' if roof_bound else 'not roof-bound'}")
    return roof_bound, why


def _estimated_max_k(spec: ReductionSpec, shape):
    """Sketch-estimate a ``max_k`` for planning when the caller gave none.

    Costs a few cheap streamed passes over the source
    (:func:`repro.core.randomized.estimate_rank`), so it runs only where
    the answer changes the plan (roof-bound sweeps, where the
    greedy-vs-sketch pass-count comparison needs a rank) and only when
    on-device probing is enabled (``REPRO_ROOFLINE_MEASURE=0`` — the CI
    determinism knob — also opts out of this).  Returns None when the
    source can't be probed (decision-level callers pass placeholder
    sources) or the estimate saturated (a lower bound must not become a
    cap).  The returned cap carries 25% + sketch_p headroom: the build's
    own tau stop remains the authority, the cap just bounds planning and
    the Q allocation.
    """
    from repro.core.randomized import estimate_rank

    try:
        est = estimate_rank(spec.source, tau=float(spec.tau),
                            seed=spec.sketch_seed, kind=spec.sketch_kind,
                            tile_m=spec.tile_m, backend=spec.backend)
    except Exception as e:
        logger.info("rank estimation skipped (%s)", e)
        return None
    if est.saturated:
        logger.info("rank estimate saturated at ell=%d; not capping",
                    est.ell)
        return None
    cap = -(-est.k * 5 // 4) + spec.sketch_p
    cap = min(cap, int(shape[0]), int(shape[1]))
    logger.info("sketch-estimated rank ~%d (ell=%d, %d pass(es)) -> "
                "planning max_k=%d", est.k, est.ell, est.passes, cap)
    return cap


def _auto_strategy(spec: ReductionSpec, shape, dtype):
    """Resolve ``"auto"`` to ``(strategy, block_p, max_k)`` and log the
    decision.  ``max_k`` is ``spec.max_k`` unless the caller gave none
    and a sketch-based rank estimate filled one in
    (:func:`_estimated_max_k`)."""
    block_p = spec.block_p
    max_k = spec.max_k
    if spec.mesh is not None:
        choice, why = "distributed", "a mesh was passed"
    else:
        need = _resident_bytes(shape, dtype, spec.max_k)
        budget = (spec.memory_budget_bytes
                  if spec.memory_budget_bytes is not None
                  else device_memory_budget())
        roof_bound, roof_why = _sweep_roofline(shape, dtype, spec)
        fits = need <= budget
        fit_why = (f"resident footprint ~{need / 1e6:.0f} MB "
                   f"{'fits' if fits else 'exceeds'} the device budget "
                   f"~{budget / 1e6:.0f} MB")
        if roof_bound and block_p == 1:
            block_p = _AUTO_BLOCK_P
        if fits:
            choice = "block_greedy" if roof_bound else "greedy"
        else:
            choice = "streamed"
        why = f"{fit_why}; {roof_why}"
        if roof_bound:
            why += f"; blocked sweep, block_p={block_p}"
        # On a roof-bound sweep every basis costs ~1/block_p of a DRAM
        # read of S, so a greedy build streams S ~ceil(max_k / block_p)
        # times; the one-pass sketch pays 1 + 2*sketch_power passes
        # regardless of k.  When a rank target exists (given, or — the
        # PR-9 follow-on — sketch-estimated when probing is enabled) and
        # greedy's pass count exceeds TWICE the sketch's, the
        # range-finder wins even after paying its probabilistic-vs-exact
        # error margin.
        if roof_bound and max_k is None:
            from repro.api.roofline import roofline_measurement_enabled

            if roofline_measurement_enabled():
                max_k = _estimated_max_k(spec, shape)
                if max_k is not None:
                    why += f"; sketch-estimated max_k={max_k}"
        if roof_bound and max_k is not None:
            greedy_passes = -(-max_k // max(block_p, 1))
            sketch_passes = 1 + 2 * spec.sketch_power
            if greedy_passes > 2 * sketch_passes:
                choice = "randomized"
                block_p = spec.block_p  # blocking is a greedy-only knob
                why += (f"; ~{greedy_passes} greedy passes over S vs "
                        f"{sketch_passes} sketch pass(es) -> randomized")
    logger.info(
        "auto strategy -> %r for shape %s %s (%s)",
        choice, tuple(shape), jnp.dtype(dtype).name, why,
    )
    return choice, block_p, max_k


# ------------------------------------------------------- strategy bodies ----
# Each returns (Q, pivots, errs, R, k, extras) with the arrays TRIMMED to
# the accepted rank and bit-identical to the corresponding legacy driver's
# (sliced) output; ``extras`` is a JSON-serializable dict merged into the
# artifact provenance (e.g. the adaptive driver's panel-width trajectory,
# the greedy family's terminal stop code).  ``ckpt_dir`` is the resolved
# mid-build checkpoint directory (the workdir's ``build/`` scratch, or
# ``spec.checkpoint_dir``); ``pod``/``mgs`` are single-shot factorizations
# with nothing to checkpoint and ignore it.


def _trim_greedy(res, extras=None):
    from repro.core.greedy import STOP_NAMES

    k = int(res.k)
    extras = dict(extras or {})
    stop = getattr(res, "stop", None)
    if stop is not None:
        extras["stop"] = STOP_NAMES.get(int(stop), str(int(stop)))
    return (res.Q[:, :k], np.asarray(res.pivots[:k]),
            np.asarray(res.errs[:k]),
            None if res.R is None else np.asarray(res.R[:k]), k,
            extras)


def _build_greedy(spec, S, ckpt_dir=None):
    from repro.core.greedy import rb_greedy

    return _trim_greedy(rb_greedy(
        S, tau=spec.tau, max_k=spec.max_k, kappa=spec.kappa,
        max_passes=spec.max_passes, callback=spec.callback,
        refresh=spec.refresh, refresh_safety=spec.refresh_safety,
        chunk=spec.chunk, backend=spec.backend,
        checkpoint_dir=ckpt_dir, resume=spec.resume,
    ))


def _build_block_greedy(spec, S, ckpt_dir=None):
    from repro.core.block_greedy import _rb_greedy_block_impl

    # spec.chunk counts greedy ITERATIONS per device-resident chunk; the
    # blocked driver's chunk counts BLOCKS of block_p, so divide to keep
    # the host-sync cadence the user configured.
    diag = {} if spec.adaptive_block else None
    res = _rb_greedy_block_impl(
        S, tau=spec.tau, p=spec.block_p, max_k=spec.max_k,
        kappa=spec.kappa, max_passes=spec.max_passes, refresh=spec.refresh,
        refresh_safety=spec.refresh_safety, backend=spec.backend,
        chunk=max(1, spec.chunk // max(spec.block_p, 1)),
        callback=spec.callback, panel=spec.panel_ortho,
        adaptive=spec.adaptive_block, diagnostics=diag,
        checkpoint_dir=ckpt_dir, resume=spec.resume,
    )
    return _trim_greedy(res, diag)


def _build_distributed(spec, S, ckpt_dir=None):
    from repro.core.distributed import distributed_greedy

    if spec.mesh is None:
        raise ValueError('strategy "distributed" requires spec.mesh')
    N, M = S.shape
    max_k = min(N, M) if spec.max_k is None else spec.max_k
    return _trim_greedy(distributed_greedy(
        S, tau=spec.tau, max_k=max_k, mesh=spec.mesh,
        callback=spec.callback, refresh=spec.refresh,
        refresh_safety=spec.refresh_safety, kappa=spec.kappa,
        max_passes=spec.max_passes, chunk=spec.chunk, backend=spec.backend,
        block_p=spec.block_p, panel_ortho=spec.panel_ortho,
        checkpoint_dir=ckpt_dir, resume=spec.resume,
    ))


def _build_streamed(spec, _S_unused=None, ckpt_dir=None):
    from repro.core.streaming import rb_greedy_streamed

    res = rb_greedy_streamed(
        spec.source, tau=spec.tau, max_k=spec.max_k, tile_m=spec.tile_m,
        block_p=spec.block_p, kappa=spec.kappa,
        max_passes=spec.max_passes, refresh=spec.refresh,
        refresh_safety=spec.refresh_safety, backend=spec.backend,
        panel_ortho=spec.panel_ortho,
        keep_R=spec.keep_R, checkpoint_dir=ckpt_dir,
        checkpoint_every_tiles=spec.checkpoint_every_tiles,
        resume=spec.resume, callback=spec.callback,
    )
    return _trim_greedy(res)


def _build_mgs(spec, S, ckpt_dir=None):
    from repro.core.mgs import _mgs_pivoted_qr_impl

    res = _mgs_pivoted_qr_impl(S, tau=spec.tau, max_k=spec.max_k)
    return (res.Q, np.asarray(res.pivots), np.asarray(res.r_diag),
            np.asarray(res.R), int(res.k), {})


def _build_pod(spec, S, ckpt_dir=None):
    from repro.core.pod import pod

    res = pod(S, tau=spec.tau)
    k = int(res.k)
    if spec.max_k is not None:
        k = min(k, spec.max_k)
    return (res.basis[:, :k], np.zeros((0,), np.int32),
            np.asarray(res.sigmas[:k]), None, k, {})


def _sketch_extras(res):
    """Randomized provenance: sketch params + singular-value estimates."""
    return {
        "sketch": {
            "ell": int(res.ell),
            "p": int(res.sketch_p),
            "power": int(res.power),
            "seed": int(res.seed),
            "kind": res.kind,
            "n_passes": int(res.n_passes),
            "n_tiles": int(res.n_tiles),
        },
        "sigma_estimates": [float(s) for s in res.svals],
    }


def _run_sketch(spec, ckpt_dir):
    from repro.core.randomized import rb_randomized_streamed

    return rb_randomized_streamed(
        spec.source, tau=spec.tau, max_k=spec.max_k,
        sketch_p=spec.sketch_p, power=spec.sketch_power,
        seed=spec.sketch_seed, kind=spec.sketch_kind,
        tile_m=spec.tile_m, backend=spec.backend,
        checkpoint_dir=ckpt_dir,
        checkpoint_every_tiles=spec.checkpoint_every_tiles,
        resume=spec.resume and ckpt_dir is not None,
    )


def _build_randomized(spec, _S_unused=None, ckpt_dir=None):
    res = _run_sketch(spec, ckpt_dir)
    k = int(res.k)
    # POD-shaped result: no pivots (the basis spans a sketched range, not
    # selected columns), errs are the spectrum estimates.
    return (res.Q, np.zeros((0,), np.int32),
            np.asarray(res.svals[:k]), None, k, _sketch_extras(res))


def _build_sketch_greedy(spec, _S_unused=None, ckpt_dir=None):
    """One-pass sketch initializes Q; streamed greedy refines to tau.

    The sketch's basis enters :func:`repro.core.streaming.
    rb_greedy_streamed` through the PR-6 ``warm_start=`` seam with
    sentinel pivots (-1: these columns were not selected from S), and the
    greedy loop extends it with whatever directions the sketch missed —
    typically zero-to-few sweeps on well-sketched families, at tau's
    EXACT Eq.-(6.3) error control rather than the probabilistic bound.
    Refinement runs stepwise (block_p=1): the blocked compaction path
    drops pivot==-1 slots, which would evict the warm columns.
    """
    from repro.core.streaming import rb_greedy_streamed

    sketch_dir = os.path.join(ckpt_dir, "sketch") if ckpt_dir else None
    refine_dir = os.path.join(ckpt_dir, "refine") if ckpt_dir else None
    res = _run_sketch(spec, sketch_dir)
    k0 = int(res.k)
    warm = {
        "Q": res.Q,
        "pivots": np.full((k0,), -1, np.int32),
        "errs": np.asarray(res.svals[:k0]),
    }
    refined = rb_greedy_streamed(
        spec.source, tau=spec.tau, max_k=spec.max_k, tile_m=spec.tile_m,
        block_p=1, kappa=spec.kappa, max_passes=spec.max_passes,
        refresh=spec.refresh, refresh_safety=spec.refresh_safety,
        backend=spec.backend, panel_ortho=spec.panel_ortho,
        keep_R=spec.keep_R, checkpoint_dir=refine_dir,
        checkpoint_every_tiles=spec.checkpoint_every_tiles,
        resume=spec.resume, callback=spec.callback, warm_start=warm,
    )
    out = _trim_greedy(refined, _sketch_extras(res))
    out[5]["sketch"]["k0"] = k0
    out[5]["sketch"]["refined_k"] = out[4]
    return out


_BUILDERS = {
    "greedy": _build_greedy,
    "block_greedy": _build_block_greedy,
    "distributed": _build_distributed,
    "streamed": _build_streamed,
    "randomized": _build_randomized,
    "sketch+greedy": _build_sketch_greedy,
    "mgs": _build_mgs,
    "pod": _build_pod,
}
# "batched" is absent deliberately: it returns a ReducedBasisSet, not a
# single basis, so build_basis delegates to build_basis_set before the
# single-basis pipeline starts (see _is_batched_workload).

# Strategies that stream the provider directly and never materialize the
# source on device (build_basis skips materialize_source for these).
_STREAMING_STRATEGIES = ("streamed", "randomized", "sketch+greedy")


def _is_batched_workload(spec: ReductionSpec) -> bool:
    """Does this spec describe a many-basis (B-lane) build?

    True when ``spec.batch`` is set, or the source is inherently
    B-laned: a (B, N, M) stacked array, a list/tuple of per-lane
    sources, or a :class:`~repro.data.bands.BandSplit`.
    """
    if spec.batch is not None:
        return True
    from repro.data.bands import BandSplit

    src = spec.source
    if isinstance(src, BandSplit) or isinstance(src, (list, tuple)):
        return True
    return getattr(src, "ndim", None) == 3


def build_basis(spec: ReductionSpec | None = None,
                **kwargs) -> ReducedBasis:
    """Build a reduced basis: the front door to every strategy.

    Call with a :class:`ReductionSpec`, keyword arguments, or both (the
    keywords override spec fields)::

        basis = build_basis(source=S, tau=1e-6)              # auto strategy
        basis = build_basis(ReductionSpec(source=S, strategy="pod"))
        basis = build_basis(spec, tau=1e-8)                  # override

    Returns a :class:`ReducedBasis` whose arrays are bit-identical to the
    corresponding legacy driver's output, trimmed to the accepted rank,
    with build provenance attached.  A many-basis workload —
    ``strategy="batched"``, or ``"auto"`` with ``spec.batch`` / a stacked
    (B, N, M) / list / :class:`~repro.data.bands.BandSplit` source —
    delegates to :func:`build_basis_set` and returns its
    :class:`~repro.api.basis_set.ReducedBasisSet` of B children instead.
    """
    if spec is None:
        spec = ReductionSpec(**kwargs)
    elif kwargs:
        spec = dataclasses.replace(spec, **kwargs)
    if not isinstance(spec, ReductionSpec):
        raise TypeError(
            f"build_basis takes a ReductionSpec (or keyword args), got "
            f"{type(spec).__name__}"
        )

    # Many-basis workloads return a set; decide BEFORE touching providers
    # (a stacked 3-D source is not a valid single-basis provider).
    if spec.strategy == "batched":
        return build_basis_set(spec)
    if spec.strategy == "auto" and _is_batched_workload(spec):
        logger.info(
            "auto strategy -> 'batched' (batch=%s, %s source)",
            spec.batch, type(spec.source).__name__)
        return build_basis_set(spec)

    from repro.core.backend import resolve_backend
    from repro.data.providers import as_provider, materialize_source

    # ------------------------------------------- workdir build lifecycle --
    # A workdir owns the whole build: mid-build checkpoints in
    # <workdir>/build/, the finished basis finalized atomically into
    # <workdir> itself, scratch removed on success.  Crash anywhere +
    # relaunch with resume=True lands on the identical artifact.
    build_dir = None
    if spec.workdir is not None:
        build_dir = os.path.join(spec.workdir, "build")
        if spec.resume:
            try:
                basis = ReducedBasis.load(spec.workdir)
            except (FileNotFoundError, IOError):
                pass  # nothing finalized yet: (re)build below
            else:
                # Already finalized (e.g. the previous run died between
                # finalize and scratch cleanup): return it, finish the GC.
                import shutil

                shutil.rmtree(build_dir, ignore_errors=True)
                logger.info("workdir %s already holds a finalized basis; "
                            "returning it", spec.workdir)
                return basis
        else:
            # A fresh (non-resume) build must not splice onto a previous
            # run's checkpoints.
            import shutil

            shutil.rmtree(build_dir, ignore_errors=True)
    ckpt_dir = build_dir if build_dir is not None else spec.checkpoint_dir

    strategy = spec.strategy
    if strategy in _STREAMING_STRATEGIES:
        shape, dtype = (p := as_provider(spec.source)).shape, p.dtype
        S = None
    else:
        # Every resident strategy accepts anything as_provider accepts
        # (small sources are materialized); "auto" decides BEFORE
        # materializing so an out-of-core source never lands on device.
        if strategy == "auto":
            prov = as_provider(spec.source)
            shape, dtype = prov.shape, prov.dtype
            strategy, auto_p, auto_k = _auto_strategy(spec, shape, dtype)
            if auto_p != spec.block_p:
                # the roofline model opted into blocking: the chosen panel
                # width must reach the driver (and the provenance)
                spec = dataclasses.replace(spec, block_p=auto_p)
            if auto_k != spec.max_k:
                # a sketch-estimated rank cap (with headroom) must reach
                # the chosen driver — the randomized builder sizes its
                # sketch from it, the greedy family bounds Q with it
                spec = dataclasses.replace(spec, max_k=auto_k)
        if strategy in _STREAMING_STRATEGIES:
            S = None
        else:
            S = materialize_source(spec.source)
            shape, dtype = S.shape, S.dtype

    build = _BUILDERS[strategy]
    t0 = time.perf_counter()
    Q, pivots, errs, R, k, extras = build(spec, S, ckpt_dir)
    jax.block_until_ready(Q)
    wall = time.perf_counter() - t0

    provenance = {
        "strategy": strategy,
        "requested_strategy": spec.strategy,
        "backend": (None if strategy in ("pod", "mgs")
                    else resolve_backend(spec.backend)),
        "dtype": jnp.dtype(dtype).name,
        "shape": [int(shape[0]), int(shape[1])],
        "tau": spec.tau,
        "max_k": spec.max_k,
        "block_p": spec.block_p,
        "wall_time_s": wall,
        "spec": spec.describe(),
        "repro_version": _repro_version(),
        **extras,
    }
    basis = ReducedBasis(Q=Q, pivots=pivots, errs=errs, k=k, R=R,
                         provenance=provenance)
    if spec.workdir is not None:
        # Finalize: atomic save into the workdir, THEN drop the build
        # scratch.  A crash between the two leaves a finalized artifact
        # plus orphan scratch, which the resume path above garbage-collects
        # on the next launch.
        import shutil

        basis.save(spec.workdir)
        shutil.rmtree(build_dir, ignore_errors=True)
    return basis


def build_basis_set(spec: ReductionSpec | None = None, **kwargs):
    """Build B reduced bases in one lockstep batched pass.

    The many-basis front door: accepts a stacked (B, N, M) array, a
    list/tuple of per-lane sources, a
    :class:`~repro.data.bands.BandSplit` (banded workload), or a shared
    (N, M) source with ``batch=B`` / a length-B ``tau`` sequence
    (tau-sweep over one matrix).  Runs
    :func:`repro.core.batch_greedy.batch_rb_greedy` — one fused pass over
    the snapshots for all B lanes — and returns a
    :class:`~repro.api.basis_set.ReducedBasisSet` whose children are
    bit-identical (stacked layouts) to B sequential
    :func:`~repro.core.greedy.rb_greedy` builds.

    With ``workdir=`` the finished set finalizes there atomically
    (``resume=True`` returns an already-finalized set without
    rebuilding).  ``build_basis`` delegates here for
    ``strategy="batched"`` (and for ``"auto"`` on batched workloads), so
    calling this directly is optional.
    """
    if spec is None:
        spec = ReductionSpec(**kwargs)
    elif kwargs:
        spec = dataclasses.replace(spec, **kwargs)
    if spec.strategy not in ("batched", "auto"):
        raise ValueError(
            f"build_basis_set builds the batched strategy, got "
            f"{spec.strategy!r}")

    from repro.api.basis_set import ReducedBasisSet
    from repro.core.backend import resolve_backend
    from repro.core.batch_greedy import batch_rb_greedy
    from repro.data.bands import BandSplit
    from repro.data.providers import materialize_source

    if spec.workdir is not None and spec.resume:
        try:
            bset = ReducedBasisSet.load(spec.workdir)
        except (FileNotFoundError, IOError):
            pass  # nothing finalized yet: build below
        else:
            logger.info("workdir %s already holds a finalized basis set; "
                        "returning it", spec.workdir)
            return bset

    src = spec.source
    bands_meta = None
    if isinstance(src, BandSplit):
        bands_meta = {
            "edges": [[int(lo), int(hi)] for lo, hi in src.edges],
            "n_freq": int(src.n_freq),
            "from_real": bool(src.from_real),
        }
        src = src.stack
    elif isinstance(src, (list, tuple)):
        src = [materialize_source(s) for s in src]
    else:
        src = materialize_source(src)
        if src.ndim not in (2, 3):
            raise ValueError(
                f"batched strategy needs an (N, M), (B, N, M), list, or "
                f"BandSplit source, got shape {src.shape}")

    t0 = time.perf_counter()
    res = batch_rb_greedy(
        src, spec.tau, max_k=spec.max_k, batch=spec.batch,
        kappa=spec.kappa, max_passes=spec.max_passes,
        refresh=spec.refresh, refresh_safety=spec.refresh_safety,
        chunk=spec.chunk, backend=spec.backend, callback=spec.callback,
    )
    jax.block_until_ready(res.Q)
    wall = time.perf_counter() - t0

    B = res.batch
    taus = np.broadcast_to(
        np.atleast_1d(np.asarray(spec.tau, dtype=np.float64)), (B,))
    layout = "stacked" if getattr(src, "ndim", 3) == 3 or \
        isinstance(src, list) else "shared"
    base = {
        "strategy": "batched",
        "requested_strategy": spec.strategy,
        "backend": resolve_backend(spec.backend),
        "batch": B,
        "layout": layout,
        "dtype": jnp.dtype(res.Q.dtype).name,
        "shape": [int(res.Q.shape[1]), int(res.R.shape[2])],
        "tau": [float(t) for t in taus],
        "max_k": spec.max_k,
        "wall_time_s": wall,
        "spec": spec.describe(),
        "repro_version": _repro_version(),
        **({"bands": bands_meta} if bands_meta is not None else {}),
    }
    children = []
    for b in range(B):
        Q, pivots, errs, R, k, extras = _trim_greedy(res.lane(b))
        prov = dict(base)
        prov["lane"] = {"index": b, "tau": float(taus[b]), **extras}
        children.append(ReducedBasis(Q=Q, pivots=pivots, errs=errs, k=k,
                                     R=R, provenance=prov))
    bset = ReducedBasisSet(children=tuple(children), provenance=base)
    if spec.workdir is not None:
        bset.save(spec.workdir)
    return bset


def _repro_version() -> str:
    import repro

    return getattr(repro, "__version__", "unknown")
