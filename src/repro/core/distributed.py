"""Column-distributed RB-greedy (the paper's Sec. 6 system on a TPU mesh).

Data decomposition is exactly greedycpp's: the snapshot matrix S is sharded
by COLUMNS over every mesh axis (each device owns an (N, M/P) slice and its
residual bookkeeping), while the basis Q (N x max_k) is replicated.  One
iteration (cf. Sec. 6.1.3):

  paper (MPI)                          |  here (SPMD collectives)
  -------------------------------------------------------------------------
  bcast q_k to P_pivot workers         |  Q replicated (no transfer)
  local residual update + local argmax |  same, fused (Pallas greedy_update)
  MPI_Allreduce (max, loc)             |  all_gather of (P, 2) pairs + local
                                       |  argmax — O(P) bytes
  owner MPI_Sends pivot column;        |  one psum of the owner-masked
  master MPI_Bcasts new basis          |  column — a single N-vector
                                       |  allreduce replaces send+bcast
  master core orthogonalizes (serial   |  every device runs IMGS redundantly
  bottleneck, Eq. 6.6)                 |  on the replicated Q — the Amdahl
                                       |  term of Eq. 6.6 disappears

The per-iteration state is a pytree (column-sharded residual trackers,
replicated basis), so the Python driver checkpoints/restores it with the
standard checkpoint machinery, and restores onto a *different* mesh
(elastic re-shard) because restore_checkpoint re-places leaves by target
sharding.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from jax.experimental.shard_map import shard_map

from repro.core.greedy import GreedyResult, imgs_orthogonalize


class DistGreedyState(NamedTuple):
    """Column-sharded greedy state (sharding noted per leaf)."""

    Q: jax.Array        # (N, max_k) REPLICATED
    R: jax.Array        # (max_k, M) col-sharded
    norms_sq: jax.Array  # (M,) col-sharded — reference residual^2
    acc: jax.Array       # (M,) col-sharded
    pivots: jax.Array    # (max_k,) replicated
    errs: jax.Array      # (max_k,) replicated
    k: jax.Array         # () replicated


def state_specs(mesh: Mesh):
    cols = P(tuple(mesh.axis_names))
    rep = P()
    return DistGreedyState(
        Q=P(None, None),
        R=P(None, tuple(mesh.axis_names)),
        norms_sq=cols,
        acc=cols,
        pivots=rep,
        errs=rep,
        k=rep,
    )


def state_shardings(mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), state_specs(mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


def dist_greedy_init(S: jax.Array, max_k: int, mesh: Mesh) -> DistGreedyState:
    N, M = S.shape
    rdtype = jnp.zeros((), S.dtype).real.dtype
    sh = state_shardings(mesh)
    return DistGreedyState(
        Q=jax.device_put(jnp.zeros((N, max_k), S.dtype), sh.Q),
        R=jax.device_put(jnp.zeros((max_k, M), S.dtype), sh.R),
        norms_sq=jax.device_put(
            jnp.sum(jnp.abs(S) ** 2, axis=0).astype(rdtype), sh.norms_sq
        ),
        acc=jax.device_put(jnp.zeros((M,), rdtype), sh.acc),
        pivots=jax.device_put(jnp.zeros((max_k,), jnp.int32), sh.pivots),
        errs=jax.device_put(jnp.zeros((max_k,), rdtype), sh.errs),
        k=jax.device_put(jnp.zeros((), jnp.int32), sh.k),
    )


def _axis_index(axes: Sequence[str]):
    """Flattened device rank over (possibly several) mesh axes."""
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    return idx


def _axis_count(axes: Sequence[str]):
    n = 1
    for a in axes:
        n *= jax.lax.axis_size(a)
    return n


def make_dist_greedy_step(
    mesh: Mesh, kappa: float = 2.0, max_passes: int = 3
):
    """Build the jitted SPMD greedy step for a mesh."""
    axes = tuple(mesh.axis_names)
    specs = state_specs(mesh)
    s_spec = P(None, axes)

    def local_step(S_loc, state):
        # ---- local pivot search (the greedy_update fusion target) ----
        res_sq = jnp.maximum(state.norms_sq - state.acc, 0.0)  # (M_loc,)
        j_loc = jnp.argmax(res_sq)
        val_loc = res_sq[j_loc]
        m_loc = res_sq.shape[0]
        rank = _axis_index(axes)
        j_glob = rank * m_loc + j_loc

        # ---- global argmax: all_gather the (val, idx) pairs ----
        vals = jax.lax.all_gather(val_loc, axes, tiled=False)  # (P,)
        idxs = jax.lax.all_gather(j_glob, axes, tiled=False)
        vals = vals.reshape(-1)
        idxs = idxs.reshape(-1)
        win = jnp.argmax(vals)
        err = jnp.sqrt(vals[win])
        j_global = idxs[win]
        owner = win == rank

        # ---- pivot column broadcast: one psum of the masked column ----
        col = jax.lax.dynamic_slice_in_dim(S_loc, j_loc, 1, axis=1)[:, 0]
        contrib = jnp.where(owner, col, jnp.zeros_like(col))
        v = jax.lax.psum(contrib, axes)  # (N,) replicated

        # ---- replicated orthogonalization (no master core) ----
        q, _, rnorm, _ = imgs_orthogonalize(
            v, state.Q, kappa=kappa, max_passes=max_passes
        )

        # ---- Eq. (6.3) update over the local shard ----
        c = q.conj() @ S_loc  # (M_loc,)
        k = state.k
        return DistGreedyState(
            Q=state.Q.at[:, k].set(q),
            R=state.R.at[k, :].set(c),
            norms_sq=state.norms_sq,
            acc=state.acc + jnp.abs(c) ** 2,
            pivots=state.pivots.at[k].set(j_global.astype(jnp.int32)),
            errs=state.errs.at[k].set(err),
            k=k + 1,
        )

    sharded = shard_map(
        local_step,
        mesh=mesh,
        in_specs=(s_spec, specs),
        out_specs=specs,
        check_rep=False,
    )
    return jax.jit(sharded, donate_argnums=(1,))


def make_dist_refresh(mesh: Mesh):
    """Exact residual recomputation (deep-tolerance mode), column-local."""
    axes = tuple(mesh.axis_names)
    specs = state_specs(mesh)
    s_spec = P(None, axes)

    def local_refresh(S_loc, state):
        C = state.Q.conj().T @ S_loc
        E = S_loc - state.Q @ C
        res = jnp.sum(jnp.abs(E) ** 2, axis=0).astype(state.norms_sq.dtype)
        return state._replace(norms_sq=res, acc=jnp.zeros_like(state.acc))

    sharded = shard_map(
        local_refresh, mesh=mesh, in_specs=(s_spec, specs),
        out_specs=specs, check_rep=False,
    )
    return jax.jit(sharded, donate_argnums=(1,))


def distributed_greedy(
    S: jax.Array,
    tau: float,
    max_k: int,
    mesh: Mesh,
    callback=None,
    refresh: str = "auto",
    refresh_safety: float = 100.0,
    kappa: float = 2.0,
    max_passes: int = 3,
) -> GreedyResult:
    """Driver mirroring :func:`repro.core.greedy.rb_greedy` on a mesh.

    ``S`` should be placed with columns sharded over all mesh axes (the
    driver places it if not).  ``callback(state)`` runs after every step
    (checkpointing hook).  Column count must divide the device count.
    """
    s_sharding = NamedSharding(mesh, P(None, tuple(mesh.axis_names)))
    if getattr(S, "sharding", None) != s_sharding:
        S = jax.device_put(S, s_sharding)

    step_fn = make_dist_greedy_step(mesh, kappa, max_passes)
    refresh_fn = make_dist_refresh(mesh)
    state = dist_greedy_init(S, max_k, mesh)

    eps = float(jnp.finfo(state.norms_sq.dtype).eps)
    ref_sq = float(jnp.max(state.norms_sq))
    scale = ref_sq ** 0.5
    k = 0
    while k < max_k:
        state = step_fn(S, state)
        k = int(state.k)
        if callback is not None:
            callback(state)
        err = float(state.errs[k - 1])
        if err < tau:
            k -= 1
            state = state._replace(
                k=jnp.asarray(k, jnp.int32),
                Q=state.Q.at[:, k].set(0),
                pivots=state.pivots.at[k].set(-1),
            )
            break
        if err < 50.0 * eps * scale:
            k -= 1
            state = state._replace(k=jnp.asarray(k, jnp.int32))
            break
        if refresh == "auto" and err * err < refresh_safety * eps * ref_sq:
            state = refresh_fn(S, state)
            ref_sq = max(float(jnp.max(state.norms_sq)), 1e-300)
            if float(ref_sq) ** 0.5 < tau:
                break
    return GreedyResult(
        Q=state.Q, R=state.R, pivots=state.pivots, errs=state.errs,
        k=state.k, n_ortho_passes=jnp.zeros_like(state.pivots),
        rnorms=jnp.zeros_like(state.errs),
    )
