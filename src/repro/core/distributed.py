"""Column-distributed RB-greedy (the paper's Sec. 6 system on a TPU mesh).

Data decomposition is exactly greedycpp's: the snapshot matrix S is sharded
by COLUMNS over every mesh axis (each device owns an (N, M/P) slice and its
residual bookkeeping), while the basis Q (N x max_k) is replicated.  One
iteration (cf. Sec. 6.1.3):

  paper (MPI)                          |  here (SPMD collectives)
  -------------------------------------------------------------------------
  bcast q_k to P_pivot workers         |  Q replicated (no transfer)
  local residual update + local argmax |  same, fused (Pallas greedy_update)
  MPI_Allreduce (max, loc)             |  all_gather of (P, 2) pairs + local
                                       |  argmax — O(P) bytes
  owner MPI_Sends pivot column;        |  one psum of the owner-masked
  master MPI_Bcasts new basis          |  column — a single N-vector
                                       |  allreduce replaces send+bcast
  master core orthogonalizes (serial   |  every device runs IMGS redundantly
  bottleneck, Eq. 6.6)                 |  on the replicated Q — the Amdahl
                                       |  term of Eq. 6.6 disappears

The per-iteration state is a pytree (column-sharded residual trackers,
replicated basis), so the Python driver checkpoints/restores it with the
standard checkpoint machinery, and restores onto a *different* mesh
(elastic re-shard) because restore_checkpoint re-places leaves by target
sharding.

Hot-loop primitives route through :mod:`repro.core.backend` (fused Pallas
kernels on TPU, ``jnp`` under XLA), and the driver runs CHUNKED: ``chunk``
iterations execute inside one jitted ``lax.while_loop`` (collectives and
all) with the host syncing only a (n_done, stop_code) scalar pair per
chunk — the per-iteration ``float(errs[k-1])`` sync of the seed driver is
gone.  ``chunk=1`` restores the seed cadence exactly.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from jax.experimental.shard_map import shard_map

from repro.core import backend as _backend
from repro.core.greedy import (
    GreedyResult,
    STOP_FLOOR,
    STOP_NONE,
    STOP_RANK,
    STOP_REFRESH,
    STOP_TAU,
    _validate_resident_tree,
    floor_estimate,
    imgs_orthogonalize,
    load_resident_checkpoint,
    panel_imgs_orthogonalize,
)


class DistGreedyState(NamedTuple):
    """Column-sharded greedy state (sharding noted per leaf)."""

    Q: jax.Array        # (N, max_k) REPLICATED
    R: jax.Array        # (max_k, M) col-sharded
    norms_sq: jax.Array  # (M,) col-sharded — reference residual^2
    acc: jax.Array       # (M,) col-sharded
    pivots: jax.Array    # (max_k,) replicated
    errs: jax.Array      # (max_k,) replicated
    k: jax.Array         # () replicated


def state_specs(mesh: Mesh):
    cols = P(tuple(mesh.axis_names))
    rep = P()
    return DistGreedyState(
        Q=P(None, None),
        R=P(None, tuple(mesh.axis_names)),
        norms_sq=cols,
        acc=cols,
        pivots=rep,
        errs=rep,
        k=rep,
    )


def state_shardings(mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), state_specs(mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


@jax.jit
def _column_norms_sq(S):
    # jitted: eager abs(S)**2 would materialize an S-sized temporary
    return jnp.sum(jnp.abs(S) ** 2, axis=0)


def dist_greedy_init(S: jax.Array, max_k: int, mesh: Mesh) -> DistGreedyState:
    N, M = S.shape
    rdtype = jnp.zeros((), S.dtype).real.dtype
    sh = state_shardings(mesh)
    return DistGreedyState(
        Q=jax.device_put(jnp.zeros((N, max_k), S.dtype), sh.Q),
        R=jax.device_put(jnp.zeros((max_k, M), S.dtype), sh.R),
        norms_sq=jax.device_put(
            _column_norms_sq(S).astype(rdtype), sh.norms_sq
        ),
        acc=jax.device_put(jnp.zeros((M,), rdtype), sh.acc),
        pivots=jax.device_put(jnp.zeros((max_k,), jnp.int32), sh.pivots),
        errs=jax.device_put(jnp.zeros((max_k,), rdtype), sh.errs),
        k=jax.device_put(jnp.zeros((), jnp.int32), sh.k),
    )


# --------------------------------------------- checkpoint/resume support ---
# Distributed sibling of repro.core.greedy's resident checkpoint helpers;
# DistGreedyState has no per-basis diagnostics (n_passes/rnorms), so it
# gets its own tree layout.  Leaves are gathered to host numpy on save and
# re-placed with the CURRENT mesh's shardings on restore, so a checkpoint
# written on one mesh resumes on a different device count (elastic).

_DIST_STATE_VERSION = 1


def _dist_state_tree(state: DistGreedyState, ref_sq: float, scale: float,
                     done: bool, stop: int) -> dict:
    k = int(state.k)
    return {
        "version": np.asarray(_DIST_STATE_VERSION, np.int64),
        "Q": np.asarray(jax.device_get(state.Q)),
        "R": np.asarray(jax.device_get(state.R))[:k],
        "norms_sq": np.asarray(jax.device_get(state.norms_sq)),
        "acc": np.asarray(jax.device_get(state.acc)),
        "pivots": np.asarray(jax.device_get(state.pivots)),
        "errs": np.asarray(jax.device_get(state.errs)),
        "k": np.asarray(k, np.int64),
        "ref_sq": np.asarray(ref_sq, np.float64),
        "scale": np.asarray(scale, np.float64),
        "done": np.asarray(int(done), np.int64),
        "stop": np.asarray(int(stop), np.int64),
    }


def _dist_state_from_tree(tree: dict, mesh: Mesh):
    version = int(tree["version"])
    if version != _DIST_STATE_VERSION:
        raise ValueError(
            f"distributed checkpoint version {version} != supported "
            f"{_DIST_STATE_VERSION}"
        )
    max_k = tree["Q"].shape[1]
    M = tree["norms_sq"].shape[0]
    R = np.zeros((max_k, M), tree["R"].dtype)
    R[:tree["R"].shape[0]] = tree["R"]
    sh = state_shardings(mesh)
    state = DistGreedyState(
        Q=jax.device_put(tree["Q"], sh.Q),
        R=jax.device_put(R, sh.R),
        norms_sq=jax.device_put(tree["norms_sq"], sh.norms_sq),
        acc=jax.device_put(tree["acc"], sh.acc),
        pivots=jax.device_put(tree["pivots"], sh.pivots),
        errs=jax.device_put(tree["errs"], sh.errs),
        k=jax.device_put(np.asarray(int(tree["k"]), np.int32), sh.k),
    )
    return (state, float(tree["ref_sq"]), float(tree["scale"]),
            bool(int(tree["done"])), int(tree["stop"]))


def _save_dist_checkpoint(directory: str, seq: int, state, ref_sq, scale,
                          done: bool, stop: int, keep: int = 2) -> int:
    from repro.checkpoint.io import prune_steps, save_checkpoint

    seq += 1
    save_checkpoint(_dist_state_tree(state, ref_sq, scale, done, stop),
                    directory, seq)
    prune_steps(directory, keep)
    return seq


def _axis_size(a: str):
    """Size of a mapped axis; ``psum(1, a)`` constant-folds to it and works
    on jax versions without ``jax.lax.axis_size``."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(a)
    return jax.lax.psum(1, a)


def _axis_index(axes: Sequence[str]):
    """Flattened device rank over (possibly several) mesh axes."""
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * _axis_size(a) + jax.lax.axis_index(a)
    return idx


def _axis_count(axes: Sequence[str]):
    n = 1
    for a in axes:
        n *= _axis_size(a)
    return n


def _make_local_step(axes, kappa: float, max_passes: int,
                     backend: str | None):
    """Per-device body of one distributed greedy iteration (SPMD).

    ``backend`` should already be resolved (the factories resolve it so
    their lru_cache keys on the concrete name, not on a None that would
    freeze whatever the env/default said at first build)."""

    def local_step(S_loc, state):
        # ---- local pivot search (the greedy_update fusion target) ----
        res_sq = jnp.maximum(state.norms_sq - state.acc, 0.0)  # (M_loc,)
        j_loc = jnp.argmax(res_sq)
        val_loc = res_sq[j_loc]
        m_loc = res_sq.shape[0]
        rank = _axis_index(axes)
        j_glob = rank * m_loc + j_loc

        # ---- global argmax: all_gather the (val, idx) pairs ----
        vals = jax.lax.all_gather(val_loc, axes, tiled=False)  # (P,)
        idxs = jax.lax.all_gather(j_glob, axes, tiled=False)
        vals = vals.reshape(-1)
        idxs = idxs.reshape(-1)
        win = jnp.argmax(vals)
        err = jnp.sqrt(vals[win])
        j_global = idxs[win]
        owner = win == rank

        # ---- pivot column broadcast: one psum of the masked column ----
        col = jax.lax.dynamic_slice_in_dim(S_loc, j_loc, 1, axis=1)[:, 0]
        contrib = jnp.where(owner, col, jnp.zeros_like(col))
        v = jax.lax.psum(contrib, axes)  # (N,) replicated

        # ---- replicated orthogonalization (no master core) ----
        q, _, rnorm, _ = imgs_orthogonalize(
            v, state.Q, kappa=kappa, max_passes=max_passes, backend=backend
        )

        # ---- fused Eq. (6.3) update over the local shard ----
        c, acc, _, _ = _backend.pivot_update(
            q, S_loc, state.acc, state.norms_sq, backend=backend
        )
        k = state.k
        return DistGreedyState(
            Q=state.Q.at[:, k].set(q),
            R=state.R.at[k, :].set(c),
            norms_sq=state.norms_sq,
            acc=acc,
            pivots=state.pivots.at[k].set(j_global.astype(jnp.int32)),
            errs=state.errs.at[k].set(err),
            k=k + 1,
        )

    return local_step


def make_dist_greedy_step(
    mesh: Mesh, kappa: float = 2.0, max_passes: int = 3,
    backend: str | None = None,
):
    """Build the jitted SPMD greedy step for a mesh (cached per signature)."""
    return _make_dist_greedy_step(
        mesh, kappa, max_passes, _backend.resolve_backend(backend)
    )


@functools.lru_cache(maxsize=None)
def _make_dist_greedy_step(mesh, kappa, max_passes, backend):
    axes = tuple(mesh.axis_names)
    specs = state_specs(mesh)
    s_spec = P(None, axes)

    sharded = shard_map(
        _make_local_step(axes, kappa, max_passes, backend),
        mesh=mesh,
        in_specs=(s_spec, specs),
        out_specs=specs,
        check_rep=False,
    )
    return jax.jit(sharded, donate_argnums=(1,))


def make_dist_greedy_chunk(
    mesh: Mesh, chunk: int, kappa: float = 2.0, max_passes: int = 3,
    backend: str | None = None, check_refresh: bool = True,
    donate: bool = True,
):
    """Build the jitted device-resident chunk for a mesh.

    Runs up to ``chunk`` SPMD iterations (collectives included) inside one
    ``lax.while_loop``; stops early on the seed driver's host events —
    checked in ITS order (tau before rank guard) — and reports them as a
    replicated ``(state, n_done, stop_code)`` so the host syncs two scalars
    per chunk instead of one error float per basis vector.
    """
    return _make_dist_greedy_chunk(
        mesh, chunk, kappa, max_passes,
        _backend.resolve_backend(backend), check_refresh, donate,
    )


@functools.lru_cache(maxsize=None)
def _make_dist_greedy_chunk(mesh, chunk, kappa, max_passes, backend,
                            check_refresh, donate):
    axes = tuple(mesh.axis_names)
    specs = state_specs(mesh)
    s_spec = P(None, axes)
    local_step = _make_local_step(axes, kappa, max_passes, backend)

    def local_chunk(S_loc, state, tau, scale, ref_sq, refresh_safety):
        max_k = state.Q.shape[1]
        eps = jnp.finfo(state.norms_sq.dtype).eps

        def cond(carry):
            st, n, stop = carry
            return (stop == STOP_NONE) & (n < chunk) & (st.k < max_k)

        def body(carry):
            st, n, _ = carry
            st = local_step(S_loc, st)
            err = st.errs[st.k - 1]
            refresh_hit = check_refresh & (
                err * err < refresh_safety * eps * ref_sq
            )
            stop = jnp.where(
                err < tau,
                STOP_TAU,
                jnp.where(err < 50.0 * eps * scale, STOP_RANK,
                          jnp.where(refresh_hit, STOP_REFRESH, STOP_NONE)),
            ).astype(jnp.int32)
            return (st, n + 1, stop)

        state, n_done, stop = jax.lax.while_loop(
            cond, body,
            (state, jnp.asarray(0, jnp.int32),
             jnp.asarray(STOP_NONE, jnp.int32)),
        )
        return state, n_done, stop

    sharded = shard_map(
        local_chunk,
        mesh=mesh,
        in_specs=(s_spec, specs, P(), P(), P(), P()),
        out_specs=(specs, P(), P()),
        check_rep=False,
    )
    # donate=False supports repeated application to one state (benchmarks)
    return jax.jit(sharded, donate_argnums=(1,) if donate else ())


# ------------------------------------------------- blocked (BLAS-3) sweep --


def _make_local_block_chunk(axes, chunk, p, kappa, max_passes, backend,
                            check_refresh, panel=True):
    """Per-device body of up to ``chunk`` BLOCKED greedy iterations (SPMD).

    One iteration selects the global top-p residual columns (local top-p +
    all-gather of the (value, column) pairs — the paper's
    ``MPI_Allreduce(MAXLOC)`` generalized to p winners), fetches the p
    pivot columns with one owner-masked psum, orthogonalizes them jointly
    (by default through the BLAS-3 panel path
    :func:`repro.core.greedy.panel_imgs_orthogonalize`, replicated on
    every device exactly like the stepwise driver's redundant IMGS;
    in-block rank guard — rejected candidates leave zero "hole" columns),
    and updates the LOCAL shard's residuals with ONE fused panel sweep
    (:func:`repro.core.backend.block_sweep`) — one read of the shard per p
    bases.

    The tau gate is mask-based rather than branch-based so no collective
    sits inside a ``lax.cond``: a converged iteration computes a zero
    panel (exact no-ops everywhere) and reports STOP_TAU without
    advancing ``k``.
    """

    def local_chunk(S_loc, state, tau, scale, ref_sq, refresh_safety):
        max_slots = state.Q.shape[1]
        eps = jnp.finfo(state.norms_sq.dtype).eps
        rdt = state.norms_sq.dtype

        def body(carry):
            st, n, _ = carry
            # ---- global top-p selection ----
            res_sq = jnp.maximum(st.norms_sq - st.acc, 0.0)
            l_vals, l_idx = jax.lax.top_k(res_sq, p)     # local top-p
            m_loc = res_sq.shape[0]
            rank = _axis_index(axes)
            g_idx = rank * m_loc + l_idx
            vals = jax.lax.all_gather(l_vals, axes).reshape(-1)  # (P*p,)
            idxs = jax.lax.all_gather(g_idx, axes).reshape(-1)
            top_vals, top_pos = jax.lax.top_k(vals, p)           # global
            top_idx = idxs[top_pos]
            err = jnp.sqrt(top_vals[0])
            go = err >= tau

            # ---- fetch the p pivot columns: one (N, p) masked psum ----
            owned = (top_idx // m_loc == rank) & go
            local_cols = jnp.where(
                owned[None, :],
                jnp.take(S_loc, top_idx % m_loc, axis=1),
                jnp.zeros((S_loc.shape[0], p), S_loc.dtype),
            )
            V = jax.lax.psum(local_cols, axes)           # (N, p) replicated

            # ---- joint IMGS with the in-block rank guard ----
            slots = st.k
            Q = st.Q
            if panel:
                Qnew, oks_p, _, _ = panel_imgs_orthogonalize(
                    V, Q, kappa=kappa, max_passes=max_passes,
                    thresh=50.0 * eps * scale, backend=backend,
                )
                # converged iterations (~go) compute a zero panel: V is
                # all-zero (the owner mask includes go), so every rnorm
                # is 0 and the guard already rejected — the explicit
                # mask keeps the no-op invariant obvious
                oks_arr = oks_p & go
                Qnew = jnp.where(go, Qnew, jnp.zeros_like(Qnew))
                Q = jax.lax.dynamic_update_slice(
                    Q, Qnew, (jnp.zeros((), slots.dtype), slots)
                )
            else:
                qs, oks = [], []
                for i in range(p):
                    q, _, rnorm, _ = imgs_orthogonalize(
                        V[:, i], Q, kappa=kappa, max_passes=max_passes,
                        backend=backend,
                    )
                    ok = go & (rnorm > 50.0 * eps * scale)
                    q = jnp.where(ok, q, jnp.zeros_like(q))
                    Q = Q.at[:, slots + i].set(q)
                    qs.append(q)
                    oks.append(ok)
                Qnew = jnp.stack(qs, axis=1)  # (N, p), rejected cols zero
                oks_arr = jnp.asarray(oks)
            # ---- ONE fused pass over the local shard ----
            C, acc = _backend.block_sweep(Qnew, S_loc, st.acc,
                                          backend=backend)
            st = st._replace(
                Q=Q,
                R=jax.lax.dynamic_update_slice_in_dim(st.R, C, slots,
                                                      axis=0),
                acc=acc,
                pivots=jax.lax.dynamic_update_slice_in_dim(
                    st.pivots,
                    jnp.where(oks_arr, top_idx, -1).astype(jnp.int32),
                    slots, axis=0,
                ),
                errs=jax.lax.dynamic_update_slice_in_dim(
                    st.errs,
                    jnp.sqrt(jnp.maximum(top_vals, 0.0)).astype(rdt),
                    slots, axis=0,
                ),
                k=jnp.where(go, slots + p, slots),
            )
            n_ok = jnp.sum(oks_arr.astype(jnp.int32))
            res_loc = jnp.maximum(jnp.max(st.norms_sq - st.acc), 0.0)
            res_after = jax.lax.pmax(res_loc, axes)
            # post-block tau stop BEFORE the refresh trigger — the
            # rb_greedy family precedence (see the resident blocked
            # chunk): a floored-but-unconverged build must not refresh
            # forever
            tau_hit = res_after < tau * tau
            refresh_hit = check_refresh & (
                res_after < refresh_safety * eps * ref_sq
            )
            stop = jnp.where(
                ~go, STOP_TAU,
                jnp.where(n_ok == 0, STOP_RANK,
                          jnp.where(tau_hit, STOP_TAU,
                                    jnp.where(refresh_hit, STOP_REFRESH,
                                              STOP_NONE))),
            ).astype(jnp.int32)
            return (st, n + 1, stop)

        def cond(carry):
            st, n, stop = carry
            return (stop == STOP_NONE) & (n < chunk) & (st.k + p <= max_slots)

        state, n_done, stop = jax.lax.while_loop(
            cond, body,
            (state, jnp.asarray(0, jnp.int32),
             jnp.asarray(STOP_NONE, jnp.int32)),
        )
        return state, n_done, stop

    return local_chunk


def make_dist_block_greedy_chunk(
    mesh: Mesh, chunk: int, p: int, kappa: float = 2.0, max_passes: int = 3,
    backend: str | None = None, check_refresh: bool = True,
    donate: bool = True, panel: bool = True,
):
    """Build the jitted device-resident BLOCKED chunk for a mesh: up to
    ``chunk`` blocked SPMD iterations (collectives included) per host
    round-trip, p bases per shard read."""
    return _make_dist_block_greedy_chunk(
        mesh, chunk, p, kappa, max_passes,
        _backend.resolve_backend(backend), check_refresh, donate, panel,
    )


@functools.lru_cache(maxsize=None)
def _make_dist_block_greedy_chunk(mesh, chunk, p, kappa, max_passes,
                                  backend, check_refresh, donate, panel):
    axes = tuple(mesh.axis_names)
    specs = state_specs(mesh)
    s_spec = P(None, axes)

    sharded = shard_map(
        _make_local_block_chunk(axes, chunk, p, kappa, max_passes, backend,
                                check_refresh, panel),
        mesh=mesh,
        in_specs=(s_spec, specs, P(), P(), P(), P()),
        out_specs=(specs, P(), P()),
        check_rep=False,
    )
    # donate=False supports repeated application to one state (benchmarks)
    return jax.jit(sharded, donate_argnums=(1,) if donate else ())


@functools.lru_cache(maxsize=None)
def make_dist_refresh(mesh: Mesh):
    """Exact residual recomputation (deep-tolerance mode), column-local."""
    axes = tuple(mesh.axis_names)
    specs = state_specs(mesh)
    s_spec = P(None, axes)

    def local_refresh(S_loc, state):
        C = state.Q.conj().T @ S_loc
        E = S_loc - state.Q @ C
        res = jnp.sum(jnp.abs(E) ** 2, axis=0).astype(state.norms_sq.dtype)
        return state._replace(norms_sq=res, acc=jnp.zeros_like(state.acc))

    sharded = shard_map(
        local_refresh, mesh=mesh, in_specs=(s_spec, specs),
        out_specs=specs, check_rep=False,
    )
    return jax.jit(sharded, donate_argnums=(1,))


def distributed_greedy(
    S,
    tau: float,
    max_k: int,
    mesh: Mesh,
    callback=None,
    refresh: str = "auto",
    refresh_safety: float = 100.0,
    kappa: float = 2.0,
    max_passes: int = 3,
    chunk: int = 16,
    backend: str | None = None,
    block_p: int = 1,
    panel_ortho: bool = True,
    checkpoint_dir: str | None = None,
    resume: bool = False,
) -> GreedyResult:
    """Driver mirroring :func:`repro.core.greedy.rb_greedy` on a mesh.

    ``S`` should be placed with columns sharded over all mesh axes (the
    driver places it if not).  Column count must divide the device count.

    Chunked device-resident hot loop: ``chunk`` SPMD iterations run inside
    one jitted ``lax.while_loop`` per host round-trip.  ``callback(state)``
    fires once per chunk (state arrays carry the per-step history); pass
    ``chunk=1`` for the seed per-iteration cadence.  With a callback set
    the chunk does not donate state buffers (retained checkpoint states
    stay valid); see :func:`repro.core.greedy.rb_greedy` for that and for
    the on-device stop-threshold dtype caveat.

    ``block_p > 1`` runs the BLOCKED sweep (the distributed sibling of
    :mod:`repro.core.block_greedy`): global top-p pivot selection per
    iteration (the paper's ``MPI_Allreduce(MAXLOC)`` generalized to p
    winners) and one fused panel GEMM per shard read — each device reads
    its S shard once per p bases instead of once per basis.  The usual
    blocked trade-off applies (pivot staleness: a few extra bases on
    fast-decaying families; rank-rejected in-block candidates are
    compacted away, so ``k`` counts accepted bases).  ``panel_ortho``
    (default True) runs each block's replicated orthogonalization through
    the BLAS-3 panel path (see :mod:`repro.core.block_greedy`).

    ``checkpoint_dir``/``resume`` mirror
    :func:`repro.core.greedy.rb_greedy` (state + done/stop persisted after
    each chunk's stop handling; leaves are saved as host numpy and
    re-placed with THIS mesh's shardings on resume, so a run restores onto
    a different device count).

    ``S`` may be anything :func:`repro.data.providers.as_provider`
    accepts; non-array sources are materialized before placement.
    """
    from repro.data.providers import materialize_source

    S = materialize_source(S)
    s_sharding = NamedSharding(mesh, P(None, tuple(mesh.axis_names)))
    if getattr(S, "sharding", None) != s_sharding:
        S = jax.device_put(S, s_sharding)
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    if block_p < 1:
        raise ValueError(f"block_p must be >= 1, got {block_p}")
    if block_p > 1:
        return _distributed_block_greedy(
            S, tau, max_k, mesh, block_p, callback=callback,
            refresh=refresh, refresh_safety=refresh_safety, kappa=kappa,
            max_passes=max_passes, chunk=chunk, backend=backend,
            panel=panel_ortho, checkpoint_dir=checkpoint_dir, resume=resume,
        )

    chunk_fn = make_dist_greedy_chunk(
        mesh, chunk, kappa, max_passes, backend,
        check_refresh=(refresh == "auto"),
        donate=(callback is None),
    )
    refresh_fn = make_dist_refresh(mesh)
    state = dist_greedy_init(S, max_k, mesh)

    rdt = state.norms_sq.dtype
    eps = float(jnp.finfo(rdt).eps)
    ref_sq = float(jnp.max(state.norms_sq))
    scale = ref_sq ** 0.5
    done = False
    final_stop = STOP_NONE
    seq = 0
    if checkpoint_dir is not None:
        from repro.checkpoint.io import latest_step

        tree = load_resident_checkpoint(checkpoint_dir) if resume else None
        if tree is not None:
            _validate_resident_tree(tree, S.shape[0], S.shape[1], max_k,
                                    S.dtype, "resume checkpoint")
            state, ref_sq, scale, done, final_stop = \
                _dist_state_from_tree(tree, mesh)
        seq = latest_step(checkpoint_dir) or 0
    # invariant thresholds device-placed once; only ref_sq changes (refresh)
    tau_d = jnp.asarray(tau, rdt)
    scale_d = jnp.asarray(scale, rdt)
    safety_d = jnp.asarray(refresh_safety, rdt)
    ref_sq_d = jnp.asarray(ref_sq, rdt)
    k = int(state.k)
    while not done and k < max_k:
        state, n_done, stop = chunk_fn(
            S, state, tau_d, scale_d, ref_sq_d, safety_d,
        )
        k = int(state.k)
        if callback is not None:
            callback(state)
        stop = int(stop)
        if stop == STOP_TAU:
            k -= 1
            state = state._replace(
                k=jnp.asarray(k, jnp.int32),
                Q=state.Q.at[:, k].set(0),
                pivots=state.pivots.at[k].set(-1),
            )
            done, final_stop = True, STOP_TAU
        elif stop == STOP_RANK:
            k -= 1
            state = state._replace(k=jnp.asarray(k, jnp.int32))
            done, final_stop = True, STOP_RANK
        elif stop == STOP_REFRESH:
            state = refresh_fn(S, state)
            ref_sq = max(float(jnp.max(state.norms_sq)), 1e-300)
            ref_sq_d = jnp.asarray(ref_sq, rdt)
            if ref_sq ** 0.5 < tau:
                done, final_stop = True, STOP_TAU
            elif ref_sq ** 0.5 <= floor_estimate(eps, scale, k):
                done, final_stop = True, STOP_FLOOR
        if not done and k >= max_k:
            done = True  # ran to capacity; final_stop stays STOP_NONE
        # (no n_done check: the chunk cond guarantees >= 1 iteration, and
        # reading it back would add a host sync per chunk)
        if checkpoint_dir is not None:
            seq = _save_dist_checkpoint(
                checkpoint_dir, seq, state, ref_sq, scale, done, final_stop)
    return GreedyResult(
        Q=state.Q, R=state.R, pivots=state.pivots, errs=state.errs,
        k=state.k, n_ortho_passes=jnp.zeros_like(state.pivots),
        rnorms=jnp.zeros_like(state.errs),
        stop=final_stop,
    )


def _distributed_block_greedy(
    S,
    tau: float,
    max_k: int,
    mesh: Mesh,
    p: int,
    callback=None,
    refresh: str = "auto",
    refresh_safety: float = 100.0,
    kappa: float = 2.0,
    max_passes: int = 3,
    chunk: int = 4,
    backend: str | None = None,
    panel: bool = True,
    checkpoint_dir: str | None = None,
    resume: bool = False,
) -> GreedyResult:
    """Blocked distributed driver body (see :func:`distributed_greedy`,
    ``block_p > 1``).  ``chunk`` counts BLOCKS per host round-trip;
    ``callback(state)`` fires once per chunk (non-donating, as in the
    stepwise driver)."""
    N, M = S.shape
    n_dev = int(mesh.devices.size)
    m_loc = M // n_dev
    p = min(p, min(N, M))
    if p > m_loc:
        raise ValueError(
            f"block_p={p} exceeds the per-device column count {m_loc} "
            f"(M={M} over {n_dev} devices) — the local top-p selection "
            f"needs p candidates per shard"
        )
    max_k = min(max_k, N, M)  # the accepted-basis cap
    max_slots = min(max_k + p, min(N, M) + p)  # + hole headroom
    chunk_fn = make_dist_block_greedy_chunk(
        mesh, chunk, p, kappa, max_passes, backend,
        check_refresh=(refresh == "auto"), donate=(callback is None),
        panel=panel,
    )
    refresh_fn = make_dist_refresh(mesh)
    state = dist_greedy_init(S, max_slots, mesh)

    rdt = state.norms_sq.dtype
    eps = float(jnp.finfo(rdt).eps)
    ref_sq = float(jnp.max(state.norms_sq))
    scale = ref_sq ** 0.5  # fixed global column scale for the rank guard
    done = False
    final_stop = STOP_NONE
    seq = 0
    if checkpoint_dir is not None:
        from repro.checkpoint.io import latest_step

        tree = load_resident_checkpoint(checkpoint_dir) if resume else None
        if tree is not None:
            _validate_resident_tree(tree, N, M, max_slots, S.dtype,
                                    "resume checkpoint")
            state, ref_sq, scale, done, final_stop = \
                _dist_state_from_tree(tree, mesh)
        seq = latest_step(checkpoint_dir) or 0
    tau_d = jnp.asarray(tau, rdt)
    scale_d = jnp.asarray(scale, rdt)
    safety_d = jnp.asarray(refresh_safety, rdt)
    ref_sq_d = jnp.asarray(ref_sq, rdt)
    while not done and int(state.k) + p <= max_slots:
        state, n_done, stop = chunk_fn(
            S, state, tau_d, scale_d, ref_sq_d, safety_d,
        )
        if callback is not None:
            callback(state)
        stop = int(stop)
        if stop == STOP_TAU or stop == STOP_RANK:
            done, final_stop = True, stop
        elif stop == STOP_REFRESH:
            state = refresh_fn(S, state)
            ref_sq = max(float(jnp.max(state.norms_sq)), 1e-300)
            ref_sq_d = jnp.asarray(ref_sq, rdt)
            if ref_sq ** 0.5 < tau:
                done, final_stop = True, STOP_TAU
            elif ref_sq ** 0.5 <= floor_estimate(eps, scale, int(state.k)):
                done, final_stop = True, STOP_FLOOR
        if not done and int(state.k) + p > max_slots:
            done = True  # out of slots; final_stop stays STOP_NONE
        if checkpoint_dir is not None:
            seq = _save_dist_checkpoint(
                checkpoint_dir, seq, state, ref_sq, scale, done, final_stop)
    # compact holes + cap at max_k: shared with the resident blocked driver
    from repro.core.block_greedy import _compact_result

    return _compact_result(state, max_k, final_stop)
