"""Optimal RRQR (Theorem 5.1) and its exactness property.

Theorem 5.1 constructs a QR factorization whose rank-k projection error is
*exactly* ``sigma_{k+1}`` — the POD optimum.  The construction:

    S = V Sigma W^T              (SVD)
    QR_hat = qr(Sigma_k W_k^T)   (QR of the k x M top block)
    Q_k = V_k @ Q_hat

The permutation is the identity.  This is the theoretical bridge between the
SVD and QR worlds; it is not a cheap algorithm (it needs an SVD), but it
proves the *existence* target the practical algorithms (Algs. 2/3) aim for.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class OptimalRRQR(NamedTuple):
    Qk: jax.Array      # (N, k) basis with |S - Qk Qk^H S|_2 = sigma_{k+1}
    R: jax.Array       # (k, M) triangular factor rows (= R_hat)
    sigmas: jax.Array  # singular values of S


def optimal_rrqr(S: jax.Array, k: int) -> OptimalRRQR:
    """Construct the Theorem-5.1 optimal RRQR of rank k."""
    V, sig, Wh = jnp.linalg.svd(S, full_matrices=False)
    # Sigma_k W_k^T  is (k, M): the top-k rows of Sigma @ W^T.
    top = sig[:k, None].astype(S.dtype) * Wh[:k, :]
    Qhat, Rhat = jnp.linalg.qr(top.conj().T, mode="reduced")  # (M,k),(k,k)
    # qr of top^H gives top = Rhat^H Qhat^H; we want top = Q_script R_script
    # with Q_script (k,k) orthogonal: use qr of top directly on the k x M
    # matrix via its transpose-free form below instead.
    del Qhat, Rhat
    # jnp.linalg.qr supports wide matrices in reduced mode: top = Qs Rs with
    # Qs (k, k), Rs (k, M).
    Qs, Rs = jnp.linalg.qr(top, mode="reduced")
    Qk = V[:, :k] @ Qs
    return OptimalRRQR(Qk=Qk, R=Rs, sigmas=sig)


def rrqr_error_2norm(S: jax.Array, Qk: jax.Array) -> jax.Array:
    """|S - Qk Qk^H S|_2 (should equal sigma_{k+1} for the optimal RRQR)."""
    E = S - Qk @ (Qk.conj().T @ S)
    return jnp.linalg.norm(E, ord=2)
