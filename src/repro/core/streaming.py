"""Out-of-core tile-streamed RB-greedy over snapshot providers.

:func:`rb_greedy_streamed` is an exact refactor of the in-memory drivers in
:mod:`repro.core.greedy` for snapshot matrices that never fit on device (the
paper's headline scenario: a dense complex 10,000 x 3,276,800 matrix,
~0.5 TB, Sec. 6.1.1).  Per iteration it sweeps column tiles of the matrix
through the SAME fused backend primitives as the resident drivers:

  per tile      the Eq.-(6.3) pivot sweep (:func:`repro.core.backend.
                pivot_update`): ``c_t = q^H S_t``, ``acc_t += |c_t|^2``,
                plus the tile's residual (max, argmax) — produced in the
                same fused pass,
  across tiles  a running (value, global column) max-loc reduction — the
                single-machine analogue of the ``MPI_Allreduce(MAXLOC)``
                the paper's code performs across ranks (Sec. 6.1.3),
  per pivot     :func:`repro.core.greedy.imgs_orthogonalize` against the
                device-resident basis Q — bit-identical to the in-memory
                drivers because Q and the pivot column are the same arrays.

``block_p > 1`` enables the BLOCKED mode (the streamed sibling of
:mod:`repro.core.block_greedy`): each sweep carries a PANEL of p pending
basis vectors through :func:`repro.core.backend.block_sweep` and folds a
top-p candidate list across tiles instead of a single max-loc, so every
host->device tile transfer is amortized over p bases.  The stream is
transfer-bound (BENCH_streaming.json), which makes this the single biggest
lever on streamed-build overhead; the cost is the same pivot staleness as
the resident blocked driver (picks 2..p of a block are selected against
residuals that ignore picks 1..i-1 — a few extra bases on fast-decaying
families, rank-guarded "holes" compacted away at the end).

Tile traffic is double-buffered: while one tile's pass runs on device, the
next tile's host read + ``jax.device_put`` is issued (jax dispatch is
async), hiding the host<->device copies that otherwise dominate streamed
builds.  Only Q (N x max_k), the p pending panel columns and two tiles
(N x tile_m each, current + prefetched) are ever device-resident;
the Eq.-(6.3) residual caches (``norms_sq``, ``acc``: M reals each) and
the optional R factor live on host.  Peak device memory is
O(N * (max_k + block_p + 2 * tile_m)) — independent of M.

Stop semantics (tau drop, rank guard, Eq.-(6.3) refresh) replicate
:func:`repro.core.greedy.rb_greedy_stepwise` exactly at ``block_p=1`` and
the chunked blocked driver's semantics at ``block_p>1``; the parity suite
(tests/test_streaming.py) asserts pivot-for-pivot agreement across tile
sizes, dtypes and providers.

Mid-build checkpointing persists the full streaming state — tile cursor,
pending panel, residual caches — through :mod:`repro.checkpoint.io`; a
killed build resumes from the last completed tile, not the last basis.
"""

from __future__ import annotations

import functools
import math
import os
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as _backend
from repro.core.greedy import (
    STOP_FLOOR,
    STOP_NONE,
    STOP_RANK,
    STOP_TAU,
    floor_estimate,
    imgs_orthogonalize,
    panel_imgs_orthogonalize,
)
from repro.data.providers import SnapshotProvider, as_provider

# v2: blocked streaming — the scalar pending/max-loc fields became
# width-block_p arrays and block_p joined the tiling invariants.  v1
# (stepwise) checkpoints are lifted on load (see _StreamState._lift_v1).
_STATE_VERSION = 2


class StreamedGreedyResult(NamedTuple):
    """Result of the streamed greedy build (field names match
    :class:`repro.core.greedy.GreedyResult`).

    Attributes:
      Q:      (N, max_k) device array, orthonormal basis; columns >= k zero.
      R:      (max_k, M) host array ``R[j] = q_j^H S`` in original column
              order, or ``None`` when built with ``keep_R=False`` (R costs
              O(max_k * M) host memory — the one result piece that scales
              with M).
      pivots: (max_k,) int32 host array; entries >= k are -1.
      errs:   (max_k,) greedy error before adding basis j (real dtype).
      k:      number of accepted bases.
      n_ortho_passes, rnorms: per-basis iterated-GS diagnostics, as in the
              in-memory drivers.
      tile_m: tile width the build used; n_tiles: ceil(M / tile_m).
      block_p: pivots per sweep the build used (1 = stepwise streaming).
      stop: why the build terminated (repro.core.greedy STOP_* code).
    """

    Q: jax.Array
    R: Optional[np.ndarray]
    pivots: np.ndarray
    errs: np.ndarray
    k: int
    n_ortho_passes: np.ndarray
    rnorms: np.ndarray
    tile_m: int
    n_tiles: int
    block_p: int = 1
    stop: int = 0


@functools.partial(jax.jit, static_argnames=("kt",))
def _tile_init(T: jax.Array, kt: int = 1):
    """Column norms^2 of one tile + the tile's top-kt (values, cols) — the
    init pass's contribution to the first block's top-p fold."""
    n = jnp.sum(jnp.abs(T) ** 2, axis=0)
    tv, ti = jax.lax.top_k(n, kt)
    return n, tv, ti.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("backend",))
def _tile_sweep(q, T, acc_t, norms_t, backend: str):
    """One tile's Eq.-(6.3) sweep through the fused backend primitive
    (the block_p=1 hot path)."""
    return _backend.pivot_update(q, T, acc_t, norms_t, backend=backend)


@functools.partial(jax.jit, static_argnames=("kt", "backend"))
def _tile_block_sweep(P, T, acc_t, norms_t, kt: int, backend: str):
    """One tile's blocked Eq.-(6.3) panel sweep + the tile's top-kt
    residual candidates, through the fused backend primitive."""
    C, acc_out = _backend.block_sweep(P, T, acc_t, backend=backend)
    res = norms_t - acc_out
    tv, ti = jax.lax.top_k(res, kt)
    return C, acc_out, tv, ti.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("kt",))
def _tile_refresh(Q: jax.Array, T: jax.Array, kt: int = 1):
    """Exact residual^2 of one tile against Q (zero columns are no-ops) —
    the tile-local form of :func:`repro.core.greedy.greedy_refresh`, plus
    the tile's top-kt contribution to the next block's candidate fold."""
    C = Q.conj().T @ T
    E = T - Q @ C
    res = jnp.sum(jnp.abs(E) ** 2, axis=0)
    tv, ti = jax.lax.top_k(res, kt)
    return res, tv, ti.astype(jnp.int32)


@jax.jit
def _commit_panel(Q, P, slots):
    """Write the pending panel's columns into the basis at ``slots``."""
    return jax.lax.dynamic_update_slice(Q, P, (0, slots))


_jit_ortho = jax.jit(
    imgs_orthogonalize, static_argnames=("kappa", "max_passes", "backend")
)

_jit_panel_ortho = jax.jit(
    panel_imgs_orthogonalize,
    static_argnames=("kappa", "max_passes", "backend"),
)


def _merge_topk(vals, cols, new_vals, new_cols, p: int):
    """Host-side fold of per-tile top-k candidates into the running top-p.

    Sorts by (-value, column): exact value ties keep the EARLIEST column,
    which matches both ``jax.lax.top_k``'s first-occurrence tie-break on
    the full residual vector and the v1 strict-``>`` scalar fold.
    """
    v = np.concatenate([vals, np.asarray(new_vals, np.float64)])
    c = np.concatenate([cols, np.asarray(new_cols, np.int64)])
    order = np.lexsort((c, -v))[:p]
    return v[order], c[order]


class _StreamState:
    """Host-side streaming state: everything needed to resume mid-build.

    ``pending == 1`` means a block of pivots has been selected and
    orthogonalized but its Eq.-(6.3) sweep has only covered tiles
    [0, cursor) — resume continues the sweep (acc/R for swept tiles are
    already updated; the sweep is deterministic given the checkpointed acc,
    so replaying the remaining tiles reproduces the uninterrupted build
    exactly).

    ``k`` counts occupied SLOTS (blocked builds can leave rank-rejected
    zero "hole" columns inside a block); ``n_acc`` counts accepted bases.
    At ``block_p == 1`` the two always agree (a rejected single candidate
    stops the build before commit).
    """

    __slots__ = (
        "Q", "R", "norms_sq", "acc", "pivots", "errs", "rnorms", "n_passes",
        "k", "n_acc", "ref_sq", "scale", "best_vals", "best_cols",
        "pending", "cursor", "pending_Q", "pending_cols", "pending_errs",
        "pending_rnorms", "pending_npass", "pending_ok", "sweep_vals",
        "sweep_cols", "seq", "tile_m", "block_p", "backend", "done", "stop",
    )

    def to_tree(self) -> dict:
        """Flat numpy pytree for :func:`repro.checkpoint.io.save_checkpoint`."""
        tree = {
            "version": np.asarray(_STATE_VERSION, np.int64),
            # cursor/pending are expressed in tile units and the pending
            # panel in block_p units, so a resume MUST use the same tiling
            # AND block width — persisted for validation, as is the
            # backend (a mid-sweep resume under a different backend would
            # mix float summation orders within one acc update).
            "tile_m": np.asarray(self.tile_m, np.int64),
            "block_p": np.asarray(self.block_p, np.int64),
            "backend": np.asarray(self.backend),
            "Q": np.asarray(jax.device_get(self.Q)),
            "norms_sq": self.norms_sq,
            "acc": self.acc,
            "pivots": self.pivots,
            "errs": self.errs,
            "rnorms": self.rnorms,
            "n_passes": self.n_passes,
            "k": np.asarray(self.k, np.int64),
            "n_acc": np.asarray(self.n_acc, np.int64),
            "ref_sq": np.asarray(self.ref_sq, np.float64),
            "scale": np.asarray(self.scale, np.float64),
            "best_vals": np.asarray(self.best_vals, np.float64),
            "best_cols": np.asarray(self.best_cols, np.int64),
            "pending": np.asarray(self.pending, np.int64),
            "cursor": np.asarray(self.cursor, np.int64),
            "pending_Q": np.asarray(jax.device_get(self.pending_Q)),
            "pending_cols": np.asarray(self.pending_cols, np.int64),
            "pending_errs": np.asarray(self.pending_errs, np.float64),
            "pending_rnorms": np.asarray(self.pending_rnorms, np.float64),
            "pending_npass": np.asarray(self.pending_npass, np.int64),
            "pending_ok": np.asarray(self.pending_ok, np.int64),
            "sweep_vals": np.asarray(self.sweep_vals, np.float64),
            "sweep_cols": np.asarray(self.sweep_cols, np.int64),
            "seq": np.asarray(self.seq, np.int64),
            # Terminal verdict.  Every other loop exit is a pure function
            # of the fields above, but the floor-stop is not (its residual
            # still sits ABOVE tau) — without a persisted done/stop a
            # resume of a floor-stopped build would keep adding bases.
            "done": np.asarray(self.done, np.int64),
            "stop": np.asarray(self.stop, np.int64),
        }
        if self.R is not None:
            # Only the rows written so far (committed slots + the pending
            # sweep's partial rows): checkpoint traffic scales with k*M,
            # not max_k*M.  keep_R=False avoids R checkpoint traffic
            # entirely.
            tree["R"] = self.R[:self.k + self.pending * self.block_p]
        return tree

    @staticmethod
    def _lift_v1(tree: dict) -> dict:
        """Lift a v1 (stepwise-only) checkpoint to the v2 layout: the
        scalar pending/max-loc fields map 1:1 onto the width-1 arrays, so
        a long-running pre-blocked build resumes losslessly."""
        out = dict(tree)
        out["version"] = np.asarray(_STATE_VERSION, np.int64)
        out["block_p"] = np.asarray(1, np.int64)
        out["n_acc"] = tree["k"]  # p=1 never leaves holes
        out["best_vals"] = np.asarray([tree["best_val"]], np.float64)
        out["best_cols"] = np.asarray([tree["best_col"]], np.int64)
        out["pending_Q"] = np.asarray(tree["pending_q"])[:, None]
        out["pending_cols"] = np.asarray([tree["pending_col"]], np.int64)
        out["pending_errs"] = np.asarray([tree["pending_err"]], np.float64)
        out["pending_rnorms"] = np.asarray([tree["pending_rnorm"]],
                                           np.float64)
        out["pending_npass"] = np.asarray([tree["pending_npass"]], np.int64)
        # v1 only set `pending` after the rank guard passed
        out["pending_ok"] = np.asarray([tree["pending"]], np.int64)
        out["sweep_vals"] = np.asarray([tree["sweep_val"]], np.float64)
        out["sweep_cols"] = np.asarray([tree["sweep_col"]], np.int64)
        for old in ("best_val", "best_col", "pending_q", "pending_col",
                    "pending_err", "pending_rnorm", "sweep_val",
                    "sweep_col"):
            out.pop(old, None)
        return out

    @classmethod
    def from_tree(cls, tree: dict) -> "_StreamState":
        version = int(tree["version"])
        if version == 1:
            tree = cls._lift_v1(tree)
            version = _STATE_VERSION
        if version != _STATE_VERSION:
            raise ValueError(
                f"streaming checkpoint version {version} != supported "
                f"{_STATE_VERSION}"
            )
        st = cls()
        st.tile_m = int(tree["tile_m"])
        st.block_p = int(tree["block_p"])
        st.backend = str(tree["backend"])
        st.Q = jnp.asarray(tree["Q"])
        max_k = st.Q.shape[1]
        M = tree["norms_sq"].shape[0]
        R_rows = tree.get("R")
        if R_rows is not None:
            st.R = np.zeros((max_k, M), R_rows.dtype)
            st.R[:R_rows.shape[0]] = R_rows
        else:
            st.R = None
        st.norms_sq = tree["norms_sq"]
        st.acc = tree["acc"]
        st.pivots = tree["pivots"]
        st.errs = tree["errs"]
        st.rnorms = tree["rnorms"]
        st.n_passes = tree["n_passes"]
        st.k = int(tree["k"])
        st.n_acc = int(tree["n_acc"])
        st.ref_sq = float(tree["ref_sq"])
        st.scale = float(tree["scale"])
        st.best_vals = np.asarray(tree["best_vals"], np.float64)
        st.best_cols = np.asarray(tree["best_cols"], np.int64)
        st.pending = int(tree["pending"])
        st.cursor = int(tree["cursor"])
        st.pending_Q = jnp.asarray(tree["pending_Q"])
        st.pending_cols = np.asarray(tree["pending_cols"], np.int64)
        st.pending_errs = np.asarray(tree["pending_errs"], np.float64)
        st.pending_rnorms = np.asarray(tree["pending_rnorms"], np.float64)
        st.pending_npass = np.asarray(tree["pending_npass"], np.int64)
        st.pending_ok = np.asarray(tree["pending_ok"], np.int64)
        st.sweep_vals = np.asarray(tree["sweep_vals"], np.float64)
        st.sweep_cols = np.asarray(tree["sweep_cols"], np.int64)
        st.seq = int(tree["seq"])
        # pre-done/stop v2 checkpoints (and lifted v1) were only written
        # mid-build, so "not done" is the faithful default
        st.done = int(tree.get("done", 0))
        st.stop = int(tree.get("stop", STOP_NONE))
        return st


def _fresh_state(prov: SnapshotProvider, max_k: int, tiles, tile_m: int,
                 block_p: int, keep_R: bool, rdt,
                 backend: str) -> _StreamState:
    """Init pass: stream all tiles once for column norms^2 + first top-p."""
    N, M = prov.shape
    p = block_p
    dtype = jnp.dtype(prov.dtype)
    st = _StreamState()
    st.tile_m = tile_m
    st.block_p = p
    st.backend = backend
    st.norms_sq = np.empty((M,), rdt)
    best_vals = np.full((p,), -math.inf, np.float64)
    best_cols = np.full((p,), -1, np.int64)
    nxt = prov.tile(*tiles[0]) if tiles else None
    for i, (lo, hi) in enumerate(tiles):
        T, nxt = nxt, None
        out = _tile_init(T, kt=min(p, hi - lo))  # async dispatch
        if i + 1 < len(tiles):
            # Prefetch the next tile (host read + async device_put) while
            # the dispatched init pass runs — see the sweep loop.
            nxt = prov.tile(*tiles[i + 1])
        n, tv, ti = out
        st.norms_sq[lo:hi] = np.asarray(n, rdt)
        best_vals, best_cols = _merge_topk(
            best_vals, best_cols, tv, lo + np.asarray(ti, np.int64), p)
    st.acc = np.zeros((M,), rdt)
    st.Q = jnp.zeros((N, max_k), dtype)
    st.R = np.zeros((max_k, M), np.dtype(dtype)) if keep_R else None
    st.pivots = np.full((max_k,), -1, np.int32)
    st.errs = np.zeros((max_k,), rdt)
    st.rnorms = np.zeros((max_k,), rdt)
    st.n_passes = np.zeros((max_k,), np.int32)
    st.k = 0
    st.n_acc = 0
    # Same reference scale the in-memory drivers fix at init: ref_sq is the
    # refresh trigger's reference, scale the rank guard's global scale.
    top = float(best_vals[0]) if best_cols[0] >= 0 else 0.0
    st.ref_sq = top
    st.scale = max(top, 0.0) ** 0.5
    st.best_vals, st.best_cols = best_vals, best_cols
    st.pending = 0
    st.cursor = 0
    st.pending_Q = jnp.zeros((N, p), dtype)
    st.pending_cols = np.full((p,), -1, np.int64)
    st.pending_errs = np.zeros((p,), np.float64)
    st.pending_rnorms = np.zeros((p,), np.float64)
    st.pending_npass = np.zeros((p,), np.int64)
    st.pending_ok = np.zeros((p,), np.int64)
    st.sweep_vals = np.full((p,), -math.inf, np.float64)
    st.sweep_cols = np.full((p,), -1, np.int64)
    st.seq = 0
    st.done = 0
    st.stop = STOP_NONE
    return st


@functools.partial(jax.jit, static_argnames=("kt",))
def _tile_warm_init(Q0: jax.Array, T: jax.Array, kt: int = 1):
    """Warm-start init pass over one tile: raw column norms^2, the tile's
    R rows against the existing basis (``C = Q0^H T``), the EXACT residuals
    of the tile against Q0, and the tile's top-kt residual candidates."""
    n_raw = jnp.sum(jnp.abs(T) ** 2, axis=0)
    C = Q0.conj().T @ T
    E = T - Q0 @ C
    res = jnp.sum(jnp.abs(E) ** 2, axis=0)
    tv, ti = jax.lax.top_k(res, kt)
    return n_raw, C, res, tv, ti.astype(jnp.int32)


def _warm_state(prov: SnapshotProvider, warm: dict, max_slots: int, tiles,
                tile_m: int, block_p: int, keep_R: bool, rdt,
                backend: str) -> _StreamState:
    """Enrichment init: seed the stream with an existing basis.

    ``warm`` carries the finalized artifact's trimmed arrays (``Q``
    (N, k0), ``pivots``/``errs``/``rnorms``/``n_passes`` (k0,)).  One
    init sweep computes, per tile, the raw norms (rank-guard scale), the
    R rows of the new source against Q0, and the EXACT residuals — which
    become the Eq.-(6.3) reference (``acc`` restarts at zero), exactly as
    if a refresh had just run: the greedy loop then extends the basis
    with only the new source's unexplained directions.
    """
    N, M = prov.shape
    Q0 = jnp.asarray(warm["Q"])
    k0 = Q0.shape[1]
    if k0 > max_slots:
        raise ValueError(
            f"warm-start basis k0={k0} exceeds max_k={max_slots}")
    p = block_p
    dtype = jnp.dtype(prov.dtype)
    if Q0.dtype != dtype:
        raise ValueError(
            f"warm-start dtype mismatch: basis {Q0.dtype}, provider {dtype}")
    st = _StreamState()
    st.tile_m = tile_m
    st.block_p = p
    st.backend = backend
    st.norms_sq = np.empty((M,), rdt)
    st.R = np.zeros((max_slots, M), np.dtype(dtype)) if keep_R else None
    best_vals = np.full((p,), -math.inf, np.float64)
    best_cols = np.full((p,), -1, np.int64)
    raw_max = 0.0
    nxt = prov.tile(*tiles[0]) if tiles else None
    for i, (lo, hi) in enumerate(tiles):
        T, nxt = nxt, None
        out = _tile_warm_init(Q0, T, kt=min(p, hi - lo))
        if i + 1 < len(tiles):
            nxt = prov.tile(*tiles[i + 1])  # overlaps the init pass
        n_raw, C, res, tv, ti = out
        raw_max = max(raw_max, float(jnp.max(n_raw)))
        st.norms_sq[lo:hi] = np.asarray(res, rdt)
        if st.R is not None:
            st.R[:k0, lo:hi] = np.asarray(C)
        best_vals, best_cols = _merge_topk(
            best_vals, best_cols, tv, lo + np.asarray(ti, np.int64), p)
    st.acc = np.zeros((M,), rdt)
    st.Q = jnp.zeros((N, max_slots), dtype).at[:, :k0].set(Q0)
    st.pivots = np.full((max_slots,), -1, np.int32)
    st.errs = np.zeros((max_slots,), rdt)
    st.rnorms = np.zeros((max_slots,), rdt)
    st.n_passes = np.zeros((max_slots,), np.int32)
    st.pivots[:k0] = np.asarray(warm["pivots"], np.int32)[:k0]
    st.errs[:k0] = np.asarray(warm["errs"], rdt)[:k0]
    if "rnorms" in warm:
        st.rnorms[:k0] = np.asarray(warm["rnorms"], rdt)[:k0]
    if "n_passes" in warm:
        st.n_passes[:k0] = np.asarray(warm["n_passes"], np.int32)[:k0]
    st.k = k0
    st.n_acc = k0
    # The exact residuals ARE the reference (post-"refresh" semantics);
    # the rank guard measures against the new source's raw data scale.
    top = float(best_vals[0]) if best_cols[0] >= 0 else 0.0
    st.ref_sq = max(top, 1e-300)
    st.scale = max(raw_max, 0.0) ** 0.5
    st.best_vals, st.best_cols = best_vals, best_cols
    st.pending = 0
    st.cursor = 0
    st.pending_Q = jnp.zeros((N, p), dtype)
    st.pending_cols = np.full((p,), -1, np.int64)
    st.pending_errs = np.zeros((p,), np.float64)
    st.pending_rnorms = np.zeros((p,), np.float64)
    st.pending_npass = np.zeros((p,), np.int64)
    st.pending_ok = np.zeros((p,), np.int64)
    st.sweep_vals = np.full((p,), -math.inf, np.float64)
    st.sweep_cols = np.full((p,), -1, np.int64)
    st.seq = 0
    st.done = 0
    st.stop = STOP_NONE
    return st


def _save_state(st: _StreamState, directory: str, keep: int = 2) -> None:
    from repro.checkpoint.io import save_checkpoint

    st.seq += 1
    save_checkpoint(st.to_tree(), directory, st.seq)
    # Prune old step dirs: each holds a full state copy (incl. R), and only
    # the newest complete one is ever restored.
    import re
    import shutil

    steps = sorted(
        int(m.group(1)) for d in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d+)", d))
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def _load_state(directory: str) -> Optional[_StreamState]:
    from repro.checkpoint.io import latest_step, load_checkpoint_raw

    if latest_step(directory) is None:
        return None
    return _StreamState.from_tree(load_checkpoint_raw(directory))


def rb_greedy_streamed(
    source,
    tau: float,
    max_k: int | None = None,
    *,
    tile_m: int = 8192,
    block_p: int = 1,
    kappa: float = 2.0,
    max_passes: int = 3,
    refresh: str = "auto",
    refresh_safety: float = 100.0,
    backend: str | None = None,
    panel_ortho: bool = True,
    keep_R: bool = True,
    checkpoint_dir: str | os.PathLike | None = None,
    checkpoint_every_tiles: int = 0,
    resume: bool = False,
    callback: Callable[[dict[str, Any]], None] | None = None,
    warm_start: dict | None = None,
) -> StreamedGreedyResult:
    """Algorithm 3 over a :class:`~repro.data.providers.SnapshotProvider`.

    ``source`` may be a provider, a resident array, or a path to a ``.npy``
    snapshot file (coerced via :func:`repro.data.providers.as_provider`).
    At ``block_p=1`` it selects the same pivots and builds the same basis
    as :func:`repro.core.greedy.rb_greedy` on the materialized matrix
    (tests/test_streaming.py), while holding only Q and one N x ``tile_m``
    tile on device.

    Args beyond the in-memory drivers':
      tile_m: columns per streamed tile.  Device peak is
        O(N * (max_k + block_p + 2 * tile_m)) — current tile plus the
        prefetched next one; throughput prefers the largest tile that fits
        (every greedy iteration re-streams all of S through the Eq.-(6.3)
        sweep either way).
      block_p: pivots selected per sweep.  ``1`` is the exact stepwise
        stream; ``> 1`` amortizes every tile transfer over ``block_p``
        bases (a top-p candidate fold across tiles + one fused panel sweep
        per tile), trading the blocked drivers' pivot staleness — the
        right trade whenever the stream is transfer-bound (see
        BENCH_streaming.json and the README "Choosing a strategy" guide).
      panel_ortho: orthogonalize each pending block through the BLAS-3
        panel path (:func:`repro.core.greedy.panel_imgs_orthogonalize`,
        the resident blocked drivers' default) instead of p sequential
        :func:`~repro.core.greedy.imgs_orthogonalize` calls.  Only
        consulted at ``block_p > 1``; both span the same space (float
        summation order differs).
      keep_R: accumulate the (max_k, M) R factor on host.  Disable for
        M so large that even one host row set is unwanted.
      checkpoint_dir: if set, persist streaming state via
        :mod:`repro.checkpoint.io` after every accepted block (and
        refresh).
      checkpoint_every_tiles: additionally checkpoint mid-sweep every this
        many tiles (0 = per-block only).  With T tiles per sweep a crash
        loses at most ``checkpoint_every_tiles`` tile sweeps of work.
      resume: load the latest checkpoint from ``checkpoint_dir`` and
        continue (fresh build if the directory has none).  The tiling,
        ``block_p`` and dtype must match the checkpoint.
      callback: called once per accepted basis with a dict
        ``{k, pivot, err, rnorm, n_passes}``.
      warm_start: seed the build with an existing basis (the enrichment
        path, :meth:`repro.api.artifact.ReducedBasis.enrich`): a dict with
        ``Q`` (N, k0) plus ``pivots``/``errs`` (and optionally
        ``rnorms``/``n_passes``) of length k0.  The init sweep computes
        the new source's exact residuals against Q0 (post-refresh
        semantics) and the greedy loop extends the basis from slot k0;
        the returned pivots < k0 are the seed's (indices into ITS
        original source), >= k0 index the new source.  Ignored when
        ``resume`` finds a checkpoint (the checkpoint already embeds it).
    """
    prov = as_provider(source)
    N, M = prov.shape
    if max_k is None:
        max_k = min(N, M)
    max_k = min(max_k, N, M)
    if tile_m < 1:
        raise ValueError(f"tile_m must be >= 1, got {tile_m}")
    if block_p < 1:
        raise ValueError(f"block_p must be >= 1, got {block_p}")
    p = min(block_p, min(N, M))
    if checkpoint_every_tiles < 0:
        raise ValueError("checkpoint_every_tiles must be >= 0")
    if resume and checkpoint_dir is None:
        raise ValueError("resume=True requires checkpoint_dir")
    backend = _backend.resolve_backend(backend)
    ckpt_dir = os.fspath(checkpoint_dir) if checkpoint_dir is not None \
        else None

    # Slot budget: blocked builds get +p headroom for rank-rejected holes
    # (compacted away at the end), exactly like the resident blocked
    # driver; the stepwise stream keeps the v1 sizing.
    max_slots = max_k if p == 1 else min(max_k + p, min(N, M) + p)

    tiles = list(prov.tiles(tile_m))
    dtype = jnp.dtype(prov.dtype)
    rdt = np.zeros((), dtype).real.dtype
    eps = float(jnp.finfo(rdt).eps)

    st = _load_state(ckpt_dir) if (resume and ckpt_dir) else None
    if st is not None:
        if st.tile_m != tile_m:
            # The persisted cursor/pending-sweep fields are in tile units:
            # resuming under a different tiling would re-apply part of the
            # in-flight sweep (silently wrong acc/R), so refuse.
            raise ValueError(
                f"checkpoint tile_m mismatch: saved {st.tile_m}, "
                f"requested {tile_m}"
            )
        if st.block_p != p:
            # The pending panel and candidate folds are width-block_p:
            # a different width cannot continue the same build (checked
            # before the shape: the blocked slot headroom depends on p).
            raise ValueError(
                f"checkpoint block_p mismatch: saved {st.block_p}, "
                f"requested {p}"
            )
        if st.Q.shape != (N, max_slots) or st.norms_sq.shape != (M,):
            raise ValueError(
                f"checkpoint shape mismatch: Q {st.Q.shape} / M "
                f"{st.norms_sq.shape[0]} vs requested ({N}, {max_slots}) / "
                f"{M}"
            )
        if st.Q.dtype != dtype:
            raise ValueError(
                f"checkpoint dtype mismatch: saved {st.Q.dtype}, provider "
                f"{dtype}"
            )
        if st.pending and st.backend != backend:
            # A completed sweep is backend-portable; an in-flight one is
            # not (its partial acc carries one backend's summation order).
            raise ValueError(
                f"checkpoint has an in-flight sweep under backend "
                f"{st.backend!r}; resume with that backend (requested "
                f"{backend!r}) or restart from a basis boundary"
            )
        st.backend = backend
        if (st.R is not None) != keep_R:
            raise ValueError("checkpoint keep_R setting differs from call")
    else:
        if warm_start is not None:
            st = _warm_state(prov, warm_start, max_slots, tiles, tile_m, p,
                             keep_R, rdt, backend)
        else:
            st = _fresh_state(prov, max_slots, tiles, tile_m, p, keep_R,
                              rdt, backend)
        if ckpt_dir:
            # A fresh build may target a directory holding an older run's
            # steps: continue the step numbering past them so the new
            # saves sort newest (and the pruner retires the stale ones)
            # instead of being shadowed — and deleted — by them.
            from repro.checkpoint.io import latest_step

            st.seq = latest_step(ckpt_dir) or 0

    rzero = np.zeros((), rdt)
    # a resumed checkpoint that already carries the done verdict needs no
    # re-recording; a live run records it at its terminal save
    done_saved = bool(st.done)

    while not st.done:
        if not st.pending:
            if st.k + p > max_slots:
                st.done, st.stop = 1, STOP_NONE  # slot capacity
                break
            # Pivot block from the running top-p fold (folded across tiles
            # during the previous sweep / init / refresh pass).  err is the
            # same clipped sqrt the in-memory drivers compute, evaluated in
            # the residual dtype.
            err = float(np.sqrt(np.maximum(
                np.asarray(st.best_vals[0], rdt), rzero)))
            if err < tau or st.best_cols[0] < 0:
                st.done, st.stop = 1, STOP_TAU
                break
            # --- joint IMGS of the block (in-block rank guard) ---------
            cols = np.asarray(st.best_cols)
            errs_blk = np.zeros((p,), np.float64)
            rnorms_blk = np.zeros((p,), np.float64)
            npass_blk = np.zeros((p,), np.int64)
            thr = 50.0 * eps * st.scale
            if p > 1 and panel_ortho:
                # BLAS-3 panel path: one fused panel orthogonalization of
                # all p candidate columns against Q (and each other) —
                # the same primitive the resident blocked driver runs
                # in-trace, so pivots/bases stay in lockstep with it.
                vs = [prov.column(int(cols[i])) if cols[i] >= 0
                      else jnp.zeros((N,), dtype) for i in range(p)]
                V = jnp.stack([jnp.asarray(v, dtype) for v in vs], axis=1)
                P_blk, oks_d, rnorms_d, npass_d = _jit_panel_ortho(
                    V, st.Q, kappa=kappa, max_passes=max_passes,
                    thresh=jnp.asarray(thr, rdt), backend=backend,
                )
                oks = [int(o) and int(cols[i]) >= 0
                       for i, o in enumerate(np.asarray(oks_d))]
                rnorms_blk[:] = np.asarray(rnorms_d, np.float64)
                npass_blk[:] = np.asarray(npass_d, np.int64)
                for i in range(p):
                    if cols[i] >= 0:
                        errs_blk[i] = float(np.sqrt(np.maximum(
                            np.asarray(st.best_vals[i], rdt), rzero)))
                qs = [P_blk[:, i] for i in range(p)]
            else:
                Qwork = st.Q
                qs, oks = [], []
                for i in range(p):
                    j = int(cols[i])
                    if j < 0:  # fewer than p candidates exist (tiny M)
                        qs.append(jnp.zeros((N,), dtype))
                        oks.append(0)
                        continue
                    v = prov.column(j)
                    q, _, rnorm_d, npass_d = _jit_ortho(
                        v, Qwork, kappa=kappa, max_passes=max_passes,
                        backend=backend,
                    )
                    rnorm = float(rnorm_d)
                    # p=1 keeps the stepwise drivers' guard boundary
                    # (reject strictly below); p>1 the resident blocked
                    # driver's (accept strictly above) — they differ only
                    # at exact float equality, but each parity suite is
                    # bitwise.
                    ok = (rnorm >= thr) if p == 1 else (rnorm > thr)
                    if not ok:
                        # Numerical-rank rejection (same guard as the
                        # in-memory drivers): a zero "hole" column.
                        q = jnp.zeros((N,), dtype)
                    Qwork = Qwork.at[:, st.k + i].set(q)
                    qs.append(q)
                    oks.append(int(ok))
                    errs_blk[i] = float(np.sqrt(np.maximum(
                        np.asarray(st.best_vals[i], rdt), rzero)))
                    rnorms_blk[i] = rnorm
                    npass_blk[i] = int(npass_d)
            if not any(oks):
                # Whole block rank-rejected: numerical-rank exhaustion,
                # stop WITHOUT committing (at block_p=1 this is exactly
                # the stepwise drivers' rank-guard break).
                st.done, st.stop = 1, STOP_RANK
                break
            st.pending = 1
            st.cursor = 0
            st.pending_Q = jnp.stack(qs, axis=1)
            st.pending_cols = cols.astype(np.int64)
            st.pending_errs = errs_blk
            st.pending_rnorms = rnorms_blk
            st.pending_npass = npass_blk
            st.pending_ok = np.asarray(oks, np.int64)
            st.sweep_vals = np.full((p,), -math.inf, np.float64)
            st.sweep_cols = np.full((p,), -1, np.int64)

        # --- Eq.-(6.3) sweep over tiles (resumable at tile granularity) ---
        # The next tile is prefetched while the current tile's sweep runs:
        # jax dispatch is async, so issuing the sweep, then the next tile's
        # host read + device_put, THEN blocking on the sweep's outputs
        # overlaps the host<->device tile traffic with device compute —
        # this copy overhead dominated the streamed build before
        # (BENCH_streaming.json: 3.58x vs resident on the CPU smoke shape).
        # At block_p>1 every transferred tile additionally serves p bases.
        P_blk = st.pending_Q
        q1 = P_blk[:, 0] if p == 1 else None
        nxt = prov.tile(*tiles[st.cursor]) if st.cursor < len(tiles) \
            else None
        while st.cursor < len(tiles):
            lo, hi = tiles[st.cursor]
            T, nxt = nxt, None
            if p == 1:
                # stepwise hot path: the fused scalar sweep (bitwise v1)
                c, acc_out, mx, am = _tile_sweep(
                    q1, T, jnp.asarray(st.acc[lo:hi]),
                    jnp.asarray(st.norms_sq[lo:hi]), backend
                )
                C = c[None, :]
                tv, ti = mx[None], am[None]
            else:
                C, acc_out, tv, ti = _tile_block_sweep(
                    P_blk, T, jnp.asarray(st.acc[lo:hi]),
                    jnp.asarray(st.norms_sq[lo:hi]),
                    min(p, hi - lo), backend
                )
            if st.cursor + 1 < len(tiles):
                nxt = prov.tile(*tiles[st.cursor + 1])  # overlaps the sweep
            st.acc[lo:hi] = np.asarray(acc_out, rdt)
            if st.R is not None:
                st.R[st.k:st.k + p, lo:hi] = np.asarray(C)
            # Running top-p fold (the paper's MPI_Allreduce(MAXLOC)
            # generalized to p winners): exact ties keep the earliest
            # column, matching jnp.argmax/top_k's first-occurrence
            # tie-break on the full residual vector.
            st.sweep_vals, st.sweep_cols = _merge_topk(
                st.sweep_vals, st.sweep_cols, tv,
                lo + np.asarray(ti, np.int64), p)
            st.cursor += 1
            if (ckpt_dir and checkpoint_every_tiles
                    and st.cursor < len(tiles)
                    and st.cursor % checkpoint_every_tiles == 0):
                _save_state(st, ckpt_dir)

        # --- commit the block -------------------------------------------
        slots = st.k
        st.Q = _commit_panel(st.Q, st.pending_Q, slots)
        for i in range(p):
            if st.pending_cols[i] < 0:
                continue
            ok = bool(st.pending_ok[i])
            st.pivots[slots + i] = st.pending_cols[i] if ok else -1
            st.errs[slots + i] = st.pending_errs[i]
            st.rnorms[slots + i] = st.pending_rnorms[i]
            st.n_passes[slots + i] = st.pending_npass[i]
            if ok:
                st.n_acc += 1
                if callback is not None:
                    callback({"k": st.n_acc,
                              "pivot": int(st.pending_cols[i]),
                              "err": float(st.errs[slots + i]),
                              "rnorm": float(st.rnorms[slots + i]),
                              "n_passes": int(st.n_passes[slots + i])})
        st.k = slots + p
        st.best_vals = st.sweep_vals.copy()
        st.best_cols = st.sweep_cols.copy()
        err = float(st.pending_errs[0])
        st.pending = 0
        st.cursor = 0
        st.pending_Q = jnp.zeros_like(st.pending_Q)

        # --- Eq.-(6.3) refresh near the cancellation floor ---------------
        # block_p=1 replicates rb_greedy_stepwise (trigger on the committed
        # pivot's pre-add err); block_p>1 the chunked blocked driver
        # (trigger on the post-block max residual — the fold's top value,
        # with the family's tau-stop precedence: a post-block residual
        # already below tau means converged, so no refresh fires — the
        # top-of-loop check breaks the build next round, matching the
        # resident chunk's post-block STOP_TAU).
        if p == 1:
            floor_sq = err * err
            tau_converged = False
        else:
            floor_sq = max(float(st.best_vals[0]), 0.0)
            tau_converged = float(np.sqrt(np.maximum(
                np.asarray(floor_sq, rdt), rzero))) < tau
        if (refresh == "auto" and not tau_converged
                and floor_sq < refresh_safety * eps * st.ref_sq):
            new_norms = np.empty_like(st.norms_sq)
            best_vals = np.full((p,), -math.inf, np.float64)
            best_cols = np.full((p,), -1, np.int64)
            nxt = prov.tile(*tiles[0]) if tiles else None
            for i, (lo, hi) in enumerate(tiles):
                T, nxt = nxt, None
                out = _tile_refresh(st.Q, T, kt=min(p, hi - lo))
                if i + 1 < len(tiles):
                    nxt = prov.tile(*tiles[i + 1])  # overlaps the refresh
                res, tv, ti = out
                new_norms[lo:hi] = np.asarray(res, rdt)
                best_vals, best_cols = _merge_topk(
                    best_vals, best_cols, tv,
                    lo + np.asarray(ti, np.int64), p)
            st.norms_sq = new_norms
            st.acc[:] = 0
            st.best_vals, st.best_cols = best_vals, best_cols
            st.ref_sq = max(float(best_vals[0]), 1e-300)
            if st.ref_sq ** 0.5 < tau:
                st.done, st.stop = 1, STOP_TAU
            elif st.ref_sq ** 0.5 <= floor_estimate(eps, st.scale,
                                                    st.n_acc):
                # Post-refresh exact residual at the achievable floor:
                # tau is unreachable in this precision — stop gracefully
                # (same gate as the resident drivers).
                st.done, st.stop = 1, STOP_FLOOR

        if ckpt_dir:
            _save_state(st, ckpt_dir)
            done_saved = bool(st.done)

    # Final save: the pre-sweep exits (tau / rank-guard / capacity) and the
    # floor-stop only mutate the done/stop verdict, but that verdict MUST be
    # persisted — a floor-stopped build's residual still sits above tau, so
    # a resume without it would keep adding bases.
    if ckpt_dir and not done_saved:
        _save_state(st, ckpt_dir)
    if p == 1:
        Q_out, R_out = st.Q, st.R
        pivots, errs = st.pivots, st.errs
        rnorms, n_passes = st.rnorms, st.n_passes
        k = st.k
    else:
        # compact: drop hole columns (rank-rejected in-block candidates)
        # and cap at max_k — the slot buffer carries +p overrun headroom
        # and the final block may push the accepted count past the cap
        # (the basis is nested, so truncation is exact)
        keep = np.where(st.pivots[:st.k] >= 0)[0][:max_k]
        k = len(keep)
        Q_host = np.asarray(jax.device_get(st.Q))
        Q_c = np.zeros_like(Q_host)
        Q_c[:, :k] = Q_host[:, keep]
        Q_out = jnp.asarray(Q_c)
        if st.R is not None:
            R_out = np.zeros_like(st.R)
            R_out[:k] = st.R[keep]
        else:
            R_out = None
        pivots = np.full_like(st.pivots, -1)
        pivots[:k] = st.pivots[keep]
        errs = np.zeros_like(st.errs)
        errs[:k] = st.errs[keep]
        rnorms = np.zeros_like(st.rnorms)
        rnorms[:k] = st.rnorms[keep]
        n_passes = np.zeros_like(st.n_passes)
        n_passes[:k] = st.n_passes[keep]
    return StreamedGreedyResult(
        Q=Q_out, R=R_out, pivots=pivots, errs=errs, k=k,
        n_ortho_passes=n_passes, rnorms=rnorms,
        tile_m=tile_m, n_tiles=len(tiles), block_p=p, stop=int(st.stop),
    )
