"""Out-of-core tile-streamed RB-greedy over snapshot providers.

:func:`rb_greedy_streamed` is an exact refactor of the in-memory drivers in
:mod:`repro.core.greedy` for snapshot matrices that never fit on device (the
paper's headline scenario: a dense complex 10,000 x 3,276,800 matrix,
~0.5 TB, Sec. 6.1.1).  Per iteration it sweeps column tiles of the matrix
through the SAME fused backend primitives as the resident drivers:

  per tile      the Eq.-(6.3) pivot sweep (:func:`repro.core.backend.
                pivot_update`): ``c_t = q^H S_t``, ``acc_t += |c_t|^2``,
                plus the tile's residual (max, argmax) — produced in the
                same fused pass,
  across tiles  a running (value, global column) max-loc reduction — the
                single-machine analogue of the ``MPI_Allreduce(MAXLOC)``
                the paper's code performs across ranks (Sec. 6.1.3),
  per pivot     :func:`repro.core.greedy.imgs_orthogonalize` against the
                device-resident basis Q — bit-identical to the in-memory
                drivers because Q and the pivot column are the same arrays.

Tile traffic is double-buffered: while one tile's pass runs on device, the
next tile's host read + ``jax.device_put`` is issued (jax dispatch is
async), hiding the host<->device copies that otherwise dominate streamed
builds.  Only Q (N x max_k) and two tiles (N x tile_m each, current +
prefetched) are ever device-resident;
the Eq.-(6.3) residual caches (``norms_sq``, ``acc``: M reals each) and
the optional R factor live on host.  Peak device memory is
O(N * (max_k + 2 * tile_m)) — independent of M.

Stop semantics (tau drop, rank guard, Eq.-(6.3) refresh) replicate
:func:`repro.core.greedy.rb_greedy_stepwise` exactly; the parity suite
(tests/test_streaming.py) asserts pivot-for-pivot agreement across tile
sizes, dtypes and providers.

Mid-build checkpointing persists the full streaming state — tile cursor,
pending pivot, residual caches — through :mod:`repro.checkpoint.io`; a
killed build resumes from the last completed tile, not the last basis.
"""

from __future__ import annotations

import functools
import math
import os
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as _backend
from repro.core.greedy import imgs_orthogonalize
from repro.data.providers import SnapshotProvider, as_provider

_STATE_VERSION = 1


class StreamedGreedyResult(NamedTuple):
    """Result of the streamed greedy build (field names match
    :class:`repro.core.greedy.GreedyResult`).

    Attributes:
      Q:      (N, max_k) device array, orthonormal basis; columns >= k zero.
      R:      (max_k, M) host array ``R[j] = q_j^H S`` in original column
              order, or ``None`` when built with ``keep_R=False`` (R costs
              O(max_k * M) host memory — the one result piece that scales
              with M).
      pivots: (max_k,) int32 host array; entries >= k are -1.
      errs:   (max_k,) greedy error before adding basis j (real dtype).
      k:      number of accepted bases.
      n_ortho_passes, rnorms: per-basis iterated-GS diagnostics, as in the
              in-memory drivers.
      tile_m: tile width the build used; n_tiles: ceil(M / tile_m).
    """

    Q: jax.Array
    R: Optional[np.ndarray]
    pivots: np.ndarray
    errs: np.ndarray
    k: int
    n_ortho_passes: np.ndarray
    rnorms: np.ndarray
    tile_m: int
    n_tiles: int


@jax.jit
def _tile_init(T: jax.Array):
    """Column norms^2 of one tile + the tile's (max, argmax) — the init
    pass's contribution to the first pivot's max-loc reduction."""
    n = jnp.sum(jnp.abs(T) ** 2, axis=0)
    return n, jnp.max(n), jnp.argmax(n).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("backend",))
def _tile_sweep(q, T, acc_t, norms_t, backend: str):
    """One tile's Eq.-(6.3) sweep through the fused backend primitive."""
    return _backend.pivot_update(q, T, acc_t, norms_t, backend=backend)


@jax.jit
def _tile_refresh(Q: jax.Array, T: jax.Array):
    """Exact residual^2 of one tile against Q (zero columns are no-ops) —
    the tile-local form of :func:`repro.core.greedy.greedy_refresh`."""
    C = Q.conj().T @ T
    E = T - Q @ C
    res = jnp.sum(jnp.abs(E) ** 2, axis=0)
    return res, jnp.max(res), jnp.argmax(res).astype(jnp.int32)


_jit_ortho = jax.jit(
    imgs_orthogonalize, static_argnames=("kappa", "max_passes", "backend")
)


class _StreamState:
    """Host-side streaming state: everything needed to resume mid-build.

    ``pending == 1`` means a pivot has been selected and orthogonalized but
    its Eq.-(6.3) sweep has only covered tiles [0, cursor) — resume
    continues the sweep (acc/R for swept tiles are already updated; the
    sweep is deterministic given the checkpointed acc, so replaying the
    remaining tiles reproduces the uninterrupted build exactly).
    """

    __slots__ = (
        "Q", "R", "norms_sq", "acc", "pivots", "errs", "rnorms", "n_passes",
        "k", "ref_sq", "scale", "best_val", "best_col", "pending", "cursor",
        "pending_q", "pending_col", "pending_err", "pending_rnorm",
        "pending_npass", "sweep_val", "sweep_col", "seq", "tile_m",
        "backend",
    )

    def to_tree(self) -> dict:
        """Flat numpy pytree for :func:`repro.checkpoint.io.save_checkpoint`."""
        tree = {
            "version": np.asarray(_STATE_VERSION, np.int64),
            # cursor/pending are expressed in tile units, so a resume MUST
            # use the same tiling — persisted for validation, as is the
            # backend (a mid-sweep resume under a different backend would
            # mix float summation orders within one acc update).
            "tile_m": np.asarray(self.tile_m, np.int64),
            "backend": np.asarray(self.backend),
            "Q": np.asarray(jax.device_get(self.Q)),
            "norms_sq": self.norms_sq,
            "acc": self.acc,
            "pivots": self.pivots,
            "errs": self.errs,
            "rnorms": self.rnorms,
            "n_passes": self.n_passes,
            "k": np.asarray(self.k, np.int64),
            "ref_sq": np.asarray(self.ref_sq, np.float64),
            "scale": np.asarray(self.scale, np.float64),
            "best_val": np.asarray(self.best_val, np.float64),
            "best_col": np.asarray(self.best_col, np.int64),
            "pending": np.asarray(self.pending, np.int64),
            "cursor": np.asarray(self.cursor, np.int64),
            "pending_q": np.asarray(jax.device_get(self.pending_q)),
            "pending_col": np.asarray(self.pending_col, np.int64),
            "pending_err": np.asarray(self.pending_err, np.float64),
            "pending_rnorm": np.asarray(self.pending_rnorm, np.float64),
            "pending_npass": np.asarray(self.pending_npass, np.int64),
            "sweep_val": np.asarray(self.sweep_val, np.float64),
            "sweep_col": np.asarray(self.sweep_col, np.int64),
            "seq": np.asarray(self.seq, np.int64),
        }
        if self.R is not None:
            # Only the rows written so far (committed bases + the pending
            # sweep's partial row): checkpoint traffic scales with k*M, not
            # max_k*M.  keep_R=False avoids R checkpoint traffic entirely.
            tree["R"] = self.R[:self.k + self.pending]
        return tree

    @classmethod
    def from_tree(cls, tree: dict) -> "_StreamState":
        version = int(tree["version"])
        if version != _STATE_VERSION:
            raise ValueError(
                f"streaming checkpoint version {version} != supported "
                f"{_STATE_VERSION}"
            )
        st = cls()
        st.tile_m = int(tree["tile_m"])
        st.backend = str(tree["backend"])
        st.Q = jnp.asarray(tree["Q"])
        max_k = st.Q.shape[1]
        M = tree["norms_sq"].shape[0]
        R_rows = tree.get("R")
        if R_rows is not None:
            st.R = np.zeros((max_k, M), R_rows.dtype)
            st.R[:R_rows.shape[0]] = R_rows
        else:
            st.R = None
        st.norms_sq = tree["norms_sq"]
        st.acc = tree["acc"]
        st.pivots = tree["pivots"]
        st.errs = tree["errs"]
        st.rnorms = tree["rnorms"]
        st.n_passes = tree["n_passes"]
        st.k = int(tree["k"])
        st.ref_sq = float(tree["ref_sq"])
        st.scale = float(tree["scale"])
        st.best_val = float(tree["best_val"])
        st.best_col = int(tree["best_col"])
        st.pending = int(tree["pending"])
        st.cursor = int(tree["cursor"])
        st.pending_q = jnp.asarray(tree["pending_q"])
        st.pending_col = int(tree["pending_col"])
        st.pending_err = float(tree["pending_err"])
        st.pending_rnorm = float(tree["pending_rnorm"])
        st.pending_npass = int(tree["pending_npass"])
        st.sweep_val = float(tree["sweep_val"])
        st.sweep_col = int(tree["sweep_col"])
        st.seq = int(tree["seq"])
        return st


def _fresh_state(prov: SnapshotProvider, max_k: int, tiles, tile_m: int,
                 keep_R: bool, rdt, backend: str) -> _StreamState:
    """Init pass: stream all tiles once for column norms^2 + first max-loc."""
    N, M = prov.shape
    dtype = jnp.dtype(prov.dtype)
    st = _StreamState()
    st.tile_m = tile_m
    st.backend = backend
    st.norms_sq = np.empty((M,), rdt)
    best_val, best_col = -math.inf, -1
    nxt = prov.tile(*tiles[0]) if tiles else None
    for i, (lo, hi) in enumerate(tiles):
        T, nxt = nxt, None
        out = _tile_init(T)  # async dispatch
        if i + 1 < len(tiles):
            # Prefetch the next tile (host read + async device_put) while
            # the dispatched init pass runs — see the sweep loop.
            nxt = prov.tile(*tiles[i + 1])
        n, mx, am = out
        st.norms_sq[lo:hi] = np.asarray(n, rdt)
        val = float(mx)
        if val > best_val:
            best_val, best_col = val, lo + int(am)
    st.acc = np.zeros((M,), rdt)
    st.Q = jnp.zeros((N, max_k), dtype)
    st.R = np.zeros((max_k, M), np.dtype(dtype)) if keep_R else None
    st.pivots = np.full((max_k,), -1, np.int32)
    st.errs = np.zeros((max_k,), rdt)
    st.rnorms = np.zeros((max_k,), rdt)
    st.n_passes = np.zeros((max_k,), np.int32)
    st.k = 0
    # Same reference scale the in-memory drivers fix at init: ref_sq is the
    # refresh trigger's reference, scale the rank guard's global scale.
    st.ref_sq = best_val
    st.scale = max(best_val, 0.0) ** 0.5
    st.best_val, st.best_col = best_val, best_col
    st.pending = 0
    st.cursor = 0
    st.pending_q = jnp.zeros((N,), dtype)
    st.pending_col = -1
    st.pending_err = 0.0
    st.pending_rnorm = 0.0
    st.pending_npass = 0
    st.sweep_val, st.sweep_col = -math.inf, -1
    st.seq = 0
    return st


def _save_state(st: _StreamState, directory: str, keep: int = 2) -> None:
    from repro.checkpoint.io import save_checkpoint

    st.seq += 1
    save_checkpoint(st.to_tree(), directory, st.seq)
    # Prune old step dirs: each holds a full state copy (incl. R), and only
    # the newest complete one is ever restored.
    import re
    import shutil

    steps = sorted(
        int(m.group(1)) for d in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d+)", d))
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def _load_state(directory: str) -> Optional[_StreamState]:
    from repro.checkpoint.io import latest_step, load_checkpoint_raw

    if latest_step(directory) is None:
        return None
    return _StreamState.from_tree(load_checkpoint_raw(directory))


def rb_greedy_streamed(
    source,
    tau: float,
    max_k: int | None = None,
    *,
    tile_m: int = 8192,
    kappa: float = 2.0,
    max_passes: int = 3,
    refresh: str = "auto",
    refresh_safety: float = 100.0,
    backend: str | None = None,
    keep_R: bool = True,
    checkpoint_dir: str | os.PathLike | None = None,
    checkpoint_every_tiles: int = 0,
    resume: bool = False,
    callback: Callable[[dict[str, Any]], None] | None = None,
) -> StreamedGreedyResult:
    """Algorithm 3 over a :class:`~repro.data.providers.SnapshotProvider`.

    ``source`` may be a provider, a resident array, or a path to a ``.npy``
    snapshot file (coerced via :func:`repro.data.providers.as_provider`).
    Selects the same pivots and builds the same basis as
    :func:`repro.core.greedy.rb_greedy` on the materialized matrix
    (tests/test_streaming.py), while holding only Q and one N x ``tile_m``
    tile on device.

    Args beyond the in-memory drivers':
      tile_m: columns per streamed tile.  Device peak is
        O(N * (max_k + 2 * tile_m)) — current tile plus the prefetched
        next one; throughput prefers the largest tile that fits (every
        greedy iteration re-streams all of S through the Eq.-(6.3) sweep
        either way).
      keep_R: accumulate the (max_k, M) R factor on host.  Disable for
        M so large that even one host row set is unwanted.
      checkpoint_dir: if set, persist streaming state via
        :mod:`repro.checkpoint.io` after every accepted basis (and refresh).
      checkpoint_every_tiles: additionally checkpoint mid-sweep every this
        many tiles (0 = per-basis only).  With T tiles per sweep a crash
        loses at most ``checkpoint_every_tiles`` tile sweeps of work.
      resume: load the latest checkpoint from ``checkpoint_dir`` and
        continue (fresh build if the directory has none).
      callback: called once per accepted basis with a dict
        ``{k, pivot, err, rnorm, n_passes}``.
    """
    prov = as_provider(source)
    N, M = prov.shape
    if max_k is None:
        max_k = min(N, M)
    max_k = min(max_k, N, M)
    if tile_m < 1:
        raise ValueError(f"tile_m must be >= 1, got {tile_m}")
    if checkpoint_every_tiles < 0:
        raise ValueError("checkpoint_every_tiles must be >= 0")
    if resume and checkpoint_dir is None:
        raise ValueError("resume=True requires checkpoint_dir")
    backend = _backend.resolve_backend(backend)
    ckpt_dir = os.fspath(checkpoint_dir) if checkpoint_dir is not None \
        else None

    tiles = list(prov.tiles(tile_m))
    dtype = jnp.dtype(prov.dtype)
    rdt = np.zeros((), dtype).real.dtype
    eps = float(jnp.finfo(rdt).eps)

    st = _load_state(ckpt_dir) if (resume and ckpt_dir) else None
    if st is not None:
        if st.Q.shape != (N, max_k) or st.norms_sq.shape != (M,):
            raise ValueError(
                f"checkpoint shape mismatch: Q {st.Q.shape} / M "
                f"{st.norms_sq.shape[0]} vs requested ({N}, {max_k}) / {M}"
            )
        if st.tile_m != tile_m:
            # The persisted cursor/pending-sweep fields are in tile units:
            # resuming under a different tiling would re-apply part of the
            # in-flight sweep (silently wrong acc/R), so refuse.
            raise ValueError(
                f"checkpoint tile_m mismatch: saved {st.tile_m}, "
                f"requested {tile_m}"
            )
        if st.Q.dtype != dtype:
            raise ValueError(
                f"checkpoint dtype mismatch: saved {st.Q.dtype}, provider "
                f"{dtype}"
            )
        if st.pending and st.backend != backend:
            # A completed sweep is backend-portable; an in-flight one is
            # not (its partial acc carries one backend's summation order).
            raise ValueError(
                f"checkpoint has an in-flight sweep under backend "
                f"{st.backend!r}; resume with that backend (requested "
                f"{backend!r}) or restart from a basis boundary"
            )
        st.backend = backend
        if (st.R is not None) != keep_R:
            raise ValueError("checkpoint keep_R setting differs from call")
    else:
        st = _fresh_state(prov, max_k, tiles, tile_m, keep_R, rdt, backend)
        if ckpt_dir:
            # A fresh build may target a directory holding an older run's
            # steps: continue the step numbering past them so the new
            # saves sort newest (and the pruner retires the stale ones)
            # instead of being shadowed — and deleted — by them.
            from repro.checkpoint.io import latest_step

            st.seq = latest_step(ckpt_dir) or 0

    rzero = np.zeros((), rdt)

    while True:
        if not st.pending:
            if st.k >= max_k:
                break
            # Pivot from the running max-loc reduction (folded across tiles
            # during the previous sweep / init / refresh pass).  err is the
            # same clipped sqrt the in-memory drivers compute, evaluated in
            # the residual dtype.
            err = float(np.sqrt(np.maximum(np.asarray(st.best_val, rdt),
                                           rzero)))
            if err < tau:
                break
            j = st.best_col
            v = prov.column(j)
            q, _, rnorm_d, npass_d = _jit_ortho(
                v, st.Q, kappa=kappa, max_passes=max_passes, backend=backend
            )
            rnorm = float(rnorm_d)
            if rnorm < 50.0 * eps * st.scale:
                # Numerical-rank exhaustion (same guard as the in-memory
                # drivers): the pivot's true residual is rounding noise.
                break
            st.pending = 1
            st.cursor = 0
            st.pending_q = q
            st.pending_col = j
            st.pending_err = err
            st.pending_rnorm = rnorm
            st.pending_npass = int(npass_d)
            st.sweep_val, st.sweep_col = -math.inf, -1

        # --- Eq.-(6.3) sweep over tiles (resumable at tile granularity) ---
        # The next tile is prefetched while the current tile's sweep runs:
        # jax dispatch is async, so issuing the sweep, then the next tile's
        # host read + device_put, THEN blocking on the sweep's outputs
        # overlaps the host<->device tile traffic with device compute —
        # this copy overhead dominated the streamed build before
        # (BENCH_streaming.json: 3.58x vs resident on the CPU smoke shape).
        q = st.pending_q
        nxt = prov.tile(*tiles[st.cursor]) if st.cursor < len(tiles) \
            else None
        while st.cursor < len(tiles):
            lo, hi = tiles[st.cursor]
            T, nxt = nxt, None
            c, acc_out, mx, am = _tile_sweep(
                q, T, jnp.asarray(st.acc[lo:hi]),
                jnp.asarray(st.norms_sq[lo:hi]), backend
            )
            if st.cursor + 1 < len(tiles):
                nxt = prov.tile(*tiles[st.cursor + 1])  # overlaps the sweep
            st.acc[lo:hi] = np.asarray(acc_out, rdt)
            if st.R is not None:
                st.R[st.k, lo:hi] = np.asarray(c)
            # Running MAXLOC fold: strict > keeps the earliest tile on
            # ties, matching jnp.argmax's first-max tie-break on the full
            # residual vector.
            val = float(mx)
            if val > st.sweep_val:
                st.sweep_val, st.sweep_col = val, lo + int(am)
            st.cursor += 1
            if (ckpt_dir and checkpoint_every_tiles
                    and st.cursor < len(tiles)
                    and st.cursor % checkpoint_every_tiles == 0):
                _save_state(st, ckpt_dir)

        # --- commit the basis -------------------------------------------
        k = st.k
        st.Q = st.Q.at[:, k].set(q)
        st.pivots[k] = st.pending_col
        st.errs[k] = st.pending_err
        st.rnorms[k] = st.pending_rnorm
        st.n_passes[k] = st.pending_npass
        st.k = k + 1
        st.best_val, st.best_col = st.sweep_val, st.sweep_col
        err = st.pending_err
        st.pending = 0
        st.cursor = 0
        st.pending_q = jnp.zeros_like(st.pending_q)
        if callback is not None:
            callback({"k": st.k, "pivot": int(st.pivots[k]),
                      "err": float(err), "rnorm": float(st.rnorms[k]),
                      "n_passes": int(st.n_passes[k])})

        # --- Eq.-(6.3) refresh near the cancellation floor ---------------
        stop_after_refresh = False
        if refresh == "auto" and err * err < refresh_safety * eps * st.ref_sq:
            new_norms = np.empty_like(st.norms_sq)
            best_val, best_col = -math.inf, -1
            nxt = prov.tile(*tiles[0]) if tiles else None
            for i, (lo, hi) in enumerate(tiles):
                T, nxt = nxt, None
                out = _tile_refresh(st.Q, T)  # async dispatch
                if i + 1 < len(tiles):
                    nxt = prov.tile(*tiles[i + 1])  # overlaps the refresh
                res, mx, am = out
                new_norms[lo:hi] = np.asarray(res, rdt)
                val = float(mx)
                if val > best_val:
                    best_val, best_col = val, lo + int(am)
            st.norms_sq = new_norms
            st.acc[:] = 0
            st.best_val, st.best_col = best_val, best_col
            st.ref_sq = max(best_val, 1e-300)
            if st.ref_sq ** 0.5 < tau:
                stop_after_refresh = True

        if ckpt_dir:
            _save_state(st, ckpt_dir)
        if stop_after_refresh:
            break

    # (no final save: every state mutation above is followed by a save —
    # the pivot-selection / tau / rank-guard exits mutate nothing)
    return StreamedGreedyResult(
        Q=st.Q, R=st.R, pivots=st.pivots, errs=st.errs, k=st.k,
        n_ortho_passes=st.n_passes, rnorms=st.rnorms,
        tile_m=tile_m, n_tiles=len(tiles),
    )
