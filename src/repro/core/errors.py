"""The paper's error identities (Thms 3.2, 4.1, 4.3; Cors 4.4, 5.6, 5.7).

These are used by the tests to validate the implementation against the
paper's exact statements and by the benchmarks to report basis quality.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def proj_error_2norm(S: jax.Array, Q: jax.Array) -> jax.Array:
    """|S - Q Q^H S|_2  (Thm 4.1 LHS)."""
    return jnp.linalg.norm(S - Q @ (Q.conj().T @ S), ord=2)


def proj_error_fro(S: jax.Array, Q: jax.Array) -> jax.Array:
    """|S - Q Q^H S|_F."""
    return jnp.linalg.norm(S - Q @ (Q.conj().T @ S))


def proj_error_max(S: jax.Array, Q: jax.Array) -> jax.Array:
    """max_i |s_i - Q Q^H s_i|_2  (Eq. 4.6; RB-greedy's error functional)."""
    E = S - Q @ (Q.conj().T @ S)
    return jnp.max(jnp.linalg.norm(E, axis=0))


def per_column_errors(S: jax.Array, Q: jax.Array) -> jax.Array:
    """|s_i - Q Q^H s_i|_2 for every column (Thm 4.3: equals |r~_i|_2)."""
    E = S - Q @ (Q.conj().T @ S)
    return jnp.linalg.norm(E, axis=0)


def r22_norm(R: jax.Array, k: int, ord=2) -> jax.Array:
    """|R22|_* for a full triangular factor R and split index k (Thm 4.1)."""
    return jnp.linalg.norm(R[k:, k:], ord=ord)


def greedy_error_determinant_identity(
    sigmas: jax.Array, r_diag: jax.Array, k: int
) -> jax.Array:
    """Corollary 5.7 RHS: (prod_{i<=k+1} sigma_i) / (prod_{i<=k} R(i,i)).

    Computed in log space for stability.
    """
    log_num = jnp.sum(jnp.log(sigmas[: k + 1]))
    log_den = jnp.sum(jnp.log(r_diag[:k]))
    return jnp.exp(log_num - log_den)


def orthogonality_defect(Q: jax.Array) -> jax.Array:
    """|I - Q^H Q|_2 — Hoffmann's conjecture: ~ kappa * eps * sqrt(M)."""
    k = Q.shape[1]
    return jnp.linalg.norm(jnp.eye(k, dtype=Q.dtype) - Q.conj().T @ Q, ord=2)
