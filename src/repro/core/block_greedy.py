"""Block RB-greedy: p pivots per sweep (beyond-paper §Perf optimization).

The paper's algorithm is memory-bound at one full pass over S per basis
vector (the Eq.-6.3 update c = q^H S dominates, arithmetic intensity ~1
FLOP/byte).  The flagship dry-run confirms it: the greedy step's roofline
is the HBM read of the local shard of S.

Block pivoting amortizes that read: select the top-p residual columns in
one sweep, orthogonalize them jointly (iterated GS, with a rank guard that
rejects candidates whose residual collapses once the earlier picks in the
block are added), then update ALL column residuals with ONE (p, N) x (N, M)
matmul — one read of S per p bases, cutting the dominant memory term by ~p.

The trade-off is pivot staleness: picks 2..p within a block are made
against residuals that ignore picks 1..i-1.  For fast-decaying (smooth /
GW) snapshot families the effect is a few extra bases at the same tau —
measured in tests/test_block_greedy.py and reported in EXPERIMENTS.md §Perf.

This is the classical blocked column-pivoted QR idea (cf. the BLAS-3
literature the paper cites: [35] Quintana-Orti; [18] Demmel et al. CA-RRQR)
applied to the paper's Eq.-6.3 greedy bookkeeping.
"""

from __future__ import annotations

import functools
import warnings
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from jax.experimental.shard_map import shard_map

from repro.core import backend as _backend
from repro.core.greedy import GreedyResult, GreedyState, greedy_init, \
    imgs_orthogonalize


def block_greedy_step(S, state: GreedyState, p: int, kappa: float = 2.0,
                      max_passes: int = 3,
                      backend: str | None = None) -> GreedyState:
    """Add up to p bases with a single Eq.-6.3 sweep over S.

    Per-candidate orthogonalization and the blocked sweep route through
    :mod:`repro.core.backend` (the sweep's fused kernel slot is
    :func:`repro.core.backend.block_sweep`).
    """
    res_sq = jnp.maximum(state.norms_sq - state.acc, 0.0)
    top_vals, top_idx = jax.lax.top_k(res_sq, p)
    err = jnp.sqrt(top_vals[0])

    eps = jnp.finfo(state.norms_sq.dtype).eps
    scale = jnp.sqrt(jnp.max(state.norms_sq))

    Q = state.Q
    k = state.k
    new_qs = []
    accepted = []
    for i in range(p):  # p is small and static
        v = jnp.take(S, top_idx[i], axis=1)
        q, _, rnorm, _ = imgs_orthogonalize(v, Q, kappa, max_passes,
                                            backend=backend)
        ok = rnorm > 50.0 * eps * scale
        q = jnp.where(ok, q, jnp.zeros_like(q))
        # fixed-slot write at k+i; rejected candidates leave zero columns
        # ("holes") that the driver compacts at the end
        Q = Q.at[:, k + i].set(q)
        new_qs.append(q)
        accepted.append(ok)

    Qnew = jnp.stack(new_qs, axis=1)           # (N, p), rejected cols zero
    # ONE pass over S: (p, M) block sweep through the dispatch layer
    C, acc = _backend.block_sweep(Qnew, S, state.acc, backend=backend)

    R = jax.lax.dynamic_update_slice_in_dim(state.R, C, k, axis=0)
    pivots = jax.lax.dynamic_update_slice_in_dim(
        state.pivots,
        jnp.where(jnp.asarray(accepted), top_idx, -1).astype(jnp.int32),
        k, axis=0,
    )
    errs = jax.lax.dynamic_update_slice_in_dim(
        state.errs, jnp.sqrt(jnp.maximum(top_vals, 0.0)), k, axis=0
    )
    n_acc = jnp.sum(jnp.asarray(accepted, jnp.int32))
    return state._replace(
        Q=Q, R=R, acc=acc, pivots=pivots, errs=errs, k=k + n_acc,
    )


@functools.partial(
    jax.jit, static_argnames=("p", "kappa", "max_passes", "backend")
)
def _jitted_block_step(S, state, p: int, kappa: float = 2.0,
                       max_passes: int = 3, backend: str | None = None):
    return block_greedy_step(S, state, p, kappa, max_passes, backend=backend)


def rb_greedy_block(
    S,
    tau: float,
    p: int = 4,
    max_k: int | None = None,
    kappa: float = 2.0,
    max_passes: int = 3,
    refresh: str = "auto",
    refresh_safety: float = 100.0,
    backend: str | None = None,
) -> GreedyResult:
    """Deprecated entry point: use ``repro.api.build_basis(source=S,
    strategy="block_greedy", tau=tau, block_p=p)``.

    Block pivoting is an execution optimization of the same greedy
    reduction — as a *public* entry point it is redundant with the front
    door.  The implementation is unchanged; this wrapper delegates to it
    verbatim.
    """
    warnings.warn(
        "rb_greedy_block is deprecated: call repro.api.build_basis("
        "source=S, strategy='block_greedy', tau=tau, block_p=p) instead "
        "(identical result, unified ReducedBasis artifact)",
        DeprecationWarning,
        stacklevel=2,
    )
    return _rb_greedy_block_impl(
        S, tau, p=p, max_k=max_k, kappa=kappa, max_passes=max_passes,
        refresh=refresh, refresh_safety=refresh_safety, backend=backend,
    )


def _rb_greedy_block_impl(
    S,
    tau: float,
    p: int = 4,
    max_k: int | None = None,
    kappa: float = 2.0,
    max_passes: int = 3,
    refresh: str = "auto",
    refresh_safety: float = 100.0,
    backend: str | None = None,
) -> GreedyResult:
    """Block-greedy driver (mirrors rb_greedy semantics at block granularity).

    Note: rejected in-block candidates leave zero columns inside the Q
    buffer; ``k`` counts accepted bases but their slots are the first
    ``k + holes`` columns.  For simplicity the driver compacts Q at the end.
    """
    from repro.data.providers import materialize_source

    S = materialize_source(S)
    N, M = S.shape
    if max_k is None:
        max_k = min(N, M)
    max_k = min(max_k + p, min(N, M) + p)
    # resolve pre-jit so the cache keys on the concrete backend name
    backend = _backend.resolve_backend(backend)
    state = greedy_init(S, max_k)
    eps = float(jnp.finfo(state.norms_sq.dtype).eps)
    ref_sq = float(jnp.max(state.norms_sq))
    slots = 0  # occupied slots including holes
    while slots + p <= max_k:
        prev_k = int(state.k)
        state = state._replace(k=jnp.asarray(slots, jnp.int32))
        state = _jitted_block_step(S, state, p=p, kappa=kappa,
                                   max_passes=max_passes, backend=backend)
        n_acc = int(state.k) - slots
        slots += p
        err = float(state.errs[slots - p])  # max residual before this block
        state = state._replace(k=jnp.asarray(prev_k + n_acc, jnp.int32))
        if err < tau:
            break
        res_now = jnp.max(jnp.maximum(state.norms_sq - state.acc, 0.0))
        err_now = float(jnp.sqrt(res_now))
        if refresh == "auto" and err_now ** 2 < refresh_safety * eps * ref_sq:
            from repro.core.greedy import greedy_refresh
            state = greedy_refresh(S, state)
            ref_sq = max(float(jnp.max(state.norms_sq)), 1e-300)
        if err_now < tau or n_acc == 0:
            break

    # compact: drop zero columns from Q / matching rows of R
    Qh = jnp.asarray(state.Q)
    norms = jnp.linalg.norm(Qh, axis=0)
    keep = jnp.where(norms > 0.5)[0]  # unit columns
    k = keep.shape[0]
    Qc = jnp.zeros_like(state.Q).at[:, :k].set(Qh[:, keep])
    Rc = jnp.zeros_like(state.R).at[:k, :].set(state.R[keep, :])
    piv = jnp.zeros_like(state.pivots).at[:k].set(state.pivots[keep])
    return GreedyResult(
        Q=Qc, R=Rc, pivots=piv, errs=state.errs,
        k=jnp.asarray(k, jnp.int32),
        n_ortho_passes=jnp.zeros_like(state.pivots),
        rnorms=jnp.zeros_like(state.errs),
    )


# --------------------------------------------------------------- distributed
def make_dist_block_greedy_step(mesh: Mesh, p: int, kappa: float = 2.0,
                                max_passes: int = 3,
                                backend: str | None = None):
    """Distributed block step: one S sweep per p bases (flagship roofline)."""
    from repro.core.distributed import DistGreedyState, state_specs, \
        _axis_index

    backend = _backend.resolve_backend(backend)  # pre-jit, concrete name

    axes = tuple(mesh.axis_names)
    specs = state_specs(mesh)
    s_spec = P(None, axes)

    def local_step(S_loc, state):
        res_sq = jnp.maximum(state.norms_sq - state.acc, 0.0)
        l_vals, l_idx = jax.lax.top_k(res_sq, p)     # local top-p
        m_loc = res_sq.shape[0]
        rank = _axis_index(axes)
        g_idx = rank * m_loc + l_idx

        vals = jax.lax.all_gather(l_vals, axes).reshape(-1)   # (P*p,)
        idxs = jax.lax.all_gather(g_idx, axes).reshape(-1)
        top_vals, top_pos = jax.lax.top_k(vals, p)            # global top-p
        top_idx = idxs[top_pos]
        err = jnp.sqrt(top_vals[0])

        # fetch the p pivot columns: owner-masked psum of a (N, p) block
        owned = top_idx // m_loc == rank
        local_cols = jnp.where(
            owned[None, :],
            jnp.take(S_loc, top_idx % m_loc, axis=1),
            jnp.zeros((S_loc.shape[0], p), S_loc.dtype),
        )
        V = jax.lax.psum(local_cols, axes)                    # (N, p)

        Q = state.Q
        k = state.k
        new_qs = []
        for i in range(p):
            q, _, rnorm, _ = imgs_orthogonalize(V[:, i], Q, kappa,
                                                max_passes, backend=backend)
            Q = Q.at[:, k + i].set(q)
            new_qs.append(q)
        Qnew = jnp.stack(new_qs, axis=1)
        # ONE pass over the local shard, through the dispatch layer
        C, acc = _backend.block_sweep(Qnew, S_loc, state.acc,
                                      backend=backend)
        R = jax.lax.dynamic_update_slice_in_dim(state.R, C, k, axis=0)
        pivots = jax.lax.dynamic_update_slice_in_dim(
            state.pivots, top_idx.astype(jnp.int32), k, axis=0)
        errs = jax.lax.dynamic_update_slice_in_dim(
            state.errs, jnp.sqrt(jnp.maximum(top_vals, 0.0)), k, axis=0)
        return state._replace(Q=Q, R=R, acc=acc, pivots=pivots, errs=errs,
                              k=k + p)

    sharded = shard_map(local_step, mesh=mesh, in_specs=(s_spec, specs),
                        out_specs=specs, check_rep=False)
    return jax.jit(sharded, donate_argnums=(1,))
