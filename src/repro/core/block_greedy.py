"""Block RB-greedy: p pivots per sweep (beyond-paper §Perf optimization).

The paper's algorithm is memory-bound at one full pass over S per basis
vector (the Eq.-6.3 update c = q^H S dominates, arithmetic intensity ~1
FLOP/byte).  The committed perf trajectory confirms it: the float32
hot-path rows in BENCH_greedy.json sit at the DRAM roof (see the README
"Choosing a strategy" guide).

Block pivoting amortizes that read: select the top-p residual columns in
one sweep, orthogonalize them jointly (iterated GS, with a rank guard that
rejects candidates whose residual collapses once the earlier picks in the
block are added), then update ALL column residuals with ONE (p, N) x (N, M)
panel GEMM — one read of S per p bases, cutting the dominant memory term
by ~p.

The trade-off is pivot staleness: picks 2..p within a block are made
against residuals that ignore picks 1..i-1.  For fast-decaying (smooth /
GW) snapshot families the effect is a few extra bases at the same tau —
measured in tests/test_block_greedy.py; the blocked hot-path rows in
BENCH_greedy.json track the speedup.

This is the classical blocked column-pivoted QR idea (cf. the BLAS-3
literature the paper cites: [35] Quintana-Orti; [18] Demmel et al. CA-RRQR)
applied to the paper's Eq.-6.3 greedy bookkeeping.

Two drivers are provided, mirroring :mod:`repro.core.greedy`:

- the chunked device-resident hot path (the front door's
  ``strategy="block_greedy"``): ``chunk`` blocks run inside ONE jitted
  ``lax.while_loop`` — top-p selection, joint IMGS with the in-block rank
  guard, and the fused panel sweep
  (:func:`repro.core.backend.block_sweep`) all execute in the trace, and
  the host syncs only a stop-code scalar per chunk,
- :func:`rb_greedy_block_stepwise` — the eager per-block driver (one
  jitted block step + host sync per block), kept as the parity oracle.
"""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as _backend
from repro.core.greedy import (
    GreedyResult,
    GreedyState,
    STOP_FLOOR,
    STOP_NONE,
    STOP_RANK,
    STOP_REFRESH,
    STOP_TAU,
    _validate_resident_tree,
    floor_estimate,
    greedy_init,
    greedy_refresh,
    imgs_orthogonalize,
    load_resident_checkpoint,
    panel_imgs_orthogonalize,
    resident_state_from_tree,
    save_resident_checkpoint,
)


def _ortho_block(S, Q, top_idx, slots, p, kappa, max_passes, eps, scale,
                 backend, panel):
    """Orthogonalize one block of p pivot candidates against ``Q`` (and
    against each other), with the in-block rank guard.

    ``panel=True`` (the default) runs the BLAS-3 panel path
    (:func:`repro.core.greedy.panel_imgs_orthogonalize`): one iterated
    (k, N) x (N, p) panel projection for the whole block plus a
    within-panel sequential sweep — k*p*N GEMM work instead of p separate
    k*N GEMV chains.  ``panel=False`` keeps the pre-panel path (p
    sequential :func:`imgs_orthogonalize` calls with fixed-slot writes);
    both span the same space and differ only in float summation order.

    Returns ``(Q, Qnew, oks, rnorms, n_passes)`` with the block written
    into ``Q`` at ``slots..slots+p-1`` (rejected candidates leave zero
    "hole" columns).
    """
    thresh = 50.0 * eps * scale
    if panel and p > 1:
        V = jnp.take(S, top_idx, axis=1)            # (N, p)
        Qnew, oks, rnorms, npasses = panel_imgs_orthogonalize(
            V, Q, kappa, max_passes, thresh=thresh, backend=backend
        )
        slots_i = jnp.asarray(slots, jnp.int32)
        Q = jax.lax.dynamic_update_slice(
            Q, Qnew, (jnp.zeros((), jnp.int32), slots_i)
        )
        return Q, Qnew, oks, rnorms, npasses
    qs, oks, rnorms, npasses = [], [], [], []
    for i in range(p):  # p is small and static
        v = jnp.take(S, top_idx[i], axis=1)
        q, _, rnorm, n_pass = imgs_orthogonalize(
            v, Q, kappa, max_passes, backend=backend
        )
        ok = rnorm > thresh
        q = jnp.where(ok, q, jnp.zeros_like(q))
        # fixed-slot write at slots+i; rejected candidates leave zero
        # columns ("holes") that the driver compacts at the end
        Q = Q.at[:, slots + i].set(q)
        qs.append(q)
        oks.append(ok)
        rnorms.append(rnorm)
        npasses.append(n_pass)
    return (
        Q,
        jnp.stack(qs, axis=1),                      # rejected cols zero
        jnp.asarray(oks),
        jnp.stack([jnp.asarray(r) for r in rnorms]),
        jnp.asarray(npasses, jnp.int32),
    )


def block_greedy_step(S, state: GreedyState, p: int, kappa: float = 2.0,
                      max_passes: int = 3,
                      backend: str | None = None,
                      scale=None, panel: bool = True) -> GreedyState:
    """Add up to p bases with a single Eq.-6.3 sweep over S.

    Block orthogonalization and the blocked sweep route through
    :mod:`repro.core.backend` (the sweep's fused kernel is
    :func:`repro.core.backend.block_sweep`; ``panel=True`` additionally
    runs the block's orthogonalization through the BLAS-3
    :func:`repro.core.backend.panel_project` panel — see
    :func:`_ortho_block`).  This is the eager per-block step used by
    :func:`rb_greedy_block_stepwise`; the chunked driver runs the same
    math inside a ``lax.while_loop`` (see :func:`_block_chunk_impl`).

    ``scale`` is the rank guard's reference column scale.  The greedy
    family fixes it at init (``sqrt(max |s_i|^2)``) so the guard measures
    candidates against the ORIGINAL data scale even after an Eq.-(6.3)
    refresh shrinks ``norms_sq``; ``None`` falls back to the in-state
    value (pre-PR-4 behavior, correct when no refresh has happened).
    """
    res_sq = jnp.maximum(state.norms_sq - state.acc, 0.0)
    top_vals, top_idx = jax.lax.top_k(res_sq, p)

    eps = jnp.finfo(state.norms_sq.dtype).eps
    if scale is None:
        scale = jnp.sqrt(jnp.max(state.norms_sq))

    k = state.k
    Q, Qnew, accepted, _, _ = _ortho_block(
        S, state.Q, top_idx, k, p, kappa, max_passes, eps, scale,
        backend, panel,
    )
    # ONE pass over S: (p, M) block sweep through the dispatch layer
    C, acc = _backend.block_sweep(Qnew, S, state.acc, backend=backend)

    R = jax.lax.dynamic_update_slice_in_dim(state.R, C, k, axis=0)
    pivots = jax.lax.dynamic_update_slice_in_dim(
        state.pivots,
        jnp.where(accepted, top_idx, -1).astype(jnp.int32),
        k, axis=0,
    )
    errs = jax.lax.dynamic_update_slice_in_dim(
        state.errs, jnp.sqrt(jnp.maximum(top_vals, 0.0)), k, axis=0
    )
    n_acc = jnp.sum(accepted.astype(jnp.int32))
    return state._replace(
        Q=Q, R=R, acc=acc, pivots=pivots, errs=errs, k=k + n_acc,
    )


@functools.partial(
    jax.jit, static_argnames=("p", "kappa", "max_passes", "backend", "panel")
)
def _jitted_block_step(S, state, p: int, kappa: float = 2.0,
                       max_passes: int = 3, backend: str | None = None,
                       scale=None, panel: bool = True):
    return block_greedy_step(S, state, p, kappa, max_passes,
                             backend=backend, scale=scale, panel=panel)


def rb_greedy_block(
    S,
    tau: float,
    p: int = 4,
    max_k: int | None = None,
    kappa: float = 2.0,
    max_passes: int = 3,
    refresh: str = "auto",
    refresh_safety: float = 100.0,
    backend: str | None = None,
) -> GreedyResult:
    """Deprecated entry point: use ``repro.api.build_basis(source=S,
    strategy="block_greedy", tau=tau, block_p=p)``.

    Block pivoting is an execution optimization of the same greedy
    reduction — as a *public* entry point it is redundant with the front
    door.  This wrapper delegates to the same chunked driver the front
    door uses.
    """
    warnings.warn(
        "rb_greedy_block is deprecated: call repro.api.build_basis("
        "source=S, strategy='block_greedy', tau=tau, block_p=p) instead "
        "(identical result, unified ReducedBasis artifact)",
        DeprecationWarning,
        stacklevel=2,
    )
    return _rb_greedy_block_impl(
        S, tau, p=p, max_k=max_k, kappa=kappa, max_passes=max_passes,
        refresh=refresh, refresh_safety=refresh_safety, backend=backend,
    )


# ------------------------------------------------ chunked blocked driver ----


def _block_chunk_impl(
    S,
    state,
    tau,
    scale,
    ref_sq,
    refresh_safety,
    chunk: int,
    p: int,
    kappa: float = 2.0,
    max_passes: int = 3,
    backend: str | None = None,
    check_refresh: bool = True,
    panel: bool = True,
):
    """Run up to ``chunk`` blocked greedy iterations device-resident.

    Each ``lax.while_loop`` round is one block: top-p residual selection,
    joint IMGS of the p pivot columns against Q and against the earlier
    in-block picks (by default through the BLAS-3 panel path — see
    :func:`_ortho_block`; ``panel=False`` keeps the p-sequential
    fixed-slot form), the in-block rank guard (a candidate whose
    orthogonalization residual is rounding noise becomes a zero "hole"
    column), and ONE fused panel sweep over S
    (:func:`repro.core.backend.block_sweep`).  ``state.k`` counts occupied
    SLOTS (holes included); the driver compacts at the end.

    Stops on the stepwise drivers' host events, reported as stop codes so
    the host syncs one scalar per chunk:

      STOP_TAU      the max residual fell below tau BEFORE a block — the
                    block is not added (no trailing drop needed),
      STOP_RANK     every candidate in a block was rank-rejected,
      STOP_REFRESH  the post-block residual neared the Eq.-(6.3)
                    cancellation floor.
    """
    max_slots = state.Q.shape[1]
    eps = jnp.finfo(state.norms_sq.dtype).eps
    rdt = state.norms_sq.dtype

    def cond(carry):
        st, n, stop = carry
        return (stop == STOP_NONE) & (n < chunk) & (st.k + p <= max_slots)

    def add_block(st, top_vals, top_idx):
        slots = st.k
        Q, Qnew, oks_arr, rnorms, npasses = _ortho_block(
            S, st.Q, top_idx, slots, p, kappa, max_passes, eps, scale,
            backend, panel,
        )
        C, acc = _backend.block_sweep(Qnew, S, st.acc, backend=backend)
        st = st._replace(
            Q=Q,
            R=jax.lax.dynamic_update_slice_in_dim(st.R, C, slots, axis=0),
            acc=acc,
            pivots=jax.lax.dynamic_update_slice_in_dim(
                st.pivots,
                jnp.where(oks_arr, top_idx, -1).astype(jnp.int32),
                slots, axis=0,
            ),
            errs=jax.lax.dynamic_update_slice_in_dim(
                st.errs,
                jnp.sqrt(jnp.maximum(top_vals, 0.0)).astype(rdt),
                slots, axis=0,
            ),
            rnorms=jax.lax.dynamic_update_slice_in_dim(
                st.rnorms, rnorms.astype(rdt), slots, axis=0,
            ),
            n_passes=jax.lax.dynamic_update_slice_in_dim(
                st.n_passes, npasses.astype(jnp.int32), slots, axis=0,
            ),
            k=slots + p,
        )
        n_ok = jnp.sum(oks_arr.astype(jnp.int32))
        res_after = jnp.maximum(jnp.max(st.norms_sq - st.acc), 0.0)
        # Post-block tau stop BEFORE the refresh trigger (the rb_greedy
        # family's precedence: a tracked residual below tau means
        # converged, even when it sits at the Eq.-(6.3) floor — matching
        # the stepwise oracle's `err_now < tau` break.  Without it a
        # floored-but-unconverged f32 build refreshes forever, each
        # refresh reviving a residual the orthogonalization noise floor
        # cannot actually reduce).
        tau_hit = res_after < tau * tau
        refresh_hit = check_refresh & (res_after
                                       < refresh_safety * eps * ref_sq)
        stop = jnp.where(
            n_ok == 0, STOP_RANK,
            jnp.where(tau_hit, STOP_TAU,
                      jnp.where(refresh_hit, STOP_REFRESH, STOP_NONE)),
        ).astype(jnp.int32)
        return st, stop

    def body(carry):
        st, n, _ = carry
        res_sq = jnp.maximum(st.norms_sq - st.acc, 0.0)
        top_vals, top_idx = jax.lax.top_k(res_sq, p)
        err = jnp.sqrt(top_vals[0])
        st, stop = jax.lax.cond(
            err >= tau,
            lambda s: add_block(s, top_vals, top_idx),
            lambda s: (s, jnp.asarray(STOP_TAU, jnp.int32)),
            st,
        )
        return (st, n + 1, stop)

    state, n_done, stop = jax.lax.while_loop(
        cond, body,
        (state, jnp.asarray(0, jnp.int32), jnp.asarray(STOP_NONE, jnp.int32)),
    )
    return state, n_done, stop


_BLOCK_CHUNK_STATICS = (
    "chunk", "p", "kappa", "max_passes", "backend", "check_refresh",
    "panel",
)

# Non-donating variant: supports repeated application to one state
# (benchmarks time the hot loop this way).
_block_chunk = jax.jit(_block_chunk_impl, static_argnames=_BLOCK_CHUNK_STATICS)

# The driver's variant donates the state pytree so Q/R/acc buffers are
# reused across chunks instead of copied (see repro.core.greedy).
_block_chunk_donated = jax.jit(
    _block_chunk_impl, static_argnames=_BLOCK_CHUNK_STATICS,
    donate_argnums=(1,),
)


def _compact_result(state, max_k: int, stop: int = STOP_NONE) -> GreedyResult:
    """Drop hole columns (rejected in-block candidates) from the slot
    buffers: keep unit columns of Q and their matching R rows / pivots /
    errs / diagnostics, capped at ``max_k`` accepted bases (the slot
    buffers carry +p overrun headroom, and the final block may push the
    accepted count past the cap — the basis is nested, so truncation is
    exact).

    Works on any state with Q/R/pivots/errs fields (GreedyState and the
    distributed DistGreedyState both qualify); per-basis diagnostics are
    compacted when present.
    """
    Qh = jnp.asarray(state.Q)
    norms = jnp.linalg.norm(Qh, axis=0)
    keep = jnp.where(norms > 0.5)[0][:max_k]  # unit columns, capped
    k = keep.shape[0]
    Qc = jnp.zeros_like(state.Q).at[:, :k].set(Qh[:, keep])
    R = jnp.asarray(state.R)
    Rc = jnp.zeros_like(R).at[:k, :].set(R[keep, :])
    piv = jnp.zeros_like(state.pivots).at[:k].set(state.pivots[keep])
    errs = jnp.zeros_like(state.errs).at[:k].set(state.errs[keep])
    rnorms_src = getattr(state, "rnorms", None)
    if rnorms_src is not None:
        rnorms = jnp.zeros_like(rnorms_src).at[:k].set(rnorms_src[keep])
        n_passes = jnp.zeros_like(state.n_passes).at[:k].set(
            state.n_passes[keep])
    else:
        rnorms = jnp.zeros_like(errs)
        n_passes = jnp.zeros_like(piv)
    return GreedyResult(
        Q=Qc, R=Rc, pivots=piv, errs=errs,
        k=jnp.asarray(k, jnp.int32),
        n_ortho_passes=n_passes,
        rnorms=rnorms,
        stop=stop,
    )


def _rb_greedy_block_impl(
    S,
    tau: float,
    p: int = 4,
    max_k: int | None = None,
    kappa: float = 2.0,
    max_passes: int = 3,
    refresh: str = "auto",
    refresh_safety: float = 100.0,
    backend: str | None = None,
    chunk: int = 4,
    callback=None,
    panel: bool = True,
    adaptive: bool = False,
    diagnostics: dict | None = None,
    checkpoint_dir: str | None = None,
    resume: bool = False,
) -> GreedyResult:
    """Chunked device-resident blocked driver (the front door's
    ``strategy="block_greedy"``).

    ``chunk`` BLOCKS (i.e. up to ``chunk * p`` bases) run inside one jitted
    ``lax.while_loop``; the host syncs only the (n_done, stop) scalars at
    chunk boundaries.  Selects the same pivots as
    :func:`rb_greedy_block_stepwise` (asserted in
    tests/test_block_greedy.py) at ~chunk x fewer dispatches.

    ``panel`` (default True) routes each block's orthogonalization through
    the BLAS-3 panel path (:func:`_ortho_block`); ``panel=False`` keeps
    the pre-panel p-sequential form (same span, different float summation
    order).

    ``adaptive`` treats ``p`` as a CEILING and retunes the live panel
    width between chunks from the in-block rank guard's rejection rate —
    the stale-pivot signal: rejections mean picks 2..p were made against
    residuals that ignored picks 1..i-1 and collapsed once they arrived,
    so the width halves; a clean chunk grows it back (doubling, capped at
    ``p``).  The width trajectory is recorded in ``diagnostics`` (key
    ``"p_trajectory"``: one ``{slots, p, rejected}`` entry per chunk)
    when a dict is passed — the front door forwards it into the artifact
    provenance.

    ``callback(state)`` fires once per chunk (the slot arrays carry the
    per-slot history up to ``state.k``, holes included); with a callback
    set the chunk does not donate the state buffers, mirroring
    :func:`repro.core.greedy.rb_greedy`.

    ``checkpoint_dir``/``resume`` mirror :func:`repro.core.greedy.rb_greedy`
    (state + done/stop persisted after each chunk's stop handling; the
    adaptive live width rides along, the diagnostics trajectory does not —
    it is provenance, not replay state).

    Note: rejected in-block candidates leave zero "hole" columns inside the
    Q slot buffer during the build; the driver compacts them away at the
    end and caps the result at ``max_k``, so the returned ``k`` counts
    accepted bases only and never exceeds ``max_k``.
    """
    from repro.data.providers import materialize_source

    S = materialize_source(S)
    N, M = S.shape
    if p < 1:
        raise ValueError(f"block_p must be >= 1, got {p}")
    p = min(p, min(N, M))
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    if max_k is None:
        max_k = min(N, M)
    max_k = min(max_k, N, M)  # the accepted-basis cap
    max_slots = min(max_k + p, min(N, M) + p)  # + hole headroom (max p)
    # resolve pre-jit so the cache keys on the concrete backend name
    backend = _backend.resolve_backend(backend)
    state = greedy_init(S, max_slots)
    rdt = state.norms_sq.dtype
    eps = float(jnp.finfo(rdt).eps)
    ref_sq = float(jnp.max(state.norms_sq))
    scale = ref_sq ** 0.5  # fixed global column scale for the rank guard
    done = False
    final_stop = STOP_NONE
    p_live = p  # adaptive: current width, halved/regrown between chunks
    seq = 0
    if checkpoint_dir is not None:
        from repro.checkpoint.io import latest_step

        tree = load_resident_checkpoint(checkpoint_dir) if resume else None
        if tree is not None:
            _validate_resident_tree(tree, N, M, max_slots, state.Q.dtype,
                                    "resume checkpoint")
            st_host, ref_sq, scale, done, final_stop = \
                resident_state_from_tree(tree)
            state = GreedyState(*(jnp.asarray(x) for x in st_host))
            p_live = int(tree.get("p_live", p))
        seq = latest_step(checkpoint_dir) or 0
    tau_d = jnp.asarray(tau, rdt)
    scale_d = jnp.asarray(scale, rdt)
    safety_d = jnp.asarray(refresh_safety, rdt)
    ref_sq_d = jnp.asarray(ref_sq, rdt)
    # a callback may retain states (checkpointing); donation would
    # invalidate those retained buffers on accelerators
    chunk_fn = _block_chunk if callback is not None else \
        _block_chunk_donated
    trajectory = [] if diagnostics is not None else None
    while not done and int(state.k) + p_live <= max_slots:
        slots_before = int(state.k)
        state, n_done, stop = chunk_fn(
            S, state, tau_d, scale_d, ref_sq_d, safety_d,
            chunk=chunk, p=p_live, kappa=kappa, max_passes=max_passes,
            backend=backend, check_refresh=(refresh == "auto"),
            panel=panel,
        )
        if callback is not None:
            callback(state)
        stop = int(stop)
        if adaptive or trajectory is not None:
            slots_added = int(state.k) - slots_before
            rejected = (
                int(np.count_nonzero(np.asarray(
                    state.pivots[slots_before:slots_before + slots_added]
                ) < 0)) if slots_added else 0
            )
            if trajectory is not None:
                trajectory.append({"slots": slots_before, "p": p_live,
                                   "rejected": rejected})
            if adaptive and slots_added:
                rate = rejected / slots_added
                if rate > 0.25 and p_live > 1:
                    # staleness bites: most in-block picks collapse once
                    # the earlier ones land — narrow the panel
                    p_live = max(1, p_live // 2)
                elif rejected == 0 and p_live < p:
                    p_live = min(p, p_live * 2)
        if stop == STOP_TAU or stop == STOP_RANK:
            done, final_stop = True, stop
        elif stop == STOP_REFRESH:
            state = greedy_refresh(S, state)
            ref_sq = max(float(jnp.max(state.norms_sq)), 1e-300)
            ref_sq_d = jnp.asarray(ref_sq, rdt)
            if ref_sq ** 0.5 < tau:
                done, final_stop = True, STOP_TAU
            elif ref_sq ** 0.5 <= floor_estimate(eps, scale, int(state.k)):
                done, final_stop = True, STOP_FLOOR
        if not done and int(state.k) + p_live > max_slots:
            done = True  # out of slots; final_stop stays STOP_NONE
        if checkpoint_dir is not None:
            seq = save_resident_checkpoint(
                checkpoint_dir, seq, state, ref_sq, scale, done, final_stop,
                extra={"p_live": p_live})
    if diagnostics is not None:
        diagnostics["p_trajectory"] = trajectory
    return _compact_result(state, max_k, final_stop)


# --------------------------------------------------- stepwise block oracle --


def rb_greedy_block_stepwise(
    S,
    tau: float,
    p: int = 4,
    max_k: int | None = None,
    kappa: float = 2.0,
    max_passes: int = 3,
    refresh: str = "auto",
    refresh_safety: float = 100.0,
    backend: str | None = None,
    panel: bool = True,
) -> GreedyResult:
    """The eager per-block driver: one jitted block step + host syncs per
    block.  Kept verbatim as the parity oracle for the chunked driver
    (mirroring :func:`repro.core.greedy.rb_greedy_stepwise`).

    Note: rejected in-block candidates leave zero columns inside the Q
    buffer; ``k`` counts accepted bases but their slots are the first
    ``k + holes`` columns.  For simplicity the driver compacts Q at the end.
    """
    from repro.data.providers import materialize_source

    S = materialize_source(S)
    N, M = S.shape
    if max_k is None:
        max_k = min(N, M)
    max_k_req = min(max_k, N, M)  # the accepted-basis cap
    max_k = min(max_k + p, min(N, M) + p)  # slot buffer incl. hole headroom
    # resolve pre-jit so the cache keys on the concrete backend name
    backend = _backend.resolve_backend(backend)
    state = greedy_init(S, max_k)
    eps = float(jnp.finfo(state.norms_sq.dtype).eps)
    ref_sq = float(jnp.max(state.norms_sq))
    # fixed global column scale for the rank guard (the greedy-family
    # convention; see block_greedy_step's docstring)
    scale_d = jnp.asarray(ref_sq ** 0.5, state.norms_sq.dtype)
    scale = ref_sq ** 0.5
    final_stop = STOP_NONE
    slots = 0  # occupied slots including holes
    while slots + p <= max_k:
        prev_k = int(state.k)
        state = state._replace(k=jnp.asarray(slots, jnp.int32))
        state = _jitted_block_step(S, state, p=p, kappa=kappa,
                                   max_passes=max_passes, backend=backend,
                                   scale=scale_d, panel=panel)
        n_acc = int(state.k) - slots
        slots += p
        err = float(state.errs[slots - p])  # max residual before this block
        state = state._replace(k=jnp.asarray(prev_k + n_acc, jnp.int32))
        if err < tau:
            final_stop = STOP_TAU
            break
        res_now = jnp.max(jnp.maximum(state.norms_sq - state.acc, 0.0))
        err_now = float(jnp.sqrt(res_now))
        if refresh == "auto" and err_now ** 2 < refresh_safety * eps * ref_sq:
            state = greedy_refresh(S, state)
            ref_sq = max(float(jnp.max(state.norms_sq)), 1e-300)
            # the post-refresh EXACT residual decides convergence (same
            # check as rb_greedy_stepwise; the pre-PR-4 block driver
            # missed it and appended one below-tau block after a refresh)
            if ref_sq ** 0.5 < tau:
                final_stop = STOP_TAU
                break
            if ref_sq ** 0.5 <= floor_estimate(eps, scale,
                                               int(state.k)):
                final_stop = STOP_FLOOR
                break
        if err_now < tau or n_acc == 0:
            final_stop = STOP_TAU if err_now < tau else STOP_RANK
            break

    # compact: drop zero columns from Q / matching rows of R, cap at the
    # requested max_k (shared with the chunked driver)
    return _compact_result(state, max_k_req, final_stop)
