"""Backend dispatch for the greedy hot-loop primitives.

Every greedy driver in this repo — single-device (:mod:`repro.core.greedy`),
blocked (:mod:`repro.core.block_greedy`) and column-sharded
(:mod:`repro.core.distributed`) — spends its time in exactly two primitives:

  pivot_update   the paper's Eq.-(6.3) sweep: ``c = q^H S``,
                 ``acc += |c|^2``, masked residual argmax — one read of the
                 snapshot shard per basis vector (Fig. 6.1a),
  project_pass   one classical-GS projection ``c = Q^H v``,
                 ``v' = v - Q c`` — the body of Hoffmann's iterated GS
                 (Fig. 6.1b).

This module is the single point where those primitives are routed to an
implementation:

  ``pallas``   the fused Pallas TPU kernels
               (:mod:`repro.kernels.greedy_update`,
               :mod:`repro.kernels.imgs_project`) — one HBM pass, argmax
               masking for padded columns, split re/im planes for complex;
               off-TPU they run in interpret mode (slow, parity-testing
               only),
  ``xla``      ``jnp`` ops fused by XLA — the fast path on CPU/GPU.
               Complex inputs run on split re/im planes (four real GEMVs),
               mirroring the Pallas kernels: XLA lowers a complex GEMV to a
               scalar loop ~10x slower than its real counterpart,
  ``xla_ref``  the literal reference ops (:mod:`..kernels.*.ref`, complex
               GEMV included) — the seed implementation, kept as the
               numerical oracle and the benchmark baseline.

Dispatch contract
-----------------

* Selection happens at **trace time** (it is a plain Python decision), so a
  backend choice is baked into each jitted computation; drivers thread
  ``backend=`` through as a static argument.
* Precedence: explicit ``backend=`` argument > ``REPRO_GREEDY_BACKEND``
  environment variable > :func:`set_default_backend` > ``"auto"``
  (``pallas`` iff the default JAX backend is TPU).
* Both implementations satisfy the same numerical contract (identical
  signatures and semantics, see ``kernels/*/ref.py``); pivot-for-pivot
  parity of whole drivers is asserted in ``tests/test_backend.py``.
* Six primitives are dispatched: ``pivot_update`` and ``project_pass``
  (above), the two blocked panel forms used by the block-pivoted
  drivers: ``block_sweep`` (the BLAS-3 Eq.-(6.3) sweep;
  :mod:`repro.kernels.block_sweep` — one read of S per p bases) and
  ``panel_project`` (the BLAS-3 classical-GS projection of a whole (N, p)
  candidate panel; :mod:`repro.kernels.imgs_panel` — one read of Q per
  panel instead of per candidate), plus the two sketch GEMMs the
  randomized range-finder (:mod:`repro.core.randomized`) streams tiles
  through: ``sketch_fold`` (``Y += T @ Omega``) and ``sketch_project``
  (``T^H @ Y``).  Both are pure GEMMs, already MXU/BLAS-3-shaped, so the
  ``pallas`` route shares the ``xla`` plane-split form (XLA emits the
  optimal GEMM; there is nothing left for a hand-written kernel to fuse)
  while keeping the no-complex-dot HLO guarantee.
* Each hot primitive also has a ``batched_*`` form carrying a leading
  B-lane axis (stacked per-lane snapshots, or one shared snapshot matrix
  swept by all lanes in a single fused GEMM) — the building blocks of the
  lockstep many-basis driver (:mod:`repro.core.batch_greedy`); see the
  "batched (B-lane) forms" section below.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.block_sweep.ops import block_sweep as _pallas_block
from repro.kernels.block_sweep.ref import block_sweep_ref as _xla_block
from repro.kernels.greedy_update.ops import greedy_update as _pallas_pivot
from repro.kernels.greedy_update.ref import greedy_update_ref as _xla_pivot
from repro.kernels.imgs_panel.ops import imgs_panel as _pallas_panel
from repro.kernels.imgs_panel.ref import imgs_panel_ref as _xla_panel
from repro.kernels.imgs_project.ops import imgs_project as _pallas_project
from repro.kernels.imgs_project.ref import imgs_project_ref as _xla_project

VALID_BACKENDS = ("auto", "xla", "pallas", "xla_ref")

_ENV_VAR = "REPRO_GREEDY_BACKEND"
_default_backend = "auto"


def set_default_backend(name: str) -> None:
    """Set the process-wide default backend (overridden by env/explicit)."""
    global _default_backend
    if name not in VALID_BACKENDS:
        raise ValueError(
            f"unknown greedy backend {name!r}; valid: {VALID_BACKENDS}"
        )
    _default_backend = name


def default_backend() -> str:
    return _default_backend


def resolve_backend(backend: str | None = None) -> str:
    """Resolve a backend request to a concrete implementation name.

    Returns ``"pallas"``, ``"xla"`` or ``"xla_ref"``.  ``None`` consults
    the ``REPRO_GREEDY_BACKEND`` env var, then :func:`default_backend`; the
    ``"auto"`` policy picks the fused Pallas kernels exactly when running
    on TPU (interpret-mode Pallas is a debugging tool, not a fast path).
    """
    if backend is None:
        backend = os.environ.get(_ENV_VAR) or _default_backend
    if backend not in VALID_BACKENDS:
        raise ValueError(
            f"unknown greedy backend {backend!r}; valid: {VALID_BACKENDS}"
        )
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def _plane_split_pivot(q, S, acc, norms_sq):
    """Complex Eq.-(6.3) sweep as four real GEMVs on split re/im planes.

    Mirrors the Pallas kernel's plane decomposition (TPU MXUs are real) —
    and is the fast path on CPU/GPU too: XLA lowers a complex GEMV to a
    scalar loop that is an order of magnitude slower than its real GEMVs
    (measured 709 ms vs 66 ms for c64 at N=4096, M=16384 on 1 CPU core).
    Same math as ``q.conj() @ S`` up to float summation order.
    """
    qr, qi = q.real, q.imag
    Sr, Si = S.real, S.imag
    cr = qr @ Sr + qi @ Si   # Re(q^H S)
    ci = qr @ Si - qi @ Sr   # Im(q^H S)
    c = jax.lax.complex(cr, ci).astype(S.dtype)
    acc_out = acc + (cr * cr + ci * ci).astype(acc.dtype)
    res = norms_sq - acc_out
    return c, acc_out, jnp.max(res), jnp.argmax(res).astype(jnp.int32)


def _plane_split_project(v, Q):
    """Complex GS projection pass on split re/im planes (see
    :func:`_plane_split_pivot` for why)."""
    Qr, Qi = Q.real, Q.imag
    vr, vi = v.real, v.imag
    # c = Q^H v = (Qr - i Qi)^T (vr + i vi)
    cr = vr @ Qr + vi @ Qi
    ci = vi @ Qr - vr @ Qi
    # v' = v - Q c
    vr_out = vr - (Qr @ cr - Qi @ ci)
    vi_out = vi - (Qr @ ci + Qi @ cr)
    return (
        jax.lax.complex(vr_out, vi_out).astype(v.dtype),
        jax.lax.complex(cr, ci).astype(Q.dtype),
    )


def pivot_update(
    q: jax.Array,
    S: jax.Array,
    acc: jax.Array,
    norms_sq: jax.Array,
    backend: str | None = None,
):
    """Fused Eq.-(6.3) update: ``c = q^H S``, ``acc += |c|^2``, argmax.

    Returns ``(c, acc_out, max_res, argmax)`` — identical semantics on both
    backends (see :func:`repro.kernels.greedy_update.ref.greedy_update_ref`).
    ``max_res``/``argmax`` describe the residual AFTER this update, i.e. the
    next iteration's pivot; drivers that re-derive the pivot from
    ``norms_sq - acc`` may ignore them (XLA dead-code-eliminates the ref
    computation; the Pallas kernel produces them for free in the same pass).
    Complex snapshots run on split re/im planes under either backend.
    """
    resolved = resolve_backend(backend)
    if resolved == "pallas":
        return _pallas_pivot(q, S, acc, norms_sq)
    if resolved == "xla" and jnp.iscomplexobj(S):
        return _plane_split_pivot(q, S, acc, norms_sq)
    return _xla_pivot(q, S, acc, norms_sq)


def project_pass(
    v: jax.Array,
    Q: jax.Array,
    backend: str | None = None,
):
    """One classical-GS pass: returns ``(v - Q Q^H v, Q^H v)``."""
    resolved = resolve_backend(backend)
    if resolved == "pallas":
        return _pallas_project(v, Q)
    if resolved == "xla" and jnp.iscomplexobj(Q):
        return _plane_split_project(v, Q)
    return _xla_project(v, Q)


def _plane_split_panel_project(V, Q):
    """Complex classical-GS PANEL projection on split re/im planes (see
    :func:`_plane_split_pivot` for why: XLA lowers complex matmuls on CPU
    to scalar loops an order of magnitude slower than their real
    counterparts).  Same math as ``(V - Q (Q^H V), Q^H V)`` up to float
    summation order — four real GEMMs per half instead of two complex
    GEMMs."""
    Qr, Qi = Q.real, Q.imag
    Vr, Vi = V.real, V.imag
    # C = Q^H V = (Qr - i Qi)^T (Vr + i Vi)
    Cr = Qr.T @ Vr + Qi.T @ Vi
    Ci = Qr.T @ Vi - Qi.T @ Vr
    # V' = V - Q C
    Vr_out = Vr - (Qr @ Cr - Qi @ Ci)
    Vi_out = Vi - (Qr @ Ci + Qi @ Cr)
    return (
        jax.lax.complex(Vr_out, Vi_out).astype(V.dtype),
        jax.lax.complex(Cr, Ci).astype(Q.dtype),
    )


def panel_project(
    V: jax.Array,
    Q: jax.Array,
    backend: str | None = None,
):
    """One classical-GS PANEL pass: returns ``(V - Q Q^H V, Q^H V)``.

    The BLAS-3 form of :func:`project_pass` applied to a whole (N, p)
    candidate panel at once — one read of Q per panel instead of per
    candidate, so k*p*N GEMM work replaces p separate k*N GEMV chains
    (the panel-factorization idea of the blocked-QR literature; see
    :mod:`repro.kernels.imgs_panel`).  ``pallas`` routes to the fused
    panel kernel; ``xla`` runs the ``jnp`` GEMM form with complex inputs
    on split re/im planes (mirroring :func:`project_pass`); ``xla_ref``
    is the literal reference
    (:func:`repro.kernels.imgs_panel.ref.imgs_panel_ref`, complex GEMM
    included).
    """
    resolved = resolve_backend(backend)
    if resolved == "pallas":
        return _pallas_panel(V, Q)
    if resolved == "xla" and jnp.iscomplexobj(Q):
        return _plane_split_panel_project(V, Q)
    return _xla_panel(V, Q)


def _plane_split_block_sweep(Qnew, S, acc):
    """Complex blocked Eq.-(6.3) sweep as four real GEMMs on split re/im
    planes (see :func:`_plane_split_pivot` for why: XLA lowers complex
    matmuls on CPU to scalar loops an order of magnitude slower than their
    real counterparts).  Same math as ``Qnew.conj().T @ S`` up to float
    summation order."""
    Qr, Qi = Qnew.real, Qnew.imag
    Sr, Si = S.real, S.imag
    # C = Qnew^H S = (Qr - i Qi)^T (Sr + i Si)
    Cr = Qr.T @ Sr + Qi.T @ Si
    Ci = Qr.T @ Si - Qi.T @ Sr
    C = jax.lax.complex(Cr, Ci).astype(S.dtype)
    acc_out = acc + jnp.sum(Cr * Cr + Ci * Ci, axis=0).astype(acc.dtype)
    return C, acc_out


def block_sweep(
    Qnew: jax.Array,
    S: jax.Array,
    acc: jax.Array,
    backend: str | None = None,
):
    """Blocked Eq.-(6.3) sweep: ``C = Qnew^H S``, ``acc += sum_i |C_i|^2``.

    One read of S per p bases — the block-greedy amortization that turns
    the memory-roof-bound BLAS-2 pivot sweep into a BLAS-3 panel GEMM.
    ``pallas`` routes to the fused panel kernel
    (:mod:`repro.kernels.block_sweep`); ``xla`` runs the ``jnp`` GEMM form,
    with complex inputs on split re/im planes (four real GEMMs, mirroring
    :func:`pivot_update`); ``xla_ref`` is the literal reference
    (:func:`repro.kernels.block_sweep.ref.block_sweep_ref`, complex GEMM
    included).
    """
    resolved = resolve_backend(backend)
    if resolved == "pallas":
        return _pallas_block(Qnew, S, acc)
    if resolved == "xla" and jnp.iscomplexobj(S):
        return _plane_split_block_sweep(Qnew, S, acc)
    return _xla_block(Qnew, S, acc)


def _plane_split_sketch_fold(T, Omega, Y):
    """Complex sketch fold ``Y += T @ Omega`` as four real GEMMs on split
    re/im planes (see :func:`_plane_split_pivot` for why: XLA lowers
    complex matmuls on CPU to scalar loops an order of magnitude slower
    than their real counterparts).  Same math as ``Y + T @ Omega`` up to
    float summation order."""
    Tr, Ti = T.real, T.imag
    Or, Oi = Omega.real, Omega.imag
    Yr = Y.real + (Tr @ Or - Ti @ Oi)
    Yi = Y.imag + (Tr @ Oi + Ti @ Or)
    return jax.lax.complex(Yr, Yi).astype(Y.dtype)


def sketch_fold(
    T: jax.Array,
    Omega: jax.Array,
    Y: jax.Array,
    backend: str | None = None,
):
    """One tile's contribution to the randomized sketch: ``Y + T @ Omega``.

    ``T`` is an (N, m) snapshot tile, ``Omega`` the matching (m, ell) test
    block, ``Y`` the running (N, ell) sketch ``Y = S @ Omega`` — the
    single-pass range-finder accumulation of :mod:`repro.core.randomized`.
    ``xla``/``pallas`` run complex inputs on split re/im planes (four real
    GEMMs, mirroring :func:`block_sweep`; the sketch GEMM is already
    BLAS-3/MXU-shaped, so there is no dedicated Pallas kernel);
    ``xla_ref`` is the literal form, complex GEMM included.
    """
    resolved = resolve_backend(backend)
    if resolved != "xla_ref" and jnp.iscomplexobj(T):
        return _plane_split_sketch_fold(T, Omega, Y)
    return Y + T @ Omega


# ------------------------------------------------ batched (B-lane) forms ----
# Every primitive above gains a leading batch axis so B independent builds
# run as ONE dispatch (:mod:`repro.core.batch_greedy`).  Two layouts:
#
#   stacked   S: (B, N, M) — one snapshot matrix per lane.  The ``xla``
#             route is ``jax.vmap`` of the scalar route, which lowers to a
#             batched dot_general whose per-lane floats are BITWISE equal
#             to the scalar GEMV/GEMM (both operands carry the batch axis,
#             so XLA runs the same per-lane kernel; asserted in
#             tests/test_batch_greedy.py).
#   shared    S: (N, M) — one snapshot matrix, B basis states (e.g. a tau
#             sweep).  The ``xla`` route stacks the B query vectors (and,
#             for complex, their re/im planes) into ONE GEMM, so each
#             lockstep round reads S from DRAM once instead of B times —
#             the roofline win the batched driver exists for.  GEMM rows
#             are not bitwise-equal to the scalar GEMV (different float
#             summation order; same pivots — the blocked-driver precedent).
#
# ``xla_ref`` vmaps the literal reference ops in the stacked layout (vmap
# with BOTH operands batched runs the same per-lane kernel as the scalar
# call, hence bitwise; a per-lane Python loop is NOT — slicing fuses into
# the GEMV/GEMM lowering and changes its FMA pattern) and loops them per
# lane in the shared layout (the literal oracle; a shared-operand vmap
# would lower to one fused GEMM, i.e. the thing being tested).  ``pallas``
# loops the fused kernels per lane, except ``batched_block_sweep`` which
# routes to the dedicated batched Pallas variant.


def _barrier_lane_loop(op, nout: int, *batched_args):
    """Per-lane loop with an optimization barrier around each lane's
    operand slices.

    The barrier keeps XLA from merging the lanes' dots into one batched
    dot or fusing the slice into the GEMV lowering — either rewrite
    changes the float summation/FMA pattern, and the whole point of this
    route is that each lane compiles exactly like the scalar op.  Used
    for the complex stacked layout, where neither ``jax.vmap`` of the
    plane-split ops nor of the literal complex ops is bitwise per lane
    (XLA merges a scalar ``a @ b + c @ d`` into one concatenated dot but
    does not apply the same rewrite to the batched form).
    """
    B = batched_args[0].shape[0]
    outs = []
    for b in range(B):
        lane = jax.lax.optimization_barrier(
            tuple(a[b] for a in batched_args))
        outs.append(op(*lane))
    if nout == 1:
        return jnp.stack(outs)
    return tuple(jnp.stack([o[i] for o in outs]) for i in range(nout))


def _is_shared(S_or_stack, batch: int) -> bool:
    if S_or_stack.ndim == 2:
        return True
    if S_or_stack.ndim == 3:
        if S_or_stack.shape[0] != batch:
            raise ValueError(
                f"stacked snapshot batch {S_or_stack.shape[0]} != query "
                f"batch {batch}")
        return False
    raise ValueError(
        f"snapshot operand must be (N, M) shared or (B, N, M) stacked, "
        f"got shape {S_or_stack.shape}")


def _fused_shared_pivot(q, S, acc, norms_sq):
    """Shared-S batched Eq.-(6.3) sweep: one read of S for all B lanes.

    Complex planes of all B query vectors stack into L = [[Qr], [Qi]]
    (2B, N); two real GEMMs ``L @ Sr`` / ``L @ Si`` read each plane ONCE,
    then recombine:  cr = (L Sr)[:B] + (L Si)[B:],
                     ci = (L Si)[:B] - (L Sr)[B:].
    """
    B = q.shape[0]
    if jnp.iscomplexobj(S):
        L = jnp.concatenate([q.real, q.imag], axis=0)     # (2B, N)
        Sr, Si = S.real, S.imag
        A = L @ Sr                                        # (2B, M)
        Bm = L @ Si
        cr = A[:B] + Bm[B:]
        ci = Bm[:B] - A[B:]
        c = jax.lax.complex(cr, ci).astype(S.dtype)
        acc_out = acc + (cr * cr + ci * ci).astype(acc.dtype)
    else:
        c = q @ S                                         # (B, M) one GEMM
        acc_out = acc + (c * c).astype(acc.dtype)
    res = norms_sq - acc_out
    return (c, acc_out, jnp.max(res, axis=1),
            jnp.argmax(res, axis=1).astype(jnp.int32))


def batched_pivot_update(
    q: jax.Array,
    S: jax.Array,
    acc: jax.Array,
    norms_sq: jax.Array,
    backend: str | None = None,
):
    """B-lane Eq.-(6.3) sweep: per-lane ``c = q_b^H S_b``, acc, argmax.

    Args:
      q:        (B, N) one current basis vector per lane.
      S:        (B, N, M) stacked or (N, M) shared snapshots.
      acc:      (B, M) per-lane accumulated ``|c|^2``.
      norms_sq: (B, M) per-lane reference norms.

    Returns ``(c, acc_out, max_res, argmax)`` with shapes
    ((B, M), (B, M), (B,), (B,)) — lane b equals
    :func:`pivot_update` on its slice (bitwise in the stacked layout,
    pivot-for-pivot in the shared layout; see the section comment).
    """
    resolved = resolve_backend(backend)
    B = q.shape[0]
    shared = _is_shared(S, B)
    if resolved == "xla" and shared:
        return _fused_shared_pivot(q, S, acc, norms_sq)
    if resolved != "pallas" and not shared:
        if jnp.iscomplexobj(S):
            # complex lanes: barrier loop (see _barrier_lane_loop — no
            # vmapped form is bitwise per lane here)
            inner = (_plane_split_pivot if resolved == "xla"
                     else _xla_pivot)
            return _barrier_lane_loop(inner, 4, q, S, acc, norms_sq)
        # real lanes: vmap of the scalar op (BOTH operands batched) runs
        # the same per-lane kernel XLA picks for the scalar call —
        # bitwise per lane.  A bare per-lane Python loop is NOT: slicing
        # fuses into the GEMV lowering and changes its FMA pattern.
        return jax.vmap(_xla_pivot)(q, S, acc, norms_sq)
    op = _pallas_pivot if resolved == "pallas" else _xla_pivot
    outs = [op(q[b], S if shared else S[b], acc[b], norms_sq[b])
            for b in range(B)]
    return tuple(jnp.stack([o[i] for o in outs]) for i in range(4))


def batched_project_pass(
    v: jax.Array,
    Q: jax.Array,
    backend: str | None = None,
):
    """B-lane classical-GS pass: per lane ``(v_b - Q_b Q_b^H v_b, Q_b^H
    v_b)`` with ``v`` (B, N) and ``Q`` (B, N, k).  The basis is always
    per-lane (each lane orthogonalizes against its own Q), so there is no
    shared layout here; ``xla``/``xla_ref`` are the vmapped scalar routes
    (bitwise per-lane — see :func:`batched_pivot_update` for why a
    per-lane loop is not), ``pallas`` loops the fused kernel."""
    resolved = resolve_backend(backend)
    if resolved != "pallas":
        if jnp.iscomplexobj(Q):
            inner = (_plane_split_project if resolved == "xla"
                     else _xla_project)
            return _barrier_lane_loop(inner, 2, v, Q)
        return jax.vmap(_xla_project)(v, Q)
    outs = [_pallas_project(v[b], Q[b]) for b in range(v.shape[0])]
    return tuple(jnp.stack([o[i] for o in outs]) for i in range(2))


def batched_panel_project(
    V: jax.Array,
    Q: jax.Array,
    backend: str | None = None,
):
    """B-lane classical-GS PANEL pass: per lane ``(V_b - Q_b Q_b^H V_b,
    Q_b^H V_b)`` with ``V`` (B, N, p) and ``Q`` (B, N, k).  Routing as in
    :func:`batched_project_pass`."""
    resolved = resolve_backend(backend)
    if resolved != "pallas":
        if jnp.iscomplexobj(Q):
            inner = (_plane_split_panel_project if resolved == "xla"
                     else _xla_panel)
            return _barrier_lane_loop(inner, 2, V, Q)
        return jax.vmap(_xla_panel)(V, Q)
    outs = [_pallas_panel(V[b], Q[b]) for b in range(V.shape[0])]
    return tuple(jnp.stack([o[i] for o in outs]) for i in range(2))


def _fused_shared_block_sweep(Qnew, S, acc):
    """Shared-S batched blocked sweep: all B (N, p) panels stack into one
    (B*p, N) x (N, M) GEMM pair, reading each plane of S once.  The
    kernel-fused per-column sums are recomputed per lane from C (each
    lane's acc only sums its OWN p rows)."""
    B, N, p = Qnew.shape
    Qh = jnp.swapaxes(Qnew, 1, 2).reshape(B * p, N)       # (B*p, N)
    if jnp.iscomplexobj(S):
        L = jnp.concatenate([Qh.real, Qh.imag], axis=0)   # (2Bp, N)
        Sr, Si = S.real, S.imag
        A = L @ Sr
        Bm = L @ Si
        Cr = A[:B * p] + Bm[B * p:]
        Ci = Bm[:B * p] - A[B * p:]
        C = jax.lax.complex(Cr, Ci).astype(S.dtype).reshape(B, p, -1)
        sq = (Cr * Cr + Ci * Ci).reshape(B, p, -1)
    else:
        C = (Qh @ S).reshape(B, p, -1)
        sq = C * C
    acc_out = acc + jnp.sum(sq, axis=1).astype(acc.dtype)
    return C, acc_out


def batched_block_sweep(
    Qnew: jax.Array,
    S: jax.Array,
    acc: jax.Array,
    backend: str | None = None,
):
    """B-lane blocked Eq.-(6.3) sweep: per lane ``C_b = Qnew_b^H S_b``,
    ``acc_b += sum_i |C_b,i|^2``.

    Args:
      Qnew: (B, N, p) one panel of new basis vectors per lane.
      S:    (B, N, M) stacked or (N, M) shared snapshots.
      acc:  (B, M) per-lane accumulated sums.

    Returns ``(C, acc_out)`` with shapes ((B, p, M), (B, M)).  ``pallas``
    routes to the batched Pallas variant
    (:func:`repro.kernels.block_sweep.ops.batched_block_sweep`): per-lane
    fused kernels when stacked, one stacked-panel kernel call when shared.
    """
    resolved = resolve_backend(backend)
    B = Qnew.shape[0]
    shared = _is_shared(S, B)
    if resolved == "pallas":
        from repro.kernels.block_sweep.ops import (
            batched_block_sweep as _pallas_batched_block,
        )

        return _pallas_batched_block(Qnew, S, acc)
    if resolved == "xla" and shared:
        return _fused_shared_block_sweep(Qnew, S, acc)
    if not shared:
        if jnp.iscomplexobj(S):
            inner = (_plane_split_block_sweep if resolved == "xla"
                     else _xla_block)
            return _barrier_lane_loop(inner, 2, Qnew, S, acc)
        return jax.vmap(_xla_block)(Qnew, S, acc)
    outs = [_xla_block(Qnew[b], S, acc[b]) for b in range(B)]
    return tuple(jnp.stack([o[i] for o in outs]) for i in range(2))


def batched_sketch_fold(
    T: jax.Array,
    Omega: jax.Array,
    Y: jax.Array,
    backend: str | None = None,
):
    """B-lane sketch fold: per lane ``Y_b + T_b @ Omega_b``.

    ``T`` is (B, N, m) stacked or (N, m) shared; ``Omega`` (B, m, ell)
    stacked or (m, ell) shared (a shared test block sketches every lane
    against the same directions — comparable sketches across lanes);
    ``Y`` is always (B, N, ell).  Routing mirrors :func:`sketch_fold`
    (``pallas`` shares the ``xla`` plane-split GEMM form).
    """
    resolved = resolve_backend(backend)
    B = Y.shape[0]
    t_ax = None if _is_shared(T, B) else 0
    o_ax = None if Omega.ndim == 2 else 0
    if resolved != "xla_ref" or t_ax == 0:
        inner = (_plane_split_sketch_fold
                 if resolved != "xla_ref" and jnp.iscomplexobj(T)
                 else (lambda t, o, y: y + t @ o))
        return jax.vmap(inner, in_axes=(t_ax, o_ax, 0))(T, Omega, Y)
    outs = [Y[b] + T @ (Omega if o_ax is None else Omega[b])
            for b in range(B)]
    return jnp.stack(outs)


def _plane_split_sketch_project(T, Y):
    """Complex sketch co-range projection ``T^H @ Y`` as four real GEMMs
    on split re/im planes (see :func:`_plane_split_pivot`)."""
    Tr, Ti = T.real, T.imag
    Yr, Yi = Y.real, Y.imag
    # Z = T^H Y = (Tr - i Ti)^T (Yr + i Yi)
    Zr = Tr.T @ Yr + Ti.T @ Yi
    Zi = Tr.T @ Yi - Ti.T @ Yr
    return jax.lax.complex(Zr, Zi).astype(T.dtype)


def sketch_project(
    T: jax.Array,
    Y: jax.Array,
    backend: str | None = None,
):
    """One tile's co-range projection for the power pass: ``T^H @ Y``.

    ``T`` is an (N, m) snapshot tile, ``Y`` the current (N, ell) range
    estimate; the returned (m, ell) block is this tile's row slab of
    ``Z = S^H Y`` (the odd pass of a randomized power iteration).  Backend
    routing mirrors :func:`sketch_fold`.
    """
    resolved = resolve_backend(backend)
    if resolved != "xla_ref" and jnp.iscomplexobj(T):
        return _plane_split_sketch_project(T, Y)
    return T.conj().T @ Y
