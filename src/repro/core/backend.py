"""Backend dispatch for the greedy hot-loop primitives.

Every greedy driver in this repo — single-device (:mod:`repro.core.greedy`),
blocked (:mod:`repro.core.block_greedy`) and column-sharded
(:mod:`repro.core.distributed`) — spends its time in exactly two primitives:

  pivot_update   the paper's Eq.-(6.3) sweep: ``c = q^H S``,
                 ``acc += |c|^2``, masked residual argmax — one read of the
                 snapshot shard per basis vector (Fig. 6.1a),
  project_pass   one classical-GS projection ``c = Q^H v``,
                 ``v' = v - Q c`` — the body of Hoffmann's iterated GS
                 (Fig. 6.1b).

This module is the single point where those primitives are routed to an
implementation:

  ``pallas``   the fused Pallas TPU kernels
               (:mod:`repro.kernels.greedy_update`,
               :mod:`repro.kernels.imgs_project`) — one HBM pass, argmax
               masking for padded columns, split re/im planes for complex;
               off-TPU they run in interpret mode (slow, parity-testing
               only),
  ``xla``      ``jnp`` ops fused by XLA — the fast path on CPU/GPU.
               Complex inputs run on split re/im planes (four real GEMVs),
               mirroring the Pallas kernels: XLA lowers a complex GEMV to a
               scalar loop ~10x slower than its real counterpart,
  ``xla_ref``  the literal reference ops (:mod:`..kernels.*.ref`, complex
               GEMV included) — the seed implementation, kept as the
               numerical oracle and the benchmark baseline.

Dispatch contract
-----------------

* Selection happens at **trace time** (it is a plain Python decision), so a
  backend choice is baked into each jitted computation; drivers thread
  ``backend=`` through as a static argument.
* Precedence: explicit ``backend=`` argument > ``REPRO_GREEDY_BACKEND``
  environment variable > :func:`set_default_backend` > ``"auto"``
  (``pallas`` iff the default JAX backend is TPU).
* Both implementations satisfy the same numerical contract (identical
  signatures and semantics, see ``kernels/*/ref.py``); pivot-for-pivot
  parity of whole drivers is asserted in ``tests/test_backend.py``.
* Six primitives are dispatched: ``pivot_update`` and ``project_pass``
  (above), the two blocked panel forms used by the block-pivoted
  drivers: ``block_sweep`` (the BLAS-3 Eq.-(6.3) sweep;
  :mod:`repro.kernels.block_sweep` — one read of S per p bases) and
  ``panel_project`` (the BLAS-3 classical-GS projection of a whole (N, p)
  candidate panel; :mod:`repro.kernels.imgs_panel` — one read of Q per
  panel instead of per candidate), plus the two sketch GEMMs the
  randomized range-finder (:mod:`repro.core.randomized`) streams tiles
  through: ``sketch_fold`` (``Y += T @ Omega``) and ``sketch_project``
  (``T^H @ Y``).  Both are pure GEMMs, already MXU/BLAS-3-shaped, so the
  ``pallas`` route shares the ``xla`` plane-split form (XLA emits the
  optimal GEMM; there is nothing left for a hand-written kernel to fuse)
  while keeping the no-complex-dot HLO guarantee.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.block_sweep.ops import block_sweep as _pallas_block
from repro.kernels.block_sweep.ref import block_sweep_ref as _xla_block
from repro.kernels.greedy_update.ops import greedy_update as _pallas_pivot
from repro.kernels.greedy_update.ref import greedy_update_ref as _xla_pivot
from repro.kernels.imgs_panel.ops import imgs_panel as _pallas_panel
from repro.kernels.imgs_panel.ref import imgs_panel_ref as _xla_panel
from repro.kernels.imgs_project.ops import imgs_project as _pallas_project
from repro.kernels.imgs_project.ref import imgs_project_ref as _xla_project

VALID_BACKENDS = ("auto", "xla", "pallas", "xla_ref")

_ENV_VAR = "REPRO_GREEDY_BACKEND"
_default_backend = "auto"


def set_default_backend(name: str) -> None:
    """Set the process-wide default backend (overridden by env/explicit)."""
    global _default_backend
    if name not in VALID_BACKENDS:
        raise ValueError(
            f"unknown greedy backend {name!r}; valid: {VALID_BACKENDS}"
        )
    _default_backend = name


def default_backend() -> str:
    return _default_backend


def resolve_backend(backend: str | None = None) -> str:
    """Resolve a backend request to a concrete implementation name.

    Returns ``"pallas"``, ``"xla"`` or ``"xla_ref"``.  ``None`` consults
    the ``REPRO_GREEDY_BACKEND`` env var, then :func:`default_backend`; the
    ``"auto"`` policy picks the fused Pallas kernels exactly when running
    on TPU (interpret-mode Pallas is a debugging tool, not a fast path).
    """
    if backend is None:
        backend = os.environ.get(_ENV_VAR) or _default_backend
    if backend not in VALID_BACKENDS:
        raise ValueError(
            f"unknown greedy backend {backend!r}; valid: {VALID_BACKENDS}"
        )
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def _plane_split_pivot(q, S, acc, norms_sq):
    """Complex Eq.-(6.3) sweep as four real GEMVs on split re/im planes.

    Mirrors the Pallas kernel's plane decomposition (TPU MXUs are real) —
    and is the fast path on CPU/GPU too: XLA lowers a complex GEMV to a
    scalar loop that is an order of magnitude slower than its real GEMVs
    (measured 709 ms vs 66 ms for c64 at N=4096, M=16384 on 1 CPU core).
    Same math as ``q.conj() @ S`` up to float summation order.
    """
    qr, qi = q.real, q.imag
    Sr, Si = S.real, S.imag
    cr = qr @ Sr + qi @ Si   # Re(q^H S)
    ci = qr @ Si - qi @ Sr   # Im(q^H S)
    c = jax.lax.complex(cr, ci).astype(S.dtype)
    acc_out = acc + (cr * cr + ci * ci).astype(acc.dtype)
    res = norms_sq - acc_out
    return c, acc_out, jnp.max(res), jnp.argmax(res).astype(jnp.int32)


def _plane_split_project(v, Q):
    """Complex GS projection pass on split re/im planes (see
    :func:`_plane_split_pivot` for why)."""
    Qr, Qi = Q.real, Q.imag
    vr, vi = v.real, v.imag
    # c = Q^H v = (Qr - i Qi)^T (vr + i vi)
    cr = vr @ Qr + vi @ Qi
    ci = vi @ Qr - vr @ Qi
    # v' = v - Q c
    vr_out = vr - (Qr @ cr - Qi @ ci)
    vi_out = vi - (Qr @ ci + Qi @ cr)
    return (
        jax.lax.complex(vr_out, vi_out).astype(v.dtype),
        jax.lax.complex(cr, ci).astype(Q.dtype),
    )


def pivot_update(
    q: jax.Array,
    S: jax.Array,
    acc: jax.Array,
    norms_sq: jax.Array,
    backend: str | None = None,
):
    """Fused Eq.-(6.3) update: ``c = q^H S``, ``acc += |c|^2``, argmax.

    Returns ``(c, acc_out, max_res, argmax)`` — identical semantics on both
    backends (see :func:`repro.kernels.greedy_update.ref.greedy_update_ref`).
    ``max_res``/``argmax`` describe the residual AFTER this update, i.e. the
    next iteration's pivot; drivers that re-derive the pivot from
    ``norms_sq - acc`` may ignore them (XLA dead-code-eliminates the ref
    computation; the Pallas kernel produces them for free in the same pass).
    Complex snapshots run on split re/im planes under either backend.
    """
    resolved = resolve_backend(backend)
    if resolved == "pallas":
        return _pallas_pivot(q, S, acc, norms_sq)
    if resolved == "xla" and jnp.iscomplexobj(S):
        return _plane_split_pivot(q, S, acc, norms_sq)
    return _xla_pivot(q, S, acc, norms_sq)


def project_pass(
    v: jax.Array,
    Q: jax.Array,
    backend: str | None = None,
):
    """One classical-GS pass: returns ``(v - Q Q^H v, Q^H v)``."""
    resolved = resolve_backend(backend)
    if resolved == "pallas":
        return _pallas_project(v, Q)
    if resolved == "xla" and jnp.iscomplexobj(Q):
        return _plane_split_project(v, Q)
    return _xla_project(v, Q)


def _plane_split_panel_project(V, Q):
    """Complex classical-GS PANEL projection on split re/im planes (see
    :func:`_plane_split_pivot` for why: XLA lowers complex matmuls on CPU
    to scalar loops an order of magnitude slower than their real
    counterparts).  Same math as ``(V - Q (Q^H V), Q^H V)`` up to float
    summation order — four real GEMMs per half instead of two complex
    GEMMs."""
    Qr, Qi = Q.real, Q.imag
    Vr, Vi = V.real, V.imag
    # C = Q^H V = (Qr - i Qi)^T (Vr + i Vi)
    Cr = Qr.T @ Vr + Qi.T @ Vi
    Ci = Qr.T @ Vi - Qi.T @ Vr
    # V' = V - Q C
    Vr_out = Vr - (Qr @ Cr - Qi @ Ci)
    Vi_out = Vi - (Qr @ Ci + Qi @ Cr)
    return (
        jax.lax.complex(Vr_out, Vi_out).astype(V.dtype),
        jax.lax.complex(Cr, Ci).astype(Q.dtype),
    )


def panel_project(
    V: jax.Array,
    Q: jax.Array,
    backend: str | None = None,
):
    """One classical-GS PANEL pass: returns ``(V - Q Q^H V, Q^H V)``.

    The BLAS-3 form of :func:`project_pass` applied to a whole (N, p)
    candidate panel at once — one read of Q per panel instead of per
    candidate, so k*p*N GEMM work replaces p separate k*N GEMV chains
    (the panel-factorization idea of the blocked-QR literature; see
    :mod:`repro.kernels.imgs_panel`).  ``pallas`` routes to the fused
    panel kernel; ``xla`` runs the ``jnp`` GEMM form with complex inputs
    on split re/im planes (mirroring :func:`project_pass`); ``xla_ref``
    is the literal reference
    (:func:`repro.kernels.imgs_panel.ref.imgs_panel_ref`, complex GEMM
    included).
    """
    resolved = resolve_backend(backend)
    if resolved == "pallas":
        return _pallas_panel(V, Q)
    if resolved == "xla" and jnp.iscomplexobj(Q):
        return _plane_split_panel_project(V, Q)
    return _xla_panel(V, Q)


def _plane_split_block_sweep(Qnew, S, acc):
    """Complex blocked Eq.-(6.3) sweep as four real GEMMs on split re/im
    planes (see :func:`_plane_split_pivot` for why: XLA lowers complex
    matmuls on CPU to scalar loops an order of magnitude slower than their
    real counterparts).  Same math as ``Qnew.conj().T @ S`` up to float
    summation order."""
    Qr, Qi = Qnew.real, Qnew.imag
    Sr, Si = S.real, S.imag
    # C = Qnew^H S = (Qr - i Qi)^T (Sr + i Si)
    Cr = Qr.T @ Sr + Qi.T @ Si
    Ci = Qr.T @ Si - Qi.T @ Sr
    C = jax.lax.complex(Cr, Ci).astype(S.dtype)
    acc_out = acc + jnp.sum(Cr * Cr + Ci * Ci, axis=0).astype(acc.dtype)
    return C, acc_out


def block_sweep(
    Qnew: jax.Array,
    S: jax.Array,
    acc: jax.Array,
    backend: str | None = None,
):
    """Blocked Eq.-(6.3) sweep: ``C = Qnew^H S``, ``acc += sum_i |C_i|^2``.

    One read of S per p bases — the block-greedy amortization that turns
    the memory-roof-bound BLAS-2 pivot sweep into a BLAS-3 panel GEMM.
    ``pallas`` routes to the fused panel kernel
    (:mod:`repro.kernels.block_sweep`); ``xla`` runs the ``jnp`` GEMM form,
    with complex inputs on split re/im planes (four real GEMMs, mirroring
    :func:`pivot_update`); ``xla_ref`` is the literal reference
    (:func:`repro.kernels.block_sweep.ref.block_sweep_ref`, complex GEMM
    included).
    """
    resolved = resolve_backend(backend)
    if resolved == "pallas":
        return _pallas_block(Qnew, S, acc)
    if resolved == "xla" and jnp.iscomplexobj(S):
        return _plane_split_block_sweep(Qnew, S, acc)
    return _xla_block(Qnew, S, acc)


def _plane_split_sketch_fold(T, Omega, Y):
    """Complex sketch fold ``Y += T @ Omega`` as four real GEMMs on split
    re/im planes (see :func:`_plane_split_pivot` for why: XLA lowers
    complex matmuls on CPU to scalar loops an order of magnitude slower
    than their real counterparts).  Same math as ``Y + T @ Omega`` up to
    float summation order."""
    Tr, Ti = T.real, T.imag
    Or, Oi = Omega.real, Omega.imag
    Yr = Y.real + (Tr @ Or - Ti @ Oi)
    Yi = Y.imag + (Tr @ Oi + Ti @ Or)
    return jax.lax.complex(Yr, Yi).astype(Y.dtype)


def sketch_fold(
    T: jax.Array,
    Omega: jax.Array,
    Y: jax.Array,
    backend: str | None = None,
):
    """One tile's contribution to the randomized sketch: ``Y + T @ Omega``.

    ``T`` is an (N, m) snapshot tile, ``Omega`` the matching (m, ell) test
    block, ``Y`` the running (N, ell) sketch ``Y = S @ Omega`` — the
    single-pass range-finder accumulation of :mod:`repro.core.randomized`.
    ``xla``/``pallas`` run complex inputs on split re/im planes (four real
    GEMMs, mirroring :func:`block_sweep`; the sketch GEMM is already
    BLAS-3/MXU-shaped, so there is no dedicated Pallas kernel);
    ``xla_ref`` is the literal form, complex GEMM included.
    """
    resolved = resolve_backend(backend)
    if resolved != "xla_ref" and jnp.iscomplexobj(T):
        return _plane_split_sketch_fold(T, Omega, Y)
    return Y + T @ Omega


def _plane_split_sketch_project(T, Y):
    """Complex sketch co-range projection ``T^H @ Y`` as four real GEMMs
    on split re/im planes (see :func:`_plane_split_pivot`)."""
    Tr, Ti = T.real, T.imag
    Yr, Yi = Y.real, Y.imag
    # Z = T^H Y = (Tr - i Ti)^T (Yr + i Yi)
    Zr = Tr.T @ Yr + Ti.T @ Yi
    Zi = Tr.T @ Yi - Ti.T @ Yr
    return jax.lax.complex(Zr, Zi).astype(T.dtype)


def sketch_project(
    T: jax.Array,
    Y: jax.Array,
    backend: str | None = None,
):
    """One tile's co-range projection for the power pass: ``T^H @ Y``.

    ``T`` is an (N, m) snapshot tile, ``Y`` the current (N, ell) range
    estimate; the returned (m, ell) block is this tile's row slab of
    ``Z = S^H Y`` (the odd pass of a randomized power iteration).  Backend
    routing mirrors :func:`sketch_fold`.
    """
    resolved = resolve_backend(backend)
    if resolved != "xla_ref" and jnp.iscomplexobj(T):
        return _plane_split_sketch_project(T, Y)
    return T.conj().T @ Y
