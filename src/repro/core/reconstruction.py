"""Algorithm 4: the reconstruction approach to QR (Sec. 5.2.2).

Run a partial pivoted greedy/MGS to j terms (cheap: O(jNM)), then take the
SVD of the *small* (j x M) triangular factor R and rotate the QR basis by its
left singular vectors:

    X_k = Q_j @ Vbar[:, :k].

Theorem 5.11: |S - X_j X_j^H S|_2 <= sigma(S_1)_{j+1} + |R22|_2, i.e. the
reconstructed basis behaves like POD whenever |R22| is small (Remark 5.13) —
at QR cost (Remark 5.9: O(M j^2 + N j^2) on top of the partial QR instead of
a full N x M SVD).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.greedy import rb_greedy


class ReconstructionResult(NamedTuple):
    X: jax.Array        # (N, k) reconstructed (SVD-rotated) basis
    Qj: jax.Array       # (N, j) greedy/QR basis actually computed
    sigmas_R: jax.Array  # (j,) singular values of R(1:j, 1:M)
    j: int              # partial QR depth (tau_1 criterion)
    k: jax.Array        # selected rank (tau_2 criterion)


def reconstruction(
    S: jax.Array,
    tau1: float,
    tau2: float,
    max_j: int | None = None,
) -> ReconstructionResult:
    """Algorithm 4.

    Step 3: partial pivoted QR (RB-greedy == MGS, Prop 5.3) until
            R(j,j) < tau1.
    Step 5: SVD of R(1:j, 1:M)  (j x M — small).
    Step 6: pick k with sigma_{k+1} < tau2.
    Step 7: X_k = Q_j Vbar(:, 1:k).
    """
    res = rb_greedy(S, tau=tau1, max_k=max_j)
    j = int(res.k)
    Qj = res.Q[:, :j]
    Rj = res.R[:j, :]

    Vbar, sig, _ = jnp.linalg.svd(Rj, full_matrices=False)
    below = sig < tau2
    k = jnp.where(jnp.any(below), jnp.argmax(below), sig.shape[0])

    X = Qj @ Vbar  # full rotation; caller slices [:, :k]
    return ReconstructionResult(X=X, Qj=Qj, sigmas_R=sig, j=j, k=k)
