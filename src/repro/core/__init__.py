"""Core model-reduction algorithms from the paper.

NOTE: these modules are the strategy *engines*.  The recommended entry
point is the front door, :mod:`repro.api` —
``build_basis(source=S, tau=...)`` dispatches to the right engine
(``strategy="pod" | "mgs" | "greedy" | "block_greedy" | "streamed" |
"distributed" | "randomized" | "sketch+greedy" | "auto"``) and returns
one ``ReducedBasis`` artifact with ``eim()`` / ``roq_weights()`` /
``save()`` built in.

- :mod:`repro.core.pod`            -- Algorithm 1 (POD via SVD).
- :mod:`repro.core.mgs`            -- Algorithm 2 (MGS with column pivoting;
  direct ``mgs_pivoted_qr`` calls are deprecated in favor of the front
  door — the implementation stays as the Prop.-5.3 reference).
- :mod:`repro.core.greedy`         -- Algorithm 3 (RB-greedy w/ Hoffmann IMGS).
- :mod:`repro.core.block_greedy`   -- blocked variant (p pivots per sweep;
  direct ``rb_greedy_block`` calls likewise deprecated).
- :mod:`repro.core.rrqr`           -- optimal RRQR (Theorem 5.1).
- :mod:`repro.core.reconstruction` -- Algorithm 4 (QR + SVD-of-R).
- :mod:`repro.core.eim`            -- empirical interpolation + ROQ.
- :mod:`repro.core.errors`         -- the paper's error identities.
- :mod:`repro.core.distributed`    -- shard_map column-parallel greedy (Sec 6).
- :mod:`repro.core.streaming`      -- out-of-core tile-streamed greedy over
  snapshot providers (M unbounded; peak device memory
  O(N(max_k+2*tile_m)) with next-tile prefetch).
- :mod:`repro.core.batch_greedy`   -- B lockstep greedy builds in one
  fused pass over shared-N snapshots (``strategy="batched"``): per-lane
  pivots/stops, converged lanes masked out, per-basis results bitwise
  vs the scalar driver in stacked layouts.
- :mod:`repro.core.randomized`     -- streamed randomized range-finder
  (sketched POD): ONE pass over the provider builds Y = S @ Omega, then
  a small dense SVD; optional power iteration; resumable +
  bit-reproducible via counter-derived per-tile test blocks.
- :mod:`repro.core.backend`        -- hot-loop primitive dispatch
  (fused Pallas TPU kernels vs pure-jnp XLA; see its module docstring).
"""

from repro.core.pod import pod, pod_basis
from repro.core.mgs import mgs_pivoted_qr
from repro.core.backend import (
    default_backend,
    resolve_backend,
    set_default_backend,
)
from repro.core.greedy import (
    GreedyResult,
    imgs_orthogonalize,
    rb_greedy,
    rb_greedy_stepwise,
)
from repro.core.batch_greedy import BatchGreedyResult, batch_rb_greedy
from repro.core.streaming import StreamedGreedyResult, rb_greedy_streamed
from repro.core.randomized import (
    RandomizedSketchResult,
    RankEstimate,
    estimate_rank,
    rb_randomized_streamed,
)
from repro.core.rrqr import optimal_rrqr
from repro.core.reconstruction import reconstruction
from repro.core.eim import eim_nodes, empirical_interpolant, roq_weights

__all__ = [
    "pod", "pod_basis", "mgs_pivoted_qr", "GreedyResult", "rb_greedy",
    "rb_greedy_stepwise", "rb_greedy_streamed", "StreamedGreedyResult",
    "batch_rb_greedy", "BatchGreedyResult",
    "rb_randomized_streamed", "RandomizedSketchResult",
    "estimate_rank", "RankEstimate",
    "imgs_orthogonalize", "optimal_rrqr",
    "reconstruction", "eim_nodes", "empirical_interpolant", "roq_weights",
    "default_backend", "resolve_backend", "set_default_backend",
]
