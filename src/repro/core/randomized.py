"""Randomized sketch (range-finder) model reduction over snapshot providers.

The greedy family streams the FULL snapshot matrix once per accepted basis
vector (or once per ``block_p`` bases): k passes over S is the floor of
Algorithm 3's cost.  The randomized range-finder (RPOD, arXiv:1312.3976;
sampled-SVD POD, arXiv:1905.05107; Halko–Martinsson–Tropp) breaks that
floor: ONE streamed pass folds every provider tile into a small sketch

    Y = S @ Omega,          Omega: (M, ell) test matrix, ell = k + p,

after which a dense QR/SVD of the (N, ell) sketch — negligible next to one
pass over S — yields a basis whose projection error matches the optimal
rank-k (POD) error up to the standard oversampling factor
(E ||(I - QQ^H) S||_F^2 <= (1 + k/(p-1)) sum_{j>k} sigma_j^2).

Streaming layout
----------------

The test matrix is never materialized: each tile ``T_t = S[:, lo:hi)``
meets its own block ``Omega_t``, generated on device from a
counter-derived key ``fold_in(PRNGKey(seed), t)`` — so the pass is
order-deterministic, bit-reproducible, and resumable (a resumed build
regenerates exactly the blocks it still needs).  The fold runs through
:func:`repro.core.backend.sketch_fold` (plane-split real GEMMs for complex
dtypes — the same no-complex-dot HLO guarantee as every other hot
primitive), the tile's column norms ride along for free, and the next
tile is prefetched while the current fold runs, mirroring
:mod:`repro.core.streaming`.

``power=q`` adds q rounds of subspace (power) iteration — 2 extra passes
per round (``Z = S^H Q``, ``Y = S Z``), orthonormalizing between
applications — sharpening the basis toward the exact POD subspace when
the spectrum decays slowly.  Total passes over S: ``1 + 2 * power``.

Singular-value estimates: with ``power=0`` the sketch's singular values
scale like ``sigma_i(S) * sqrt(ell)`` for a Gaussian test matrix, so
``s_i(Y)/sqrt(ell)`` estimates the spectrum; with ``power>=1`` the final
pass applies S to an ORTHONORMAL (M, ell) co-range basis, so ``s_i(Y)``
are Ritz values converging to ``sigma_i(S)`` from below.  Rank selection
follows Algorithm 1's criterion on those estimates (smallest k with
``sigma_hat_{k+1} < tau``), capped at ``max_k``.

Mid-build checkpointing persists the partial sketch (phase, tile cursor,
Y, Z, norms) through :mod:`repro.checkpoint.io`; a killed pass resumes
from the last completed tile and lands on a bit-identical basis.
"""

from __future__ import annotations

import functools
import math
import os
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as _backend
from repro.data.providers import SnapshotProvider, as_provider

_STATE_VERSION = 1

SKETCH_KINDS = ("gaussian", "rademacher")


class RandomizedSketchResult(NamedTuple):
    """Result of the streamed randomized range-finder.

    Attributes:
      Q:        (N, k) orthonormal basis (left singular vectors of the
                sketch), provider dtype.
      svals:    (ell,) singular-value ESTIMATES of S from the sketch
                (see module docstring), real dtype, non-increasing.
      k:        selected rank (Algorithm-1 tau criterion on ``svals``,
                capped at ``max_k``).
      ell:      sketch width ``min(max_k + sketch_p, N, M)``.
      n_passes: streamed passes over the provider (``1 + 2 * power``).
      tile_m / n_tiles: tiling the pass used.
      sketch_p / power / seed / kind: the sketch parameters (provenance).
      norms_sq: (M,) snapshot column norms^2, accumulated in the same
                pass (free — the tile is already on device).
    """

    Q: jax.Array
    svals: np.ndarray
    k: int
    ell: int
    n_passes: int
    tile_m: int
    n_tiles: int
    sketch_p: int
    power: int
    seed: int
    kind: str
    norms_sq: np.ndarray


def _test_block(key, shape, dtype, kind: str) -> jax.Array:
    """One (m, ell) block of the test matrix, derived purely from ``key``.

    Gaussian: standard normal (complex: (g1 + i g2)/sqrt(2), unit column
    variance).  Rademacher: +-1 entries (complex: unit phases from +-1
    pairs scaled by 1/sqrt(2)) — cheaper draws, same guarantees in
    practice.
    """
    rdt = jnp.zeros((), dtype).real.dtype
    if kind == "gaussian":
        if jnp.issubdtype(dtype, jnp.complexfloating):
            gr = jax.random.normal(jax.random.fold_in(key, 0), shape, rdt)
            gi = jax.random.normal(jax.random.fold_in(key, 1), shape, rdt)
            return (jax.lax.complex(gr, gi) / np.sqrt(2.0)).astype(dtype)
        return jax.random.normal(key, shape, rdt).astype(dtype)
    if kind == "rademacher":
        if jnp.issubdtype(dtype, jnp.complexfloating):
            sr = jax.random.rademacher(
                jax.random.fold_in(key, 0), shape, rdt)
            si = jax.random.rademacher(
                jax.random.fold_in(key, 1), shape, rdt)
            return (jax.lax.complex(sr, si) / np.sqrt(2.0)).astype(dtype)
        return jax.random.rademacher(key, shape, rdt).astype(dtype)
    raise ValueError(f"unknown sketch kind {kind!r}; valid: {SKETCH_KINDS}")


@functools.partial(jax.jit, static_argnames=("shape", "kind", "backend"))
def _tile_fold(key, T, Y, shape, kind: str, backend: str):
    """Phase-0 fold of one tile: generate Omega_t on device from the
    counter-derived key, ``Y += T @ Omega_t``, column norms^2 for free."""
    Om = _test_block(key, shape, T.dtype, kind)
    n = jnp.sum(jnp.abs(T) ** 2, axis=0)
    return _backend.sketch_fold(T, Om, Y, backend=backend), n


@functools.partial(jax.jit, static_argnames=("backend",))
def _tile_project(T, Y, backend: str):
    """Odd-phase slab: this tile's rows of ``Z = S^H Y``."""
    return _backend.sketch_project(T, Y, backend=backend)


@functools.partial(jax.jit, static_argnames=("backend",))
def _tile_apply(T, Zt, Y, backend: str):
    """Even-phase fold: ``Y += T @ Z[lo:hi]`` (re-application of S)."""
    return _backend.sketch_fold(T, Zt, Y, backend=backend)


@jax.jit
def _thin_q(Y):
    """Orthonormalize between power-iteration applications (Halko
    Alg. 4.4's stabilization; a thin QR of a tall-skinny array)."""
    return jnp.linalg.qr(Y, mode="reduced")[0]


class _SketchState:
    """Host-side resumable state of the streamed sketch pass(es).

    ``phase`` counts applications of S: 0 is the sketch fold
    ``Y = S Omega``; odd phases fill ``Z = S^H Y``; even phases >= 2
    re-apply ``Y = S Z``.  ``cursor`` is the next tile INDEX of the
    current phase; phase transitions (orthonormalizations) happen at
    ``cursor == n_tiles`` and are replayed deterministically on resume.
    """

    __slots__ = ("tile_m", "ell", "seed", "kind", "backend", "phase",
                 "cursor", "Y", "Z", "norms_sq", "done", "seq")

    def to_tree(self) -> dict:
        tree = {
            "version": np.asarray(_STATE_VERSION, np.int64),
            # The cursor is in tile units and Omega blocks are derived
            # per (seed, tile): a resume MUST replay the same tiling,
            # width, seed and draw kind — persisted for validation.  The
            # backend is persisted too: a partial Y carries one backend's
            # float summation order.
            "tile_m": np.asarray(self.tile_m, np.int64),
            "ell": np.asarray(self.ell, np.int64),
            "seed": np.asarray(self.seed, np.int64),
            "kind": np.asarray(self.kind),
            "backend": np.asarray(self.backend),
            "phase": np.asarray(self.phase, np.int64),
            "cursor": np.asarray(self.cursor, np.int64),
            "Y": np.asarray(jax.device_get(self.Y)),
            "norms_sq": self.norms_sq,
            "done": np.asarray(self.done, np.int64),
        }
        if self.Z is not None:
            tree["Z"] = self.Z
        return tree

    @classmethod
    def from_tree(cls, tree: dict) -> "_SketchState":
        version = int(tree["version"])
        if version != _STATE_VERSION:
            raise ValueError(
                f"sketch checkpoint version {version} != supported "
                f"{_STATE_VERSION}"
            )
        st = cls()
        st.tile_m = int(tree["tile_m"])
        st.ell = int(tree["ell"])
        st.seed = int(tree["seed"])
        st.kind = str(tree["kind"])
        st.backend = str(tree["backend"])
        st.phase = int(tree["phase"])
        st.cursor = int(tree["cursor"])
        st.Y = jnp.asarray(tree["Y"])
        st.Z = tree.get("Z")
        st.norms_sq = tree["norms_sq"]
        st.done = int(tree["done"])
        st.seq = 0
        return st


def _save_state(st: _SketchState, directory: str, keep: int = 2) -> None:
    from repro.checkpoint.io import save_checkpoint

    st.seq += 1
    save_checkpoint(st.to_tree(), directory, st.seq)
    import re
    import shutil

    steps = sorted(
        int(m.group(1)) for d in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d+)", d))
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def _load_state(directory: str) -> Optional[_SketchState]:
    from repro.checkpoint.io import latest_step, load_checkpoint_raw

    if latest_step(directory) is None:
        return None
    return _SketchState.from_tree(load_checkpoint_raw(directory))


class RankEstimate(NamedTuple):
    """Result of :func:`estimate_rank`.

    Attributes:
      k: estimated numerical rank at ``tau`` (the smallest k with
        ``sigma_hat_{k+1} < tau`` on the sketch's singular-value
        estimates).
      ell: final sketch width the estimate came from.
      saturated: True when every sketched singular value sat above
        ``tau`` even at the widest sketch tried — the true rank is
        ``>= k`` and the estimate is only a lower bound.
      passes: total streamed passes over the provider spent estimating
        (one per doubling round).
    """

    k: int
    ell: int
    saturated: bool
    passes: int


def estimate_rank(
    source,
    tau: float,
    *,
    ell0: int = 32,
    max_ell: int = 512,
    seed: int = 0,
    kind: str = "gaussian",
    tile_m: int = 8192,
    backend: str | None = None,
) -> RankEstimate:
    """Sketch-based numerical-rank estimate (for ``"auto"``'s planning).

    One cheap randomized pass folds ``Y = S @ Omega`` at width ``ell``
    and counts sketched singular-value estimates above ``tau`` — exactly
    :func:`rb_randomized_streamed`'s Algorithm-1 rank criterion, at a
    width far below a production sketch.  A SATURATED estimate (all
    ``ell`` values above ``tau``: the spectrum didn't decay inside the
    sketch) doubles ``ell`` and re-streams, up to ``min(max_ell, N, M)``
    — so a rank-r family costs ``O(log2(r / ell0))`` passes, each
    touching S once.

    This is the PR-7 follow-on that lets ``"auto"`` plan greedy-vs-sketch
    pass counts when the caller gave no ``max_k``: the returned ``k`` is
    an ESTIMATE of where the tau stop will land, good enough for a
    cutover decision (and, with headroom, a basis-size cap) but not a
    substitute for the build's own stopping test.
    """
    prov = as_provider(source)
    N, M = prov.shape
    hard_cap = min(max_ell, N, M)
    ell = min(max(int(ell0), 1), hard_cap)
    passes = 0
    while True:
        res = rb_randomized_streamed(
            source, tau=tau, max_k=ell, sketch_p=0, power=0, seed=seed,
            kind=kind, tile_m=tile_m, backend=backend,
        )
        passes += res.n_passes
        saturated = int(res.k) >= res.ell
        if not saturated or res.ell >= hard_cap:
            return RankEstimate(k=int(res.k), ell=res.ell,
                                saturated=saturated, passes=passes)
        ell = min(2 * ell, hard_cap)


def rb_randomized_streamed(
    source,
    tau: float | None = None,
    max_k: int | None = None,
    *,
    sketch_p: int = 10,
    power: int = 0,
    seed: int = 0,
    kind: str = "gaussian",
    tile_m: int = 8192,
    backend: str | None = None,
    checkpoint_dir: str | os.PathLike | None = None,
    checkpoint_every_tiles: int = 0,
    resume: bool = False,
) -> RandomizedSketchResult:
    """Single-pass randomized range-finder over a snapshot provider.

    ``source`` may be a provider, a resident array, or a ``.npy`` path
    (coerced via :func:`repro.data.providers.as_provider`).  With
    ``power=0`` the provider is streamed EXACTLY ONCE (one ``tile()``
    call per tile — asserted with a read counter in
    ``tests/test_randomized.py``); each additional power round costs two
    more passes.

    Args:
      tau: Algorithm-1 rank-selection tolerance applied to the sketch's
        singular-value estimates (``None`` keeps all ``max_k``).
      max_k: target rank cap (default ``min(N, M)``); the sketch width is
        ``min(max_k + sketch_p, N, M)``.
      sketch_p: oversampling columns beyond ``max_k`` (the range-finder
        bound's p; 5-10 is the standard regime).
      power: subspace-iteration rounds (2 extra passes each).
      seed / kind: test-matrix generation — ``"gaussian"`` or
        ``"rademacher"`` blocks derived per tile from
        ``fold_in(PRNGKey(seed), tile_index)``.
      tile_m / backend: as in :func:`repro.core.streaming.
        rb_greedy_streamed`.
      checkpoint_dir / checkpoint_every_tiles / resume: persist the
        partial sketch every N tiles (phase boundaries always checkpoint
        when a directory is given); a resumed pass regenerates the
        remaining test blocks from the counter-derived keys and is
        bit-identical to an uninterrupted one.
    """
    prov = as_provider(source)
    N, M = prov.shape
    if max_k is None:
        max_k = min(N, M)
    max_k = min(max_k, N, M)
    if sketch_p < 0:
        raise ValueError(f"sketch_p must be >= 0, got {sketch_p}")
    if power < 0:
        raise ValueError(f"power must be >= 0, got {power}")
    if kind not in SKETCH_KINDS:
        raise ValueError(f"unknown sketch kind {kind!r}; valid: "
                         f"{SKETCH_KINDS}")
    if tile_m < 1:
        raise ValueError(f"tile_m must be >= 1, got {tile_m}")
    if checkpoint_every_tiles < 0:
        raise ValueError("checkpoint_every_tiles must be >= 0")
    if resume and checkpoint_dir is None:
        raise ValueError("resume=True requires checkpoint_dir")
    backend = _backend.resolve_backend(backend)
    ckpt_dir = os.fspath(checkpoint_dir) if checkpoint_dir is not None \
        else None

    ell = min(max_k + sketch_p, N, M)
    tiles = list(prov.tiles(tile_m))
    n_tiles = len(tiles)
    n_phases = 1 + 2 * power
    dtype = jnp.dtype(prov.dtype)
    rdt = np.zeros((), dtype).real.dtype

    st = _load_state(ckpt_dir) if (resume and ckpt_dir) else None
    if st is not None:
        if st.tile_m != tile_m:
            raise ValueError(
                f"sketch checkpoint tile_m mismatch: saved {st.tile_m}, "
                f"requested {tile_m}"
            )
        if st.ell != ell:
            raise ValueError(
                f"sketch checkpoint width mismatch: saved ell={st.ell}, "
                f"requested {ell} (max_k + sketch_p changed?)"
            )
        if st.seed != seed or st.kind != kind:
            raise ValueError(
                f"sketch checkpoint test-matrix mismatch: saved "
                f"(seed={st.seed}, kind={st.kind!r}), requested "
                f"(seed={seed}, kind={kind!r})"
            )
        if st.Y.shape != (N, ell) or st.norms_sq.shape != (M,):
            raise ValueError(
                f"sketch checkpoint shape mismatch: Y {st.Y.shape} / M "
                f"{st.norms_sq.shape[0]} vs requested ({N}, {ell}) / {M}"
            )
        if st.Y.dtype != dtype:
            raise ValueError(
                f"sketch checkpoint dtype mismatch: saved {st.Y.dtype}, "
                f"provider {dtype}"
            )
        if st.backend != backend and not st.done:
            # A partial Y/Z carries one backend's float summation order;
            # mixing orders inside one accumulation breaks bit-identity.
            raise ValueError(
                f"sketch checkpoint was written under backend "
                f"{st.backend!r}; resume with that backend (requested "
                f"{backend!r})"
            )
    else:
        st = _SketchState()
        st.tile_m, st.ell = tile_m, ell
        st.seed, st.kind, st.backend = seed, kind, backend
        st.phase, st.cursor = 0, 0
        st.Y = jnp.zeros((N, ell), dtype)
        st.Z = None
        st.norms_sq = np.zeros((M,), rdt)
        st.done = 0
        st.seq = 0
        if ckpt_dir:
            from repro.checkpoint.io import latest_step

            st.seq = latest_step(ckpt_dir) or 0

    base_key = jax.random.PRNGKey(seed)

    def maybe_ckpt(mid_sweep: bool):
        if not ckpt_dir:
            return
        if mid_sweep and not (checkpoint_every_tiles
                              and st.cursor < n_tiles
                              and st.cursor % checkpoint_every_tiles == 0):
            return
        _save_state(st, ckpt_dir)

    while not st.done:
        ph = st.phase
        if ph == 0:
            # --- the single-pass sketch fold ---------------------------
            nxt = prov.tile(*tiles[st.cursor]) if st.cursor < n_tiles \
                else None
            while st.cursor < n_tiles:
                lo, hi = tiles[st.cursor]
                T, nxt = nxt, None
                Y2, n = _tile_fold(
                    jax.random.fold_in(base_key, st.cursor), T, st.Y,
                    (hi - lo, ell), kind, backend,
                )
                if st.cursor + 1 < n_tiles:
                    nxt = prov.tile(*tiles[st.cursor + 1])  # overlaps fold
                st.Y = Y2
                st.norms_sq[lo:hi] = np.asarray(n, rdt)
                st.cursor += 1
                maybe_ckpt(mid_sweep=True)
        elif ph % 2 == 1:
            # --- odd pass: Z = S^H Q (co-range slab per tile) ----------
            if st.cursor == 0:
                st.Y = _thin_q(st.Y)
                st.Z = np.zeros((M, ell), np.dtype(dtype))
            nxt = prov.tile(*tiles[st.cursor]) if st.cursor < n_tiles \
                else None
            while st.cursor < n_tiles:
                lo, hi = tiles[st.cursor]
                T, nxt = nxt, None
                Zt = _tile_project(T, st.Y, backend)
                if st.cursor + 1 < n_tiles:
                    nxt = prov.tile(*tiles[st.cursor + 1])
                st.Z[lo:hi] = np.asarray(Zt)
                st.cursor += 1
                maybe_ckpt(mid_sweep=True)
        else:
            # --- even pass: Y = S Z_orth (re-application) --------------
            if st.cursor == 0:
                # Orthonormalize the co-range so the final sketch's
                # singular values are Ritz values of S (and the
                # re-application stays well-conditioned).
                st.Z = np.asarray(_thin_q(jnp.asarray(st.Z)))
                st.Y = jnp.zeros((N, ell), dtype)
            nxt = prov.tile(*tiles[st.cursor]) if st.cursor < n_tiles \
                else None
            while st.cursor < n_tiles:
                lo, hi = tiles[st.cursor]
                T, nxt = nxt, None
                Y2 = _tile_apply(T, jnp.asarray(st.Z[lo:hi]), st.Y,
                                 backend)
                if st.cursor + 1 < n_tiles:
                    nxt = prov.tile(*tiles[st.cursor + 1])
                st.Y = Y2
                st.cursor += 1
                maybe_ckpt(mid_sweep=True)
        st.phase += 1
        st.cursor = 0
        if st.phase >= n_phases:
            st.done = 1
            st.Z = None
        maybe_ckpt(mid_sweep=False)

    # --- small dense SVD of the sketch (negligible next to one pass) ----
    U, s, _ = jnp.linalg.svd(st.Y, full_matrices=False)
    s = np.asarray(s, rdt)
    if power == 0:
        # E ||x^T Omega||^2 = ell ||x||^2 for unit-variance test columns
        svals = s / np.sqrt(float(ell))
    else:
        svals = s  # Ritz values of S on the orthonormal co-range
    if tau is None:
        k = min(max_k, ell)
    else:
        # Algorithm 1's criterion on the estimates: smallest k with
        # sigma_hat_{k+1} < tau.
        k = int(np.sum(svals >= tau))
        k = min(k, max_k, ell)
    Q = U[:, :k].astype(dtype)
    return RandomizedSketchResult(
        Q=Q, svals=svals, k=k, ell=ell, n_passes=n_phases,
        tile_m=tile_m, n_tiles=n_tiles, sketch_p=sketch_p, power=power,
        seed=seed, kind=kind, norms_sq=st.norms_sq,
    )
