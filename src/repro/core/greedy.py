"""Algorithm 3: RB-greedy with well-conditioned iterated Gram-Schmidt.

This is the paper's workhorse (the algorithm ``greedycpp`` implements).  The
per-iteration structure follows Sec. 6.1.2 exactly:

  pivot search:      sigma_k^2(s_i) = |s_i|^2 - sum_j |c_j|^2,  c_j = q_j^H s_i
                     (Eq. 6.3 — squared form, monotone accumulated sum, no
                     square roots, avoids catastrophic cancellation),
  orthogonalization: Hoffmann's iterated Gram-Schmidt with kappa = 2.

Orthogonalization note (hardware adaptation, see DESIGN.md §2): the paper's
serial code uses Hoffmann's iterated *modified* GS ("MGSCI", kappa=2) and
notes in §6.1.5 that its sequential column sweeps preclude BLAS-2/matvec
execution, suggesting the classical iterated variant ("CMGSI") for parallel
hardware.  We take that suggestion: orthogonalization is iterated *classical*
GS (two matvecs per pass, MXU-friendly), with the same kappa=2 re-run test
and the same conjectured orthogonality level |I - Q^H Q| ~ kappa eps sqrt(M).

Two drivers are provided:

- :func:`rb_greedy` — Python driver calling one jitted step per iteration
  (checkpointable/restartable between iterations; this is what the
  production launcher uses).
- :func:`rb_greedy_scan` — a single ``lax.scan`` over ``max_k`` iterations
  with masked dynamic stopping (embeddable inside a larger jit).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class GreedyResult(NamedTuple):
    """Result of Algorithm 3 / Algorithm 2 (they are equivalent, Prop 5.3).

    Attributes:
      Q:      (N, max_k) orthonormal basis; columns >= k are zero.
      R:      (max_k, M) rows of the triangular factor in ORIGINAL column
              order: R[j] = q_j^H S.  The pivoted-order diagonal is
              ``R[j, pivots[j]]`` (non-increasing, Prop 5.3).
      pivots: (max_k,) int32 selected column indices (the permutation Pi).
      errs:   (max_k,) greedy error *before* adding basis j, i.e.
              max_i |s_i - Q_j Q_j^H s_i|_2 with j bases (Cor. 5.6: equals
              R(j+1, j+1) in the paper's 1-based pivoted notation).
      k:      number of valid bases (first k with errs >= tau).
      n_ortho_passes: (max_k,) iterated-GS pass count per basis (paper: nu_j).
      rnorms: (max_k,) orthogonalization residual norms |v - Q Q^H v|_2 of
              each pivot column.  In exact arithmetic rnorms[j] == errs[j]
              (Cor. 5.6); their divergence signals numerical-rank exhaustion
              and drives the driver's rank guard.
    """

    Q: jax.Array
    R: jax.Array
    pivots: jax.Array
    errs: jax.Array
    k: jax.Array
    n_ortho_passes: jax.Array
    rnorms: jax.Array


def imgs_orthogonalize(
    v: jax.Array,
    Q: jax.Array,
    kappa: float = 2.0,
    max_passes: int = 3,
):
    """Hoffmann iterated (classical) Gram-Schmidt with ratio test kappa.

    Orthogonalizes ``v`` against the columns of ``Q`` (zero columns are
    harmless no-ops, so a zero-padded basis needs no masking).  Re-runs the
    projection while the norm dropped by more than a factor ``kappa``
    (Hoffmann's criterion; "twice is almost always enough", nu_j <= 3).

    Returns ``(q, coeffs, rnorm, n_passes)`` with
    ``v = Q @ coeffs + rnorm * q`` and ``|q|_2 = 1`` (when rnorm > 0).
    """
    norm0 = jnp.linalg.norm(v)

    def one_pass(v):
        c = Q.conj().T @ v
        return v - Q @ c, c

    # First pass is unconditional.
    v1, c1 = one_pass(v)

    def cond(state):
        v_cur, _, norm_prev, norm_cur, n = state
        return (norm_cur < norm_prev / kappa) & (n < max_passes)

    def body(state):
        v_cur, coeffs, _, norm_cur, n = state
        v_next, c = one_pass(v_cur)
        return (v_next, coeffs + c, norm_cur, jnp.linalg.norm(v_next), n + 1)

    v_fin, coeffs, _, rnorm, n_passes = jax.lax.while_loop(
        cond, body, (v1, c1, norm0, jnp.linalg.norm(v1), jnp.asarray(1))
    )
    safe = jnp.maximum(rnorm, jnp.finfo(rnorm.dtype).tiny)
    q = v_fin / safe.astype(v_fin.dtype)
    return q, coeffs, rnorm, n_passes


class GreedyState(NamedTuple):
    """Carried state of the greedy iteration (checkpointable pytree).

    ``norms_sq``/``acc`` implement the paper's Eq. (6.3) residual tracking:
    residual_i^2 = norms_sq_i - acc_i.  After an exact *refresh* (see
    :func:`greedy_refresh`) ``norms_sq`` holds the exact residuals at the
    refresh point and ``acc`` restarts from zero — same algebra, new (much
    smaller) reference scale, which removes the sqrt(eps)*|s| cancellation
    floor inherent to Eq. (6.3).
    """

    Q: jax.Array        # (N, max_k) basis, zero-padded
    R: jax.Array        # (max_k, M)
    norms_sq: jax.Array  # (M,)   reference residual^2 at last refresh (real)
    acc: jax.Array       # (M,)   sum_j |c_j|^2 since refresh (real, monotone)
    pivots: jax.Array    # (max_k,) int32
    errs: jax.Array      # (max_k,) real
    n_passes: jax.Array  # (max_k,) int32
    rnorms: jax.Array    # (max_k,) real — true residual norm of each pivot
    k: jax.Array         # () int32


def greedy_init(S: jax.Array, max_k: int) -> GreedyState:
    N, M = S.shape
    rdtype = jnp.zeros((), S.dtype).real.dtype
    return GreedyState(
        Q=jnp.zeros((N, max_k), S.dtype),
        R=jnp.zeros((max_k, M), S.dtype),
        norms_sq=jnp.sum(jnp.abs(S) ** 2, axis=0).astype(rdtype),
        acc=jnp.zeros((M,), rdtype),
        pivots=jnp.zeros((max_k,), jnp.int32),
        errs=jnp.zeros((max_k,), rdtype),
        n_passes=jnp.zeros((max_k,), jnp.int32),
        rnorms=jnp.zeros((max_k,), rdtype),
        k=jnp.asarray(0, jnp.int32),
    )


def greedy_step(
    S: jax.Array, state: GreedyState, kappa: float = 2.0, max_passes: int = 3
) -> GreedyState:
    """One iteration of Algorithm 3 (pivot search + orthogonalization).

    The residuals are the paper's Eq. (6.3): ``norms_sq - acc``; the argmax
    over columns is the pivot.  The selected column is orthogonalized with
    iterated GS and appended; the new row of R is ``q_k^H S`` which also
    updates the accumulated sums for every column at O(NM) — constant per
    iteration (paper Fig. 6.1a).
    """
    k = state.k
    res_sq = jnp.maximum(state.norms_sq - state.acc, 0.0)
    j = jnp.argmax(res_sq)
    err = jnp.sqrt(res_sq[j])

    v = jax.lax.dynamic_slice_in_dim(S, j, 1, axis=1)[:, 0]
    q, _, rnorm, n_pass = imgs_orthogonalize(v, state.Q, kappa, max_passes)

    c = q.conj() @ S  # (M,) row k of R — also the Eq. (6.3) update
    acc = state.acc + jnp.abs(c) ** 2

    return GreedyState(
        Q=state.Q.at[:, k].set(q),
        R=state.R.at[k, :].set(c),
        norms_sq=state.norms_sq,
        acc=acc,
        pivots=state.pivots.at[k].set(j.astype(jnp.int32)),
        errs=state.errs.at[k].set(err),
        n_passes=state.n_passes.at[k].set(n_pass.astype(jnp.int32)),
        rnorms=state.rnorms.at[k].set(rnorm.astype(state.rnorms.dtype)),
        k=k + 1,
    )


@functools.partial(jax.jit, static_argnames=("kappa", "max_passes"))
def _jitted_step(S, state, kappa: float = 2.0, max_passes: int = 3):
    return greedy_step(S, state, kappa, max_passes)


@jax.jit
def greedy_refresh(S: jax.Array, state: GreedyState) -> GreedyState:
    """Exact residual recomputation (beyond-paper deep-tolerance mode).

    Eq. (6.3) tracks residual^2 = |s|^2 - sum|c|^2, whose subtraction has an
    absolute error floor of eps * |s|^2 — i.e. the *reported* greedy error
    can never drop below ~sqrt(eps) * |s| even though the true residual does
    (the paper's code shares this property; its taus sit above the floor).
    This refresh recomputes E = S - Q (Q^H S) exactly (O(kNM), done O(log)
    times), storing the exact residual^2 as the new reference so subsequent
    Eq.-(6.3) updates are accurate relative to the *refreshed* scale.
    """
    C = state.Q.conj().T @ S             # (max_k, M); zero rows are no-ops
    E = S - state.Q @ C
    res = jnp.sum(jnp.abs(E) ** 2, axis=0).astype(state.norms_sq.dtype)
    return state._replace(norms_sq=res, acc=jnp.zeros_like(state.acc))


def rb_greedy(
    S: jax.Array,
    tau: float,
    max_k: int | None = None,
    kappa: float = 2.0,
    max_passes: int = 3,
    callback=None,
    refresh: str = "auto",
    refresh_safety: float = 100.0,
) -> GreedyResult:
    """Algorithm 3 driver: iterate until ``err < tau`` or ``k == max_k``.

    One jitted step per iteration; ``callback(state)`` (if given) is invoked
    after each step — the production launcher uses it for checkpointing.

    refresh: "auto" triggers :func:`greedy_refresh` when the tracked residual
    nears the Eq.-(6.3) cancellation floor (err^2 < safety * eps * ref^2);
    "never" is the paper-faithful mode.
    """
    N, M = S.shape
    if max_k is None:
        max_k = min(N, M)
    max_k = min(max_k, min(N, M))
    state = greedy_init(S, max_k)
    eps = float(jnp.finfo(state.norms_sq.dtype).eps)
    ref_sq = float(jnp.max(state.norms_sq))
    scale = ref_sq ** 0.5  # fixed global column scale for the rank guard
    k = 0
    while k < max_k:
        state = _jitted_step(S, state, kappa=kappa, max_passes=max_passes)
        k = int(state.k)
        if callback is not None:
            callback(state)
        err = float(state.errs[k - 1])
        rnorm = float(state.rnorms[k - 1])
        if rnorm < 50.0 * eps * scale:
            # Numerical-rank exhaustion: the pivot's true orthogonalization
            # residual is rounding noise — adding it would inject a junk,
            # non-orthogonal direction (Cor. 5.6 says rnorm == err in exact
            # arithmetic; their divergence is the symptom).  Drop and stop.
            k -= 1
            state = state._replace(
                k=jnp.asarray(k, jnp.int32),
                Q=state.Q.at[:, k].set(0),
                R=state.R.at[k, :].set(0),
                pivots=state.pivots.at[k].set(-1),
            )
            break
        if err < tau:
            # Last added basis was selected at an error already below tau:
            # drop it to match Algorithm 3's while-condition semantics.
            k -= 1
            state = state._replace(
                k=jnp.asarray(k, jnp.int32),
                Q=state.Q.at[:, k].set(0),
                R=state.R.at[k, :].set(0),
                pivots=state.pivots.at[k].set(-1),
            )
            break
        if refresh == "auto" and err * err < refresh_safety * eps * ref_sq:
            # Approaching the Eq.-(6.3) cancellation floor while still above
            # tau: recompute exact residuals and rescale the reference.
            state = greedy_refresh(S, state)
            ref_sq = max(float(jnp.max(state.norms_sq)), 1e-300)
            # The recorded err was floor noise; the *post-add* exact error
            # decides whether any further basis is needed (keep this one).
            if float(jnp.sqrt(ref_sq)) < tau:
                break
    return GreedyResult(
        Q=state.Q, R=state.R, pivots=state.pivots, errs=state.errs,
        k=state.k, n_ortho_passes=state.n_passes, rnorms=state.rnorms,
    )


@functools.partial(jax.jit, static_argnames=("max_k", "kappa", "max_passes"))
def rb_greedy_scan(
    S: jax.Array,
    tau: float,
    max_k: int,
    kappa: float = 2.0,
    max_passes: int = 3,
) -> GreedyResult:
    """Fixed-length ``lax.scan`` variant (embeddable inside jit).

    Runs exactly ``max_k`` iterations; iterations whose pre-add error is
    already below ``tau`` are masked out (the basis column stays zero), so
    the result matches :func:`rb_greedy` semantics with static shapes.
    """

    state0 = greedy_init(S, max_k)
    eps = jnp.finfo(state0.norms_sq.dtype).eps
    scale = jnp.sqrt(jnp.max(state0.norms_sq))

    def body(state, _):
        res_sq = jnp.maximum(state.norms_sq - state.acc, 0.0)
        j = jnp.argmax(res_sq)
        err = jnp.sqrt(res_sq[j])

        v = jax.lax.dynamic_slice_in_dim(S, j, 1, axis=1)[:, 0]
        q, _, rnorm, n_pass = imgs_orthogonalize(v, state.Q, kappa, max_passes)
        # Mask out both converged iterations and numerical-rank-exhausted
        # pivots (junk directions whose residual is rounding noise).
        active = (err >= tau) & (rnorm >= 50.0 * eps * scale)
        q = jnp.where(active, q, jnp.zeros_like(q))
        c = q.conj() @ S

        k = state.k
        new = GreedyState(
            Q=state.Q.at[:, k].set(q),
            R=state.R.at[k, :].set(c),
            norms_sq=state.norms_sq,
            acc=state.acc + jnp.abs(c) ** 2,
            pivots=state.pivots.at[k].set(
                jnp.where(active, j.astype(jnp.int32), -1)
            ),
            errs=state.errs.at[k].set(err),
            n_passes=state.n_passes.at[k].set(n_pass.astype(jnp.int32)),
            rnorms=state.rnorms.at[k].set(rnorm.astype(state.rnorms.dtype)),
            k=k + active.astype(jnp.int32),
        )
        return new, None

    state, _ = jax.lax.scan(body, state0, None, length=max_k)
    return GreedyResult(
        Q=state.Q, R=state.R, pivots=state.pivots, errs=state.errs,
        k=state.k, n_ortho_passes=state.n_passes, rnorms=state.rnorms,
    )
