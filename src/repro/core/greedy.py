"""Algorithm 3: RB-greedy with well-conditioned iterated Gram-Schmidt.

This is the paper's workhorse (the algorithm ``greedycpp`` implements).  The
per-iteration structure follows Sec. 6.1.2 exactly:

  pivot search:      sigma_k^2(s_i) = |s_i|^2 - sum_j |c_j|^2,  c_j = q_j^H s_i
                     (Eq. 6.3 — squared form, monotone accumulated sum, no
                     square roots, avoids catastrophic cancellation),
  orthogonalization: Hoffmann's iterated Gram-Schmidt with kappa = 2.

Orthogonalization note (hardware adaptation, see DESIGN.md §2): the paper's
serial code uses Hoffmann's iterated *modified* GS ("MGSCI", kappa=2) and
notes in §6.1.5 that its sequential column sweeps preclude BLAS-2/matvec
execution, suggesting the classical iterated variant ("CMGSI") for parallel
hardware.  We take that suggestion: orthogonalization is iterated *classical*
GS (two matvecs per pass, MXU-friendly), with the same kappa=2 re-run test
and the same conjectured orthogonality level |I - Q^H Q| ~ kappa eps sqrt(M).

Hot-loop primitives (the Eq.-6.3 sweep and the GS projection pass) are
routed through :mod:`repro.core.backend`, which dispatches to the fused
Pallas TPU kernels or the pure-``jnp`` XLA path (``backend=`` on every
entry point; default ``auto``).

Three drivers are provided:

- :func:`rb_greedy` — chunked device-resident driver: runs ``chunk``
  iterations inside ONE jitted ``lax.while_loop`` and only syncs with the
  host at chunk boundaries (stop codes for tau / rank-guard / refresh), so
  per-iteration dispatch + device->host transfer is amortized by ~chunk.
  ``callback(state)`` fires once per chunk; the state arrays carry the full
  per-step history (``chunk=1`` restores exact per-iteration callbacks).
- :func:`rb_greedy_stepwise` — the seed per-step driver (one jitted step +
  host sync per basis vector).  Kept as the parity oracle and benchmark
  baseline; semantics are identical pivot-for-pivot.
- :func:`rb_greedy_scan` — a single ``lax.scan`` over ``max_k`` iterations
  with masked dynamic stopping (embeddable inside a larger jit).
"""

from __future__ import annotations

import functools
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as _backend


class GreedyResult(NamedTuple):
    """Result of Algorithm 3 / Algorithm 2 (they are equivalent, Prop 5.3).

    Attributes:
      Q:      (N, max_k) orthonormal basis; columns >= k are zero.
      R:      (max_k, M) rows of the triangular factor in ORIGINAL column
              order: R[j] = q_j^H S.  The pivoted-order diagonal is
              ``R[j, pivots[j]]`` (non-increasing, Prop 5.3).
      pivots: (max_k,) int32 selected column indices (the permutation Pi).
      errs:   (max_k,) greedy error *before* adding basis j, i.e.
              max_i |s_i - Q_j Q_j^H s_i|_2 with j bases (Cor. 5.6: equals
              R(j+1, j+1) in the paper's 1-based pivoted notation).
      k:      number of valid bases (first k with errs >= tau).
      n_ortho_passes: (max_k,) iterated-GS pass count per basis (paper: nu_j).
      rnorms: (max_k,) orthogonalization residual norms |v - Q Q^H v|_2 of
              each pivot column.  In exact arithmetic rnorms[j] == errs[j]
              (Cor. 5.6); their divergence signals numerical-rank exhaustion
              and drives the driver's rank guard.
      stop:   why the build terminated (one of the STOP_* codes; see
              ``STOP_NAMES``).  ``STOP_NONE`` means it ran to ``max_k``.
    """

    Q: jax.Array
    R: jax.Array
    pivots: jax.Array
    errs: jax.Array
    k: jax.Array
    n_ortho_passes: jax.Array
    rnorms: jax.Array
    stop: int = 0


def imgs_orthogonalize(
    v: jax.Array,
    Q: jax.Array,
    kappa: float = 2.0,
    max_passes: int = 3,
    backend: str | None = None,
):
    """Hoffmann iterated (classical) Gram-Schmidt with ratio test kappa.

    Orthogonalizes ``v`` against the columns of ``Q`` (zero columns are
    harmless no-ops, so a zero-padded basis needs no masking).  Re-runs the
    projection while the norm dropped by more than a factor ``kappa``
    (Hoffmann's criterion; "twice is almost always enough", nu_j <= 3).
    Each projection pass goes through :func:`repro.core.backend.project_pass`
    (fused Pallas kernel on TPU, ``jnp`` under XLA).

    Returns ``(q, coeffs, rnorm, n_passes)`` with
    ``v = Q @ coeffs + rnorm * q`` and ``|q|_2 = 1`` (when rnorm > 0).
    """
    norm0 = jnp.linalg.norm(v)

    def one_pass(v):
        v_out, c = _backend.project_pass(v, Q, backend=backend)
        return v_out, c

    # First pass is unconditional.
    v1, c1 = one_pass(v)

    def cond(state):
        v_cur, _, norm_prev, norm_cur, n = state
        return (norm_cur < norm_prev / kappa) & (n < max_passes)

    def body(state):
        v_cur, coeffs, _, norm_cur, n = state
        v_next, c = one_pass(v_cur)
        return (v_next, coeffs + c, norm_cur, jnp.linalg.norm(v_next), n + 1)

    v_fin, coeffs, _, rnorm, n_passes = jax.lax.while_loop(
        cond, body, (v1, c1, norm0, jnp.linalg.norm(v1), jnp.asarray(1))
    )
    safe = jnp.maximum(rnorm, jnp.finfo(rnorm.dtype).tiny)
    q = v_fin / safe.astype(v_fin.dtype)
    return q, coeffs, rnorm, n_passes


def panel_imgs_orthogonalize(
    V: jax.Array,
    Q: jax.Array,
    kappa: float = 2.0,
    max_passes: int = 3,
    thresh=0.0,
    backend: str | None = None,
):
    """BLAS-3 panel orthogonalization: p candidates against Q in one pass.

    The blocked drivers' panel ortho hot path (classical panel
    factorization, cf. Quintana-Orti's BLAS-3 QR / Demmel et al. CA-RRQR):

    1. iterated classical-GS projection of the WHOLE (N, p) panel against
       ``Q`` through :func:`repro.core.backend.panel_project` — one
       (k, N) x (N, p) GEMM pair per pass instead of p GEMV chains — with
       Hoffmann's kappa re-run test evaluated PER COLUMN on the panel's
       post-update norms (converged columns are masked out of later
       passes),
    2. a within-panel sequential orthogonalization among the p candidates
       themselves (candidate i against the finalized panel columns < i,
       each via :func:`imgs_orthogonalize`'s iterated passes — O(p^2 N)
       work, negligible next to step 1's O(k p N)),
    3. the rank guard: a candidate whose final residual norm is not
       strictly above ``thresh`` becomes a zero "hole" column, so later
       candidates never orthogonalize against junk directions (zero
       columns are exact no-ops in every projection),
    4. a re-orthogonalization cycle (a second vs-Q panel pass + one
       within-panel sweep) on the NORMALIZED panel — the BCGS2 "twice is
       enough" pass, gated by Hoffmann's criterion applied to the
       within-panel drop: it runs exactly when some accepted candidate
       lost more than a ``kappa`` factor in step 2.  Step 2's large
       within-panel subtractions reintroduce O(eps * |c|) components
       along Q that step 1 cannot see, and normalizing a
       marginally-accepted candidate amplifies them by ``|v| / rnorm``
       (measured: percent-level defect on near-degenerate blocks);
       re-projecting the unit columns removes them at O(k p N) extra —
       the sequential path gets this for free because its iterated loop
       projects against Q and the earlier picks jointly.  Well-separated
       blocks (no within-panel cancellation) skip the cycle.

    Returns ``(P, oks, rnorms, n_passes)``:
      P:        (N, p) panel, orthonormal against Q and within itself;
                rejected candidates are zero columns.
      oks:      (p,) bool rank-guard verdicts (``rnorm > thresh``).
      rnorms:   (p,) real residual norms after steps 1-3 (recorded even
                when rejected, matching the stepwise drivers'
                diagnostics; the step-4 renormalization is an O(eps)
                correction on accepted columns).
      n_passes: (p,) int32 — vs-Q panel passes (incl. the re-ortho cycle)
                plus within-panel re-runs beyond the first (the
                per-candidate nu_j analogue).

    Spans the same space as p sequential :func:`imgs_orthogonalize` calls
    with fixed-slot writes (the pre-panel blocked path): candidate i is
    projected off Q and off the earlier in-block picks either way; only
    the float summation order differs (parity asserted in
    tests/test_block_greedy.py).
    """
    p = V.shape[1]
    norms0 = jnp.linalg.norm(V, axis=0)                       # (p,) real

    # First panel pass is unconditional (as in imgs_orthogonalize).
    V1, _ = _backend.panel_project(V, Q, backend=backend)
    norms1 = jnp.linalg.norm(V1, axis=0)

    def rerun_mask(norm_prev, norm_cur, n_col):
        return (norm_cur < norm_prev / kappa) & (n_col < max_passes)

    def cond(state):
        _, norm_prev, norm_cur, n_col = state
        return jnp.any(rerun_mask(norm_prev, norm_cur, n_col))

    def body(state):
        V_cur, norm_prev, norm_cur, n_col = state
        rerun = rerun_mask(norm_prev, norm_cur, n_col)
        # Full panel re-projection; converged columns keep their value
        # (the masked where below), so the per-column semantics match the
        # scalar driver's — the extra FLOPs on converged columns are free
        # next to the panel GEMM itself.
        V_next, _ = _backend.panel_project(V_cur, Q, backend=backend)
        norm_next = jnp.linalg.norm(V_next, axis=0)
        return (
            jnp.where(rerun[None, :], V_next, V_cur),
            jnp.where(rerun, norm_cur, norm_prev),
            jnp.where(rerun, norm_next, norm_cur),
            n_col + rerun.astype(n_col.dtype),
        )

    V_fin, _, norms_q, n_col = jax.lax.while_loop(
        cond, body, (V1, norms0, norms1, jnp.ones((p,), jnp.int32))
    )

    # Within-panel sequential orthogonalization (p is small and static):
    # candidate i against the finalized panel columns < i.  Zero columns
    # (later slots, rejected candidates) are exact no-ops.
    P = jnp.zeros_like(V)
    oks, rnorms, extra = [], [], []
    for i in range(p):
        q, _, rnorm, n_pass = imgs_orthogonalize(
            V_fin[:, i], P, kappa, max_passes, backend=backend
        )
        ok = rnorm > thresh
        q = jnp.where(ok, q, jnp.zeros_like(q))
        P = P.at[:, i].set(q)
        oks.append(ok)
        rnorms.append(rnorm)
        extra.append(n_pass - 1)  # re-runs beyond the unconditional pass
    oks = jnp.asarray(oks)
    rnorms = jnp.stack(rnorms)

    # Re-orthogonalization cycle (step 4), gated per block: some accepted
    # candidate dropped by more than kappa through the within-panel sweep
    # — its normalization amplified rounding noise along Q/panel by the
    # same factor.  Rejected (zero) columns project to zero and stay zero.
    need_reortho = jnp.any(oks & (rnorms * kappa < norms_q))

    def reortho(P_in):
        P2, _ = _backend.panel_project(P_in, Q, backend=backend)
        P_out = jnp.zeros_like(P_in)
        for i in range(p):
            v, _ = _backend.project_pass(P2[:, i], P_out, backend=backend)
            nrm = jnp.linalg.norm(v)
            safe = jnp.maximum(nrm, jnp.finfo(nrm.dtype).tiny)
            q = jnp.where(oks[i], v / safe.astype(v.dtype),
                          jnp.zeros_like(v))
            P_out = P_out.at[:, i].set(q)
        return P_out

    P = jax.lax.cond(need_reortho, reortho, lambda P_in: P_in, P)

    return (
        P,
        oks,
        rnorms,
        n_col + need_reortho.astype(jnp.int32) + jnp.asarray(extra,
                                                             jnp.int32),
    )


class GreedyState(NamedTuple):
    """Carried state of the greedy iteration (checkpointable pytree).

    ``norms_sq``/``acc`` implement the paper's Eq. (6.3) residual tracking:
    residual_i^2 = norms_sq_i - acc_i.  After an exact *refresh* (see
    :func:`greedy_refresh`) ``norms_sq`` holds the exact residuals at the
    refresh point and ``acc`` restarts from zero — same algebra, new (much
    smaller) reference scale, which removes the sqrt(eps)*|s| cancellation
    floor inherent to Eq. (6.3).
    """

    Q: jax.Array        # (N, max_k) basis, zero-padded
    R: jax.Array        # (max_k, M)
    norms_sq: jax.Array  # (M,)   reference residual^2 at last refresh (real)
    acc: jax.Array       # (M,)   sum_j |c_j|^2 since refresh (real, monotone)
    pivots: jax.Array    # (max_k,) int32
    errs: jax.Array      # (max_k,) real
    n_passes: jax.Array  # (max_k,) int32
    rnorms: jax.Array    # (max_k,) real — true residual norm of each pivot
    k: jax.Array         # () int32


@functools.partial(jax.jit, static_argnames=("max_k",))
def greedy_init(S: jax.Array, max_k: int) -> GreedyState:
    """Initial greedy state.  Jitted: eager ``jnp.abs(S) ** 2`` would
    materialize a full S-sized temporary before the norm reduction — at the
    production shape that is an extra multi-hundred-MB allocation and two
    memory passes per driver call."""
    N, M = S.shape
    rdtype = jnp.zeros((), S.dtype).real.dtype
    return GreedyState(
        Q=jnp.zeros((N, max_k), S.dtype),
        R=jnp.zeros((max_k, M), S.dtype),
        norms_sq=jnp.sum(jnp.abs(S) ** 2, axis=0).astype(rdtype),
        acc=jnp.zeros((M,), rdtype),
        pivots=jnp.zeros((max_k,), jnp.int32),
        errs=jnp.zeros((max_k,), rdtype),
        n_passes=jnp.zeros((max_k,), jnp.int32),
        rnorms=jnp.zeros((max_k,), rdtype),
        k=jnp.asarray(0, jnp.int32),
    )


def greedy_step(
    S: jax.Array,
    state: GreedyState,
    kappa: float = 2.0,
    max_passes: int = 3,
    backend: str | None = None,
) -> GreedyState:
    """One iteration of Algorithm 3 (pivot search + orthogonalization).

    The residuals are the paper's Eq. (6.3): ``norms_sq - acc``; the argmax
    over columns is the pivot.  The selected column is orthogonalized with
    iterated GS and appended; the new row of R is ``q_k^H S`` which also
    updates the accumulated sums for every column at O(NM) — constant per
    iteration (paper Fig. 6.1a).  The sweep runs through
    :func:`repro.core.backend.pivot_update` (fused Pallas kernel on TPU).
    """
    k = state.k
    res_sq = jnp.maximum(state.norms_sq - state.acc, 0.0)
    j = jnp.argmax(res_sq)
    err = jnp.sqrt(res_sq[j])

    v = jax.lax.dynamic_slice_in_dim(S, j, 1, axis=1)[:, 0]
    q, _, rnorm, n_pass = imgs_orthogonalize(
        v, state.Q, kappa, max_passes, backend=backend
    )

    # Row k of R and the Eq.-(6.3) update in one fused S pass.  The fused
    # kernel's post-update max/argmax belong to the NEXT pivot; this step
    # re-derives them from norms_sq - acc above, so they are unused here
    # (free in the Pallas pass, dead-code-eliminated under XLA).
    c, acc, _, _ = _backend.pivot_update(
        q, S, state.acc, state.norms_sq, backend=backend
    )

    return GreedyState(
        Q=state.Q.at[:, k].set(q),
        R=state.R.at[k, :].set(c),
        norms_sq=state.norms_sq,
        acc=acc,
        pivots=state.pivots.at[k].set(j.astype(jnp.int32)),
        errs=state.errs.at[k].set(err),
        n_passes=state.n_passes.at[k].set(n_pass.astype(jnp.int32)),
        rnorms=state.rnorms.at[k].set(rnorm.astype(state.rnorms.dtype)),
        k=k + 1,
    )


@functools.partial(
    jax.jit, static_argnames=("kappa", "max_passes", "backend")
)
def _jitted_step(S, state, kappa: float = 2.0, max_passes: int = 3,
                 backend: str | None = None):
    return greedy_step(S, state, kappa, max_passes, backend=backend)


@jax.jit
def greedy_refresh(S: jax.Array, state: GreedyState) -> GreedyState:
    """Exact residual recomputation (beyond-paper deep-tolerance mode).

    Eq. (6.3) tracks residual^2 = |s|^2 - sum|c|^2, whose subtraction has an
    absolute error floor of eps * |s|^2 — i.e. the *reported* greedy error
    can never drop below ~sqrt(eps) * |s| even though the true residual does
    (the paper's code shares this property; its taus sit above the floor).
    This refresh recomputes E = S - Q (Q^H S) exactly (O(kNM), done O(log)
    times), storing the exact residual^2 as the new reference so subsequent
    Eq.-(6.3) updates are accurate relative to the *refreshed* scale.
    """
    C = state.Q.conj().T @ S             # (max_k, M); zero rows are no-ops
    E = S - state.Q @ C
    res = jnp.sum(jnp.abs(E) ** 2, axis=0).astype(state.norms_sq.dtype)
    return state._replace(norms_sq=res, acc=jnp.zeros_like(state.acc))


# Stop codes reported by a device-resident chunk (host reads ONE scalar per
# chunk instead of err/rnorm floats per iteration).  STOP_FLOOR is a
# host-side verdict only (the post-refresh floor gate), never an in-chunk
# code.
STOP_NONE, STOP_RANK, STOP_TAU, STOP_REFRESH, STOP_FLOOR = 0, 1, 2, 3, 4

STOP_NAMES = {
    STOP_NONE: "STOP_NONE",        # ran to max_k (or slot capacity)
    STOP_RANK: "STOP_RANK",        # numerical-rank exhaustion (rank guard)
    STOP_TAU: "STOP_TAU",          # converged below tau
    STOP_REFRESH: "STOP_REFRESH",  # internal chunk code, never final
    STOP_FLOOR: "STOP_FLOOR",      # estimated achievable floor reached
}

# Safety factor of the achievable-floor gate.  After an exact refresh the
# residuals are trustworthy; if the max residual sits within FLOOR_SAFETY
# of the estimated floor the build cannot meaningfully improve and further
# bases would be noise-amplified directions.
FLOOR_SAFETY = 10.0


def floor_estimate(eps: float, scale: float, k: int) -> float:
    """Estimated achievable residual floor of a k-basis build.

    Each of the k orthogonalization/projection stages contributes O(eps)
    rounding relative to the data scale ``scale`` (= max column norm, the
    rank guard's reference); the contributions accumulate stochastically,
    giving ~eps * |s| * sqrt(k).  ``FLOOR_SAFETY`` absorbs the constants.
    A post-refresh exact residual at or below this value is indistinguishable
    from orthogonalization noise — the principled stop point PR 5's
    tau-before-refresh precedence only papered over.
    """
    return FLOOR_SAFETY * eps * scale * max(k, 1) ** 0.5


def _drop_last(state: GreedyState, k: int) -> GreedyState:
    """Remove the most recently added basis (tau-stop / rank-guard drop)."""
    return state._replace(
        k=jnp.asarray(k, jnp.int32),
        Q=state.Q.at[:, k].set(0),
        R=state.R.at[k, :].set(0),
        pivots=state.pivots.at[k].set(-1),
    )


# ------------------------------------------- resident checkpoint/resume ----
# The chunked resident drivers (rb_greedy here; the blocked/distributed
# siblings reuse these helpers) persist their GreedyState at chunk
# boundaries through repro.checkpoint.io.  The tree carries the host-side
# loop variables too (ref_sq changes at refresh; scale is fixed at init but
# must survive a restart) plus a ``done``/``stop`` pair saved AFTER the
# host's stop handling: the jitted chunk always runs >= 1 iteration, so
# resuming a finished build into the loop would add extra bases — a done
# checkpoint short-circuits straight to the result instead.

_RESIDENT_STATE_VERSION = 1


def resident_state_tree(state, ref_sq: float, scale: float, done: bool,
                        stop: int, extra: dict | None = None) -> dict:
    """Flat numpy tree of a resident GreedyState + host loop variables.

    Only the first ``k`` rows of R are saved (checkpoint traffic scales
    with k*M, not max_k*M); :func:`resident_state_from_tree` zero-pads
    them back.
    """
    k = int(state.k)
    tree = {
        "version": np.asarray(_RESIDENT_STATE_VERSION, np.int64),
        "Q": np.asarray(jax.device_get(state.Q)),
        "R": np.asarray(jax.device_get(state.R))[:k],
        "norms_sq": np.asarray(jax.device_get(state.norms_sq)),
        "acc": np.asarray(jax.device_get(state.acc)),
        "pivots": np.asarray(jax.device_get(state.pivots)),
        "errs": np.asarray(jax.device_get(state.errs)),
        "n_passes": np.asarray(jax.device_get(state.n_passes)),
        "rnorms": np.asarray(jax.device_get(state.rnorms)),
        "k": np.asarray(k, np.int64),
        "ref_sq": np.asarray(ref_sq, np.float64),
        "scale": np.asarray(scale, np.float64),
        "done": np.asarray(int(done), np.int64),
        "stop": np.asarray(int(stop), np.int64),
    }
    for key, val in (extra or {}).items():
        tree[key] = np.asarray(val)
    return tree


def resident_state_from_tree(tree: dict):
    """Inverse of :func:`resident_state_tree`.

    Returns ``(state, ref_sq, scale, done, stop)`` with the state's array
    leaves as host numpy (callers device_put / shard as needed).
    """
    version = int(tree["version"])
    if version != _RESIDENT_STATE_VERSION:
        raise ValueError(
            f"resident checkpoint version {version} != supported "
            f"{_RESIDENT_STATE_VERSION}"
        )
    max_k = tree["Q"].shape[1]
    M = tree["norms_sq"].shape[0]
    R = np.zeros((max_k, M), tree["R"].dtype)
    R[:tree["R"].shape[0]] = tree["R"]
    state = GreedyState(
        Q=tree["Q"], R=R, norms_sq=tree["norms_sq"], acc=tree["acc"],
        pivots=tree["pivots"], errs=tree["errs"],
        n_passes=tree["n_passes"], rnorms=tree["rnorms"],
        k=np.asarray(int(tree["k"]), np.int32),
    )
    return (state, float(tree["ref_sq"]), float(tree["scale"]),
            bool(int(tree["done"])), int(tree["stop"]))


def save_resident_checkpoint(directory: str, seq: int, state, ref_sq, scale,
                             done: bool, stop: int,
                             extra: dict | None = None, keep: int = 2) -> int:
    """Persist one resident-driver step; returns the new sequence number."""
    from repro.checkpoint.io import prune_steps, save_checkpoint

    seq += 1
    save_checkpoint(
        resident_state_tree(state, ref_sq, scale, done, stop, extra),
        directory, seq,
    )
    prune_steps(directory, keep)
    return seq


def load_resident_checkpoint(directory: str):
    """Latest intact resident checkpoint tree, or None if none exists."""
    from repro.checkpoint.io import latest_step, load_checkpoint_raw

    if latest_step(directory) is None:
        return None
    return load_checkpoint_raw(directory)


def _validate_resident_tree(tree, N, M, max_k, dtype, what="checkpoint"):
    if tree["Q"].shape != (N, max_k) or tree["norms_sq"].shape != (M,):
        raise ValueError(
            f"{what} shape mismatch: Q {tree['Q'].shape} / M "
            f"{tree['norms_sq'].shape[0]} vs requested ({N}, {max_k}) / {M}"
        )
    if tree["Q"].dtype != np.dtype(dtype):
        raise ValueError(
            f"{what} dtype mismatch: saved {tree['Q'].dtype}, "
            f"requested {np.dtype(dtype)}"
        )


def _greedy_chunk_impl(
    S,
    state,
    tau,
    scale,
    ref_sq,
    refresh_safety,
    chunk: int,
    kappa: float = 2.0,
    max_passes: int = 3,
    backend: str | None = None,
    check_refresh: bool = True,
):
    """Run up to ``chunk`` greedy iterations device-resident.

    A ``lax.while_loop`` applies :func:`greedy_step` until a host-relevant
    event fires (rank-guard, tau, refresh trigger — checked in the seed
    driver's order) or ``chunk``/``max_k`` iterations elapse.  Returns
    ``(state, n_done, stop_code)``; the host only ever syncs these, so
    dispatch + transfer cost is paid once per chunk, not per basis vector.
    """
    max_k = state.Q.shape[1]
    eps = jnp.finfo(state.norms_sq.dtype).eps

    def cond(carry):
        st, n, stop = carry
        return (stop == STOP_NONE) & (n < chunk) & (st.k < max_k)

    def body(carry):
        st, n, _ = carry
        st = greedy_step(S, st, kappa, max_passes, backend=backend)
        k = st.k
        err = st.errs[k - 1]
        rnorm = st.rnorms[k - 1]
        refresh_hit = check_refresh & (err * err < refresh_safety * eps
                                       * ref_sq)
        stop = jnp.where(
            rnorm < 50.0 * eps * scale,
            STOP_RANK,
            jnp.where(err < tau, STOP_TAU,
                      jnp.where(refresh_hit, STOP_REFRESH, STOP_NONE)),
        ).astype(jnp.int32)
        return (st, n + 1, stop)

    state, n_done, stop = jax.lax.while_loop(
        cond, body,
        (state, jnp.asarray(0, jnp.int32), jnp.asarray(STOP_NONE, jnp.int32)),
    )
    return state, n_done, stop


_CHUNK_STATICS = ("chunk", "kappa", "max_passes", "backend", "check_refresh")

# Non-donating variant: supports repeated application to one state
# (benchmarks time the hot loop this way).
_greedy_chunk = jax.jit(_greedy_chunk_impl, static_argnames=_CHUNK_STATICS)

# The driver's variant donates the state pytree so Q/R/acc buffers are
# reused across chunks instead of copied (matters on accelerators; CPU
# ignores donation).  The previous state is never touched again by the
# driver, so donation is safe there.
_greedy_chunk_donated = jax.jit(
    _greedy_chunk_impl, static_argnames=_CHUNK_STATICS, donate_argnums=(1,)
)


def rb_greedy(
    S,
    tau: float,
    max_k: int | None = None,
    kappa: float = 2.0,
    max_passes: int = 3,
    callback=None,
    refresh: str = "auto",
    refresh_safety: float = 100.0,
    chunk: int = 16,
    backend: str | None = None,
    checkpoint_dir: str | None = None,
    resume: bool = False,
) -> GreedyResult:
    """Algorithm 3 driver: iterate until ``err < tau`` or ``k == max_k``.

    Chunked device-resident hot loop: ``chunk`` iterations run inside one
    jitted ``lax.while_loop`` and the host syncs only the (n_done, stop)
    scalars at chunk boundaries — identical pivots/bases to
    :func:`rb_greedy_stepwise` (asserted in tests/test_chunked_driver.py),
    ~chunk x fewer dispatches and device->host transfers.

    ``callback(state)`` fires once per chunk (the state arrays hold the full
    per-step history up to ``state.k``); pass ``chunk=1`` to restore the
    seed driver's exact per-iteration callback cadence.  When a callback is
    set the chunk does NOT donate the state buffers, so retained states
    (checkpoint histories) stay valid on accelerators; without one the
    state is donated and Q/R/acc buffers are reused across chunks.

    Stop thresholds are compared ON DEVICE in the residual dtype: with x64
    disabled (f32/c64 inputs) an err within ~1 ulp of ``tau`` can round the
    stopping decision differently from the stepwise driver's float64 host
    comparison — one basis at the boundary, nothing else.

    refresh: "auto" triggers :func:`greedy_refresh` when the tracked residual
    nears the Eq.-(6.3) cancellation floor (err^2 < safety * eps * ref^2);
    "never" is the paper-faithful mode.  If the post-refresh exact residual
    is still above tau but at or below :func:`floor_estimate`, the build
    stops with ``STOP_FLOOR`` instead of accepting noise-amplified
    directions.

    ``checkpoint_dir``/``resume``: with a directory set the driver persists
    its full state (plus a done/stop marker) after every chunk's stop
    handling; ``resume=True`` picks up from the newest intact step and a
    finished checkpoint short-circuits straight to the result, so killing
    the process at any point and re-running yields a bit-identical build.

    ``S`` may be anything :func:`repro.data.providers.as_provider` accepts
    (arrays pass through; paths/providers are materialized — use
    :func:`repro.core.streaming.rb_greedy_streamed` for sources that do
    not fit on device).
    """
    from repro.data.providers import materialize_source

    S = materialize_source(S)
    N, M = S.shape
    if max_k is None:
        max_k = min(N, M)
    max_k = min(max_k, min(N, M))
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    # Resolve here, NOT at trace time: the jit cache is keyed on the static
    # backend argument, so a still-None backend would freeze whatever the
    # env/default resolved to at first trace.
    backend = _backend.resolve_backend(backend)
    state = greedy_init(S, max_k)
    rdt = state.norms_sq.dtype
    eps = float(jnp.finfo(rdt).eps)
    ref_sq = float(jnp.max(state.norms_sq))
    scale = ref_sq ** 0.5  # fixed global column scale for the rank guard
    done = False
    final_stop = STOP_NONE
    seq = 0
    if checkpoint_dir is not None:
        from repro.checkpoint.io import latest_step

        tree = load_resident_checkpoint(checkpoint_dir) if resume else None
        if tree is not None:
            _validate_resident_tree(tree, N, M, max_k, state.Q.dtype,
                                    "resume checkpoint")
            st_host, ref_sq, scale, done, final_stop = \
                resident_state_from_tree(tree)
            state = GreedyState(*(jnp.asarray(x) for x in st_host))
        # Fresh build into a dir with older steps: continue the sequence so
        # prune/latest never interleave with stale numbering.
        seq = latest_step(checkpoint_dir) or 0
    # A callback may retain states (checkpointing); donation would
    # invalidate those retained buffers on accelerators.
    chunk_fn = _greedy_chunk if callback is not None else \
        _greedy_chunk_donated
    # invariant thresholds device-placed once; only ref_sq changes (refresh)
    tau_d = jnp.asarray(tau, rdt)
    scale_d = jnp.asarray(scale, rdt)
    safety_d = jnp.asarray(refresh_safety, rdt)
    ref_sq_d = jnp.asarray(ref_sq, rdt)
    k = int(state.k)
    while not done and k < max_k:
        state, n_done, stop = chunk_fn(
            S, state, tau_d, scale_d, ref_sq_d, safety_d,
            chunk=chunk, kappa=kappa, max_passes=max_passes,
            backend=backend, check_refresh=(refresh == "auto"),
        )
        k = int(state.k)
        if callback is not None:
            callback(state)
        stop = int(stop)
        if stop == STOP_RANK:
            # Numerical-rank exhaustion: the pivot's true orthogonalization
            # residual is rounding noise — adding it would inject a junk,
            # non-orthogonal direction (Cor. 5.6 says rnorm == err in exact
            # arithmetic; their divergence is the symptom).  Drop and stop.
            k -= 1
            state = _drop_last(state, k)
            done, final_stop = True, STOP_RANK
        elif stop == STOP_TAU:
            # Last added basis was selected at an error already below tau:
            # drop it to match Algorithm 3's while-condition semantics.
            k -= 1
            state = _drop_last(state, k)
            done, final_stop = True, STOP_TAU
        elif stop == STOP_REFRESH:
            # Approaching the Eq.-(6.3) cancellation floor while still above
            # tau: recompute exact residuals and rescale the reference.
            state = greedy_refresh(S, state)
            ref_sq = max(float(jnp.max(state.norms_sq)), 1e-300)
            ref_sq_d = jnp.asarray(ref_sq, rdt)
            # The recorded err was floor noise; the *post-add* exact error
            # decides whether any further basis is needed (keep this one).
            if ref_sq ** 0.5 < tau:
                done, final_stop = True, STOP_TAU
            elif ref_sq ** 0.5 <= floor_estimate(eps, scale, k):
                # Exact residual parked at the achievable floor: tau is
                # unreachable in this precision — stop gracefully rather
                # than accept noise-amplified directions.
                done, final_stop = True, STOP_FLOOR
        if not done and k >= max_k:
            done = True  # ran to capacity; final_stop stays STOP_NONE
        # (no n_done check: the chunk cond guarantees >= 1 iteration, and
        # reading it back would add a host sync per chunk)
        if checkpoint_dir is not None:
            # Save AFTER stop handling: the chunk always runs >= 1
            # iteration, so a pre-handling snapshot of a finished build
            # would grow extra bases on resume.
            seq = save_resident_checkpoint(
                checkpoint_dir, seq, state, ref_sq, scale, done, final_stop)
    return GreedyResult(
        Q=state.Q, R=state.R, pivots=state.pivots, errs=state.errs,
        k=state.k, n_ortho_passes=state.n_passes, rnorms=state.rnorms,
        stop=final_stop,
    )


def rb_greedy_stepwise(
    S,
    tau: float,
    max_k: int | None = None,
    kappa: float = 2.0,
    max_passes: int = 3,
    callback=None,
    refresh: str = "auto",
    refresh_safety: float = 100.0,
    backend: str | None = None,
) -> GreedyResult:
    """The seed per-step driver: one jitted step + host sync per iteration.

    Pays one dispatch plus ``float(errs[k-1])``/``float(rnorms[k-1])``
    device->host syncs per basis vector.  Kept verbatim as (a) the parity
    oracle for :func:`rb_greedy` and (b) the benchmark baseline the chunked
    driver is measured against; ``callback(state)`` fires every iteration.
    """
    from repro.data.providers import materialize_source

    S = materialize_source(S)
    N, M = S.shape
    if max_k is None:
        max_k = min(N, M)
    max_k = min(max_k, min(N, M))
    backend = _backend.resolve_backend(backend)  # see rb_greedy
    state = greedy_init(S, max_k)
    eps = float(jnp.finfo(state.norms_sq.dtype).eps)
    ref_sq = float(jnp.max(state.norms_sq))
    scale = ref_sq ** 0.5  # fixed global column scale for the rank guard
    final_stop = STOP_NONE
    k = 0
    while k < max_k:
        state = _jitted_step(S, state, kappa=kappa, max_passes=max_passes,
                             backend=backend)
        k = int(state.k)
        if callback is not None:
            callback(state)
        err = float(state.errs[k - 1])
        rnorm = float(state.rnorms[k - 1])
        if rnorm < 50.0 * eps * scale:
            k -= 1
            state = _drop_last(state, k)
            final_stop = STOP_RANK
            break
        if err < tau:
            k -= 1
            state = _drop_last(state, k)
            final_stop = STOP_TAU
            break
        if refresh == "auto" and err * err < refresh_safety * eps * ref_sq:
            state = greedy_refresh(S, state)
            ref_sq = max(float(jnp.max(state.norms_sq)), 1e-300)
            if float(jnp.sqrt(ref_sq)) < tau:
                final_stop = STOP_TAU
                break
            if ref_sq ** 0.5 <= floor_estimate(eps, scale, k):
                final_stop = STOP_FLOOR
                break
    return GreedyResult(
        Q=state.Q, R=state.R, pivots=state.pivots, errs=state.errs,
        k=state.k, n_ortho_passes=state.n_passes, rnorms=state.rnorms,
        stop=final_stop,
    )


def rb_greedy_scan(
    S: jax.Array,
    tau: float,
    max_k: int,
    kappa: float = 2.0,
    max_passes: int = 3,
    backend: str | None = None,
) -> GreedyResult:
    """Fixed-length ``lax.scan`` variant (embeddable inside jit).

    Runs exactly ``max_k`` iterations; iterations whose pre-add error is
    already below ``tau`` are masked out (the basis column stays zero), so
    the result matches :func:`rb_greedy` semantics with static shapes.
    """
    # resolve pre-jit so the cache keys on the concrete backend name
    return _rb_greedy_scan(S, tau, max_k, kappa, max_passes,
                           _backend.resolve_backend(backend))


@functools.partial(
    jax.jit, static_argnames=("max_k", "kappa", "max_passes", "backend")
)
def _rb_greedy_scan(
    S: jax.Array,
    tau: float,
    max_k: int,
    kappa: float = 2.0,
    max_passes: int = 3,
    backend: str | None = None,
) -> GreedyResult:

    state0 = greedy_init(S, max_k)
    eps = jnp.finfo(state0.norms_sq.dtype).eps
    scale = jnp.sqrt(jnp.max(state0.norms_sq))

    def body(state, _):
        res_sq = jnp.maximum(state.norms_sq - state.acc, 0.0)
        j = jnp.argmax(res_sq)
        err = jnp.sqrt(res_sq[j])

        v = jax.lax.dynamic_slice_in_dim(S, j, 1, axis=1)[:, 0]
        q, _, rnorm, n_pass = imgs_orthogonalize(
            v, state.Q, kappa, max_passes, backend=backend
        )
        # Mask out both converged iterations and numerical-rank-exhausted
        # pivots (junk directions whose residual is rounding noise).
        active = (err >= tau) & (rnorm >= 50.0 * eps * scale)
        q = jnp.where(active, q, jnp.zeros_like(q))
        c, acc_out, _, _ = _backend.pivot_update(
            q, S, state.acc, state.norms_sq, backend=backend
        )

        k = state.k
        new = GreedyState(
            Q=state.Q.at[:, k].set(q),
            R=state.R.at[k, :].set(c),
            norms_sq=state.norms_sq,
            acc=acc_out,
            pivots=state.pivots.at[k].set(
                jnp.where(active, j.astype(jnp.int32), -1)
            ),
            errs=state.errs.at[k].set(err),
            n_passes=state.n_passes.at[k].set(n_pass.astype(jnp.int32)),
            rnorms=state.rnorms.at[k].set(rnorm.astype(state.rnorms.dtype)),
            k=k + active.astype(jnp.int32),
        )
        return new, None

    state, _ = jax.lax.scan(body, state0, None, length=max_k)
    return GreedyResult(
        Q=state.Q, R=state.R, pivots=state.pivots, errs=state.errs,
        k=state.k, n_ortho_passes=state.n_passes, rnorms=state.rnorms,
    )
