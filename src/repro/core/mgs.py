"""Algorithm 2: modified Gram-Schmidt with column pivoting.

This is the faithful, column-sweep MGS of the paper (the linear-algebra
community's presentation).  It is kept as the *reference* implementation for
the equivalence result (Proposition 5.3): `tests/test_equivalence.py` checks
that it selects exactly the same pivots as :func:`repro.core.greedy.rb_greedy`
and spans the same subspace.

The working matrix V is updated in place (rank-1 deflation per step), which
is what gives MGS its O(6kNM) count (Remark 5.4) and its extra memory
overhead relative to RB-greedy (Remark 5.4's discussion).
"""

from __future__ import annotations

import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp


class MGSResult(NamedTuple):
    Q: jax.Array        # (N, k) orthonormal basis (pivoted order)
    R: jax.Array        # (k, M) triangular rows in ORIGINAL column order
    pivots: jax.Array   # (k,) selected columns
    r_diag: jax.Array   # (k,) R(j, j) in pivoted order == column norms at pick
    k: int


def mgs_pivoted_qr(S, tau: float, max_k: int | None = None) -> MGSResult:
    """Deprecated entry point: use ``repro.api.build_basis(source=S,
    strategy="mgs", tau=tau)``.

    Pivoted MGS selects the same pivots as RB-greedy (Prop. 5.3) — as a
    *public* entry point it is redundant with the front door, which also
    returns the unified :class:`~repro.api.artifact.ReducedBasis` artifact.
    The implementation is unchanged and stays the Prop.-5.3 reference
    oracle; this wrapper delegates to it verbatim.
    """
    warnings.warn(
        "mgs_pivoted_qr is deprecated: call repro.api.build_basis("
        "source=S, strategy='mgs', tau=tau) instead (identical pivots and "
        "basis, unified ReducedBasis result)",
        DeprecationWarning,
        stacklevel=2,
    )
    return _mgs_pivoted_qr_impl(S, tau, max_k)


def _mgs_pivoted_qr_impl(S, tau: float,
                         max_k: int | None = None) -> MGSResult:
    """Algorithm 2 (host-loop reference implementation).

    Stops when ``R(k,k) = max_j |V(:,j)|_2 < tau`` (the paper's criterion,
    equal to the RB-greedy max-residual by Cor. 5.6) or at ``max_k``.

    ``S`` may be anything :func:`repro.data.providers.as_provider`
    accepts (arrays pass through; paths/providers are materialized).
    """
    from repro.data.providers import materialize_source

    S = materialize_source(S)
    N, M = S.shape
    if max_k is None:
        max_k = min(N, M)
    max_k = min(max_k, min(N, M))

    V = jnp.asarray(S)
    Q_cols = []
    R_rows = []
    pivots = []
    r_diag = []

    for _ in range(max_k):
        col_norms = jnp.linalg.norm(V, axis=0)
        j = int(jnp.argmax(col_norms))
        rkk = float(col_norms[j])
        if rkk < tau:
            break
        q = V[:, j] / jnp.asarray(rkk, V.dtype)
        # MGS deflation: R(k, :) = q^H V are the coefficients against the
        # *current* working columns; by Prop 5.3 these equal q^H S for the
        # not-yet-pivoted columns.
        r_row = q.conj() @ V
        V = V - jnp.outer(q, r_row)
        # Freeze already-pivoted columns at zero to avoid re-selection.
        V = V.at[:, j].set(0)
        Q_cols.append(q)
        # report R in original column order as q^H S (identical for the
        # active columns; makes cross-checking with rb_greedy trivial).
        R_rows.append(q.conj() @ jnp.asarray(S))
        pivots.append(j)
        r_diag.append(rkk)

    k = len(Q_cols)
    Q = jnp.stack(Q_cols, axis=1) if k else jnp.zeros((N, 0), S.dtype)
    R = jnp.stack(R_rows, axis=0) if k else jnp.zeros((0, M), S.dtype)
    return MGSResult(
        Q=Q,
        R=R,
        pivots=jnp.asarray(pivots, jnp.int32),
        r_diag=jnp.asarray(r_diag),
        k=k,
    )
