"""Empirical interpolation (EIM/DEIM) and reduced-order quadrature (ROQ).

The greedycpp code pairs the greedy basis with empirical-interpolation node
selection ("a fast algorithm, see Alg. 5 of Ref. [6]") and uses the result to
build reduced-order quadrature rules that accelerate gravitational-wave
likelihood evaluations.  This module implements:

- :func:`eim_nodes` — greedy node selection (DEIM): node i maximizes the
  magnitude of the i-th basis vector's interpolation residual.
- :func:`empirical_interpolant` — builds B = Q (Q[nodes, :])^{-1} so that
  I_k[f] = B @ f[nodes] interpolates f at the nodes.
- :func:`roq_weights` — reduced-order quadrature weights: for an inner
  product <d, h> = sum_x w_x conj(d_x) h_x, precompute omega so that
  <d, h> ~= sum_j omega_j h(node_j)  (the paper's GW inference application).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EIMResult(NamedTuple):
    nodes: jax.Array   # (k,) int32 interpolation rows ("empirical nodes")
    B: jax.Array       # (N, k) interpolant matrix: I[f] = B @ f[nodes]


def eim_nodes(Q: jax.Array) -> EIMResult:
    """Greedy EIM node selection for the basis columns of Q (N, k).

    Iteration i selects the row where the current basis vector is worst
    represented by interpolation on the existing nodes (classic DEIM).
    Implemented with ``lax.fori_loop`` and a growing (masked) node set so it
    jits with static shapes.
    """
    N, k = Q.shape

    def body(i, carry):
        nodes, = carry
        qi = Q[:, i]
        # Solve interpolation coefficients on existing nodes (first i rows):
        # A c = qi[nodes[:i]]  with A = Q[nodes[:i], :i].
        # Build a padded k x k system that is identity beyond i.
        sel = Q[nodes, :]                       # (k, k) rows at current nodes
        row_mask = jnp.arange(k) < i
        A = jnp.where(
            row_mask[:, None] & row_mask[None, :],
            sel,
            jnp.eye(k, dtype=Q.dtype),
        )
        rhs = jnp.where(row_mask, qi[nodes], jnp.zeros((k,), Q.dtype))
        c = jnp.linalg.solve(A, rhs)
        r = qi - Q @ jnp.where(row_mask, c, jnp.zeros_like(c))
        node_i = jnp.argmax(jnp.abs(r)).astype(jnp.int32)
        return (nodes.at[i].set(node_i),)

    nodes0 = jnp.zeros((k,), jnp.int32)
    nodes0 = nodes0.at[0].set(jnp.argmax(jnp.abs(Q[:, 0])).astype(jnp.int32))
    (nodes,) = jax.lax.fori_loop(1, k, body, (nodes0,))

    B = Q @ jnp.linalg.inv(Q[nodes, :])
    return EIMResult(nodes=nodes, B=B)


def empirical_interpolant(B: jax.Array, nodes: jax.Array, f: jax.Array):
    """Evaluate the empirical interpolant of f (vector or batch of columns)."""
    if f.ndim == 1:
        return B @ f[nodes]
    return B @ f[nodes, :]


def roq_weights(data: jax.Array, quad_w: jax.Array, B: jax.Array):
    """Reduced-order quadrature weights for <data, .> (GW likelihood use).

    <d, h> = sum_x w_x conj(d_x) h_x ~= sum_j omega_j h(node_j) with
    omega = B^T (w * conj(d)).
    """
    return B.T @ (quad_w.astype(B.dtype) * jnp.conj(data))
