"""Lockstep batched RB-greedy: B independent builds in one fused pass.

The offline stage of a real GW pipeline builds MANY bases — one per
parameter region for the serving router, one per frequency band
(FFT-then-reduce), one per tau in a tolerance sweep.  Each scalar build
spends its time in the Eq.-(6.3) pivot sweep, which is DRAM-roof-bound at
production shapes: B sequential builds read the snapshot matrix B times
per accepted basis vector.  This driver runs the B builds in LOCKSTEP —
one batched iteration advances every still-active build by one basis
vector — through the ``batched_*`` primitives of
:mod:`repro.core.backend`, in two snapshot layouts:

  stacked   ``S``: (B, N, M), one matrix per lane (banded / per-region
            workloads).  The vmapped sweep runs the same per-lane kernels
            XLA picks for the scalar driver, so every lane's pivots,
            errors, Q and R are BITWISE identical to
            :func:`repro.core.greedy.rb_greedy` on its slice (asserted in
            tests/test_batch_greedy.py).  The win is one jitted dispatch
            and one host sync per chunk for all B builds.
  shared    ``S``: (N, M), one matrix swept by B basis states (tau /
            hyperparameter sweeps).  All B query vectors (and their re/im
            planes) stack into ONE GEMM per lockstep round, reading S
            from DRAM once instead of B times — the fused-pass roofline
            win (the ``batched_vs_sequential`` rows of BENCH_greedy.json).
            GEMM float summation differs from the scalar GEMV's, so lanes
            match the scalar driver pivot-for-pivot, not bitwise (the
            same contract as the blocked drivers).

Per-lane semantics are the scalar driver's, exactly: independent pivots,
tau / rank-guard / refresh / floor-stop decisions per lane (host float64
comparisons included), a converged lane masks out of the sweep (its
basis state freezes; in the shared layout its query row is dead weight in
the fused GEMM, in the stacked layout its lane of the batched dot is
discarded), and every lane's refresh runs the SAME jitted
:func:`repro.core.greedy.greedy_refresh` on its slice.  The build ends
when every lane has stopped; per-lane results compact to their accepted
ranks via :meth:`BatchGreedyResult.lane`.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as _backend
from repro.core.greedy import (
    STOP_FLOOR,
    STOP_NONE,
    STOP_RANK,
    STOP_REFRESH,
    STOP_TAU,
    GreedyResult,
    GreedyState,
    floor_estimate,
    greedy_refresh,
    greedy_step,
)


class BatchGreedyState(NamedTuple):
    """B-lane greedy state: every :class:`~repro.core.greedy.GreedyState`
    leaf with a leading batch axis, plus a per-lane rank counter.  Lane b
    of every leaf is exactly the scalar state of build b."""

    Q: jax.Array         # (B, N, max_k) per-lane basis, zero-padded
    R: jax.Array         # (B, max_k, M)
    norms_sq: jax.Array  # (B, M) per-lane reference residual^2
    acc: jax.Array       # (B, M) per-lane sum_j |c_j|^2 since refresh
    pivots: jax.Array    # (B, max_k) int32
    errs: jax.Array      # (B, max_k) real
    n_passes: jax.Array  # (B, max_k) int32
    rnorms: jax.Array    # (B, max_k) real
    k: jax.Array         # (B,) int32 per-lane accepted rank


class BatchGreedyResult(NamedTuple):
    """Result of a lockstep batched build (all arrays zero-padded to
    max_k; per-lane valid ranks in ``k``, per-lane stop codes in
    ``stops``).  :meth:`lane` compacts one lane to the scalar result
    shape."""

    Q: jax.Array         # (B, N, max_k)
    R: jax.Array         # (B, max_k, M)
    pivots: jax.Array    # (B, max_k)
    errs: jax.Array      # (B, max_k)
    k: np.ndarray        # (B,) accepted ranks
    n_ortho_passes: jax.Array
    rnorms: jax.Array
    stops: np.ndarray    # (B,) STOP_* codes

    @property
    def batch(self) -> int:
        return int(self.Q.shape[0])

    def lane(self, b: int) -> GreedyResult:
        """Lane ``b`` as a scalar :class:`~repro.core.greedy.GreedyResult`
        (zero-padded arrays, like the scalar drivers return)."""
        return GreedyResult(
            Q=self.Q[b], R=self.R[b], pivots=self.pivots[b],
            errs=self.errs[b], k=jnp.asarray(int(self.k[b]), jnp.int32),
            n_ortho_passes=self.n_ortho_passes[b], rnorms=self.rnorms[b],
            stop=int(self.stops[b]),
        )


def batched_imgs_orthogonalize(
    v: jax.Array,
    Q: jax.Array,
    kappa: float = 2.0,
    max_passes: int = 3,
    backend: str | None = None,
):
    """B-lane Hoffmann iterated classical GS: lane b orthogonalizes
    ``v[b]`` against its own ``Q[b]``.

    The re-run loop applies Hoffmann's kappa test PER LANE: the
    while_loop runs while any lane still wants a pass, and lanes that
    converged keep their values through a per-lane select — exactly the
    batching rule ``jax.vmap`` applies to a while_loop, so each lane's
    floats match the scalar :func:`repro.core.greedy.imgs_orthogonalize`
    bitwise.  Returns ``(q, coeffs, rnorm, n_passes)`` with a leading B
    axis on each.
    """
    B = v.shape[0]
    norm0 = jax.vmap(jnp.linalg.norm)(v)

    # First pass is unconditional (as in the scalar form).
    v1, c1 = _backend.batched_project_pass(v, Q, backend=backend)

    def rerun(norm_prev, norm_cur, n):
        return (norm_cur < norm_prev / kappa) & (n < max_passes)

    def cond(state):
        _, _, norm_prev, norm_cur, n = state
        return jnp.any(rerun(norm_prev, norm_cur, n))

    def body(state):
        v_cur, coeffs, norm_prev, norm_cur, n = state
        go = rerun(norm_prev, norm_cur, n)
        v_next, c = _backend.batched_project_pass(v_cur, Q,
                                                  backend=backend)
        norm_next = jax.vmap(jnp.linalg.norm)(v_next)
        return (
            jnp.where(go[:, None], v_next, v_cur),
            jnp.where(go[:, None], coeffs + c, coeffs),
            jnp.where(go, norm_cur, norm_prev),
            jnp.where(go, norm_next, norm_cur),
            n + go.astype(n.dtype),
        )

    v_fin, coeffs, _, rnorm, n_passes = jax.lax.while_loop(
        cond, body,
        (v1, c1, norm0, jax.vmap(jnp.linalg.norm)(v1),
         jnp.ones((B,), jnp.int32)),
    )
    safe = jnp.maximum(rnorm, jnp.finfo(rnorm.dtype).tiny)
    q = v_fin / safe[:, None].astype(v_fin.dtype)
    return q, coeffs, rnorm, n_passes


@functools.partial(jax.jit, static_argnames=("max_k", "batch"))
def batch_greedy_init(S: jax.Array, max_k: int,
                      batch: int | None = None) -> BatchGreedyState:
    """Initial B-lane state.  ``S`` (B, N, M) stacked (``batch`` ignored)
    or (N, M) shared (``batch`` required).  Per-lane column norms are
    computed lane-by-lane on 2-D slices (stacked) or once and broadcast
    (shared), so each lane's values equal the scalar
    :func:`repro.core.greedy.greedy_init` bitwise."""
    rdtype = jnp.zeros((), S.dtype).real.dtype
    if S.ndim == 2:
        if batch is None:
            raise ValueError("shared-S batched init requires batch=")
        B = batch
        N, M = S.shape
        norms = jnp.sum(jnp.abs(S) ** 2, axis=0).astype(rdtype)
        norms_sq = jnp.broadcast_to(norms, (B, M))
    else:
        # Lane-by-lane on fenced 2-D slices: the barrier keeps the slice
        # from fusing into the reduction, so each lane's norms compile
        # exactly like the scalar greedy_init's (same op on a parameter).
        B, N, M = S.shape
        norms_sq = jnp.stack([
            jnp.sum(jnp.abs(jax.lax.optimization_barrier(S[b])) ** 2,
                    axis=0).astype(rdtype)
            for b in range(B)
        ])
    return BatchGreedyState(
        Q=jnp.zeros((B, N, max_k), S.dtype),
        R=jnp.zeros((B, max_k, M), S.dtype),
        norms_sq=norms_sq,
        acc=jnp.zeros((B, M), rdtype),
        pivots=jnp.zeros((B, max_k), jnp.int32),
        errs=jnp.zeros((B, max_k), rdtype),
        n_passes=jnp.zeros((B, max_k), jnp.int32),
        rnorms=jnp.zeros((B, max_k), rdtype),
        k=jnp.zeros((B,), jnp.int32),
    )


def _lane_fenced_step(
    S: jax.Array,
    state: BatchGreedyState,
    kappa: float,
    max_passes: int,
    backend: str | None,
) -> BatchGreedyState:
    """Stacked-complex lockstep round: the SCALAR
    :func:`repro.core.greedy.greedy_step` traced once per lane between
    optimization barriers.

    Complex lanes cannot go through ``jax.vmap``: XLA merges a scalar
    ``a @ b + c @ d`` (the plane-split recombinations — and the complex
    dot's own lowering) into one concatenated reduction but does not
    apply the same rewrite to the batched form, so vmapped lanes drift
    from the scalar driver by an ulp per iteration.  Fencing each lane's
    operands keeps XLA from merging dots across lanes or fusing the lane
    slice into the GEMV lowering; inside the fence the graph IS the
    scalar step's, so it compiles — and rounds — identically (asserted
    bitwise in tests/test_batch_greedy.py).  The dispatch amortization
    (one jit call, one host sync per chunk for all B builds) is
    unchanged; only the sweep arithmetic stays per-lane.
    """
    B = state.k.shape[0]
    outs = []
    for b in range(B):
        lane = GreedyState(
            Q=state.Q[b], R=state.R[b], norms_sq=state.norms_sq[b],
            acc=state.acc[b], pivots=state.pivots[b],
            errs=state.errs[b], n_passes=state.n_passes[b],
            rnorms=state.rnorms[b], k=state.k[b],
        )
        Sb, lane = jax.lax.optimization_barrier((S[b], lane))
        outs.append(greedy_step(Sb, lane, kappa, max_passes,
                                backend=backend))
    return BatchGreedyState(*(
        jnp.stack([getattr(o, f) for o in outs])
        for f in BatchGreedyState._fields
    ))


def batch_greedy_step(
    S: jax.Array,
    state: BatchGreedyState,
    kappa: float = 2.0,
    max_passes: int = 3,
    backend: str | None = None,
) -> BatchGreedyState:
    """One lockstep iteration: every lane picks ITS argmax pivot,
    orthogonalizes against ITS basis, and appends — the batched image of
    :func:`repro.core.greedy.greedy_step`.  Lane ranks may differ (lanes
    freeze and reactivate independently), so all slot writes are per-lane
    dynamic updates at ``k[b]``.

    Stacked complex snapshots on the non-Pallas backends take the fenced
    per-lane route (:func:`_lane_fenced_step`) — the only form whose
    floats match the scalar driver bitwise; everything else runs the
    vmapped/fused batched primitives."""
    if (S.ndim == 3 and jnp.iscomplexobj(S)
            and _backend.resolve_backend(backend) != "pallas"):
        return _lane_fenced_step(S, state, kappa, max_passes, backend)
    k = state.k
    res_sq = jnp.maximum(state.norms_sq - state.acc, 0.0)
    j = jax.vmap(jnp.argmax)(res_sq)
    err = jnp.sqrt(jax.vmap(lambda r, jj: r[jj])(res_sq, j))

    if S.ndim == 2:
        v = jax.vmap(
            lambda jj: jax.lax.dynamic_slice_in_dim(S, jj, 1, axis=1)[:, 0]
        )(j)
    else:
        v = jax.vmap(
            lambda Sb, jj:
            jax.lax.dynamic_slice_in_dim(Sb, jj, 1, axis=1)[:, 0]
        )(S, j)
    q, _, rnorm, n_pass = batched_imgs_orthogonalize(
        v, state.Q, kappa, max_passes, backend=backend
    )

    c, acc, _, _ = _backend.batched_pivot_update(
        q, S, state.acc, state.norms_sq, backend=backend
    )

    set_col = jax.vmap(lambda Qb, qb, kb: Qb.at[:, kb].set(qb))
    set_row = jax.vmap(lambda Rb, cb, kb: Rb.at[kb, :].set(cb))
    set_at = jax.vmap(lambda xb, val, kb: xb.at[kb].set(val))
    return BatchGreedyState(
        Q=set_col(state.Q, q, k),
        R=set_row(state.R, c, k),
        norms_sq=state.norms_sq,
        acc=acc,
        pivots=set_at(state.pivots, j.astype(jnp.int32), k),
        errs=set_at(state.errs, err, k),
        n_passes=set_at(state.n_passes, n_pass.astype(jnp.int32), k),
        rnorms=set_at(state.rnorms, rnorm.astype(state.rnorms.dtype), k),
        k=k + 1,
    )


def _lane_where(mask, new, old):
    """Per-lane select: broadcast a (B,) mask over each leaf's trailing
    axes (the rule vmap applies to while_loop carries)."""
    return jnp.where(mask.reshape(mask.shape + (1,) * (new.ndim - 1)),
                     new, old)


def _batch_chunk_impl(
    S,
    state,
    taus,
    scales,
    ref_sqs,
    refresh_safety,
    done,
    chunk: int,
    kappa: float = 2.0,
    max_passes: int = 3,
    backend: str | None = None,
    check_refresh: bool = True,
):
    """Run up to ``chunk`` lockstep rounds device-resident.

    Per-lane stop codes latch inside the loop: a lane whose newest basis
    trips the rank guard / tau / refresh trigger FREEZES (its state stops
    updating through the per-lane select; its sweep lane is dead weight
    until the host handles the latched code at the chunk boundary), while
    the other lanes keep stepping.  The loop exits when no lane is active
    or ``chunk`` rounds elapsed.  Returns ``(state, n_rounds, stops)``
    with ``stops`` (B,) int32 — the host syncs only those.
    """
    max_k = state.Q.shape[2]
    eps = jnp.finfo(state.norms_sq.dtype).eps

    def active_mask(st, stop):
        return (stop == STOP_NONE) & (~done) & (st.k < max_k)

    def cond(carry):
        st, n, stop = carry
        return jnp.any(active_mask(st, stop)) & (n < chunk)

    def body(carry):
        st, n, stop = carry
        active = active_mask(st, stop)
        st_new = batch_greedy_step(S, st, kappa, max_passes,
                                   backend=backend)
        st = BatchGreedyState(*(
            _lane_where(active, new, old)
            for new, old in zip(st_new, st)
        ))
        idx = jnp.maximum(st.k - 1, 0)
        err = jnp.take_along_axis(st.errs, idx[:, None], axis=1)[:, 0]
        rnorm = jnp.take_along_axis(st.rnorms, idx[:, None], axis=1)[:, 0]
        refresh_hit = check_refresh & (err * err < refresh_safety * eps
                                       * ref_sqs)
        new_stop = jnp.where(
            rnorm < 50.0 * eps * scales,
            STOP_RANK,
            jnp.where(err < taus, STOP_TAU,
                      jnp.where(refresh_hit, STOP_REFRESH, STOP_NONE)),
        ).astype(jnp.int32)
        stop = jnp.where(active, new_stop, stop)
        return (st, n + 1, stop)

    B = state.k.shape[0]
    state, n_done, stops = jax.lax.while_loop(
        cond, body,
        (state, jnp.asarray(0, jnp.int32),
         jnp.full((B,), STOP_NONE, jnp.int32)),
    )
    return state, n_done, stops


_CHUNK_STATICS = ("chunk", "kappa", "max_passes", "backend", "check_refresh")

_batch_chunk = jax.jit(_batch_chunk_impl, static_argnames=_CHUNK_STATICS)

# Donating variant (see repro.core.greedy: the driver never touches the
# previous state again, so Q/R/acc buffers are reused across chunks).
_batch_chunk_donated = jax.jit(
    _batch_chunk_impl, static_argnames=_CHUNK_STATICS, donate_argnums=(1,)
)


def _drop_last_lane(state: BatchGreedyState, b: int,
                    k: int) -> BatchGreedyState:
    """Remove lane ``b``'s most recent basis (tau-stop / rank-guard)."""
    return state._replace(
        k=state.k.at[b].set(k),
        Q=state.Q.at[b, :, k].set(0),
        R=state.R.at[b, k, :].set(0),
        pivots=state.pivots.at[b, k].set(-1),
    )


def _refresh_lane(S, state: BatchGreedyState, b: int) -> BatchGreedyState:
    """Exact residual refresh of ONE lane, through the same jitted
    :func:`repro.core.greedy.greedy_refresh` the scalar driver uses on
    lane-shaped views — per-lane bitwise identity is by construction."""
    Sb = S if S.ndim == 2 else S[b]
    lane = GreedyState(
        Q=state.Q[b], R=state.R[b], norms_sq=state.norms_sq[b],
        acc=state.acc[b], pivots=state.pivots[b], errs=state.errs[b],
        n_passes=state.n_passes[b], rnorms=state.rnorms[b], k=state.k[b],
    )
    ref = greedy_refresh(Sb, lane)
    return state._replace(
        norms_sq=state.norms_sq.at[b].set(ref.norms_sq),
        acc=state.acc.at[b].set(ref.acc),
    )


def batch_rb_greedy(
    S,
    tau,
    max_k: int | None = None,
    batch: int | None = None,
    kappa: float = 2.0,
    max_passes: int = 3,
    refresh: str = "auto",
    refresh_safety: float = 100.0,
    chunk: int = 16,
    backend: str | None = None,
    callback=None,
) -> BatchGreedyResult:
    """Run B greedy builds in lockstep; every lane stops on its own terms.

    Args:
      S: the snapshot workload —
         * (B, N, M) array (or a list/tuple of equal-shape 2-D sources,
           each anything :func:`repro.data.providers.as_provider`
           accepts): STACKED layout, per-lane bitwise parity with
           :func:`repro.core.greedy.rb_greedy`;
         * (N, M) array with ``batch=B`` (or ``tau`` a length-B
           sequence): SHARED layout, one fused GEMM sweep per lockstep
           round (pivot-for-pivot parity).
      tau: scalar (every lane) or length-B sequence (per-lane
        tolerances — the tau-sweep workload).
      max_k / kappa / max_passes / refresh / refresh_safety / chunk /
        backend: exactly as on :func:`repro.core.greedy.rb_greedy`,
        applied PER LANE (one shared chunk cadence; stop decisions,
        refreshes and the floor gate are per-lane, with the same host
        float64 comparisons).
      callback: fires once per chunk with the :class:`BatchGreedyState`.

    Returns a :class:`BatchGreedyResult`; ``result.lane(b)`` is the
    scalar-shaped view of build b.
    """
    from repro.data.providers import materialize_source

    if isinstance(S, (list, tuple)):
        mats = [materialize_source(s) for s in S]
        shapes = {tuple(m.shape) for m in mats}
        if len(shapes) != 1:
            raise ValueError(
                f"batched sources must share one (N, M) shape, got "
                f"{sorted(shapes)}")
        S = jnp.stack(mats)
    else:
        S = jnp.asarray(S)
    if S.ndim not in (2, 3):
        raise ValueError(
            f"batched snapshots must be (B, N, M) stacked or (N, M) "
            f"shared, got shape {S.shape}")

    taus_in = np.atleast_1d(np.asarray(tau, np.float64))
    if S.ndim == 3:
        B = int(S.shape[0])
        if batch is not None and batch != B:
            raise ValueError(f"batch={batch} != stacked batch {B}")
    else:
        B = batch if batch is not None else int(taus_in.shape[0])
        if B < 1:
            raise ValueError(f"batch must be >= 1, got {B}")
    if taus_in.shape[0] == 1:
        taus_in = np.full((B,), float(taus_in[0]))
    if taus_in.shape[0] != B:
        raise ValueError(
            f"tau must be scalar or length-{B}, got {taus_in.shape[0]}")
    taus_host = [float(t) for t in taus_in]

    N, M = (int(S.shape[-2]), int(S.shape[-1]))
    if max_k is None:
        max_k = min(N, M)
    max_k = min(max_k, min(N, M))
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    backend = _backend.resolve_backend(backend)  # see rb_greedy

    state = batch_greedy_init(S, max_k, batch=B if S.ndim == 2 else None)
    rdt = state.norms_sq.dtype
    eps = float(jnp.finfo(rdt).eps)
    # Per-lane host loop variables, exactly the scalar driver's floats.
    ref_sqs = [float(jnp.max(state.norms_sq[b])) for b in range(B)]
    scales = [r ** 0.5 for r in ref_sqs]
    done = np.zeros((B,), bool)
    final = np.full((B,), STOP_NONE, np.int64)

    chunk_fn = _batch_chunk if callback is not None else \
        _batch_chunk_donated
    taus_d = jnp.asarray(taus_host, rdt)
    scales_d = jnp.asarray(scales, rdt)
    safety_d = jnp.asarray(refresh_safety, rdt)
    ref_sqs_d = jnp.asarray(ref_sqs, rdt)
    done_d = jnp.asarray(done)

    while not done.all():
        state, _, stops = chunk_fn(
            S, state, taus_d, scales_d, ref_sqs_d, safety_d, done_d,
            chunk=chunk, kappa=kappa, max_passes=max_passes,
            backend=backend, check_refresh=(refresh == "auto"),
        )
        if callback is not None:
            callback(state)
        ks = np.asarray(state.k)
        stops_h = np.asarray(stops)
        ref_changed = False
        for b in range(B):
            if done[b]:
                continue
            stop = int(stops_h[b])
            k = int(ks[b])
            if stop in (STOP_RANK, STOP_TAU):
                # Same drop semantics as the scalar driver: the newest
                # basis was rank-guard junk / selected below tau.
                state = _drop_last_lane(state, b, k - 1)
                done[b], final[b] = True, stop
            elif stop == STOP_REFRESH:
                state = _refresh_lane(S, state, b)
                ref_sqs[b] = max(float(jnp.max(state.norms_sq[b])),
                                 1e-300)
                ref_changed = True
                if ref_sqs[b] ** 0.5 < taus_host[b]:
                    done[b], final[b] = True, STOP_TAU
                elif ref_sqs[b] ** 0.5 <= floor_estimate(eps, scales[b],
                                                         k):
                    done[b], final[b] = True, STOP_FLOOR
            if not done[b] and int(ks[b]) >= max_k:
                done[b] = True  # lane ran to capacity; stays STOP_NONE
        done_d = jnp.asarray(done)
        if ref_changed:
            ref_sqs_d = jnp.asarray(ref_sqs, rdt)

    return BatchGreedyResult(
        Q=state.Q, R=state.R, pivots=state.pivots, errs=state.errs,
        k=np.asarray(state.k), n_ortho_passes=state.n_passes,
        rnorms=state.rnorms, stops=np.asarray(final),
    )
