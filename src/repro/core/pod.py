"""Algorithm 1 (POD) and the POD error identities of Theorem 3.2.

POD computes the optimal rank-k *-norm approximation of the snapshot matrix
``S`` (* = 2 or F).  ``pod`` follows Algorithm 1 of the paper: compute the
SVD, pick the smallest k with ``sigma_{k+1} < tau``, return the first k left
singular vectors.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class PODResult(NamedTuple):
    """Result of Algorithm 1.

    Attributes:
      basis:  (N, k_max) left singular vectors; columns beyond ``k`` are
              still valid singular vectors (full economy SVD) — use
              ``basis[:, :k]`` for the tolerance-selected POD basis.
      sigmas: (min(N,M),) singular values, non-increasing.
      k:      smallest k such that sigma_{k+1} < tau  (Algorithm 1, step 4).
    """

    basis: jax.Array
    sigmas: jax.Array
    k: jax.Array


def pod_basis(S, k: int) -> jax.Array:
    """First k left singular vectors of S (the rank-k POD basis)."""
    from repro.data.providers import materialize_source

    V, _, _ = jnp.linalg.svd(materialize_source(S), full_matrices=False)
    return V[:, :k]


def pod(S, tau: float) -> PODResult:
    """Algorithm 1: POD with error tolerance ``tau`` (2-norm criterion).

    By Theorem 3.2(ii), ``|S - V_k V_k^H S|_2 = sigma_{k+1}``, so choosing the
    smallest k with ``sigma_{k+1} < tau`` guarantees a 2-norm projection error
    below ``tau``.

    ``S`` may be anything :func:`repro.data.providers.as_provider` accepts
    (arrays pass through; paths/providers are materialized).
    """
    from repro.data.providers import materialize_source

    V, sig, _ = jnp.linalg.svd(materialize_source(S), full_matrices=False)
    # smallest k with sigma_{k+1} < tau;  sigma indices are 0-based here:
    # sigma_{k+1} in the paper == sig[k].
    below = sig < tau
    k = jnp.argmax(below)  # first index where sig[k] < tau
    k = jnp.where(jnp.any(below), k, sig.shape[0])
    return PODResult(basis=V, sigmas=sig, k=k)


def pod_error_2norm(S: jax.Array, k: int) -> jax.Array:
    """|S - V_k V_k^H S|_2 — equals sigma_{k+1} by Theorem 3.2(ii)."""
    Vk = pod_basis(S, k)
    E = S - Vk @ (Vk.conj().T @ S)
    return jnp.linalg.norm(E, ord=2)


def pod_error_fro(S: jax.Array, k: int) -> jax.Array:
    """|S - V_k V_k^H S|_F — equals sqrt(sum_{j>k} sigma_j^2) (Thm 3.2(i))."""
    Vk = pod_basis(S, k)
    E = S - Vk @ (Vk.conj().T @ S)
    return jnp.linalg.norm(E)
