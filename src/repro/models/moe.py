"""Mixture-of-Experts block (GShard-style grouped capacity dispatch).

Top-k routing with per-group expert capacity: tokens are processed in groups
of ``cfg.moe_group_size``; within a group each expert accepts at most
``C = ceil(group * k * capacity_factor / E)`` tokens (overflow tokens fall
through on the residual path — standard "dropped" MoE semantics).  Dispatch
and combine are one-hot einsums, which map onto the MXU and shard cleanly:
experts' hidden dim is tensor-parallel ("tp"), so any expert count (8 or
128) divides evenly over the mesh without expert-count constraints.

This matches the dominant TPU MoE recipe (GShard / Switch / MaxText
"dropped") and gives the dry-run the *active*-FLOP profile of the paper
configs (top-1 / top-2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dtype_of, trunc_normal
from repro.sharding import constrain


def init_moe(key, cfg):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": trunc_normal(ks[0], (d, E), 1.0, jnp.float32),
        "w_gate": trunc_normal(ks[1], (E, d, f), 1.0, dt),
        "w_up": trunc_normal(ks[2], (E, d, f), 1.0, dt),
        "w_down": trunc_normal(ks[3], (E, f, d), 1.0, dt),
    }


def moe_specs(cfg):
    if cfg.moe_ep:
        # expert parallelism: experts sharded over the model axis, token
        # buffers all-to-all'd to their experts (GSPMD inserts the a2a at
        # the dispatch-einsum resharding); d_model dim ZeRO-sharded.
        return {
            "router": (None, None),
            "w_gate": ("tp", "fsdp", None),
            "w_up": ("tp", "fsdp", None),
            "w_down": ("tp", None, "fsdp"),
        }
    return {
        "router": (None, None),
        "w_gate": (None, "fsdp", "tp"),
        "w_up": (None, "fsdp", "tp"),
        "w_down": (None, "tp", "fsdp"),
    }


def moe_block(p, x: jax.Array, cfg) -> jax.Array:
    """x: (B, S, d) -> (B, S, d).  Top-k dropped dispatch."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, d)

    group = min(cfg.moe_group_size, T)
    n_groups = -(-T // group)
    pad = n_groups * group - T
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    xg = xt.reshape(n_groups, group, d)
    cap = max(1, int(group * k * cfg.capacity_factor / E))

    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), p["router"]
    )
    gate_all = jax.nn.softmax(logits, axis=-1)          # (g, t, E)
    top_g, top_e = jax.lax.top_k(gate_all, k)           # (g, t, k)
    top_g = top_g / jnp.maximum(
        jnp.sum(top_g, axis=-1, keepdims=True), 1e-9
    )  # renormalize over selected experts (Mixtral convention)

    # one-hot expert assignment per choice: (g, t, k, E)
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)
    # position within each expert's buffer (cumulative over (t, k)):
    flat = onehot.reshape(n_groups, group * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat               # rank within expert
    pos = pos.reshape(n_groups, group, k, E)
    in_cap = pos < cap
    keep = onehot * in_cap
    pos_oh = jax.nn.one_hot(jnp.sum(pos * onehot, -1).astype(jnp.int32),
                            cap, dtype=jnp.float32)      # (g, t, k, C)
    # dispatch tensor (g, t, E, C)
    disp = jnp.einsum("gtke,gtkc->gtec", keep, pos_oh)
    comb = jnp.einsum(
        "gtke,gtkc,gtk->gtec", keep, pos_oh, top_g.astype(jnp.float32)
    )

    if cfg.moe_bf16_dispatch:
        disp = disp.astype(xg.dtype)
        comb = comb.astype(xg.dtype)
    xe = jnp.einsum("gtec,gtd->gecd", disp.astype(xg.dtype), xg)
    if cfg.moe_ep:
        # route token buffers to expert shards (a2a), compute locally
        xe = constrain(xe, "dp", "tp", None, None)
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"]))
        h = h * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
        h = constrain(h, "dp", "tp", None, None)
        ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
        ye = constrain(ye, "dp", "tp", None, None)
    else:
        xe = constrain(xe, "dp", None, None, None)
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"]))
        h = h * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
        h = constrain(h, "dp", None, None, "tp")
        ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = jnp.einsum("gtec,gecd->gtd", comb.astype(ye.dtype), ye)

    y = y.reshape(n_groups * group, d)[:T]
    return y.reshape(B, S, d)


def moe_decode(p, x: jax.Array, cfg) -> jax.Array:
    """Decode-path MoE: tiny token counts -> gather experts directly.

    x: (B, 1, d).  For B tokens we compute each selected expert via gathered
    weights (k gathers of (d, f) per token) — no capacity machinery.
    """
    B, S, d = x.shape
    k = cfg.experts_per_token
    xt = x.reshape(B * S, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    gate_all = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gate_all, k)
    top_g = top_g / jnp.maximum(jnp.sum(top_g, -1, keepdims=True), 1e-9)

    wg = p["w_gate"][top_e]   # (T, k, d, f)
    wu = p["w_up"][top_e]
    wd = p["w_down"][top_e]   # (T, k, f, d)
    h = jax.nn.silu(jnp.einsum("td,tkdf->tkf", xt, wg))
    h = h * jnp.einsum("td,tkdf->tkf", xt, wu)
    y = jnp.einsum("tkf,tkfd->tkd", h, wd)
    y = jnp.einsum("tkd,tk->td", y, top_g.astype(y.dtype))
    return y.reshape(B, S, d)
