"""Family-dispatched public model API: init / loss / prefill / decode.

Everything downstream (trainer, serving engine, dry-run) goes through these
five functions, so adding an architecture family means extending exactly
this registry.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.sharding import constrain


def init_params(cfg: ModelConfig, key: jax.Array):
    if cfg.family == "encdec":
        return tfm.init_encdec(key, cfg)
    return tfm.init_decoder(key, cfg)


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.key(0)
    )


def param_specs(cfg: ModelConfig):
    if cfg.family == "encdec":
        return tfm.encdec_specs(cfg)
    return tfm.decoder_specs(cfg)


def forward_logits(cfg: ModelConfig, params, batch: dict) -> jax.Array:
    """Teacher-forced logits (B, S, V) for any family."""
    if cfg.family == "encdec":
        return tfm.encdec_forward(
            params, cfg, batch["frames"], batch["tokens"]
        )
    return tfm.decoder_forward(
        params, cfg, batch["tokens"],
        vision_embeds=batch.get("vision"),
    )


def loss_fn(cfg: ModelConfig, params, batch: dict) -> jax.Array:
    """Next-token cross entropy in f32 (with standard 1e-4 z-loss)."""
    logits = forward_logits(cfg, params, batch)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None], axis=-1
    )[..., 0]
    nll = logz - gold
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    nll = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    zloss = jnp.sum((logz * mask) ** 2) / jnp.maximum(jnp.sum(mask), 1.0)
    return nll + 1e-4 * zloss


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family == "encdec":
        return tfm.init_encdec_cache(cfg, batch, max_len, cfg.audio_frames)
    return tfm.init_decode_cache(cfg, batch, max_len)


def prefill(cfg: ModelConfig, params, batch: dict,
            max_len: Optional[int] = None):
    """Prompt prefill -> (last-token logits (B, V), cache)."""
    if cfg.family == "encdec":
        return tfm.encdec_prefill(
            params, cfg, batch["frames"], batch["tokens"], max_len=max_len
        )
    return tfm.decoder_prefill(
        params, cfg, batch["tokens"],
        vision_embeds=batch.get("vision"), max_len=max_len,
    )


def decode_step(cfg: ModelConfig, params, token: jax.Array, cache):
    """One-token decode -> (logits (B, V), cache')."""
    if cfg.family == "encdec":
        return tfm.encdec_decode_step(params, cfg, token, cache)
    return tfm.decoder_decode_step(params, cfg, token, cache)


def make_batch(cfg: ModelConfig, key, batch: int, seq: int,
               dtype=jnp.float32) -> dict:
    """Random smoke-test batch with every family extra included."""
    ks = jax.random.split(key, 3)
    out = {
        "tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab_size),
    }
    from repro.models.layers import dtype_of
    dt = dtype_of(cfg.dtype)
    if cfg.family == "vlm":
        out["vision"] = jax.random.normal(
            ks[2], (batch, cfg.vision_tokens, cfg.vision_dim), dt
        )
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            ks[2], (batch, cfg.audio_frames, cfg.audio_dim), dt
        )
    return out
