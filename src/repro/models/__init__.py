"""Model zoo: the 10 assigned architectures as composable JAX modules."""

from repro.models.config import ModelConfig, ShapeConfig, SHAPES

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES"]
