"""Architecture assembly for all assigned families.

Families and their layer layouts (all layer stacks are ``lax.scan`` over
stacked parameter pytrees so the HLO stays compact at 32–80 layers, with
per-block ``jax.checkpoint`` when cfg.remat):

  dense / moe : scan over L identical decoder blocks (MoE replaces the MLP).
  vlm         : scan over (L / cross_every) super-groups = [cross_every self
                blocks (inner scan)] + 1 gated cross-attn block.
  hybrid      : scan over (L // attn_every) super-groups = [(attn_every - 1)
                RG-LRU blocks + 1 local-attention block]; leftover recurrent
                blocks unrolled at the tail.
  ssm         : scan over L Mamba-2 (SSD) blocks.
  encdec      : encoder scan (bidirectional self) + decoder scan (causal
                self + cross over encoder memory).

Decode caches mirror the scan layout: leading dims match the stacked params
so one ``lax.scan`` threads (params_layer, cache_layer) pairs per step.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as att
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssd as ssd_mod
from repro.models.layers import (
    dtype_of,
    init_mlp,
    mlp,
    mlp_specs,
    rms_norm,
    trunc_normal,
)
from repro.sharding import constrain



# ---------------------------------------------------------------- layer scan
# Layer stacks normally lower as lax.scan (compact HLO).  XLA's HLO cost
# analysis counts a while-loop body ONCE regardless of trip count, so the
# roofline methodology (launch/roofline.py) re-lowers models under
# ``unroll_layers()`` where every layer scan becomes a Python loop over
# sliced stacked params — exact per-op accounting at small n_layers, then a
# linear fit in L extrapolates to the full depth.
import contextlib
import threading

_UNROLL_STATE = threading.local()


@contextlib.contextmanager
def unroll_layers():
    prev = getattr(_UNROLL_STATE, "on", False)
    _UNROLL_STATE.on = True
    try:
        yield
    finally:
        _UNROLL_STATE.on = prev


def layer_scan(body, carry, xs, length=None):
    """lax.scan over stacked layer params, or unrolled under unroll_layers."""
    if not getattr(_UNROLL_STATE, "on", False):
        return jax.lax.scan(body, carry, xs, length=length)
    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    stacked = jax.tree.map(lambda *z: jnp.stack(z), *ys)
    return carry, stacked


# =============================================================== init helpers
def _stack_init(fn, key, n):
    """vmap an init function over n layer keys -> stacked params."""
    return jax.vmap(fn)(jax.random.split(key, n))


def _zeros_like_spec(spec_tree):
    return spec_tree


# ============================================================= decoder blocks
def init_decoder_block(key, cfg):
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": jnp.zeros((cfg.d_model,), dtype_of(cfg.dtype)),
        "attn": att.init_attn(ks[0], cfg),
        "mlp_norm": jnp.zeros((cfg.d_model,), dtype_of(cfg.dtype)),
    }
    if cfg.n_experts:
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[2], cfg)
    return p


def decoder_block_specs(cfg):
    p = {
        "attn_norm": (None,),
        "attn": att.attn_specs(cfg),
        "mlp_norm": (None,),
    }
    if cfg.n_experts:
        p["moe"] = moe_mod.moe_specs(cfg)
    else:
        p["mlp"] = mlp_specs(cfg)
    return p


def decoder_block(bp, x, cfg, positions, window=None):
    """One pre-norm decoder block (full-sequence path).

    With ``cfg.opt_collectives`` the sub-block outputs are constrained to
    the sequence-sharded layout BEFORE the residual add, turning the TP
    partial-sum all-reduce (full activation, f32 on the convert-hoisted
    path) into a reduce-scatter whose per-device result is 1/tp of the
    bytes; the post-norm activation is constrained in bf16 so the sequence
    all-gather moves 2-byte words (see EXPERIMENTS.md §Perf).
    """
    ulysses = cfg.tp_mode in ("ulysses", "megatron_rs")
    h = rms_norm(x, bp["attn_norm"], cfg.norm_eps)
    if ulysses:
        h = constrain(h, "dp", "sp", None)      # stay sequence-sharded
    elif cfg.opt_collectives:
        h = constrain(h, "dp", None, None)      # bf16 AG boundary
    h = att.multihead_attention(
        bp["attn"], h, cfg, positions=positions, window=window
    )
    if ulysses or cfg.opt_collectives:
        h = constrain(h, "dp", "sp", None)      # RS boundary (1/tp bytes)
    x = x + h
    x = constrain(x, "dp", "sp", None)
    h = rms_norm(x, bp["mlp_norm"], cfg.norm_eps)
    if ulysses:
        h = constrain(h, "dp", "sp", None)
    elif cfg.opt_collectives:
        h = constrain(h, "dp", None, None)
    if cfg.n_experts:
        h = moe_mod.moe_block(bp["moe"], h, cfg)
    else:
        h = mlp(bp["mlp"], h, cfg)
    if ulysses or cfg.opt_collectives:
        h = constrain(h, "dp", "sp", None)
    x = x + h
    return constrain(x, "dp", "sp", None)


def decoder_block_decode(bp, x_t, cache, cfg, window=None):
    h = rms_norm(x_t, bp["attn_norm"], cfg.norm_eps)
    h, cache = att.decode_attention(bp["attn"], h, cache, cfg, window=window)
    x_t = x_t + h
    h = rms_norm(x_t, bp["mlp_norm"], cfg.norm_eps)
    if cfg.n_experts:
        h = moe_mod.moe_decode(bp["moe"], h, cfg)
    else:
        h = mlp(bp["mlp"], h, cfg)
    return x_t + h, cache


# ------------------------------------------------------------- cross blocks
def init_cross_block(key, cfg):
    return {
        "norm": jnp.zeros((cfg.d_model,), dtype_of(cfg.dtype)),
        "attn": att.init_attn(key, cfg, cross=True),
        "gate": jnp.zeros((), jnp.float32),
    }


def cross_block_specs(cfg):
    return {
        "norm": (None,),
        "attn": att.attn_specs(cfg, cross=True),
        "gate": (),
    }


def cross_block(bp, x, memory, cfg):
    h = rms_norm(x, bp["norm"], cfg.norm_eps)
    h = att.multihead_attention(
        bp["attn"], h, cfg, kv_x=memory, causal=False, use_rope=False,
        impl="einsum",
    )
    return x + jnp.tanh(bp["gate"]).astype(x.dtype) * h


def cross_block_cached(bp, x_t, mem_kv, cfg):
    """Decode-path cross attention over precomputed memory K/V."""
    mk, mv = mem_kv
    B = x_t.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x_t, bp["norm"], cfg.norm_eps)
    q = (h @ bp["attn"]["wq"]).reshape(B, 1, K, H // K, hd)
    logits = jnp.einsum(
        "bqkgd,bskd->bkgqs", q.astype(jnp.float32) * (hd ** -0.5),
        mk.astype(jnp.float32),
    )
    pa = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pa, mv.astype(jnp.float32))
    o = o.reshape(B, 1, H * hd).astype(x_t.dtype) @ bp["attn"]["wo"]
    return x_t + jnp.tanh(bp["gate"]).astype(x_t.dtype) * o


def cross_memory_kv(bp, memory, cfg):
    B, S = memory.shape[:2]
    K, hd = cfg.n_kv_heads, cfg.hd
    mk = (memory @ bp["attn"]["wk"]).reshape(B, S, K, hd)
    mv = (memory @ bp["attn"]["wv"]).reshape(B, S, K, hd)
    return mk, mv


# ------------------------------------------------------------ hybrid blocks
def init_rec_block(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "rec_norm": jnp.zeros((cfg.d_model,), dtype_of(cfg.dtype)),
        "rec": rglru_mod.init_rglru_block(ks[0], cfg),
        "mlp_norm": jnp.zeros((cfg.d_model,), dtype_of(cfg.dtype)),
        "mlp": init_mlp(ks[1], cfg),
    }


def rec_block_specs(cfg):
    return {
        "rec_norm": (None,),
        "rec": rglru_mod.rglru_specs(cfg),
        "mlp_norm": (None,),
        "mlp": mlp_specs(cfg),
    }


def rec_block(bp, x, cfg, cache=None):
    h = rms_norm(x, bp["rec_norm"], cfg.norm_eps)
    h, cache = rglru_mod.rglru_block(bp["rec"], h, cfg, cache)
    x = x + h
    h = rms_norm(x, bp["mlp_norm"], cfg.norm_eps)
    x = x + mlp(bp["mlp"], h, cfg)
    return constrain(x, "dp", "sp", None), cache


# ---------------------------------------------------------------- ssm blocks
def init_ssm_block(key, cfg):
    return {
        "norm": jnp.zeros((cfg.d_model,), dtype_of(cfg.dtype)),
        "ssd": ssd_mod.init_ssd(key, cfg),
    }


def ssm_block_specs(cfg):
    return {"norm": (None,), "ssd": ssd_mod.ssd_specs(cfg)}


def ssm_block(bp, x, cfg, cache=None):
    h = rms_norm(x, bp["norm"], cfg.norm_eps)
    h, cache = ssd_mod.ssd_layer(bp["ssd"], h, cfg, cache)
    return constrain(x + h, "dp", "sp", None), cache


# ================================================================== assembly
class Decoder(NamedTuple):
    """Decoder-only model parameters (dense / moe / vlm / hybrid / ssm)."""

    embed: jax.Array
    blocks: Any
    cross: Any          # vlm only (stacked cross blocks) else None
    vision_proj: Any    # vlm only
    tail: Any           # hybrid leftover blocks else None
    final_norm: jax.Array
    lm_head: Any        # None if tied


def init_decoder(key, cfg) -> Decoder:
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 6)
    embed = trunc_normal(ks[0], (cfg.vocab_size, cfg.d_model), 1.0, dt)
    cross = None
    vision_proj = None
    tail = None

    if cfg.family == "ssm":
        blocks = _stack_init(
            lambda k: init_ssm_block(k, cfg), ks[1], cfg.n_layers
        )
    elif cfg.family == "hybrid":
        per = cfg.attn_every
        n_super = cfg.n_layers // per
        n_tail = cfg.n_layers - n_super * per

        def init_super(k):
            k1, k2 = jax.random.split(k)
            return {
                "recs": _stack_init(
                    lambda kk: init_rec_block(kk, cfg), k1, per - 1
                ),
                "attn": init_decoder_block(k2, cfg),
            }

        blocks = _stack_init(init_super, ks[1], n_super)
        tail = _stack_init(
            lambda k: init_rec_block(k, cfg), ks[2], max(n_tail, 1)
        )
        if n_tail == 0:
            tail = None
    elif cfg.family == "vlm":
        per = cfg.cross_every
        n_groups = cfg.n_layers // per

        def init_group(k):
            return _stack_init(lambda kk: init_decoder_block(kk, cfg), k, per)

        blocks = _stack_init(init_group, ks[1], n_groups)
        cross = _stack_init(
            lambda k: init_cross_block(k, cfg), ks[2], n_groups
        )
        vision_proj = trunc_normal(
            ks[3], (cfg.vision_dim, cfg.d_model), 1.0, dt
        )
    else:  # dense / moe
        blocks = _stack_init(
            lambda k: init_decoder_block(k, cfg), ks[1], cfg.n_layers
        )

    final_norm = jnp.zeros((cfg.d_model,), dt)
    lm_head = (
        None
        if cfg.tie_embeddings
        else trunc_normal(ks[4], (cfg.d_model, cfg.vocab_size), 1.0, dt)
    )
    return Decoder(embed, blocks, cross, vision_proj, tail, final_norm, lm_head)


def decoder_specs(cfg) -> Decoder:
    """Logical-axis spec tree matching init_decoder (stacked dims get None)."""

    def stack(spec_tree):
        return jax.tree.map(
            lambda s: (None,) + s,
            spec_tree,
            is_leaf=lambda s: isinstance(s, tuple)
            and all(x is None or isinstance(x, str) for x in s),
        )

    cross = None
    vision_proj = None
    tail = None
    if cfg.family == "ssm":
        blocks = stack(ssm_block_specs(cfg))
    elif cfg.family == "hybrid":
        blocks = stack(
            {"recs": stack(rec_block_specs(cfg)),
             "attn": decoder_block_specs(cfg)}
        )
        n_tail = cfg.n_layers - (cfg.n_layers // cfg.attn_every) * cfg.attn_every
        tail = stack(rec_block_specs(cfg)) if n_tail else None
    elif cfg.family == "vlm":
        blocks = stack(stack(decoder_block_specs(cfg)))
        cross = stack(cross_block_specs(cfg))
        vision_proj = ("fsdp", "tp")
    else:
        blocks = stack(decoder_block_specs(cfg))
    return Decoder(
        embed=("tp", "fsdp"),
        blocks=blocks,
        cross=cross,
        vision_proj=vision_proj,
        tail=tail,
        final_norm=(None,),
        lm_head=None if cfg.tie_embeddings else ("fsdp", "tp"),
    )


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def _lm_logits(params: Decoder, x, cfg):
    x = rms_norm(x, params.final_norm, cfg.norm_eps)
    head = params.lm_head if params.lm_head is not None else params.embed.T
    logits = x @ head
    return constrain(logits, "dp", None, "tp")


def decoder_forward(
    params: Decoder,
    cfg,
    tokens: jax.Array,
    vision_embeds: Optional[jax.Array] = None,
) -> jax.Array:
    """Full-sequence forward -> logits (B, S, V)."""
    B, S = tokens.shape
    x = params.embed[tokens]
    x = constrain(x, "dp", "sp", None)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    window = cfg.sliding_window

    if cfg.family == "ssm":
        def body(x, bp):
            fn = _maybe_remat(
                lambda bp_, x_: ssm_block(bp_, x_, cfg)[0], cfg
            )
            return fn(bp, x), None

        x, _ = layer_scan(body, x, params.blocks)

    elif cfg.family == "hybrid":
        def body(x, bp):
            def inner(bp_, x_):
                def rec_body(xx, rp):
                    y, _ = rec_block(rp, xx, cfg)
                    return y, None

                x_, _ = layer_scan(rec_body, x_, bp_["recs"])
                return decoder_block(
                    bp_["attn"], x_, cfg, positions, window=cfg.local_window
                )

            return _maybe_remat(inner, cfg)(bp, x), None

        x, _ = layer_scan(body, x, params.blocks)
        if params.tail is not None:
            def tail_body(xx, rp):
                fn = _maybe_remat(lambda rp_, x_: rec_block(rp_, x_, cfg)[0], cfg)
                return fn(rp, xx), None

            x, _ = layer_scan(tail_body, x, params.tail)

    elif cfg.family == "vlm":
        memory = vision_embeds @ params.vision_proj
        memory = constrain(memory, "dp", None, None)

        def body(x, bps):
            bp, cp = bps

            def inner(bp_, cp_, x_):
                def self_body(xx, sp):
                    return decoder_block(sp, xx, cfg, positions, window), None

                x_, _ = layer_scan(self_body, x_, bp_)
                return cross_block(cp_, x_, memory, cfg)

            return _maybe_remat(inner, cfg)(bp, cp, x), None

        x, _ = layer_scan(body, x, (params.blocks, params.cross))

    else:  # dense / moe
        def body(x, bp):
            fn = _maybe_remat(
                lambda bp_, x_: decoder_block(bp_, x_, cfg, positions, window),
                cfg,
            )
            return fn(bp, x), None

        x, _ = layer_scan(body, x, params.blocks)

    return _lm_logits(params, x, cfg)


# =========================================================== caches & decode
class DecodeCache(NamedTuple):
    self_kv: Any     # family-dependent stacked cache
    cross_kv: Any    # vlm: (n_groups, B, vis, K, hd) pair; encdec similar
    pos: jax.Array


def init_decode_cache(cfg, batch: int, max_len: int) -> DecodeCache:
    window = cfg.sliding_window

    def kv(n, win):
        base = jax.vmap(
            lambda _: att.init_kv_cache(cfg, batch, max_len, win)
        )(jnp.arange(n))
        return base._replace(pos=jnp.zeros((n,), jnp.int32))

    if cfg.family == "ssm":
        self_kv = jax.vmap(lambda _: ssd_mod.init_ssm_cache(cfg, batch))(
            jnp.arange(cfg.n_layers)
        )
        cross = None
    elif cfg.family == "hybrid":
        per = cfg.attn_every
        n_super = cfg.n_layers // per
        n_tail = cfg.n_layers - n_super * per
        recs = jax.vmap(
            lambda _: jax.vmap(
                lambda __: rglru_mod.init_lru_cache(cfg, batch)
            )(jnp.arange(per - 1))
        )(jnp.arange(n_super))
        self_kv = {
            "recs": recs,
            "attn": kv(n_super, cfg.local_window),
            "tail": (
                jax.vmap(lambda _: rglru_mod.init_lru_cache(cfg, batch))(
                    jnp.arange(n_tail)
                )
                if n_tail
                else None
            ),
        }
        cross = None
    elif cfg.family == "vlm":
        n_groups = cfg.n_layers // cfg.cross_every
        base = jax.vmap(jax.vmap(
            lambda _: att.init_kv_cache(cfg, batch, max_len, None)
        ))(jnp.zeros((n_groups, cfg.cross_every)))
        self_kv = {
            "self": base._replace(
                pos=jnp.zeros((n_groups, cfg.cross_every), jnp.int32)
            )
        }
        cross = (
            jnp.zeros(
                (n_groups, batch, cfg.vision_tokens, cfg.n_kv_heads, cfg.hd),
                dtype_of(cfg.dtype),
            ),
            jnp.zeros(
                (n_groups, batch, cfg.vision_tokens, cfg.n_kv_heads, cfg.hd),
                dtype_of(cfg.dtype),
            ),
        )
    else:
        self_kv = kv(cfg.n_layers, window)
        cross = None
    return DecodeCache(
        self_kv=self_kv, cross_kv=cross, pos=jnp.zeros((), jnp.int32)
    )


def decoder_decode_step(
    params: Decoder, cfg, token: jax.Array, cache: DecodeCache
):
    """One decode step.  token: (B,) int32 -> logits (B, V)."""
    B = token.shape[0]
    x = params.embed[token][:, None, :]  # (B, 1, d)
    window = cfg.sliding_window
    pos = cache.pos

    if cfg.family == "ssm":
        def body(x_t, inp):
            bp, c = inp
            h = rms_norm(x_t, bp["norm"], cfg.norm_eps)
            h, c2 = ssd_mod.ssd_layer(bp["ssd"], h, cfg, c)
            return x_t + h, c2

        x, new_kv = layer_scan(body, x, (params.blocks, cache.self_kv))
        new_cache = DecodeCache(new_kv, None, pos + 1)

    elif cfg.family == "hybrid":
        def body(x_t, inp):
            bp, recs_c, kv_c = inp

            def rec_body(xx, rp_c):
                rp, c = rp_c
                h = rms_norm(xx, rp["rec_norm"], cfg.norm_eps)
                h, c2 = rglru_mod.rglru_block(rp["rec"], h, cfg, c)
                xx = xx + h
                h = rms_norm(xx, rp["mlp_norm"], cfg.norm_eps)
                return xx + mlp(rp["mlp"], h, cfg), c2

            x_t, recs_c2 = layer_scan(
                rec_body, x_t, (bp["recs"], recs_c)
            )
            x_t, kv_c2 = decoder_block_decode(
                bp["attn"], x_t, kv_c, cfg, window=cfg.local_window
            )
            return x_t, (recs_c2, kv_c2)

        x, (recs2, kv2) = layer_scan(
            body, x,
            (params.blocks, cache.self_kv["recs"], cache.self_kv["attn"]),
        )
        tail2 = cache.self_kv.get("tail")
        if params.tail is not None:
            def tail_body(xx, inp):
                rp, c = inp
                h = rms_norm(xx, rp["rec_norm"], cfg.norm_eps)
                h, c2 = rglru_mod.rglru_block(rp["rec"], h, cfg, c)
                xx = xx + h
                h = rms_norm(xx, rp["mlp_norm"], cfg.norm_eps)
                return xx + mlp(rp["mlp"], h, cfg), c2

            x, tail2 = layer_scan(
                tail_body, x, (params.tail, cache.self_kv["tail"])
            )
        new_cache = DecodeCache(
            {"recs": recs2, "attn": kv2, "tail": tail2}, None, pos + 1
        )

    elif cfg.family == "vlm":
        mem_k, mem_v = cache.cross_kv

        def body(x_t, inp):
            bp, cp, kv_c, mk, mv = inp

            def self_body(xx, sp_c):
                sp, c = sp_c
                return decoder_block_decode(sp, xx, c, cfg, window)

            x_t, kv2 = layer_scan(self_body, x_t, (bp, kv_c))
            x_t = cross_block_cached(cp, x_t, (mk, mv), cfg)
            return x_t, kv2

        kvs = cache.self_kv["self"]
        x, kv2 = layer_scan(
            body, x, (params.blocks, params.cross, kvs, mem_k, mem_v)
        )
        new_cache = DecodeCache({"self": kv2}, cache.cross_kv, pos + 1)

    else:
        def body(x_t, inp):
            bp, c = inp
            return decoder_block_decode(bp, x_t, c, cfg, window=window)

        x, kv2 = layer_scan(body, x, (params.blocks, cache.self_kv))
        new_cache = DecodeCache(kv2, None, pos + 1)

    logits = _lm_logits(params, x, cfg)[:, 0]
    return logits, new_cache


# ==================================================================== prefill
def decoder_block_prefill(bp, x, cfg, positions, window=None):
    """Decoder block that also returns (k, v) for cache construction."""
    h = rms_norm(x, bp["attn_norm"], cfg.norm_eps)
    h, (k, v) = att.multihead_attention(
        bp["attn"], h, cfg, positions=positions, window=window,
        return_kv=True,
    )
    x = x + h
    x = constrain(x, "dp", "sp", None)
    h = rms_norm(x, bp["mlp_norm"], cfg.norm_eps)
    if cfg.n_experts:
        h = moe_mod.moe_block(bp["moe"], h, cfg)
    else:
        h = mlp(bp["mlp"], h, cfg)
    return constrain(x + h, "dp", "sp", None), (k, v)


def decoder_prefill(
    params: Decoder,
    cfg,
    tokens: jax.Array,
    vision_embeds: Optional[jax.Array] = None,
    max_len: Optional[int] = None,
):
    """Prefill: forward the prompt, return (last-token logits, DecodeCache)."""
    B, S = tokens.shape
    max_len = max_len or S
    x = params.embed[tokens]
    x = constrain(x, "dp", "sp", None)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    window = cfg.sliding_window

    def to_cache(k, v, win):
        return att.fill_kv_cache(cfg, k, v, max_len, win)

    if cfg.family == "ssm":
        cache0 = init_decode_cache(cfg, B, max_len)

        def body(x, inp):
            bp, c = inp

            def inner(bp_, c_, x_):
                h = rms_norm(x_, bp_["norm"], cfg.norm_eps)
                h, c2 = ssd_mod.ssd_layer(bp_["ssd"], h, cfg, c_)
                return constrain(x_ + h, "dp", "sp", None), c2

            x, c2 = _maybe_remat(inner, cfg)(bp, c, x)
            return x, c2

        x, new_kv = layer_scan(body, x, (params.blocks, cache0.self_kv))
        cache = DecodeCache(new_kv, None, jnp.asarray(S, jnp.int32))

    elif cfg.family == "hybrid":
        cache0 = init_decode_cache(cfg, B, max_len)

        def body(x, inp):
            bp, recs_c = inp

            def inner(bp_, rc_, x_):
                def rec_body(xx, rp_c):
                    rp, c = rp_c
                    y, c2 = rec_block(rp, xx, cfg, c)
                    return y, c2

                x_, rc2 = layer_scan(rec_body, x_, (bp_["recs"], rc_))
                y, (k, v) = decoder_block_prefill(
                    bp_["attn"], x_, cfg, positions, window=cfg.local_window
                )
                return y, (rc2, k, v)

            x, out = _maybe_remat(inner, cfg)(bp, recs_c, x)
            return x, out

        x, (recs2, ks, vs) = layer_scan(
            body, x, (params.blocks, cache0.self_kv["recs"])
        )
        kv2 = jax.vmap(lambda k, v: to_cache(k, v, cfg.local_window))(ks, vs)
        tail2 = cache0.self_kv["tail"]
        if params.tail is not None:
            def tail_body(xx, inp):
                rp, c = inp
                y, c2 = rec_block(rp, xx, cfg, c)
                return y, c2

            x, tail2 = layer_scan(
                tail_body, x, (params.tail, cache0.self_kv["tail"])
            )
        cache = DecodeCache(
            {"recs": recs2, "attn": kv2, "tail": tail2},
            None, jnp.asarray(S, jnp.int32),
        )

    elif cfg.family == "vlm":
        memory = vision_embeds @ params.vision_proj
        memory = constrain(memory, "dp", None, None)

        def body(x, inp):
            bp, cp = inp

            def inner(bp_, cp_, x_):
                def self_body(xx, sp):
                    y, kv = decoder_block_prefill(sp, xx, cfg, positions, window)
                    return y, kv

                x_, (ks, vs) = layer_scan(self_body, x_, bp_)
                x_ = cross_block(cp_, x_, memory, cfg)
                mk, mv = cross_memory_kv(cp_, memory, cfg)
                return x_, (ks, vs, mk, mv)

            x, out = _maybe_remat(inner, cfg)(bp, cp, x)
            return x, out

        x, (ks, vs, mks, mvs) = layer_scan(
            body, x, (params.blocks, params.cross)
        )
        kv2 = jax.vmap(jax.vmap(lambda k, v: to_cache(k, v, window)))(ks, vs)
        cache = DecodeCache(
            {"self": kv2}, (mks, mvs), jnp.asarray(S, jnp.int32)
        )

    else:  # dense / moe
        def body(x, bp):
            fn = _maybe_remat(
                lambda bp_, x_: decoder_block_prefill(
                    bp_, x_, cfg, positions, window
                ),
                cfg,
            )
            x, kv = fn(bp, x)
            return x, kv

        x, (ks, vs) = layer_scan(body, x, params.blocks)
        kv2 = jax.vmap(lambda k, v: to_cache(k, v, window))(ks, vs)
        cache = DecodeCache(kv2, None, jnp.asarray(S, jnp.int32))

    logits = _lm_logits(params, x[:, -1:, :], cfg)[:, 0]
    return logits, cache


# ==================================================================== enc-dec
class EncDec(NamedTuple):
    """Encoder-decoder model (seamless-m4t family; audio frontend stubbed)."""

    audio_proj: jax.Array          # (audio_dim, d)
    enc_blocks: Any
    enc_norm: jax.Array
    embed: jax.Array               # decoder token embeddings
    dec_blocks: Any                # self + cross + mlp
    final_norm: jax.Array
    lm_head: Any


def init_enc_block(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": jnp.zeros((cfg.d_model,), dtype_of(cfg.dtype)),
        "attn": att.init_attn(ks[0], cfg),
        "mlp_norm": jnp.zeros((cfg.d_model,), dtype_of(cfg.dtype)),
        "mlp": init_mlp(ks[1], cfg),
    }


def init_dec_block(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "attn_norm": jnp.zeros((cfg.d_model,), dtype_of(cfg.dtype)),
        "attn": att.init_attn(ks[0], cfg),
        "cross_norm": jnp.zeros((cfg.d_model,), dtype_of(cfg.dtype)),
        "cross": att.init_attn(ks[1], cfg, cross=True),
        "mlp_norm": jnp.zeros((cfg.d_model,), dtype_of(cfg.dtype)),
        "mlp": init_mlp(ks[2], cfg),
    }


def init_encdec(key, cfg) -> EncDec:
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 6)
    return EncDec(
        audio_proj=trunc_normal(ks[0], (cfg.audio_dim, cfg.d_model), 1.0, dt),
        enc_blocks=_stack_init(
            lambda k: init_enc_block(k, cfg), ks[1], cfg.encoder_layers
        ),
        enc_norm=jnp.zeros((cfg.d_model,), dt),
        embed=trunc_normal(ks[2], (cfg.vocab_size, cfg.d_model), 1.0, dt),
        dec_blocks=_stack_init(
            lambda k: init_dec_block(k, cfg), ks[3], cfg.n_layers
        ),
        final_norm=jnp.zeros((cfg.d_model,), dt),
        lm_head=trunc_normal(ks[4], (cfg.d_model, cfg.vocab_size), 1.0, dt),
    )


def encdec_specs(cfg) -> EncDec:
    def stack(spec_tree):
        return jax.tree.map(
            lambda s: (None,) + s,
            spec_tree,
            is_leaf=lambda s: isinstance(s, tuple)
            and all(x is None or isinstance(x, str) for x in s),
        )

    enc_spec = {
        "attn_norm": (None,),
        "attn": att.attn_specs(cfg),
        "mlp_norm": (None,),
        "mlp": mlp_specs(cfg),
    }
    dec_spec = {
        "attn_norm": (None,),
        "attn": att.attn_specs(cfg),
        "cross_norm": (None,),
        "cross": att.attn_specs(cfg, cross=True),
        "mlp_norm": (None,),
        "mlp": mlp_specs(cfg),
    }
    return EncDec(
        audio_proj=("fsdp", "tp"),
        enc_blocks=stack(enc_spec),
        enc_norm=(None,),
        embed=("tp", "fsdp"),
        dec_blocks=stack(dec_spec),
        final_norm=(None,),
        lm_head=("fsdp", "tp"),
    )


def encode_audio(params: EncDec, cfg, frames: jax.Array) -> jax.Array:
    """frames: (B, T_frames, audio_dim) stub embeddings -> memory (B,T,d)."""
    x = frames @ params.audio_proj
    x = constrain(x, "dp", "sp", None)
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1])[None], x.shape[:2]
    )

    def body(x, bp):
        def inner(bp_, x_):
            h = rms_norm(x_, bp_["attn_norm"], cfg.norm_eps)
            h = att.multihead_attention(
                bp_["attn"], h, cfg, positions=positions, causal=False
            )
            x_ = x_ + h
            h = rms_norm(x_, bp_["mlp_norm"], cfg.norm_eps)
            return constrain(x_ + mlp(bp_["mlp"], h, cfg), "dp", "sp", None)

        return _maybe_remat(inner, cfg)(bp, x), None

    x, _ = layer_scan(body, x, params.enc_blocks)
    return rms_norm(x, params.enc_norm, cfg.norm_eps)


def encdec_forward(
    params: EncDec, cfg, frames: jax.Array, tokens: jax.Array
) -> jax.Array:
    """Teacher-forced decoder logits (B, S, V)."""
    memory = encode_audio(params, cfg, frames)
    B, S = tokens.shape
    x = params.embed[tokens]
    x = constrain(x, "dp", "sp", None)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, bp):
        def inner(bp_, x_):
            h = rms_norm(x_, bp_["attn_norm"], cfg.norm_eps)
            h = att.multihead_attention(
                bp_["attn"], h, cfg, positions=positions, causal=True
            )
            x_ = x_ + h
            h = rms_norm(x_, bp_["cross_norm"], cfg.norm_eps)
            h = att.multihead_attention(
                bp_["cross"], h, cfg, kv_x=memory, causal=False,
                use_rope=False, impl="einsum",
            )
            x_ = x_ + h
            h = rms_norm(x_, bp_["mlp_norm"], cfg.norm_eps)
            return constrain(x_ + mlp(bp_["mlp"], h, cfg), "dp", "sp", None)

        return _maybe_remat(inner, cfg)(bp, x), None

    x, _ = layer_scan(body, x, params.dec_blocks)
    x = rms_norm(x, params.final_norm, cfg.norm_eps)
    logits = x @ params.lm_head
    return constrain(logits, "dp", None, "tp")


class EncDecCache(NamedTuple):
    self_kv: att.KVCache   # stacked (L, ...)
    cross_k: jax.Array     # (L, B, T_frames, K, hd)
    cross_v: jax.Array
    pos: jax.Array


def encdec_prefill(
    params: EncDec, cfg, frames: jax.Array, tokens: jax.Array,
    max_len: Optional[int] = None,
):
    """Encode audio + prefill decoder prompt -> (logits, cache)."""
    memory = encode_audio(params, cfg, frames)
    B, S = tokens.shape
    max_len = max_len or S
    x = params.embed[tokens]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, bp):
        def inner(bp_, x_):
            h = rms_norm(x_, bp_["attn_norm"], cfg.norm_eps)
            h, (k, v) = att.multihead_attention(
                bp_["attn"], h, cfg, positions=positions, causal=True,
                return_kv=True,
            )
            x_ = x_ + h
            h = rms_norm(x_, bp_["cross_norm"], cfg.norm_eps)
            h = att.multihead_attention(
                bp_["cross"], h, cfg, kv_x=memory, causal=False,
                use_rope=False, impl="einsum",
            )
            x_ = x_ + h
            K, hd = cfg.n_kv_heads, cfg.hd
            mk = (memory @ bp_["cross"]["wk"]).reshape(
                B, memory.shape[1], K, hd
            )
            mv = (memory @ bp_["cross"]["wv"]).reshape(
                B, memory.shape[1], K, hd
            )
            h = rms_norm(x_, bp_["mlp_norm"], cfg.norm_eps)
            return x_ + mlp(bp_["mlp"], h, cfg), (k, v, mk, mv)

        x, out = _maybe_remat(inner, cfg)(bp, x)
        return x, out

    x, (ks, vs, mks, mvs) = layer_scan(body, x, params.dec_blocks)
    self_kv = jax.vmap(
        lambda k, v: att.fill_kv_cache(cfg, k, v, max_len, None)
    )(ks, vs)
    x = rms_norm(x[:, -1:, :], params.final_norm, cfg.norm_eps)
    logits = (x @ params.lm_head)[:, 0]
    cache = EncDecCache(self_kv, mks, mvs, jnp.asarray(S, jnp.int32))
    return logits, cache


def init_encdec_cache(cfg, batch: int, max_len: int, n_frames: int):
    dt = dtype_of(cfg.dtype)
    L = cfg.n_layers
    K, hd = cfg.n_kv_heads, cfg.hd
    return EncDecCache(
        self_kv=att.KVCache(
            k=jnp.zeros((L, batch, max_len, K, hd), dt),
            v=jnp.zeros((L, batch, max_len, K, hd), dt),
            pos=jnp.zeros((L,), jnp.int32),
        ),
        cross_k=jnp.zeros((L, batch, n_frames, K, hd), dt),
        cross_v=jnp.zeros((L, batch, n_frames, K, hd), dt),
        pos=jnp.zeros((), jnp.int32),
    )


def encdec_decode_step(
    params: EncDec, cfg, token: jax.Array, cache: EncDecCache
):
    B = token.shape[0]
    x = params.embed[token][:, None, :]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def body(x_t, inp):
        bp, c, mk, mv = inp
        h = rms_norm(x_t, bp["attn_norm"], cfg.norm_eps)
        h, c2 = att.decode_attention(bp["attn"], h, c, cfg)
        x_t = x_t + h
        h = rms_norm(x_t, bp["cross_norm"], cfg.norm_eps)
        q = (h @ bp["cross"]["wq"]).reshape(B, 1, K, H // K, hd)
        logit = jnp.einsum(
            "bqkgd,bskd->bkgqs", q.astype(jnp.float32) * (hd ** -0.5),
            mk.astype(jnp.float32),
        )
        pa = jax.nn.softmax(logit, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", pa, mv.astype(jnp.float32))
        o = o.reshape(B, 1, H * hd).astype(x_t.dtype) @ bp["cross"]["wo"]
        x_t = x_t + o
        h = rms_norm(x_t, bp["mlp_norm"], cfg.norm_eps)
        return x_t + mlp(bp["mlp"], h, cfg), c2

    x, kv2 = layer_scan(
        body, x, (params.dec_blocks, cache.self_kv, cache.cross_k,
                  cache.cross_v)
    )
    x = rms_norm(x, params.final_norm, cfg.norm_eps)
    logits = (x @ params.lm_head)[:, 0]
    return logits, EncDecCache(kv2, cache.cross_k, cache.cross_v,
                               cache.pos + 1)
