"""Attention blocks: GQA/MQA/MHA, RoPE, sliding window, cross-attn, KV cache.

Three interchangeable implementations (``cfg.attn_impl``):

  einsum  — materialized logits; right for short sequences (train_4k).
  chunked — pure-JAX online softmax over kv chunks (lax.scan): peak memory
            O(Sq * chunk) instead of O(Sq * Skv); the dry-run/default path
            for 32k prefill, and the CPU-runnable stand-in with identical
            math to the Pallas kernel.
  flash   — the Pallas TPU kernel (repro.kernels.flash_attention).

Decode attends a single query over a (possibly sequence-sharded) cache with
explicit length masking; sliding-window caches are ring buffers of size
``window`` so long_500k memory is O(window), not O(context).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.models.layers import dtype_of, rope, trunc_normal
from repro.sharding import constrain

NEG_INF = -1e30


def init_attn(key, cfg, cross: bool = False):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": trunc_normal(ks[0], (d, H * hd), 1.0, dt),
        "wk": trunc_normal(ks[1], (d, K * hd), 1.0, dt),
        "wv": trunc_normal(ks[2], (d, K * hd), 1.0, dt),
        "wo": trunc_normal(ks[3], (H * hd, d), 1.0, dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((K * hd,), dt)
        p["bv"] = jnp.zeros((K * hd,), dt)
    return p


def attn_specs(cfg, cross: bool = False):
    p = {
        "wq": ("fsdp", "tp"),
        "wk": ("fsdp", "tp"),
        "wv": ("fsdp", "tp"),
        "wo": ("tp", "fsdp"),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = ("tp",)
        p["bk"] = ("tp",)
        p["bv"] = ("tp",)
    return p


def _qkv(p, x, kv_x, cfg):
    B = x.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = kv_x @ p["wk"]
    v = kv_x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, x.shape[1], H, hd)
    k = k.reshape(B, kv_x.shape[1], K, hd)
    v = v.reshape(B, kv_x.shape[1], K, hd)
    return q, k, v


def _einsum_attn(q, k, v, causal, window, lengths=None):
    """q: (B,Sq,H,hd); k/v: (B,Skv,K,hd). Materialized-logit attention."""
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    g = H // K
    qh = q.reshape(B, Sq, K, g, hd)
    logits = jnp.einsum(
        "bqkgd,bskd->bkgqs", qh.astype(jnp.float32), k.astype(jnp.float32)
    ) * (hd ** -0.5)
    i = jnp.arange(Sq)[:, None] + (Skv - Sq)
    j = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= j <= i
    if window is not None:
        mask &= j > i - window
    if lengths is not None:
        mask = mask[None] & (j[None] < lengths[:, None, None])
        mask = mask[:, None, None]
    else:
        mask = mask[None, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    pattn = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", pattn, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _chunked_attn(q, k, v, causal, window, chunk):
    """Online-softmax over kv chunks; math identical to the flash kernel."""
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    g = H // K
    nchunk = -(-Skv // chunk)
    pad = nchunk * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunk, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunk, chunk, K, hd).transpose(1, 0, 2, 3, 4)

    qh = (q.reshape(B, Sq, K, g, hd).astype(jnp.float32)) * (hd ** -0.5)
    i_pos = jnp.arange(Sq) + (Skv - Sq)

    def body(carry, inputs):
        m, l, acc = carry
        kb, vb, c_idx = inputs
        j_pos = c_idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qh, kb.astype(jnp.float32))
        mask = j_pos[None, :] < Skv
        if causal:
            mask = mask & (j_pos[None, :] <= i_pos[:, None])
        if window is not None:
            mask = mask & (j_pos[None, :] > i_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, g, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, g, Sq), jnp.float32)
    a0 = jnp.zeros((B, K, g, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(nchunk))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def multihead_attention(
    p,
    x: jax.Array,
    cfg,
    positions: Optional[jax.Array] = None,
    kv_x: Optional[jax.Array] = None,
    causal: bool = True,
    window: Optional[int] = None,
    use_rope: bool = True,
    impl: Optional[str] = None,
    return_kv: bool = False,
):
    """Full-sequence attention (train / prefill / cross)."""
    cross = kv_x is not None
    manual_rs = getattr(cfg, "tp_mode", "megatron") == "megatron_rs" \
        and not cross
    if manual_rs:
        # fused manual (bf16 seq-AG + qkv projections): the backward
        # input-cotangent merge becomes the AG's transpose (bf16 RS)
        from repro.sharding import tp_ag_matmuls
        B = x.shape[0]
        H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        q, k, v = tp_ag_matmuls(x, p["wq"], p["wk"], p["wv"])
        if "bq" in p:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        S_full = q.shape[1]  # logical shapes are global: S_full == x.shape[1]
        q = q.reshape(B, S_full, H, hd)
        k = k.reshape(B, S_full, K, hd)
        v = v.reshape(B, S_full, K, hd)
        kv_src = x
    else:
        kv_src = kv_x if cross else x
        q, k, v = _qkv(p, x, kv_src, cfg)
    if use_rope and not cross:
        if positions is None:
            positions = jnp.arange(x.shape[1])[None, :]
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if getattr(cfg, "tp_mode", "megatron") == "ulysses" and not cross:
        # Ulysses-style: projections ran on the sequence-sharded stream;
        # these constraints reshard seq->heads, which GSPMD lowers as an
        # all-to-all of activation/tp bytes (vs. a full-activation
        # all-reduce in the Megatron layout).
        q = constrain(q, "dp", "sp", None, None)
        k = constrain(k, "dp", "sp", None, None)
        v = constrain(v, "dp", "sp", None, None)
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    v = constrain(v, "dp", None, "tp", None)

    impl = impl or cfg.attn_impl
    if impl == "auto":
        impl = "einsum" if k.shape[1] <= 8192 else "chunked"
    if impl == "flash":
        o = flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=window,
            use_kernel=True,
        ).transpose(0, 2, 1, 3)
    elif impl == "chunked":
        o = _chunked_attn(q, k, v, causal, window, cfg.attn_chunk)
    else:
        o = _einsum_attn(q, k, v, causal, window)
    o = constrain(o, "dp", None, "tp", None)
    if getattr(cfg, "tp_mode", "megatron") == "ulysses" and not cross:
        o = constrain(o, "dp", "sp", None, None)  # a2a back to seq-sharded
    B, S = o.shape[0], o.shape[1]
    o2 = o.reshape(B, S, cfg.n_heads * cfg.hd)
    if manual_rs:
        from repro.sharding import tp_rs_matmul
        out = tp_rs_matmul(o2, p["wo"])  # bf16 psum_scatter merge
    else:
        out = o2 @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


# ------------------------------------------------------------------ KV cache
class KVCache(NamedTuple):
    """KV cache; with cfg.kv_cache_dtype == "int8" the k/v planes are
    symmetric per-(token, head) absmax-quantized int8 with bf16 scales —
    halving the decode cells' dominant (cache-read) HBM term.
    """

    k: jax.Array      # (B, S_cache, K, hd) — ring buffer if windowed
    v: jax.Array
    k_scale: Any      # (B, S_cache, K, 1) or None
    v_scale: Any
    pos: jax.Array    # () int32 — absolute position of next token


def _cache_is_q(cfg) -> bool:
    return getattr(cfg, "kv_cache_dtype", "model") == "int8"


def quantize_kv(x: jax.Array):
    """(…, hd) -> int8 values + per-row absmax scale."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-6)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale * 127.0), -127, 127
    ).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype):
    return (q.astype(jnp.float32) * (scale.astype(jnp.float32) / 127.0)
            ).astype(dtype)


def init_kv_cache(cfg, batch: int, max_len: int, window: Optional[int] = None):
    size = min(max_len, window) if window else max_len
    dt = dtype_of(cfg.dtype)
    shape = (batch, size, cfg.n_kv_heads, cfg.hd)
    if _cache_is_q(cfg):
        return KVCache(
            k=jnp.zeros(shape, jnp.int8),
            v=jnp.zeros(shape, jnp.int8),
            k_scale=jnp.zeros(shape[:-1] + (1,), jnp.bfloat16),
            v_scale=jnp.zeros(shape[:-1] + (1,), jnp.bfloat16),
            pos=jnp.zeros((), jnp.int32),
        )
    return KVCache(
        k=jnp.zeros(shape, dt),
        v=jnp.zeros(shape, dt),
        k_scale=None,
        v_scale=None,
        pos=jnp.zeros((), jnp.int32),
    )


def fill_kv_cache(cfg, k, v, max_len: int, window: Optional[int] = None):
    """Build a cache from prefill keys/values (end-aligned for ring buffers)."""
    if _cache_is_q(cfg):
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        base = fill_kv_cache(
            cfg.replace(kv_cache_dtype="model"),
            jnp.concatenate([kq.astype(jnp.bfloat16),
                             jnp.broadcast_to(ks, kq.shape[:-1] + (1,)).astype(jnp.bfloat16)], -1),
            jnp.concatenate([vq.astype(jnp.bfloat16),
                             jnp.broadcast_to(vs, vq.shape[:-1] + (1,)).astype(jnp.bfloat16)], -1),
            max_len, window,
        )
        return KVCache(
            k=jnp.round(base.k[..., :-1] ).astype(jnp.int8),
            v=jnp.round(base.v[..., :-1]).astype(jnp.int8),
            k_scale=base.k[..., -1:],
            v_scale=base.v[..., -1:],
            pos=base.pos,
        )
    B, S = k.shape[:2]
    size = min(max_len, window) if window else max_len
    if S >= size:
        kk, vv = k[:, S - size:], v[:, S - size:]
        if window:
            # ring-buffer layout: slot = pos % window
            idx = (jnp.arange(S - size, S)) % size
            kk = jnp.zeros((B, size) + k.shape[2:], k.dtype).at[:, idx].set(kk)
            vv = jnp.zeros((B, size) + v.shape[2:], v.dtype).at[:, idx].set(vv)
    else:
        pad = size - S
        if window:
            idx = jnp.arange(S) % size
            kk = jnp.zeros((B, size) + k.shape[2:], k.dtype).at[:, idx].set(k)
            vv = jnp.zeros((B, size) + v.shape[2:], v.dtype).at[:, idx].set(v)
        else:
            kk = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return KVCache(k=kk, v=vv, k_scale=None, v_scale=None,
                   pos=jnp.asarray(S, jnp.int32))


def decode_attention(
    p,
    x_t: jax.Array,            # (B, 1, d)
    cache: KVCache,
    cfg,
    window: Optional[int] = None,
    use_rope: bool = True,
) -> tuple[jax.Array, KVCache]:
    """One decode step: append token kv, attend over the cache."""
    B = x_t.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, k_t, v_t = _qkv(p, x_t, x_t, cfg)
    pos = cache.pos
    if use_rope:
        pp = jnp.full((B, 1), pos, jnp.int32)
        q = rope(q, pp, cfg.rope_theta)
        k_t = rope(k_t, pp, cfg.rope_theta)

    size = cache.k.shape[1]
    slot = (pos % size).astype(jnp.int32)
    quantized = cache.k_scale is not None
    if quantized:
        kq, ks = quantize_kv(k_t)
        vq, vs = quantize_kv(v_t)
        ck = jax.lax.dynamic_update_slice_in_dim(cache.k, kq, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache.v, vq, slot, axis=1)
        cks = jax.lax.dynamic_update_slice_in_dim(
            cache.k_scale, ks, slot, axis=1)
        cvs = jax.lax.dynamic_update_slice_in_dim(
            cache.v_scale, vs, slot, axis=1)
        k_read = dequantize_kv(ck, cks, x_t.dtype)
        v_read = dequantize_kv(cv, cvs, x_t.dtype)
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_t.astype(cache.k.dtype), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_t.astype(cache.v.dtype), slot, axis=1)
        cks = cvs = None
        k_read, v_read = ck, cv

    g = H // K
    qh = q.reshape(B, 1, K, g, hd).astype(jnp.float32) * (hd ** -0.5)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qh, k_read.astype(jnp.float32))
    slots = jnp.arange(size)
    if window:
        valid = slots[None, :] <= jnp.minimum(pos, size - 1)
        # ring buffer: every slot written so far is within the window
        valid = jnp.broadcast_to(valid, (B, size))
    else:
        valid = jnp.broadcast_to(slots[None, :] <= pos, (B, size))
    logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    pattn = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pattn, v_read.astype(jnp.float32))
    o = o.reshape(B, 1, H * hd).astype(x_t.dtype)
    out = o @ p["wo"]
    return out, KVCache(k=ck, v=cv, k_scale=cks, v_scale=cvs, pos=pos + 1)
