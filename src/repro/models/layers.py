"""Shared building blocks: norms, RoPE, MLPs, initializers."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding import constrain


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------- init utils
def trunc_normal(key, shape, scale, dtype):
    fan_in = shape[0] if len(shape) >= 1 else 1
    std = (scale / max(fan_in, 1)) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(
        dtype
    )


def init_linear(key, d_in, d_out, dtype, bias: bool = False, scale=1.0):
    p = {"w": trunc_normal(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# --------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------- RoPE
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- MLPs
def init_mlp(key, cfg, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": trunc_normal(ks[0], (d, f), 1.0, dt),
            "w_up": trunc_normal(ks[1], (d, f), 1.0, dt),
            "w_down": trunc_normal(ks[2], (f, d), 1.0, dt),
        }
    p = {
        "w_up": trunc_normal(ks[0], (d, f), 1.0, dt),
        "w_down": trunc_normal(ks[1], (f, d), 1.0, dt),
    }
    if cfg.mlp_bias:
        p["b_up"] = jnp.zeros((f,), dt)
        p["b_down"] = jnp.zeros((d,), dt)
    return p


def mlp(p, x, cfg):
    """Feed-forward block.

    megatron: hidden activation sharded over tp (partial-sum all-reduce on
    the down projection).  ulysses: the token stream stays sequence-sharded
    and the (small) weights are gathered instead — no activation collective.
    """
    mode = getattr(cfg, "tp_mode", "megatron")
    ulysses = mode == "ulysses"
    manual_rs = mode == "megatron_rs"
    hidden_spec = ("dp", "sp", None) if ulysses else ("dp", None, "tp")
    if manual_rs:
        from repro.sharding import tp_ag_matmuls, tp_rs_matmul
        if cfg.mlp_type == "swiglu":
            g, u = tp_ag_matmuls(x, p["w_gate"], p["w_up"])
            h = jax.nn.silu(g) * u
            h = constrain(h, *hidden_spec)
            return tp_rs_matmul(h, p["w_down"])
        (h,) = tp_ag_matmuls(x, p["w_up"])
        if "b_up" in p:
            h = h + p["b_up"]
        h = jax.nn.gelu(h)
        h = constrain(h, *hidden_spec)
        y = tp_rs_matmul(h, p["w_down"])
        if "b_down" in p:
            y = y + p["b_down"]
        return y
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        h = constrain(h, *hidden_spec)
        return h @ p["w_down"]
    h = x @ p["w_up"]
    if "b_up" in p:
        h = h + p["b_up"]
    h = jax.nn.gelu(h)
    h = constrain(h, *hidden_spec)
    if manual_rs:
        y = tp_rs_matmul(h, p["w_down"])
    else:
        y = h @ p["w_down"]
    if "b_down" in p:
        y = y + p["b_down"]
    return y


def mlp_specs(cfg):
    """Logical-axis tuples matching init_mlp's structure."""
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": ("fsdp", "tp"),
            "w_up": ("fsdp", "tp"),
            "w_down": ("tp", "fsdp"),
        }
    p = {"w_up": ("fsdp", "tp"), "w_down": ("tp", "fsdp")}
    if cfg.mlp_bias:
        p["b_up"] = ("tp",)
        p["b_down"] = (None,)
    return p
