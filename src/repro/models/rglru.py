"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The real-gated linear recurrent unit:

  r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
  i_t = sigmoid(W_x x_t + b_x)          (input gate)
  a_t = a^(c * r_t),  a = sigmoid(Lambda)  (per-channel, c = 8)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses ``jax.lax.associative_scan`` over the linear
recurrence (log-depth on TPU, the standard lowering for linear RNNs) —
the TPU-native analogue of the paper's custom "linear scan" kernel.
Decode is the O(1) sequential update.

The full recurrent block (Griffin):  x -> [gate branch: GeLU(W_g x)]
                                      x -> [W_r x -> conv1d(4) -> RG-LRU]
                                      out = W_o (gate * lru_out)
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dtype_of, trunc_normal

C_CONST = 8.0


class LRUCache(NamedTuple):
    conv: jax.Array    # (B, W-1, lru_width)
    h: jax.Array       # (B, lru_width) f32
    pos: jax.Array


def init_rglru_block(key, cfg):
    d = cfg.d_model
    w = cfg.lru_width or d
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "w_gate": trunc_normal(ks[0], (d, w), 1.0, dt),
        "w_rec": trunc_normal(ks[1], (d, w), 1.0, dt),
        "conv_w": trunc_normal(ks[2], (4, w), 4.0, dt),
        "conv_b": jnp.zeros((w,), dt),
        "wa": trunc_normal(ks[3], (w, w), 1.0, dt),
        "ba": jnp.zeros((w,), jnp.float32),
        "wx": trunc_normal(ks[4], (w, w), 1.0, dt),
        "bx": jnp.zeros((w,), jnp.float32),
        # Lambda init so that a in (0.9, 0.999) (paper's init range)
        "lam": jnp.log(
            jnp.linspace(0.9, 0.999, w, dtype=jnp.float32)
            / (1.0 - jnp.linspace(0.9, 0.999, w, dtype=jnp.float32))
        ),
        "w_out": trunc_normal(ks[5], (w, d), 1.0, dt),
    }


def rglru_specs(cfg):
    return {
        "w_gate": ("fsdp", "tp"),
        "w_rec": ("fsdp", "tp"),
        "conv_w": (None, "tp"),
        "conv_b": ("tp",),
        "wa": ("fsdp", "tp"),
        "ba": ("tp",),
        "wx": ("fsdp", "tp"),
        "bx": ("tp",),
        "lam": ("tp",),
        "w_out": ("tp", "fsdp"),
    }


def _causal_conv(x, w, b, init_state=None):
    W = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = init_state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(W))
    return out + b[None, None], xp[:, -(W - 1):]


def _rglru_scan(x, a_t, h0=None):
    """h_t = a_t h_{t-1} + x_t via associative scan.  x, a_t: (B, T, W)."""
    if h0 is not None:
        # absorb the initial state as a virtual first timestep
        x = jnp.concatenate([h0[:, None], x], axis=1)
        a_t = jnp.concatenate([jnp.ones_like(a_t[:, :1]), a_t], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_cum, h = jax.lax.associative_scan(combine, (a_t, x), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h


def rglru_block(p, u, cfg, cache: LRUCache | None = None):
    """u: (B, T, d) -> (B, T, d) (+ cache')."""
    gate = jax.nn.gelu(u @ p["w_gate"])
    x = u @ p["w_rec"]
    conv_init = cache.conv if cache is not None else None
    x, conv_state = _causal_conv(x, p["conv_w"], p["conv_b"], conv_init)

    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["wa"].astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(xf @ p["wx"].astype(jnp.float32) + p["bx"])
    log_a = -C_CONST * r * jax.nn.softplus(-p["lam"])  # log sigmoid(lam)^(c r)
    a_t = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - a_t * a_t, 1e-12)) * (i * xf)

    h0 = cache.h if cache is not None else None
    h = _rglru_scan(gated_x, a_t, h0)
    y = (h.astype(u.dtype) * gate) @ p["w_out"]
    if cache is not None:
        return y, LRUCache(conv=conv_state, h=h[:, -1].astype(jnp.float32),
                           pos=cache.pos + u.shape[1])
    return y, None


def init_lru_cache(cfg, batch: int):
    w = cfg.lru_width or cfg.d_model
    return LRUCache(
        conv=jnp.zeros((batch, 3, w), dtype_of(cfg.dtype)),
        h=jnp.zeros((batch, w), jnp.float32),
        pos=jnp.zeros((), jnp.int32),
    )
