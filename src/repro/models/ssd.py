"""Mamba-2 SSD (state-space duality) layer — arXiv:2405.21060.

The chunked SSD algorithm: split the sequence into chunks of Q tokens;
within a chunk the quadratic ("attention-like") form is used, across chunks
a recurrent state (H = heads, P = head_dim, N = d_state) is carried:

  intra:  Y_diag = (C B^T ∘ L) X           (L = lower-tri decay products)
  state:  h' = h * decay_chunk + B^T (X * decay_tail)
  inter:  Y_off = C h_prev * decay_head

Scalar-per-head A (Mamba-2 simplification); dt via softplus with learned
bias; short causal conv on x/B/C; gated RMSNorm on the output (z branch).
The chunk scan is ``lax.scan`` (sequential over T/Q chunks — the TPU-native
replacement for the paper's fused CUDA kernel; Q=ssm_chunk keeps the
quadratic block MXU-shaped).

Decode carries (conv_state, ssm_state) — O(1) per token, which is what
makes long_500k runnable for this family.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dtype_of, rms_norm, trunc_normal
from repro.sharding import constrain


class SSMCache(NamedTuple):
    conv: jax.Array   # (B, W-1, conv_dim)
    state: jax.Array  # (B, H, P, N) f32
    pos: jax.Array


def _conv_dim(cfg):
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def init_ssd(key, cfg):
    d = cfg.d_model
    di = cfg.d_inner
    H = cfg.ssm_heads
    G, N = cfg.ssm_groups, cfg.ssm_state
    W = cfg.ssm_conv_width
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 5)
    conv_dim = _conv_dim(cfg)
    return {
        # fused input projection: [z, x, B, C, dt]
        "in_proj": trunc_normal(
            ks[0], (d, 2 * di + 2 * G * N + H), 1.0, dt
        ),
        "conv_w": trunc_normal(ks[1], (W, conv_dim), 4.0, dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)
        ),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm_w": jnp.zeros((di,), dt),
        "out_proj": trunc_normal(ks[2], (di, d), 1.0, dt),
    }


def ssd_specs(cfg):
    return {
        "in_proj": ("fsdp", "tp"),
        "conv_w": (None, "tp"),
        "conv_b": ("tp",),
        "A_log": ("tp",),
        "dt_bias": ("tp",),
        "D": ("tp",),
        "norm_w": ("tp",),
        "out_proj": ("tp", "fsdp"),
    }


def _split_proj(cfg, zxbcdt):
    di = cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di:2 * di]
    Bm = zxbcdt[..., 2 * di:2 * di + G * N]
    Cm = zxbcdt[..., 2 * di + G * N:2 * di + 2 * G * N]
    dt_raw = zxbcdt[..., 2 * di + 2 * G * N:]
    return z, x, Bm, Cm, dt_raw


def _causal_conv(xbc, w, b, init_state=None):
    """Depthwise causal conv along time.  xbc: (B, T, C); w: (W, C)."""
    W = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = init_state
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(
        xp[:, i:i + xbc.shape[1]] * w[i][None, None, :] for i in range(W)
    )
    return jax.nn.silu(out + b[None, None, :]), xp[:, -(W - 1):]


def ssd_chunked(cfg, x, Bm, Cm, dt, A, init_state=None):
    """Chunked SSD scan.

    x:  (B, T, H, P) — inputs per head.
    Bm: (B, T, G, N); Cm: (B, T, G, N); dt: (B, T, H) (post-softplus).
    A:  (H,) negative reals.
    Returns y (B, T, H, P) and final state (B, H, P, N).
    """
    Bsz, T, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(cfg.ssm_chunk, T)
    nc = -(-T // Q)
    pad = nc * Q - T
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))

    # reshape to chunks, scan axis first
    xc = x.reshape(Bsz, nc, Q, H, Pd).transpose(1, 0, 2, 3, 4)
    Bc = Bm.reshape(Bsz, nc, Q, G, N).transpose(1, 0, 2, 3, 4)
    Cc = Cm.reshape(Bsz, nc, Q, G, N).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(Bsz, nc, Q, H).transpose(1, 0, 2, 3)

    heads_per_group = H // G

    def chunk_step(h_prev, inp):
        xq, bq, cq, dtq = inp              # (B,Q,H,P), (B,Q,G,N), ., (B,Q,H)
        dA = dtq * A[None, None, :]        # (B,Q,H) negative
        cum = jnp.cumsum(dA, axis=1)       # segsum prefix
        # L[i,j] = exp(cum_i - cum_j) for i >= j  (decay from j+1..i).
        # Mask BEFORE the exp: the upper triangle holds large positive
        # values whose exp overflows and poisons gradients through where.
        Li = cum[:, :, None, :] - cum[:, None, :, :]     # (B,Q,Q,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.exp(jnp.where(tri[None, :, :, None], Li, -1e30))

        bqh = jnp.repeat(bq, heads_per_group, axis=2)     # (B,Q,H,N)
        cqh = jnp.repeat(cq, heads_per_group, axis=2)
        # intra-chunk (quadratic) term
        scores = jnp.einsum("bihn,bjhn->bijh", cqh, bqh) * L
        xdt = xq * dtq[..., None]                        # (B,Q,H,P)
        y = jnp.einsum("bijh,bjhp->bihp", scores, xdt)
        # inter-chunk: contribution of carried state
        decay_head = jnp.exp(cum)                        # (B,Q,H)
        y += jnp.einsum("bihn,bhpn->bihp", cqh, h_prev) * decay_head[..., None]
        # state update
        total = cum[:, -1, :]                            # (B,H)
        decay_tail = jnp.exp(total[:, None, :] - cum)    # (B,Q,H)
        h_new = h_prev * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "bjhn,bjhp->bhpn", bqh * decay_tail[..., None], xdt
        )
        return h_new, y

    if init_state is None:
        init_state = jnp.zeros((Bsz, H, Pd, N), jnp.float32)
    h_fin, ys = jax.lax.scan(
        chunk_step, init_state,
        (xc.astype(jnp.float32), Bc.astype(jnp.float32),
         Cc.astype(jnp.float32), dtc.astype(jnp.float32)),
    )
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, nc * Q, H, Pd)[:, :T]
    return y, h_fin


def ssd_layer(p, u, cfg, cache: SSMCache | None = None):
    """Full Mamba-2 block. u: (B, T, d) -> (B, T, d) (+ cache')."""
    Bsz, T, d = u.shape
    H, Pd = cfg.ssm_heads, cfg.ssm_head_dim
    di = cfg.d_inner

    zxbcdt = u @ p["in_proj"]
    z, x, Bm, Cm, dt_raw = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)
    conv_init = cache.conv if cache is not None else None
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_init)
    x = xbc[..., :di]
    Bm = xbc[..., di:di + cfg.ssm_groups * cfg.ssm_state]
    Cm = xbc[..., di + cfg.ssm_groups * cfg.ssm_state:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(Bsz, T, H, Pd)
    Bh = Bm.reshape(Bsz, T, cfg.ssm_groups, cfg.ssm_state)
    Ch = Cm.reshape(Bsz, T, cfg.ssm_groups, cfg.ssm_state)

    init_state = cache.state if cache is not None else None
    y, h_fin = ssd_chunked(cfg, xh, Bh, Ch, dt, A, init_state)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, T, di).astype(u.dtype)
    y = constrain(y, "dp", None, "tp")
    # gated RMSNorm (Mamba-2's "norm before gate" variant)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if cache is not None:
        new_cache = SSMCache(conv=conv_state, state=h_fin,
                             pos=cache.pos + T)
        return out, new_cache
    return out, None


def init_ssm_cache(cfg, batch: int):
    return SSMCache(
        conv=jnp.zeros(
            (batch, cfg.ssm_conv_width - 1, _conv_dim(cfg)),
            dtype_of(cfg.dtype),
        ),
        state=jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        ),
        pos=jnp.zeros((), jnp.int32),
    )
