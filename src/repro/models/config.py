"""Model configuration dataclass covering all assigned architecture families."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | vlm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_group_size: int = 1024       # GShard-style dispatch group
    capacity_factor: float = 1.25

    # --- attention flavour ---
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    mlp_bias: bool = False
    mlp_type: str = "swiglu"         # swiglu | gelu
    sliding_window: Optional[int] = None
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    attn_chunk: int = 1024           # kv-chunk for the online-softmax path
    attn_impl: str = "auto"          # auto | einsum | chunked | flash

    # --- ssm (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    ssm_groups: int = 1

    # --- hybrid (RG-LRU / Griffin) ---
    lru_width: Optional[int] = None
    local_window: Optional[int] = None
    attn_every: int = 0              # 1 attention layer per `attn_every` (3 -> 1:2)

    # --- vlm ---
    cross_every: int = 0             # a cross-attn block after every N self layers
    vision_dim: int = 0
    vision_tokens: int = 0

    # --- encdec (audio) ---
    encoder_layers: int = 0
    audio_frames: int = 0
    audio_dim: int = 0

    # --- numerics / runtime ---
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    # §Perf hillclimb knobs (EXPERIMENTS.md): baseline = all off
    opt_collectives: bool = False   # RS residual boundaries + bf16 AG points
    moe_bf16_dispatch: bool = False  # bf16 dispatch/combine one-hot einsums
    tp_mode: str = "megatron"        # megatron | ulysses | megatron_rs
    moe_ep: bool = False             # expert parallelism: experts over tp
    kv_cache_dtype: str = "model"    # model (= cfg.dtype) | int8 (quantized)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, f, V = self.d_model, self.d_ff, self.vocab_size
        hd, H, K = self.hd, self.n_heads, self.n_kv_heads
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di, st = self.d_inner, self.ssm_state
            per = d * (2 * di + 2 * self.ssm_groups * st + self.ssm_heads)
            per += di * d + 2 * d  # out proj + norms
            return emb + self.n_layers * per
        attn = d * hd * (H + 2 * K) + H * hd * d
        if self.qkv_bias:
            attn += hd * (H + 2 * K)
        if self.mlp_type == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.n_experts:
            mlp = mlp * self.n_experts + d * self.n_experts
        per = attn + mlp + 2 * d
        n_attn_layers = self.n_layers
        if self.family == "hybrid":
            n_rec = self.n_layers - self.n_layers // (self.attn_every or 3)
            lw = self.lru_width or d
            rec = d * lw * 3 + lw * d + 4 * lw  # gate+x+out projections + lru
            n_att = self.n_layers - n_rec
            return emb + n_att * per + n_rec * (rec + mlp + 2 * d)
        total = emb + n_attn_layers * per
        if self.family == "vlm" and self.cross_every:
            n_cross = self.n_layers // self.cross_every
            cross = d * hd * (H + 2 * K) + H * hd * d + 2 * d
            total += n_cross * cross + self.vision_dim * d
        if self.family == "encdec":
            enc_per = attn + mlp + 2 * d
            cross = d * hd * (H + 2 * K) + H * hd * d + d
            total += self.encoder_layers * enc_per + self.n_layers * cross
            total += self.audio_dim * d
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_mlp = (3 if self.mlp_type == "swiglu" else 2) * d * f
        inactive = (self.n_experts - self.experts_per_token) * dense_mlp
        return self.param_count() - self.n_layers * inactive

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
