"""Sharded, atomic, resharding-aware checkpointing.

Layout:  <dir>/step_<N>/
           manifest.json       — leaf paths, shapes, dtypes, crc32s
           <leaf-path>.npy     — one array per pytree leaf

Writes go to ``step_<N>.tmp`` and are atomically renamed, so a crash during
save never corrupts the latest checkpoint — the supervisor always restarts
from the newest *complete* step directory.

Restore takes a *target* pytree (for structure + shardings): leaves are
loaded from disk and ``device_put`` with the target's sharding, so a
checkpoint written on one mesh restores onto a different mesh / device
count (elastic scaling).  ``AsyncCheckpointer`` overlaps serialization with
the next training step (one background thread, latest-wins queue).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "__".join(parts) if parts else "leaf"


def save_checkpoint(tree: Any, directory: str, step: int) -> str:
    """Atomic synchronous save; returns the final directory."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "leaves": []}
    for path, leaf in leaves:
        name = _leaf_name(path)
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"].append(
            {
                "name": name,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(arr.tobytes()),
            }
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for d in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d+)", d))
    ]
    return max(steps) if steps else None


def load_checkpoint_raw(directory: str, step: Optional[int] = None,
                        names=None) -> dict[str, np.ndarray]:
    """Load a checkpoint as a flat ``{leaf-name: array}`` dict, no target.

    :func:`restore_checkpoint` needs a template pytree for structure and
    shardings; consumers that own their state layout (the streaming greedy
    driver's resume path) can instead read the manifest directly.  CRCs are
    verified; arrays come back as host numpy.  ``names`` (optional set)
    restricts loading to those leaves — untouched leaves pay no I/O.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    out = {}
    for meta in manifest["leaves"]:
        if names is not None and meta["name"] not in names:
            continue
        arr = np.load(os.path.join(d, meta["name"] + ".npy"))
        if zlib.crc32(arr.tobytes()) != meta["crc32"]:
            raise IOError(f"crc mismatch for {meta['name']}")
        out[meta["name"]] = arr
    return out


def restore_checkpoint(target: Any, directory: str,
                       step: Optional[int] = None) -> Any:
    """Load into the structure/shardings of ``target`` (reshard-on-restore)."""
    paths_leaves = jax.tree_util.tree_flatten_with_path(target)[0]
    treedef = jax.tree_util.tree_structure(target)
    wanted = {_leaf_name(path) for path, _ in paths_leaves}
    by_name = load_checkpoint_raw(directory, step, names=wanted)
    out = []
    for path, leaf in paths_leaves:
        name = _leaf_name(path)
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = by_name[name]
        if hasattr(leaf, "sharding") and leaf.sharding is not None:
            out.append(jax.device_put(arr, leaf.sharding))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """One-slot async writer: save() enqueues, latest snapshot wins."""

    def __init__(self, directory: str):
        self.directory = directory
        self._lock = threading.Lock()
        self._pending = None
        self._thread = None
        self.last_saved: Optional[int] = None

    def save(self, tree: Any, step: int):
        # Snapshot to host synchronously (cheap vs. serialization) so the
        # training step can donate/overwrite device buffers immediately.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        with self._lock:
            self._pending = (host_tree, step)
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(target=self._drain, daemon=True)
                self._thread.start()

    def _drain(self):
        while True:
            with self._lock:
                if self._pending is None:
                    return
                tree, step = self._pending
                self._pending = None
            save_checkpoint(tree, self.directory, step)
            self.last_saved = step

    def wait(self):
        t = self._thread
        if t is not None:
            t.join()
