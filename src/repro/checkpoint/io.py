"""Sharded, atomic, resharding-aware checkpointing.

Layout:  <dir>/step_<N>/
           manifest.json       — leaf paths, shapes, dtypes, crc32s
           <leaf-path>.npy     — one array per pytree leaf

Writes go to ``step_<N>.tmp`` and are atomically renamed, so a crash during
save never corrupts the latest checkpoint — the supervisor always restarts
from the newest *complete* step directory.

Restore takes a *target* pytree (for structure + shardings): leaves are
loaded from disk and ``device_put`` with the target's sharding, so a
checkpoint written on one mesh restores onto a different mesh / device
count (elastic scaling).  ``AsyncCheckpointer`` overlaps serialization with
the next training step (one background thread, latest-wins queue).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _fault_once(kind: str) -> bool:
    """True if the env-keyed fault ``kind`` should fire now.

    ``REPRO_FAULT_ONCE=<path>`` arms at-most-once semantics across process
    restarts: the first firing creates ``<path>.<kind>`` and later calls see
    it and stay quiet — so a supervised relaunch is not re-injured by the
    same fault.  Without the marker the fault fires on every save.
    """
    marker = os.environ.get("REPRO_FAULT_ONCE")
    if not marker:
        return True
    marker = f"{marker}.{kind}"
    if os.path.exists(marker):
        return False
    with open(marker, "w") as f:
        f.write(kind)
    return True


def _inject_post_save_faults(final: str, manifest: dict) -> None:
    """Env-keyed corruption faults, applied AFTER the atomic rename.

    These simulate silent disk corruption of an already-committed step (bit
    rot, torn write on a non-atomic filesystem):

      REPRO_FAULT_CORRUPT_LEAF=<name|any>  flip a byte in that leaf's .npy
      REPRO_FAULT_TRUNCATE_MANIFEST=1      cut manifest.json in half

    Both honor REPRO_FAULT_ONCE (see :func:`_fault_once`).  Test-only.
    """
    leaf = os.environ.get("REPRO_FAULT_CORRUPT_LEAF")
    if leaf and _fault_once("corrupt_leaf"):
        names = [m["name"] for m in manifest["leaves"]]
        victim = names[0] if leaf == "any" else leaf
        if victim in names:
            p = os.path.join(final, victim + ".npy")
            with open(p, "r+b") as f:
                f.seek(max(os.path.getsize(p) - 1, 0))
                b = f.read(1)
                f.seek(max(os.path.getsize(p) - 1, 0))
                f.write(bytes([b[0] ^ 0xFF]) if b else b"\xff")
    if os.environ.get("REPRO_FAULT_TRUNCATE_MANIFEST") and \
            _fault_once("truncate_manifest"):
        p = os.path.join(final, "manifest.json")
        with open(p, "r+b") as f:
            f.truncate(max(os.path.getsize(p) // 2, 1))


def _gc_orphan_tmps(directory: str, min_age_s: float = 0.0) -> None:
    """Remove ``step_*.tmp`` dirs left behind by a crash mid-save.

    ``min_age_s`` guards the scan-time path (:func:`latest_step`) against
    racing a concurrent in-flight save from another process: only tmps
    whose mtime is older than the threshold are collected.
    """
    if not os.path.isdir(directory):
        return
    now = time.time()
    for d in os.listdir(directory):
        if not re.fullmatch(r"step_\d+\.tmp", d):
            continue
        p = os.path.join(directory, d)
        try:
            if min_age_s and now - os.path.getmtime(p) < min_age_s:
                continue
            shutil.rmtree(p)
        except OSError:
            pass


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "__".join(parts) if parts else "leaf"


def save_checkpoint(tree: Any, directory: str, step: int,
                    meta: Optional[dict] = None) -> str:
    """Atomic synchronous save; returns the final directory.

    ``meta`` (JSON-serializable dict) is merged into the manifest under the
    ``"meta"`` key — callers use it to tag a step (e.g. the artifact layer's
    ``{"final": true}`` commit marker) without adding pytree leaves.  Any
    orphaned ``step_*.tmp`` left by an earlier crash is collected first.
    """
    _gc_orphan_tmps(directory)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "leaves": []}
    if meta:
        manifest["meta"] = dict(meta)
    for path, leaf in leaves:
        name = _leaf_name(path)
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"].append(
            {
                "name": name,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(arr.tobytes()),
            }
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # Env-keyed crash fault: die with the step fully written but NOT yet
    # renamed — the exact window an atomic-commit bug would corrupt.  Fires
    # only on finalize saves (meta final=True) so build checkpoints in the
    # same process are unaffected; honors REPRO_FAULT_ONCE.  Test-only.
    if (os.environ.get("REPRO_FAULT_KILL_AT_FINALIZE")
            and meta and meta.get("final")
            and _fault_once("kill_at_finalize")):
        os._exit(int(os.environ.get("REPRO_FAULT_EXIT_CODE", "42")))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _inject_post_save_faults(final, manifest)
    return final


def list_steps(directory: str) -> list[int]:
    """All complete step numbers in ``directory``, ascending."""
    if not os.path.isdir(directory):
        return []
    return sorted(
        int(m.group(1))
        for d in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d+)", d))
    )


def latest_step(directory: str) -> Optional[int]:
    # Opportunistic GC of crash orphans; age-gated so a concurrent
    # in-flight save from another process is never swept.
    _gc_orphan_tmps(directory, min_age_s=3600.0)
    steps = list_steps(directory)
    return max(steps) if steps else None


def prune_steps(directory: str, keep: int) -> None:
    """Delete all but the newest ``keep`` complete steps (best-effort)."""
    steps = list_steps(directory)
    for s in steps[:-keep] if keep > 0 else steps:
        try:
            shutil.rmtree(os.path.join(directory, f"step_{s:08d}"))
        except OSError:
            pass


def load_manifest(directory: str, step: int) -> dict:
    """Read a step's manifest.json (raises with the offending path)."""
    p = os.path.join(directory, f"step_{step:08d}", "manifest.json")
    try:
        with open(p) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        raise IOError(f"unreadable manifest {p}: {e}") from e


def _load_step_verified(directory: str, step: int,
                        names=None) -> dict[str, np.ndarray]:
    d = os.path.join(directory, f"step_{step:08d}")
    manifest = load_manifest(directory, step)
    out = {}
    for meta in manifest["leaves"]:
        if names is not None and meta["name"] not in names:
            continue
        p = os.path.join(d, meta["name"] + ".npy")
        try:
            arr = np.load(p)
        except (OSError, ValueError) as e:
            raise IOError(f"unreadable leaf {p}: {e}") from e
        if zlib.crc32(arr.tobytes()) != meta["crc32"]:
            raise IOError(f"crc mismatch for {meta['name']} in {p}")
        out[meta["name"]] = arr
    return out


def load_checkpoint_raw(directory: str, step: Optional[int] = None,
                        names=None) -> dict[str, np.ndarray]:
    """Load a checkpoint as a flat ``{leaf-name: array}`` dict, no target.

    :func:`restore_checkpoint` needs a template pytree for structure and
    shardings; consumers that own their state layout (the streaming greedy
    driver's resume path) can instead read the manifest directly.  CRCs are
    verified; arrays come back as host numpy.  ``names`` (optional set)
    restricts loading to those leaves — untouched leaves pay no I/O.

    With ``step=None`` (newest), a corrupt or truncated step — CRC
    mismatch, unreadable leaf, or unreadable manifest — is *skipped* and
    the scan falls back to the next-newest intact step, so one bad step
    never strands an otherwise resumable run.  An explicitly requested
    ``step`` is loaded verbatim: corruption raises, with the offending
    file path in the message.
    """
    if step is not None:
        return _load_step_verified(directory, step, names=names)
    steps = list_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    errors = []
    for s in reversed(steps):
        try:
            return _load_step_verified(directory, s, names=names)
        except (IOError, KeyError) as e:
            errors.append(str(e))
    raise IOError(
        f"no intact checkpoint in {directory}; tried steps "
        f"{list(reversed(steps))}: " + "; ".join(errors))


def restore_checkpoint(target: Any, directory: str,
                       step: Optional[int] = None) -> Any:
    """Load into the structure/shardings of ``target`` (reshard-on-restore)."""
    paths_leaves = jax.tree_util.tree_flatten_with_path(target)[0]
    treedef = jax.tree_util.tree_structure(target)
    wanted = {_leaf_name(path) for path, _ in paths_leaves}
    by_name = load_checkpoint_raw(directory, step, names=wanted)
    out = []
    for path, leaf in paths_leaves:
        name = _leaf_name(path)
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = by_name[name]
        if hasattr(leaf, "sharding") and leaf.sharding is not None:
            out.append(jax.device_put(arr, leaf.sharding))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """One-slot async writer: save() enqueues, latest snapshot wins."""

    def __init__(self, directory: str):
        self.directory = directory
        self._lock = threading.Lock()
        self._pending = None
        self._thread = None
        self.last_saved: Optional[int] = None

    def save(self, tree: Any, step: int):
        # Snapshot to host synchronously (cheap vs. serialization) so the
        # training step can donate/overwrite device buffers immediately.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        with self._lock:
            self._pending = (host_tree, step)
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(target=self._drain, daemon=True)
                self._thread.start()

    def _drain(self):
        while True:
            with self._lock:
                if self._pending is None:
                    return
                tree, step = self._pending
                self._pending = None
            save_checkpoint(tree, self.directory, step)
            self.last_saved = step

    def wait(self):
        t = self._thread
        if t is not None:
            t.join()
