from repro.checkpoint.io import (
    save_checkpoint, restore_checkpoint, load_checkpoint_raw, latest_step,
    list_steps, load_manifest, prune_steps, AsyncCheckpointer,
)

__all__ = [
    "save_checkpoint", "restore_checkpoint", "load_checkpoint_raw",
    "latest_step", "list_steps", "load_manifest", "prune_steps",
    "AsyncCheckpointer",
]
