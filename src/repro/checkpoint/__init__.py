from repro.checkpoint.io import (
    save_checkpoint, restore_checkpoint, load_checkpoint_raw, latest_step,
    AsyncCheckpointer,
)

__all__ = [
    "save_checkpoint", "restore_checkpoint", "load_checkpoint_raw",
    "latest_step", "AsyncCheckpointer",
]
