"""Snapshot providers: column-tile access to matrices of unbounded M.

The paper's headline run greedy-reduces a dense complex 10,000 x 3,276,800
snapshot matrix (~0.5 TB) that never fits in one worker's memory
(Sec. 6.1.1: each MPI process forms a "slice" of S over a subset of
columns).  A :class:`SnapshotProvider` is the single-machine analogue of
that contract: the streaming drivers (:func:`repro.core.streaming.
rb_greedy_streamed` and the one-pass range-finder :func:`repro.core.
randomized.rb_randomized_streamed`) only ever ask for one column *tile*
``S[:, lo:hi]`` at a time, so peak device memory is
O(N * (max_k + tile_m)) regardless of M.  ``FaultyProvider.reads`` is the
acceptance hook for pass-count claims: the randomized sketch must touch
each tile exactly ``1 + 2*power`` times.

Three implementations:

- :class:`ArrayProvider`   — a resident array (the trivial case; used by
  the parity tests to prove the streamed driver is an exact refactor of
  the in-memory one).
- :class:`MemmapProvider`  — a memory-mapped ``.npy`` file; a tile
  materializes only its own columns.  Write snapshots column-major
  (:func:`write_snapshot_npy` with ``fortran_order=True``, the default)
  so a column tile is one contiguous read.
- :class:`WaveformProvider` — generates GW snapshot columns on the fly
  from :mod:`repro.gw.waveform` over a parameter grid
  (:mod:`repro.gw.grids`); the snapshot matrix is never materialized
  anywhere, matching greedycpp's generate-your-slice strategy.
"""

from __future__ import annotations

import abc
import os
import time
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _read_with_retry(fn, what: str):
    """Run an I/O-backed read with bounded retry + exponential backoff.

    Shared storage (NFS, object-store FUSE mounts) throws transient
    ``IOError``s under load; a multi-hour streamed build should not die on
    one.  Retries ``REPRO_IO_RETRIES`` times (default 3) with backoff
    ``REPRO_IO_RETRY_BASE_S * 2**attempt`` (default base 0.05 s); the last
    failure re-raises with ``what`` and the attempt count in the message
    so the supervisor log shows *which* tile read was the casualty.
    """
    retries = int(os.environ.get("REPRO_IO_RETRIES", "3"))
    base = float(os.environ.get("REPRO_IO_RETRY_BASE_S", "0.05"))
    for attempt in range(retries + 1):
        try:
            return fn()
        except (IOError, OSError) as e:
            if attempt >= retries:
                raise IOError(
                    f"{what} failed after {retries + 1} attempts: {e}"
                ) from e
            time.sleep(base * (2.0 ** attempt))


class SnapshotProvider(abc.ABC):
    """Column-tile access to an (N, M) snapshot matrix.

    Implementations supply :attr:`shape`, :attr:`dtype` and :meth:`tile`;
    everything else has default implementations in terms of those.  A tile
    request must be cheap in memory: O(N * (hi - lo)), never O(N * M).
    """

    @property
    @abc.abstractmethod
    def shape(self) -> tuple[int, int]:
        """(N, M): rows (physical dimension) x columns (parameter values)."""

    @property
    @abc.abstractmethod
    def dtype(self):
        """Element dtype of the snapshot matrix (numpy/jax dtype)."""

    @abc.abstractmethod
    def tile(self, lo: int, hi: int) -> jax.Array:
        """Return columns [lo, hi) as an (N, hi - lo) device array."""

    def column(self, j: int) -> jax.Array:
        """One snapshot column (N,).  Default: a width-1 tile."""
        return self.tile(j, j + 1)[:, 0]

    def tiles(self, tile_m: int) -> Iterator[tuple[int, int]]:
        """Tile boundaries [lo, hi) covering all M columns in order."""
        M = self.shape[1]
        for lo in range(0, M, tile_m):
            yield lo, min(lo + tile_m, M)

    def materialize(self) -> jax.Array:
        """The full matrix as ONE tile — small providers / tests only."""
        return self.tile(0, self.shape[1])


class ArrayProvider(SnapshotProvider):
    """A resident (N, M) array behind the provider interface.

    Host (numpy) arrays are kept host-resident: each tile is device_put
    separately, so streaming a big host matrix never places all of it on
    device (and the ``"auto"`` strategy can probe shape/dtype without a
    transfer).  Device arrays pass through and tiles are device slices.
    """

    def __init__(self, S):
        self._S = S if isinstance(S, (jax.Array, np.ndarray)) \
            else jnp.asarray(S)
        if self._S.ndim != 2:
            raise ValueError(f"expected a 2-D snapshot matrix, got shape "
                             f"{self._S.shape}")

    @property
    def shape(self) -> tuple[int, int]:
        return tuple(self._S.shape)

    @property
    def dtype(self):
        return self._S.dtype

    def tile(self, lo: int, hi: int) -> jax.Array:
        if isinstance(self._S, np.ndarray):
            return jax.device_put(self._S[:, lo:hi])
        return self._S[:, lo:hi]


class MemmapProvider(SnapshotProvider):
    """A memory-mapped ``.npy`` snapshot matrix on disk.

    Only the requested columns of a tile are read (and copied to device);
    the file itself can exceed host memory.  Column-major files
    (``fortran_order=True`` in the npy header — what
    :func:`write_snapshot_npy` emits by default) give contiguous tile
    reads; row-major files still work but each tile read is strided.
    """

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)
        self._mm = _read_with_retry(
            lambda: np.load(self.path, mmap_mode="r"),
            f"open {self.path}")
        if self._mm.ndim != 2:
            raise ValueError(
                f"{self.path}: expected a 2-D snapshot matrix, got shape "
                f"{self._mm.shape}"
            )

    @property
    def shape(self) -> tuple[int, int]:
        return tuple(self._mm.shape)

    @property
    def dtype(self):
        return self._mm.dtype

    def tile(self, lo: int, hi: int) -> jax.Array:
        # np.asarray materializes ONLY the requested columns on host; the
        # async jax.device_put lets the streaming driver prefetch the next
        # tile while the current tile's sweep runs.  The memmap stays lazy.
        # The page-in is where a flaky filesystem actually faults, so it
        # runs under the bounded-retry wrapper.
        return jax.device_put(_read_with_retry(
            lambda: np.asarray(self._mm[:, lo:hi]),
            f"read {self.path}[:, {lo}:{hi}]"))


class WaveformProvider(SnapshotProvider):
    """On-the-fly GW snapshot tiles: columns are TaylorF2 waveforms.

    Wraps :func:`repro.gw.waveform.taylorf2` over a parameter grid from
    :mod:`repro.gw.grids`; ``tile(lo, hi)`` jit-generates the waveforms
    for parameters [lo, hi) directly on device, so the snapshot matrix is
    never materialized on host OR device — the enabling trick for the
    paper's "matrix too large to load into memory" regime.
    """

    def __init__(self, f, m1s, m2s, dtype=jnp.complex64,
                 normalize: bool = True):
        from repro.gw.waveform import taylorf2_batch

        self._f = jnp.asarray(f)
        self._m1 = np.asarray(m1s)
        self._m2 = np.asarray(m2s)
        if self._m1.shape != self._m2.shape or self._m1.ndim != 1:
            raise ValueError("m1s/m2s must be equal-length 1-D arrays")
        self._dtype = jnp.dtype(dtype)
        # One jit cache entry per distinct tile width (at most two with
        # fixed boundaries: the full width and the ragged last tile).
        self._gen = jax.jit(
            lambda a, b: taylorf2_batch(
                self._f, a, b, normalize=normalize, dtype=self._dtype
            )
        )

    @property
    def shape(self) -> tuple[int, int]:
        return (self._f.shape[0], self._m1.shape[0])

    @property
    def dtype(self):
        return self._dtype

    def tile(self, lo: int, hi: int) -> jax.Array:
        # Generation itself is pure compute, but the parameter grids may be
        # memmap-backed (np.load(mmap_mode=...) arrays pass np.asarray
        # checks), so the host gather goes through the retry wrapper too.
        m1, m2 = _read_with_retry(
            lambda: (np.array(self._m1[lo:hi]), np.array(self._m2[lo:hi])),
            f"read waveform params [{lo}:{hi})")
        return self._gen(jnp.asarray(m1), jnp.asarray(m2))


@dataclass(frozen=True)
class FaultPlan:
    """What to break, and when — the fault-injection schedule.

    Counted in provider *tile reads* (0-based), the unit of forward
    progress in a streamed build:

    - ``kill_at_tile``:    ``os._exit`` the process on that read — the
      harness's stand-in for OOM-kills / preemption at an arbitrary point.
    - ``raise_at_tile``:   raise a hard ``IOError`` on that read (survives
      retry; the build dies with a diagnosable error).
    - ``transient_every``: every n-th read raises ``IOError`` once, then
      succeeds — exercises the bounded-retry path, the build completes.

    ``from_env`` builds the plan from ``REPRO_FAULT_KILL_AT_TILE``,
    ``REPRO_FAULT_RAISE_AT_TILE``, ``REPRO_FAULT_TRANSIENT_EVERY`` (and
    ``REPRO_FAULT_EXIT_CODE``), so a supervised subprocess can be injured
    without any code changes.  One-shot faults honor ``REPRO_FAULT_ONCE``
    (see :mod:`repro.checkpoint.io`): after a supervised restart the same
    kill does not fire again — exactly a real crash's shape.
    """

    kill_at_tile: Optional[int] = None
    raise_at_tile: Optional[int] = None
    transient_every: Optional[int] = None
    exit_code: int = 42

    @classmethod
    def from_env(cls) -> "FaultPlan":
        def geti(name):
            v = os.environ.get(name)
            return int(v) if v else None

        return cls(
            kill_at_tile=geti("REPRO_FAULT_KILL_AT_TILE"),
            raise_at_tile=geti("REPRO_FAULT_RAISE_AT_TILE"),
            transient_every=geti("REPRO_FAULT_TRANSIENT_EVERY"),
            exit_code=geti("REPRO_FAULT_EXIT_CODE") or 42,
        )

    def active(self) -> bool:
        return any(v is not None for v in
                   (self.kill_at_tile, self.raise_at_tile,
                    self.transient_every))


class FaultyProvider(SnapshotProvider):
    """Fault-injecting wrapper around any :class:`SnapshotProvider`.

    Transparent (shape/dtype/tiles delegate) until the :class:`FaultPlan`
    says otherwise.  Counts tile reads across its lifetime in ``reads``;
    the count is per-process, so a resumed run's counter restarts at 0 —
    pair one-shot faults with ``REPRO_FAULT_ONCE`` to keep the relaunch
    unharmed.
    """

    def __init__(self, inner: SnapshotProvider,
                 plan: Optional[FaultPlan] = None):
        self.inner = inner
        self.plan = plan if plan is not None else FaultPlan.from_env()
        self.reads = 0

    @property
    def shape(self) -> tuple[int, int]:
        return self.inner.shape

    @property
    def dtype(self):
        return self.inner.dtype

    def tile(self, lo: int, hi: int) -> jax.Array:
        from repro.checkpoint.io import _fault_once

        plan, n = self.plan, self.reads
        self.reads += 1
        if (plan.kill_at_tile is not None and n >= plan.kill_at_tile
                and _fault_once("kill_at_tile")):
            os._exit(plan.exit_code)
        if (plan.raise_at_tile is not None and n >= plan.raise_at_tile
                and _fault_once("raise_at_tile")):
            raise IOError(
                f"injected hard I/O fault at tile read {n} "
                f"(columns [{lo}:{hi}))")
        first = [True]

        def attempt():
            if (plan.transient_every and (n + 1) % plan.transient_every == 0
                    and first[0]):
                first[0] = False
                raise IOError(
                    f"injected transient I/O fault at tile read {n}")
            return self.inner.tile(lo, hi)

        return _read_with_retry(attempt, f"tile [{lo}:{hi})")


def write_snapshot_npy(path: str | os.PathLike, S,
                       fortran_order: bool = True) -> str:
    """Write a snapshot matrix as ``.npy`` for :class:`MemmapProvider`.

    ``fortran_order=True`` stores columns contiguously, so a streamed
    column tile is one sequential read instead of N strided ones.
    """
    path = os.fspath(path)
    if not path.endswith(".npy"):
        path += ".npy"  # np.save appends it; return the real file name
    arr = np.asarray(S)
    np.save(path, np.asfortranarray(arr) if fortran_order
            else np.ascontiguousarray(arr))
    return path


def create_snapshot_npy(path: str | os.PathLike, shape: tuple[int, int],
                        dtype, fortran_order: bool = True) -> np.memmap:
    """Create an empty on-disk ``.npy`` to be filled tile by tile.

    Returns a writable memmap; fill ``mm[:, lo:hi]`` per tile (and
    ``mm.flush()`` when done) to build matrices larger than host memory.
    """
    return np.lib.format.open_memmap(
        os.fspath(path), mode="w+", dtype=np.dtype(dtype), shape=shape,
        fortran_order=fortran_order,
    )


def as_provider(source) -> SnapshotProvider:
    """Coerce an array / ``.npy`` path / provider into a provider.

    When ``REPRO_FAULT_*`` env vars arm a :class:`FaultPlan`, the provider
    comes back wrapped in a :class:`FaultyProvider` — the hook the
    fault-injection harness uses to injure a supervised subprocess from
    the outside.  Already-wrapped providers are never double-wrapped.
    """
    if isinstance(source, SnapshotProvider):
        prov = source
    elif isinstance(source, (str, os.PathLike)):
        prov = MemmapProvider(source)
    else:
        prov = ArrayProvider(source)
    if not isinstance(prov, FaultyProvider):
        plan = FaultPlan.from_env()
        if plan.active():
            prov = FaultyProvider(prov, plan)
    return prov


def materialize_source(source) -> jax.Array:
    """Coerce anything :func:`as_provider` accepts into a resident matrix.

    The in-memory drivers (``rb_greedy``, ``mgs_pivoted_qr``, ``pod``, ...)
    call this so the same ``source=`` value works across every strategy:
    a provider or ``.npy`` path is materialized as ONE tile — appropriate
    for sources that fit on device; use the streamed driver otherwise.
    Arrays pass through untouched (no copy, shardings preserved).
    """
    if isinstance(source, jax.Array):
        return source
    if isinstance(source, np.ndarray):
        if source.ndim != 2:
            raise ValueError(
                f"expected a 2-D snapshot matrix, got shape {source.shape}"
            )
        return jnp.asarray(source)
    return as_provider(source).materialize()
