"""Deterministic, restart-safe data pipelines.

Both sources are *step-keyed*: batch(step) is a pure function of (seed,
step), so a job restarted from a step-N checkpoint re-reads exactly the
batches N+1, N+2, ... — the property the fault-tolerance supervisor relies
on (DESIGN.md §7).  Batches can be placed with a NamedSharding so each host
only materializes its slice (device_put with sharding).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    """Markov-ish synthetic token stream (learnable but non-trivial).

    Tokens follow x_{t+1} = (a * x_t + b + noise) mod V with per-sequence
    (a, b) drawn from the step-keyed PRNG — a task a small LM visibly
    learns within a few hundred steps (used by the end-to-end example).
    """

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    sharding: Optional[jax.sharding.NamedSharding] = None

    def batch(self, step: int) -> dict:
        key = jax.random.key(
            np.uint32(self.seed) * np.uint32(2654435761) + np.uint32(step)
        )
        ka, kb, kx, kn = jax.random.split(key, 4)
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        a = jax.random.randint(ka, (B, 1), 1, 8)
        b = jax.random.randint(kb, (B, 1), 0, V)
        x0 = jax.random.randint(kx, (B, 1), 0, V)
        steps = jnp.arange(S + 1)[None, :]
        # closed form of the affine recurrence mod V (noise-free core)
        toks = (x0 * jnp.power(a, steps) + b * steps) % V
        noise = jax.random.bernoulli(kn, 0.05, (B, S + 1))
        rand = jax.random.randint(kn, (B, S + 1), 0, V)
        toks = jnp.where(noise, rand, toks).astype(jnp.int32)
        out = {"tokens": toks[:, :S], "labels": toks[:, 1:]}
        if self.sharding is not None:
            out = {k: jax.device_put(v, self.sharding) for k, v in out.items()}
        return out


@dataclasses.dataclass
class FileLMData:
    """Memory-mapped token-file source (np.int32 flat stream).

    Deterministic strided reads keyed by step; wraps around the file.
    """

    path: str
    seq_len: int
    global_batch: int
    seed: int = 0
    sharding: Optional[jax.sharding.NamedSharding] = None

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")

    def batch(self, step: int) -> dict:
        B, S = self.global_batch, self.seq_len
        n = len(self._data)
        rng = np.random.default_rng(self.seed + step)
        starts = rng.integers(0, max(n - S - 1, 1), size=B)
        toks = np.stack([self._data[s:s + S + 1] for s in starts])
        out = {
            "tokens": jnp.asarray(toks[:, :S]),
            "labels": jnp.asarray(toks[:, 1:]),
        }
        if self.sharding is not None:
            out = {k: jax.device_put(v, self.sharding) for k, v in out.items()}
        return out
