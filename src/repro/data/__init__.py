from repro.data.bands import BandSplit, band_split
from repro.data.pipeline import SyntheticLMData, FileLMData
from repro.data.providers import (
    SnapshotProvider,
    ArrayProvider,
    FaultPlan,
    FaultyProvider,
    MemmapProvider,
    WaveformProvider,
    as_provider,
    create_snapshot_npy,
    materialize_source,
    write_snapshot_npy,
)

__all__ = [
    "BandSplit", "band_split",
    "SyntheticLMData", "FileLMData",
    "SnapshotProvider", "ArrayProvider", "FaultPlan", "FaultyProvider",
    "MemmapProvider", "WaveformProvider", "as_provider",
    "create_snapshot_npy", "materialize_source", "write_snapshot_npy",
]
