from repro.data.pipeline import SyntheticLMData, FileLMData

__all__ = ["SyntheticLMData", "FileLMData"]
