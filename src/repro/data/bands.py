"""Frequency-band workload splitting for batched many-basis builds.

The pyNekTools-style banded reduction: FFT the sample axis of one
snapshot matrix, slice the spectrum into B contiguous bands, and reduce
each band with its own basis.  A narrow band's waveform family is far
smoother than the broadband signal, so per-band bases are much smaller
than one global basis at equal tau — and the B band matrices share one
(N_b, M) shape, which is exactly the stacked workload
``strategy="batched"`` builds in one lockstep pass
(:mod:`repro.core.batch_greedy`).  The per-band artifacts register
directly with the serving router (one route per band; see
``examples/banded_bases.py``).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class BandSplit(NamedTuple):
    """A banded snapshot workload (the output of :func:`band_split`).

    Attributes:
      stack: (B, N_b, M) complex array — band b's spectrum rows for every
        snapshot column; feed it to ``build_basis(source=split.stack,
        strategy="batched")`` (or any (B, N, M)-accepting driver).
      edges: tuple of (lo, hi) frequency-bin index pairs, one per band —
        band b covers spectrum rows ``lo <= r < hi`` of the full FFT.
      n_freq: total number of frequency bins the FFT produced (before
        any truncation to equal band heights).
      from_real: True when the input was real (rFFT one-sided spectrum).
    """

    stack: jax.Array
    edges: tuple
    n_freq: int
    from_real: bool

    @property
    def batch(self) -> int:
        return int(self.stack.shape[0])


def band_split(source: Any, bands: int) -> BandSplit:
    """FFT the sample axis and split the spectrum into ``bands`` equal bands.

    Args:
      source: the snapshot matrix — anything
        :func:`repro.data.providers.materialize_source` accepts, shaped
        (N, M) with snapshots in columns.  Real input takes the one-sided
        rFFT (N//2 + 1 bins); complex input the full FFT (N bins).
      bands: number of equal-height bands B (>= 1).  The topmost
        ``n_freq % bands`` bins are dropped so every band has the same
        height — the lockstep driver needs one shared (N_b, M) shape (the
        discarded remainder is the extreme high-frequency tail; widen N
        or pick a divisor of ``n_freq`` to keep it).

    Returns a :class:`BandSplit`; ``.stack`` is (B, n_freq // B, M).
    """
    from repro.data.providers import materialize_source

    if bands < 1:
        raise ValueError(f"bands must be >= 1, got {bands}")
    S = materialize_source(source)
    if S.ndim != 2:
        raise ValueError(f"band_split needs an (N, M) source, got {S.shape}")
    from_real = not jnp.iscomplexobj(S)
    F = jnp.fft.rfft(S, axis=0) if from_real else jnp.fft.fft(S, axis=0)
    n_freq = int(F.shape[0])
    height = n_freq // bands
    if height < 1:
        raise ValueError(
            f"{bands} bands from {n_freq} frequency bins leaves empty "
            f"bands")
    edges = tuple((b * height, (b + 1) * height) for b in range(bands))
    stack = F[: bands * height].reshape(bands, height, F.shape[1])
    return BandSplit(stack=stack, edges=edges, n_freq=n_freq,
                     from_real=from_real)
