"""Batched serving engine: prefill + jitted decode loop.

The engine batches requests (left-padding-free: equal-length prompt slabs;
production continuous batching composes request slabs per step), prefills
once, and steps the jitted decode function.  ``serve_step`` is exactly what
the decode_* dry-run cells lower: one token through the model with a full
KV cache.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import api


class ServeEngine:
    def __init__(self, cfg, params, max_len: int):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            functools.partial(api.prefill, cfg, max_len=max_len)
        )
        self._decode = jax.jit(functools.partial(api.decode_step, cfg))

    def generate(
        self,
        batch: dict,
        n_tokens: int,
        temperature: float = 0.0,
        key: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Greedy (or sampled) continuation of the prompt batch.

        Returns (B, n_tokens) int32 generated token ids.
        """
        logits, cache = self._prefill(self.params, batch)
        B = logits.shape[0]
        toks = []
        # The per-step key is derived ONCE per step as fold_in(key, step)
        # inside _select; the base key is never advanced here.  (Folding
        # it in this loop as well compounded the folds — steps drew from
        # correlated, index-colliding streams.)
        tok = self._select(logits, temperature, key, 0)
        for i in range(n_tokens):
            toks.append(tok)
            logits, cache = self._decode(self.params, tok, cache)
            tok = self._select(logits, temperature, key, i + 1)
        return jnp.stack(toks, axis=1)

    @staticmethod
    def _select(logits, temperature, key, i):
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            jax.random.fold_in(key, i), logits / temperature
        ).astype(jnp.int32)
