from repro.serving.engine import ServeEngine
from repro.serving.metrics import ServingMetrics
from repro.serving.roq import (
    EngineClosedError,
    InterpolantCache,
    QueueFullError,
    ROQEngine,
    batch_bucket,
    direct_interpolate,
)
from repro.serving.router import BasisRouter

__all__ = [
    "ServeEngine",
    "ROQEngine",
    "BasisRouter",
    "ServingMetrics",
    "InterpolantCache",
    "QueueFullError",
    "EngineClosedError",
    "batch_bucket",
    "direct_interpolate",
]
