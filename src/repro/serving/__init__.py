from repro.serving.admission import (
    AdmissionController,
    CircuitBreakerBoard,
    CircuitOpenError,
    QuotaExceededError,
    ShedError,
    TokenBucket,
)
from repro.serving.engine import ServeEngine
from repro.serving.health import (
    EngineUnhealthyError,
    HealthState,
    RestartPolicy,
    RestartTracker,
)
from repro.serving.metrics import ServingMetrics
from repro.serving.roq import (
    EngineClosedError,
    InterpolantCache,
    QueueFullError,
    ROQEngine,
    batch_bucket,
    direct_interpolate,
)
from repro.serving.router import BasisRouter

__all__ = [
    "ServeEngine",
    "ROQEngine",
    "BasisRouter",
    "ServingMetrics",
    "InterpolantCache",
    "QueueFullError",
    "EngineClosedError",
    "EngineUnhealthyError",
    "ShedError",
    "QuotaExceededError",
    "CircuitOpenError",
    "AdmissionController",
    "CircuitBreakerBoard",
    "TokenBucket",
    "HealthState",
    "RestartPolicy",
    "RestartTracker",
    "batch_bucket",
    "direct_interpolate",
]
