"""Persistent ROQ serving engine: the paper's *online* stage as a service.

The offline stage builds a reduced basis once; the whole point is the
online stage — many cheap queries against it.  A request here is a vector
``f`` known only at the basis's ``k`` EIM nodes; the engine answers with
the full N-sample empirical interpolant ``I_k[f] = B @ f[nodes]`` (Alg. 5
of Ref. [6]).  One :class:`ROQEngine` turns that single GEMV into a
persistent batched service:

- ``submit(basis_id, f_nodes, client_id=...)`` runs the admission
  pipeline — engine health, the basis's circuit breaker, the client's
  token-bucket quota, deadline-aware shedding — then puts the request on
  a BOUNDED queue and returns a ``concurrent.futures.Future``.  Every
  rejection is an explicit, distinct error (:class:`EngineClosedError` /
  :class:`~repro.serving.health.EngineUnhealthyError` /
  :class:`~repro.serving.admission.CircuitOpenError` /
  :class:`~repro.serving.admission.QuotaExceededError` /
  :class:`~repro.serving.admission.ShedError` / :class:`QueueFullError`),
  never silent latency.
- A worker thread forms dynamic per-basis batches under the latency /
  throughput dial: flush at ``max_batch`` requests OR ``max_wait_ms``
  after the oldest pending one, whichever first.  Deadlines are enforced
  while requests WAIT, not only at flush: the poll wakes for the earliest
  pending deadline, so ``timeout_s << max_wait_ms`` still times out
  promptly.
- Batches evaluate through a warm :class:`InterpolantCache` keyed by
  ``(basis_id, generation, batch_bucket, dtype)``: batch widths round up
  to power-of-two buckets so the number of XLA compilations is
  O(log2(max_batch)) per basis; the generation comes from the router and
  lets :meth:`refresh` hot-swap a rebuilt artifact without poisoning
  warm entries (old-generation batches in flight finish correctly, then
  their entries are retired).
- ``basis_id`` routes through a :class:`~repro.serving.router.BasisRouter`
  (multi-artifact working set, LRU under a device-memory budget); router
  evictions drop the matching warm cache entries.
- Per-request timeout and error isolation: a malformed request (wrong
  length, uncastable dtype, unknown basis) fails ALONE via its future;
  its batchmates still serve.  Batch-level failures (injected via
  ``REPRO_FAULT_SERVE_RAISE_AT_BATCH``, PR-6 conventions) fail one
  batch, never the engine — and feed the per-basis circuit breaker, so a
  basis failing ``breaker_threshold`` consecutive batches stops burning
  batch slots until a cooldown probe succeeds.
- The worker runs SUPERVISED: an exception escaping the batching/poll
  logic (simulate with ``REPRO_FAULT_SERVE_KILL_WORKER``) fails every
  pending and queued future with ``EngineUnhealthyError`` — nothing ever
  hangs — flips :meth:`healthy` false, and (per the
  :class:`~repro.serving.health.RestartPolicy`) restarts the worker
  under a sliding restart window with exponential backoff.
- ``close()`` drains: intake stops, everything already accepted is
  served, then the worker exits.  A ``submit`` racing ``close`` can
  never strand its future: both sides re-drain the queue after the
  worker is gone.

Bitwise contract (load-bearing for tests and the multi-basis acceptance
row): padded-bucket evaluation is bit-identical to the unpadded direct
evaluation of the same requests.  Two ingredients make that true: complex
interpolants run as plane-split real GEMMs (the repo-wide convention —
XLA CPU's complex GEMM both differs bitwise under padding and lowers
badly), and every GEMM is kept at width >= 2 (width-1 dots route to a
GEMV with a different accumulation order, so :func:`direct_interpolate`
pads a lone column to 2).  Per-column GEMM results are then independent
of the padded width — asserted across dtypes in tests/test_serving.py.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import logging
import os
import queue
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.admission import AdmissionController, CircuitBreakerBoard
from repro.serving.health import (
    EngineUnhealthyError,
    HealthState,
    RestartPolicy,
    RestartTracker,
)
from repro.serving.metrics import ServingMetrics
from repro.serving.router import BasisRouter

logger = logging.getLogger("repro.serving")


class QueueFullError(RuntimeError):
    """Backpressure: the engine's bounded queue is full; retry or shed."""


class EngineClosedError(RuntimeError):
    """The engine is closed (or closing) and takes no new requests."""


def batch_bucket(n: int) -> int:
    """Padded batch width for a batch of ``n`` requests: the smallest
    power of two >= max(n, 2).  The floor of 2 keeps even a lone request
    on the bitwise-stable GEMM path (see module docstring)."""
    if n < 1:
        raise ValueError(f"batch of {n} requests")
    return 1 << (max(n, 2) - 1).bit_length()


# One jitted apply per arithmetic form, shared by every basis; XLA's trace
# cache keys on shapes/dtypes, so distinct buckets compile once each and
# same-shaped bases share executables.  The explicit InterpolantCache on
# top tracks warmth per (basis_id, generation, bucket, dtype) and owns the
# device-committed interpolant planes.
@jax.jit
def _apply_real(B, F):
    return B @ F


@jax.jit
def _apply_split(Br, Bi, Fr, Fi):
    return Br @ Fr - Bi @ Fi, Br @ Fi + Bi @ Fr


def _eval_planes(planes, Fp: np.ndarray) -> np.ndarray:
    """Evaluate the committed interpolant on a padded (k, bucket) batch."""
    if len(planes) == 1:
        (B,) = planes
        return np.asarray(_apply_real(B, jnp.asarray(Fp)))
    Br, Bi = planes
    re, im = _apply_split(Br, Bi, jnp.asarray(np.ascontiguousarray(Fp.real)),
                          jnp.asarray(np.ascontiguousarray(Fp.imag)))
    out = np.empty((re.shape[0], re.shape[1]), dtype=Fp.dtype)
    out.real = np.asarray(re)
    out.imag = np.asarray(im)
    return out


def _commit_planes(eim_B) -> tuple:
    """Device-commit an interpolant matrix once per routed basis."""
    B = np.asarray(eim_B)
    if np.issubdtype(B.dtype, np.complexfloating):
        return (jnp.asarray(np.ascontiguousarray(B.real)),
                jnp.asarray(np.ascontiguousarray(B.imag)))
    return (jnp.asarray(B),)


def direct_interpolate(eim, F) -> np.ndarray:
    """Reference evaluation: unpadded, unbatched-policy-free ``B @ F``.

    ``F`` is (k,) or (k, b) at the EIM nodes; returns (N,) or (N, b).
    This is "direct per-basis evaluation" in the acceptance sense — the
    engine's padded-bucket path must match it bit for bit.  A single
    column is padded to width 2 to stay on the GEMM path.
    """
    B = np.asarray(eim.B)
    F = np.asarray(F, dtype=B.dtype)
    squeeze = F.ndim == 1
    if squeeze:
        F = F[:, None]
    b = F.shape[1]
    if b < 2:
        Fp = np.zeros((F.shape[0], 2), dtype=F.dtype)
        Fp[:, :b] = F
    else:
        Fp = F
    out = _eval_planes(_commit_planes(B), Fp)[:, :b]
    return out[:, 0] if squeeze else out


class InterpolantCache:
    """Warm jitted interpolants keyed ``(basis_id, generation, bucket,
    dtype)``.

    Holds the device-committed interpolant planes per (basis, generation)
    plus the set of (bucket, dtype) combinations already traced/compiled
    for it; a miss pays the device commit and/or XLA compile, every later
    batch in the same bucket is warm.  ``evict(basis_id)`` drops every
    generation (wired to router LRU evictions); ``retire(basis_id,
    below_gen)`` drops only generations below a hot-reload floor — an
    in-flight old-generation batch still evaluates correctly, it just no
    longer repopulates the cache.
    """

    def __init__(self):
        self._planes: dict[tuple, tuple] = {}   # (basis_id, gen) -> planes
        self._warm: set[tuple] = set()          # (basis_id, gen, bucket, dt)
        self._floor: dict[str, int] = {}        # basis_id -> min live gen
        self._lock = threading.Lock()

    def evaluate(self, basis_id: str, eim, F: np.ndarray,
                 generation: int = 0):
        """(out, bucket, was_warm) for a (k, b) request batch ``F``."""
        b = F.shape[1]
        bucket = batch_bucket(b)
        key = (basis_id, generation, bucket, str(F.dtype))
        with self._lock:
            retired = generation < self._floor.get(basis_id, 0)
            warm = key in self._warm
            planes = self._planes.get((basis_id, generation))
            if planes is None:
                planes = _commit_planes(eim.B)
                if not retired:
                    self._planes[(basis_id, generation)] = planes
        Fp = np.zeros((F.shape[0], bucket), dtype=F.dtype)
        Fp[:, :b] = F
        out = _eval_planes(planes, Fp)[:, :b]
        with self._lock:
            if not retired:
                self._warm.add(key)
        return out, bucket, warm

    def warm_keys(self, basis_id: str) -> list[tuple]:
        with self._lock:
            return sorted(k for k in self._warm if k[0] == basis_id)

    def evict(self, basis_id: str) -> None:
        with self._lock:
            self._planes = {k: v for k, v in self._planes.items()
                            if k[0] != basis_id}
            self._warm = {k for k in self._warm if k[0] != basis_id}

    def retire(self, basis_id: str, below_gen: int) -> None:
        """Hot-reload floor: drop entries with generation < ``below_gen``
        and refuse to re-admit them (in-flight old-generation batches
        finish, their results stay bitwise-correct, nothing is cached)."""
        with self._lock:
            self._floor[basis_id] = max(
                self._floor.get(basis_id, 0), int(below_gen))
            self._planes = {k: v for k, v in self._planes.items()
                            if k[0] != basis_id or k[1] >= below_gen}
            self._warm = {k for k in self._warm
                          if k[0] != basis_id or k[1] >= below_gen}

    def stats(self) -> dict:
        with self._lock:
            return {"committed_bases": len(self._planes),
                    "warm_entries": len(self._warm)}


@dataclasses.dataclass
class _Request:
    basis_id: str
    f: np.ndarray
    future: concurrent.futures.Future
    t_submit: float
    deadline: Optional[float]


def _resolve(fut: concurrent.futures.Future, *, result=None,
             error: Optional[BaseException] = None) -> bool:
    """Resolve a future, tolerating caller-side cancellation."""
    try:
        if error is not None:
            fut.set_exception(error)
        else:
            fut.set_result(result)
        return True
    except concurrent.futures.InvalidStateError:
        return False


class ROQEngine:
    """Persistent batched ROQ interpolation service (see module docstring).

    Args:
      router: a :class:`BasisRouter`, or a ``{basis_id: directory |
        ReducedBasis}`` mapping to build one from (budgeted by
        ``REPRO_DEVICE_MEM_BUDGET`` conventions).
      max_batch: flush a basis's pending batch at this many requests.
      max_wait_ms: ... or this long after its oldest pending request —
        the latency/throughput dial (small = low latency, large = big
        batches).
      queue_depth: bounded intake; a full queue rejects with
        :class:`QueueFullError` (explicit backpressure).
      timeout_s: default per-request deadline (None = no deadline),
        overridable per ``submit``.
      client_rate / client_burst: per-client token-bucket quota (req/s
        steady rate + burst capacity) keyed by ``submit``'s
        ``client_id`` (anonymous requests share one bucket); ``None``
        disables quotas.
      degrade_queue_frac: queue-depth watermark (fraction of
        ``queue_depth``) past which admission enters degraded mode and
        quota refill is multiplied by ``degraded_factor`` (cleared with
        hysteresis at half the watermark).
      degrade_p95_ms: optional p95-latency watermark (over the metrics
        window) with the same effect.
      breaker_threshold / breaker_cooldown_s: per-basis circuit breaker —
        this many CONSECUTIVE batch failures open it (requests fast-fail
        with ``CircuitOpenError``); after the cooldown one probe batch is
        admitted half-open.
      restart: a :class:`~repro.serving.health.RestartPolicy` for the
        supervised worker (default: restart up to 3 times per 60 s
        window with exponential backoff).  ``RestartPolicy(enabled=
        False)`` latches the engine unhealthy on worker death instead.
      start: spin up the worker immediately (tests pass False to poke
        the queue unserviced).
    """

    def __init__(self, router, *, max_batch: int = 32,
                 max_wait_ms: float = 2.0, queue_depth: int = 1024,
                 timeout_s: Optional[float] = None,
                 metrics: Optional[ServingMetrics] = None,
                 client_rate: Optional[float] = None,
                 client_burst: Optional[float] = None,
                 degraded_factor: float = 0.5,
                 degrade_queue_frac: float = 0.75,
                 degrade_p95_ms: Optional[float] = None,
                 breaker_threshold: int = 5,
                 breaker_cooldown_s: float = 5.0,
                 restart: Optional[RestartPolicy] = None,
                 start: bool = True):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.metrics = metrics if metrics is not None else ServingMetrics()
        if isinstance(router, dict):
            mapping, router = router, BasisRouter(metrics=self.metrics)
            for bid, src in mapping.items():
                router.register(bid, src)
        if router._metrics is None:
            router._metrics = self.metrics
        self.router = router
        self.cache = InterpolantCache()
        prev_evict = router._on_evict
        def _on_evict(bid, _prev=prev_evict):
            self.cache.evict(bid)
            if _prev is not None:
                _prev(bid)
        router._on_evict = _on_evict
        prev_refresh = router._on_refresh
        def _on_refresh(bid, old_gen, new_gen, _prev=prev_refresh):
            self.cache.retire(bid, below_gen=new_gen)
            if _prev is not None:
                _prev(bid, old_gen, new_gen)
        router._on_refresh = _on_refresh
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_ms) / 1e3
        self.timeout_s = timeout_s
        self.degrade_queue_frac = float(degrade_queue_frac)
        self.degrade_p95_ms = degrade_p95_ms
        self.admission = AdmissionController(
            client_rate=client_rate, client_burst=client_burst,
            degraded_factor=degraded_factor,
            delay_estimator=self.estimated_delay_s, metrics=self.metrics)
        self.breakers = CircuitBreakerBoard(
            threshold=breaker_threshold, cooldown_s=breaker_cooldown_s,
            probe_budget=self.max_batch, metrics=self.metrics)
        self.restart_policy = restart if restart is not None \
            else RestartPolicy()
        self._restarts = RestartTracker(self.restart_policy)
        self._health = HealthState()
        self._queue: queue.Queue = queue.Queue(maxsize=int(queue_depth))
        self._pending: dict[str, list[_Request]] = {}
        self._closed = False
        self._abort = False
        self._wake = threading.Event()
        self._stop_backoff = threading.Event()
        self._batch_ordinal = 0
        self._batch_ewma_s = 0.0
        self._last_pressure_check = 0.0
        self._worker: Optional[threading.Thread] = None
        if start:
            self.start()

    # ----------------------------------------------------------- intake ----
    def submit(self, basis_id: str, f_nodes,
               timeout_s: Optional[float] = None, *,
               client_id=None) -> concurrent.futures.Future:
        """Run the admission pipeline and enqueue one interpolation
        request; returns its future.

        The future resolves to the (N,) interpolant, or raises the
        request's own failure (bad shape/dtype, unknown basis, timeout,
        batch evaluation error, worker death).  Raises synchronously for
        engine- and admission-level conditions, each with its own type:
        closed intake (:class:`EngineClosedError`), dead worker
        (``EngineUnhealthyError``), open circuit for this basis
        (``CircuitOpenError``), client over quota
        (``QuotaExceededError``), hopeless deadline (``ShedError``), and
        a full queue (:class:`QueueFullError`).
        """
        if self._closed:
            raise EngineClosedError("engine is closed to new requests")
        if not self._health.healthy():
            raise EngineUnhealthyError(
                f"engine unhealthy: {self._health.reason}")
        f = np.asarray(f_nodes)
        if f.ndim != 1:
            self.metrics.count("errors")
            raise ValueError(
                f"a request is ONE vector at the EIM nodes; got shape "
                f"{f.shape} (batching is the engine's job)")
        now = time.perf_counter()
        if timeout_s is None:
            timeout_s = self.timeout_s
        deadline = None if timeout_s is None else now + float(timeout_s)
        basis_id = str(basis_id)
        self.breakers.allow(basis_id, now)        # CircuitOpenError
        self.admission.admit(client_id, deadline, now)  # Quota / Shed
        req = _Request(basis_id=basis_id, f=f,
                       future=concurrent.futures.Future(), t_submit=now,
                       deadline=deadline)
        try:
            self._queue.put_nowait(req)
        except queue.Full:
            self.metrics.count("rejected")
            raise QueueFullError(
                f"serving queue full ({self._queue.maxsize} deep); "
                f"backpressure — retry or shed load") from None
        self.metrics.count("submitted")
        self._wake.set()
        # close()/worker-death race: the intake checks above can pass just
        # before the engine stops serving, landing this request on a queue
        # nothing will ever drain.  Re-check AFTER the enqueue and, unless
        # a live healthy worker is still draining, fail everything queued —
        # a future must resolve exactly one way, never hang.
        if self._closed or not self._health.healthy():
            w = self._worker
            serving = (not self._abort and self._health.healthy()
                       and w is not None and w.is_alive())
            if not serving:
                err = (EngineClosedError("engine closed during submit")
                       if self._closed else EngineUnhealthyError(
                           f"engine unhealthy: {self._health.reason}"))
                self._fail_all_pending(err)
        return req.future

    def warm(self, basis_id: str, buckets=None) -> None:
        """Pre-compile interpolant entries for ``basis_id`` off the
        request path (all power-of-two buckets up to ``max_batch`` by
        default) and fault in the routed basis."""
        entry = self.router.get_entry(basis_id)
        dtype = np.asarray(entry.basis.Q).dtype
        if buckets is None:
            buckets, b = [], 2
            while b < batch_bucket(self.max_batch):
                buckets.append(b)
                b *= 2
            buckets.append(batch_bucket(self.max_batch))
        for b in buckets:
            zeros = np.zeros((entry.basis.k, int(b)), dtype=dtype)
            self.cache.evaluate(basis_id, entry.eim, zeros,
                                generation=entry.generation)

    # ------------------------------------------------------- hot reload ----
    def refresh(self, basis_id: str, source=None) -> int:
        """Hot-swap ``basis_id`` to the artifact now on disk (see
        :meth:`BasisRouter.refresh`): CRC-verified candidate, atomic
        generation-counted swap, old-generation warm entries retired,
        in-flight batches unaffected.  Returns the new generation."""
        return self.router.refresh(basis_id, source)

    # ----------------------------------------------------------- worker ----
    def start(self) -> None:
        if self._worker is not None:
            return
        self._worker = threading.Thread(
            target=self._worker_main, name="roq-engine", daemon=True)
        self._worker.start()

    def healthy(self) -> bool:
        """Readiness: True while the (supervised) worker is serving."""
        return self._health.healthy() and not self._closed

    def close(self, drain: bool = True) -> None:
        """Stop intake; serve everything already accepted (``drain=True``)
        or fail it with :class:`EngineClosedError` (``drain=False``);
        join the worker.  Anything still queued after the worker is gone
        — abort leftovers, a racing ``submit``, or a backlog stranded by
        a dead worker — is failed, never left hanging."""
        self._closed = True
        if not drain:
            self._abort = True
        self._wake.set()
        self._stop_backoff.set()
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        self._fail_all_pending(EngineClosedError(
            "engine aborted" if self._abort
            else "engine closed during submit"))

    def __enter__(self) -> "ROQEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=True)

    def _worker_main(self) -> None:
        """Supervision guard around the batching loop.

        PR 8 shipped with one silent failure mode: any exception escaping
        :meth:`_run` outside the per-batch ``try`` killed the worker with
        every submitted future stranded forever.  Now a dying loop (a)
        fails every pending AND queued future with
        ``EngineUnhealthyError``, (b) flips the health latch (readiness
        false, ``submit`` refuses), and (c) restarts under the sliding
        restart window + exponential backoff of :attr:`restart_policy`,
        or stays down once the budget is exhausted/disabled.
        """
        while True:
            try:
                self._run()
                return    # clean exit: closed and drained/aborted
            except BaseException as e:  # supervision guard — never hang
                self.metrics.count("worker_deaths")
                logger.exception(
                    "serving worker died in the batching loop: %r", e)
                self._health.set_unhealthy(f"worker died: {e!r}")
                self._fail_inflight(EngineUnhealthyError(
                    f"serving worker died: {e!r}"))
                if self._closed:
                    return
                delay = self._restarts.next_delay()
                if delay is None:
                    p = self.restart_policy
                    self._health.set_unhealthy(
                        f"worker died: {e!r}; restart budget exhausted "
                        f"({p.max_restarts} per {p.window_s:.0f}s) or "
                        f"restarts disabled")
                    return
                if delay > 0:
                    self._stop_backoff.wait(delay)
                if self._closed:
                    return
                self.metrics.count("worker_restarts")
                self._health.set_healthy("worker restarted after death")

    def _run(self) -> None:
        pending = self._pending
        while True:
            if self._abort:
                break
            self._wake.wait(timeout=self._poll_s(pending))
            self._wake.clear()
            if self._abort:
                break
            while True:
                try:
                    req = self._queue.get_nowait()
                except queue.Empty:
                    break
                pending.setdefault(req.basis_id, []).append(req)
            n_pending = sum(len(v) for v in pending.values())
            self.metrics.set_queue_depth(self._queue.qsize() + n_pending)
            now = time.perf_counter()
            self._update_pressure(now, n_pending)
            self._expire_deadlines(pending, now)
            draining = self._closed and self._queue.empty()
            for bid in list(pending):
                lst = pending[bid]
                while len(lst) >= self.max_batch:
                    self._flush(bid, lst[:self.max_batch])
                    del lst[:self.max_batch]
                if lst and (draining
                            or now - lst[0].t_submit >= self.max_wait_s):
                    self._flush(bid, lst)
                    lst.clear()
                if not lst:
                    del pending[bid]
            if self._closed and self._queue.empty() and not pending:
                break
        if self._abort:
            for lst in pending.values():
                for r in lst:
                    if _resolve(r.future,
                                error=EngineClosedError("engine aborted")):
                        self.metrics.count("errors")
            pending.clear()

    def _poll_s(self, pending) -> float:
        """Sleep until the next max_wait flush OR the earliest pending
        deadline is due (capped so close() and fresh submissions stay
        responsive) — a request with ``timeout_s`` far below
        ``max_wait_ms`` gets its TimeoutError promptly, not at flush."""
        cap = 0.05
        if self._closed:
            return 1e-3
        now = time.perf_counter()
        due = None
        for lst in pending.values():
            if not lst:
                continue
            t = lst[0].t_submit + self.max_wait_s
            due = t if due is None else min(due, t)
            for r in lst:
                if r.deadline is not None and r.deadline < due:
                    due = r.deadline
        if due is None:
            return cap
        return max(1e-4, min(cap, due - now))

    def _expire_deadlines(self, pending, now: float) -> None:
        """Fail requests whose deadline passed while they WAITED — they
        never reach a batch slot, and their TimeoutError is prompt."""
        for bid in list(pending):
            lst = pending[bid]
            if not any(r.deadline is not None and now > r.deadline
                       for r in lst):
                continue
            live = []
            for r in lst:
                if r.deadline is not None and now > r.deadline:
                    if _resolve(r.future, error=TimeoutError(
                            f"request waited past its "
                            f"{r.deadline - r.t_submit:.3f}s deadline")):
                        self.metrics.count("timeouts")
                else:
                    live.append(r)
            lst[:] = live
            if not lst:
                del pending[bid]

    def _update_pressure(self, now: float, n_pending: int = 0) -> None:
        """Degraded-mode watermark check, throttled to ~20 Hz.

        The backlog is queued PLUS pending requests — the worker drains
        the queue into its pending dict before checking, so ``qsize()``
        alone reads ~0 at exactly the wrong moment."""
        if now - self._last_pressure_check < 0.05:
            return
        self._last_pressure_check = now
        frac = ((self._queue.qsize() + n_pending)
                / max(self._queue.maxsize, 1))
        p95 = (self.metrics.recent_p95_ms()
               if self.degrade_p95_ms is not None else None)
        if frac >= self.degrade_queue_frac or (
                p95 is not None and p95 >= self.degrade_p95_ms):
            if self.admission.set_degraded(True):
                logger.warning(
                    "admission degraded: queue %.0f%% of depth, p95=%s ms",
                    frac * 100, f"{p95:.1f}" if p95 is not None else "n/a")
        elif self.admission.degraded and frac <= 0.5 * self.degrade_queue_frac \
                and (p95 is None or p95 < self.degrade_p95_ms):
            if self.admission.set_degraded(False):
                logger.info("admission back to normal (pressure cleared)")

    def estimated_delay_s(self) -> float:
        """Estimated queueing delay for a request admitted NOW: backlog
        batches x the EWMA batch service time.  0.0 with no backlog or
        before the first served batch — shedding only ever fires on
        measured congestion, never cold."""
        ewma = self._batch_ewma_s
        if ewma <= 0.0:
            return 0.0
        # best-effort backlog: queued + whatever the worker already drained
        # into its pending dict (len() reads race benignly under the GIL)
        backlog = self._queue.qsize() + sum(
            len(v) for v in list(self._pending.values()))
        return (backlog / max(self.max_batch, 1)) * ewma

    def _fail_inflight(self, err: BaseException) -> None:
        """Fail everything the worker owned (pending batches) plus the
        whole queue — the worker-death path; nothing may hang."""
        pending, self._pending = self._pending, {}
        for lst in pending.values():
            for r in lst:
                if _resolve(r.future, error=err):
                    self.metrics.count("errors")
        self._fail_all_pending(err)

    def _fail_all_pending(self, err: BaseException) -> None:
        while True:
            try:
                r = self._queue.get_nowait()
            except queue.Empty:
                return
            if _resolve(r.future, error=err):
                self.metrics.count("errors")

    # ------------------------------------------------------------ flush ----
    def _flush(self, basis_id: str, reqs: list) -> None:
        now = time.perf_counter()
        live = []
        for r in reqs:
            if r.deadline is not None and now > r.deadline:
                if _resolve(r.future, error=TimeoutError(
                        f"request waited past its "
                        f"{r.deadline - r.t_submit:.3f}s deadline")):
                    self.metrics.count("timeouts")
            else:
                live.append(r)
        if not live:
            return
        try:
            entry = self.router.get_entry(basis_id)
        except Exception as e:  # unknown id, unreadable artifact, ...
            self.breakers.record_failure(basis_id)
            for r in live:
                if _resolve(r.future, error=e):
                    self.metrics.count("errors")
            return
        basis, eim = entry.basis, entry.eim
        dtype = np.asarray(basis.Q).dtype
        good = []
        for r in live:
            if r.f.shape != (basis.k,):
                err = ValueError(
                    f"request for {basis_id!r} has shape {r.f.shape}, "
                    f"expected ({basis.k},) — one value per EIM node")
            elif not np.can_cast(r.f.dtype, dtype, casting="same_kind"):
                err = ValueError(
                    f"request dtype {r.f.dtype} does not cast to basis "
                    f"dtype {dtype}")
            else:
                good.append(r)
                continue
            if _resolve(r.future, error=err):
                self.metrics.count("errors")
        if not good:
            return
        F = np.stack([r.f for r in good], axis=1).astype(dtype, copy=False)
        self._batch_ordinal += 1
        # OUTSIDE the per-batch try: an injected death here escapes the
        # batching logic entirely and must be caught by the supervision
        # guard, not batch error isolation.
        self._maybe_kill_worker(self._batch_ordinal)
        self.breakers.on_batch_start(basis_id)
        t_eval0 = time.perf_counter()
        try:
            self._maybe_inject_batch_fault(self._batch_ordinal)
            self._maybe_slow_batch()
            out, bucket, warm = self.cache.evaluate(
                basis_id, eim, F, generation=entry.generation)
        except Exception as e:
            # batch-level failure: isolated to THIS batch's requests;
            # the engine keeps serving subsequent batches.  Consecutive
            # failures feed the basis's circuit breaker.
            logger.warning("batch %d for %r failed: %s",
                           self._batch_ordinal, basis_id, e)
            self.breakers.record_failure(basis_id)
            for r in good:
                if _resolve(r.future, error=e):
                    self.metrics.count("errors")
            return
        self.breakers.record_success(basis_id)
        t_done = time.perf_counter()
        dt = t_done - t_eval0
        self._batch_ewma_s = dt if self._batch_ewma_s == 0.0 \
            else 0.2 * dt + 0.8 * self._batch_ewma_s
        self.metrics.count("cache_hits" if warm else "cache_misses")
        self.metrics.observe_batch(len(good), bucket)
        for i, r in enumerate(good):
            if _resolve(r.future, result=out[:, i]):
                self.metrics.count("completed")
                self.metrics.observe_latency(t_done - r.t_submit)

    # ------------------------------------------------------ chaos hooks ----
    @staticmethod
    def _maybe_inject_batch_fault(ordinal: int) -> None:
        """PR-6-convention fault hook: ``REPRO_FAULT_SERVE_RAISE_AT_BATCH=n``
        raises a transient error evaluating the n-th batch (at most once
        under ``REPRO_FAULT_ONCE``), exercising batch error isolation."""
        at = os.environ.get("REPRO_FAULT_SERVE_RAISE_AT_BATCH")
        if not at or ordinal != int(at):
            return
        from repro.checkpoint.io import _fault_once

        if _fault_once("serve_raise_at_batch"):
            raise RuntimeError(
                f"injected serving fault at batch {ordinal} "
                f"(REPRO_FAULT_SERVE_RAISE_AT_BATCH)")

    @staticmethod
    def _maybe_kill_worker(ordinal: int) -> None:
        """``REPRO_FAULT_SERVE_KILL_WORKER=n`` raises in the BATCHING
        logic (outside the per-batch try) at the n-th batch — the silent
        worker-death scenario the supervision guard exists for.  At most
        once under ``REPRO_FAULT_ONCE``."""
        at = os.environ.get("REPRO_FAULT_SERVE_KILL_WORKER")
        if not at or ordinal != int(at):
            return
        from repro.checkpoint.io import _fault_once

        if _fault_once("serve_kill_worker"):
            raise RuntimeError(
                f"injected worker death at batch {ordinal} "
                f"(REPRO_FAULT_SERVE_KILL_WORKER)")

    @staticmethod
    def _maybe_slow_batch() -> None:
        """``REPRO_FAULT_SERVE_SLOW_BATCH=<ms>`` stalls every batch
        evaluation — the straggler/overload injection behind the
        degraded-mode and shedding chaos scenarios."""
        ms = os.environ.get("REPRO_FAULT_SERVE_SLOW_BATCH")
        if ms:
            time.sleep(float(ms) / 1e3)

    # ------------------------------------------------------------ status ----
    def stats(self) -> dict:
        """One observability rollup: metrics snapshot + router + cache +
        health/admission/breaker state."""
        snap = self.metrics.snapshot()
        snap["router"] = self.router.stats()
        snap["interpolant_cache"] = self.cache.stats()
        snap["healthy"] = self.healthy()
        snap["health"] = self._health.snapshot()
        snap["admission"] = self.admission.stats()
        snap["breakers"] = self.breakers.stats()
        snap["estimated_delay_ms"] = self.estimated_delay_s() * 1e3
        return snap
