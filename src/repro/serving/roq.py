"""Persistent ROQ serving engine: the paper's *online* stage as a service.

The offline stage builds a reduced basis once; the whole point is the
online stage — many cheap queries against it.  A request here is a vector
``f`` known only at the basis's ``k`` EIM nodes; the engine answers with
the full N-sample empirical interpolant ``I_k[f] = B @ f[nodes]`` (Alg. 5
of Ref. [6]).  One :class:`ROQEngine` turns that single GEMV into a
persistent batched service:

- ``submit(basis_id, f_nodes)`` puts a request on a BOUNDED queue and
  returns a ``concurrent.futures.Future`` (queue full -> explicit
  :class:`QueueFullError` reject, never silent latency).
- A worker thread forms dynamic per-basis batches under the latency /
  throughput dial: flush at ``max_batch`` requests OR ``max_wait_ms``
  after the oldest pending one, whichever first.
- Batches evaluate through a warm :class:`InterpolantCache` keyed by
  ``(basis_id, batch_bucket, dtype)``: batch widths round up to
  power-of-two buckets so the number of XLA compilations is
  O(log2(max_batch)) per basis, not one per width.
- ``basis_id`` routes through a :class:`~repro.serving.router.BasisRouter`
  (multi-artifact working set, LRU under a device-memory budget); router
  evictions drop the matching warm cache entries.
- Per-request timeout and error isolation: a malformed request (wrong
  length, uncastable dtype, unknown basis) fails ALONE via its future;
  its batchmates still serve.  Injected faults
  (``REPRO_FAULT_SERVE_RAISE_AT_BATCH``, PR-6 conventions) fail one
  batch, never the engine.
- ``close()`` drains: intake stops, everything already accepted is
  served, then the worker exits.

Bitwise contract (load-bearing for tests and the multi-basis acceptance
row): padded-bucket evaluation is bit-identical to the unpadded direct
evaluation of the same requests.  Two ingredients make that true: complex
interpolants run as plane-split real GEMMs (the repo-wide convention —
XLA CPU's complex GEMM both differs bitwise under padding and lowers
badly), and every GEMM is kept at width >= 2 (width-1 dots route to a
GEMV with a different accumulation order, so :func:`direct_interpolate`
pads a lone column to 2).  Per-column GEMM results are then independent
of the padded width — asserted across dtypes in tests/test_serving.py.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import logging
import os
import queue
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.metrics import ServingMetrics
from repro.serving.router import BasisRouter

logger = logging.getLogger("repro.serving")


class QueueFullError(RuntimeError):
    """Backpressure: the engine's bounded queue is full; retry or shed."""


class EngineClosedError(RuntimeError):
    """The engine is closed (or closing) and takes no new requests."""


def batch_bucket(n: int) -> int:
    """Padded batch width for a batch of ``n`` requests: the smallest
    power of two >= max(n, 2).  The floor of 2 keeps even a lone request
    on the bitwise-stable GEMM path (see module docstring)."""
    if n < 1:
        raise ValueError(f"batch of {n} requests")
    return 1 << (max(n, 2) - 1).bit_length()


# One jitted apply per arithmetic form, shared by every basis; XLA's trace
# cache keys on shapes/dtypes, so distinct buckets compile once each and
# same-shaped bases share executables.  The explicit InterpolantCache on
# top tracks warmth per (basis_id, bucket, dtype) and owns the
# device-committed interpolant planes.
@jax.jit
def _apply_real(B, F):
    return B @ F


@jax.jit
def _apply_split(Br, Bi, Fr, Fi):
    return Br @ Fr - Bi @ Fi, Br @ Fi + Bi @ Fr


def _eval_planes(planes, Fp: np.ndarray) -> np.ndarray:
    """Evaluate the committed interpolant on a padded (k, bucket) batch."""
    if len(planes) == 1:
        (B,) = planes
        return np.asarray(_apply_real(B, jnp.asarray(Fp)))
    Br, Bi = planes
    re, im = _apply_split(Br, Bi, jnp.asarray(np.ascontiguousarray(Fp.real)),
                          jnp.asarray(np.ascontiguousarray(Fp.imag)))
    out = np.empty((re.shape[0], re.shape[1]), dtype=Fp.dtype)
    out.real = np.asarray(re)
    out.imag = np.asarray(im)
    return out


def _commit_planes(eim_B) -> tuple:
    """Device-commit an interpolant matrix once per routed basis."""
    B = np.asarray(eim_B)
    if np.issubdtype(B.dtype, np.complexfloating):
        return (jnp.asarray(np.ascontiguousarray(B.real)),
                jnp.asarray(np.ascontiguousarray(B.imag)))
    return (jnp.asarray(B),)


def direct_interpolate(eim, F) -> np.ndarray:
    """Reference evaluation: unpadded, unbatched-policy-free ``B @ F``.

    ``F`` is (k,) or (k, b) at the EIM nodes; returns (N,) or (N, b).
    This is "direct per-basis evaluation" in the acceptance sense — the
    engine's padded-bucket path must match it bit for bit.  A single
    column is padded to width 2 to stay on the GEMM path.
    """
    B = np.asarray(eim.B)
    F = np.asarray(F, dtype=B.dtype)
    squeeze = F.ndim == 1
    if squeeze:
        F = F[:, None]
    b = F.shape[1]
    if b < 2:
        Fp = np.zeros((F.shape[0], 2), dtype=F.dtype)
        Fp[:, :b] = F
    else:
        Fp = F
    out = _eval_planes(_commit_planes(B), Fp)[:, :b]
    return out[:, 0] if squeeze else out


class InterpolantCache:
    """Warm jitted interpolants keyed by ``(basis_id, bucket, dtype)``.

    Holds the device-committed interpolant planes per basis plus the set
    of (bucket, dtype) combinations already traced/compiled for it; a
    miss pays the device commit and/or XLA compile, every later batch in
    the same bucket is warm.  ``evict(basis_id)`` drops both (wired to
    router LRU evictions).
    """

    def __init__(self):
        self._planes: dict[str, tuple] = {}
        self._warm: set[tuple] = set()
        self._lock = threading.Lock()

    def evaluate(self, basis_id: str, eim, F: np.ndarray):
        """(out, bucket, was_warm) for a (k, b) request batch ``F``."""
        b = F.shape[1]
        bucket = batch_bucket(b)
        key = (basis_id, bucket, str(F.dtype))
        with self._lock:
            warm = key in self._warm
            planes = self._planes.get(basis_id)
            if planes is None:
                planes = _commit_planes(eim.B)
                self._planes[basis_id] = planes
        Fp = np.zeros((F.shape[0], bucket), dtype=F.dtype)
        Fp[:, :b] = F
        out = _eval_planes(planes, Fp)[:, :b]
        with self._lock:
            self._warm.add(key)
        return out, bucket, warm

    def warm_keys(self, basis_id: str) -> list[tuple]:
        with self._lock:
            return sorted(k for k in self._warm if k[0] == basis_id)

    def evict(self, basis_id: str) -> None:
        with self._lock:
            self._planes.pop(basis_id, None)
            self._warm = {k for k in self._warm if k[0] != basis_id}

    def stats(self) -> dict:
        with self._lock:
            return {"committed_bases": len(self._planes),
                    "warm_entries": len(self._warm)}


@dataclasses.dataclass
class _Request:
    basis_id: str
    f: np.ndarray
    future: concurrent.futures.Future
    t_submit: float
    deadline: Optional[float]


def _resolve(fut: concurrent.futures.Future, *, result=None,
             error: Optional[BaseException] = None) -> bool:
    """Resolve a future, tolerating caller-side cancellation."""
    try:
        if error is not None:
            fut.set_exception(error)
        else:
            fut.set_result(result)
        return True
    except concurrent.futures.InvalidStateError:
        return False


class ROQEngine:
    """Persistent batched ROQ interpolation service (see module docstring).

    Args:
      router: a :class:`BasisRouter`, or a ``{basis_id: directory |
        ReducedBasis}`` mapping to build one from (budgeted by
        ``REPRO_DEVICE_MEM_BUDGET`` conventions).
      max_batch: flush a basis's pending batch at this many requests.
      max_wait_ms: ... or this long after its oldest pending request —
        the latency/throughput dial (small = low latency, large = big
        batches).
      queue_depth: bounded intake; a full queue rejects with
        :class:`QueueFullError` (explicit backpressure).
      timeout_s: default per-request deadline (None = no deadline),
        overridable per ``submit``.
      start: spin up the worker immediately (tests pass False to poke
        the queue unserviced).
    """

    def __init__(self, router, *, max_batch: int = 32,
                 max_wait_ms: float = 2.0, queue_depth: int = 1024,
                 timeout_s: Optional[float] = None,
                 metrics: Optional[ServingMetrics] = None,
                 start: bool = True):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.metrics = metrics if metrics is not None else ServingMetrics()
        if isinstance(router, dict):
            mapping, router = router, BasisRouter(metrics=self.metrics)
            for bid, src in mapping.items():
                router.register(bid, src)
        if router._metrics is None:
            router._metrics = self.metrics
        self.router = router
        self.cache = InterpolantCache()
        prev_evict = router._on_evict
        def _on_evict(bid, _prev=prev_evict):
            self.cache.evict(bid)
            if _prev is not None:
                _prev(bid)
        router._on_evict = _on_evict
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_ms) / 1e3
        self.timeout_s = timeout_s
        self._queue: queue.Queue = queue.Queue(maxsize=int(queue_depth))
        self._closed = False
        self._abort = False
        self._wake = threading.Event()
        self._batch_ordinal = 0
        self._worker: Optional[threading.Thread] = None
        if start:
            self.start()

    # ----------------------------------------------------------- intake ----
    def submit(self, basis_id: str, f_nodes,
               timeout_s: Optional[float] = None
               ) -> concurrent.futures.Future:
        """Enqueue one interpolation request; returns its future.

        The future resolves to the (N,) interpolant, or raises the
        request's own failure (bad shape/dtype, unknown basis, timeout,
        batch evaluation error).  Raises synchronously only for
        engine-level conditions: closed intake or a full queue.
        """
        if self._closed:
            raise EngineClosedError("engine is closed to new requests")
        f = np.asarray(f_nodes)
        if f.ndim != 1:
            self.metrics.count("errors")
            raise ValueError(
                f"a request is ONE vector at the EIM nodes; got shape "
                f"{f.shape} (batching is the engine's job)")
        now = time.perf_counter()
        if timeout_s is None:
            timeout_s = self.timeout_s
        req = _Request(
            basis_id=str(basis_id), f=f,
            future=concurrent.futures.Future(), t_submit=now,
            deadline=None if timeout_s is None else now + float(timeout_s),
        )
        try:
            self._queue.put_nowait(req)
        except queue.Full:
            self.metrics.count("rejected")
            raise QueueFullError(
                f"serving queue full ({self._queue.maxsize} deep); "
                f"backpressure — retry or shed load") from None
        self.metrics.count("submitted")
        self._wake.set()
        return req.future

    def warm(self, basis_id: str, buckets=None) -> None:
        """Pre-compile interpolant entries for ``basis_id`` off the
        request path (all power-of-two buckets up to ``max_batch`` by
        default) and fault in the routed basis."""
        basis, eim = self.router.get(basis_id)
        dtype = np.asarray(basis.Q).dtype
        if buckets is None:
            buckets, b = [], 2
            while b < batch_bucket(self.max_batch):
                buckets.append(b)
                b *= 2
            buckets.append(batch_bucket(self.max_batch))
        for b in buckets:
            zeros = np.zeros((basis.k, int(b)), dtype=dtype)
            self.cache.evaluate(basis_id, eim, zeros)

    # ----------------------------------------------------------- worker ----
    def start(self) -> None:
        if self._worker is not None:
            return
        self._worker = threading.Thread(
            target=self._run, name="roq-engine", daemon=True)
        self._worker.start()

    def close(self, drain: bool = True) -> None:
        """Stop intake; serve everything already accepted (``drain=True``)
        or fail it with :class:`EngineClosedError` (``drain=False``);
        join the worker."""
        self._closed = True
        if not drain:
            self._abort = True
        self._wake.set()
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._abort:
            self._fail_all_pending(EngineClosedError("engine aborted"))

    def __enter__(self) -> "ROQEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=True)

    def _run(self) -> None:
        pending: dict[str, list[_Request]] = {}
        while True:
            if self._abort:
                break
            self._wake.wait(timeout=self._poll_s(pending))
            self._wake.clear()
            if self._abort:
                break
            while True:
                try:
                    req = self._queue.get_nowait()
                except queue.Empty:
                    break
                pending.setdefault(req.basis_id, []).append(req)
            self.metrics.set_queue_depth(self._queue.qsize())
            draining = self._closed and self._queue.empty()
            now = time.perf_counter()
            for bid in list(pending):
                lst = pending[bid]
                while len(lst) >= self.max_batch:
                    self._flush(bid, lst[:self.max_batch])
                    del lst[:self.max_batch]
                if lst and (draining
                            or now - lst[0].t_submit >= self.max_wait_s):
                    self._flush(bid, lst)
                    lst.clear()
                if not lst:
                    del pending[bid]
            if self._closed and self._queue.empty() and not pending:
                break
        if self._abort:
            for lst in pending.values():
                for r in lst:
                    if _resolve(r.future,
                                error=EngineClosedError("engine aborted")):
                        self.metrics.count("errors")

    def _poll_s(self, pending) -> float:
        """Sleep until the next max_wait flush is due (capped so close()
        and fresh submissions stay responsive)."""
        cap = 0.05
        if self._closed:
            return 1e-3
        if not pending:
            return cap
        now = time.perf_counter()
        oldest = min(lst[0].t_submit for lst in pending.values() if lst)
        return max(1e-4, min(cap, oldest + self.max_wait_s - now))

    def _fail_all_pending(self, err: BaseException) -> None:
        while True:
            try:
                r = self._queue.get_nowait()
            except queue.Empty:
                return
            if _resolve(r.future, error=err):
                self.metrics.count("errors")

    # ------------------------------------------------------------ flush ----
    def _flush(self, basis_id: str, reqs: list) -> None:
        now = time.perf_counter()
        live = []
        for r in reqs:
            if r.deadline is not None and now > r.deadline:
                if _resolve(r.future, error=TimeoutError(
                        f"request waited past its "
                        f"{r.deadline - r.t_submit:.3f}s deadline")):
                    self.metrics.count("timeouts")
            else:
                live.append(r)
        if not live:
            return
        try:
            basis, eim = self.router.get(basis_id)
        except Exception as e:  # unknown id, unreadable artifact, ...
            for r in live:
                if _resolve(r.future, error=e):
                    self.metrics.count("errors")
            return
        dtype = np.asarray(basis.Q).dtype
        good = []
        for r in live:
            if r.f.shape != (basis.k,):
                err = ValueError(
                    f"request for {basis_id!r} has shape {r.f.shape}, "
                    f"expected ({basis.k},) — one value per EIM node")
            elif not np.can_cast(r.f.dtype, dtype, casting="same_kind"):
                err = ValueError(
                    f"request dtype {r.f.dtype} does not cast to basis "
                    f"dtype {dtype}")
            else:
                good.append(r)
                continue
            if _resolve(r.future, error=err):
                self.metrics.count("errors")
        if not good:
            return
        F = np.stack([r.f for r in good], axis=1).astype(dtype, copy=False)
        self._batch_ordinal += 1
        try:
            self._maybe_inject_batch_fault(self._batch_ordinal)
            out, bucket, warm = self.cache.evaluate(basis_id, eim, F)
        except Exception as e:
            # batch-level failure: isolated to THIS batch's requests;
            # the engine keeps serving subsequent batches.
            logger.warning("batch %d for %r failed: %s",
                           self._batch_ordinal, basis_id, e)
            for r in good:
                if _resolve(r.future, error=e):
                    self.metrics.count("errors")
            return
        self.metrics.count("cache_hits" if warm else "cache_misses")
        self.metrics.observe_batch(len(good), bucket)
        t_done = time.perf_counter()
        for i, r in enumerate(good):
            if _resolve(r.future, result=out[:, i]):
                self.metrics.count("completed")
                self.metrics.observe_latency(t_done - r.t_submit)

    @staticmethod
    def _maybe_inject_batch_fault(ordinal: int) -> None:
        """PR-6-convention fault hook: ``REPRO_FAULT_SERVE_RAISE_AT_BATCH=n``
        raises a transient error evaluating the n-th batch (at most once
        under ``REPRO_FAULT_ONCE``), exercising batch error isolation."""
        at = os.environ.get("REPRO_FAULT_SERVE_RAISE_AT_BATCH")
        if not at or ordinal != int(at):
            return
        from repro.checkpoint.io import _fault_once

        if _fault_once("serve_raise_at_batch"):
            raise RuntimeError(
                f"injected serving fault at batch {ordinal} "
                f"(REPRO_FAULT_SERVE_RAISE_AT_BATCH)")

    # ------------------------------------------------------------ status ----
    def stats(self) -> dict:
        """One observability rollup: metrics snapshot + router + cache."""
        snap = self.metrics.snapshot()
        snap["router"] = self.router.stats()
        snap["interpolant_cache"] = self.cache.stats()
        return snap
