"""Engine health and worker supervision policy for the serving layer.

PR 8's worker thread had one failure mode with no story: an exception
escaping the batching/poll logic (outside the per-batch ``try``) killed
the thread silently — every submitted future hung forever and ``submit``
kept accepting new ones into the void.  This module gives the engine the
PR-6 supervisor's vocabulary, in process:

- :class:`HealthState` — a thread-safe healthy/unhealthy latch with a
  bounded transition log, surfaced through ``ROQEngine.healthy()`` and
  ``stats()["health"]`` (the readiness signal an ingress or probe reads).
- :class:`RestartPolicy` — the sliding-window restart budget + exponential
  backoff knobs (same semantics as ``launch/supervisor.py``: up to
  ``max_restarts`` within any ``window_s`` span, ``backoff_base_s *
  2**(restarts in window)`` capped at ``backoff_cap_s`` between restarts).
- :class:`RestartTracker` — the mechanism: ``next_delay()`` returns the
  backoff to sleep before the next restart, or ``None`` when the budget
  is exhausted (or restarts are disabled) and the engine must stay down.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Optional


class EngineUnhealthyError(RuntimeError):
    """The engine's worker is dead (or restarting); intake is refused
    until supervision brings it back."""


class HealthState:
    """Thread-safe healthy/unhealthy latch with a transition log."""

    def __init__(self, max_transitions: int = 64):
        self._lock = threading.Lock()
        self._healthy = True
        self._reason = "started"
        self._transitions: collections.deque = collections.deque(
            maxlen=max_transitions)
        self._mark(True, "started")

    def _mark(self, healthy: bool, reason: str) -> None:
        self._transitions.append(
            {"t": time.time(), "healthy": healthy, "reason": reason})

    def set_healthy(self, reason: str) -> None:
        with self._lock:
            if not self._healthy:
                self._mark(True, reason)
            self._healthy, self._reason = True, reason

    def set_unhealthy(self, reason: str) -> None:
        with self._lock:
            if self._healthy:
                self._mark(False, reason)
            self._healthy, self._reason = False, reason

    def healthy(self) -> bool:
        with self._lock:
            return self._healthy

    @property
    def reason(self) -> str:
        with self._lock:
            return self._reason

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "healthy": self._healthy,
                "reason": self._reason,
                "transitions": list(self._transitions),
            }


@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    """Worker restart policy (PR-6 supervisor semantics, in process).

    ``enabled=False`` (or ``max_restarts=0``) means a dead worker stays
    dead: the engine latches unhealthy and refuses intake until closed.
    Backoff doubles per restart *in the window* and is capped; the
    defaults are tuned for an in-process thread (milliseconds), not the
    out-of-process supervisor (seconds).
    """

    enabled: bool = True
    max_restarts: int = 3
    window_s: float = 60.0
    backoff_base_s: float = 0.02
    backoff_cap_s: float = 2.0


class RestartTracker:
    """Sliding-window restart accounting for one supervised worker."""

    def __init__(self, policy: RestartPolicy):
        self.policy = policy
        self._times: collections.deque = collections.deque()
        self._lock = threading.Lock()

    def next_delay(self, now: Optional[float] = None) -> Optional[float]:
        """Backoff seconds before the next permitted restart, or ``None``
        if the budget is exhausted / restarts are disabled.  Calling this
        RECORDS the restart against the window (callers restart iff the
        returned delay is not None)."""
        p = self.policy
        if not p.enabled or p.max_restarts < 1:
            return None
        if now is None:
            now = time.monotonic()
        with self._lock:
            while self._times and now - self._times[0] > p.window_s:
                self._times.popleft()
            if len(self._times) >= p.max_restarts:
                return None
            delay = (min(p.backoff_base_s * (2.0 ** len(self._times)),
                         p.backoff_cap_s)
                     if p.backoff_base_s > 0 else 0.0)
            self._times.append(now)
            return delay

    def restarts_in_window(self, now: Optional[float] = None) -> int:
        if now is None:
            now = time.monotonic()
        with self._lock:
            while self._times and now - self._times[0] > self.policy.window_s:
                self._times.popleft()
            return len(self._times)
