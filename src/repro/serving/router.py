"""Multi-basis routing: request key -> loaded ``ReducedBasis`` + EIM.

A production ROQ service holds MANY bases — e.g. one per parameter region
of the GW space, each cheap to build with the randomized sketch — but the
device cannot hold all of them at once.  :class:`BasisRouter` owns that
working set:

- ``register(basis_id, source)`` declares a basis by artifact directory
  (lazily loaded, evictable) or as an in-memory ``ReducedBasis`` (pinned:
  with no directory to reload from, evicting it would lose it).
- ``get(basis_id)`` returns the loaded ``(basis, eim)`` pair, loading on
  first use and counting the persisted-vs-recomputed EIM path.
- Loaded bases form an LRU under a device-memory budget following the
  ``REPRO_DEVICE_MEM_BUDGET`` convention (default:
  :func:`repro.api.build.device_memory_budget`); crossing it evicts
  least-recently-used directory-backed bases, firing ``on_evict`` so the
  engine can drop their warm interpolant-cache entries too.  A later
  ``get`` reloads from the artifact directory — bit-identical arrays, by
  the artifact round-trip guarantee.
- ``refresh(basis_id)`` hot-swaps a refreshed on-disk artifact (e.g. an
  ``enrich()``-ed basis, or a per-region rebuild) into live traffic: the
  candidate's NEWEST artifact step is CRC-verified first, then the
  routed entry is replaced under the lock with a bumped **generation**
  counter and ``on_refresh(basis_id, old_gen, new_gen)`` fires so the
  engine retires the old generation's warm interpolant-cache entries.
  In-flight batches that already resolved the old entry finish on the
  old generation (their arrays are immutable); a corrupt candidate
  raises and leaves the live basis untouched.
"""

from __future__ import annotations

import collections
import logging
import os
import threading
from typing import Callable, NamedTuple, Optional

import numpy as np

logger = logging.getLogger("repro.serving")


class _Entry(NamedTuple):
    basis: object          # ReducedBasis
    eim: object            # EIMResult (nodes, B)
    nbytes: int            # device working-set estimate
    evictable: bool        # directory-backed (reloadable) vs pinned
    generation: int = 0    # bumped by refresh(); keys warm-cache entries


def _entry_bytes(basis, eim) -> int:
    """Device working set of one routed basis: Q + interpolant B + nodes."""
    total = 0
    for arr in (basis.Q, eim.B, eim.nodes):
        a = np.asarray(arr)
        total += int(a.size) * int(a.dtype.itemsize)
    return total


class BasisRouter:
    def __init__(self, memory_budget_bytes: Optional[int] = None,
                 on_evict: Optional[Callable[[str], None]] = None,
                 on_refresh: Optional[Callable[[str, int, int], None]] = None,
                 metrics=None):
        if memory_budget_bytes is None:
            from repro.api.build import device_memory_budget

            memory_budget_bytes = device_memory_budget()
        self.memory_budget_bytes = int(memory_budget_bytes)
        self._on_evict = on_evict
        self._on_refresh = on_refresh
        self._metrics = metrics
        self._sources: dict[str, object] = {}   # id -> dir | ReducedBasis
        self._live: collections.OrderedDict[str, _Entry] = \
            collections.OrderedDict()           # LRU: oldest first
        self._generations: dict[str, int] = {}  # survives eviction
        self._lock = threading.RLock()

    # ---------------------------------------------------------- registry ----
    def register(self, basis_id: str, source) -> None:
        """Declare ``basis_id`` -> artifact directory or ReducedBasis.

        Directories stay on disk until routed to; an in-memory basis with
        a backing :attr:`~repro.api.ReducedBasis.directory` is registered
        by that directory (evictable), one without is pinned.
        """
        from repro.api import ReducedBasis

        with self._lock:
            if basis_id in self._sources:
                raise ValueError(f"basis_id {basis_id!r} already registered")
            if isinstance(source, (str, os.PathLike)):
                self._sources[basis_id] = os.fspath(source)
            elif isinstance(source, ReducedBasis):
                if source.directory is not None:
                    self._sources[basis_id] = source.directory
                else:
                    self._sources[basis_id] = source  # pinned
            else:
                raise TypeError(
                    f"register() wants an artifact directory or a "
                    f"ReducedBasis, got {type(source).__name__}")

    def ids(self) -> list[str]:
        with self._lock:
            return list(self._sources)

    def loaded_ids(self) -> list[str]:
        """Currently-resident ids, least recently used first."""
        with self._lock:
            return list(self._live)

    def __contains__(self, basis_id: str) -> bool:
        with self._lock:
            return basis_id in self._sources

    # ------------------------------------------------------------ lookup ----
    def get(self, basis_id: str):
        """Resident ``(basis, eim)`` for ``basis_id`` (loads, LRU-bumps,
        and evicts colder bases as needed).  KeyError on unknown ids —
        the engine turns that into a per-request failure."""
        entry = self.get_entry(basis_id)
        return entry.basis, entry.eim

    def get_entry(self, basis_id: str) -> _Entry:
        """Like :meth:`get` but returns the full routed entry, including
        the reload ``generation`` the engine keys warm-cache entries on."""
        with self._lock:
            if basis_id not in self._sources:
                raise KeyError(f"unknown basis_id {basis_id!r}; "
                               f"registered: {sorted(self._sources)}")
            entry = self._live.get(basis_id)
            if entry is None:
                entry = self._load(basis_id)
                self._live[basis_id] = entry
                self._shrink_to_budget(keep=basis_id)
            else:
                self._live.move_to_end(basis_id)
            return entry

    @staticmethod
    def _maybe_inject_load_fault(basis_id: str) -> None:
        """PR-6-convention chaos hook: ``REPRO_FAULT_SERVE_RAISE_AT_LOAD=
        <basis_id|any>`` makes the router's artifact load fail (at most
        once under ``REPRO_FAULT_ONCE``) — the consecutive-batch-failure
        signal the per-basis circuit breaker trips on."""
        at = os.environ.get("REPRO_FAULT_SERVE_RAISE_AT_LOAD")
        if not at or at not in ("any", basis_id):
            return
        from repro.checkpoint.io import _fault_once

        if _fault_once(f"serve_raise_at_load.{basis_id}"):
            raise IOError(
                f"injected router load fault for {basis_id!r} "
                f"(REPRO_FAULT_SERVE_RAISE_AT_LOAD)")

    def _load(self, basis_id: str) -> _Entry:
        from repro.api import ReducedBasis

        self._maybe_inject_load_fault(basis_id)
        source = self._sources[basis_id]
        if isinstance(source, str):
            basis = ReducedBasis.load(source)
            evictable = True
        else:
            basis = source
            evictable = False
        persisted = "_eim" in vars(basis)
        eim = basis.eim()   # instant when the artifact carried the leaves
        if self._metrics is not None:
            self._metrics.count("basis_loads")
        entry = _Entry(basis, eim, _entry_bytes(basis, eim), evictable,
                       self._generations.get(basis_id, 0))
        logger.info(
            "router loaded %r: k=%d N=%d dtype=%s eim=%s gen=%d (%.1f MiB)",
            basis_id, basis.k, basis.N, basis.Q.dtype,
            "persisted" if persisted else "computed",
            entry.generation, entry.nbytes / 2**20)
        return entry

    # ------------------------------------------------------- hot reload ----
    def verify_artifact(self, directory: str) -> int:
        """CRC-verify the NEWEST artifact step in ``directory``; returns
        the verified step number or raises ``IOError``/``KeyError``.

        Unlike :meth:`ReducedBasis.load` — which *skips* damaged steps
        and falls back to older intact ones (right for startup, wrong for
        a refresh: silently re-serving the stale artifact would report a
        successful swap that swapped nothing) — this checks exactly the
        candidate a refresh is about to go live with.
        """
        from repro.checkpoint.io import list_steps, load_checkpoint_raw

        if os.environ.get("REPRO_FAULT_SERVE_CORRUPT_RELOAD"):
            from repro.checkpoint.io import _fault_once

            if _fault_once("serve_corrupt_reload"):
                raise IOError(
                    "injected corrupt reload candidate "
                    "(REPRO_FAULT_SERVE_CORRUPT_RELOAD)")
        steps = list_steps(directory)
        if not steps:
            raise IOError(f"no artifact steps in {directory}")
        newest = steps[-1]
        tree = load_checkpoint_raw(directory, step=newest)  # raises on CRC
        if "artifact_version" not in tree:
            raise KeyError(
                f"newest step {newest} in {directory} is not a "
                f"ReducedBasis artifact")
        return newest

    def refresh(self, basis_id: str, source=None) -> int:
        """Atomically swap ``basis_id``'s live entry for the artifact now
        on disk; returns the new generation.

        The candidate (``source`` directory if given, else the registered
        one) is loaded and CRC-verified OUTSIDE the lock — a corrupt or
        unreadable candidate raises (counted as ``reload_failures``) and
        the live basis keeps serving untouched.  On success the entry is
        replaced under the lock with generation ``old+1`` and
        ``on_refresh(basis_id, old_gen, new_gen)`` fires, so the engine
        retires the old generation's warm interpolant-cache entries;
        batches already holding the old entry finish on the old
        generation.  Works on non-resident ids too (the bumped generation
        just applies to the next load).
        """
        from repro.api import ReducedBasis

        with self._lock:
            if basis_id not in self._sources:
                raise KeyError(f"unknown basis_id {basis_id!r}")
            registered = self._sources[basis_id]
            directory = os.fspath(source) if source is not None \
                else registered
        if not isinstance(directory, str):
            raise ValueError(
                f"refresh({basis_id!r}) needs an artifact directory; the "
                f"basis is registered in-memory (pinned) — pass source=")
        try:
            self.verify_artifact(directory)
            basis = ReducedBasis.load(directory)
            eim = basis.eim()
        except Exception:
            if self._metrics is not None:
                self._metrics.count("reload_failures")
            logger.exception(
                "refresh(%r) rejected candidate in %s; live basis "
                "untouched", basis_id, directory)
            raise
        with self._lock:
            old_gen = self._generations.get(basis_id, 0)
            if basis_id in self._live:
                old_gen = self._live[basis_id].generation
            new_gen = old_gen + 1
            self._generations[basis_id] = new_gen
            self._sources[basis_id] = directory
            entry = _Entry(basis, eim, _entry_bytes(basis, eim), True,
                           new_gen)
            was_live = basis_id in self._live
            self._live[basis_id] = entry   # keeps / takes LRU slot
            if was_live:
                self._live.move_to_end(basis_id)
            self._shrink_to_budget(keep=basis_id)
        if self._metrics is not None:
            self._metrics.count("reloads")
        logger.info("refresh(%r): generation %d -> %d (k=%d, %s)",
                    basis_id, old_gen, new_gen, basis.k, directory)
        if self._on_refresh is not None:
            self._on_refresh(basis_id, old_gen, new_gen)
        return new_gen

    def _shrink_to_budget(self, keep: str) -> None:
        """Evict LRU evictable entries (never ``keep``) while over budget.

        A single basis larger than the whole budget stays resident — the
        router serves it and logs, rather than thrashing or failing."""
        def resident():
            return sum(e.nbytes for e in self._live.values())

        while resident() > self.memory_budget_bytes:
            victim = next(
                (bid for bid, e in self._live.items()
                 if bid != keep and e.evictable), None)
            if victim is None:
                logger.warning(
                    "router over memory budget (%d > %d bytes) with no "
                    "evictable basis left; keeping %d resident",
                    resident(), self.memory_budget_bytes, len(self._live))
                return
            self._live.pop(victim)
            if self._metrics is not None:
                self._metrics.count("basis_evictions")
            logger.info("router evicted %r (LRU, over budget)", victim)
            if self._on_evict is not None:
                self._on_evict(victim)

    def stats(self) -> dict:
        with self._lock:
            return {
                "registered": len(self._sources),
                "resident": len(self._live),
                "resident_bytes": sum(e.nbytes
                                      for e in self._live.values()),
                "memory_budget_bytes": self.memory_budget_bytes,
                "generations": dict(self._generations),
            }
