"""Multi-basis routing: request key -> loaded ``ReducedBasis`` + EIM.

A production ROQ service holds MANY bases — e.g. one per parameter region
of the GW space, each cheap to build with the randomized sketch — but the
device cannot hold all of them at once.  :class:`BasisRouter` owns that
working set:

- ``register(basis_id, source)`` declares a basis by artifact directory
  (lazily loaded, evictable) or as an in-memory ``ReducedBasis`` (pinned:
  with no directory to reload from, evicting it would lose it).
- ``get(basis_id)`` returns the loaded ``(basis, eim)`` pair, loading on
  first use and counting the persisted-vs-recomputed EIM path.
- Loaded bases form an LRU under a device-memory budget following the
  ``REPRO_DEVICE_MEM_BUDGET`` convention (default:
  :func:`repro.api.build.device_memory_budget`); crossing it evicts
  least-recently-used directory-backed bases, firing ``on_evict`` so the
  engine can drop their warm interpolant-cache entries too.  A later
  ``get`` reloads from the artifact directory — bit-identical arrays, by
  the artifact round-trip guarantee.
"""

from __future__ import annotations

import collections
import logging
import os
import threading
from typing import Callable, NamedTuple, Optional

import numpy as np

logger = logging.getLogger("repro.serving")


class _Entry(NamedTuple):
    basis: object          # ReducedBasis
    eim: object            # EIMResult (nodes, B)
    nbytes: int            # device working-set estimate
    evictable: bool        # directory-backed (reloadable) vs pinned


def _entry_bytes(basis, eim) -> int:
    """Device working set of one routed basis: Q + interpolant B + nodes."""
    total = 0
    for arr in (basis.Q, eim.B, eim.nodes):
        a = np.asarray(arr)
        total += int(a.size) * int(a.dtype.itemsize)
    return total


class BasisRouter:
    def __init__(self, memory_budget_bytes: Optional[int] = None,
                 on_evict: Optional[Callable[[str], None]] = None,
                 metrics=None):
        if memory_budget_bytes is None:
            from repro.api.build import device_memory_budget

            memory_budget_bytes = device_memory_budget()
        self.memory_budget_bytes = int(memory_budget_bytes)
        self._on_evict = on_evict
        self._metrics = metrics
        self._sources: dict[str, object] = {}   # id -> dir | ReducedBasis
        self._live: collections.OrderedDict[str, _Entry] = \
            collections.OrderedDict()           # LRU: oldest first
        self._lock = threading.RLock()

    # ---------------------------------------------------------- registry ----
    def register(self, basis_id: str, source) -> None:
        """Declare ``basis_id`` -> artifact directory or ReducedBasis.

        Directories stay on disk until routed to; an in-memory basis with
        a backing :attr:`~repro.api.ReducedBasis.directory` is registered
        by that directory (evictable), one without is pinned.
        """
        from repro.api import ReducedBasis

        with self._lock:
            if basis_id in self._sources:
                raise ValueError(f"basis_id {basis_id!r} already registered")
            if isinstance(source, (str, os.PathLike)):
                self._sources[basis_id] = os.fspath(source)
            elif isinstance(source, ReducedBasis):
                if source.directory is not None:
                    self._sources[basis_id] = source.directory
                else:
                    self._sources[basis_id] = source  # pinned
            else:
                raise TypeError(
                    f"register() wants an artifact directory or a "
                    f"ReducedBasis, got {type(source).__name__}")

    def ids(self) -> list[str]:
        with self._lock:
            return list(self._sources)

    def loaded_ids(self) -> list[str]:
        """Currently-resident ids, least recently used first."""
        with self._lock:
            return list(self._live)

    def __contains__(self, basis_id: str) -> bool:
        with self._lock:
            return basis_id in self._sources

    # ------------------------------------------------------------ lookup ----
    def get(self, basis_id: str):
        """Resident ``(basis, eim)`` for ``basis_id`` (loads, LRU-bumps,
        and evicts colder bases as needed).  KeyError on unknown ids —
        the engine turns that into a per-request failure."""
        with self._lock:
            if basis_id not in self._sources:
                raise KeyError(f"unknown basis_id {basis_id!r}; "
                               f"registered: {sorted(self._sources)}")
            entry = self._live.get(basis_id)
            if entry is None:
                entry = self._load(basis_id)
                self._live[basis_id] = entry
                self._shrink_to_budget(keep=basis_id)
            else:
                self._live.move_to_end(basis_id)
            return entry.basis, entry.eim

    def _load(self, basis_id: str) -> _Entry:
        from repro.api import ReducedBasis

        source = self._sources[basis_id]
        if isinstance(source, str):
            basis = ReducedBasis.load(source)
            evictable = True
        else:
            basis = source
            evictable = False
        persisted = "_eim" in vars(basis)
        eim = basis.eim()   # instant when the artifact carried the leaves
        if self._metrics is not None:
            self._metrics.count("basis_loads")
        entry = _Entry(basis, eim, _entry_bytes(basis, eim), evictable)
        logger.info(
            "router loaded %r: k=%d N=%d dtype=%s eim=%s (%.1f MiB)",
            basis_id, basis.k, basis.N, basis.Q.dtype,
            "persisted" if persisted else "computed",
            entry.nbytes / 2**20)
        return entry

    def _shrink_to_budget(self, keep: str) -> None:
        """Evict LRU evictable entries (never ``keep``) while over budget.

        A single basis larger than the whole budget stays resident — the
        router serves it and logs, rather than thrashing or failing."""
        def resident():
            return sum(e.nbytes for e in self._live.values())

        while resident() > self.memory_budget_bytes:
            victim = next(
                (bid for bid, e in self._live.items()
                 if bid != keep and e.evictable), None)
            if victim is None:
                logger.warning(
                    "router over memory budget (%d > %d bytes) with no "
                    "evictable basis left; keeping %d resident",
                    resident(), self.memory_budget_bytes, len(self._live))
                return
            self._live.pop(victim)
            if self._metrics is not None:
                self._metrics.count("basis_evictions")
            logger.info("router evicted %r (LRU, over budget)", victim)
            if self._on_evict is not None:
                self._on_evict(victim)

    def stats(self) -> dict:
        with self._lock:
            return {
                "registered": len(self._sources),
                "resident": len(self._live),
                "resident_bytes": sum(e.nbytes
                                      for e in self._live.values()),
                "memory_budget_bytes": self.memory_budget_bytes,
            }
