"""Serving observability: counters, gauges, and latency reservoirs.

One :class:`ServingMetrics` instance rides along with each
:class:`~repro.serving.roq.ROQEngine`.  Every event on the request path
increments a counter here (submit / reject / timeout / error / complete,
batch flushes, interpolant-cache hits and misses, router loads and
evictions), per-request latencies and batch occupancies land in bounded
reservoirs, and :meth:`snapshot` rolls the lot into a JSON-friendly dict
with p50/p95/p99 latency via :func:`repro.timing.percentiles` — the same
quantile code the load harness uses, so benchmark rows and engine
snapshots can never disagree on method.

Thread-safety: the engine worker and any number of submitting threads
touch the same instance, so every mutation takes the one internal lock.
The reservoirs keep the most recent ``window`` samples (deque) — a
long-running engine reports *recent* tail latency, not the all-time mix.
"""

from __future__ import annotations

import collections
import threading
import time

from repro.timing import percentiles

# Counter names, fixed so snapshots are schema-stable for dashboards/tests.
COUNTERS = (
    "submitted",        # accepted onto the queue
    "rejected",         # backpressure: queue full at submit time
    "completed",        # future resolved with a result
    "errors",           # future resolved with an exception (incl. injected)
    "timeouts",         # request deadline expired before evaluation
    "batches",          # batch flushes (one interpolant evaluation each)
    "cache_hits",       # warm interpolant-cache entry served the batch
    "cache_misses",     # entry built (jit trace / device commit) on demand
    "basis_loads",      # router loaded an artifact from disk
    "basis_evictions",  # router dropped an LRU basis under memory pressure
    # --- admission control (PR 10) ---
    "shed",             # deadline-aware shed: hopeless request rejected
    "quota_rejected",   # per-client token bucket empty at submit time
    "degraded_entered",  # admission tightened (watermark crossed)
    "degraded_exited",   # admission relaxed (pressure cleared)
    # --- per-basis circuit breakers ---
    "breaker_rejected",   # request fast-failed on an open breaker
    "breaker_opened",     # CLOSED/HALF_OPEN -> OPEN transitions
    "breaker_half_open",  # OPEN -> HALF_OPEN probe transitions
    "breaker_closed",     # HALF_OPEN -> CLOSED (probe served)
    # --- engine supervision ---
    "worker_deaths",    # exception escaped the batching loop
    "worker_restarts",  # supervision brought the worker back
    # --- hot artifact reload ---
    "reloads",          # router generation swaps (refresh succeeded)
    "reload_failures",  # refresh found a corrupt/unloadable candidate
)


class ServingMetrics:
    def __init__(self, window: int = 4096):
        self._lock = threading.Lock()
        self._counts = {name: 0 for name in COUNTERS}
        self._latency_s = collections.deque(maxlen=window)
        self._occupancy = collections.deque(maxlen=window)
        self._queue_depth = 0
        self._gauges: dict[str, float] = {}
        self._started = time.perf_counter()

    # ------------------------------------------------------------ events ----
    def count(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counts[name] += n

    def observe_latency(self, seconds: float) -> None:
        with self._lock:
            self._latency_s.append(float(seconds))

    def observe_batch(self, size: int, bucket: int) -> None:
        """A flush of ``size`` live requests padded to ``bucket`` columns."""
        with self._lock:
            self._counts["batches"] += 1
            self._occupancy.append(size / float(max(bucket, 1)))

    def set_queue_depth(self, depth: int) -> None:
        with self._lock:
            self._queue_depth = int(depth)

    def set_gauge(self, name: str, value: float) -> None:
        """Free-form gauges (``degraded``, breaker states, ...) — rolled
        into the snapshot under ``gauges``."""
        with self._lock:
            self._gauges[name] = value

    def recent_p95_ms(self) -> float | None:
        """p95 over the recent-latency window (ms) — the degraded-mode
        watermark input; None before the first completion."""
        with self._lock:
            lat = list(self._latency_s)
        if not lat:
            return None
        return percentiles(lat, (95.0,))[95.0] * 1e3

    # ---------------------------------------------------------- snapshot ----
    def snapshot(self) -> dict:
        """Point-in-time rollup (JSON-serializable).

        ``latency_ms`` holds p50/p95/p99 over the recent-latency window
        (``None`` before the first completion); ``throughput_rps`` is
        completions per wall-second since construction — a coarse
        whole-run rate, not a windowed one (the load harness measures its
        own steady-state rates).
        """
        with self._lock:
            counts = dict(self._counts)
            lat = list(self._latency_s)
            occ = list(self._occupancy)
            depth = self._queue_depth
            gauges = dict(self._gauges)
            elapsed = time.perf_counter() - self._started
        snap = {
            "counters": counts,
            "queue_depth": depth,
            "gauges": gauges,
            "latency_ms": None,
            "batch_occupancy_mean": (sum(occ) / len(occ)) if occ else None,
            "cache_hit_rate": None,
            "throughput_rps": counts["completed"] / elapsed
            if elapsed > 0 else 0.0,
        }
        if lat:
            pct = percentiles(lat, (50.0, 95.0, 99.0))
            snap["latency_ms"] = {
                "p50": pct[50.0] * 1e3,
                "p95": pct[95.0] * 1e3,
                "p99": pct[99.0] * 1e3,
                "n": len(lat),
            }
        probes = counts["cache_hits"] + counts["cache_misses"]
        if probes:
            snap["cache_hit_rate"] = counts["cache_hits"] / probes
        return snap
