"""Admission control for the ROQ serving engine: quotas, shedding, breakers.

The engine's bounded queue (PR 8) is the *last* line of overload defense —
by the time :class:`~repro.serving.roq.QueueFullError` fires, every
accepted request is already paying queueing delay.  This module is the
layer in FRONT of ``submit``:

- **Per-client token-bucket quotas** — each ``client_id`` draws from its
  own :class:`TokenBucket` (``client_rate`` req/s refill, ``client_burst``
  capacity); an empty bucket rejects with :class:`QuotaExceededError`
  *before* the request touches the queue, so one chatty client cannot
  starve the rest.  Requests without a ``client_id`` share one anonymous
  bucket.  Quotas are off until a rate is configured.
- **Deadline-aware shedding** — a request whose deadline is *already*
  hopeless given the estimated queue delay (backlog batches x the EWMA
  batch service time, supplied by the engine) is rejected with
  :class:`ShedError` instead of occupying a batch slot it can only
  time out in.  Hopeless work never displaces feasible work.
- **Degraded mode** — when the engine reports pressure past the
  configured watermarks (queue depth fraction, p95 latency), quotas
  tighten by ``degraded_factor`` until pressure clears (with hysteresis,
  so the mode doesn't flap at the watermark).  Entered/exited transitions
  are counted in the serving metrics.
- **Per-basis circuit breakers** — :class:`CircuitBreakerBoard` tracks
  consecutive *batch* failures per basis.  ``threshold`` consecutive
  failures OPEN the breaker: new requests fast-fail with
  :class:`CircuitOpenError` instead of queueing behind a basis that
  cannot serve.  After ``cooldown_s`` the next request flips it
  HALF_OPEN and a bounded probe batch is admitted; a served probe
  CLOSEs the breaker, a failed one re-OPENs it with a fresh cooldown.
  Every transition is counted.

All state is engine-internal and thread-safe; none of it touches the
worker's hot path beyond one lock acquisition per submit.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Optional


class ShedError(RuntimeError):
    """Admission shed: the request's deadline is already hopeless given
    the estimated queue delay — rejected instead of queued to time out."""


class QuotaExceededError(RuntimeError):
    """Per-client token bucket empty: the client is over its quota."""


class CircuitOpenError(RuntimeError):
    """The target basis's circuit breaker is open (recent consecutive
    batch failures); requests fast-fail instead of queueing."""


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s refill up to ``burst``.

    Not self-locking — the owning controller serializes access."""

    __slots__ = ("rate", "burst", "tokens", "t_last")

    def __init__(self, rate: float, burst: float, now: float):
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self.t_last = now

    def try_acquire(self, now: float, *, rate_scale: float = 1.0) -> bool:
        """Take one token if available (refilled at ``rate*rate_scale``)."""
        self.tokens = min(
            self.burst,
            self.tokens + (now - self.t_last) * self.rate * rate_scale)
        self.t_last = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


class AdmissionController:
    """Quotas + shedding + degraded mode, consulted by ``submit``.

    Args:
      client_rate: per-client steady admission rate (req/s); ``None``
        disables quotas entirely.
      client_burst: bucket capacity (default ``max(2*client_rate, 4)``).
      degraded_factor: multiplier on the refill rate while degraded.
      delay_estimator: callable returning the engine's current estimated
        queue delay in seconds (0 = no backlog / no history yet).
      metrics: a :class:`~repro.serving.metrics.ServingMetrics` (or None)
        that receives the ``degraded_entered``/``degraded_exited``
        counters and the ``degraded`` gauge.
    """

    def __init__(self, *, client_rate: Optional[float] = None,
                 client_burst: Optional[float] = None,
                 degraded_factor: float = 0.5,
                 delay_estimator: Optional[Callable[[], float]] = None,
                 metrics=None):
        if client_rate is not None and client_rate <= 0:
            raise ValueError("client_rate must be positive (or None)")
        self.client_rate = client_rate
        self.client_burst = (float(client_burst) if client_burst is not None
                             else max(2.0 * (client_rate or 0.0), 4.0))
        self.degraded_factor = float(degraded_factor)
        self._delay_estimator = delay_estimator or (lambda: 0.0)
        self._metrics = metrics
        self._buckets: dict = {}
        self._degraded = False
        self._lock = threading.Lock()

    # ----------------------------------------------------------- intake ----
    def admit(self, client_id, deadline: Optional[float],
              now: Optional[float] = None) -> None:
        """Raise :class:`QuotaExceededError` / :class:`ShedError`, or
        return to admit.  ``deadline`` is absolute ``perf_counter`` time
        (None = no deadline, never shed)."""
        if now is None:
            now = time.perf_counter()
        if self.client_rate is not None:
            with self._lock:
                bucket = self._buckets.get(client_id)
                if bucket is None:
                    bucket = TokenBucket(self.client_rate,
                                         self.client_burst, now)
                    self._buckets[client_id] = bucket
                scale = self.degraded_factor if self._degraded else 1.0
                ok = bucket.try_acquire(now, rate_scale=scale)
            if not ok:
                if self._metrics is not None:
                    self._metrics.count("quota_rejected")
                raise QuotaExceededError(
                    f"client {client_id!r} over quota "
                    f"({self.client_rate:g} req/s, burst "
                    f"{self.client_burst:g}"
                    + (", degraded" if self._degraded else "") + ")")
        if deadline is not None:
            est = self._delay_estimator()
            if est > 0.0 and deadline - now < est:
                if self._metrics is not None:
                    self._metrics.count("shed")
                raise ShedError(
                    f"estimated queue delay {est * 1e3:.1f}ms exceeds the "
                    f"request's remaining {max(deadline - now, 0) * 1e3:.1f}"
                    f"ms deadline; shed instead of queued to time out")

    # --------------------------------------------------------- pressure ----
    def set_degraded(self, degraded: bool, reason: str = "") -> bool:
        """Flip degraded mode; returns True if the state changed."""
        with self._lock:
            if degraded == self._degraded:
                return False
            self._degraded = degraded
        if self._metrics is not None:
            self._metrics.count(
                "degraded_entered" if degraded else "degraded_exited")
            self._metrics.set_gauge("degraded", int(degraded))
        return True

    @property
    def degraded(self) -> bool:
        return self._degraded

    def stats(self) -> dict:
        with self._lock:
            return {
                "quotas_enabled": self.client_rate is not None,
                "client_rate": self.client_rate,
                "client_burst": (self.client_burst
                                 if self.client_rate is not None else None),
                "degraded": self._degraded,
                "degraded_factor": self.degraded_factor,
                "clients_tracked": len(self._buckets),
            }


# ------------------------------------------------------------- breakers ----

_CLOSED, _OPEN, _HALF_OPEN = "closed", "open", "half_open"


class _Breaker:
    __slots__ = ("state", "consecutive_failures", "opened_at",
                 "probes_admitted", "probe_inflight")

    def __init__(self):
        self.state = _CLOSED
        self.consecutive_failures = 0
        self.opened_at = 0.0
        self.probes_admitted = 0
        self.probe_inflight = False


class CircuitBreakerBoard:
    """Per-basis circuit breakers over consecutive batch failures.

    Args:
      threshold: consecutive batch failures that OPEN a basis's breaker.
      cooldown_s: OPEN -> HALF_OPEN after this long without traffic
        being admitted.
      probe_budget: requests admitted in HALF_OPEN before fast-failing
        again (the engine passes ``max_batch`` so the probe is one batch).
      metrics: receives ``breaker_opened`` / ``breaker_half_open`` /
        ``breaker_closed`` / ``breaker_rejected`` counters.
    """

    def __init__(self, *, threshold: int = 5, cooldown_s: float = 5.0,
                 probe_budget: int = 1, metrics=None):
        if threshold < 1:
            raise ValueError("breaker threshold must be >= 1")
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self.probe_budget = max(int(probe_budget), 1)
        self._metrics = metrics
        self._breakers: dict[str, _Breaker] = {}
        self._lock = threading.Lock()

    def _count(self, name: str) -> None:
        if self._metrics is not None:
            self._metrics.count(name)

    def allow(self, basis_id: str, now: Optional[float] = None) -> None:
        """Admit a request for ``basis_id`` or raise
        :class:`CircuitOpenError` (counted as ``breaker_rejected``)."""
        if now is None:
            now = time.perf_counter()
        with self._lock:
            br = self._breakers.get(basis_id)
            if br is None or br.state == _CLOSED:
                return
            if br.state == _OPEN:
                if now - br.opened_at < self.cooldown_s:
                    remaining = self.cooldown_s - (now - br.opened_at)
                    self._count("breaker_rejected")
                    raise CircuitOpenError(
                        f"circuit for basis {basis_id!r} is open "
                        f"({br.consecutive_failures} consecutive batch "
                        f"failures); probe in {remaining * 1e3:.0f}ms")
                br.state = _HALF_OPEN
                br.probes_admitted = 0
                br.probe_inflight = False
                self._count("breaker_half_open")
            # HALF_OPEN: admit up to probe_budget requests for ONE probe
            # batch; everything else fast-fails until the probe resolves.
            if br.probes_admitted < self.probe_budget \
                    and not br.probe_inflight:
                br.probes_admitted += 1
                return
            self._count("breaker_rejected")
            raise CircuitOpenError(
                f"circuit for basis {basis_id!r} is half-open with its "
                f"probe batch in flight; fast-failing until it resolves")

    def on_batch_start(self, basis_id: str) -> None:
        """The worker is evaluating a batch for ``basis_id`` — in
        HALF_OPEN this freezes further probe admissions until the batch
        resolves one way or the other."""
        with self._lock:
            br = self._breakers.get(basis_id)
            if br is not None and br.state == _HALF_OPEN:
                br.probe_inflight = True

    def record_success(self, basis_id: str) -> None:
        with self._lock:
            br = self._breakers.get(basis_id)
            if br is None:
                return
            if br.state == _HALF_OPEN:
                self._count("breaker_closed")
            br.state = _CLOSED
            br.consecutive_failures = 0
            br.probe_inflight = False

    def record_failure(self, basis_id: str,
                       now: Optional[float] = None) -> None:
        if now is None:
            now = time.perf_counter()
        with self._lock:
            br = self._breakers.setdefault(basis_id, _Breaker())
            br.consecutive_failures += 1
            br.probe_inflight = False
            if br.state == _HALF_OPEN or (
                    br.state == _CLOSED
                    and br.consecutive_failures >= self.threshold):
                br.state = _OPEN
                br.opened_at = now
                self._count("breaker_opened")

    def state(self, basis_id: str) -> str:
        with self._lock:
            br = self._breakers.get(basis_id)
            return br.state if br is not None else _CLOSED

    def stats(self) -> dict:
        with self._lock:
            return {
                "threshold": self.threshold,
                "cooldown_s": self.cooldown_s,
                "states": {bid: br.state
                           for bid, br in self._breakers.items()
                           if br.state != _CLOSED
                           or br.consecutive_failures > 0},
            }
