"""ShapeDtypeStruct input specs + shardings for every (arch x shape) cell.

``input_specs(cfg, shape, mesh)`` returns everything the dry-run needs to
lower a cell without allocating a byte: abstract params/optimizer state,
abstract batch or cache, and the matching NamedShardings (weak-type-correct
stand-ins; the same pattern the real launchers use for real arrays).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_size
from repro.models import api
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.optim.adamw import AdamWState
from repro.sharding import resolve, tree_shardings
from repro.training.trainer import TrainState


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _is_spec_leaf(s):
    return isinstance(s, tuple) and all(
        x is None or isinstance(x, str) for x in s
    )


def sanitize_sharding(sh: NamedSharding, shape, mesh) -> NamedSharding:
    """Drop sharding on any dim the axis sizes don't evenly divide.

    Explicit input shardings (unlike internal GSPMD constraints) require
    even divisibility — e.g. granite's vocab 49155 or seamless's 256206
    cannot shard 16 ways, so those dims fall back to replicated.
    """
    spec = sh.spec
    new = []
    for d, ax in enumerate(spec):
        if ax is None:
            new.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        prod = 1
        for a in axes:
            prod *= mesh.shape[a]
        new.append(ax if shape[d] % prod == 0 else None)
    new += [None] * (len(shape) - len(new))
    return NamedSharding(mesh, P(*new))


def param_shardings(cfg: ModelConfig, mesh):
    return tree_shardings(mesh, api.param_specs(cfg))


def abstract_sharded_params(cfg: ModelConfig, mesh):
    """Params as ShapeDtypeStructs carrying (sanitized) NamedShardings."""
    shapes = api.abstract_params(cfg)
    shards = param_shardings(cfg, mesh)
    return jax.tree.map(
        lambda s, sh: _sds(
            s.shape, s.dtype, sanitize_sharding(sh, s.shape, mesh)
        ),
        shapes, shards,
    )


def abstract_train_state(cfg: ModelConfig, mesh) -> TrainState:
    params = abstract_sharded_params(cfg, mesh)
    rep = NamedSharding(mesh, P())
    moments = jax.tree.map(
        lambda p: _sds(p.shape, jnp.float32, p.sharding), params
    )
    return TrainState(
        params=params,
        opt=AdamWState(
            m=moments,
            v=jax.tree.map(lambda m: m, moments),
            step=_sds((), jnp.int32, rep),
        ),
        ef=None,
        step=_sds((), jnp.int32, rep),
    )


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                seq_override: Optional[int] = None) -> dict:
    """Training/prefill batch ShapeDtypeStructs with dp sharding."""
    B = shape.global_batch
    S = seq_override or shape.seq_len
    dp = dp_size(mesh)
    bspec = "dp" if B % dp == 0 and B >= dp else None
    tok_sh = NamedSharding(mesh, resolve(mesh, bspec, None))
    out = {
        "tokens": _sds((B, S), jnp.int32, tok_sh),
        "labels": _sds((B, S), jnp.int32, tok_sh),
    }
    from repro.models.layers import dtype_of

    dt = dtype_of(cfg.dtype)
    if cfg.family == "vlm":
        out["vision"] = _sds(
            (B, cfg.vision_tokens, cfg.vision_dim), dt,
            NamedSharding(mesh, resolve(mesh, bspec, None, None)),
        )
    if cfg.family == "encdec":
        out["frames"] = _sds(
            (B, cfg.audio_frames, cfg.audio_dim), dt,
            NamedSharding(mesh, resolve(mesh, bspec, None, None)),
        )
    return out


# ------------------------------------------------------------ cache sharding
def cache_shardings(cfg: ModelConfig, mesh, cache_tree, batch: int):
    """Per-leaf NamedShardings for a decode cache pytree.

    Rules (by leaf role, matched on the key path):
      kv k/v        (..., B, S, K, hd): B -> dp (if divisible), S -> tp
      cross k/v     (..., B, S_mem, K, hd): B -> dp only
      ssm conv      (..., B, W, C): C -> tp
      ssm state     (..., B, H, P, N): H -> tp
      lru conv      (..., B, W, w): w -> tp
      lru h         (..., B, w): w -> tp
      pos / scalars: replicated
    """
    dp = dp_size(mesh)
    b_ok = batch % dp == 0 and batch >= dp

    def spec_for(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = str(keys[-1]) if keys else ""
        nd = leaf.ndim
        lead = nd  # count leading stack dims by matching trailing roles

        def dims(*trailing):
            return [None] * (nd - len(trailing)) + list(trailing)

        if name in ("k_scale", "v_scale"):
            d = dims("dp" if b_ok else None, "tp", None, None)
        elif name in ("k", "v"):
            is_cross = any("cross" in str(k) for k in keys)
            if is_cross:
                d = dims("dp" if b_ok else None, None, None, None)
            else:
                d = dims("dp" if b_ok else None, "tp", None, None)
        elif name in ("cross_k", "cross_v"):
            d = dims("dp" if b_ok else None, None, None, None)
        elif name == "conv":
            d = dims("dp" if b_ok else None, None, "tp")
        elif name == "state":
            d = dims("dp" if b_ok else None, "tp", None, None)
        elif name == "h":
            d = dims("dp" if b_ok else None, "tp")
        elif name == "cross_kv" or (name.isdigit() and nd == 5):
            # vlm cross memory tuple entries (n_groups, B, vis, K, hd)
            d = dims("dp" if b_ok else None, None, None, None)
        else:  # pos etc.
            d = [None] * nd
        return NamedSharding(mesh, resolve(mesh, *d))

    leaves = jax.tree_util.tree_flatten_with_path(cache_tree)[0]
    treedef = jax.tree_util.tree_structure(cache_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in leaves]
    )


def abstract_cache(cfg: ModelConfig, mesh, batch: int, max_len: int):
    shapes = jax.eval_shape(lambda: api.init_cache(cfg, batch, max_len))
    shards = cache_shardings(cfg, mesh, shapes, batch)
    return jax.tree.map(
        lambda s, sh: _sds(
            s.shape, s.dtype, sanitize_sharding(sh, s.shape, mesh)
        ),
        shapes, shards,
    )


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """(token, cache) specs for a decode cell (cache holds seq_len context)."""
    B = shape.global_batch
    dp = dp_size(mesh)
    bspec = "dp" if B % dp == 0 and B >= dp else None
    tok = _sds((B,), jnp.int32, NamedSharding(mesh, resolve(mesh, bspec)))
    cache = abstract_cache(cfg, mesh, B, shape.seq_len)
    return tok, cache


def n_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Grad-accumulation depth: ~1 sample/device/microbatch for big models."""
    dp = dp_size(mesh)
    per_dp = max(1, shape.global_batch // dp)
    per_micro = 1 if cfg.d_model >= 4096 else 4
    return max(1, per_dp // per_micro)
