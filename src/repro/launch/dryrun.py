import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax import: jax locks the device count
at first init, and the production meshes need 512 placeholder host devices.
Smoke tests / benches never import this module, so they see 1 device.

Per cell this script:
  1. builds the jitted step (train_step / prefill / serve_step) with the
     production shardings from launch/specs.py,
  2. ``.lower().compile()`` on the requested mesh — success IS the test,
  3. records ``compiled.memory_analysis()`` (fits-in-HBM evidence) and
     ``compiled.cost_analysis()`` + the partitioned-HLO collective bytes,
  4. optionally re-lowers the roofline variant (layers unrolled, einsum
     attention, no grad-accum scan) at 1 and 2 layer-groups and fits the
     exact per-device FLOPs/bytes/collective-bytes linearly in depth
     (see launch/roofline.py for why scans undercount),
  5. writes one JSON artifact per cell under --out.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k \
      --mesh single --mode both --out artifacts/dryrun
  python -m repro.launch.dryrun --all --mesh multi --mode full
"""

import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import arch_ids, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as S
from repro.launch import roofline as R
from repro.models import api
from repro.models.config import SHAPES
from repro.models.transformer import unroll_layers
from repro.sharding import use_mesh
from repro.training.trainer import make_train_step

# archs whose attention is full/quadratic: long_500k is skipped (DESIGN.md).
FULL_ATTENTION_ARCHS = {
    "llama4-maverick-400b-a17b", "starcoder2-15b", "stablelm-3b",
    "granite-3-8b", "qwen1.5-110b", "llama-3.2-vision-11b",
    "seamless-m4t-medium",
}


def cell_is_skipped(arch: str, shape_name: str):
    if shape_name == "long_500k" and arch in FULL_ATTENTION_ARCHS:
        return "long_500k needs sub-quadratic attention; full-attention arch"
    return None


def shape_overrides(cfg, shape):
    """Per-shape config tweaks (documented in EXPERIMENTS.md)."""
    kw = {}
    if shape.kind == "prefill" and shape.seq_len > 8192:
        kw["attn_chunk"] = 512
    if cfg.family == "encdec" and shape.kind != "train":
        # decode/prefill keep the spec'd 4096-frame encoder memory
        pass
    return cfg.replace(**kw) if kw else cfg


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (fn, args) ready to lower for the cell."""
    shape = SHAPES[shape_name]
    cfg = shape_overrides(get_config(arch), shape)

    if shape.kind == "train":
        n_micro = S.n_microbatches(cfg, shape, mesh)
        step = make_train_step(cfg, n_microbatches=n_micro, donate=False)
        state = S.abstract_train_state(cfg, mesh)
        batch = S.batch_specs(cfg, shape, mesh)
        return step, (state, batch), {"n_microbatches": n_micro}

    params = S.abstract_sharded_params(cfg, mesh)
    if shape.kind == "prefill":
        fn = jax.jit(
            functools.partial(api.prefill, cfg, max_len=shape.seq_len)
        )
        batch = S.batch_specs(cfg, shape, mesh)
        return fn, (params, batch), {}

    # decode
    fn = jax.jit(functools.partial(api.decode_step, cfg))
    tok, cache = S.decode_specs(cfg, shape, mesh)
    return fn, (params, tok, cache), {}


def run_full(arch: str, shape_name: str, mesh, mesh_name: str):
    fn, args, extra = build_cell(arch, shape_name, mesh)
    t0 = time.time()
    with use_mesh(mesh):
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_rec = {}
    if mem is not None:
        for attr in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            if hasattr(mem, attr):
                mem_rec[attr] = int(getattr(mem, attr))
    print(f"[{arch} {shape_name} {mesh_name}] memory_analysis: {mem_rec}")

    hlo = compiled.as_text()
    raw = R.cost_terms(compiled, hlo)
    print(
        f"[{arch} {shape_name} {mesh_name}] cost_analysis(raw, scans "
        f"counted once): flops={raw['flops']:.3e} bytes={raw['bytes']:.3e} "
        f"coll={raw['collective_bytes']:.3e}"
    )
    return {
        "ok": True,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "memory": mem_rec,
        "raw_cost": {k: v for k, v in raw.items()
                     if k != "collective_detail"},
        "collective_detail": raw["collective_detail"],
        **extra,
    }


# ---------------------------------------------------------- roofline variant
def _depth_variants(cfg):
    """(cfg_small_list, n_units_list, full_units, unit_extras).

    Returns configs at 1 and 2 repeating layer-groups for the linear fit.
    """
    if cfg.family == "vlm":
        per = cfg.cross_every
        return (
            [cfg.replace(n_layers=per), cfg.replace(n_layers=2 * per)],
            [1, 2], cfg.n_layers // per,
        )
    if cfg.family == "hybrid":
        per = cfg.attn_every
        # fit in super-blocks; the 38-layer config has a 2-rec tail that the
        # fit counts as 2/3 of a super-block (documented approximation)
        return (
            [cfg.replace(n_layers=per), cfg.replace(n_layers=2 * per)],
            [1, 2], cfg.n_layers / per,
        )
    if cfg.family == "encdec":
        # fit decoder depth with 1 encoder layer, then add encoder fit
        return (
            [cfg.replace(n_layers=1, encoder_layers=1),
             cfg.replace(n_layers=2, encoder_layers=1)],
            [1, 2], cfg.n_layers,
        )
    return (
        [cfg.replace(n_layers=1), cfg.replace(n_layers=2)],
        [1, 2], cfg.n_layers,
    )


def _roofline_lower(cfg, shape, mesh, seq_override=None):
    cfg = cfg.replace(attn_impl="einsum", remat=False)
    if shape.kind == "train":
        step = make_train_step(cfg, n_microbatches=1, donate=False)
        args = (
            S.abstract_train_state(cfg, mesh),
            S.batch_specs(cfg, shape, mesh, seq_override=seq_override),
        )
        fn = step
    elif shape.kind == "prefill":
        fn = jax.jit(functools.partial(
            api.prefill, cfg, max_len=seq_override or shape.seq_len
        ))
        args = (
            S.abstract_sharded_params(cfg, mesh),
            S.batch_specs(cfg, shape, mesh, seq_override=seq_override),
        )
    else:
        fn = jax.jit(functools.partial(api.decode_step, cfg))
        tok, cache = S.decode_specs(cfg, shape, mesh)
        args = (S.abstract_sharded_params(cfg, mesh), tok, cache)
    with use_mesh(mesh), unroll_layers():
        compiled = fn.lower(*args).compile()
    return R.cost_terms(compiled)


def run_roofline(arch: str, shape_name: str, mesh, mesh_name: str):
    shape = SHAPES[shape_name]
    cfg = shape_overrides(get_config(arch), shape)

    # SSD chunk scans are inside each block; lower at T0 = ssm_chunk (one
    # chunk -> exact) and scale by T/T0 (every term in this family is
    # linear in T).  Decode is single-token: no scaling.
    seq_override = None
    seq_scale = 1.0
    if cfg.family == "ssm" and shape.kind != "decode":
        seq_override = cfg.ssm_chunk
        seq_scale = shape.seq_len / seq_override

    variants, units, full_units = _depth_variants(cfg)
    c1 = _roofline_lower(variants[0], shape, mesh, seq_override)
    c2 = _roofline_lower(variants[1], shape, mesh, seq_override)
    fitted = R.fit_linear(c1, c2, units[0], units[1], full_units)

    if cfg.family == "encdec":
        # add encoder depth: fit encoder at 1,2 with decoder fixed at 1
        e2 = _roofline_lower(
            cfg.replace(n_layers=1, encoder_layers=2), shape, mesh,
            seq_override,
        )
        for k in ("flops", "bytes", "collective_bytes"):
            enc_per_layer = e2[k] - c1[k]
            fitted[k] += enc_per_layer * (cfg.encoder_layers - 1)

    for k in ("flops", "bytes", "collective_bytes"):
        fitted[k] *= seq_scale

    sec = R.roofline_seconds(fitted)
    mf = R.model_flops(cfg, shape, backward=(shape.kind == "train"))
    n_dev = mesh.size
    useful = mf / max(fitted["flops"] * n_dev, 1.0)
    rec = {
        "fitted_per_device": fitted,
        "roofline": sec,
        "model_flops_global": mf,
        "useful_flop_ratio": useful,
        "roofline_fraction": min(useful, 1.0) if sec["dominant"] == "compute"
        else None,
    }
    print(
        f"[{arch} {shape_name} {mesh_name}] roofline: "
        f"compute={sec['compute_s']:.4f}s memory={sec['memory_s']:.4f}s "
        f"collective={sec['collective_s']:.4f}s dominant={sec['dominant']} "
        f"useful_ratio={useful:.3f}"
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--mode", choices=["full", "roofline", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    os.makedirs(args.out, exist_ok=True)

    archs = arch_ids() if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            tag = f"{arch}__{shape_name}__{args.mesh}"
            path = os.path.join(args.out, tag + ".json")
            rec = {"arch": arch, "shape": shape_name, "mesh": args.mesh,
                   "devices": mesh.size}
            skip = cell_is_skipped(arch, shape_name)
            if skip:
                rec["skipped"] = skip
                print(f"[{tag}] SKIP: {skip}")
            else:
                try:
                    if args.mode in ("full", "both"):
                        rec["full"] = run_full(arch, shape_name, mesh,
                                               args.mesh)
                    if args.mode in ("roofline", "both"):
                        rec["roofline"] = run_roofline(
                            arch, shape_name, mesh, args.mesh
                        )
                except Exception as e:
                    n_fail += 1
                    rec["error"] = f"{type(e).__name__}: {e}"
                    rec["traceback"] = traceback.format_exc()
                    print(f"[{tag}] FAIL: {type(e).__name__}: {e}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1, default=str)
    print(f"dry-run done; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
