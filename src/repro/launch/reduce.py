import os

if os.environ.get("REPRO_DRYRUN"):  # must precede any jax import
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Distributed greedy-reduction launcher (the paper's production job).

Two modes:

  real   — build the snapshot matrix column-sharded over the current mesh
           (each device generates its own parameter slice, greedycpp-style),
           run distributed RB-greedy with periodic checkpointing, export
           basis/pivots/EI nodes.

  dryrun — REPRO_DRYRUN=1: lower + compile one distributed-greedy step at
           the Blue Waters flagship shape (10,000 x 3,276,800 complex64,
           ~0.5 TB) on the 256- or 512-device production mesh, and report
           memory/cost/collective analysis.  No data is allocated
           (ShapeDtypeStruct stand-ins).

Usage:
  python -m repro.launch.reduce --tau 1e-6 --out basis/      # real (small)
  REPRO_DRYRUN=1 python -m repro.launch.reduce --mesh multi  # flagship
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_auto_mesh
from repro.configs.gw_greedy import CONFIG as GW_CONFIG, reduced as gw_reduced
from repro.core.distributed import (
    DistGreedyState,
    make_dist_greedy_step,
    state_shardings,
)
from repro.launch import roofline as R
from repro.launch.mesh import make_production_mesh


def dryrun(mesh_kind: str, out_dir: str):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    wl = GW_CONFIG
    n_dev = mesh.size
    # pad columns to divide the device count (the real launcher does the
    # same: greedycpp distributes N/P column blocks)
    M = ((wl.n_cols + n_dev - 1) // n_dev) * n_dev
    N = wl.n_rows
    dt = jnp.complex64

    cols = P(None, tuple(mesh.axis_names))
    s_sds = jax.ShapeDtypeStruct((N, M), dt, sharding=NamedSharding(mesh, cols))
    sh = state_shardings(mesh)
    rdt = jnp.float32

    def sds(shape, dtype, s):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=s)

    state = DistGreedyState(
        Q=sds((N, wl.max_k), dt, sh.Q),
        R=sds((wl.max_k, M), dt, sh.R),
        norms_sq=sds((M,), rdt, sh.norms_sq),
        acc=sds((M,), rdt, sh.acc),
        pivots=sds((wl.max_k,), jnp.int32, sh.pivots),
        errs=sds((wl.max_k,), rdt, sh.errs),
        k=sds((), jnp.int32, sh.k),
    )

    step = make_dist_greedy_step(mesh)
    t0 = time.time()
    lowered = step.lower(s_sds, state)
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_rec = {
        a: int(getattr(mem, a))
        for a in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes")
        if mem is not None and hasattr(mem, a)
    }
    terms = R.cost_terms(compiled)
    sec = R.roofline_seconds(terms)
    # useful flops of one iteration: c = q^H S -> 8*N*M/P complex flops
    useful = 8.0 * N * (M / n_dev)
    rec = {
        "workload": wl.name,
        "mesh": mesh_kind,
        "devices": n_dev,
        "shape": [N, M],
        "dtype": str(dt.__name__ if hasattr(dt, "__name__") else dt),
        "compile_s": t_compile,
        "memory": mem_rec,
        "per_device_cost": {k: v for k, v in terms.items()
                            if k != "collective_detail"},
        "collective_detail": terms["collective_detail"],
        "roofline": sec,
        "useful_flops_per_device": useful,
        "useful_flop_ratio": useful / max(terms["flops"], 1.0),
    }
    print(json.dumps(rec, indent=1, default=str))
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(
            out_dir, f"gw_greedy__{mesh_kind}.json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def real_run(tau: float, out: str, small: bool, chunk: int = 16,
             backend: str | None = None, strategy: str = "distributed",
             workdir: str | None = None, resume: bool = False,
             tile_m: int = 4096):
    from repro.api import ReductionSpec, build_basis
    from repro.checkpoint import save_checkpoint
    from repro.data.providers import WaveformProvider
    from repro.gw import build_snapshot_matrix, chirp_grid, frequency_grid

    wl = gw_reduced() if small else GW_CONFIG
    f = frequency_grid(20.0, 512.0, wl.n_rows)
    n_cols = wl.n_cols
    m1, m2 = chirp_grid(n_mc=n_cols // 16, n_eta=16)

    common = dict(
        tau=wl.tau, max_k=wl.max_k, chunk=chunk, backend=backend,
        workdir=workdir, resume=resume,
    )
    if strategy == "distributed":
        devs = jax.devices()
        mesh = make_auto_mesh((len(devs),), ("cols",))
        sharding = NamedSharding(mesh, P(None, ("cols",)))
        S = build_snapshot_matrix(f, m1, m2, dtype=jnp.complex64,
                                  sharding=sharding)
        if workdir is None:
            # Legacy standalone checkpointing; with a workdir the build
            # lifecycle owns its own <workdir>/build/ checkpoints.
            os.makedirs(out, exist_ok=True)
            ckpt_dir = os.path.join(out, "ckpt")
            # The chunked driver invokes the callback once per chunk (k
            # advances by up to `chunk` between calls), so checkpoint on
            # an interval threshold rather than an exact k % 25 == 0 hit.
            last_ckpt = [0]

            def cb(state):
                k = int(state.k)
                if k - last_ckpt[0] >= 25:
                    save_checkpoint(state, ckpt_dir, k)
                    last_ckpt[0] = k

            common["callback"] = cb
        spec = ReductionSpec(source=S, strategy="distributed", mesh=mesh,
                             **common)
    else:
        # Every other strategy reads the snapshot columns through a
        # provider: "streamed" never materializes the matrix (tiles are
        # generated on the fly, greedycpp's generate-your-slice strategy);
        # resident strategies materialize it once on device.
        prov = WaveformProvider(f, m1, m2, dtype=jnp.complex64)
        spec = ReductionSpec(
            source=prov, strategy=strategy, tile_m=tile_m,
            checkpoint_every_tiles=1 if workdir is not None else 0,
            **common)

    t0 = time.time()
    basis = build_basis(spec)
    k = basis.k
    print(f"greedy k={k} in {time.time()-t0:.1f}s; "
          f"final err={float(basis.errs[max(k-1, 0)]):.3e}; "
          f"stop={basis.provenance.get('stop')}")
    os.makedirs(out, exist_ok=True)
    # the durable artifact (Q/R/pivots/errs + provenance; serve with
    # `python -m repro.launch.serve --basis <dir>`): with a workdir the
    # build already finalized it there; otherwise save under out/ ...
    if workdir is None:
        basis.save(os.path.join(out, "basis"))
    # ... plus the legacy flat exports
    np.save(os.path.join(out, "basis.npy"), np.asarray(basis.Q))
    np.save(os.path.join(out, "pivots.npy"), np.asarray(basis.pivots))
    ei = basis.eim()
    np.save(os.path.join(out, "ei_nodes.npy"), np.asarray(ei.nodes))
    print(f"exported ReducedBasis artifact + {k} EI nodes to {out}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--tau", type=float, default=1e-6)
    ap.add_argument("--out", default="artifacts/reduce")
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--chunk", type=int, default=16,
                    help="greedy iterations per device-resident chunk "
                         "(1 = seed per-iteration cadence)")
    ap.add_argument("--backend",
                    choices=["auto", "xla", "pallas", "xla_ref"],
                    default=None,
                    help="hot-loop primitive backend (default: auto — "
                         "Pallas kernels on TPU, jnp/XLA elsewhere; "
                         "xla_ref = seed reference ops baseline)")
    ap.add_argument("--strategy",
                    choices=["distributed", "streamed", "greedy",
                             "block_greedy", "auto"],
                    default="distributed",
                    help="reduction strategy (streamed generates waveform "
                         "tiles on the fly and never materializes S)")
    ap.add_argument("--workdir", default=None,
                    help="build-lifecycle directory: checkpoints in "
                         "<workdir>/build/, finalized artifact in "
                         "<workdir>; resumable and supervisor-safe")
    ap.add_argument("--resume", action="store_true",
                    help="resume from --workdir checkpoints (or return "
                         "the already-finalized artifact)")
    ap.add_argument("--tile-m", type=int, default=4096,
                    help="streamed tile width in columns")
    args = ap.parse_args()
    if os.environ.get("REPRO_DRYRUN"):
        dryrun(args.mesh, args.out)
    else:
        real_run(args.tau, args.out, args.small, chunk=args.chunk,
                 backend=args.backend, strategy=args.strategy,
                 workdir=args.workdir, resume=args.resume,
                 tile_m=args.tile_m)


if __name__ == "__main__":
    main()
