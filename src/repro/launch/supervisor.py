"""Fault-tolerance supervisor: run, watch, restart-from-checkpoint.

Wraps any launcher subprocess (train / reduce).  On non-zero exit or on a
heartbeat stall (straggler / hang mitigation) the job is killed and
relaunched; because checkpoints are atomic and the data pipeline is
step-keyed, the relaunch resumes bit-identically from the last checkpoint
(tested in tests/test_fault_tolerance.py).
"""

from __future__ import annotations

import argparse
import collections
import os
import signal
import subprocess
import sys
import time


def run_supervised(
    cmd: list[str],
    max_restarts: int = 3,
    stall_timeout_s: float | None = None,
    log_path: str | None = None,
    backoff_base_s: float = 0.5,
    backoff_cap_s: float = 30.0,
    restart_window_s: float = 3600.0,
) -> int:
    """Run ``cmd``; restart on crash or output stall.  Returns final rc.

    The restart budget is a SLIDING WINDOW, not a lifetime count: up to
    ``max_restarts`` restarts within any ``restart_window_s`` span.  A
    long-running job that hiccups once a day never exhausts its budget,
    while a crash loop (the lifetime count's real target) still trips it
    within minutes.  Between restarts the supervisor sleeps an exponential
    backoff — ``backoff_base_s * 2**(restarts in window)``, capped at
    ``backoff_cap_s`` — so a crash caused by contended shared state (a
    checkpoint filesystem coming back, a port being released) gets time to
    clear instead of burning the whole budget in one second.  Set
    ``backoff_base_s=0`` to disable the sleep (tests).
    """
    restart_times: collections.deque[float] = collections.deque()
    while True:
        log = open(log_path, "ab") if log_path else None
        proc = subprocess.Popen(
            cmd,
            stdout=log or None,
            stderr=subprocess.STDOUT if log else None,
        )
        last_size = -1
        last_progress = time.time()
        while True:
            rc = proc.poll()
            if rc is not None:
                break
            if stall_timeout_s and log_path:
                size = os.path.getsize(log_path)
                if size != last_size:
                    last_size = size
                    last_progress = time.time()
                elif time.time() - last_progress > stall_timeout_s:
                    print(f"supervisor: stall > {stall_timeout_s}s, killing",
                          file=sys.stderr)
                    proc.send_signal(signal.SIGKILL)
                    proc.wait()
                    rc = -9
                    break
            time.sleep(0.2)
        if log:
            log.close()
        if rc == 0:
            return 0
        now = time.time()
        while restart_times and now - restart_times[0] > restart_window_s:
            restart_times.popleft()
        if len(restart_times) >= max_restarts:
            print(f"supervisor: giving up after {len(restart_times)} "
                  f"restarts in {restart_window_s:.0f}s window",
                  file=sys.stderr)
            return rc
        delay = min(backoff_base_s * (2.0 ** len(restart_times)),
                    backoff_cap_s) if backoff_base_s > 0 else 0.0
        restart_times.append(now)
        print(f"supervisor: rc={rc}; restart "
              f"{len(restart_times)}/{max_restarts} in window"
              + (f" after {delay:.1f}s backoff" if delay else ""),
              file=sys.stderr)
        if delay:
            time.sleep(delay)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="restart budget within --restart-window seconds")
    ap.add_argument("--stall-timeout", type=float, default=None)
    ap.add_argument("--log", default=None)
    ap.add_argument("--backoff", type=float, default=0.5,
                    help="base restart backoff seconds (0 disables; "
                         "doubles per restart in the window)")
    ap.add_argument("--backoff-cap", type=float, default=30.0)
    ap.add_argument("--restart-window", type=float, default=3600.0,
                    help="sliding window (s) the restart budget applies to")
    ap.add_argument("cmd", nargs=argparse.REMAINDER)
    args = ap.parse_args()
    cmd = args.cmd
    if cmd and cmd[0] == "--":
        cmd = cmd[1:]
    raise SystemExit(
        run_supervised(cmd, args.max_restarts, args.stall_timeout, args.log,
                       backoff_base_s=args.backoff,
                       backoff_cap_s=args.backoff_cap,
                       restart_window_s=args.restart_window)
    )


if __name__ == "__main__":
    main()
