"""Fault-tolerance supervisor: run, watch, restart-from-checkpoint.

Wraps any launcher subprocess (train / reduce).  On non-zero exit or on a
heartbeat stall (straggler / hang mitigation) the job is killed and
relaunched; because checkpoints are atomic and the data pipeline is
step-keyed, the relaunch resumes bit-identically from the last checkpoint
(tested in tests/test_fault_tolerance.py).
"""

from __future__ import annotations

import argparse
import os
import signal
import subprocess
import sys
import time


def run_supervised(
    cmd: list[str],
    max_restarts: int = 3,
    stall_timeout_s: float | None = None,
    log_path: str | None = None,
) -> int:
    """Run ``cmd``; restart on crash or output stall.  Returns final rc."""
    restarts = 0
    while True:
        log = open(log_path, "ab") if log_path else None
        proc = subprocess.Popen(
            cmd,
            stdout=log or None,
            stderr=subprocess.STDOUT if log else None,
        )
        last_size = -1
        last_progress = time.time()
        while True:
            rc = proc.poll()
            if rc is not None:
                break
            if stall_timeout_s and log_path:
                size = os.path.getsize(log_path)
                if size != last_size:
                    last_size = size
                    last_progress = time.time()
                elif time.time() - last_progress > stall_timeout_s:
                    print(f"supervisor: stall > {stall_timeout_s}s, killing",
                          file=sys.stderr)
                    proc.send_signal(signal.SIGKILL)
                    proc.wait()
                    rc = -9
                    break
            time.sleep(0.2)
        if log:
            log.close()
        if rc == 0:
            return 0
        restarts += 1
        if restarts > max_restarts:
            print(f"supervisor: giving up after {restarts - 1} restarts",
                  file=sys.stderr)
            return rc
        print(f"supervisor: rc={rc}; restart {restarts}/{max_restarts}",
              file=sys.stderr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--stall-timeout", type=float, default=None)
    ap.add_argument("--log", default=None)
    ap.add_argument("cmd", nargs=argparse.REMAINDER)
    args = ap.parse_args()
    cmd = args.cmd
    if cmd and cmd[0] == "--":
        cmd = cmd[1:]
    raise SystemExit(
        run_supervised(cmd, args.max_restarts, args.stall_timeout, args.log)
    )


if __name__ == "__main__":
    main()
