"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

  compute    = FLOPs_per_device / peak_FLOPs          (197e12 bf16 FLOP/s)
  memory     = bytes_per_device / HBM_bw              (819e9 B/s)
  collective = collective_bytes_per_device / link_bw  (50e9 B/s ICI)

cost_analysis() returns per-device numbers for the SPMD-partitioned module
but counts while-loop bodies ONCE — so layer scans would undercount by L.
The methodology here (see EXPERIMENTS.md §Roofline) re-lowers each cell
with layers UNROLLED (repro.models.transformer.unroll_layers) at 1 and 2
layer-groups, fits cost = overhead + L * per_group, and extrapolates to the
full depth.  Collective bytes come from the partitioned HLO text: each
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
contributes its result bytes x an op weight (all-reduce counts 2x for its
reduce-scatter + all-gather ring decomposition).
"""

from __future__ import annotations

import re
from typing import Dict

# --------------------------------------------------------- TPU v5e constants
PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per chip (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVE_WEIGHT = {
    "all-reduce": 2.0,        # ring RS + AG decomposition
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective bytes by op kind from partitioned HLO text.

    Counts each async collective once (the ``-start`` op); sync forms are
    counted directly.  Returns {"total": weighted_bytes, per-op raw bytes}.
    """
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVE_WEIGHT}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done" in line.split("=")[1][:60]:
            continue
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        out[kind] += b
        total += b * _COLLECTIVE_WEIGHT[kind]
    out["total"] = total
    return out


def cost_terms(compiled, hlo_text: str | None = None) -> Dict[str, float]:
    """Raw per-device cost terms from one compiled executable."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    return {
        "flops": flops,
        "bytes": bytes_acc,
        "collective_bytes": coll["total"],
        "collective_detail": {
            k: v for k, v in coll.items() if k != "total"
        },
    }


def roofline_seconds(terms: Dict[str, float]) -> Dict[str, float]:
    compute = terms["flops"] / PEAK_FLOPS
    memory = terms["bytes"] / HBM_BW
    coll = terms["collective_bytes"] / LINK_BW
    dominant = max(
        ("compute", compute), ("memory", memory), ("collective", coll),
        key=lambda kv: kv[1],
    )[0]
    total = max(compute, memory, coll)
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": coll,
        "dominant": dominant,
        "bound_s": total,
    }


def fit_linear(costs_1, costs_2, n1: int, n2: int, n_full: int):
    """Fit cost = a + b*n from two measurements; extrapolate to n_full."""
    out = {}
    for k in ("flops", "bytes", "collective_bytes"):
        b = (costs_2[k] - costs_1[k]) / (n2 - n1)
        a = costs_1[k] - b * n1
        out[k] = max(a + b * n_full, 0.0)
    return out


def model_flops(cfg, shape, backward: bool) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D (train) or 2*N_active*D (fwd).

    D = total tokens processed; decode shapes process global_batch tokens
    per step.  Used for the usefulness ratio MODEL_FLOPS / HLO_FLOPs.
    """
    n_active = cfg.active_param_count() if hasattr(cfg, "active_param_count") \
        else cfg.param_count()
    if shape.kind == "train":
        tokens = shape.tokens
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.tokens
        mult = 2.0
    else:  # decode: one token per sequence per step
        tokens = shape.global_batch
        mult = 2.0
    return mult * n_active * tokens
