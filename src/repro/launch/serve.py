"""Serving launcher: batched-request generation with a reduced config.

Usage:
  python -m repro.launch.serve --arch mixtral-8x7b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import api
from repro.serving import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    key = jax.random.key(0)
    params = api.init_params(cfg, key)
    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.gen + 1)
    batch = api.make_batch(cfg, key, args.batch, args.prompt_len)

    t0 = time.time()
    out = eng.generate(batch, args.gen, temperature=args.temperature,
                       key=key)
    dt = time.time() - t0
    toks = args.batch * args.gen
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(out[0]))
    return out


if __name__ == "__main__":
    main()
