"""Serving launcher: batched-request generation with a reduced config,
or batched reduced-order evaluation from a saved basis artifact.

LM mode (unchanged):
  python -m repro.launch.serve --arch mixtral-8x7b --reduced \
      --batch 4 --prompt-len 32 --gen 16

Basis mode — load a ReducedBasis saved by ``repro.api`` (e.g. by
``python -m repro.launch.reduce``) and serve batched empirical-interpolation
requests from its EIM nodes (the paper's ROQ online stage):
  python -m repro.launch.serve --basis artifacts/reduce/basis --batch 256
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import api
from repro.serving import ServeEngine
from repro.timing import steady_min


def serve_basis(basis_dir: str, batch: int, seed: int = 0):
    """Reduced-order serving from a saved artifact: each "request" is a
    vector known only at the k EIM nodes; the interpolant reconstructs the
    full N-sample response (I_k[f] = B @ f[nodes], Alg. 5 of Ref. [6])."""
    import jax.numpy as jnp

    from repro.api import ReducedBasis

    basis = ReducedBasis.load(basis_dir)
    prov = basis.provenance
    print(f"loaded {basis!r}")
    print(f"  built by strategy={prov.get('strategy')!r} over "
          f"shape={prov.get('shape')} in {prov.get('wall_time_s', 0):.1f}s")

    ei = basis.eim()
    nodes = np.asarray(ei.nodes)
    print(f"  EIM: {basis.k} nodes of N={basis.N} samples "
          f"({basis.N / max(basis.k, 1):.0f}x fewer model evaluations "
          f"per request)")

    # synthetic requests: basis-span vectors sampled at the EIM nodes
    rng = np.random.default_rng(seed)
    coeff = rng.standard_normal((basis.k, batch))
    if jnp.iscomplexobj(basis.Q):
        coeff = coeff + 1j * rng.standard_normal((basis.k, batch))
    full = basis.Q @ jnp.asarray(coeff.astype(basis.Q.dtype))
    at_nodes = full[nodes, :]

    interp = jax.jit(lambda fn: ei.B @ fn)
    out = jax.block_until_ready(interp(at_nodes))  # compile out of clock
    # Steady-state best-of-N, not a single shot: one wall-clock sample
    # swings ±40% on a shared box (the same reason every committed BENCH
    # row uses this method).
    repeats = 12
    dt = steady_min(
        lambda: jax.block_until_ready(interp(at_nodes)),
        per=1, repeats=repeats,
    )
    err = float(jnp.max(jnp.linalg.norm(out - full, axis=0)))
    print(f"served {batch} interpolation requests in {dt*1e3:.2f} ms "
          f"(best of {repeats} steady-state rounds; "
          f"{batch / max(dt, 1e-9):.0f} req/s); "
          f"max reconstruction error {err:.2e}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--basis",
                    help="directory of a ReducedBasis artifact "
                         "(repro.api .save); serves reduced-order "
                         "evaluations instead of LM generation")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    if args.basis:
        return serve_basis(args.basis, batch=args.batch)
    if not args.arch:
        ap.error("--arch is required unless --basis is given")

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    key = jax.random.key(0)
    params = api.init_params(cfg, key)
    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.gen + 1)
    batch = api.make_batch(cfg, key, args.batch, args.prompt_len)

    t0 = time.time()
    out = eng.generate(batch, args.gen, temperature=args.temperature,
                       key=key)
    dt = time.time() - t0
    toks = args.batch * args.gen
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(out[0]))
    return out


if __name__ == "__main__":
    main()
