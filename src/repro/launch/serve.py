"""Serving launcher: batched-request generation with a reduced config,
or a persistent reduced-order (ROQ) service over saved basis artifacts.

LM mode (unchanged):
  python -m repro.launch.serve --arch mixtral-8x7b --reduced \
      --batch 4 --prompt-len 32 --gen 16

Basis mode — spin up the persistent :class:`repro.serving.ROQEngine`
over one or MORE ReducedBasis artifacts (e.g. per parameter-region GW
bases) and drive synthetic empirical-interpolation traffic through it
(the paper's ROQ online stage):
  python -m repro.launch.serve --basis artifacts/region_a \
      --basis artifacts/region_b --max-batch 64 --max-wait-ms 2 \
      --requests 4096
Each request is a vector known only at a basis's k EIM nodes; the engine
batches requests per basis under the latency/throughput dial, evaluates
them through the warm jitted interpolant cache, and reports a latency /
throughput / cache metrics snapshot on exit.  ``--duration`` submits for
a fixed wall time instead of a fixed request count.

(The pre-engine one-shot path rebuilt — and recompiled — the jitted
interpolant ``jax.jit(lambda fn: ei.B @ fn)`` on every invocation, and
reused the compile round's output as the correctness reference even when
the batch changed; both are gone: evaluation goes through the shared
interpolant cache, warm across calls, and every response is checked
against its own request's reference.)
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import api
from repro.serving import ServeEngine


def _basis_ids(basis_dirs: list) -> list:
    """Stable, human-readable ids: directory basename, deduped."""
    ids, seen = [], set()
    for d in basis_dirs:
        bid = os.path.basename(os.path.normpath(os.fspath(d))) or "basis"
        if bid in seen:
            i = 2
            while f"{bid}.{i}" in seen:
                i += 1
            bid = f"{bid}.{i}"
        seen.add(bid)
        ids.append(bid)
    return ids


def _request_pool(basis, eim, pool: int, seed: int):
    """Synthetic requests: basis-span vectors sampled at the EIM nodes.

    Returns ``(at_nodes (k, pool), full (N, pool))`` — ``full`` is the
    exact interpolant of each request (requests lie in span(Q), where the
    empirical interpolant is exact up to the interpolation solve), used
    as the per-request correctness reference."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    coeff = rng.standard_normal((basis.k, pool))
    if jnp.iscomplexobj(basis.Q):
        coeff = coeff + 1j * rng.standard_normal((basis.k, pool))
    full = np.asarray(basis.Q @ jnp.asarray(coeff.astype(
        np.asarray(basis.Q).dtype)))
    nodes = np.asarray(eim.nodes)
    return full[nodes, :], full


def serve_basis(basis_dirs, *, max_batch: int = 64,
                max_wait_ms: float = 2.0, requests: int | None = None,
                duration: float | None = None, queue_depth: int = 4096,
                timeout_s: float | None = None, seed: int = 0,
                client_rate: float | None = None,
                client_burst: float | None = None,
                degrade_queue_frac: float = 0.75,
                degrade_p95_ms: float | None = None,
                breaker_threshold: int = 5,
                breaker_cooldown_s: float = 5.0,
                max_restarts: int = 3):
    """Serve synthetic ROQ traffic over the given artifacts; returns the
    final engine stats dict (plus ``max_err`` / ``served`` keys)."""
    from repro.serving import (
        CircuitOpenError, QueueFullError, QuotaExceededError, RestartPolicy,
        ROQEngine, ShedError)

    if isinstance(basis_dirs, (str, os.PathLike)):
        basis_dirs = [basis_dirs]
    ids = _basis_ids(basis_dirs)
    engine = ROQEngine({bid: d for bid, d in zip(ids, basis_dirs)},
                       max_batch=max_batch, max_wait_ms=max_wait_ms,
                       queue_depth=queue_depth, timeout_s=timeout_s,
                       client_rate=client_rate, client_burst=client_burst,
                       degrade_queue_frac=degrade_queue_frac,
                       degrade_p95_ms=degrade_p95_ms,
                       breaker_threshold=breaker_threshold,
                       breaker_cooldown_s=breaker_cooldown_s,
                       restart=RestartPolicy(enabled=max_restarts > 0,
                                             max_restarts=max_restarts))
    pools = {}
    for bid in ids:
        basis, eim = engine.router.get(bid)
        prov = basis.provenance
        print(f"[{bid}] {basis!r}")
        print(f"  built by strategy={prov.get('strategy')!r} over "
              f"shape={prov.get('shape')}; EIM: {basis.k} nodes of "
              f"N={basis.N} samples "
              f"({basis.N / max(basis.k, 1):.0f}x fewer model "
              f"evaluations per request)")
        pools[bid] = _request_pool(basis, eim, pool=max(2 * max_batch, 64),
                                   seed=seed)
        engine.warm(bid)

    if requests is None and duration is None:
        requests = 16 * max_batch

    futures = []   # (future, bid, pool column)
    rejected = shed = quota = breaker = 0
    t0 = time.perf_counter()
    i = 0
    while True:
        if duration is not None:
            if time.perf_counter() - t0 >= duration:
                break
        elif i >= requests:
            break
        bid = ids[i % len(ids)]
        at_nodes, _ = pools[bid]
        col = i % at_nodes.shape[1]
        try:
            futures.append((engine.submit(bid, at_nodes[:, col],
                                          client_id="launcher"), bid, col))
        except QueueFullError:
            rejected += 1
            time.sleep(1e-4)  # brief backoff, then keep offering load
        except ShedError:
            shed += 1
            time.sleep(1e-4)
        except QuotaExceededError:
            quota += 1
            time.sleep(1e-3)  # wait for the token bucket to refill
        except CircuitOpenError:
            breaker += 1
            time.sleep(1e-3)
        i += 1
    engine.close(drain=True)
    wall = time.perf_counter() - t0

    max_err = 0.0
    for fut, bid, col in futures:
        out = fut.result()
        ref = pools[bid][1][:, col]
        max_err = max(max_err, float(np.max(np.abs(out - ref))))
    stats = engine.stats()
    stats["max_err"] = max_err
    stats["served"] = len(futures)
    stats["submit_rejected"] = rejected
    stats["submit_shed"] = shed
    stats["submit_quota_rejected"] = quota
    stats["submit_breaker_rejected"] = breaker
    lat = stats["latency_ms"] or {}
    print(f"served {len(futures)} requests over {len(ids)} bases in "
          f"{wall:.3f}s ({len(futures) / max(wall, 1e-9):.0f} req/s "
          f"end-to-end; {rejected} backpressure, {shed} shed, "
          f"{quota} quota, {breaker} breaker rejects)")
    if lat:
        print(f"  latency p50={lat['p50']:.3f}ms p95={lat['p95']:.3f}ms "
              f"p99={lat['p99']:.3f}ms (n={lat['n']})")
    print(f"  batches={stats['counters']['batches']} "
          f"occupancy={stats['batch_occupancy_mean']:.2f} "
          f"cache_hit_rate={stats['cache_hit_rate']:.2f} "
          f"(misses={stats['counters']['cache_misses']})")
    c = stats["counters"]
    print(f"  health: worker_deaths={c['worker_deaths']} "
          f"restarts={c['worker_restarts']} "
          f"degraded_entered={c['degraded_entered']} "
          f"breaker_opened={c['breaker_opened']} reloads={c['reloads']}")
    print(f"  max interpolation error {max_err:.2e}")
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--basis", action="append", default=[],
                    help="directory of a ReducedBasis artifact "
                         "(repro.api .save); repeatable — serves "
                         "reduced-order interpolation across all given "
                         "bases instead of LM generation")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    # basis-mode engine dial
    ap.add_argument("--max-batch", type=int, default=64,
                    help="flush a basis's batch at this many requests")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="... or this long after its oldest request")
    ap.add_argument("--requests", type=int, default=None,
                    help="total synthetic requests to submit "
                         "(default 16*max_batch)")
    ap.add_argument("--duration", type=float, default=None,
                    help="submit for this many seconds instead of a "
                         "fixed --requests count")
    ap.add_argument("--queue-depth", type=int, default=4096)
    ap.add_argument("--timeout-s", type=float, default=None,
                    help="per-request deadline")
    # overload hardening (PR 10)
    ap.add_argument("--client-rate", type=float, default=None,
                    help="per-client admission quota (req/s; default: "
                         "quotas off)")
    ap.add_argument("--client-burst", type=float, default=None,
                    help="quota bucket capacity (default 2*rate)")
    ap.add_argument("--degrade-queue-frac", type=float, default=0.75,
                    help="backlog fraction of queue-depth past which "
                         "admission enters degraded mode")
    ap.add_argument("--degrade-p95-ms", type=float, default=None,
                    help="p95 latency watermark for degraded mode")
    ap.add_argument("--breaker-threshold", type=int, default=5,
                    help="consecutive batch failures that open a "
                         "basis's circuit breaker")
    ap.add_argument("--breaker-cooldown-s", type=float, default=5.0,
                    help="open-breaker cooldown before a half-open probe")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="supervised worker restarts per 60s window "
                         "(0 disables: a dead worker latches unhealthy)")
    args = ap.parse_args(argv)

    if args.basis:
        return serve_basis(
            args.basis, max_batch=args.max_batch,
            max_wait_ms=args.max_wait_ms, requests=args.requests,
            duration=args.duration, queue_depth=args.queue_depth,
            timeout_s=args.timeout_s,
            client_rate=args.client_rate, client_burst=args.client_burst,
            degrade_queue_frac=args.degrade_queue_frac,
            degrade_p95_ms=args.degrade_p95_ms,
            breaker_threshold=args.breaker_threshold,
            breaker_cooldown_s=args.breaker_cooldown_s,
            max_restarts=args.max_restarts)
    if not args.arch:
        ap.error("--arch is required unless --basis is given")

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    key = jax.random.key(0)
    params = api.init_params(cfg, key)
    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.gen + 1)
    batch = api.make_batch(cfg, key, args.batch, args.prompt_len)

    t0 = time.time()
    out = eng.generate(batch, args.gen, temperature=args.temperature,
                       key=key)
    dt = time.time() - t0
    toks = args.batch * args.gen
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(out[0]))
    return out


if __name__ == "__main__":
    main()
