"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and everything else must see the default single device.
"""

from __future__ import annotations

import jax

from repro.compat import make_auto_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 = 256 chips per pod; 2 pods for multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_host_mesh(n: int | None = None, axes=("data", "model")):
    """Small host-device mesh for tests (requires forced device count)."""
    devs = jax.devices()
    n = n or len(devs)
    if len(axes) == 1:
        shape = (n,)
    else:
        a = 2 if n % 2 == 0 and n > 1 else 1
        shape = (a, n // a)
    return make_auto_mesh(shape, axes)


def dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def tp_size(mesh) -> int:
    return mesh.shape.get("model", 1)
