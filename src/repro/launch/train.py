"""Training launcher: config-driven, checkpointed, supervisor-compatible.

Runs a reduced or full architecture with the production trainer: sharded
state (on whatever mesh the host offers), async checkpointing, step-keyed
data, deterministic restart.  On a real TPU pod this same entry point runs
under ``jax.distributed.initialize()`` with the production mesh; on CPU it
drives the end-to-end example (examples/train_lm_reduced.py wraps it).

Usage:
  python -m repro.launch.train --arch stablelm-3b --reduced --steps 200 \
      --ckpt-dir ckpts/ --seq 256 --batch 8
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.checkpoint import (
    AsyncCheckpointer, latest_step, restore_checkpoint,
)
from repro.configs import get_config, get_reduced
from repro.data import SyntheticLMData
from repro.training import TrainState, make_train_step, train_state_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compression", type=float, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="fault-injection: hard-exit at this step")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    key = jax.random.key(0)
    state = train_state_init(cfg, key,
                             compression=args.compression is not None)
    start = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir)
        last = latest_step(args.ckpt_dir)
        if last is not None:
            state = restore_checkpoint(state, args.ckpt_dir, last)
            start = int(state.step)
            print(f"restored checkpoint at step {start}")

    data = SyntheticLMData(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch,
    )
    step_fn = make_train_step(
        cfg, n_microbatches=args.microbatches, base_lr=args.lr,
        warmup=max(args.steps // 20, 10), total_steps=args.steps,
        compression_ratio=args.compression,
    )

    t0 = time.time()
    history = []
    for i in range(start, args.steps):
        state, metrics = step_fn(state, data.batch(i))
        if args.crash_at is not None and i + 1 == args.crash_at:
            print(f"fault injection: exiting hard at step {i + 1}")
            os._exit(42)
        if (i + 1) % args.log_every == 0 or i + 1 == args.steps:
            loss = float(metrics["loss"])
            history.append({"step": i + 1, "loss": loss})
            print(f"step {i+1:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time()-t0)/(i-start+1):.2f}s/step)")
        if ckpt and (i + 1) % args.ckpt_every == 0:
            ckpt.save(state, i + 1)
    if ckpt:
        ckpt.save(state, args.steps)
        ckpt.wait()
    return history


if __name__ == "__main__":
    main()
