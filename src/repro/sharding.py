"""Logical-axis sharding rules and mesh-aware constraints.

Logical axes used throughout the model zoo:

  "dp"   — batch / data-parallel        -> mesh ("pod", "data") or ("data",)
  "fsdp" — ZeRO-3 parameter sharding    -> same mesh axes as "dp"
  "tp"   — tensor parallel (heads/ffn/vocab/experts) -> mesh ("model",)
  "sp"   — sequence parallel (residual stream) -> mesh ("model",)

Models call :func:`constrain` with logical names; when no mesh is active the
call is a no-op, so the same code runs in single-device smoke tests and in
the 512-chip dry-run.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _axes_for(mesh: Mesh, logical: str):
    names = mesh.axis_names
    if logical in ("dp", "fsdp"):
        axes = tuple(a for a in ("pod", "data") if a in names)
        return axes if axes else None
    if logical in ("tp", "sp"):
        return "model" if "model" in names else None
    if logical == "cols":  # distributed-greedy column axis: all axes
        return tuple(names)
    raise ValueError(f"unknown logical axis {logical!r}")


def resolve(mesh: Mesh, *logical: Optional[str]) -> P:
    """PartitionSpec for a tuple of per-dim logical axis names (None = rep)."""
    out = []
    for ax in logical:
        if ax is None:
            out.append(None)
        else:
            out.append(_axes_for(mesh, ax))
    return P(*out)


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Activate a mesh for :func:`constrain` (and nested jit sharding)."""
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = resolve(mesh, *logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, *logical: Optional[str]) -> NamedSharding:
    return NamedSharding(mesh, resolve(mesh, *logical))


def tree_shardings(mesh: Mesh, logical_tree: Any) -> Any:
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, resolve(mesh, *spec)),
        logical_tree,
        is_leaf=lambda s: isinstance(s, tuple)
        and all(x is None or isinstance(x, str) for x in s),
    )


# ---------------------------------------------------- manual TP micro-kernels
def _dp_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def seq_allgather(x: jax.Array) -> jax.Array:
    """Gather a sequence-sharded activation to full length, explicitly in
    its own (bf16) dtype.

    GSPMD sometimes gathers the f32 pre-cast intermediate of rms_norm
    (convert-hoisting), doubling AG bytes; doing the gather manually via
    shard_map pins both the dtype and the collective (all-gather over
    "model").  x: (B, S, d) sharded (dp, model, None) -> (B, S, d)
    replicated over model.  No-op without an active mesh.
    """
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    from jax.experimental.shard_map import shard_map

    dp = _dp_axes(mesh)

    def local(xl):
        return jax.lax.all_gather(xl, "model", axis=1, tiled=True)

    return shard_map(
        local, mesh=mesh,
        in_specs=P(dp, "model", None),
        out_specs=P(dp, None, None),
        check_rep=False,
    )(x)


def tp_rs_matmul(h: jax.Array, w: jax.Array) -> jax.Array:
    """y = h @ w with a MANUAL bf16 reduce-scatter over the model axis.

    h: (B, S, f) sharded (dp, None, model); w: (f, d) sharded (model, fsdp).
    Each shard computes its partial product and the partial sums are merged
    with ``psum_scatter`` over "model" onto the sequence dimension — the
    Megatron-LM bf16 RS, which GSPMD's convert-hoisted f32 all-reduce misses
    (EXPERIMENTS.md §Perf it1/it4).  Returns (B, S, d) sharded
    (dp, model, None).  No-op matmul without an active mesh.
    """
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return h @ w
    from jax.experimental.shard_map import shard_map

    dp = _dp_axes(mesh)

    def local(hl, wl):
        part = (hl @ wl).astype(h.dtype)  # bf16 partial sums (Megatron)
        return jax.lax.psum_scatter(
            part, "model", scatter_dimension=1, tiled=True
        )

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None, "model"), P("model", None)),
        out_specs=P(dp, "model", None),
        check_rep=False,
    )(h, w)


def tp_ag_matmuls(x: jax.Array, *ws: jax.Array):
    """Fused (sequence all-gather + n projections) in one manual region.

    x: (B, S, d) sharded (dp, model, None); each w: (d, f) sharded
    (fsdp, model).  Returns one (B, S, f) output per w, sharded
    (dp, None, model).  Fusing the gather with the matmuls matters for the
    BACKWARD pass: the input-cotangent partial sums feed the transpose of
    the manual all-gather (a bf16 psum_scatter) directly, instead of being
    merged by GSPMD's f32 all-reduce before reaching it.  Plain matmuls
    without an active mesh.
    """
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return tuple(x @ w for w in ws)
    from jax.experimental.shard_map import shard_map

    dp = _dp_axes(mesh)

    def local(xl, *wls):
        xg = jax.lax.all_gather(xl, "model", axis=1, tiled=True)
        return tuple(xg @ wl for wl in wls)

    out = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, "model", None),) + tuple(
            P(None, "model") for _ in ws),
        out_specs=tuple(P(dp, None, "model") for _ in ws),
        check_rep=False,
    )(x, *ws)
    return out
