"""Small cross-version JAX compatibility helpers.

The repo targets recent JAX, but two APIs moved under our feet:

* ``jax.sharding.AxisType`` (explicit/auto axis types) does not exist on
  older releases — :func:`make_auto_mesh` passes ``axis_types`` only when
  available (every mesh here is fully ``Auto``, which is also the default
  on versions without the enum).
* ``jax.lax.axis_size`` is similarly recent; see
  ``repro.core.distributed._axis_size`` for the in-shard_map fallback.
"""

from __future__ import annotations

from typing import Sequence

import jax


def make_auto_mesh(
    shape: Sequence[int],
    axis_names: Sequence[str],
    devices=None,
):
    """``jax.make_mesh`` with all axes ``Auto``, on any supported version."""
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (
            jax.sharding.AxisType.Auto,
        ) * len(tuple(axis_names))
    if devices is not None:
        kwargs["devices"] = devices
    return jax.make_mesh(tuple(shape), tuple(axis_names), **kwargs)
