"""AdamW with decoupled weight decay, global-norm clipping, f32 moments.

Moments are kept in f32 regardless of parameter dtype (bf16 training), and
their sharding follows the parameter sharding (ZeRO: the dry-run shards
them over both the fsdp and tp axes via the same spec tree as params).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree
    )
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr: jax.Array | float,
    weight_decay: float = 0.01,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    clip_norm: float | None = 1.0,
):
    """Returns (new_params, new_state)."""
    step = state.step + 1
    if clip_norm is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-12))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * g32 * g32
        mh = m2 / c1
        vh = v2 / c2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(
            jnp.float32
        )
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(m=new_m, v=new_v, step=step)
