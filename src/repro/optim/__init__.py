from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine
from repro.optim.compression import ef_topk_compress, ef_state_init

__all__ = [
    "AdamWState", "adamw_init", "adamw_update", "warmup_cosine",
    "ef_topk_compress", "ef_state_init",
]
