"""Error-feedback top-k gradient compression (distributed-optimization trick).

Before the data-parallel all-reduce, each gradient tensor is sparsified to
its top-k fraction by magnitude; the residual (what was dropped) is carried
in an error-feedback accumulator and added back next step (Stich et al.;
1-bit Adam lineage).  On TPU pjit meshes the all-reduce is implicit, so the
bandwidth win applies when the trainer runs its gradient sync through the
shard_map DP path; the correctness contract (convergence on a small task)
is tested either way in tests/test_optim.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_state_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_sparsify(g: jax.Array, ratio: float) -> jax.Array:
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * ratio))
    if k >= flat.shape[0]:
        return g
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def ef_topk_compress(grads, ef_state, ratio: float = 0.1):
    """Returns (compressed_grads, new_ef_state)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        sparse = _topk_sparsify(g32, ratio)
        return sparse.astype(g.dtype), g32 - sparse

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        tdef.unflatten([o[0] for o in out]),
        tdef.unflatten([o[1] for o in out]),
    )
