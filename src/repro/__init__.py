"""GreedyJAX: QR-based model reduction at pod scale.

Reproduction + extension of Antil, Chen & Field (2018), "A Note on QR-Based
Model Reduction: Algorithm, Software, and Gravitational Wave Applications".
"""

__version__ = "1.0.0"
