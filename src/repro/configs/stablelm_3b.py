"""stablelm-3b [dense] — hf:stabilityai/stablelm family; unverified.

32L d_model=2560 32H (MHA: kv=32) d_ff=6912 vocab=50304.
Full attention -> long_500k skip.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
)


def reduced():
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, dtype="float32",
    )
