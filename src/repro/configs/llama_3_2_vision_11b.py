"""llama-3.2-vision-11b [vlm] — hf:meta-llama/Llama-3.2-11B-Vision; unverified.

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256, gated cross-attn
image blocks after every 5th self layer.  Per the assignment the modality
frontend is a STUB: ``input_specs()`` provides precomputed patch embeddings
(vision_tokens x vision_dim); the backbone projects + cross-attends them.
Full attention -> long_500k skip.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    cross_every=5,
    vision_dim=1280,
    vision_tokens=1600,
    rope_theta=500000.0,
)


def reduced():
    return CONFIG.replace(
        n_layers=4, cross_every=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, vision_dim=32, vision_tokens=16,
        dtype="float32",
    )
