"""seamless-m4t-medium [audio] — arXiv:2308.11596; hf:facebook/seamless-m4t.

Enc-dec: 12L encoder over audio-frame embeddings (frontend STUBBED per the
assignment: input_specs() provides precomputed frame embeddings) + 12L
decoder, d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=256206, GeLU MLP.
Enc-dec full attention -> long_500k skip; decode_32k uses the decoder
self-attn cache + fixed cross-attn memory.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    mlp_type="gelu",
    encoder_layers=12,
    audio_frames=4096,
    audio_dim=1024,
)


def reduced():
    return CONFIG.replace(
        n_layers=2, encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, audio_frames=24, audio_dim=32,
        dtype="float32",
    )
