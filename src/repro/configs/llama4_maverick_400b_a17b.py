"""llama4-maverick-400b-a17b [moe] — hf:meta-llama/Llama-4 family; unverified.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts
top-1.  Early fusion is multimodal plumbing outside the text backbone scope;
the assignment specifies the transformer backbone, which is what we build.
Full attention (no published sub-quadratic variant in the spec line) —
long_500k is skipped for this arch (see DESIGN.md §Arch-applicability).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    n_experts=128,
    experts_per_token=1,
    rope_theta=500000.0,
)


def reduced():
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=256, n_experts=8, experts_per_token=1,
        moe_group_size=64, capacity_factor=8.0, dtype="float32",
    )
