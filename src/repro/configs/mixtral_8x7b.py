"""mixtral-8x7b [moe] — arXiv:2401.04088; hf:mistralai/Mixtral-8x7B.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, 8 experts top-2,
sliding-window attention (4096).  SWA makes the decode cache O(window), so
long_500k RUNS for this arch.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    rope_theta=1000000.0,
)


def reduced():
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=256, n_experts=4, experts_per_token=2,
        sliding_window=16, moe_group_size=64, capacity_factor=8.0,
        dtype="float32",
    )
