"""recurrentgemma-9b [hybrid] — arXiv:2402.19427 (Griffin); unverified.

38L d_model=4096 16H (MQA kv=1, head_dim 256) d_ff=12288 vocab=256000;
RG-LRU recurrent blocks + local attention in a 1:2 pattern (attn_every=3),
lru_width=4096, local window 2048.  Bounded state -> long_500k RUNS.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    attn_every=3,
    lru_width=4096,
    local_window=2048,
    tie_embeddings=True,
)


def reduced():
    return CONFIG.replace(
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
        vocab_size=256, lru_width=64, local_window=16, dtype="float32",
    )
