"""starcoder2-15b [dense] — arXiv:2402.19173; hf:bigcode/starcoder2-15b.

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152 — GQA, RoPE, GeLU
MLP with biases (the StarCoder2 recipe).  Full attention -> long_500k skip.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    mlp_type="gelu",
    mlp_bias=True,
    qkv_bias=True,
    rope_theta=100000.0,
)


def reduced():
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, dtype="float32",
    )
