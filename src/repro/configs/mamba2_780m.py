"""mamba2-780m [ssm] — arXiv:2405.21060; unverified.

48L d_model=1536 (attention-free), ssm_state=128, vocab=50280, SSD layers
(expand=2, head_dim=64 -> 48 heads).  O(1) decode state -> long_500k RUNS.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,       # unused by the ssm family
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    tie_embeddings=True,
)


def reduced():
    return CONFIG.replace(
        n_layers=2, d_model=64, vocab_size=256, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=32, dtype="float32",
    )
