"""granite-3-8b [dense] — hf:ibm-granite/granite-3.0-8b-base.

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155; tied embeddings
(HF config).  Full attention -> long_500k skip.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    tie_embeddings=True,
    rope_theta=10000.0,
)


def reduced():
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, dtype="float32",
    )
