"""Assigned-architecture registry: one module per arch, exact public configs.

Each module exports ``CONFIG`` (the full assignment-spec config) and
``reduced()`` (a same-family, CPU-smoke-test-sized config).
"""

import importlib

ARCHS = [
    "llama4_maverick_400b_a17b",
    "mixtral_8x7b",
    "starcoder2_15b",
    "stablelm_3b",
    "granite_3_8b",
    "qwen1_5_110b",
    "mamba2_780m",
    "llama_3_2_vision_11b",
    "recurrentgemma_9b",
    "seamless_m4t_medium",
]

# CLI ids (dashes) -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCHS}
ALIASES.update({
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "mixtral-8x7b": "mixtral_8x7b",
    "starcoder2-15b": "starcoder2_15b",
    "stablelm-3b": "stablelm_3b",
    "granite-3-8b": "granite_3_8b",
    "qwen1.5-110b": "qwen1_5_110b",
    "mamba2-780m": "mamba2_780m",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "seamless-m4t-medium": "seamless_m4t_medium",
})


def get_config(name: str):
    mod = importlib.import_module(
        f"repro.configs.{ALIASES.get(name, name)}"
    )
    return mod.CONFIG


def get_reduced(name: str):
    mod = importlib.import_module(
        f"repro.configs.{ALIASES.get(name, name)}"
    )
    return mod.reduced()


def arch_ids():
    return [
        "llama4-maverick-400b-a17b", "mixtral-8x7b", "starcoder2-15b",
        "stablelm-3b", "granite-3-8b", "qwen1.5-110b", "mamba2-780m",
        "llama-3.2-vision-11b", "recurrentgemma-9b", "seamless-m4t-medium",
    ]
