"""The paper's own production workload (Sec. 6.1.4, Blue Waters).

Column-pivoted QR via RB-greedy on a dense complex snapshot matrix:
N = 10,000 rows x M = 3,276,800 columns (~0.5 TB at complex64), k = 100
basis vectors — the largest matrix the paper reports (32,768 cores).
This config drives the distributed-greedy dry-run + roofline cell.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class GreedyWorkload:
    name: str = "gw-greedy-bluewaters"
    n_rows: int = 10_000
    n_cols: int = 3_276_800
    dtype: str = "complex64"
    max_k: int = 100
    tau: float = 1e-12


CONFIG = GreedyWorkload()


def reduced():
    return GreedyWorkload(
        name="gw-greedy-small", n_rows=256, n_cols=2048, max_k=40, tau=1e-5
    )
