"""GW waveform model sanity + the paper's n-width-decay premise."""

import jax.numpy as jnp
import numpy as np

from repro.gw import (
    build_snapshot_matrix, chirp_grid, frequency_grid, taylorf2,
)
from repro.gw.grids import mass_grid, random_mass_samples


def test_waveform_normalized_and_finite():
    f = jnp.asarray(frequency_grid(20.0, 512.0, 500))
    h = taylorf2(f, 10.0, 8.0, dtype=jnp.complex128)
    assert np.isfinite(np.asarray(h)).all()
    assert float(jnp.linalg.norm(h)) == 1.0 or abs(
        float(jnp.linalg.norm(h)) - 1.0) < 1e-8


def test_amplitude_powerlaw():
    f = jnp.asarray(frequency_grid(20.0, 512.0, 500))
    h = taylorf2(f, 10.0, 8.0, normalize=False, dtype=jnp.complex128)
    amp = np.abs(np.asarray(h))
    slope = np.polyfit(np.log(np.asarray(f)), np.log(amp), 1)[0]
    assert abs(slope + 7.0 / 6.0) < 1e-6


def test_phase_smoothness_in_parameters():
    """Waveforms converge as the parameter delta shrinks (smoothness in the
    sense the greedy theory needs); absolute deltas are large even for
    small mass changes (many phase cycles), so test CONVERGENCE."""
    f = jnp.asarray(frequency_grid(20.0, 256.0, 400))
    h0 = taylorf2(f, 10.0, 8.0, dtype=jnp.complex128)
    diffs = []
    for d in (1e-2, 1e-3, 1e-4, 1e-5):
        h = taylorf2(f, 10.0 + d, 8.0, dtype=jnp.complex128)
        diffs.append(float(jnp.linalg.norm(h - h0)))
    assert all(a > b for a, b in zip(diffs, diffs[1:]))
    assert diffs[-1] < 1e-2


def test_nwidth_exponential_decay():
    """The paper's premise: smooth families have fast-decaying n-width, so
    the singular values of S decay (near-)exponentially."""
    # a narrow parameter range: the regime where the n-width premise bites
    f = frequency_grid(20.0, 256.0, 400)
    m1, m2 = chirp_grid(mc_min=9.0, mc_max=10.0, n_mc=24, n_eta=6)
    S = build_snapshot_matrix(f, m1, m2, dtype=jnp.complex128)
    sig = np.linalg.svd(np.asarray(S), compute_uv=False)
    sig = sig / sig[0]
    assert sig[60] < 1e-6
    ks = np.arange(5, 40)
    slope = np.polyfit(ks, np.log(np.maximum(sig[5:40], 1e-300)), 1)[0]
    assert slope < -0.1


def test_grids():
    m1, m2 = mass_grid(5.0, 30.0, 10)
    assert (m1 >= m2).all()
    m1, m2 = random_mass_samples(50)
    assert (m1 >= m2).all()
    m1, m2 = chirp_grid(n_mc=8, n_eta=4)
    eta = m1 * m2 / (m1 + m2) ** 2
    assert (eta <= 0.25 + 1e-12).all()


def test_out_of_sample_validation():
    """greedycpp-style validation: basis built on a grid represents
    out-of-sample waveforms to similar accuracy."""
    from repro.core import rb_greedy
    from repro.core.errors import per_column_errors

    f = frequency_grid(20.0, 256.0, 400)
    m1, m2 = chirp_grid(n_mc=24, n_eta=8)
    S = build_snapshot_matrix(f, m1, m2, dtype=jnp.complex128)
    res = rb_greedy(S, tau=1e-6)
    k = int(res.k)

    mv1, mv2 = random_mass_samples(64, 7.0, 25.0, seed=5)
    # keep validation inside the training chirp-mass hull
    V = build_snapshot_matrix(f, mv1, mv2, dtype=jnp.complex128)
    errs = per_column_errors(V, res.Q[:, :k])
    assert float(jnp.median(errs)) < 1e-3
