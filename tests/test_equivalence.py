"""Proposition 5.3: RB-greedy == MGS with column pivoting.

Identical pivot sequences, identical pivoted-diagonal values, identical
basis spans — on deterministic smooth families, random matrices (hypothesis
sweep), and GW waveform snapshots.

MGS runs through the front door (``build_basis(strategy="mgs")``; the
direct ``mgs_pivoted_qr`` entry point is deprecated) — its ``errs`` are
the pivoted diagonal R(j,j).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from conftest import make_smooth_matrix
from repro.api import build_basis
from repro.core import rb_greedy


def mgs_front_door(S, tau, max_k=None):
    return build_basis(source=S, strategy="mgs", tau=tau, max_k=max_k)


def _span_distance(Q1, Q2):
    """sin of largest principal angle between the column spans."""
    s = np.linalg.svd(np.asarray(Q1).conj().T @ np.asarray(Q2),
                      compute_uv=False)
    return float(np.sqrt(max(0.0, 1.0 - np.min(s) ** 2)))


@pytest.mark.parametrize("dtype", [np.float64, np.complex128])
def test_equivalence_smooth(dtype):
    """Exact pivot equality above the tie zone (smooth families produce
    near-degenerate residuals once the error is tiny, where tie-breaks may
    legitimately differ between the two formulations)."""
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    tau = 1e-4
    g = rb_greedy(S, tau=tau)
    m = mgs_front_door(S, tau=tau)
    k = int(g.k)
    assert m.k == k
    assert np.array_equal(np.asarray(g.pivots[:k]), np.asarray(m.pivots))
    assert np.allclose(np.asarray(g.errs[:k]), np.asarray(m.errs),
                       rtol=1e-6)
    assert _span_distance(g.Q[:, :k], m.Q) < 1e-5


@pytest.mark.parametrize("dtype", [np.float64, np.complex128])
def test_functional_equivalence_deep(dtype):
    """At deep tolerance both algorithms deliver a basis meeting tau, with
    identical error sequences (Cor 5.6) even if tie-breaks differ."""
    from repro.core.errors import proj_error_max
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    tau = 1e-8
    g = rb_greedy(S, tau=tau)
    m = mgs_front_door(S, tau=tau)
    k = int(g.k)
    assert abs(m.k - k) <= 1
    kk = min(k, m.k)
    # compare error sequences up to the first tie-break divergence (after
    # a divergence the two runs legitimately track different columns)
    gp, mp = np.asarray(g.pivots[:kk]), np.asarray(m.pivots[:kk])
    j_div = next((i for i in range(kk) if gp[i] != mp[i]), kk)
    assert j_div >= min(kk, 8)
    assert np.allclose(np.asarray(g.errs[:j_div]),
                       np.asarray(m.errs[:j_div]), rtol=1e-3)
    # greedy + Hoffmann iterated GS meets tau;
    assert float(proj_error_max(S, g.Q[:, :k])) < tau * 1.01
    # plain MGS deflation loses ~kappa(S)*eps of true accuracy — exactly
    # the ill-conditioning the paper cites (Remark 5.5) as motivation for
    # the iterated GS.  Its claimed R(k,k) hits tau but the realized error
    # is a few orders worse:
    assert float(proj_error_max(S, m.Q)) < 1e-5


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.integers(12, 60),
    m=st.integers(8, 40),
    rank=st.integers(3, 8),
    use_complex=st.booleans(),
)
def test_equivalence_random(seed, n, m, rank, use_complex):
    """Property (Prop 5.3): on random low-rank + noise matrices — real AND
    complex — RB-greedy and pivoted MGS agree on pivots and span the same
    subspace."""
    rng = np.random.default_rng(seed)
    rank = min(rank, n, m)

    def rand(*shape):
        x = rng.standard_normal(shape)
        return x + 1j * rng.standard_normal(shape) if use_complex else x

    S = rand(n, rank) @ rand(rank, m) + 1e-9 * rand(n, m)
    S = jnp.asarray(S)
    tau = 1e-6 * float(jnp.linalg.norm(S, ord=2))
    g = rb_greedy(S, tau=tau)
    ms = mgs_front_door(S, tau=tau)
    k = min(int(g.k), ms.k)
    assert k >= 1
    assert np.array_equal(np.asarray(g.pivots[:k]),
                          np.asarray(ms.pivots[:k]))
    # span agreement: identical pivot columns + full-precision GS on both
    # sides keep the largest principal angle near the noise floor
    assert _span_distance(g.Q[:, :k], ms.Q[:, :k]) < 1e-4


def test_equivalence_gw_waveforms():
    """Unnormalized snapshots (normalized ones tie at iteration 0: every
    column norm is exactly 1, so the first pivot is a pure tie-break)."""
    from repro.gw import taylorf2, chirp_grid, frequency_grid

    f = jnp.asarray(frequency_grid(20.0, 256.0, 300))
    m1, m2 = chirp_grid(n_mc=16, n_eta=5)
    cols = [taylorf2(f, a, b, normalize=False, dtype=jnp.complex128)
            for a, b in zip(m1[:60], m2[:60])]
    S = jnp.stack(cols, axis=1)
    tau = 1e-5 * float(jnp.max(jnp.linalg.norm(S, axis=0)))
    g = rb_greedy(S, tau=tau)
    m = mgs_front_door(S, tau=tau)
    k = int(g.k)
    assert m.k == k
    assert np.array_equal(np.asarray(g.pivots[:k]), np.asarray(m.pivots))
