import os

# Tests must see exactly ONE device (the dry-run forces 512 in its own
# subprocess); also keep kernels in interpret mode on CPU.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# Auto-strategy decisions must be deterministic under test: the one-time
# roofline calibration would otherwise feed MEASURED (noisy-box) roofs into
# the PR-4 decision table.  Tests that exercise the measurement itself
# monkeypatch this back on.
os.environ.setdefault("REPRO_ROOFLINE_MEASURE", "0")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import pytest  # noqa: E402


def pytest_collection_modifyitems(config, items):
    """Timing-sensitive tests skip (not flake) on the noisy shared CI box;
    opt in with REPRO_RUN_TIMING_TESTS=1."""
    if os.environ.get("REPRO_RUN_TIMING_TESTS"):
        return
    skip = pytest.mark.skip(
        reason="timing-sensitive (noisy shared box); "
               "set REPRO_RUN_TIMING_TESTS=1 to run"
    )
    for item in items:
        if "timing" in item.keywords:
            item.add_marker(skip)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def dtype_tol(dtype, n=1, factor=1000.0):
    """``factor * eps * sqrt(n)`` comparison tolerance — scales with the
    working precision and problem size instead of hard-coding ULP-tight
    constants that flake across BLAS/XLA versions."""
    eps = float(np.finfo(np.dtype(dtype)).eps)
    return factor * eps * float(np.sqrt(n))


def make_smooth_matrix(n=200, m=120, dtype=np.float64):
    """Snapshots of a smooth parameterized family (fast-decaying n-width)."""
    x = np.linspace(0, 1, n)
    nu = np.linspace(0.5, 2.0, m)
    S = np.stack([np.sin(2 * np.pi * v * x) * np.exp(-v * x) for v in nu],
                 axis=1)
    if np.issubdtype(dtype, np.complexfloating):
        S = S * np.exp(1j * np.outer(x, nu))
    return S.astype(dtype)
