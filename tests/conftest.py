import os

# Tests must see exactly ONE device (the dry-run forces 512 in its own
# subprocess); also keep kernels in interpret mode on CPU.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_smooth_matrix(n=200, m=120, dtype=np.float64):
    """Snapshots of a smooth parameterized family (fast-decaying n-width)."""
    x = np.linspace(0, 1, n)
    nu = np.linspace(0.5, 2.0, m)
    S = np.stack([np.sin(2 * np.pi * v * x) * np.exp(-v * x) for v in nu],
                 axis=1)
    if np.issubdtype(dtype, np.complexfloating):
        S = S * np.exp(1j * np.outer(x, nu))
    return S.astype(dtype)
