"""Fault-injection matrix: self-healing builds under crash/corruption.

PR-6 acceptance surface:

- randomized kill-point crash + resume across {streamed, blocked,
  resident-with-workdir} x {f32, c64} lands on an artifact bit-identical
  to the uninterrupted build;
- a build killed MID-FINALIZE (after the artifact step is fully written
  but before the atomic rename) never exposes a partial artifact and
  resumes to the identical one;
- corrupted-leaf / truncated-manifest artifact steps fall back to the
  newest intact step on load;
- the principled floor-stop (STOP_FLOOR) fires on all four driver paths
  (greedy / block_greedy / streamed / distributed) on an f32 family whose
  post-refresh residual plateaus above tau, and lands in artifact
  provenance;
- the fault-injection harness itself (FaultPlan / FaultyProvider /
  bounded retry) behaves as documented.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from conftest import make_smooth_matrix
from repro.api import ReducedBasis, ReductionSpec, build_basis
from repro.data import (
    ArrayProvider,
    FaultPlan,
    FaultyProvider,
    as_provider,
    write_snapshot_npy,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ------------------------------------------------------------------ helpers


def floor_regime_matrix(seed=7, N=200, M=160, r=50, sigma=1.45e-7):
    """f32 family whose exact residual plateaus ABOVE a tiny tau.

    r modes decay smoothly over 4 decades, then the spectrum cliffs onto
    an incompressible noise floor at ~sigma*sqrt(N) ~ 2e-6 — inside the
    floor-stop window (50*eps*scale, 10*eps*scale*sqrt(k)) once k grows
    past the modes.  With an aggressive refresh cadence a refresh is
    guaranteed to land while the residual is in that window, so every
    greedy driver must terminate with STOP_FLOOR instead of looping
    refreshes (the PR-5 stop-gap's failure mode) or mining noise columns
    until the rank guard trips.
    """
    rng = np.random.default_rng(seed)
    U, _ = np.linalg.qr(rng.standard_normal((N, r)))
    V, _ = np.linalg.qr(rng.standard_normal((M, r)))
    sv = np.logspace(0, -4, r)
    return ((U * sv) @ V.T + sigma * rng.standard_normal((N, M))).astype(
        np.float32)


FLOOR_TAU = 1e-7
FLOOR_SAFETY = 2e6  # refresh trigger ratio sqrt(safety*eps) ~ 0.5 per step


class _SimulatedCrash(RuntimeError):
    pass


def _crashing_callback(kill_k):
    """Per-chunk callback that raises once the basis reaches kill_k."""

    def cb(state):
        if int(np.asarray(state["k"] if isinstance(state, dict)
                          else state.k)) >= kill_k:
            raise _SimulatedCrash(f"injected crash at k>={kill_k}")

    return cb


def _assert_basis_equal(a: ReducedBasis, b: ReducedBasis):
    assert a.k == b.k
    for f in ("Q", "pivots", "errs"):
        x = np.asarray(getattr(a, f))
        y = np.asarray(getattr(b, f))
        assert np.array_equal(x, y), f"{f} differs"
    if a.R is not None or b.R is not None:
        assert np.array_equal(np.asarray(a.R), np.asarray(b.R))


# ----------------------------------------- randomized crash/resume matrix --


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize("strategy,block_p", [
    ("streamed", 1), ("streamed", 4), ("block_greedy", 4), ("greedy", 1),
])
def test_random_killpoint_resume_bit_identical(tmp_path, strategy, block_p,
                                               dtype):
    """Crash at a randomized point, resume, compare to the uninterrupted
    build: Q/pivots/errs (and R) must be bit-identical."""
    S = make_smooth_matrix(n=80, m=64, dtype=dtype)
    common = dict(strategy=strategy, tau=1e-6, block_p=block_p,
                  tile_m=16, chunk=4, checkpoint_every_tiles=1)

    ref = build_basis(source=S, workdir=str(tmp_path / "ref"), **common)
    assert ref.k > 4  # enough progress for a mid-build kill to matter

    import zlib

    rng = np.random.default_rng(
        zlib.crc32(f"{strategy}/{block_p}/{np.dtype(dtype)}".encode()))
    for trial in range(2):
        wd = str(tmp_path / f"crash_{trial}")
        if strategy == "streamed":
            # kill via a hard provider fault at a random tile read (every
            # build does well over 20: an init sweep plus one sweep per
            # accepted block); the resumed run streams through a healthy
            # provider.
            kill_tile = int(rng.integers(1, 20))
            faulty = FaultyProvider(ArrayProvider(S),
                                    FaultPlan(raise_at_tile=kill_tile))
            with pytest.raises(IOError):
                build_basis(source=faulty, workdir=wd, **common)
        else:
            # resident drivers: crash from the per-chunk callback at a
            # random rank (exercises the chunked checkpoint cadence).
            kill_k = int(rng.integers(2, max(ref.k, 3)))
            with pytest.raises(_SimulatedCrash):
                build_basis(source=S, workdir=wd,
                            callback=_crashing_callback(kill_k), **common)
        assert not os.path.exists(os.path.join(wd, "step_00000000")), \
            "partial artifact observable after crash"
        resumed = build_basis(source=S, workdir=wd, resume=True, **common)
        _assert_basis_equal(ref, resumed)
        # resume of the FINISHED workdir is a no-op returning the artifact
        again = build_basis(source=S, workdir=wd, resume=True, **common)
        _assert_basis_equal(ref, again)
        assert not os.path.isdir(os.path.join(wd, "build")), \
            "build scratch survived finalize"


def test_workdir_fresh_build_wipes_stale_scratch(tmp_path):
    S = make_smooth_matrix(n=60, m=40, dtype=np.float32)
    wd = str(tmp_path / "w")
    # kill in the SECOND chunk so the first chunk's checkpoint exists
    with pytest.raises(_SimulatedCrash):
        build_basis(source=S, strategy="greedy", tau=1e-6, chunk=4,
                    workdir=wd, callback=_crashing_callback(8))
    assert os.path.isdir(os.path.join(wd, "build"))
    # resume=False must NOT splice onto the stale checkpoints
    b = build_basis(source=S, strategy="greedy", tau=1e-6, chunk=4,
                    workdir=wd)
    ref = build_basis(source=S, strategy="greedy", tau=1e-6, chunk=4)
    _assert_basis_equal(ref, b)


def test_workdir_checkpoint_dir_mutually_exclusive():
    with pytest.raises(ValueError, match="mutually exclusive"):
        ReductionSpec(source=np.eye(4, dtype=np.float32),
                      workdir="a", checkpoint_dir="b")


# ------------------------------------------------- corrupted-artifact load --


def _save_two_steps(tmp_path):
    S = make_smooth_matrix(n=40, m=24, dtype=np.float32)
    basis = build_basis(source=S, strategy="greedy", tau=1e-6)
    d = str(tmp_path / "art")
    basis.save(d)  # step 0
    basis.save(d)  # step 1 (newest)
    return basis, d


def test_load_falls_back_on_corrupt_leaf(tmp_path):
    basis, d = _save_two_steps(tmp_path)
    q = os.path.join(d, "step_00000001", "Q.npy")
    with open(q, "r+b") as f:  # flip a byte -> CRC mismatch
        f.seek(os.path.getsize(q) - 1)
        b = f.read(1)
        f.seek(os.path.getsize(q) - 1)
        f.write(bytes([b[0] ^ 0xFF]))
    loaded = ReducedBasis.load(d)
    _assert_basis_equal(basis, loaded)


def test_load_falls_back_on_truncated_manifest(tmp_path):
    basis, d = _save_two_steps(tmp_path)
    m = os.path.join(d, "step_00000001", "manifest.json")
    with open(m, "r+b") as f:
        f.truncate(os.path.getsize(m) // 2)
    loaded = ReducedBasis.load(d)
    _assert_basis_equal(basis, loaded)


def test_load_error_names_offending_file(tmp_path):
    import re

    from repro.checkpoint import load_checkpoint_raw

    basis, d = _save_two_steps(tmp_path)
    for s in ("step_00000000", "step_00000001"):
        m = os.path.join(d, s, "manifest.json")
        with open(m, "r+b") as f:
            f.truncate(1)
    with pytest.raises(IOError, match="manifest"):
        load_checkpoint_raw(d)
    with pytest.raises(
            IOError, match=re.escape(os.path.join(d, "step_00000001"))):
        load_checkpoint_raw(d, step=1)


def test_load_skips_non_artifact_steps(tmp_path):
    """A raw driver checkpoint in the artifact dir must not shadow it."""
    from repro.checkpoint import save_checkpoint

    basis, d = _save_two_steps(tmp_path)
    save_checkpoint({"not_an_artifact": np.zeros(3)}, d, 2)
    loaded = ReducedBasis.load(d)
    _assert_basis_equal(basis, loaded)


def test_orphan_tmp_dirs_collected_on_save(tmp_path):
    basis, d = _save_two_steps(tmp_path)
    orphan = os.path.join(d, "step_00000009.tmp")
    os.makedirs(orphan)
    basis.save(d)
    assert not os.path.exists(orphan)


# ----------------------------------------------------- principled floor-stop


class TestFloorStop:
    """The PR-5 floor-regime scenario ends in STOP_FLOOR on all four
    driver paths (and the verdict reaches artifact provenance)."""

    @pytest.fixture(scope="class")
    def S(self):
        return floor_regime_matrix()

    def _check(self, res):
        from repro.core.greedy import STOP_FLOOR, STOP_NAMES

        assert int(res.stop) == STOP_FLOOR, STOP_NAMES.get(int(res.stop))
        # terminated above tau (the whole point: tau was unreachable)
        assert float(res.errs[int(res.k) - 1]) > FLOOR_TAU

    def test_resident_greedy(self, S):
        from repro.core.greedy import rb_greedy

        self._check(rb_greedy(S, FLOOR_TAU, refresh_safety=FLOOR_SAFETY))

    def test_block_greedy(self, S):
        from repro.core.block_greedy import _rb_greedy_block_impl

        self._check(_rb_greedy_block_impl(
            S, FLOOR_TAU, p=4, refresh_safety=FLOOR_SAFETY))

    def test_streamed(self, S):
        from repro.core.streaming import rb_greedy_streamed

        self._check(rb_greedy_streamed(
            S, FLOOR_TAU, tile_m=50, refresh_safety=FLOOR_SAFETY))

    def test_distributed(self, S):
        from repro.compat import make_auto_mesh
        from repro.core.distributed import distributed_greedy

        mesh = make_auto_mesh((1,), ("cols",))
        self._check(distributed_greedy(
            S, FLOOR_TAU, max_k=min(S.shape), mesh=mesh,
            refresh_safety=FLOOR_SAFETY))

    def test_floor_stop_in_provenance(self, S, tmp_path):
        b = build_basis(source=S, strategy="greedy", tau=FLOOR_TAU,
                        refresh_safety=FLOOR_SAFETY,
                        workdir=str(tmp_path / "w"))
        assert b.provenance["stop"] == "STOP_FLOOR"
        assert ReducedBasis.load(
            str(tmp_path / "w")).provenance["stop"] == "STOP_FLOOR"


# ------------------------------------------------- fault harness unit tests


def test_faulty_provider_transient_heals(monkeypatch):
    monkeypatch.setenv("REPRO_IO_RETRY_BASE_S", "0.001")
    S = make_smooth_matrix(n=30, m=20, dtype=np.float32)
    p = FaultyProvider(ArrayProvider(S), FaultPlan(transient_every=2))
    tiles = [np.asarray(p.tile(lo, hi)) for lo, hi in p.tiles(5)]
    assert np.array_equal(np.concatenate(tiles, axis=1), S)


def test_faulty_provider_hard_raise():
    S = make_smooth_matrix(n=30, m=20, dtype=np.float32)
    p = FaultyProvider(ArrayProvider(S), FaultPlan(raise_at_tile=1))
    p.tile(0, 5)
    with pytest.raises(IOError, match="injected hard I/O fault"):
        p.tile(5, 10)


def test_as_provider_env_autowrap(monkeypatch):
    S = make_smooth_matrix(n=30, m=20, dtype=np.float32)
    monkeypatch.setenv("REPRO_FAULT_TRANSIENT_EVERY", "3")
    p = as_provider(S)
    assert isinstance(p, FaultyProvider)
    assert as_provider(p) is p  # never double-wrapped
    monkeypatch.delenv("REPRO_FAULT_TRANSIENT_EVERY")
    assert not isinstance(as_provider(S), FaultyProvider)


def test_memmap_read_retry_transient(tmp_path, monkeypatch):
    """The retry wrapper survives transient faults on real file reads."""
    from repro.data.providers import MemmapProvider, _read_with_retry

    monkeypatch.setenv("REPRO_IO_RETRY_BASE_S", "0.001")
    S = make_smooth_matrix(n=30, m=20, dtype=np.float32)
    path = write_snapshot_npy(str(tmp_path / "s.npy"), S)
    prov = MemmapProvider(path)
    assert np.array_equal(np.asarray(prov.tile(3, 11)), S[:, 3:11])

    calls = [0]

    def flaky():
        calls[0] += 1
        if calls[0] < 3:
            raise IOError("transient")
        return "ok"

    assert _read_with_retry(flaky, "test") == "ok"
    assert calls[0] == 3

    def always():
        raise IOError("permanent")

    monkeypatch.setenv("REPRO_IO_RETRIES", "2")
    with pytest.raises(IOError, match="failed after 3 attempts"):
        _read_with_retry(always, "doomed read")


# --------------------------------------------- supervisor restart policy ---


def test_supervisor_restart_budget_and_backoff(tmp_path):
    """Crash-twice-then-succeed fits a budget of 2 but not 1."""
    from repro.launch.supervisor import run_supervised

    marker = tmp_path / "attempts"
    prog = (f"import os, sys\n"
            f"p = {str(marker)!r}\n"
            f"n = int(open(p).read()) if os.path.exists(p) else 0\n"
            f"open(p, 'w').write(str(n + 1))\n"
            f"sys.exit(0 if n >= 2 else 7)\n")
    cmd = [sys.executable, "-c", prog]
    rc = run_supervised(cmd, max_restarts=2, backoff_base_s=0.01)
    assert rc == 0
    assert marker.read_text() == "3"

    marker.unlink()
    rc = run_supervised(cmd, max_restarts=1, backoff_base_s=0.01)
    assert rc == 7  # budget of 1 exhausted before the 3rd attempt


# ------------------------------------------------ supervised e2e smoke -----

_BUILD_PROG = """
import sys
import numpy as np
from repro.api import build_basis
b = build_basis(source=sys.argv[1], strategy="streamed", tau=1e-6,
                tile_m=8, block_p=4, checkpoint_every_tiles=1,
                workdir=sys.argv[2], resume=True)
print("k =", b.k)
"""


@pytest.mark.slow
def test_supervised_streamed_build_survives_kill(tmp_path, monkeypatch):
    """Kill a streamed blocked build mid-run (randomized tile) AND
    mid-finalize; the supervisor's relaunch must finalize an artifact
    bit-identical to the uninterrupted build, with no partial artifact
    ever loadable."""
    from repro.launch.supervisor import run_supervised

    S = make_smooth_matrix(n=60, m=48, dtype=np.complex64)
    npy = write_snapshot_npy(str(tmp_path / "S.npy"), S)
    # the supervised subprocess inherits this test's environment
    monkeypatch.setenv("PYTHONPATH", SRC)
    monkeypatch.setenv("JAX_PLATFORMS", "cpu")

    def build(workdir):
        os.makedirs(workdir, exist_ok=True)
        return run_supervised(
            [sys.executable, "-c", _BUILD_PROG, npy, workdir],
            max_restarts=2, backoff_base_s=0.0,
            log_path=os.path.join(workdir, "run.log"))

    # uninterrupted reference
    assert build(str(tmp_path / "ref")) == 0
    ref = ReducedBasis.load(str(tmp_path / "ref"))

    kill_tile = int(np.random.default_rng(0).integers(3, 30))
    wd = str(tmp_path / "killed")
    monkeypatch.setenv("REPRO_FAULT_KILL_AT_TILE", str(kill_tile))
    monkeypatch.setenv("REPRO_FAULT_KILL_AT_FINALIZE", "1")
    monkeypatch.setenv("REPRO_FAULT_ONCE", str(tmp_path / "fault_marker"))
    rc = build(wd)
    monkeypatch.delenv("REPRO_FAULT_KILL_AT_TILE")
    monkeypatch.delenv("REPRO_FAULT_KILL_AT_FINALIZE")
    monkeypatch.delenv("REPRO_FAULT_ONCE")
    assert rc == 0, open(os.path.join(wd, "run.log"), "rb").read()[-2000:]
    # both faults actually fired (at-most-once markers exist)
    assert os.path.exists(str(tmp_path / "fault_marker") + ".kill_at_tile")
    assert os.path.exists(
        str(tmp_path / "fault_marker") + ".kill_at_finalize")
    _assert_basis_equal(ref, ReducedBasis.load(wd))
    # the artifact dir holds exactly the finalized step — the finalize
    # kill's fully-written-but-unrenamed tmp never became observable
    assert [d for d in os.listdir(wd)
            if d.startswith("step_") and not d.endswith(".tmp")] \
        == ["step_00000000"]


# ------------------------------------------------------------- enrichment --


def test_enrich_extends_and_resaves(tmp_path):
    S = make_smooth_matrix(n=60, m=40, dtype=np.complex64)
    wd = str(tmp_path / "w")
    b = build_basis(source=S, strategy="streamed", tau=1e-6, tile_m=16,
                    workdir=wd)
    # new snapshots: the old family plus genuinely new directions
    rng = np.random.default_rng(5)
    extra = (rng.standard_normal((60, 6))
             + 1j * rng.standard_normal((60, 6))).astype(np.complex64)
    S2 = np.concatenate([S, extra], axis=1)
    e = b.enrich(S2, tile_m=16)
    assert e.k > b.k
    # seed bases kept verbatim, new pivots index the enrichment source
    assert np.array_equal(np.asarray(e.Q[:, :b.k]), np.asarray(b.Q))
    assert np.array_equal(np.asarray(e.pivots[:b.k]), np.asarray(b.pivots))
    assert all(int(p) < S2.shape[1] for p in e.pivots[b.k:])
    # the enriched basis covers the new family down to the c64 working
    # precision (the greedy may stop at the rank guard ~50*eps*scale, so
    # compare against a precision-scaled bound, not tau itself)
    E = S2 - np.asarray(e.Q) @ (np.asarray(e.Q).conj().T @ S2)
    scale = float(np.linalg.norm(S2, axis=0).max())
    assert float(np.linalg.norm(E, axis=0).max()) < 1e-4 * scale
    assert e.provenance["enriched_from_k"] == b.k
    # re-saved as the newest artifact step in the same workdir
    assert ReducedBasis.load(wd).k == e.k


def test_enrich_noop_when_covered(tmp_path):
    S = make_smooth_matrix(n=60, m=40, dtype=np.float32)
    b = build_basis(source=S, strategy="greedy", tau=1e-6)
    # source already covered well below this tau: no new bases.  (The f32
    # build rank-guard-stops with true residuals ~2e-4, legitimately
    # enrichable at tighter taus — so test no-op safely above that.)
    e = b.enrich(S[:, :10], tau=1e-3, tile_m=8, save=False)
    assert e.k == b.k
    assert np.array_equal(np.asarray(e.Q), np.asarray(b.Q))
