"""Empirical interpolation + reduced-order quadrature (the GW application)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    eim_nodes, empirical_interpolant, rb_greedy, roq_weights,
)
from repro.gw import build_snapshot_matrix, chirp_grid, frequency_grid


@pytest.fixture(scope="module")
def gw_basis():
    f = frequency_grid(20.0, 256.0, 400)
    m1, m2 = chirp_grid(n_mc=20, n_eta=6)
    S = build_snapshot_matrix(f, m1, m2, dtype=jnp.complex128)
    res = rb_greedy(S, tau=1e-6)
    k = int(res.k)
    return f, S, res.Q[:, :k]


def test_nodes_unique(gw_basis):
    _, _, Q = gw_basis
    ei = eim_nodes(Q)
    nodes = np.asarray(ei.nodes)
    assert len(set(nodes.tolist())) == Q.shape[1]


def test_interpolation_exact_on_basis(gw_basis):
    """The interpolant reproduces every basis vector exactly."""
    _, _, Q = gw_basis
    ei = eim_nodes(Q)
    for i in (0, Q.shape[1] // 2, Q.shape[1] - 1):
        q = Q[:, i]
        interp = empirical_interpolant(ei.B, ei.nodes, q)
        assert float(jnp.max(jnp.abs(interp - q))) < 1e-10


def test_interpolation_exact_at_nodes(gw_basis):
    _, S, Q = gw_basis
    ei = eim_nodes(Q)
    fvec = S[:, 3]
    interp = empirical_interpolant(ei.B, ei.nodes, fvec)
    assert float(jnp.max(jnp.abs(interp[ei.nodes] - fvec[ei.nodes]))) < 1e-9


def test_interpolation_error_tracks_basis_error(gw_basis):
    """EIM error on snapshots is within a (Lebesgue) factor of tau."""
    _, S, Q = gw_basis
    ei = eim_nodes(Q)
    errs = []
    for i in range(0, S.shape[1], 17):
        fvec = S[:, i]
        interp = empirical_interpolant(ei.B, ei.nodes, fvec)
        errs.append(float(jnp.linalg.norm(interp - fvec)))
    assert max(errs) < 1e-3  # tau=1e-6 basis; generous Lebesgue allowance


def test_roq_inner_product(gw_basis):
    """ROQ weights reproduce <d, h> for in-span h at the EI nodes."""
    f, S, Q = gw_basis
    ei = eim_nodes(Q)
    w = jnp.ones((S.shape[0],)) * (f[1] - f[0])
    d = S[:, 7]
    omega = roq_weights(d, w, ei.B)
    h = S[:, 21]
    full = jnp.sum(w * jnp.conj(d) * h)
    fast = jnp.sum(omega * h[ei.nodes])
    assert abs(complex(full - fast)) < 1e-6 * abs(complex(full)) + 1e-10
