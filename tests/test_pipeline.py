"""Pipeline parallelism (GPipe over the pod axis): correctness on 8 devs."""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import jax, json
import jax.numpy as jnp
import numpy as np
from repro.compat import make_auto_mesh
from repro.configs import get_reduced
from repro.training.pipeline import make_pipeline_forward
from repro.models import api, transformer as tfm

mesh = make_auto_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_reduced("stablelm-3b").replace(n_layers=4)
params = api.init_params(cfg, jax.random.key(0))
n_micro, B, S = 4, 2, 16
loss_fn, _ = make_pipeline_forward(cfg, mesh, n_micro)
toks = jax.random.randint(jax.random.key(0), (n_micro, B, S), 0, cfg.vocab_size)
labs = jax.random.randint(jax.random.key(1), (n_micro, B, S), 0, cfg.vocab_size)
blocks_st = jax.tree.map(lambda b: b.reshape(2, 2, *b.shape[1:]), params.blocks)
lm_head = params.lm_head if params.lm_head is not None else params.embed.T
lp = float(loss_fn(params.embed, blocks_st, params.final_norm, lm_head, toks, labs))
ls = []
for i in range(n_micro):
    logits = tfm.decoder_forward(params, cfg, toks[i]).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labs[i][..., None], -1)[..., 0]
    ls.append(float(jnp.mean(logz - gold)))
g = jax.grad(lambda e: loss_fn(e, blocks_st, params.final_norm, lm_head,
                               toks, labs))(params.embed)
print("RESULT " + json.dumps({
    "pp": lp, "ref": float(np.mean(ls)),
    "grad_finite": bool(np.isfinite(np.asarray(g, np.float32)).all()),
    "grad_norm": float(jnp.linalg.norm(g.astype(jnp.float32)))}))
"""


@pytest.mark.slow
def test_pipeline_matches_reference():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    p = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert p.returncode == 0, p.stderr[-2500:]
    line = [l for l in p.stdout.splitlines() if l.startswith("RESULT ")][-1]
    r = json.loads(line[len("RESULT "):])
    assert abs(r["pp"] - r["ref"]) < 1e-3
    assert r["grad_finite"] and r["grad_norm"] > 0
