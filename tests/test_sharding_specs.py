"""Sharding rules: logical-axis resolution, spec trees, mesh helpers."""

import subprocess
import sys
import os

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_reduced
from repro.models import api
from repro.sharding import resolve, tree_shardings


class FakeMesh:
    def __init__(self, names):
        self.axis_names = names


def test_resolve_single_pod():
    m = FakeMesh(("data", "model"))
    assert resolve(m, "dp", None) == P(("data",), None)
    assert resolve(m, "fsdp", "tp") == P(("data",), "model")
    assert resolve(m, None, "sp", None) == P(None, "model", None)


def test_resolve_multi_pod():
    m = FakeMesh(("pod", "data", "model"))
    assert resolve(m, "dp", None) == P(("pod", "data"), None)
    assert resolve(m, "cols") == P(("pod", "data", "model"))


@pytest.mark.parametrize("arch", ["stablelm-3b", "mixtral-8x7b",
                                  "mamba2-780m", "recurrentgemma-9b",
                                  "llama-3.2-vision-11b",
                                  "seamless-m4t-medium"])
def test_param_specs_cover_params(arch):
    """Every param leaf has a spec leaf with matching tree structure."""
    cfg = get_reduced(arch)
    shapes = api.abstract_params(cfg)
    specs = api.param_specs(cfg)
    jax.tree.map(
        lambda s, spec: None,
        shapes, specs,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            y is None or isinstance(y, str) for y in x),
    )  # raises on structure mismatch
    # spec ranks match param ranks
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(
        specs,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            y is None or isinstance(y, str) for y in x),
    )
    assert len(flat_shapes) == len(flat_specs)
    for s, spec in zip(flat_shapes, flat_specs):
        assert len(spec) == len(s.shape), f"{spec} vs {s.shape}"


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp
    from repro.sharding import constrain
    x = jnp.ones((4, 4))
    y = constrain(x, "dp", "tp")
    assert np.array_equal(np.asarray(x), np.asarray(y))


def test_production_mesh_subprocess():
    """make_production_mesh builds 256/512-device meshes (forced devices)."""
    script = (
        "import os;"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=512';"
        "from repro.launch.mesh import make_production_mesh;"
        "m1=make_production_mesh();m2=make_production_mesh(multi_pod=True);"
        "print(m1.shape, m2.shape);"
        "assert m1.size==256 and m2.size==512;"
        "assert m1.axis_names==('data','model');"
        "assert m2.axis_names==('pod','data','model')"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    p = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=300)
    assert p.returncode == 0, p.stderr[-1500:]


def test_dryrun_machinery_small_mesh():
    """input_specs + lowering works on an 8-device host mesh (subprocess)."""
    script = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, functools
from repro.configs import get_reduced
from repro.launch import specs as S
from repro.launch import roofline as R
from repro.models import api
from repro.compat import make_auto_mesh
from repro.models.config import ShapeConfig
from repro.sharding import use_mesh
from repro.training.trainer import make_train_step

mesh = make_auto_mesh((2, 4), ("data", "model"))
cfg = get_reduced("mixtral-8x7b")
shape = ShapeConfig("t", 64, 8, "train")
step = make_train_step(cfg, n_microbatches=2, donate=False)
with use_mesh(mesh):
    compiled = step.lower(S.abstract_train_state(cfg, mesh),
                          S.batch_specs(cfg, shape, mesh)).compile()
terms = R.cost_terms(compiled)
assert terms["flops"] > 0
assert terms["bytes"] > 0
# decode cell
shape_d = ShapeConfig("d", 64, 8, "decode")
fn = jax.jit(functools.partial(api.decode_step, cfg))
tok, cache = S.decode_specs(cfg, shape_d, mesh)
with use_mesh(mesh):
    c2 = fn.lower(S.abstract_sharded_params(cfg, mesh), tok, cache).compile()
assert R.cost_terms(c2)["flops"] > 0
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    p = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert p.returncode == 0, p.stderr[-2500:]
    assert "OK" in p.stdout


def test_collective_parser():
    from repro.launch.roofline import collective_bytes, _shape_bytes
    assert _shape_bytes("f32[16,4096,2560]{2,1,0}") == 16 * 4096 * 2560 * 4
    assert _shape_bytes("(bf16[8,4]{1,0}, f32[2]{0})") == 8 * 4 * 2 + 2 * 4
    text = """
  %all-reduce.1 = f32[16,2560]{1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[4,8]{1,0} all-gather(%y), channel_id=1
  %ar-done = f32[4]{0} all-reduce-done(%z)
"""
    out = collective_bytes(text)
    assert out["all-reduce"] == 16 * 2560 * 4
    assert out["all-gather"] == 4 * 8 * 2
    assert out["total"] == 2 * 16 * 2560 * 4 + 4 * 8 * 2


def test_tp_modes_numerically_equivalent():
    """megatron vs ulysses vs +EP shardings compute the same loss (8 devs)."""
    script = r"""
import jax, numpy as np
import jax.numpy as jnp
from repro.configs import get_reduced
from repro.models import api
from repro.compat import make_auto_mesh
from repro.launch import specs as S
from repro.sharding import use_mesh
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = make_auto_mesh((2, 4), ("data", "model"))
for arch in ("stablelm-3b", "mixtral-8x7b"):
    base = get_reduced(arch).replace(
        d_model=64, n_heads=8, n_kv_heads=4, vocab_size=256)
    key = jax.random.key(0)
    losses = {}
    for mode, ov in [("megatron", {}), ("ulysses", {"tp_mode": "ulysses"}),
                     ("megatron_rs", {"tp_mode": "megatron_rs"}),
                     ("ulysses+ep", {"tp_mode": "ulysses", "moe_ep": True})]:
        cfg = base.replace(**ov)
        params = api.init_params(cfg, key)
        batch = api.make_batch(cfg, key, batch=4, seq=32)
        shardings = jax.tree.map(
            lambda sh: sh, S.param_shardings(cfg, mesh))
        params = jax.tree.map(
            lambda x, sh: jax.device_put(
                x, S.sanitize_sharding(sh, x.shape, mesh)),
            params, shardings)
        with use_mesh(mesh):
            losses[mode] = float(jax.jit(
                lambda p: api.loss_fn(cfg, p, batch))(params))
    vals = list(losses.values())
    assert max(vals) - min(vals) < 5e-3, (arch, losses)
    print(arch, losses)
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    p = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert p.returncode == 0, p.stderr[-2500:]
    assert "OK" in p.stdout
