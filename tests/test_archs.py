"""Per-architecture reduced-config smoke tests (assignment requirement):
one forward/train step on CPU asserting output shapes + no NaNs, plus
prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import arch_ids, get_config, get_reduced
from repro.models import api
from repro.models.config import SHAPES


@pytest.mark.parametrize("arch", arch_ids())
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "mamba2-780m": (48, 1536, None, None, 0, 50280),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    }[arch]
    L, d, H, K, ff, V = spec
    assert cfg.n_layers == L
    assert cfg.d_model == d
    if H is not None:
        assert cfg.n_heads == H
        assert cfg.n_kv_heads == K
    assert cfg.d_ff == ff
    assert cfg.vocab_size == V


def test_moe_configs():
    c = get_config("llama4-maverick-400b-a17b")
    assert (c.n_experts, c.experts_per_token) == (128, 1)
    c = get_config("mixtral-8x7b")
    assert (c.n_experts, c.experts_per_token) == (8, 2)
    assert c.sliding_window == 4096


@pytest.mark.parametrize("arch", arch_ids())
def test_smoke_train_step(arch):
    """Reduced config: one forward/backward; shapes + finiteness."""
    cfg = get_reduced(arch)
    key = jax.random.key(0)
    params = api.init_params(cfg, key)
    batch = api.make_batch(cfg, key, batch=2, seq=24)

    logits = jax.jit(lambda p: api.forward_logits(cfg, p, batch))(params)
    assert logits.shape == (2, 24, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    loss, grads = jax.jit(
        jax.value_and_grad(lambda p: api.loss_fn(cfg, p, batch))
    )(params)
    assert np.isfinite(float(loss))
    gn = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: float(jnp.sum(jnp.abs(g))), grads),
    )
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", arch_ids())
def test_smoke_prefill_decode_consistency(arch):
    """Greedy decode after prefill == teacher-forced argmax continuation."""
    cfg = get_reduced(arch)
    key = jax.random.key(1)
    params = api.init_params(cfg, key)
    batch = api.make_batch(cfg, key, batch=2, seq=16)

    lg, cache = jax.jit(
        lambda p, b: api.prefill(cfg, p, b, max_len=24)
    )(params, batch)
    assert lg.shape == (2, cfg.vocab_size)

    # teacher forcing: the prefill last-token logits must match the full
    # forward's last position
    full = api.forward_logits(cfg, params, batch)
    np.testing.assert_allclose(
        np.asarray(lg, np.float32),
        np.asarray(full[:, -1], np.float32),
        rtol=2e-2, atol=2e-2,
    )

    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    lg2, cache2 = jax.jit(
        lambda p, t, c: api.decode_step(cfg, p, t, c)
    )(params, tok, cache)
    assert lg2.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg2, np.float32)).all()

    # decode must match a fresh forward on the extended sequence
    toks_ext = jnp.concatenate([batch["tokens"], tok[:, None]], axis=1)
    batch_ext = dict(batch, tokens=toks_ext)
    full2 = api.forward_logits(cfg, params, batch_ext)
    np.testing.assert_allclose(
        np.asarray(lg2, np.float32),
        np.asarray(full2[:, -1], np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_param_count_formulas():
    """Config param_count is within 2% of actually-initialized params."""
    for arch in ("stablelm-3b", "mixtral-8x7b", "mamba2-780m"):
        cfg = get_reduced(arch)
        params = api.init_params(cfg, jax.random.key(0))
        n_real = sum(
            int(np.prod(p.shape)) for p in jax.tree.leaves(params)
        )
        n_est = cfg.param_count()
        assert abs(n_real - n_est) / n_real < 0.1


def test_shape_table():
    assert SHAPES["train_4k"].tokens == 4096 * 256
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["decode_32k"].kind == "decode"


@pytest.mark.parametrize("arch", ["stablelm-3b", "mixtral-8x7b"])
def test_int8_kv_cache_decode(arch):
    """int8-quantized KV cache decodes close to the bf16 cache path."""
    cfg = get_reduced(arch)
    key = jax.random.key(1)
    params = api.init_params(cfg, key)
    batch = api.make_batch(cfg, key, batch=2, seq=16)
    lg, cache = api.prefill(cfg, params, batch, max_len=24)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    lg_f, _ = api.decode_step(cfg, params, tok, cache)

    cfg_q = cfg.replace(kv_cache_dtype="int8")
    _, cache_q = api.prefill(cfg_q, params, batch, max_len=24)
    lg_q, cache_q2 = api.decode_step(cfg_q, params, tok, cache_q)
    assert cache_q2.self_kv.k.dtype == jnp.int8
    d = float(jnp.max(jnp.abs(lg_f - lg_q)))
    assert d < 0.25
    assert np.array_equal(np.asarray(jnp.argmax(lg_f, -1)),
                          np.asarray(jnp.argmax(lg_q, -1)))
