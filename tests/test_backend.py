"""Backend dispatch layer: resolution rules + xla/pallas driver parity.

Parity is asserted pivot-for-pivot at tolerances above the Eq.-(6.3)
cancellation floor (below it, residuals are degenerate to f32 rounding and
tie-breaks legitimately differ between implementations — the same caveat
the equivalence tests document for RB-greedy vs pivoted MGS).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_smooth_matrix
from repro.core import backend as B
from repro.core import rb_greedy
from repro.kernels.greedy_update.ref import greedy_update_ref
from repro.kernels.imgs_project.ref import imgs_project_ref


# ------------------------------------------------------------- resolution
# (resolution tests clear REPRO_GREEDY_BACKEND: CI runs the whole suite
# under both backend-matrix values of that env var)
def test_resolve_auto_is_xla_off_tpu(monkeypatch):
    monkeypatch.delenv("REPRO_GREEDY_BACKEND", raising=False)
    assert jax.default_backend() != "tpu"  # conftest forces cpu
    assert B.resolve_backend(None) == "xla"
    assert B.resolve_backend("auto") == "xla"


def test_resolve_explicit_wins():
    assert B.resolve_backend("pallas") == "pallas"
    assert B.resolve_backend("xla") == "xla"


def test_resolve_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_GREEDY_BACKEND", "pallas")
    assert B.resolve_backend(None) == "pallas"
    # explicit argument still beats the env var
    assert B.resolve_backend("xla") == "xla"


def test_resolve_default_backend_setting(monkeypatch):
    monkeypatch.delenv("REPRO_GREEDY_BACKEND", raising=False)
    try:
        B.set_default_backend("pallas")
        assert B.resolve_backend(None) == "pallas"
    finally:
        B.set_default_backend("auto")
    assert B.resolve_backend(None) == "xla"


def test_backend_switch_after_compile(monkeypatch):
    """Drivers resolve the backend BEFORE jit, so changing the env var
    between same-shaped calls takes effect (a still-None static argument
    would freeze the first trace's resolution into the jit cache)."""
    S = jnp.asarray(make_smooth_matrix(n=64, m=40, dtype=np.float32))
    monkeypatch.delenv("REPRO_GREEDY_BACKEND", raising=False)
    a = rb_greedy(S, tau=1e-2)          # resolves to xla on cpu
    monkeypatch.setenv("REPRO_GREEDY_BACKEND", "pallas")
    b = rb_greedy(S, tau=1e-2)          # must now take the pallas path
    # same pivots either way (parity), but the second call must not crash
    # or silently reuse the xla executable — the resolved name is part of
    # the jit cache key, so this exercises a fresh pallas trace.
    assert int(a.k) == int(b.k)
    assert np.array_equal(np.asarray(a.pivots), np.asarray(b.pivots))


def test_invalid_backend_rejected():
    with pytest.raises(ValueError, match="unknown greedy backend"):
        B.resolve_backend("cuda")
    with pytest.raises(ValueError, match="unknown greedy backend"):
        B.set_default_backend("tpu")


# ------------------------------------------- complex plane-split (xla path)
@pytest.mark.parametrize("dtype", [np.complex64, np.complex128])
def test_plane_split_matches_ref(rng, dtype):
    """The xla backend's split re/im-plane complex sweep equals the
    reference complex-GEMV ops (xla_ref) up to summation order."""
    N, M, K = 130, 70, 19
    S = jnp.asarray((rng.standard_normal((N, M))
                     + 1j * rng.standard_normal((N, M))).astype(dtype))
    q = rng.standard_normal(N) + 1j * rng.standard_normal(N)
    q = jnp.asarray((q / np.linalg.norm(q)).astype(dtype))
    rdt = np.float64 if dtype == np.complex128 else np.float32
    acc = jnp.asarray(np.abs(rng.standard_normal(M)).astype(rdt))
    norms = jnp.sum(jnp.abs(S) ** 2, axis=0).astype(rdt)
    tol = 1e-12 if dtype == np.complex128 else 1e-5

    out_x = B.pivot_update(q, S, acc, norms, backend="xla")
    out_r = B.pivot_update(q, S, acc, norms, backend="xla_ref")
    np.testing.assert_allclose(np.asarray(out_x[0]), np.asarray(out_r[0]),
                               rtol=tol, atol=10 * tol)
    np.testing.assert_allclose(np.asarray(out_x[1]), np.asarray(out_r[1]),
                               rtol=tol, atol=100 * tol)
    assert int(out_x[3]) == int(out_r[3])

    Q = jnp.asarray(np.linalg.qr(
        rng.standard_normal((N, K)) + 1j * rng.standard_normal((N, K))
    )[0].astype(dtype))
    v = jnp.asarray((rng.standard_normal(N)
                     + 1j * rng.standard_normal(N)).astype(dtype))
    vx, cx = B.project_pass(v, Q, backend="xla")
    vr, cr = B.project_pass(v, Q, backend="xla_ref")
    np.testing.assert_allclose(np.asarray(vx), np.asarray(vr),
                               rtol=10 * tol, atol=10 * tol)
    np.testing.assert_allclose(np.asarray(cx), np.asarray(cr),
                               rtol=10 * tol, atol=10 * tol)


def _dot_lines(hlo_text):
    return [l for l in hlo_text.splitlines() if "dot" in l]


@pytest.mark.parametrize("dtype", [np.complex64, np.complex128])
def test_complex_sweep_lowers_to_real_gemvs(rng, dtype):
    """Regression pin for the PR-1 complex-GEMV pathology: under the xla
    backend, complex pivot sweeps and projection passes must lower to REAL
    dot ops only (the split re/im 4-GEMV plan).  A complex-dtype dot in the
    lowered program means the plane-split path silently regressed — on CPU
    XLA lowers a complex GEMV to a scalar loop ~10x slower (measured
    709 ms vs 66 ms at N=4096, M=16384).  Structural, not wall-clock: the
    pin cannot flake on a noisy box."""
    N, M, K = 64, 96, 8
    S = jnp.asarray((rng.standard_normal((N, M))
                     + 1j * rng.standard_normal((N, M))).astype(dtype))
    q = jnp.asarray(rng.standard_normal(N).astype(dtype))
    rdt = np.float64 if dtype == np.complex128 else np.float32
    acc = jnp.zeros((M,), rdt)
    norms = jnp.sum(jnp.abs(S) ** 2, axis=0).astype(rdt)

    def lower_pivot(bk):
        return jax.jit(
            lambda *a: B.pivot_update(*a, backend=bk)
        ).lower(q, S, acc, norms).as_text()

    dots = _dot_lines(lower_pivot("xla"))
    assert dots, "expected the sweep to contain dot ops"
    assert not any("complex" in l for l in dots), (
        "xla-backend complex sweep emitted a complex-dtype dot — the "
        "plane-split 4-GEMV path regressed")
    # control: the reference path DOES emit a complex dot, so the
    # detection above is actually discriminating.
    assert any("complex" in l for l in _dot_lines(lower_pivot("xla_ref")))

    Q = jnp.asarray(np.linalg.qr(
        rng.standard_normal((N, K)) + 1j * rng.standard_normal((N, K))
    )[0].astype(dtype))
    v = jnp.asarray((rng.standard_normal(N)
                     + 1j * rng.standard_normal(N)).astype(dtype))

    def lower_proj(bk):
        return jax.jit(
            lambda *a: B.project_pass(*a, backend=bk)
        ).lower(v, Q).as_text()

    dots = _dot_lines(lower_proj("xla"))
    assert dots and not any("complex" in l for l in dots)
    assert any("complex" in l for l in _dot_lines(lower_proj("xla_ref")))


def test_complex_dispatch_routes_to_plane_split(rng, monkeypatch):
    """The xla backend must take the plane-split branch for complex inputs
    (and the plain ref branch for real ones) — guards the dispatch itself,
    complementing the lowering pin above."""
    calls = []
    real_split = B._plane_split_pivot
    monkeypatch.setattr(
        B, "_plane_split_pivot",
        lambda *a, **k: (calls.append("split"), real_split(*a, **k))[1],
    )
    N, M = 16, 12
    Sc = jnp.asarray((rng.standard_normal((N, M))
                      + 1j * rng.standard_normal((N, M))).astype(np.complex64))
    qc = jnp.asarray(rng.standard_normal(N).astype(np.complex64))
    accc = jnp.zeros((M,), jnp.float32)
    normsc = jnp.sum(jnp.abs(Sc) ** 2, axis=0)
    B.pivot_update(qc, Sc, accc, normsc, backend="xla")
    assert calls == ["split"]
    Sr = jnp.asarray(rng.standard_normal((N, M)).astype(np.float32))
    qr_ = jnp.asarray(rng.standard_normal(N).astype(np.float32))
    B.pivot_update(qr_, Sr, jnp.zeros((M,), jnp.float32),
                   jnp.sum(Sr * Sr, axis=0), backend="xla")
    assert calls == ["split"]  # real input must NOT take the split path


def test_xla_ref_driver_parity_complex():
    """Whole-driver parity between the optimized (plane-split) xla path and
    the seed reference ops.

    tau is kept above the Eq.-(6.3) cancellation floor: at res_sq ~
    eps * |s|^2 the residuals of near-degenerate columns differ by less
    than the tracking noise and tie-breaks legitimately depend on float
    summation order (seen at tau=1e-6 on this family)."""
    from repro.core import rb_greedy
    S = jnp.asarray(make_smooth_matrix(dtype=np.complex128))
    a = rb_greedy(S, tau=1e-4, backend="xla")
    b = rb_greedy(S, tau=1e-4, backend="xla_ref")
    k = int(a.k)
    assert int(b.k) == k
    assert k >= 6
    assert np.array_equal(np.asarray(a.pivots), np.asarray(b.pivots))
    np.testing.assert_allclose(np.asarray(a.Q), np.asarray(b.Q),
                               rtol=1e-9, atol=1e-9)


# -------------------------------------------------- primitive-level parity
@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize("shape", [(100, 70), (256, 384), (17, 33)])
def test_pivot_update_backend_parity(rng, dtype, shape):
    """pallas (interpret) and xla agree on c/acc and pick the same pivot,
    including non-tile-multiple (padded) shapes."""
    N, M = shape
    if np.issubdtype(dtype, np.complexfloating):
        S = (rng.standard_normal((N, M))
             + 1j * rng.standard_normal((N, M))).astype(dtype)
        q = (rng.standard_normal(N) + 1j * rng.standard_normal(N))
    else:
        S = rng.standard_normal((N, M)).astype(dtype)
        q = rng.standard_normal(N)
    q = (q / np.linalg.norm(q)).astype(dtype)
    acc = np.abs(rng.standard_normal(M)).astype(np.float32)
    norms = np.sum(np.abs(S) ** 2, axis=0).astype(np.float32)
    args = tuple(jnp.asarray(a) for a in (q, S, acc, norms))

    c_p, a_p, mx_p, am_p = B.pivot_update(*args, backend="pallas")
    c_x, a_x, mx_x, am_x = B.pivot_update(*args, backend="xla")
    scale = float(jnp.max(jnp.abs(c_x))) + 1e-6
    np.testing.assert_allclose(np.asarray(c_p), np.asarray(c_x),
                               rtol=1e-4, atol=1e-4 * scale)
    np.testing.assert_allclose(np.asarray(a_p), np.asarray(a_x),
                               rtol=1e-4, atol=1e-3 * scale ** 2)
    assert int(am_p) == int(am_x)


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize("shape", [(128, 16), (513, 37)])
def test_project_pass_backend_parity(rng, dtype, shape):
    N, K = shape
    Q = rng.standard_normal((N, K))
    if np.issubdtype(dtype, np.complexfloating):
        Q = Q + 1j * rng.standard_normal((N, K))
    Qo, _ = np.linalg.qr(Q)
    Qo = Qo.astype(dtype)
    v = rng.standard_normal(N)
    if np.issubdtype(dtype, np.complexfloating):
        v = v + 1j * rng.standard_normal(N)
    v = v.astype(dtype)
    vp, cp = B.project_pass(jnp.asarray(v), jnp.asarray(Qo),
                            backend="pallas")
    vx, cx = B.project_pass(jnp.asarray(v), jnp.asarray(Qo), backend="xla")
    np.testing.assert_allclose(np.asarray(vp), np.asarray(vx),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(cp), np.asarray(cx),
                               rtol=1e-4, atol=1e-4)


def test_xla_path_matches_refs(rng):
    """The xla backend IS the reference op (same objects or same values)."""
    N, M, K = 64, 48, 8
    S = jnp.asarray(rng.standard_normal((N, M)), jnp.float32)
    q = jnp.asarray(rng.standard_normal(N), jnp.float32)
    acc = jnp.zeros((M,), jnp.float32)
    norms = jnp.sum(jnp.abs(S) ** 2, axis=0)
    out_b = B.pivot_update(q, S, acc, norms, backend="xla")
    out_r = greedy_update_ref(q, S, acc, norms)
    for b, r in zip(out_b, out_r):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(r))
    Q = jnp.asarray(np.linalg.qr(rng.standard_normal((N, K)))[0], jnp.float32)
    v = jnp.asarray(rng.standard_normal(N), jnp.float32)
    for b, r in zip(B.project_pass(v, Q, backend="xla"),
                    imgs_project_ref(v, Q)):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(r))


# ----------------------------------------------------- driver-level parity
@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
def test_driver_backend_parity(dtype):
    """Pallas-routed and jnp-routed drivers pick identical pivots and bases
    (above the f32 cancellation floor), on padded (non-128-multiple)
    shapes."""
    S = jnp.asarray(make_smooth_matrix(n=150, m=90, dtype=dtype))
    tau = 1e-2 * float(jnp.max(jnp.linalg.norm(S, axis=0)))
    x = rb_greedy(S, tau=tau, backend="xla")
    p = rb_greedy(S, tau=tau, backend="pallas")
    k = int(x.k)
    assert int(p.k) == k
    assert k >= 4
    assert np.array_equal(np.asarray(x.pivots), np.asarray(p.pivots))
    np.testing.assert_allclose(np.asarray(x.Q[:, :k]),
                               np.asarray(p.Q[:, :k]),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(x.errs[:k]),
                               np.asarray(p.errs[:k]),
                               rtol=1e-3, atol=1e-5)


def test_block_sweep_matches_manual(rng):
    N, M, p = 60, 40, 3
    S = jnp.asarray(rng.standard_normal((N, M)), jnp.float32)
    Qn = jnp.asarray(np.linalg.qr(rng.standard_normal((N, p)))[0],
                     jnp.float32)
    acc = jnp.abs(jnp.asarray(rng.standard_normal(M), jnp.float32))
    C, acc_out = B.block_sweep(Qn, S, acc)
    C_ref = Qn.conj().T @ S
    np.testing.assert_allclose(np.asarray(C), np.asarray(C_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(acc_out),
        np.asarray(acc + jnp.sum(jnp.abs(C_ref) ** 2, axis=0)),
        rtol=1e-5, atol=1e-5,
    )


def _block_args(rng, dtype, N, M, p):
    cplx = np.issubdtype(dtype, np.complexfloating)
    S = rng.standard_normal((N, M))
    Qn = rng.standard_normal((N, p))
    if cplx:
        S = S + 1j * rng.standard_normal((N, M))
        Qn = Qn + 1j * rng.standard_normal((N, p))
    rdt = np.float64 if dtype in (np.complex128, np.float64) else np.float32
    acc = np.abs(rng.standard_normal(M)).astype(rdt)
    return (jnp.asarray(Qn.astype(dtype)), jnp.asarray(S.astype(dtype)),
            jnp.asarray(acc))


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize("shape", [(100, 70, 3), (256, 384, 8), (17, 33, 5)])
def test_block_sweep_backend_parity(rng, dtype, shape):
    """pallas (interpret), xla (plane-split for complex) and xla_ref agree
    on the panel C and the acc update, including non-tile-multiple
    (padded) shapes and non-sublane-multiple panel widths."""
    N, M, p = shape
    args = _block_args(rng, dtype, N, M, p)
    C_r, a_r = B.block_sweep(*args, backend="xla_ref")
    scale = float(jnp.max(jnp.abs(C_r))) + 1e-6
    for bk in ("xla", "pallas"):
        C_b, a_b = B.block_sweep(*args, backend=bk)
        np.testing.assert_allclose(np.asarray(C_b), np.asarray(C_r),
                                   rtol=1e-4, atol=1e-4 * scale)
        np.testing.assert_allclose(np.asarray(a_b), np.asarray(a_r),
                                   rtol=1e-3, atol=1e-3 * scale ** 2)


def test_block_sweep_dispatch_routes_to_plane_split(rng, monkeypatch):
    """Complex inputs under the xla backend must take the 4-GEMM
    plane-split branch; real inputs must not."""
    calls = []
    real_split = B._plane_split_block_sweep
    monkeypatch.setattr(
        B, "_plane_split_block_sweep",
        lambda *a, **k: (calls.append("split"), real_split(*a, **k))[1],
    )
    B.block_sweep(*_block_args(rng, np.complex64, 16, 12, 2), backend="xla")
    assert calls == ["split"]
    B.block_sweep(*_block_args(rng, np.float32, 16, 12, 2), backend="xla")
    assert calls == ["split"]  # real input must NOT take the split path


@pytest.mark.parametrize("dtype", [np.complex64, np.complex128])
def test_complex_block_sweep_lowers_to_real_gemms(rng, dtype):
    """Extension of the PR-2 plane-split regression pin to the blocked
    panel sweep: under the xla backend a complex blocked sweep must lower
    to REAL dot ops only (the 4-GEMM plan) — a complex-dtype dot means the
    c64 panel GEMM would hit XLA CPU's scalar complex loop.  Structural,
    not wall-clock: cannot flake on a noisy box."""
    args = _block_args(rng, dtype, 64, 96, 4)

    def lower(bk):
        return jax.jit(
            lambda *a: B.block_sweep(*a, backend=bk)
        ).lower(*args).as_text()

    dots = _dot_lines(lower("xla"))
    assert dots, "expected the blocked sweep to contain dot ops"
    assert not any("complex" in l for l in dots), (
        "xla-backend complex blocked sweep emitted a complex-dtype dot — "
        "the plane-split 4-GEMM path regressed")
    # control: the reference path DOES emit a complex dot, so the
    # detection above is actually discriminating.
    assert any("complex" in l for l in _dot_lines(lower("xla_ref")))


# ------------------------------------------------- panel projection (PR 5)
def _panel_args(rng, dtype, N, K, p):
    cplx = np.issubdtype(dtype, np.complexfloating)
    Q = rng.standard_normal((N, K))
    V = rng.standard_normal((N, p))
    if cplx:
        Q = Q + 1j * rng.standard_normal((N, K))
        V = V + 1j * rng.standard_normal((N, p))
    Qo = np.linalg.qr(Q)[0].astype(dtype)
    return jnp.asarray(V.astype(dtype)), jnp.asarray(Qo)


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize("shape", [(128, 16, 4), (513, 37, 5), (100, 70, 3)])
def test_panel_project_backend_parity(rng, dtype, shape):
    """pallas (interpret), xla (plane-split for complex) and xla_ref agree
    on the panel projection, including non-tile-multiple (padded) shapes
    and non-sublane-multiple panel widths."""
    N, K, p = shape
    V, Q = _panel_args(rng, dtype, N, K, p)
    vr, cr = B.panel_project(V, Q, backend="xla_ref")
    for bk in ("xla", "pallas"):
        vb, cb = B.panel_project(V, Q, backend=bk)
        np.testing.assert_allclose(np.asarray(vb), np.asarray(vr),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(cb), np.asarray(cr),
                                   rtol=1e-4, atol=1e-4)


def test_panel_project_xla_matches_ref_real(rng):
    """For real inputs the xla backend IS the reference op."""
    V, Q = _panel_args(rng, np.float32, 64, 8, 3)
    from repro.kernels.imgs_panel.ref import imgs_panel_ref

    for b, r in zip(B.panel_project(V, Q, backend="xla"),
                    imgs_panel_ref(V, Q)):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(r))


def test_panel_dispatch_routes_to_plane_split(rng, monkeypatch):
    """Complex panels under the xla backend must take the plane-split GEMM
    branch; real panels must not."""
    calls = []
    real_split = B._plane_split_panel_project
    monkeypatch.setattr(
        B, "_plane_split_panel_project",
        lambda *a, **k: (calls.append("split"), real_split(*a, **k))[1],
    )
    B.panel_project(*_panel_args(rng, np.complex64, 16, 4, 2),
                    backend="xla")
    assert calls == ["split"]
    B.panel_project(*_panel_args(rng, np.float32, 16, 4, 2), backend="xla")
    assert calls == ["split"]  # real input must NOT take the split path


@pytest.mark.parametrize("dtype", [np.complex64, np.complex128])
def test_complex_panel_project_lowers_to_real_gemms(rng, dtype):
    """Extension of the plane-split regression pin to the panel
    projection: under the xla backend a complex panel pass must lower to
    REAL dot ops only — a complex-dtype dot means the ortho panel GEMM
    would hit XLA CPU's scalar complex loop.  Structural, not wall-clock:
    cannot flake on a noisy box."""
    args = _panel_args(rng, dtype, 64, 8, 4)

    def lower(bk):
        return jax.jit(
            lambda *a: B.panel_project(*a, backend=bk)
        ).lower(*args).as_text()

    dots = _dot_lines(lower("xla"))
    assert dots, "expected the panel projection to contain dot ops"
    assert not any("complex" in l for l in dots), (
        "xla-backend complex panel projection emitted a complex-dtype dot "
        "— the plane-split GEMM path regressed")
    # control: the reference path DOES emit a complex dot, so the
    # detection above is actually discriminating.
    assert any("complex" in l for l in _dot_lines(lower("xla_ref")))


# --------------------------------------------------- ops-level validation
def test_tile_validation_rejects_non_lane_multiples(rng):
    from repro.kernels.greedy_update.ops import greedy_update
    from repro.kernels.imgs_panel.ops import imgs_panel
    from repro.kernels.imgs_project.ops import imgs_project

    S = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    q = jnp.asarray(rng.standard_normal(64), jnp.float32)
    acc = jnp.zeros((64,), jnp.float32)
    norms = jnp.sum(S * S, axis=0)
    with pytest.raises(ValueError, match="multiple of 128"):
        greedy_update(q, S, acc, norms, nt=300)
    with pytest.raises(ValueError, match="multiple of 128"):
        greedy_update(q, S, acc, norms, mt=100)
    with pytest.raises(ValueError, match="multiple of 128"):
        imgs_project(q, S, kt=65)
    with pytest.raises(ValueError, match="multiple of 128"):
        imgs_panel(S[:, :3], S, kt=65)


def test_default_interpret_cached():
    from repro.kernels.greedy_update.ops import default_interpret

    assert default_interpret() is True  # cpu in tests
    assert default_interpret.cache_info().hits >= 1


# ------------------------------------------------- batched primitives (PR 9)


@pytest.mark.parametrize("layout", ["shared", "stacked"])
def test_complex_batched_primitives_lower_to_real_dots(rng, layout):
    """The PR-1 no-complex-dot HLO pin, extended to the B-lane primitives:
    under the xla backend every batched complex sweep/projection/fold must
    lower to REAL dot ops only — the fused stacked-plane GEMMs (shared
    layout) and the barrier-fenced per-lane plane-split ops (stacked
    layout) both ride the 4-real-GEMM plan.  A complex-dtype dot means a
    batched route silently fell back to naive complex arithmetic."""
    Bn, N, M, K, p = 3, 48, 64, 6, 4
    dtype = np.complex64

    def c(shape):
        return jnp.asarray((rng.standard_normal(shape)
                            + 1j * rng.standard_normal(shape)).astype(dtype))

    S = c((N, M)) if layout == "shared" else c((Bn, N, M))
    q = c((Bn, N))
    acc = jnp.zeros((Bn, M), np.float32)
    norms = jnp.broadcast_to(
        jnp.sum(jnp.abs(S) ** 2, axis=-2).astype(np.float32), (Bn, M))

    def lower(fn, *args):
        def f(bk):
            return jax.jit(
                lambda *a: fn(*a, backend=bk)).lower(*args).as_text()
        return f

    cases = [
        ("pivot", lower(B.batched_pivot_update, q, S, acc, norms)),
        ("block_sweep",
         lower(B.batched_block_sweep, c((Bn, N, p)), S, acc)),
        ("sketch_fold",
         lower(B.batched_sketch_fold, S, c((M, K)) if layout == "shared"
               else c((Bn, M, K)), c((Bn, N, K)))),
    ]
    if layout == "stacked":  # Q is always per-lane: no shared variant
        cases += [
            ("project", lower(B.batched_project_pass, q, c((Bn, N, K)))),
            ("panel",
             lower(B.batched_panel_project, c((Bn, N, p)), c((Bn, N, K)))),
        ]
    for name, low in cases:
        dots = _dot_lines(low("xla"))
        assert dots, f"{layout}/{name}: expected dot ops in the lowering"
        assert not any("complex" in l for l in dots), (
            f"{layout}/{name}: xla-backend batched complex primitive "
            f"emitted a complex-dtype dot")
        # control: the literal reference route DOES emit complex dots,
        # so the detection is discriminating.
        assert any("complex" in l for l in _dot_lines(low("xla_ref"))), (
            f"{layout}/{name}: control failed — xla_ref emitted no "
            f"complex dot")
