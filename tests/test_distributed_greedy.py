"""Distributed (shard_map) greedy == serial greedy, on 8 host devices.

Runs in a subprocess because the device count must be forced before jax
initializes (the main test process keeps 1 device).
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp, numpy as np, json
from repro.core import rb_greedy
from repro.compat import make_auto_mesh
from repro.core.distributed import distributed_greedy, dist_greedy_init, state_shardings
from repro.core.errors import proj_error_max, orthogonality_defect
from repro.gw import build_snapshot_matrix, chirp_grid, frequency_grid

f = frequency_grid(20., 512., 600)
m1, m2 = chirp_grid(n_mc=32, n_eta=8)
S = build_snapshot_matrix(f, m1, m2, dtype=jnp.complex128)

g_ser = rb_greedy(S, tau=1e-5)
k = int(g_ser.k)

out = {"n_devices": len(jax.devices())}
for shape, axes in [((8,), ("cols",)), ((2, 4), ("data", "model"))]:
    mesh = make_auto_mesh(shape, axes)
    g = distributed_greedy(S, tau=1e-5, max_k=min(*S.shape), mesh=mesh)
    kd = int(g.k)
    out[str(shape)] = {
        "k_serial": k, "k_dist": kd,
        "pivots_equal": bool(np.array_equal(np.array(g_ser.pivots[:k]),
                                            np.array(g.pivots[:kd]))),
        "max_err_diff": float(np.max(np.abs(
            np.array(g_ser.errs[:k]) - np.array(g.errs[:kd])))),
        "defect": float(orthogonality_defect(
            jnp.asarray(np.array(g.Q[:, :kd])))),
        "proj_err": float(proj_error_max(S, jnp.asarray(np.array(g.Q[:, :kd])))),
    }

# blocked (BLAS-3 panel) distributed sweep: 8-device mesh vs the resident
# chunked blocked driver — same pivots, one shard read per p bases
from repro.core.block_greedy import _rb_greedy_block_impl
mesh8b = make_auto_mesh((8,), ("cols",))
g_blk_ref = _rb_greedy_block_impl(S, tau=1e-5, p=4)
g_blk = distributed_greedy(S, tau=1e-5, max_k=min(*S.shape), mesh=mesh8b,
                           block_p=4)
kb = int(g_blk_ref.k)
out["blocked"] = {
    "k_resident": kb, "k_dist": int(g_blk.k),
    "pivots_equal": bool(np.array_equal(np.array(g_blk_ref.pivots[:kb]),
                                        np.array(g_blk.pivots[:int(g_blk.k)]))),
    "defect": float(orthogonality_defect(
        jnp.asarray(np.array(g_blk.Q[:, :int(g_blk.k)])))),
    "proj_err": float(proj_error_max(
        S, jnp.asarray(np.array(g_blk.Q[:, :int(g_blk.k)])))),
}

# elastic restart: checkpoint on 8 devices, restore/finish on 4
import tempfile
import repro.core.distributed as D
from repro.checkpoint import save_checkpoint, restore_checkpoint
from jax.sharding import NamedSharding, PartitionSpec as P

mesh8 = make_auto_mesh((8,), ("cols",))
S8 = jax.device_put(S, NamedSharding(mesh8, P(None, ("cols",))))
state = D.dist_greedy_init(S8, 30, mesh8)
step8 = D.make_dist_greedy_step(mesh8)
for _ in range(10):
    state = step8(S8, state)

with tempfile.TemporaryDirectory() as d:
    save_checkpoint(state, d, 10)
    mesh4 = make_auto_mesh((4,), ("cols",), devices=jax.devices()[:4])
    specs4 = D.state_specs(mesh4)
    # placement targets with the NEW mesh's shardings (reshard-on-restore)
    tgt = jax.tree.map(
        lambda arr, spec: jax.device_put(
            np.zeros(arr.shape, arr.dtype), NamedSharding(mesh4, spec)),
        jax.tree.map(np.asarray, state), specs4,
        is_leaf=lambda z: isinstance(z, np.ndarray))
    st4 = D.DistGreedyState(*restore_checkpoint(tgt, d, 10))
    step4 = D.make_dist_greedy_step(mesh4)
    S4 = jax.device_put(S, NamedSharding(mesh4, P(None, ("cols",))))
    for _ in range(5):
        st4 = step4(S4, st4)
    st8 = state
    for _ in range(5):
        st8 = step8(S8, st8)
    out["elastic"] = {
        "pivots_equal": bool(np.array_equal(np.array(st8.pivots[:15]),
                                            np.array(st4.pivots[:15]))),
    }
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_result():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_devices_forced(dist_result):
    assert dist_result["n_devices"] == 8


@pytest.mark.parametrize("mesh", ["(8,)", "(2, 4)"])
def test_matches_serial(dist_result, mesh):
    r = dist_result[mesh]
    assert r["k_dist"] == r["k_serial"]
    assert r["pivots_equal"]
    assert r["max_err_diff"] < 1e-10
    assert r["defect"] < 1e-12
    assert r["proj_err"] < 1e-4


def test_elastic_restart(dist_result):
    assert dist_result["elastic"]["pivots_equal"]


def test_blocked_matches_resident_blocked(dist_result):
    """block_p=4 on the 8-device mesh: the all-gathered top-p selection +
    sharded panel sweep reproduces the resident chunked blocked driver
    pivot for pivot (deep-precision c128 family — selection is
    deterministic)."""
    r = dist_result["blocked"]
    assert r["k_dist"] == r["k_resident"]
    assert r["pivots_equal"]
    assert r["defect"] < 1e-12
    assert r["proj_err"] < 1e-4
