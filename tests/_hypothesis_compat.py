"""Optional-``hypothesis`` shim for property tests.

When ``hypothesis`` is installed (the ``[test]`` extra), this re-exports the
real ``given``/``settings``/``st``.  On a bare ``jax`` install the property
tests still run: ``given`` degrades to a deterministic sweep drawing
``REPRO_FALLBACK_EXAMPLES`` (default 5) samples per test from a seeded
generator — no shrinking or database, but the same code paths execute, so
the suite collects and passes without the dependency.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback on bare installs
    import os

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(values):
            vals = list(values)
            return _Strategy(
                lambda rng: vals[int(rng.integers(0, len(vals)))]
            )

    st = _Strategies()

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    def given(**strategies):
        n_examples = int(os.environ.get("REPRO_FALLBACK_EXAMPLES", "5"))

        def deco(fn):
            # NOTE: no functools.wraps — it would set __wrapped__ and make
            # pytest introspect fn's original signature, then try to resolve
            # the strategy parameters as fixtures.
            def wrapper():
                rng = np.random.default_rng(0)
                for _ in range(n_examples):
                    drawn = {
                        name: s.draw(rng)
                        for name, s in strategies.items()
                    }
                    fn(**drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
