"""Validates the §Roofline methodology itself.

The central claim: with layers unrolled, per-device compiled cost is EXACTLY
affine in the layer count, so a 2-point fit extrapolates correctly.  We
verify by predicting L=3 from the L={1,2} fit on an 8-device mesh and
checking the actual L=3 lowering (sub-1% tolerance), and we re-verify the
scan undercount that motivates the methodology.
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os, json
import jax
from repro.configs import get_reduced
from repro.launch import specs as S
from repro.compat import make_auto_mesh
from repro.launch import roofline as R
from repro.models.config import ShapeConfig
from repro.models.transformer import unroll_layers
from repro.sharding import use_mesh
from repro.training.trainer import make_train_step

mesh = make_auto_mesh((2, 4), ("data", "model"))
shape = ShapeConfig("t", 128, 8, "train")

def cost(L, unroll):
    cfg = get_reduced("stablelm-3b").replace(
        n_layers=L, attn_impl="einsum", remat=False, dtype="float32")
    step = make_train_step(cfg, n_microbatches=1, donate=False)
    ctx = unroll_layers() if unroll else None
    import contextlib
    with use_mesh(mesh), (ctx or contextlib.nullcontext()):
        compiled = step.lower(S.abstract_train_state(cfg, mesh),
                              S.batch_specs(cfg, shape, mesh)).compile()
    return R.cost_terms(compiled)

c1, c2, c3 = cost(1, True), cost(2, True), cost(3, True)
fit3 = R.fit_linear(c1, c2, 1, 2, 3)
scan2 = cost(2, False)
out = {
    "flops_pred": fit3["flops"], "flops_act": c3["flops"],
    "bytes_pred": fit3["bytes"], "bytes_act": c3["bytes"],
    "coll_pred": fit3["collective_bytes"],
    "coll_act": c3["collective_bytes"],
    "scan_flops_L2": scan2["flops"], "unroll_flops_L2": c2["flops"],
    "unroll_flops_L1": c1["flops"],
}
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def fit():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    p = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert p.returncode == 0, p.stderr[-2500:]
    line = [l for l in p.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_linear_fit_predicts_L3(fit):
    assert fit["flops_pred"] == pytest.approx(fit["flops_act"], rel=0.01)
    assert fit["bytes_pred"] == pytest.approx(fit["bytes_act"], rel=0.02)
    assert fit["coll_pred"] == pytest.approx(fit["coll_act"], rel=0.05)


def test_scan_undercounts_layers(fit):
    """The motivation: scan-lowered L=2 reports ~the L=1 unrolled body."""
    # scan counts the body once -> its flops are far below unrolled L=2
    assert fit["scan_flops_L2"] < 0.75 * fit["unroll_flops_L2"]
