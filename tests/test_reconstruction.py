"""Algorithm 4 (reconstruction) — Theorems 5.8/5.11, Remarks 5.12/5.13."""

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_smooth_matrix
from repro.core import reconstruction, rb_greedy
from repro.core.errors import proj_error_2norm


@pytest.mark.parametrize("dtype", [np.float64, np.complex128])
def test_reconstruction_matches_pod_when_r22_small(dtype):
    """Rem 5.13: with |R22| ~ eps the reconstructed basis behaves like POD."""
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    sig = np.linalg.svd(np.asarray(S), compute_uv=False)
    res = reconstruction(S, tau1=1e-13, tau2=1e-10)
    k = int(res.k)
    err = float(proj_error_2norm(S, res.X[:, :k]))
    # POD error at rank k is sig[k]; reconstruction should be within a
    # small factor (and far better than the plain greedy at the same k).
    assert err <= 20 * max(float(sig[k]), 1e-14)


def test_reconstruction_beats_plain_greedy_at_same_rank():
    """The SVD rotation enriches the basis (Rem 5.9: R-diag decays slower
    than the singular values)."""
    S = jnp.asarray(make_smooth_matrix())
    res = reconstruction(S, tau1=1e-12, tau2=1e-9)
    g = rb_greedy(S, tau=1e-12)
    for k in (4, 6, 8):
        rec_err = float(proj_error_2norm(S, res.X[:, :k]))
        greedy_err = float(proj_error_2norm(S, g.Q[:, :k]))
        assert rec_err <= greedy_err * 1.5 + 1e-14


def test_theorem_5_11_bound():
    """|S - X_j X_j^H S|_2 <= sigma(S1)_{j+1} + |R22|_2."""
    S = jnp.asarray(make_smooth_matrix())
    res = reconstruction(S, tau1=1e-10, tau2=1e-8)
    j_qr = res.j
    # Build S1 from the greedy QR factors: S1 = Q_j R(1:j,:)
    S1 = res.Qj @ rb_greedy(S, tau=1e-10).R[:j_qr, :]
    sig1 = np.linalg.svd(np.asarray(S1), compute_uv=False)
    r22 = float(jnp.linalg.norm(S - S1, ord=2))
    for jj in (3, 5):
        lhs = float(proj_error_2norm(S, res.X[:, :jj]))
        rhs = float(sig1[jj]) + r22
        assert lhs <= rhs * (1 + 1e-8) + 1e-12
