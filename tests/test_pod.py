"""Theorem 3.2 (POD error identities) + Algorithm 1 semantics."""

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_smooth_matrix
from repro.core import pod, pod_basis
from repro.core.pod import pod_error_2norm, pod_error_fro


@pytest.mark.parametrize("dtype", [np.float64, np.complex128])
def test_pod_2norm_identity(dtype):
    """Thm 3.2(ii): |S - V_k V_k^H S|_2 == sigma_{k+1} exactly."""
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    _, sig, _ = np.linalg.svd(np.asarray(S))
    for k in (1, 5, 10):
        err = float(pod_error_2norm(S, k))
        assert err == pytest.approx(float(sig[k]), rel=1e-8, abs=1e-12)


@pytest.mark.parametrize("dtype", [np.float64, np.complex128])
def test_pod_fro_identity(dtype):
    """Thm 3.2(i): |S - V_k V_k^H S|_F^2 == sum_{j>k} sigma_j^2."""
    S = jnp.asarray(make_smooth_matrix(dtype=dtype))
    _, sig, _ = np.linalg.svd(np.asarray(S))
    for k in (1, 5, 10):
        err = float(pod_error_fro(S, k)) ** 2
        assert err == pytest.approx(float(np.sum(sig[k:] ** 2)),
                                    rel=1e-8, abs=1e-12)


def test_pod_tolerance_selection():
    """Algorithm 1 picks the smallest k with sigma_{k+1} < tau."""
    S = jnp.asarray(make_smooth_matrix())
    res = pod(S, tau=1e-6)
    k = int(res.k)
    sig = np.asarray(res.sigmas)
    assert sig[k] < 1e-6
    assert k == 0 or sig[k - 1] >= 1e-6


def test_pod_optimality_vs_random_basis(rng):
    """POD beats an arbitrary orthonormal basis in both norms (Eq. 3.1)."""
    S = jnp.asarray(make_smooth_matrix())
    k = 8
    Vk = pod_basis(S, k)
    Q, _ = np.linalg.qr(rng.standard_normal((S.shape[0], k)))
    pod_err = float(jnp.linalg.norm(S - Vk @ (Vk.conj().T @ S)))
    rand_err = float(jnp.linalg.norm(S - Q @ (Q.T @ np.asarray(S))))
    assert pod_err <= rand_err
