"""Serving subsystem tests: ROQEngine batching/padding, router LRU,
timeout/error isolation, backpressure, EIM artifact persistence, and the
end-to-end multi-basis smoke over greedy- and randomized-built artifacts.

The load-bearing contract: every response the engine produces — through
padded batch buckets, warm cache entries, and routed bases — is
BIT-IDENTICAL to :func:`repro.serving.direct_interpolate` of the same
request (plane-split complex, GEMM width >= 2; see serving/roq.py).
"""

import os
import threading
import time

import numpy as np
import pytest

from repro.api import ReducedBasis, build_basis
from repro.serving import (
    BasisRouter,
    EngineClosedError,
    InterpolantCache,
    QueueFullError,
    ROQEngine,
    batch_bucket,
    direct_interpolate,
)
from tests.conftest import make_smooth_matrix

WAIT_S = 10.0  # generous future timeout: worker flushes in milliseconds


def _requests(basis, n, seed=0):
    """n random request vectors (k,) in the basis dtype."""
    rng = np.random.default_rng(seed)
    dtype = np.asarray(basis.Q).dtype
    f = rng.standard_normal((basis.k, n))
    if np.issubdtype(dtype, np.complexfloating):
        f = f + 1j * rng.standard_normal((basis.k, n))
    return f.astype(dtype)


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    """One f32 greedy + one c64 randomized artifact, saved to disk."""
    root = tmp_path_factory.mktemp("serving_bases")
    f32 = build_basis(source=make_smooth_matrix(120, 60, np.float32),
                      strategy="greedy", tau=1e-5, max_k=8)
    c64 = build_basis(source=make_smooth_matrix(80, 50, np.complex64),
                      strategy="randomized", tau=1e-5, max_k=6)
    dirs = {"f32_greedy": str(root / "f32_greedy"),
            "c64_rand": str(root / "c64_rand")}
    f32.save(dirs["f32_greedy"])
    c64.save(dirs["c64_rand"])
    return dirs


# ----------------------------------------------------------- buckets ----

def test_batch_bucket_powers_of_two_with_floor_two():
    assert [batch_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9, 16, 17)] == \
        [2, 2, 4, 4, 8, 8, 16, 16, 32]
    with pytest.raises(ValueError):
        batch_bucket(0)


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
def test_padded_bucket_eval_bitwise_vs_unpadded(dtype):
    """Ragged batch widths through the cache == unpadded direct eval,
    bit for bit — and each column == the per-request direct eval."""
    basis = build_basis(source=make_smooth_matrix(64, 40, dtype),
                        strategy="greedy", tau=1e-5, max_k=7)
    eim = basis.eim()
    cache = InterpolantCache()
    for width in (1, 2, 3, 5, 7):
        F = _requests(basis, width, seed=width)
        out, bucket, _ = cache.evaluate(f"b_{dtype.__name__}", eim, F)
        assert bucket == batch_bucket(width)
        assert out.shape == (basis.N, width)
        # whole-batch direct (unpadded) reference
        assert np.array_equal(out, direct_interpolate(eim, F))
        # per-request direct reference
        for j in range(width):
            assert np.array_equal(out[:, j],
                                  direct_interpolate(eim, F[:, j]))


def test_cache_warm_after_first_bucket_and_evict():
    basis = build_basis(source=make_smooth_matrix(48, 30, np.float32),
                        strategy="greedy", tau=1e-5, max_k=5)
    cache = InterpolantCache()
    F = _requests(basis, 3)
    _, bucket, warm0 = cache.evaluate("x", basis.eim(), F)
    _, _, warm1 = cache.evaluate("x", basis.eim(), F)
    assert (warm0, warm1) == (False, True)
    assert cache.warm_keys("x") == [("x", 0, bucket, str(F.dtype))]
    cache.evict("x")
    assert cache.warm_keys("x") == []
    _, _, warm2 = cache.evaluate("x", basis.eim(), F)
    assert warm2 is False


# ------------------------------------------------------------ router ----

def test_router_lru_eviction_reload_roundtrip(artifacts):
    evicted = []
    # budget of 1 byte: exactly the requested basis stays resident
    router = BasisRouter(memory_budget_bytes=1, on_evict=evicted.append)
    for bid, d in artifacts.items():
        router.register(bid, d)
    b1, e1 = router.get("f32_greedy")
    q1 = np.asarray(b1.Q).copy()
    assert router.loaded_ids() == ["f32_greedy"]
    router.get("c64_rand")
    assert router.loaded_ids() == ["c64_rand"]
    assert evicted == ["f32_greedy"]
    b1b, e1b = router.get("f32_greedy")  # reload round-trip
    assert evicted == ["f32_greedy", "c64_rand"]
    assert np.array_equal(np.asarray(b1b.Q), q1)
    assert np.array_equal(np.asarray(e1b.nodes), np.asarray(e1.nodes))
    assert np.array_equal(np.asarray(e1b.B), np.asarray(e1.B))


def test_router_pinned_in_memory_basis_never_evicted(artifacts):
    pinned = build_basis(source=make_smooth_matrix(48, 30, np.float32),
                         strategy="greedy", tau=1e-5, max_k=5)
    assert pinned.directory is None
    router = BasisRouter(memory_budget_bytes=1)
    router.register("pinned", pinned)
    router.register("disk", artifacts["f32_greedy"])
    router.get("pinned")
    router.get("disk")
    # over budget, but the pinned basis has nowhere to reload from and
    # the disk one is the just-requested keep -> both stay resident
    assert sorted(router.loaded_ids()) == ["disk", "pinned"]


def test_router_unknown_and_duplicate_ids(artifacts):
    router = BasisRouter(memory_budget_bytes=1 << 30)
    router.register("a", artifacts["f32_greedy"])
    with pytest.raises(ValueError, match="already registered"):
        router.register("a", artifacts["c64_rand"])
    with pytest.raises(KeyError, match="unknown basis_id"):
        router.get("nope")
    with pytest.raises(TypeError):
        router.register("b", 123)


def test_router_default_budget_honors_env(monkeypatch):
    monkeypatch.setenv("REPRO_DEVICE_MEM_BUDGET", str(12345))
    assert BasisRouter().memory_budget_bytes == 12345


# ------------------------------------------------------------ engine ----

def test_engine_serves_bitwise_and_routes(artifacts):
    with ROQEngine(artifacts, max_batch=4, max_wait_ms=1.0) as eng:
        futs = []
        for bid in artifacts:
            basis, _ = eng.router.get(bid)
            F = _requests(basis, 9, seed=3)
            futs += [(bid, F[:, j], eng.submit(bid, F[:, j]))
                     for j in range(9)]
        for bid, f, fut in futs:
            out = fut.result(timeout=WAIT_S)
            _, eim = eng.router.get(bid)
            assert np.array_equal(out, direct_interpolate(eim, f))
    snap = eng.stats()
    assert snap["counters"]["completed"] == 18
    assert snap["counters"]["errors"] == 0
    assert snap["latency_ms"]["n"] == 18
    assert snap["latency_ms"]["p50"] <= snap["latency_ms"]["p99"]


def test_engine_warm_prewarms_all_buckets(artifacts):
    with ROQEngine({"a": artifacts["f32_greedy"]}, max_batch=8,
                   max_wait_ms=0.5) as eng:
        eng.warm("a")
        assert {k[2] for k in eng.cache.warm_keys("a")} == {2, 4, 8}
        basis, _ = eng.router.get("a")
        F = _requests(basis, 20)
        futs = [eng.submit("a", F[:, j]) for j in range(20)]
        for fut in futs:
            fut.result(timeout=WAIT_S)
    snap = eng.stats()
    assert snap["counters"]["cache_misses"] == 0
    assert snap["counters"]["cache_hits"] >= 3
    assert snap["cache_hit_rate"] == 1.0


def test_malformed_request_fails_alone_batchmates_serve(artifacts):
    eng = ROQEngine({"a": artifacts["f32_greedy"]}, max_batch=8,
                    max_wait_ms=0.5, start=False)
    basis, eim = eng.router.get("a")
    F = _requests(basis, 3)
    good = [eng.submit("a", F[:, j]) for j in range(3)]
    bad_len = eng.submit("a", np.zeros(basis.k + 1, np.float32))
    bad_dtype = eng.submit("a", np.zeros(basis.k, np.complex64))
    bad_id = eng.submit("missing", F[:, 0])
    eng.start()
    eng.close(drain=True)
    for j, fut in enumerate(good):
        assert np.array_equal(fut.result(timeout=WAIT_S),
                              direct_interpolate(eim, F[:, j]))
    with pytest.raises(ValueError, match="one value per EIM node"):
        bad_len.result(timeout=WAIT_S)
    with pytest.raises(ValueError, match="does not cast"):
        bad_dtype.result(timeout=WAIT_S)
    with pytest.raises(KeyError, match="unknown basis_id"):
        bad_id.result(timeout=WAIT_S)
    snap = eng.stats()
    assert snap["counters"]["completed"] == 3
    assert snap["counters"]["errors"] == 3


def test_submit_rejects_2d_batch_synchronously(artifacts):
    with ROQEngine({"a": artifacts["f32_greedy"]}) as eng:
        with pytest.raises(ValueError, match="ONE vector"):
            eng.submit("a", np.zeros((4, 4), np.float32))


def test_timeout_expires_alone_batchmates_serve(artifacts):
    eng = ROQEngine({"a": artifacts["f32_greedy"]}, max_batch=8,
                    max_wait_ms=0.5, start=False)
    basis, eim = eng.router.get("a")
    F = _requests(basis, 2)
    doomed = eng.submit("a", F[:, 0], timeout_s=0.0)
    ok = eng.submit("a", F[:, 1])
    time.sleep(0.01)  # let the deadline pass before the worker ever runs
    eng.start()
    eng.close(drain=True)
    with pytest.raises(TimeoutError):
        doomed.result(timeout=WAIT_S)
    assert np.array_equal(ok.result(timeout=WAIT_S),
                          direct_interpolate(eim, F[:, 1]))
    snap = eng.stats()
    assert snap["counters"]["timeouts"] == 1
    assert snap["counters"]["completed"] == 1


def test_queue_full_backpressure_explicit_reject(artifacts):
    eng = ROQEngine({"a": artifacts["f32_greedy"]}, queue_depth=2,
                    start=False)
    basis, _ = eng.router.get("a")
    F = _requests(basis, 3)
    f0 = eng.submit("a", F[:, 0])
    f1 = eng.submit("a", F[:, 1])
    with pytest.raises(QueueFullError, match="backpressure"):
        eng.submit("a", F[:, 2])
    assert eng.stats()["counters"]["rejected"] == 1
    eng.start()
    eng.close(drain=True)
    f0.result(timeout=WAIT_S)
    f1.result(timeout=WAIT_S)
    assert eng.stats()["counters"]["completed"] == 2


def test_injected_batch_fault_isolated_engine_survives(
        artifacts, monkeypatch):
    monkeypatch.setenv("REPRO_FAULT_SERVE_RAISE_AT_BATCH", "1")
    monkeypatch.delenv("REPRO_FAULT_ONCE", raising=False)
    eng = ROQEngine({"a": artifacts["f32_greedy"]}, max_batch=8,
                    max_wait_ms=0.5, start=False)
    basis, eim = eng.router.get("a")
    F = _requests(basis, 2)
    doomed = [eng.submit("a", F[:, j]) for j in range(2)]
    eng.start()
    for fut in doomed:  # batch 1: the injected fault fails ALL its requests
        with pytest.raises(RuntimeError, match="injected serving fault"):
            fut.result(timeout=WAIT_S)
    # ... but only that batch: the engine keeps serving (batch 2)
    ok = eng.submit("a", F[:, 0])
    assert np.array_equal(ok.result(timeout=WAIT_S),
                          direct_interpolate(eim, F[:, 0]))
    eng.close(drain=True)
    snap = eng.stats()
    assert snap["counters"]["errors"] == 2
    assert snap["counters"]["completed"] == 1


def test_close_drains_then_rejects_new_requests(artifacts):
    eng = ROQEngine({"a": artifacts["f32_greedy"]}, max_batch=64,
                    max_wait_ms=1e4, start=False)  # no flush until drain
    basis, eim = eng.router.get("a")
    F = _requests(basis, 5)
    futs = [eng.submit("a", F[:, j]) for j in range(5)]
    eng.start()
    eng.close(drain=True)  # max_wait of 10s never elapsed: drain flushes
    for j, fut in enumerate(futs):
        assert np.array_equal(fut.result(timeout=WAIT_S),
                              direct_interpolate(eim, F[:, j]))
    with pytest.raises(EngineClosedError):
        eng.submit("a", F[:, 0])


def test_close_abort_fails_pending(artifacts):
    eng = ROQEngine({"a": artifacts["f32_greedy"]}, max_batch=64,
                    max_wait_ms=1e4, start=False)
    basis, _ = eng.router.get("a")
    fut = eng.submit("a", _requests(basis, 1)[:, 0])
    eng.start()
    eng.close(drain=False)
    with pytest.raises(EngineClosedError):
        fut.result(timeout=WAIT_S)


def test_router_eviction_drops_warm_cache_entries(artifacts):
    # 1-byte budget: routing to basis b evicts a AND its cache entries
    router = BasisRouter(memory_budget_bytes=1)
    for bid, d in artifacts.items():
        router.register(bid, d)
    with ROQEngine(router, max_batch=4, max_wait_ms=0.5) as eng:
        basis_a, _ = eng.router.get("f32_greedy")
        eng.submit("f32_greedy",
                   _requests(basis_a, 1)[:, 0]).result(timeout=WAIT_S)
        assert eng.cache.warm_keys("f32_greedy")
        basis_b, _ = eng.router.get("c64_rand")   # evicts f32_greedy
        assert eng.cache.warm_keys("f32_greedy") == []
        # re-route: reloads and re-warms transparently, still bitwise
        f = _requests(basis_a, 1, seed=9)[:, 0]
        out = eng.submit("f32_greedy", f).result(timeout=WAIT_S)
        _, eim = eng.router.get("f32_greedy")
        assert np.array_equal(out, direct_interpolate(eim, f))
    assert eng.stats()["counters"]["basis_evictions"] >= 2


def test_concurrent_submitters_all_bitwise(artifacts):
    """Many threads hammering both bases: every response still exact."""
    with ROQEngine(artifacts, max_batch=8, max_wait_ms=1.0,
                   queue_depth=4096) as eng:
        results = []
        lock = threading.Lock()

        def client(bid, seed):
            basis, eim = eng.router.get(bid)
            F = _requests(basis, 16, seed=seed)
            futs = [(F[:, j], eng.submit(bid, F[:, j])) for j in range(16)]
            good = all(
                np.array_equal(fut.result(timeout=WAIT_S),
                               direct_interpolate(eim, f))
                for f, fut in futs)
            with lock:
                results.append(good)

        threads = [threading.Thread(target=client, args=(bid, s))
                   for s, bid in enumerate(list(artifacts) * 3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert results and all(results)
    assert eng.stats()["counters"]["completed"] == 16 * len(threads)


# ----------------------------------------------- EIM artifact leaves ----

def test_eim_persisted_on_save_preseeded_on_load(artifacts):
    loaded = ReducedBasis.load(artifacts["f32_greedy"])
    # the leaves pre-seed the cache: no recompute on first eim() call
    assert "_eim" in vars(loaded)
    from repro.core.eim import eim_nodes

    fresh = eim_nodes(loaded.Q)
    assert np.array_equal(np.asarray(loaded.eim().nodes),
                          np.asarray(fresh.nodes))
    assert np.array_equal(np.asarray(loaded.eim().B), np.asarray(fresh.B))


def test_legacy_artifact_without_eim_leaves_recomputes(tmp_path):
    """Artifacts saved before the EIM leaves existed still load and
    serve; eim() falls back to recomputing."""
    import json

    from repro.checkpoint.io import save_checkpoint

    basis = build_basis(source=make_smooth_matrix(48, 30, np.float32),
                        strategy="greedy", tau=1e-5, max_k=5)
    tree = {
        "artifact_version": np.asarray(1, np.int64),
        "Q": np.asarray(basis.Q),
        "pivots": np.asarray(basis.pivots),
        "errs": np.asarray(basis.errs),
        "k": np.asarray(basis.k, np.int64),
        "provenance_json": np.asarray(json.dumps(basis.provenance,
                                                 default=str)),
    }
    save_checkpoint(tree, str(tmp_path), 0, meta={"final": True})
    loaded = ReducedBasis.load(str(tmp_path))
    assert "_eim" not in vars(loaded)
    ei = loaded.eim()  # recompute fallback
    assert np.array_equal(np.asarray(ei.nodes),
                          np.asarray(basis.eim().nodes))


def test_eim_leaves_gated_on_version(tmp_path, monkeypatch):
    """A future eim_version is ignored (recompute), not misread."""
    import repro.api.artifact as artifact_mod

    basis = build_basis(source=make_smooth_matrix(48, 30, np.float32),
                        strategy="greedy", tau=1e-5, max_k=5)
    monkeypatch.setattr(artifact_mod, "_EIM_VERSION", 999)
    basis.save(str(tmp_path))
    monkeypatch.undo()
    loaded = ReducedBasis.load(str(tmp_path))
    assert "_eim" not in vars(loaded)
    assert loaded.eim().B.shape == (basis.N, basis.k)


# ------------------------------------------------------ launcher e2e ----

def test_serve_launcher_end_to_end(artifacts):
    from repro.launch.serve import main

    stats = main(["--basis", artifacts["f32_greedy"],
                  "--basis", artifacts["c64_rand"],
                  "--max-batch", "8", "--max-wait-ms", "1",
                  "--requests", "64"])
    assert stats["served"] == 64
    assert stats["counters"]["completed"] == 64
    assert stats["max_err"] < 1e-4
    assert stats["latency_ms"]["n"] == 64
    for q in ("p50", "p95", "p99"):
        assert stats["latency_ms"][q] > 0.0
