"""Batched many-basis greedy (PR 9): B lockstep builds in one fused pass.

The headline contract: in the STACKED layout every lane of
``batch_rb_greedy`` is BITWISE identical — Q, R, pivots, errs, rnorms,
ortho pass counts, rank, stop code — to a scalar :func:`rb_greedy` run on
that lane's matrix, across {f32, c64} x {xla, xla_ref}, including lanes
that converge at different ranks and keep riding frozen through the
lockstep loop.  The SHARED layout (one S, B tau/basis states) trades
bitwise for pivot-for-pivot parity: its fused sweep reads S once for all
lanes through stacked-plane GEMMs whose float summation order is GEMM-
not GEMV-shaped (the same documented drift as the blocked driver).

Also here: the band-split workload helper, the front-door ``"batched"``
strategy (spec validation, auto delegation, ReducedBasisSet artifact
save/load/register, workdir finalize+resume).
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_smooth_matrix
from repro.core.batch_greedy import batch_rb_greedy
from repro.core.greedy import STOP_RANK, STOP_TAU, rb_greedy

BACKENDS = ("xla", "xla_ref")
DTYPES = (np.float32, np.complex64)

_BITWISE_FIELDS = ("Q", "R", "pivots", "errs", "rnorms", "n_ortho_passes")


def _noisy(dtype, N=96, M=160, rank=12, seed=0, noise=0.01):
    r = np.random.default_rng(seed)
    X = r.standard_normal((N, rank)) @ r.standard_normal((rank, M))
    X = X + noise * r.standard_normal((N, M))
    if np.issubdtype(dtype, np.complexfloating):
        X = X + 1j * (r.standard_normal((N, rank))
                      @ r.standard_normal((rank, M)))
    return jnp.asarray(X.astype(dtype))


def _assert_lane_bitwise(lane, ref, ctx):
    assert int(lane.k) == int(ref.k), (ctx, int(lane.k), int(ref.k))
    assert lane.stop == ref.stop, (ctx, lane.stop, ref.stop)
    for name in _BITWISE_FIELDS:
        a, b = np.asarray(getattr(lane, name)), np.asarray(getattr(ref, name))
        assert np.array_equal(a, b), (
            ctx, name,
            float(np.max(np.abs(a - b))) if a.dtype.kind in "fc" else "int")


# ------------------------------------------------ stacked bitwise parity ----


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_stacked_lanes_bitwise_vs_scalar_driver(dtype, backend):
    """Acceptance: per-basis results of the lockstep driver are BITWISE
    the scalar driver's, lane by lane, on distinct same-shape matrices."""
    Ss = [_noisy(dtype, seed=s) for s in (1, 2, 3)]
    taus = [1e-4, 1e-3, 1e-5]
    res = batch_rb_greedy(jnp.stack(Ss), taus, max_k=40, backend=backend,
                          chunk=7)
    assert res.batch == 3
    for b, (S, tau) in enumerate(zip(Ss, taus)):
        ref = rb_greedy(S, tau, max_k=40, backend=backend, chunk=7)
        _assert_lane_bitwise(res.lane(b), ref,
                             (np.dtype(dtype).name, backend, b))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_masked_convergence_lanes_stop_at_different_ranks(dtype, backend):
    """Lanes hitting their stop at different k freeze in place (masked out
    of the sweep) while the rest keep building — and every lane's frozen
    tail still matches its scalar run bitwise.  Exact low-rank lanes force
    well-separated STOP_RANK points."""
    ranks = (5, 12, 8)
    Ss = [_noisy(dtype, rank=r, seed=10 + r, noise=0.0) for r in ranks]
    res = batch_rb_greedy(jnp.stack(Ss), 1e-8, max_k=30, backend=backend,
                          chunk=6)
    ks = [int(k) for k in res.k]
    assert len(set(ks)) == len(ks), f"ranks did not separate: {ks}"
    for b, S in enumerate(Ss):
        ref = rb_greedy(S, 1e-8, max_k=30, backend=backend, chunk=6)
        assert int(ref.stop) in (STOP_RANK, STOP_TAU)
        _assert_lane_bitwise(res.lane(b), ref,
                             (np.dtype(dtype).name, backend, b))


def test_list_of_sources_equals_stacked():
    Ss = [_noisy(np.float32, seed=s) for s in (4, 5)]
    a = batch_rb_greedy(Ss, 1e-4, max_k=20)
    b = batch_rb_greedy(jnp.stack(Ss), 1e-4, max_k=20)
    for lane in range(2):
        assert np.array_equal(np.asarray(a.Q[lane]), np.asarray(b.Q[lane]))


# ------------------------------------------------ shared-S fused layout ----


@pytest.mark.parametrize("dtype", DTYPES)
def test_shared_tau_sweep_pivot_parity(dtype):
    """Shared layout: one S swept by B independent basis states (a tau
    sweep).  The fused stacked-plane GEMM sweep is pivot-for-pivot the
    scalar driver (ranks and pivot sequences exact; errs agree to sweep
    float drift)."""
    S = jnp.asarray(make_smooth_matrix(160, 120, dtype))
    taus = [1e-2, 1e-3, 1e-4, 1e-5]
    res = batch_rb_greedy(S, taus, max_k=60, backend="xla", chunk=7)
    assert res.batch == 4
    ks = [int(k) for k in res.k]
    assert ks == sorted(ks)  # tighter tau never needs fewer bases
    for b, tau in enumerate(taus):
        ref = rb_greedy(S, tau, max_k=60, backend="xla", chunk=7)
        lane = res.lane(b)
        k = int(lane.k)
        assert k == int(ref.k), (b, k, int(ref.k))
        assert lane.stop == ref.stop
        assert np.array_equal(np.asarray(lane.pivots)[:k],
                              np.asarray(ref.pivots)[:k]), b
        # errs near the tau floor are cancellation-degenerate (relative
        # comparison meaningless there); pivots + rank above pin the
        # semantics exactly, so compare to the family's scale
        np.testing.assert_allclose(
            np.asarray(lane.errs)[:k], np.asarray(ref.errs)[:k],
            rtol=1e-2, atol=1e-3 * float(ref.errs[0]))


def test_shared_layout_batch_inference():
    S = _noisy(np.float32, seed=7)
    # length-B tau implies B; batch= with scalar tau broadcasts it; a
    # bare scalar tau on a shared source is a 1-lane build
    assert batch_rb_greedy(S, [1e-3, 1e-4], max_k=10).batch == 2
    assert batch_rb_greedy(S, 1e-3, max_k=10, batch=3).batch == 3
    assert batch_rb_greedy(S, 1e-3, max_k=10).batch == 1
    with pytest.raises(ValueError, match="tau"):
        batch_rb_greedy(S, [1e-3, 1e-4, 1e-5], max_k=10, batch=2)


def test_stacked_shape_validation():
    with pytest.raises(ValueError, match="shape"):
        batch_rb_greedy([_noisy(np.float32, N=32), _noisy(np.float32, N=48)],
                        1e-4)


# ------------------------------------------------------- band splitting ----


def test_band_split_layout_and_edges():
    from repro.data import band_split

    S = np.asarray(make_smooth_matrix(128, 40, np.float64))
    split = band_split(S, 4)
    n_freq = 128 // 2 + 1  # one-sided rFFT bins
    h = n_freq // 4
    assert split.batch == 4
    assert split.from_real and split.n_freq == n_freq
    assert split.stack.shape == (4, h, 40)
    assert split.edges == tuple((b * h, (b + 1) * h) for b in range(4))
    # band rows are literally the FFT rows they claim to be
    F = np.fft.rfft(S, axis=0)
    for b, (lo, hi) in enumerate(split.edges):
        np.testing.assert_allclose(np.asarray(split.stack[b]), F[lo:hi],
                                   rtol=1e-6, atol=1e-9)
    # complex input: full (two-sided) FFT
    split_c = band_split(S.astype(np.complex128), 4)
    assert not split_c.from_real and split_c.n_freq == 128

    with pytest.raises(ValueError, match="bands"):
        band_split(S, 0)
    with pytest.raises(ValueError, match="empty"):
        band_split(S, 4096)
    with pytest.raises(ValueError, match="2-D"):
        band_split(np.zeros((4, 4, 4)), 2)


def test_band_split_feeds_batched_build():
    from repro.api import build_basis
    from repro.data import band_split

    split = band_split(make_smooth_matrix(96, 48, np.float64)
                       .astype(np.float32), 3)
    bset = build_basis(source=split, tau=1e-3, max_k=20)
    assert bset.batch == 3
    meta = bset.provenance["bands"]
    assert meta["from_real"] is True
    assert [tuple(e) for e in meta["edges"]] == list(split.edges)
    # each child reduces ITS band bitwise like a scalar build on it
    for b in range(3):
        ref = rb_greedy(split.stack[b], 1e-3, max_k=20)
        k = bset[b].k
        assert k == int(ref.k)
        assert np.array_equal(np.asarray(bset[b].Q),
                              np.asarray(ref.Q[:, :k]))


# ------------------------------------------------------------ front door ----


def test_spec_batched_validation():
    from repro.api import ReductionSpec

    with pytest.raises(ValueError, match="batch"):
        ReductionSpec(source="x", strategy="batched", batch=0)
    with pytest.raises(ValueError, match="batch"):
        ReductionSpec(source="x", strategy="greedy", batch=2)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        ReductionSpec(source="x", strategy="batched", checkpoint_dir="c")
    # batch rides along with auto (it implies the batched strategy)
    ReductionSpec(source="x", strategy="auto", batch=2)


def test_auto_delegates_batched_workloads(caplog):
    import logging

    from repro.api import ReducedBasisSet, build_basis

    stack = jnp.stack([_noisy(np.float32, seed=s) for s in (1, 2)])
    with caplog.at_level(logging.INFO, logger="repro.api"):
        bset = build_basis(source=stack, tau=1e-3, max_k=15)
    assert isinstance(bset, ReducedBasisSet)
    assert any("'batched'" in r.getMessage() for r in caplog.records)
    assert bset.provenance["requested_strategy"] == "auto"
    assert bset.provenance["strategy"] == "batched"


def test_front_door_lane_provenance_and_parity():
    from repro.api import build_basis

    Ss = [_noisy(np.complex64, seed=s) for s in (1, 2)]
    taus = [1e-4, 1e-3]
    bset = build_basis(source=Ss, strategy="batched", tau=taus, max_k=25,
                       chunk=6)
    assert bset.provenance["layout"] == "stacked"
    assert bset.provenance["tau"] == taus
    for b, (S, tau) in enumerate(zip(Ss, taus)):
        ref = rb_greedy(S, tau, max_k=25, chunk=6)
        child = bset[b]
        k = child.k
        assert k == int(ref.k)
        assert np.array_equal(np.asarray(child.Q), np.asarray(ref.Q[:, :k]))
        assert np.array_equal(np.asarray(child.R), np.asarray(ref.R[:k]))
        assert np.array_equal(child.pivots, np.asarray(ref.pivots[:k]))
        lane = child.provenance["lane"]
        assert lane["index"] == b and lane["tau"] == tau
        assert "stop" in lane


def test_set_save_load_register_roundtrip(tmp_path):
    from repro.api import ReducedBasisSet, build_basis_set
    from repro.serving.router import BasisRouter

    bset = build_basis_set(
        source=[_noisy(np.complex64, seed=s) for s in (3, 4)],
        strategy="batched", tau=1e-3, max_k=20)
    d = str(tmp_path / "set")
    bset.save(d)
    assert os.path.exists(os.path.join(d, "set.json"))
    loaded = ReducedBasisSet.load(d)
    assert loaded.batch == 2
    for b in range(2):
        assert loaded[b].k == bset[b].k
        assert np.array_equal(np.asarray(loaded[b].Q),
                              np.asarray(bset[b].Q))
        # children are full artifacts: EIM machinery intact after reload
        nodes, _ = loaded[b].eim()
        assert len(nodes) == loaded[b].k
    router = BasisRouter()
    ids = loaded.register(router, prefix="lane")
    assert ids == ["lane_0", "lane_1"]
    basis, eim = router.get("lane_1")
    assert basis.k == loaded[1].k

    with pytest.raises(FileNotFoundError, match="set"):
        ReducedBasisSet.load(str(tmp_path / "nope"))


def test_workdir_finalize_and_resume(tmp_path):
    from repro.api import build_basis

    wd = str(tmp_path / "wd")
    stack = jnp.stack([_noisy(np.float32, seed=s) for s in (5, 6)])
    built = build_basis(source=stack, strategy="batched", tau=1e-3,
                        max_k=15, workdir=wd)
    assert os.path.exists(os.path.join(wd, "set.json"))
    resumed = build_basis(source=stack, strategy="batched", tau=1e-3,
                          max_k=15, workdir=wd, resume=True)
    for b in range(2):
        assert np.array_equal(np.asarray(resumed[b].Q),
                              np.asarray(built[b].Q))


def test_callback_reports_lockstep_progress():
    seen = []
    batch_rb_greedy(jnp.stack([_noisy(np.float32, seed=s) for s in (1, 2)]),
                    1e-4, max_k=12, chunk=5,
                    callback=lambda info: seen.append(info))
    assert seen  # fired at least once per chunk boundary


def test_floor_stop_lane_matches_scalar_driver():
    """A lane whose refresh lands on the incompressible noise floor must
    latch STOP_FLOOR exactly like the scalar driver (regression: the
    lockstep driver referenced the stop code without importing it, so
    this path raised NameError instead of stopping)."""
    from repro.core.greedy import STOP_FLOOR

    # the test_fault_matrix floor-regime recipe: smooth modes cliffing
    # onto a ~2e-6 noise floor, tau below it, aggressive refresh cadence
    rng = np.random.default_rng(7)
    U, _ = np.linalg.qr(rng.standard_normal((200, 50)))
    V, _ = np.linalg.qr(rng.standard_normal((160, 50)))
    sv = np.logspace(0, -4, 50)
    S = ((U * sv) @ V.T
         + 1.45e-7 * rng.standard_normal((200, 160))).astype(np.float32)

    ref = rb_greedy(S, 1e-7, refresh_safety=2e6, backend="xla")
    assert int(ref.stop) == STOP_FLOOR

    res = batch_rb_greedy(np.stack([S, S]), 1e-7, refresh_safety=2e6,
                          backend="xla")
    assert list(res.stops) == [STOP_FLOOR, STOP_FLOOR]
    for b in range(2):
        _assert_lane_bitwise(res.lane(b), ref, ctx=f"floor lane {b}")
