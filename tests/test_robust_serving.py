"""Overload-hardening tests for the serving engine (PR 10).

Covers the failure modes PR 8 had no story for: silent worker death
(futures stranded forever), the close()/submit enqueue race, deadlines
ignored while waiting, plus the new admission pipeline (per-client
quotas, deadline-aware shedding, degraded mode), per-basis circuit
breakers, supervised worker restarts, and generation-counted hot
artifact reload.  The invariant everything here defends: every submit
resolves EXACTLY one way — bitwise-correct result, or one distinct
explicit error — and never hangs.
"""

import os
import threading
import time

import numpy as np
import pytest

from repro.api import ReducedBasis, build_basis
from repro.serving import (
    AdmissionController,
    CircuitBreakerBoard,
    CircuitOpenError,
    EngineClosedError,
    EngineUnhealthyError,
    QueueFullError,
    QuotaExceededError,
    RestartPolicy,
    RestartTracker,
    ROQEngine,
    ShedError,
    direct_interpolate,
)
from tests.conftest import make_smooth_matrix

WAIT_S = 10.0


def _requests(basis, n, seed=0):
    rng = np.random.default_rng(seed)
    dtype = np.asarray(basis.Q).dtype
    f = rng.standard_normal((basis.k, n))
    if np.issubdtype(dtype, np.complexfloating):
        f = f + 1j * rng.standard_normal((basis.k, n))
    return f.astype(dtype)


@pytest.fixture(scope="module")
def artifact(tmp_path_factory):
    root = tmp_path_factory.mktemp("robust_bases")
    basis = build_basis(source=make_smooth_matrix(96, 50, np.float32),
                        strategy="greedy", tau=1e-5, max_k=6)
    d = str(root / "a")
    basis.save(d)
    return d


def _wait_until(cond, timeout=WAIT_S, step=0.005):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if cond():
            return True
        time.sleep(step)
    return False


# ----------------------------------------------------- worker death ----

def test_worker_death_fails_futures_and_restarts(artifact, monkeypatch):
    """Regression for PR 8's silent failure mode: a fault injected into
    the BATCHING loop (outside per-batch isolation) must fail every
    in-flight future with EngineUnhealthyError — never strand them — and
    the supervised worker must come back and serve again."""
    monkeypatch.setenv("REPRO_FAULT_SERVE_KILL_WORKER", "1")
    with ROQEngine({"a": artifact}, max_batch=8, max_wait_ms=1.0,
                   restart=RestartPolicy(backoff_base_s=0.01)) as eng:
        basis, eim = eng.router.get("a")
        F = _requests(basis, 3)
        futs = [eng.submit("a", F[:, j]) for j in range(3)]
        for fut in futs:   # the killed batch: failed, not hung
            with pytest.raises(EngineUnhealthyError):
                fut.result(timeout=WAIT_S)
        assert _wait_until(eng.healthy)   # supervision restarted it
        f = _requests(basis, 1, seed=7)[:, 0]
        out = eng.submit("a", f).result(timeout=WAIT_S)
        assert np.array_equal(out, direct_interpolate(eim, f))
    snap = eng.stats()
    assert snap["counters"]["worker_deaths"] == 1
    assert snap["counters"]["worker_restarts"] == 1
    trans = snap["health"]["transitions"]
    assert [t["healthy"] for t in trans] == [True, False, True]


def test_worker_death_without_restart_latches_unhealthy(
        artifact, monkeypatch):
    monkeypatch.setenv("REPRO_FAULT_SERVE_KILL_WORKER", "1")
    eng = ROQEngine({"a": artifact}, max_batch=8, max_wait_ms=1.0,
                    restart=RestartPolicy(enabled=False))
    basis, _ = eng.router.get("a")
    fut = eng.submit("a", _requests(basis, 1)[:, 0])
    with pytest.raises(EngineUnhealthyError):
        fut.result(timeout=WAIT_S)
    assert _wait_until(lambda: not eng.healthy())
    with pytest.raises(EngineUnhealthyError):   # intake refused while down
        eng.submit("a", _requests(basis, 1)[:, 0])
    snap = eng.stats()
    assert snap["counters"]["worker_deaths"] == 1
    assert snap["counters"]["worker_restarts"] == 0
    assert snap["healthy"] is False
    eng.close()


def test_restart_tracker_window_and_backoff():
    p = RestartPolicy(max_restarts=2, window_s=10.0,
                      backoff_base_s=0.5, backoff_cap_s=4.0)
    tr = RestartTracker(p)
    assert tr.next_delay(now=100.0) == 0.5          # 2**0
    assert tr.next_delay(now=100.1) == 1.0          # 2**1
    assert tr.next_delay(now=100.2) is None         # budget exhausted
    assert tr.next_delay(now=111.0) == 0.5          # window slid
    assert RestartTracker(RestartPolicy(enabled=False)).next_delay() is None


# ----------------------------------------------------- close()/submit race ----

def test_submit_racing_close_never_strands_future(artifact):
    """A request enqueued between submit's intake check and close()'s
    final drain must still resolve (with EngineClosedError), not hang."""
    eng = ROQEngine({"a": artifact}, start=False)
    basis = ReducedBasis.load(artifact)
    orig_put = eng._queue.put_nowait

    def racing_put(req):   # close() wins the race right after the enqueue
        orig_put(req)
        eng._closed = True

    eng._queue.put_nowait = racing_put
    fut = eng.submit("a", _requests(basis, 1)[:, 0])
    assert fut.done()
    with pytest.raises(EngineClosedError):
        fut.result(timeout=0)
    eng._queue.put_nowait = orig_put
    eng.close(drain=False)


def test_close_drains_queue_left_by_dead_worker(artifact, monkeypatch):
    """Even with the worker down and restarts disabled, close() fails
    whatever is still queued — exactly-once resolution, no strands."""
    monkeypatch.setenv("REPRO_FAULT_SERVE_KILL_WORKER", "1")
    eng = ROQEngine({"a": artifact}, max_batch=8, max_wait_ms=1.0,
                    restart=RestartPolicy(enabled=False))
    basis, _ = eng.router.get("a")
    fut = eng.submit("a", _requests(basis, 1)[:, 0])
    with pytest.raises(EngineUnhealthyError):
        fut.result(timeout=WAIT_S)
    assert _wait_until(lambda: not eng._worker.is_alive())
    # worker is gone; sneak a request past intake onto the dead queue
    req = _mkreq(basis)
    eng._queue.put_nowait(req)
    stranded = req.future
    eng.close()
    assert stranded.done()
    with pytest.raises(EngineClosedError):
        stranded.result(timeout=0)


def _mkreq(basis):
    import concurrent.futures

    from repro.serving.roq import _Request

    return _Request(basis_id="a", f=_requests(basis, 1)[:, 0],
                    future=concurrent.futures.Future(),
                    t_submit=time.perf_counter(), deadline=None)


# ------------------------------------------------- deadlines while waiting ----

def test_deadline_enforced_while_waiting(artifact):
    """timeout_s far below max_wait_ms gets a PROMPT TimeoutError — the
    poll wakes for the earliest pending deadline instead of dozing until
    the flush timer."""
    with ROQEngine({"a": artifact}, max_batch=64,
                   max_wait_ms=2000.0) as eng:
        basis, _ = eng.router.get("a")
        t0 = time.monotonic()
        fut = eng.submit("a", _requests(basis, 1)[:, 0], timeout_s=0.05)
        with pytest.raises(TimeoutError):
            fut.result(timeout=WAIT_S)
        elapsed = time.monotonic() - t0
        assert elapsed < 1.0, f"deadline enforced lazily ({elapsed:.2f}s)"
    assert eng.stats()["counters"]["timeouts"] == 1


# ------------------------------------------------------------- admission ----

def test_quota_token_bucket_per_client():
    ctl = AdmissionController(client_rate=10.0, client_burst=2)
    now = 1000.0
    ctl.admit("alice", None, now)
    ctl.admit("alice", None, now)
    with pytest.raises(QuotaExceededError):
        ctl.admit("alice", None, now)       # burst spent
    ctl.admit("bob", None, now)             # other clients unaffected
    ctl.admit("alice", None, now + 0.1)     # refilled one token (10/s)
    with pytest.raises(QuotaExceededError):
        ctl.admit("alice", None, now + 0.1)


def test_quota_tightens_in_degraded_mode():
    ctl = AdmissionController(client_rate=10.0, client_burst=1,
                              degraded_factor=0.5)
    now = 1000.0
    ctl.admit("c", None, now)
    assert ctl.set_degraded(True)
    # refill is halved: 0.1s * 10/s * 0.5 = 0.5 tokens — not enough
    with pytest.raises(QuotaExceededError):
        ctl.admit("c", None, now + 0.1)
    ctl.admit("c", None, now + 0.2)   # 1.0 tokens under the halved rate
    assert ctl.set_degraded(False)
    assert not ctl.set_degraded(False)   # idempotent


def test_shed_hopeless_deadline():
    ctl = AdmissionController(delay_estimator=lambda: 1.0)
    now = 1000.0
    with pytest.raises(ShedError):
        ctl.admit(None, now + 0.1, now)    # 100ms budget vs 1s backlog
    ctl.admit(None, now + 5.0, now)        # feasible deadline admitted
    ctl.admit(None, None, now)             # no deadline: never shed
    cold = AdmissionController(delay_estimator=lambda: 0.0)
    cold.admit(None, now + 1e-9, now)      # no backlog estimate: admit


def test_engine_sheds_under_measured_backlog(artifact):
    eng = ROQEngine({"a": artifact}, max_batch=4, start=False)
    basis, _ = eng.router.get("a")
    eng._batch_ewma_s = 1.0     # pretend batches take 1s
    for j in range(8):          # unserviced backlog: est = 8/4 * 1s = 2s
        eng.submit("a", _requests(basis, 1)[:, 0])
    with pytest.raises(ShedError):
        eng.submit("a", _requests(basis, 1)[:, 0], timeout_s=0.01)
    eng.submit("a", _requests(basis, 1)[:, 0], timeout_s=30.0)
    snap = eng.stats()
    assert snap["counters"]["shed"] == 1
    assert snap["estimated_delay_ms"] > 0
    eng.close(drain=False)


def test_degraded_mode_watermarks_and_hysteresis(artifact):
    eng = ROQEngine({"a": artifact}, max_batch=4, queue_depth=8,
                    degrade_queue_frac=0.5, start=False)
    basis, _ = eng.router.get("a")
    for j in range(5):          # 5/8 = 62% > 50% watermark
        eng.submit("a", _requests(basis, 1)[:, 0])
    eng._update_pressure(time.perf_counter())
    assert eng.admission.degraded
    eng._fail_all_pending(EngineClosedError("test drain"))
    eng._last_pressure_check = 0.0   # bypass the 20 Hz throttle
    eng._update_pressure(time.perf_counter())   # 0/8 <= half watermark
    assert not eng.admission.degraded
    snap = eng.stats()
    assert snap["counters"]["degraded_entered"] == 1
    assert snap["counters"]["degraded_exited"] == 1
    assert snap["gauges"]["degraded"] == 0
    eng.close(drain=False)


# ------------------------------------------------------ circuit breakers ----

def test_breaker_lifecycle_unit():
    bd = CircuitBreakerBoard(threshold=2, cooldown_s=5.0)
    bd.allow("b", now=0.0)
    bd.record_failure("b", now=0.0)
    bd.allow("b", now=0.1)                     # under threshold: closed
    bd.record_failure("b", now=0.2)            # 2nd consecutive -> OPEN
    assert bd.state("b") == "open"
    with pytest.raises(CircuitOpenError):
        bd.allow("b", now=1.0)                 # inside cooldown
    bd.allow("b", now=6.0)                     # cooldown over -> HALF_OPEN
    assert bd.state("b") == "half_open"
    bd.on_batch_start("b")                     # probe batch in flight
    with pytest.raises(CircuitOpenError):
        bd.allow("b", now=6.1)
    bd.record_success("b")                     # probe served -> CLOSED
    assert bd.state("b") == "closed"
    bd.allow("b", now=6.2)
    # a failed probe re-opens immediately (no threshold accumulation)
    bd.record_failure("b", now=7.0)
    bd.record_failure("b", now=7.1)
    bd.allow("b", now=13.0)                    # half-open again
    bd.record_failure("b", now=13.1)
    assert bd.state("b") == "open"


def test_engine_breaker_opens_and_recovers(artifact):
    with ROQEngine({"a": artifact}, max_batch=4, max_wait_ms=0.5,
                   breaker_threshold=2, breaker_cooldown_s=0.2) as eng:
        basis, eim = eng.router.get("a")
        real_evaluate = eng.cache.evaluate

        def broken(*a, **k):
            raise RuntimeError("injected basis meltdown")

        eng.cache.evaluate = broken
        for _ in range(2):   # two consecutive failed batches -> OPEN
            fut = eng.submit("a", _requests(basis, 1)[:, 0])
            with pytest.raises(RuntimeError, match="meltdown"):
                fut.result(timeout=WAIT_S)
        with pytest.raises(CircuitOpenError):   # fast-fail, no queueing
            eng.submit("a", _requests(basis, 1)[:, 0])
        eng.cache.evaluate = real_evaluate
        time.sleep(0.3)      # past cooldown: next request is the probe
        f = _requests(basis, 1, seed=3)[:, 0]
        out = eng.submit("a", f).result(timeout=WAIT_S)
        assert np.array_equal(out, direct_interpolate(eim, f))
        assert eng.breakers.state("a") == "closed"
    snap = eng.stats()
    assert snap["counters"]["breaker_opened"] >= 1
    assert snap["counters"]["breaker_rejected"] >= 1
    assert snap["counters"]["breaker_half_open"] >= 1
    assert snap["counters"]["breaker_closed"] >= 1


# ------------------------------------------------------- hot artifact reload ----

def test_refresh_swaps_generations_bitwise(tmp_path):
    d = str(tmp_path / "hot")
    b1 = build_basis(source=make_smooth_matrix(80, 40, np.float32),
                     strategy="greedy", tau=1e-5, max_k=4)
    b1.save(d)
    with ROQEngine({"hot": d}, max_batch=4, max_wait_ms=0.5) as eng:
        basis1, eim1 = eng.router.get("hot")
        f1 = _requests(basis1, 1)[:, 0]
        out1 = eng.submit("hot", f1).result(timeout=WAIT_S)
        assert np.array_equal(out1, direct_interpolate(eim1, f1))
        # rebuild offline (larger basis), save a NEW artifact step in place
        b2 = build_basis(source=make_smooth_matrix(80, 40, np.float32),
                         strategy="greedy", tau=1e-6, max_k=8)
        b2.save(d)
        gen = eng.refresh("hot")
        assert gen == 1
        basis2, eim2 = eng.router.get("hot")
        assert basis2.k == b2.k
        f2 = _requests(basis2, 1, seed=5)[:, 0]
        out2 = eng.submit("hot", f2).result(timeout=WAIT_S)
        assert np.array_equal(out2, direct_interpolate(eim2, f2))
        # old generation's warm entries were retired, new gen is live
        assert all(k[1] == 1 for k in eng.cache.warm_keys("hot"))
    snap = eng.stats()
    assert snap["counters"]["reloads"] == 1
    assert snap["router"]["generations"] == {"hot": 1}


def test_refresh_rejects_corrupt_candidate_keeps_serving(tmp_path):
    d = str(tmp_path / "hot")
    b1 = build_basis(source=make_smooth_matrix(64, 32, np.float32),
                     strategy="greedy", tau=1e-5, max_k=4)
    b1.save(d)
    with ROQEngine({"hot": d}, max_batch=4, max_wait_ms=0.5) as eng:
        basis, eim = eng.router.get("hot")
        # a rebuild lands... and rots on disk before the swap
        b2 = build_basis(source=make_smooth_matrix(64, 32, np.float32),
                         strategy="greedy", tau=1e-6, max_k=6)
        b2.save(d)
        from repro.checkpoint.io import list_steps

        step_dir = os.path.join(d, f"step_{list_steps(d)[-1]:08d}")
        victim = next(p for p in sorted(os.listdir(step_dir))
                      if p.endswith(".npy"))
        path = os.path.join(step_dir, victim)
        raw = bytearray(open(path, "rb").read())
        raw[-1] ^= 0xFF
        open(path, "wb").write(bytes(raw))
        with pytest.raises((IOError, KeyError)):
            eng.refresh("hot")
        # live basis untouched: same generation, still serving bitwise
        f = _requests(basis, 1, seed=2)[:, 0]
        out = eng.submit("hot", f).result(timeout=WAIT_S)
        assert np.array_equal(out, direct_interpolate(eim, f))
    snap = eng.stats()
    assert snap["counters"]["reload_failures"] == 1
    assert snap["counters"]["reloads"] == 0
    assert snap["router"]["generations"] == {}


def test_refresh_injected_corruption_hook(artifact, monkeypatch):
    monkeypatch.setenv("REPRO_FAULT_SERVE_CORRUPT_RELOAD", "1")
    with ROQEngine({"a": artifact}, max_wait_ms=0.5) as eng:
        with pytest.raises(IOError, match="injected corrupt reload"):
            eng.refresh("a")
    assert eng.stats()["counters"]["reload_failures"] == 1


# ------------------------------------------------------- overload soak ----

def test_overload_soak_every_submit_resolves_exactly_once(
        artifact, monkeypatch):
    """Sustained overload with slow batches, tight queue, quotas, and
    mixed deadlines: every submit ends in EXACTLY one bucket — bitwise
    result, QueueFullError, ShedError, QuotaExceededError, or
    TimeoutError — and the metrics counters sum to the offered load."""
    monkeypatch.setenv("REPRO_FAULT_SERVE_SLOW_BATCH", "3")   # 3ms/batch
    eng = ROQEngine({"a": artifact}, max_batch=4, max_wait_ms=1.0,
                    queue_depth=16, client_rate=400.0, client_burst=40.0)
    basis, eim = eng.router.get("a")
    n_threads, per_thread = 4, 60
    lock = threading.Lock()
    sync_rejects = {"queue_full": 0, "shed": 0, "quota": 0}
    accepted = []   # (future, f_vector)

    def client(tid):
        rng = np.random.default_rng(tid)
        for i in range(per_thread):
            f = _requests(basis, 1, seed=tid * 1000 + i)[:, 0]
            timeout = None if rng.random() < 0.5 else \
                float(rng.choice([0.002, 0.05, 5.0]))
            try:
                fut = eng.submit("a", f, timeout_s=timeout,
                                 client_id=f"client-{tid}")
            except QueueFullError:
                with lock:
                    sync_rejects["queue_full"] += 1
            except ShedError:
                with lock:
                    sync_rejects["shed"] += 1
            except QuotaExceededError:
                with lock:
                    sync_rejects["quota"] += 1
            else:
                with lock:
                    accepted.append((fut, f))

    threads = [threading.Thread(target=client, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    eng.close(drain=True)   # serve/fail everything accepted

    offered = n_threads * per_thread
    served = timed_out = 0
    for fut, f in accepted:
        err = fut.exception(timeout=WAIT_S)   # never hangs
        if err is None:
            assert np.array_equal(fut.result(), direct_interpolate(eim, f))
            served += 1
        elif isinstance(err, TimeoutError):
            timed_out += 1
        else:
            pytest.fail(f"unexpected resolution: {err!r}")
    assert served + timed_out == len(accepted)
    assert len(accepted) + sum(sync_rejects.values()) == offered

    c = eng.stats()["counters"]
    assert c["submitted"] == len(accepted)
    assert c["completed"] == served
    assert c["timeouts"] == timed_out
    assert c["rejected"] == sync_rejects["queue_full"]
    assert c["shed"] == sync_rejects["shed"]
    assert c["quota_rejected"] == sync_rejects["quota"]
    assert c["submitted"] == c["completed"] + c["timeouts"] + c["errors"]
    assert c["errors"] == 0
    assert c["worker_deaths"] == 0
